"""Pallas TPU kernel: simLSH weighted sign-projection (paper Alg. 1, Eq. 3).

Computes, for a tile of items (columns), the pre-sign accumulator

    S[n, g] = Σ_d  Ψ(r)[n, d] · Φ[n, d, g]

over ELL-padded per-item rater lists (degree-padded to ``deg``), i.e. a
batched [1, deg] × [deg, bits] matvec per item — MXU-shaped.  The CUDA
version assigns one thread block per item and warp-shuffles the reduction;
the TPU version tiles (items × deg × bits) into VMEM and lets the MXU do
the contraction (DESIGN.md §2 hardware adaptation).

Grid: items/TILE_N.  Block shapes keep the working set in VMEM:
TILE_N·deg f32 + TILE_N·deg·bits f32 + TILE_N·bits f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _encode_kernel(psi_ref, phi_ref, out_ref):
    # psi  [TILE_N, deg]        — Ψ(r) weights (0 at padding)
    # phi  [TILE_N, deg, bits]  — ±1 rows Φ(H_i) for this item's raters
    # out  [TILE_N, bits]
    psi = psi_ref[...]
    phi = phi_ref[...]
    acc = jnp.einsum("nd,ndb->nb", psi, phi,
                     preferred_element_type=jnp.float32)
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("tile_n", "interpret"))
def simlsh_encode(psi, phi, *, tile_n: int = 8, interpret: bool = True):
    """psi [N, deg] f32, phi [N, deg, bits] f32 → S [N, bits] f32."""
    N, deg = psi.shape
    bits = phi.shape[-1]
    pad = (-N) % tile_n
    if pad:
        psi = jnp.pad(psi, ((0, pad), (0, 0)))
        phi = jnp.pad(phi, ((0, pad), (0, 0), (0, 0)))
    Np = psi.shape[0]

    out = pl.pallas_call(
        _encode_kernel,
        grid=(Np // tile_n,),
        in_specs=[
            pl.BlockSpec((tile_n, deg), lambda i: (i, 0)),
            pl.BlockSpec((tile_n, deg, bits), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_n, bits), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Np, bits), jnp.float32),
        interpret=interpret,
    )(psi, phi)
    return out[:N]
