"""jit'd wrapper: ELL conversion + kernel dispatch for simLSH encoding.

``encode_band`` reproduces core/simlsh.band_accumulate through the Pallas
kernel: per-item rater lists are ELL-padded (host/XLA side — data movement,
not the hot loop), Φ rows are generated with the same stateless fold_in
scheme, and the kernel does the fused weighted projection.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.simlsh import SimLSHConfig, phi_rows, psi
from repro.data.sparse import SparseMatrix
from repro.kernels.simlsh_encode.kernel import simlsh_encode


def ell_pack(sp: SparseMatrix, deg: int):
    """Column-major ELL: rater ids + ratings per item, padded to ``deg``.

    Returns (row_ids [N, deg] i32 (0-padded), vals [N, deg] f32 (0-padded)).
    Items with more than ``deg`` raters are truncated (cap documented)."""
    order = jnp.argsort(sp.cols)
    cols_s, rows_s, vals_s = sp.cols[order], sp.rows[order], sp.vals[order]
    first = jnp.searchsorted(cols_s, jnp.arange(sp.N, dtype=cols_s.dtype))
    rank = jnp.arange(sp.rows.shape[0]) - first[cols_s]
    ok = rank < deg
    addr = jnp.where(ok, cols_s * deg + rank, sp.N * deg)
    ids = jnp.zeros((sp.N * deg + 1,), jnp.int32).at[addr].set(rows_s)
    vals = jnp.zeros((sp.N * deg + 1,), jnp.float32).at[addr].set(
        jnp.where(ok, vals_s, 0.0))
    return (ids[:-1].reshape(sp.N, deg),
            vals[:-1].reshape(sp.N, deg))


def encode_band(sp: SparseMatrix, cfg: SimLSHConfig, key, band, *,
                deg: int = 128, interpret: bool = True):
    """One band's pre-sign accumulators via the Pallas kernel. [N, bits]."""
    ids, vals = ell_pack(sp, deg)
    w = psi(vals, cfg.psi_pow, cfg.psi_mode, cfg.psi_center) * (vals != 0)
    phi = phi_rows(key, band, ids.reshape(-1), cfg.sig_bits)
    phi = phi.reshape(sp.N, deg, cfg.sig_bits)
    return simlsh_encode(w, phi, interpret=interpret)
