"""Pure-jnp oracle for the simlsh_encode kernel."""
import jax.numpy as jnp


def simlsh_encode_ref(psi, phi):
    """psi [N, deg], phi [N, deg, bits] → [N, bits] f32."""
    return jnp.einsum("nd,ndb->nb", psi.astype(jnp.float32),
                      phi.astype(jnp.float32))
