"""Pallas TPU kernel: in-VMEM LSH bucket walk + dedup → candidate ids.

The other half of the serving hot path.  `candidate_score` moved the
score-side gather into the chip; this kernel does the same for retrieval.
Window *descriptors* (flat start + valid length per (seed, band) bucket
window, from `serve.index.window_slices`) enter scalar-prefetched SMEM;
the flattened sorted-id plane stays in HBM (`pltpu.ANY`) and each user's
I windows are DMA'd as static ``cap``-wide slices into a VMEM scratch
tile — the ``[B, pool]`` gathered-id intermediate and the host-side
``[B, ~1100]`` dedup sort never exist in HBM.  The walk is
double-buffered across grid steps: scratch persists between sequential
programs, so while user ``b``'s pool is folded, user ``b+1``'s windows
are already in flight.

In VMEM the pool (windows ‖ extras) is masked to the valid prefixes,
exclusions knocked out, and pushed through the same invertible 30-bit
multiplicative hash `retrieve.dedup_candidates` uses.  Dedup is two
bitonic sorting networks over the power-of-two padded row: sort once
(duplicate hashes become adjacent — the hash is injective on [0, 2³⁰)),
mark repeats as INTMAX padding, sort again to compact, unhash the first
C.  A sorting network is the right shape on-chip: ~log²(W)/2 static
compare-exchange stages of full-row vector ops, no data-dependent
control flow.  Output is exactly the `ref.lsh_retrieve_topc_ref`
contract — unique ids in hashed order — so candidate ids can feed the
`candidate_score` kernel's scalar-prefetch operand directly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.topk import SENTINEL
from repro.kernels.lsh_retrieve.ref import INTMAX, INV, MASK30, MULT


def _bitonic_sort_row(x):
    """Ascending bitonic sort of one [1, W] int32 row, W a power of two.
    Fully static: log(W)·(log(W)+1)/2 compare-exchange stages, each a
    reshape + min/max/select over the whole row."""
    W = x.shape[1]
    assert W & (W - 1) == 0, "bitonic width must be a power of two"
    k = 2
    while k <= W:
        j = k // 2
        while j >= 1:
            y = x.reshape(W // (2 * j), 2, j)
            a, b = y[:, 0, :], y[:, 1, :]
            # element index of a[g, t] is g·2j + t; ascending block iff
            # its index has bit k clear (the standard bitonic direction)
            idx = (jax.lax.broadcasted_iota(jnp.int32, (W // (2 * j), j), 0)
                   * (2 * j)
                   + jax.lax.broadcasted_iota(jnp.int32, (W // (2 * j), j), 1))
            up = (idx & k) == 0
            lo = jnp.minimum(a, b)
            hi = jnp.maximum(a, b)
            y = jnp.stack([jnp.where(up, lo, hi), jnp.where(up, hi, lo)],
                          axis=1)
            x = y.reshape(1, W)
            j //= 2
        k *= 2
    return x


def _retrieve_kernel(starts_ref, exclude_ref, lens_ref, extra_ref, ids_ref,
                     cand_out, wins, sem, *, C: int, cap: int, E: int,
                     Wp: int):
    """starts_ref [B, I] int32 SMEM (scalar prefetch); exclude_ref [E]
    int32 SMEM (scalar prefetch); lens_ref [1, I] VMEM; extra_ref [1, X]
    VMEM; ids_ref [q·N + cap] in ANY/HBM; cand_out [1, C]; wins
    [2, I, cap] VMEM scratch (double buffer); sem [2] DMA."""
    I = lens_ref.shape[1]
    X = extra_ref.shape[1]
    b = pl.program_id(0)
    nb = pl.num_programs(0)

    def win_dma(slot, u, i):
        # one static cap-wide window slice, HBM → the slot's scratch row;
        # the id plane's SENTINEL apron keeps the tail read in-bounds
        return pltpu.make_async_copy(
            ids_ref.at[pl.ds(starts_ref[u, i], cap)],
            wins.at[slot, i], sem.at[slot])

    def start_user(slot, u):
        jax.lax.fori_loop(
            0, I, lambda i, _: (win_dma(slot, u, i).start(), 0)[1], 0)

    def wait_user(slot, u):
        jax.lax.fori_loop(
            0, I, lambda i, _: (win_dma(slot, u, i).wait(), 0)[1], 0)

    slot = jax.lax.rem(b, 2)

    @pl.when(b == 0)
    def _():                       # cold start: first user's windows
        start_user(0, 0)

    @pl.when(b + 1 < nb)
    def _():                       # prefetch next user into the other slot
        start_user(1 - slot, b + 1)

    wait_user(slot, b)

    ok = (jax.lax.broadcasted_iota(jnp.int32, (I, cap), 1)
          < lens_ref[0, :][:, None])
    pool = jnp.where(ok, wins[slot], SENTINEL).reshape(1, I * cap)
    pool = jnp.concatenate([pool, extra_ref[...]], axis=1)  # [1, I·cap + X]
    for e in range(E):             # static unroll over the tiny exclude set
        pool = jnp.where(pool == exclude_ref[e], SENTINEL, pool)
    valid = (pool != SENTINEL) & (pool >= 0)
    h = jnp.where(valid, (pool * MULT) & MASK30, INTMAX)
    W = I * cap + X
    if Wp > W:
        h = jnp.concatenate(
            [h, jnp.full((1, Wp - W), INTMAX, jnp.int32)], axis=1)
    h = _bitonic_sort_row(h)
    prev = jnp.concatenate(
        [jnp.full((1, 1), -1, jnp.int32), h[:, :-1]], axis=1)
    h = jnp.where((h != prev) & (h != INTMAX), h, INTMAX)
    h = _bitonic_sort_row(h)       # compact survivors left
    keys = h[:, :C]
    cand_out[...] = jnp.where(keys != INTMAX, (keys * INV) & MASK30, SENTINEL)


@functools.partial(jax.jit, static_argnames=("C", "cap", "interpret"))
def lsh_retrieve_topc(starts, lens, extra, ids_flat, exclude, *, C: int,
                      cap: int, interpret: bool = True):
    """starts/lens [B, I] int32 window descriptors; extra [B, X] int32
    SENTINEL-padded appended ids; ids_flat [q·N + cap] int32
    (`padded_flat_ids` — the apron is load-bearing, see `win_dma`);
    exclude [E] int32 → cand [B, C] int32 unique ids, SENTINEL-padded,
    in hashed order (the `ref.lsh_retrieve_topc_ref` contract)."""
    B, I = starts.shape
    X = extra.shape[1]
    W = I * cap + X
    assert C <= W, f"candidate budget C={C} exceeds pool width {W}"
    Wp = 1 << (W - 1).bit_length()             # next power of two
    E = exclude.shape[0]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                 # starts, exclude → SMEM
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, I), lambda b, *_: (b, 0)),
            pl.BlockSpec((1, X), lambda b, *_: (b, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),  # id plane stays in HBM
        ],
        out_specs=pl.BlockSpec((1, C), lambda b, *_: (b, 0)),
        scratch_shapes=[pltpu.VMEM((2, I, cap), jnp.int32),
                        pltpu.SemaphoreType.DMA((2,))],
    )
    return pl.pallas_call(
        functools.partial(_retrieve_kernel, C=C, cap=cap, E=E, Wp=Wp),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, C), jnp.int32),
        interpret=interpret,
    )(starts, exclude, lens, extra, ids_flat)
