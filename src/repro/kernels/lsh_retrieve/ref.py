"""Pure-jnp oracle for the fused LSH-retrieval kernel.

Mirrors the kernel's contract bit for bit: window descriptors come in
(flat starts + valid lengths from `serve.index.window_slices`), each
descriptor is expanded as a static ``cap``-wide read of the padded flat
id plane, extras (tail hits) are appended, exclusions and invalid slots
are masked, and the surviving ids are deduplicated through the same
invertible 30-bit multiplicative hash the kernel sorts in VMEM.  The
output is each user's first C unique ids in *hashed* order — identical
to the kernel because both reduce to "sort the same multiset of hash
keys, drop duplicate neighbours, sort again, unhash the first C".

Kept separate from `serve.retrieve`'s walk path on purpose: the walk
path never materialises a dedup at all (duplicates survive to top-n
selection); this oracle exists so interpret-mode kernel tests have an
exact reference for the in-VMEM dedup.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.topk import SENTINEL

# same invertible multiplicative hash pair as retrieve.dedup_candidates:
# h = 2654435761·x mod 2³⁰ (as int32), x = 244002641·h mod 2³⁰
MULT = -1640531535
INV = 244002641
MASK30 = 0x3FFFFFFF
# sort-domain padding: above every 30-bit hash, so padding sinks last
INTMAX = 0x7FFFFFFF


def lsh_retrieve_topc_ref(starts, lens, extra, ids_flat, exclude, *,
                          C: int, cap: int):
    """starts/lens [B, I] int32 (`window_slices` descriptors); extra
    [B, X] int32 SENTINEL-padded ids appended to the pool (tail hits);
    ids_flat [q·N + cap] int32 (`padded_flat_ids`); exclude [E] int32 ids
    dropped from the output (SENTINEL entries inert) → cand [B, C] int32,
    each user's unique pool ids in hashed order, SENTINEL-padded."""
    B, I = starts.shape
    pos = starts[:, :, None] + jnp.arange(cap, dtype=jnp.int32)    # [B,I,cap]
    ids = ids_flat[pos]
    ok = jnp.arange(cap, dtype=jnp.int32)[None, None, :] < lens[:, :, None]
    pool = jnp.concatenate(
        [jnp.where(ok, ids, SENTINEL).reshape(B, I * cap), extra], axis=1)
    excluded = jnp.any(pool[:, :, None] == exclude[None, None, :], axis=2)
    valid = (pool != SENTINEL) & (pool >= 0) & ~excluded
    h = jnp.where(valid, (pool * jnp.int32(MULT)) & jnp.int32(MASK30),
                  jnp.int32(INTMAX))
    h = jnp.sort(h, axis=1)
    prev = jnp.concatenate([jnp.full((B, 1), -1, h.dtype), h[:, :-1]], axis=1)
    h = jnp.where((h != prev) & (h != INTMAX), h, jnp.int32(INTMAX))
    h = jnp.sort(h, axis=1)[:, :C]
    return jnp.where(h != INTMAX, (h * jnp.int32(INV)) & jnp.int32(MASK30),
                     SENTINEL)
