"""jit'd wrapper: seeds → window descriptors → in-VMEM walk + dedup →
[B, C] candidate ids.

The retrieval-side twin of `candidate_score.ops.score_candidates`: host
code builds only the micro-batch-sized descriptor tensors (starts/lens
[B, I], tail extras [B, X]); the catalog-sized work — walking the bucket
windows and deduplicating the union — happens inside the kernel against
the HBM-resident id plane.  The output feeds `score_candidates`'s
scalar-prefetch candidate operand directly, so on TPU the fused
recommend path is two chained kernels with no [B, pool] intermediate.

``impl='ref'`` swaps in the pure-jnp oracle (`ref.lsh_retrieve_topc_ref`)
with the identical contract — the CPU path, where Pallas only has the
(slow) interpreter.  Note the *serving* CPU fast path does not dedup at
all (`service.recommend_walked` defers duplicates to top-n selection);
this wrapper is the contract for accelerators and for parity tests.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.topk import SENTINEL
from repro.data.sparse import SparseMatrix
from repro.kernels.lsh_retrieve.kernel import lsh_retrieve_topc
from repro.kernels.lsh_retrieve.ref import lsh_retrieve_topc_ref
from repro.serve.index import LSHIndex, padded_flat_ids, window_slices
from repro.serve.retrieve import seed_items, tail_hits


@partial(jax.jit, static_argnames=("n_seeds", "cap", "C", "window",
                                   "tail_scan", "interpret", "impl"))
def retrieve_candidates(index: LSHIndex, sp: SparseMatrix,
                        user_ids: jax.Array, *, n_seeds: int, cap: int,
                        C: int, popular: jax.Array | None = None,
                        window: int = 64, tail_scan: bool = True,
                        interpret: bool = True, impl: str = "pallas",
                        ids_flat: jax.Array | None = None) -> jax.Array:
    """user_ids [B] → cand [B, C] int32 unique candidate ids,
    SENTINEL-padded.  Same slot layout as `retrieve.finalize_candidates`:
    when ``popular`` [P] is given it occupies reserved trailing slots and
    is excluded from the walked core (inside the kernel, not by a second
    dedup).  ``ids_flat`` lets services pass a cached `padded_flat_ids`
    plane instead of re-concatenating it per flush."""
    seeds = seed_items(sp, user_ids, n_seeds=n_seeds, window=window)
    starts, lens = window_slices(index, seeds, cap=cap)
    B = user_ids.shape[0]
    if tail_scan and index.tail_cap:
        extra = tail_hits(index, seeds)
    else:                          # X ≥ 1 keeps the kernel shape static
        extra = jnp.full((B, 1), SENTINEL, jnp.int32)
    if ids_flat is None:
        ids_flat = padded_flat_ids(index, cap=cap)
    if popular is not None:
        P = popular.shape[0]
        assert C > P, f"candidate budget C={C} must exceed the shortlist {P}"
        exclude, core_C = popular, C - P
    else:
        exclude = jnp.full((1,), SENTINEL, jnp.int32)
        core_C = C
    fn = lsh_retrieve_topc_ref if impl == "ref" else partial(
        lsh_retrieve_topc, interpret=interpret)
    core = fn(starts, lens, extra, ids_flat, exclude, C=core_C, cap=cap)
    if popular is None:
        return core
    return jnp.concatenate(
        [core, jnp.broadcast_to(popular[None, :], (B, P))], axis=1)
