"""jit'd wrapper: gather user/candidate factors → fused score+top-N kernel.

The [B, C, F] candidate-factor gather happens here (XLA gather from the full
V), so the kernel only ever sees dense VMEM tiles; the returned top-N slots
are translated back to global item ids, SENTINEL where a slot was padding.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.topk import SENTINEL
from repro.kernels.candidate_score.kernel import NEG, candidate_score_topn
from repro.kernels.candidate_score.ref import candidate_score_topn_ref


@partial(jax.jit, static_argnames=("topn", "tile_b", "interpret", "impl"))
def score_candidates(params, user_ids: jax.Array, cand: jax.Array, *,
                     topn: int, tile_b: int = 8, interpret: bool = True,
                     impl: str = "pallas"):
    """params (core.model.Params), user_ids [B], cand [B, C] SENTINEL-padded
    → (scores [B, topn], items [B, topn] int32, SENTINEL where deficient).

    ``impl='ref'`` runs the pure-jnp oracle instead of the Pallas kernel —
    the fast path on CPU, where Pallas only has the (slow) interpreter.
    """
    safe = jnp.clip(cand, 0, params.V.shape[0] - 1)
    mask = (cand != SENTINEL).astype(jnp.float32)
    u = params.U[user_ids]
    bu = params.mu + params.b[user_ids]
    vc = params.V[safe]                       # [B, C, F]
    bc = params.bh[safe]
    if impl == "ref":
        scores, idx = candidate_score_topn_ref(u, bu, vc, bc, mask, topn=topn)
    else:
        scores, idx = candidate_score_topn(u, bu, vc, bc, mask, topn=topn,
                                           tile_b=tile_b, interpret=interpret)
    items = jnp.take_along_axis(cand, idx, axis=1)
    items = jnp.where(scores > NEG, items, SENTINEL)
    return scores, items
