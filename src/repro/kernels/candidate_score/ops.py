"""jit'd wrapper: serve-plane row gather → in-kernel candidate gather +
fused score + top-N.

One gather per side: the *row* plane (`U‖b`, micro-batch-sized) is
gathered here and the μ baseline folded into its bias column; the *col*
plane (`V‖b̂`) is handed to the kernel whole, which fetches candidate
rows by id inside (Pallas DMA gather) or per user-tile (jnp ref scan) —
either way the `[B, C, F]` candidate cube of the PR 1 scorer never
materializes.  The returned top-N slots are translated back to global
item ids, SENTINEL where a slot was padding.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.model import Params, ServePlanes, pack_serve_planes
from repro.core.topk import SENTINEL
from repro.kernels.candidate_score.kernel import NEG, candidate_score_topn
from repro.kernels.candidate_score.ref import candidate_score_topn_ref


@partial(jax.jit, static_argnames=("topn", "tile_b", "interpret", "impl"))
def score_candidates(planes, user_ids: jax.Array, cand: jax.Array, *,
                     topn: int, tile_b: int = 8, interpret: bool = True,
                     impl: str = "pallas"):
    """planes (`model.ServePlanes`; a `Params` is packed on the fly for
    compatibility), user_ids [B], cand [B, C] SENTINEL-padded →
    (scores [B, topn], items [B, topn] int32, SENTINEL where deficient).

    ``impl='ref'`` runs the pure-jnp tiled-scan oracle instead of the
    Pallas kernel — the fast path on CPU, where Pallas only has the
    (slow) interpreter.
    """
    if isinstance(planes, Params):
        planes = pack_serve_planes(planes)
    F = planes.F
    safe = jnp.clip(cand, 0, planes.n_items - 1)
    mask = (cand != SENTINEL).astype(jnp.float32)
    urow = planes.row[user_ids]                    # ONE row-side gather
    urow = urow.at[:, F].add(planes.mu)            # bias col := μ + b_i
    if impl == "ref":
        scores, idx = candidate_score_topn_ref(urow, planes.col, safe, mask,
                                               topn=topn, tile_b=tile_b)
    else:
        scores, idx = candidate_score_topn(urow, planes.col, safe, mask,
                                           topn=topn, tile_b=tile_b,
                                           interpret=interpret)
    items = jnp.take_along_axis(cand, idx, axis=1)
    items = jnp.where(scores > NEG, items, SENTINEL)
    return scores, items
