"""Pure-jnp oracle for the fused candidate-score + top-N kernel."""
import jax
import jax.numpy as jnp

from repro.kernels.candidate_score.kernel import NEG


def candidate_score_topn_ref(u, bu, vc, bc, mask, *, topn: int):
    s = jnp.einsum("bf,bcf->bc", u, vc) + bc + bu[:, None]
    s = jnp.where(mask > 0, s, NEG)
    scores, idx = jax.lax.top_k(s, topn)
    return scores, idx.astype(jnp.int32)
