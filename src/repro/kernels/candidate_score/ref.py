"""Pure-jnp oracle for the fused candidate-score + top-N kernel.

Mirrors the kernel's in-kernel-gather contract: candidate *ids* come in,
plane rows are fetched per user-tile inside a `lax.scan`, so the gather
intermediate is ``[tile_b, C, F+1]`` — the full ``[B, C, F]`` candidate
cube never appears in the HLO (asserted by
`tests/test_serve.py::test_scorer_hlo_has_no_candidate_cube`).  On CPU
this is also the fast path: a tile's rows stay cache-resident between the
gather and the matvec instead of round-tripping a ~25 MB cube through
memory per flush.
"""
import jax
import jax.numpy as jnp

from repro.kernels.candidate_score.kernel import NEG


def candidate_score_topn_ref(urow, plane, cand, mask, *, topn: int,
                             tile_b: int = 8):
    """urow [B, F+1] (= U‖(μ+b) rows, pre-gathered); plane [N, F+1] = V‖b̂;
    cand [B, C] int32 ids (pre-clipped to [0, N)); mask [B, C] (1.0 valid)
    → (scores [B, topn] f32, idx [B, topn] int32 slots into C)."""
    B, C = cand.shape
    F = plane.shape[1] - 1
    pad = (-B) % tile_b
    if pad:
        urow = jnp.pad(urow, ((0, pad), (0, 0)))
        cand = jnp.pad(cand, ((0, pad), (0, 0)))
        mask = jnp.pad(mask, ((0, pad), (0, 0)))
    T = urow.shape[0] // tile_b

    def tile(_, args):
        u, c, m = args
        rows = plane[c]                                  # [tile_b, C, F+1]
        s = (jnp.einsum("bf,bcf->bc", u[:, :F], rows[..., :F])
             + rows[..., F] + u[:, F][:, None])
        s = jnp.where(m > 0, s, NEG)
        sc, idx = jax.lax.top_k(s, topn)
        return None, (sc, idx.astype(jnp.int32))

    _, (scores, idx) = jax.lax.scan(
        tile, None, (urow.reshape(T, tile_b, F + 1),
                     cand.reshape(T, tile_b, C), mask.reshape(T, tile_b, C)))
    return scores.reshape(-1, topn)[:B], idx.reshape(-1, topn)[:B]
