"""Pallas TPU kernel: in-kernel candidate gather + score + top-N.

Serving hot path.  Candidate *ids* enter the kernel (scalar-prefetched
into SMEM); the packed serve plane ``[N, F+1] = V‖b̂`` stays in HBM
(`pltpu.ANY`) and each user's C candidate rows are DMA'd into a VMEM
scratch tile on demand — the ``[B, C, F]`` candidate-factor cube that the
PR 1 scorer materialized via an XLA gather (25–38 MB per 256-user flush
at C=512–768, F=48) never exists in HBM.  The gather is double-buffered
across users: while user ``b``'s scores are computed, user ``b+1``'s rows
are already in flight (the embedding-gather analogue of the guide's
double-buffering pattern).

Per user the score is Eq. (1)'s serving part

    s[c] = (μ + b_i) + b̂[cand[c]] + u · v[cand[c]]

with the μ + b_i term pre-folded into the user row's bias column by
`ops.score_candidates` (one row-plane gather outside the kernel — [B, F+1]
is micro-batch-sized, not candidate-sized).  Masked (SENTINEL-padded)
slots score NEG; top-N is the same static-depth iterative argmax as the
PR 1 kernel (first-index tie rule, matching `jax.lax.top_k`), computed on
the [1, C] row while it is still VMEM-resident.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# python floats (not jnp scalars): they must enter the kernel as literals,
# pallas_call rejects captured traced constants
NEG = -3e38     # effective -inf that survives f32 arithmetic
_NEG2 = -3.4e38  # knock-out value, strictly below NEG so already-selected
                 # (incl. masked) slots never repeat


def _gather_score_kernel(cand_ref, urow_ref, mask_ref, plane_ref,
                         score_out, idx_out, rows, sem, *,
                         topn: int, tile_b: int):
    """cand_ref [Bp, C] int32 in SMEM (scalar prefetch); urow_ref
    [tile_b, F+1] VMEM; mask_ref [tile_b, C] VMEM; plane_ref [N, F+1] in
    ANY/HBM; rows [2, C, F+1] VMEM scratch (double buffer); sem [2] DMA."""
    C = mask_ref.shape[1]
    F = plane_ref.shape[1] - 1
    base = pl.program_id(0) * tile_b

    def row_dma(slot, b, c):
        # one serve-plane row, HBM → the slot's scratch tile
        return pltpu.make_async_copy(plane_ref.at[cand_ref[base + b, c]],
                                     rows.at[slot, c], sem.at[slot])

    def start_user(slot, b):
        jax.lax.fori_loop(
            0, C, lambda c, _: (row_dma(slot, b, c).start(), 0)[1], 0)

    def wait_user(slot, b):
        # waits are per-copy on the slot's shared semaphore
        jax.lax.fori_loop(
            0, C, lambda c, _: (row_dma(slot, b, c).wait(), 0)[1], 0)

    start_user(0, 0)

    def user_body(b, _):
        slot = jax.lax.rem(b, 2)

        @pl.when(b + 1 < tile_b)
        def _():  # prefetch the next user's rows into the other buffer
            start_user(1 - slot, b + 1)

        wait_user(slot, b)
        v = rows[slot, :, :F]                                   # [C, F]
        bc = rows[slot, :, F]                                   # [C]
        u = urow_ref[b, :F]                                     # [F]
        bu = urow_ref[b, F]                                     # [] = μ + b_i
        s = jnp.dot(v, u, preferred_element_type=jnp.float32) + bc + bu
        s = jnp.where(mask_ref[b, :] > 0, s, NEG)[None, :]      # [1, C]

        col = jax.lax.broadcasted_iota(jnp.int32, (1, C), 1)
        big = jnp.int32(C)
        for t in range(topn):          # static unroll, same as PR 1 kernel
            m = jnp.max(s, axis=1)
            at = jnp.min(jnp.where(s == m[:, None], col, big), axis=1)
            score_out[b, t] = m[0]
            idx_out[b, t] = at[0]
            s = jnp.where(col == at[:, None], _NEG2, s)
        return 0

    jax.lax.fori_loop(0, tile_b, user_body, 0)


@functools.partial(jax.jit,
                   static_argnames=("topn", "tile_b", "interpret"))
def candidate_score_topn(urow, plane, cand, mask, *, topn: int,
                         tile_b: int = 8, interpret: bool = True):
    """urow [B, F+1] (U‖(μ+b) rows); plane [N, F+1] (V‖b̂); cand [B, C]
    int32 ids pre-clipped to [0, N); mask [B, C] f32 (1.0 valid) →
    (scores [B, topn] f32, idx [B, topn] int32 slots into C).

    Masked slots (and padded rows) surface as NEG scores in candidate-slot
    order, exactly like the ref's `top_k` over the masked matrix — callers
    translate idx through their candidate id table and mask on score > NEG.
    """
    B, C = cand.shape
    assert C >= topn, "need at least topn candidate slots"
    Fp1 = plane.shape[1]
    pad = (-B) % tile_b
    if pad:
        urow = jnp.pad(urow, ((0, pad), (0, 0)))
        cand = jnp.pad(cand, ((0, pad), (0, 0)))
        mask = jnp.pad(mask, ((0, pad), (0, 0)))
    Bp = urow.shape[0]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,                     # cand ids → SMEM
        grid=(Bp // tile_b,),
        in_specs=[
            pl.BlockSpec((tile_b, Fp1), lambda i, *_: (i, 0)),
            pl.BlockSpec((tile_b, C), lambda i, *_: (i, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),  # plane stays in HBM
        ],
        out_specs=[pl.BlockSpec((tile_b, topn), lambda i, *_: (i, 0)),
                   pl.BlockSpec((tile_b, topn), lambda i, *_: (i, 0))],
        scratch_shapes=[pltpu.VMEM((2, C, Fp1), jnp.float32),
                        pltpu.SemaphoreType.DMA((2,))],
    )
    scores, idx = pl.pallas_call(
        functools.partial(_gather_score_kernel, topn=topn, tile_b=tile_b),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((Bp, topn), jnp.float32),
                   jax.ShapeDtypeStruct((Bp, topn), jnp.int32)],
        interpret=interpret,
    )(cand, urow, mask, plane)
    return scores[:B], idx[:B]
