"""Pallas TPU kernel: fused candidate scoring + top-N (serving hot path).

For a tile of users, one VMEM pass computes the Eq. (1) baseline+latent
serving score against each user's C retrieved candidates

    s[b, c] = (μ + b_i[b]) + b̂[b, c] + u[b]·v[b, c]

masks the SENTINEL padding, and selects the per-user top-N *inside the
kernel* — the [TB, C] score matrix never round-trips to HBM, only the
[TB, topn] result does.  The contraction u·v over candidates is a batched
[1, F] × [F, C] matvec — MXU-shaped, like `simlsh_encode`.

Top-N is a static-depth iterative argmax (select max, knock it out with
-BIG, repeat).  Ties resolve to the lowest candidate slot via a min-over-
equal-scores reduction — the same first-index rule `jax.lax.top_k` uses,
which keeps the ref path bit-comparable.  (`topn` is 10-ish; topn·C
compares per user are noise next to the F·C MACs.)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# python floats (not jnp scalars): they must enter the kernel as literals,
# pallas_call rejects captured traced constants
NEG = -3e38     # effective -inf that survives f32 arithmetic
_NEG2 = -3.4e38  # knock-out value, strictly below NEG so already-selected
                 # (incl. masked) slots never repeat


def _score_kernel(u_ref, bu_ref, vc_ref, bc_ref, mask_ref,
                  score_out, idx_out, *, topn: int):
    u = u_ref[...]                     # [TB, F]
    bu = bu_ref[...]                   # [TB]
    vc = vc_ref[...]                   # [TB, C, F]
    bc = bc_ref[...]                   # [TB, C]
    mask = mask_ref[...]               # [TB, C]  (1.0 valid)

    s = jnp.einsum("bf,bcf->bc", u, vc,
                   preferred_element_type=jnp.float32)
    s = s + bc + bu[:, None]
    s = jnp.where(mask > 0, s, NEG)

    TB, C = s.shape
    col = jax.lax.broadcasted_iota(jnp.int32, (TB, C), 1)
    big = jnp.int32(C)
    for t in range(topn):              # static unroll
        m = jnp.max(s, axis=1)                                  # [TB]
        at = jnp.min(jnp.where(s == m[:, None], col, big), axis=1)
        score_out[:, t] = m
        idx_out[:, t] = at
        s = jnp.where(col == at[:, None], _NEG2, s)


@functools.partial(jax.jit, static_argnames=("topn", "tile_b", "interpret"))
def candidate_score_topn(u, bu, vc, bc, mask, *, topn: int,
                         tile_b: int = 8, interpret: bool = True):
    """u [B,F]; bu [B]; vc [B,C,F]; bc,mask [B,C] →
    (scores [B,topn] f32, idx [B,topn] int32 slots into C).

    Masked slots (and padded rows) surface as NEG scores in candidate-slot
    order, exactly like the ref's `top_k` over the masked matrix — callers
    translate idx through their candidate id table and mask on score > NEG.
    """
    assert vc.shape[1] >= topn, "need at least topn candidate slots"
    B, C, F = vc.shape
    pad = (-B) % tile_b
    if pad:
        u = jnp.pad(u, ((0, pad), (0, 0)))
        bu = jnp.pad(bu, (0, pad))
        vc = jnp.pad(vc, ((0, pad), (0, 0), (0, 0)))
        bc = jnp.pad(bc, ((0, pad), (0, 0)))
        mask = jnp.pad(mask, ((0, pad), (0, 0)))
    Bp = u.shape[0]

    mat = pl.BlockSpec((tile_b, F), lambda i: (i, 0))
    vec = pl.BlockSpec((tile_b,), lambda i: (i,))
    cmat = pl.BlockSpec((tile_b, C), lambda i: (i, 0))
    cube = pl.BlockSpec((tile_b, C, F), lambda i: (i, 0, 0))
    tmat = pl.BlockSpec((tile_b, topn), lambda i: (i, 0))
    scores, idx = pl.pallas_call(
        functools.partial(_score_kernel, topn=topn),
        grid=(Bp // tile_b,),
        in_specs=[mat, vec, cube, cmat, cmat],
        out_specs=[tmat, tmat],
        out_shape=[jax.ShapeDtypeStruct((Bp, topn), jnp.float32),
                   jax.ShapeDtypeStruct((Bp, topn), jnp.int32)],
        interpret=interpret,
    )(u, bu, vc, bc, mask)
    return scores[:B], idx[:B]
