"""jit'd wrapper: gather rows → fused kernel step → scatter rows back.

The conflict-free batch guarantee makes the scatter race-free (each i/j
appears once), matching MCULSH-MF's D×D-block invariant.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.model import Params
from repro.kernels.mf_sgd.kernel import mf_sgd_step


def apply_mf_sgd(p: Params, i, j, r, valid, hp, decay, *,
                 interpret: bool = True) -> Params:
    import dataclasses
    u2, v2, _ = mf_sgd_step(
        p.U[i], p.V[j], r, valid,
        jnp.float32(hp.a_u) * decay, jnp.float32(hp.a_v) * decay,
        jnp.float32(hp.l_u), jnp.float32(hp.l_v), interpret=interpret)
    return dataclasses.replace(
        p, U=p.U.at[i].set(u2), V=p.V.at[j].set(v2))
