"""jit'd wrappers: gather plane rows → fused kernel step → scatter deltas.

The packed-parameter layout (`model.PackedParams`) makes the whole step
**two** gather/scatter pairs: one [B, F+1] row-plane gather + delta
scatter (U and b together) and one [B, F+2K+1] col-plane pair (V, W, C
and b̂) — versus the six of the pre-packed layout.  The conflict-free
batch guarantee (see `data.sparse.conflict_free_schedule`) makes the
scatter race-free: each valid i/j appears once, so adding the per-row
*delta* is exactly Eq. (5).  Deltas (not `.set`) also make padding slots
— which repeat a live triple with ``valid`` False — harmless no-ops.

``impl="auto"`` resolves to the pure-jnp ref on CPU (where Pallas only has
the slow interpreter) and the fused Pallas kernel elsewhere, mirroring
`kernels.candidate_score`.  This is the training hot path behind
`FitConfig.use_kernels` (via `sgd.train_epoch_scheduled`).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.model import Batch, PackedParams
from repro.kernels.mf_sgd.kernel import culsh_sgd_step, mf_sgd_step
from repro.kernels.mf_sgd.ref import culsh_sgd_step_ref, mf_sgd_step_ref


def resolve_impl(impl: str) -> str:
    """'auto' → 'ref' on CPU, 'pallas' on accelerators (call outside jit)."""
    if impl != "auto":
        return impl
    return "ref" if jax.default_backend() == "cpu" else "pallas"


def apply_mf_sgd(pp: PackedParams, bt: Batch, hp, decay, *,
                 impl: str = "pallas", tile_b: int = 256,
                 interpret: bool = True, bce: bool = False) -> PackedParams:
    """CUSGD++ step applied to the packed planes via a conflict-free batch
    (only the U/V columns are touched)."""
    F = pp.F
    u = pp.row[bt.i, :F]
    v = pp.col[bt.j, :F]
    args = (u, v, bt.r, bt.valid,
            jnp.float32(hp.a_u) * decay, jnp.float32(hp.a_v) * decay,
            jnp.float32(hp.l_u), jnp.float32(hp.l_v))
    if impl == "ref":
        u2, v2, _ = mf_sgd_step_ref(*args, bce=bce)
    else:
        u2, v2, _ = mf_sgd_step(*args, tile_b=tile_b, interpret=interpret,
                                bce=bce)
    return dataclasses.replace(
        pp, row=pp.row.at[bt.i, :F].add(u2 - u),
        col=pp.col.at[bt.j, :F].add(v2 - v))


def apply_culsh_sgd(pp: PackedParams, bt: Batch, hp, decay, *,
                    impl: str = "pallas", tile_b: int = 256,
                    interpret: bool = True, bce: bool = False) -> PackedParams:
    """Fused six-parameter CULSH-MF step applied to the packed planes.

    XLA-level gathers assemble the plane tiles (same split as
    `candidate_score`: gathers outside, dense tiles inside the kernel);
    the only extra gather is the neighbour-baseline read b̂[J^K[j]],
    which needs rows of the col plane the batch doesn't own.
    """
    F, K = pp.F, pp.K
    row = pp.row[bt.i]                      # [B, F+1]
    col = pp.col[bt.j]                      # [B, F+2K+1]
    bh_nb = pp.col[bt.nb, F + 2 * K]        # [B, K]
    d = decay
    hpv = jnp.stack([hp.a_b * d, hp.a_bh * d, hp.a_u * d, hp.a_v * d,
                     hp.a_w * d, hp.a_c * d,
                     jnp.float32(hp.l_b), jnp.float32(hp.l_bh),
                     jnp.float32(hp.l_u), jnp.float32(hp.l_v),
                     jnp.float32(hp.l_w), jnp.float32(hp.l_c), pp.mu])
    step = (culsh_sgd_step_ref if impl == "ref"
            else partial(culsh_sgd_step, tile_b=tile_b, interpret=interpret))
    row2, col2 = step(row, col, bt.rnb, bh_nb, bt.expl, bt.r, bt.valid, hpv,
                      bce=bce)
    return dataclasses.replace(
        pp, row=pp.row.at[bt.i].add(row2 - row),
        col=pp.col.at[bt.j].add(col2 - col))
