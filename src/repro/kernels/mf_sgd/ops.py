"""jit'd wrappers: gather rows → fused kernel step → scatter deltas back.

The conflict-free batch guarantee (see `data.sparse.conflict_free_schedule`)
makes the scatter race-free: each valid i/j appears once, so adding the
per-row *delta* is exactly Eq. (5).  Deltas (not `.set`) also make padding
slots — which repeat triple 0 with ``valid`` False — harmless no-ops even
when triple 0 is live in the same batch.

``impl="auto"`` resolves to the pure-jnp ref on CPU (where Pallas only has
the slow interpreter) and the fused Pallas kernel elsewhere, mirroring
`kernels.candidate_score`.  This is the training hot path behind
`FitConfig.use_kernels` (via `sgd.train_epoch_scheduled`).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.model import Batch, Params
from repro.kernels.mf_sgd.kernel import culsh_sgd_step, mf_sgd_step
from repro.kernels.mf_sgd.ref import culsh_sgd_step_ref, mf_sgd_step_ref


def resolve_impl(impl: str) -> str:
    """'auto' → 'ref' on CPU, 'pallas' on accelerators (call outside jit)."""
    if impl != "auto":
        return impl
    return "ref" if jax.default_backend() == "cpu" else "pallas"


def apply_mf_sgd(p: Params, i, j, r, valid, hp, decay, *,
                 impl: str = "pallas", tile_b: int = 256,
                 interpret: bool = True, bce: bool = False) -> Params:
    """CUSGD++ step applied to Params via a conflict-free batch."""
    u, v = p.U[i], p.V[j]
    args = (u, v, r, valid,
            jnp.float32(hp.a_u) * decay, jnp.float32(hp.a_v) * decay,
            jnp.float32(hp.l_u), jnp.float32(hp.l_v))
    if impl == "ref":
        u2, v2, _ = mf_sgd_step_ref(*args, bce=bce)
    else:
        u2, v2, _ = mf_sgd_step(*args, tile_b=tile_b, interpret=interpret,
                                bce=bce)
    return dataclasses.replace(
        p, U=p.U.at[i].add(u2 - u), V=p.V.at[j].add(v2 - v))


def apply_culsh_sgd(p: Params, bt: Batch, hp, decay, *,
                    impl: str = "pallas", tile_b: int = 256,
                    interpret: bool = True, bce: bool = False) -> Params:
    """Fused six-parameter CULSH-MF step applied to Params.

    XLA-level gathers assemble the row-aligned operands (same split as
    `candidate_score`: gathers outside, dense tiles inside the kernel).
    """
    b_i, bh_j = p.b[bt.i], p.bh[bt.j]
    u, v, w, c = p.U[bt.i], p.V[bt.j], p.W[bt.j], p.C[bt.j]
    bbar = p.mu + b_i + bh_j
    bbar_nb = p.mu + b_i[:, None] + p.bh[bt.nb]
    resid = (bt.rnb - bbar_nb) * bt.expl
    nR = jnp.sum(bt.expl, 1)
    nN = jnp.sum(bt.impl, 1)
    sR = jnp.where(nR > 0, jax.lax.rsqrt(jnp.maximum(nR, 1.0)), 0.0)
    sN = jnp.where(nN > 0, jax.lax.rsqrt(jnp.maximum(nN, 1.0)), 0.0)
    d = decay
    hpv = jnp.stack([hp.a_b * d, hp.a_bh * d, hp.a_u * d, hp.a_v * d,
                     hp.a_w * d, hp.a_c * d,
                     jnp.float32(hp.l_b), jnp.float32(hp.l_bh),
                     jnp.float32(hp.l_u), jnp.float32(hp.l_v),
                     jnp.float32(hp.l_w), jnp.float32(hp.l_c)])
    step = (culsh_sgd_step_ref if impl == "ref"
            else partial(culsh_sgd_step, tile_b=tile_b, interpret=interpret))
    b2, bh2, u2, v2, w2, c2 = step(
        b_i, bh_j, u, v, w, c, resid, bt.impl, bt.expl, bbar, bt.r, bt.valid,
        sR, sN, hpv, bce=bce)
    return dataclasses.replace(
        p,
        b=p.b.at[bt.i].add(b2 - b_i), bh=p.bh.at[bt.j].add(bh2 - bh_j),
        U=p.U.at[bt.i].add(u2 - u), V=p.V.at[bt.j].add(v2 - v),
        W=p.W.at[bt.j].add(w2 - w), C=p.C.at[bt.j].add(c2 - c))
