"""Pallas TPU kernels: fused SGD steps — CUSGD++ (`mf_sgd_step`, paper
Alg. 2) and the six-parameter CULSH-MF step (`culsh_sgd_step`, Alg. 3),
both over conflict-free batch tiles (update rule Eq. 5).

For a conflict-free batch tile (each i / j at most once — the invariant the
paper's D×D blocking provides), one VMEM pass computes

    e   = r − u·v
    u' = u + γu (e·v − λu·u)
    v' = v + γv (e·u − λv·v)

using the *pre-update* u in the v update exactly like the register-resident
CUDA kernel (both updates read the same stale operands).  This is the TPU
image of "keep u_i in registers, fuse dot + update": tile-resident operands,
one round trip to HBM per row.

The CULSH kernel works on the **packed planes** (`model.PackedParams`):
its tiles are ``row [TB, F+1]`` = U‖b and ``col [TB, F+2K+1]`` = V‖W‖C‖b̂,
so the pallas_call carries 7 operands and 2 outputs instead of the 15/6 of
the pre-packed layout, and the surrounding step is one gather + one
delta-scatter per plane.  In-kernel the planes are split with *static*
lane slices; with F and K multiples of 128 every slice is lane-aligned on
real hardware (the b/b̂ scalar columns are strided single-lane reads
either way).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sgd_kernel(bce, u_ref, v_ref, r_ref, valid_ref, hp_ref,
                u_out, v_out, e_out):
    u = u_ref[...]                       # [TB, F]
    v = v_ref[...]
    r = r_ref[...]                       # [TB]
    valid = valid_ref[...]
    gu, gv, lu, lv = hp_ref[0], hp_ref[1], hp_ref[2], hp_ref[3]
    pred = jnp.sum(u * v, axis=-1)
    e = (r - (jax.nn.sigmoid(pred) if bce else pred)) * valid
    eb = e[:, None]
    vm = valid[:, None]
    u_out[...] = u + gu * (eb * v - lu * u) * vm
    v_out[...] = v + gv * (eb * u - lv * v) * vm
    e_out[...] = e


def _culsh_kernel(bce, row_ref, col_ref, rnb_ref, bhnb_ref, expl_ref,
                  r_ref, valid_ref, hp_ref, row_out, col_out):
    row = row_ref[...]                         # [TB, F+1] — U ‖ b
    col = col_ref[...]                         # [TB, F+2K+1] — V ‖ W ‖ C ‖ b̂
    rnb = rnb_ref[...]                         # [TB, K]
    bh_nb = bhnb_ref[...]                      # [TB, K] — b̂[J^K[j]] gather
    expl = expl_ref[...]
    r, valid = r_ref[...], valid_ref[...]
    F = row.shape[-1] - 1
    K = rnb.shape[-1]
    gb, gbh, gu, gv = hp_ref[0], hp_ref[1], hp_ref[2], hp_ref[3]
    gw, gc = hp_ref[4], hp_ref[5]
    lb, lbh, lu, lv = hp_ref[6], hp_ref[7], hp_ref[8], hp_ref[9]
    lw, lc = hp_ref[10], hp_ref[11]
    mu = hp_ref[12]

    u, b = row[:, :F], row[:, F]
    v, w = col[:, :F], col[:, F:F + K]
    c, bh = col[:, F + K:F + 2 * K], col[:, F + 2 * K]
    impl = 1.0 - expl
    bbar = mu + b + bh
    resid = (rnb - (mu + b[:, None] + bh_nb)) * expl
    nR = jnp.sum(expl, axis=-1)
    nN = jnp.sum(impl, axis=-1)
    sR = jnp.where(nR > 0, jax.lax.rsqrt(jnp.maximum(nR, 1.0)), 0.0)
    sN = jnp.where(nN > 0, jax.lax.rsqrt(jnp.maximum(nN, 1.0)), 0.0)
    pred = (bbar + sR * jnp.sum(resid * w, axis=-1)
            + sN * jnp.sum(impl * c, axis=-1) + jnp.sum(u * v, axis=-1))
    e = (r - (jax.nn.sigmoid(pred) if bce else pred)) * valid
    eb = e[:, None]
    vm = valid[:, None]
    row_out[:, :F] = u + gu * (eb * v - lu * u) * vm
    row_out[:, F] = b + gb * (e - lb * b) * valid
    col_out[:, :F] = v + gv * (eb * u - lv * v) * vm
    col_out[:, F:F + K] = w + gw * (sR[:, None] * eb * resid - lw * w) * expl * vm
    col_out[:, F + K:F + 2 * K] = c + gc * (sN[:, None] * eb - lc * c) * impl * vm
    col_out[:, F + 2 * K] = bh + gbh * (e - lbh * bh) * valid


def _clamp_tile(tile_b: int, B: int) -> int:
    """Width-generic tiling: narrow schedule tiers (quarter/eighth width)
    shouldn't pay for a mostly-padding 256-row tile.  Clamp the tile to
    the batch rounded up to the fp32 sublane multiple (8)."""
    return max(8, min(tile_b, -(-B // 8) * 8))


@functools.partial(jax.jit, static_argnames=("tile_b", "interpret", "bce"))
def culsh_sgd_step(row, col, rnb, bh_nb, expl, r, valid, hp, *,
                   tile_b: int = 256, interpret: bool = True,
                   bce: bool = False):
    """Fused six-parameter CULSH-MF step (paper Alg. 3, update rule Eq. 5)
    on packed plane tiles.

    One VMEM pass per batch tile computes the Eq. (1) forward *and* both
    updated parameter planes — the TPU image of the paper's register-
    resident CUDA kernel, which the load-balance property of §4.2(2)
    (every sample touches exactly K of the 2K {w, c} slots) keeps dense.
    Batch must be conflict-free but may have any width (every schedule
    tier routes through here; the tile is clamped to the batch).
    Operand layout and the ``hp`` 13-vector are documented on
    `ref.culsh_sgd_step_ref`; plane gathers/scatters happen in `ops`.
    """
    B = row.shape[0]
    F = row.shape[1] - 1
    K = rnb.shape[1]
    tile_b = _clamp_tile(tile_b, B)
    pad = (-B) % tile_b
    if pad:
        padded = lambda a: jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1))
        row, col, rnb, bh_nb, expl, r, valid = map(
            padded, (row, col, rnb, bh_nb, expl, r, valid))
    Bp = row.shape[0]
    mat = lambda d: pl.BlockSpec((tile_b, d), lambda i: (i, 0))
    vec = pl.BlockSpec((tile_b,), lambda i: (i,))
    hp_spec = pl.BlockSpec((13,), lambda i: (0,))
    outs = pl.pallas_call(
        functools.partial(_culsh_kernel, bce),
        grid=(Bp // tile_b,),
        in_specs=[mat(F + 1), mat(F + 2 * K + 1), mat(K), mat(K), mat(K),
                  vec, vec, hp_spec],
        out_specs=[mat(F + 1), mat(F + 2 * K + 1)],
        out_shape=[jax.ShapeDtypeStruct((Bp, F + 1), jnp.float32),
                   jax.ShapeDtypeStruct((Bp, F + 2 * K + 1), jnp.float32)],
        interpret=interpret,
    )(row, col, rnb, bh_nb, expl, r, valid.astype(jnp.float32),
      hp.astype(jnp.float32))
    return tuple(o[:B] for o in outs)


@functools.partial(jax.jit, static_argnames=("tile_b", "interpret", "bce"))
def mf_sgd_step(u, v, r, valid, gamma_u, gamma_v, lam_u, lam_v, *,
                tile_b: int = 256, interpret: bool = True,
                bce: bool = False):
    """u,v [B,F]; r,valid [B] → (u', v', e).  Batch must be conflict-free;
    any width (tile clamped to the batch — see `_clamp_tile`)."""
    B, F = u.shape
    tile_b = _clamp_tile(tile_b, B)
    pad = (-B) % tile_b
    if pad:
        u = jnp.pad(u, ((0, pad), (0, 0)))
        v = jnp.pad(v, ((0, pad), (0, 0)))
        r = jnp.pad(r, (0, pad))
        valid = jnp.pad(valid, (0, pad))
    Bp = u.shape[0]
    hp = jnp.stack([gamma_u, gamma_v, lam_u, lam_v]).astype(jnp.float32)

    mat = pl.BlockSpec((tile_b, F), lambda i: (i, 0))
    vec = pl.BlockSpec((tile_b,), lambda i: (i,))
    hp_spec = pl.BlockSpec((4,), lambda i: (0,))
    u2, v2, e = pl.pallas_call(
        functools.partial(_sgd_kernel, bce),
        grid=(Bp // tile_b,),
        in_specs=[mat, mat, vec, vec, hp_spec],
        out_specs=[mat, mat, vec],
        out_shape=[jax.ShapeDtypeStruct((Bp, F), jnp.float32),
                   jax.ShapeDtypeStruct((Bp, F), jnp.float32),
                   jax.ShapeDtypeStruct((Bp,), jnp.float32)],
        interpret=interpret,
    )(u, v, r, valid.astype(jnp.float32), hp)
    return u2[:B], v2[:B], e[:B]
