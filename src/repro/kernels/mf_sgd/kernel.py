"""Pallas TPU kernels: fused SGD steps — CUSGD++ (`mf_sgd_step`, paper
Alg. 2) and the six-parameter CULSH-MF step (`culsh_sgd_step`, Alg. 3),
both over conflict-free batch tiles (update rule Eq. 5).

For a conflict-free batch tile (each i / j at most once — the invariant the
paper's D×D blocking provides), one VMEM pass computes

    e   = r − u·v
    u' = u + γu (e·v − λu·u)
    v' = v + γv (e·u − λv·v)

using the *pre-update* u in the v update exactly like the register-resident
CUDA kernel (both updates read the same stale operands).  This is the TPU
image of "keep u_i in registers, fuse dot + update": tile-resident operands,
one round trip to HBM per row.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sgd_kernel(bce, u_ref, v_ref, r_ref, valid_ref, hp_ref,
                u_out, v_out, e_out):
    u = u_ref[...]                       # [TB, F]
    v = v_ref[...]
    r = r_ref[...]                       # [TB]
    valid = valid_ref[...]
    gu, gv, lu, lv = hp_ref[0], hp_ref[1], hp_ref[2], hp_ref[3]
    pred = jnp.sum(u * v, axis=-1)
    e = (r - (jax.nn.sigmoid(pred) if bce else pred)) * valid
    eb = e[:, None]
    vm = valid[:, None]
    u_out[...] = u + gu * (eb * v - lu * u) * vm
    v_out[...] = v + gv * (eb * u - lv * v) * vm
    e_out[...] = e


def _culsh_kernel(bce, u_ref, v_ref, w_ref, c_ref, resid_ref, impl_ref,
                  expl_ref, b_ref, bh_ref, bbar_ref, r_ref, valid_ref,
                  sR_ref, sN_ref, hp_ref,
                  b_out, bh_out, u_out, v_out, w_out, c_out):
    u, v = u_ref[...], v_ref[...]              # [TB, F]
    w, c = w_ref[...], c_ref[...]              # [TB, K]
    resid = resid_ref[...]                     # [TB, K] (expl-masked already)
    impl, expl = impl_ref[...], expl_ref[...]
    b, bh = b_ref[...], bh_ref[...]            # [TB]
    r, valid = r_ref[...], valid_ref[...]
    sR, sN = sR_ref[...], sN_ref[...]
    gb, gbh, gu, gv = hp_ref[0], hp_ref[1], hp_ref[2], hp_ref[3]
    gw, gc = hp_ref[4], hp_ref[5]
    lb, lbh, lu, lv = hp_ref[6], hp_ref[7], hp_ref[8], hp_ref[9]
    lw, lc = hp_ref[10], hp_ref[11]

    pred = (bbar_ref[...] + sR * jnp.sum(resid * w, axis=-1)
            + sN * jnp.sum(impl * c, axis=-1) + jnp.sum(u * v, axis=-1))
    e = (r - (jax.nn.sigmoid(pred) if bce else pred)) * valid
    eb = e[:, None]
    vm = valid[:, None]
    b_out[...] = b + gb * (e - lb * b) * valid
    bh_out[...] = bh + gbh * (e - lbh * bh) * valid
    u_out[...] = u + gu * (eb * v - lu * u) * vm
    v_out[...] = v + gv * (eb * u - lv * v) * vm
    w_out[...] = w + gw * (sR[:, None] * eb * resid - lw * w) * expl * vm
    c_out[...] = c + gc * (sN[:, None] * eb - lc * c) * impl * vm


def _clamp_tile(tile_b: int, B: int) -> int:
    """Width-generic tiling: narrow schedule tiers (quarter/eighth width)
    shouldn't pay for a mostly-padding 256-row tile.  Clamp the tile to
    the batch rounded up to the fp32 sublane multiple (8)."""
    return max(8, min(tile_b, -(-B // 8) * 8))


@functools.partial(jax.jit, static_argnames=("tile_b", "interpret", "bce"))
def culsh_sgd_step(b_i, bh_j, u, v, w, c, resid, impl, expl, bbar, r, valid,
                   sR, sN, hp, *, tile_b: int = 256, interpret: bool = True,
                   bce: bool = False):
    """Fused six-parameter CULSH-MF step (paper Alg. 3, update rule Eq. 5).

    One VMEM pass per batch tile computes the Eq. (1) forward *and* all six
    parameter deltas — the TPU image of the paper's register-resident CUDA
    kernel, which the load-balance property of §4.2(2) (every sample touches
    exactly K of the 2K {w, c} slots) keeps dense.  Batch must be
    conflict-free but may have any width (every schedule tier routes
    through here; the tile is clamped to the batch).  All operands are
    row-aligned (gathers happen in `ops`).  ``hp`` packs the 12 decayed
    scalars (see `ref.culsh_sgd_step_ref`).
    """
    B, F = u.shape
    K = w.shape[1]
    tile_b = _clamp_tile(tile_b, B)
    pad = (-B) % tile_b
    if pad:
        padded = lambda a: jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1))
        b_i, bh_j, u, v, w, c, resid, impl, expl, bbar, r, valid, sR, sN = map(
            padded, (b_i, bh_j, u, v, w, c, resid, impl, expl, bbar, r, valid,
                     sR, sN))
    Bp = u.shape[0]
    mat = lambda d: pl.BlockSpec((tile_b, d), lambda i: (i, 0))
    vec = pl.BlockSpec((tile_b,), lambda i: (i,))
    hp_spec = pl.BlockSpec((12,), lambda i: (0,))
    outs = pl.pallas_call(
        functools.partial(_culsh_kernel, bce),
        grid=(Bp // tile_b,),
        in_specs=[mat(F), mat(F), mat(K), mat(K), mat(K), mat(K), mat(K),
                  vec, vec, vec, vec, vec, vec, vec, hp_spec],
        out_specs=[vec, vec, mat(F), mat(F), mat(K), mat(K)],
        out_shape=[jax.ShapeDtypeStruct((Bp,), jnp.float32),
                   jax.ShapeDtypeStruct((Bp,), jnp.float32),
                   jax.ShapeDtypeStruct((Bp, F), jnp.float32),
                   jax.ShapeDtypeStruct((Bp, F), jnp.float32),
                   jax.ShapeDtypeStruct((Bp, K), jnp.float32),
                   jax.ShapeDtypeStruct((Bp, K), jnp.float32)],
        interpret=interpret,
    )(u, v, w, c, resid, impl, expl, b_i, bh_j, bbar, r,
      valid.astype(jnp.float32), sR, sN, hp.astype(jnp.float32))
    return tuple(o[:B] for o in outs)


@functools.partial(jax.jit, static_argnames=("tile_b", "interpret", "bce"))
def mf_sgd_step(u, v, r, valid, gamma_u, gamma_v, lam_u, lam_v, *,
                tile_b: int = 256, interpret: bool = True,
                bce: bool = False):
    """u,v [B,F]; r,valid [B] → (u', v', e).  Batch must be conflict-free;
    any width (tile clamped to the batch — see `_clamp_tile`)."""
    B, F = u.shape
    tile_b = _clamp_tile(tile_b, B)
    pad = (-B) % tile_b
    if pad:
        u = jnp.pad(u, ((0, pad), (0, 0)))
        v = jnp.pad(v, ((0, pad), (0, 0)))
        r = jnp.pad(r, (0, pad))
        valid = jnp.pad(valid, (0, pad))
    Bp = u.shape[0]
    hp = jnp.stack([gamma_u, gamma_v, lam_u, lam_v]).astype(jnp.float32)

    mat = pl.BlockSpec((tile_b, F), lambda i: (i, 0))
    vec = pl.BlockSpec((tile_b,), lambda i: (i,))
    hp_spec = pl.BlockSpec((4,), lambda i: (0,))
    u2, v2, e = pl.pallas_call(
        functools.partial(_sgd_kernel, bce),
        grid=(Bp // tile_b,),
        in_specs=[mat, mat, vec, vec, hp_spec],
        out_specs=[mat, mat, vec],
        out_shape=[jax.ShapeDtypeStruct((Bp, F), jnp.float32),
                   jax.ShapeDtypeStruct((Bp, F), jnp.float32),
                   jax.ShapeDtypeStruct((Bp,), jnp.float32)],
        interpret=interpret,
    )(u, v, r, valid.astype(jnp.float32), hp)
    return u2[:B], v2[:B], e[:B]
