"""Pallas TPU kernel: fused CUSGD++ step (paper Alg. 2, update rule Eq. 5).

For a conflict-free batch tile (each i / j at most once — the invariant the
paper's D×D blocking provides), one VMEM pass computes

    e   = r − u·v
    u' = u + γu (e·v − λu·u)
    v' = v + γv (e·u − λv·v)

using the *pre-update* u in the v update exactly like the register-resident
CUDA kernel (both updates read the same stale operands).  This is the TPU
image of "keep u_i in registers, fuse dot + update": tile-resident operands,
one round trip to HBM per row.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sgd_kernel(u_ref, v_ref, r_ref, valid_ref, hp_ref, u_out, v_out, e_out):
    u = u_ref[...]                       # [TB, F]
    v = v_ref[...]
    r = r_ref[...]                       # [TB]
    valid = valid_ref[...]
    gu, gv, lu, lv = hp_ref[0], hp_ref[1], hp_ref[2], hp_ref[3]
    e = (r - jnp.sum(u * v, axis=-1)) * valid
    eb = e[:, None]
    vm = valid[:, None]
    u_out[...] = u + gu * (eb * v - lu * u) * vm
    v_out[...] = v + gv * (eb * u - lv * v) * vm
    e_out[...] = e


@functools.partial(jax.jit, static_argnames=("tile_b", "interpret"))
def mf_sgd_step(u, v, r, valid, gamma_u, gamma_v, lam_u, lam_v, *,
                tile_b: int = 256, interpret: bool = True):
    """u,v [B,F]; r,valid [B] → (u', v', e).  Batch must be conflict-free."""
    B, F = u.shape
    pad = (-B) % tile_b
    if pad:
        u = jnp.pad(u, ((0, pad), (0, 0)))
        v = jnp.pad(v, ((0, pad), (0, 0)))
        r = jnp.pad(r, (0, pad))
        valid = jnp.pad(valid, (0, pad))
    Bp = u.shape[0]
    hp = jnp.stack([gamma_u, gamma_v, lam_u, lam_v]).astype(jnp.float32)

    mat = pl.BlockSpec((tile_b, F), lambda i: (i, 0))
    vec = pl.BlockSpec((tile_b,), lambda i: (i,))
    hp_spec = pl.BlockSpec((4,), lambda i: (0,))
    u2, v2, e = pl.pallas_call(
        _sgd_kernel,
        grid=(Bp // tile_b,),
        in_specs=[mat, mat, vec, vec, hp_spec],
        out_specs=[mat, mat, vec],
        out_shape=[jax.ShapeDtypeStruct((Bp, F), jnp.float32),
                   jax.ShapeDtypeStruct((Bp, F), jnp.float32),
                   jax.ShapeDtypeStruct((Bp,), jnp.float32)],
        interpret=interpret,
    )(u, v, r, valid.astype(jnp.float32), hp)
    return u2[:B], v2[:B], e[:B]
