"""Pure-jnp oracles for the fused SGD steps (CUSGD++ and CULSH-MF).

On CPU these *are* the fast path: `ops` resolves ``impl="auto"`` to the ref
(Pallas only has the interpreter there), mirroring `candidate_score`.
"""
import jax
import jax.numpy as jnp

from repro.core.model import predict_gathered


def mf_sgd_step_ref(u, v, r, valid, gamma_u, gamma_v, lam_u, lam_v, *,
                    bce: bool = False):
    pred = jnp.sum(u * v, axis=-1)
    e = (r - (jax.nn.sigmoid(pred) if bce else pred)) * valid
    eb = e[:, None]
    vm = valid[:, None]
    u2 = u + gamma_u * (eb * v - lam_u * u) * vm
    v2 = v + gamma_v * (eb * u - lam_v * v) * vm
    return u2, v2, e


def culsh_sgd_step_ref(row, col, rnb, bh_nb, expl, r, valid, hp, *,
                       bce: bool = False):
    """Fused six-parameter Eq. (5) step on a conflict-free packed tile.

    Packed-plane operands (see `model.PackedParams`): ``row [B, F+1]`` =
    U‖b and ``col [B, F+2K+1]`` = V‖W‖C‖b̂ are row-aligned gathers of the
    two parameter planes; ``hp`` packs the 12 decayed hyper scalars
    ``(γb, γb̂, γu, γv, γw, γc, λb, λb̂, λu, λv, λw, λc)`` plus ``μ``.
    The Eq. (1) forward (including b̄, residuals and the |R|/|N|
    normalizers) happens *inside* the step — only the neighbour-baseline
    gather ``bh_nb`` = b̂[J^K[j]] needs the full plane and stays outside.
    Returns the two updated tiles; `ops.apply_culsh_sgd` turns them into
    one delta-scatter per plane.
    """
    F = row.shape[-1] - 1
    K = rnb.shape[-1]
    gb, gbh, gu, gv, gw, gc = (hp[k] for k in range(6))
    lb, lbh, lu, lv, lw, lc = (hp[k] for k in range(6, 12))
    mu = hp[12]
    u, b = row[:, :F], row[:, F]
    v, w = col[:, :F], col[:, F:F + K]
    c, bh = col[:, F + K:F + 2 * K], col[:, F + 2 * K]
    impl = 1.0 - expl
    pred, aux = predict_gathered(mu, b, bh, u, v, w, c, bh_nb,
                                 rnb, expl, impl)
    resid, sR, sN = aux["resid"], aux["sR"], aux["sN"]
    e = (r - (jax.nn.sigmoid(pred) if bce else pred)) * valid
    eb = e[:, None]
    vm = valid[:, None]
    b2 = b + gb * (e - lb * b) * valid
    bh2 = bh + gbh * (e - lbh * bh) * valid
    u2 = u + gu * (eb * v - lu * u) * vm
    v2 = v + gv * (eb * u - lv * v) * vm
    w2 = w + gw * (sR[:, None] * eb * resid - lw * w) * expl * vm
    c2 = c + gc * (sN[:, None] * eb - lc * c) * impl * vm
    row2 = jnp.concatenate([u2, b2[:, None]], axis=1)
    col2 = jnp.concatenate([v2, w2, c2, bh2[:, None]], axis=1)
    return row2, col2
