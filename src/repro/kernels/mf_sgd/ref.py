"""Pure-jnp oracle for the fused CUSGD++ step."""
import jax.numpy as jnp


def mf_sgd_step_ref(u, v, r, valid, gamma_u, gamma_v, lam_u, lam_v):
    e = (r - jnp.sum(u * v, axis=-1)) * valid
    eb = e[:, None]
    vm = valid[:, None]
    u2 = u + gamma_u * (eb * v - lam_u * u) * vm
    v2 = v + gamma_v * (eb * u - lam_v * v) * vm
    return u2, v2, e
