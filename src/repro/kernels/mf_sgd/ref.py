"""Pure-jnp oracles for the fused SGD steps (CUSGD++ and CULSH-MF).

On CPU these *are* the fast path: `ops` resolves ``impl="auto"`` to the ref
(Pallas only has the interpreter there), mirroring `candidate_score`.
"""
import jax
import jax.numpy as jnp


def mf_sgd_step_ref(u, v, r, valid, gamma_u, gamma_v, lam_u, lam_v, *,
                    bce: bool = False):
    pred = jnp.sum(u * v, axis=-1)
    e = (r - (jax.nn.sigmoid(pred) if bce else pred)) * valid
    eb = e[:, None]
    vm = valid[:, None]
    u2 = u + gamma_u * (eb * v - lam_u * u) * vm
    v2 = v + gamma_v * (eb * u - lam_v * v) * vm
    return u2, v2, e


def culsh_sgd_step_ref(b_i, bh_j, u, v, w, c, resid, impl, expl, bbar, r,
                       valid, sR, sN, hp, *, bce: bool = False):
    """Fused six-parameter Eq. (5) step on a conflict-free batch tile.

    ``hp`` packs the 12 decayed hyper scalars
    ``(γb, γb̂, γu, γv, γw, γc, λb, λb̂, λu, λv, λw, λc)``; all other
    operands are row-aligned gathers (see `ops.apply_culsh_sgd`).
    """
    gb, gbh, gu, gv, gw, gc, lb, lbh, lu, lv, lw, lc = hp
    pred = (bbar + sR * jnp.sum(resid * w, axis=-1)
            + sN * jnp.sum(impl * c, axis=-1) + jnp.sum(u * v, axis=-1))
    e = (r - (jax.nn.sigmoid(pred) if bce else pred)) * valid
    eb = e[:, None]
    vm = valid[:, None]
    b2 = b_i + gb * (e - lb * b_i) * valid
    bh2 = bh_j + gbh * (e - lbh * bh_j) * valid
    u2 = u + gu * (eb * v - lu * u) * vm
    v2 = v + gv * (eb * u - lv * v) * vm
    w2 = w + gw * (sR[:, None] * eb * resid - lw * w) * expl * vm
    c2 = c + gc * (sN[:, None] * eb - lc * c) * impl * vm
    return b2, bh2, u2, v2, w2, c2
