"""Pallas TPU kernel: fused Eq. (1) prediction (CULSH-MF Alg. 3 lines 9–11).

One VMEM pass computes, per sample b of a batch tile:

    pred[b] = b̄[b] + sR[b]·Σ_k resid[b,k]·w[b,k]
                    + sN[b]·Σ_k impl[b,k]·c[b,k]
                    + Σ_f u[b,f]·v[b,f]

The CUDA version keeps {v_j, b̂_j, w_j, c_j} in registers and warp-shuffles
the three reductions; the TPU version tiles the whole sample block into
VMEM and fuses the three contractions in one kernel — same insight
("touch each operand once, reduce in fast memory"), MXU/VPU-shaped
(F and K on the 128-lane axis).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _predict_kernel(u_ref, v_ref, w_ref, c_ref, resid_ref, impl_ref,
                    bbar_ref, sR_ref, sN_ref, out_ref):
    u = u_ref[...]                # [TB, F]
    v = v_ref[...]
    w = w_ref[...]                # [TB, K]
    c = c_ref[...]
    resid = resid_ref[...]        # [TB, K] (already masked by explicit)
    impl = impl_ref[...]          # [TB, K]
    dot = jnp.sum(u * v, axis=-1)
    expl = jnp.sum(resid * w, axis=-1)
    imp = jnp.sum(impl * c, axis=-1)
    out_ref[...] = bbar_ref[...] + sR_ref[...] * expl + sN_ref[...] * imp + dot


@functools.partial(jax.jit, static_argnames=("tile_b", "interpret"))
def neighbor_predict(u, v, w, c, resid, impl, bbar, sR, sN, *,
                     tile_b: int = 128, interpret: bool = True):
    """All inputs row-aligned on the batch dim B → pred [B] f32."""
    B, F = u.shape
    K = w.shape[1]
    pad = (-B) % tile_b
    if pad:
        padded = lambda a: jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1))
        u, v, w, c, resid, impl, bbar, sR, sN = map(
            padded, (u, v, w, c, resid, impl, bbar, sR, sN))
    Bp = u.shape[0]
    mat = lambda d: pl.BlockSpec((tile_b, d), lambda i: (i, 0))
    vec = pl.BlockSpec((tile_b,), lambda i: (i,))
    out = pl.pallas_call(
        _predict_kernel,
        grid=(Bp // tile_b,),
        in_specs=[mat(F), mat(F), mat(K), mat(K), mat(K), mat(K),
                  vec, vec, vec],
        out_specs=vec,
        out_shape=jax.ShapeDtypeStruct((Bp,), jnp.float32),
        interpret=interpret,
    )(u, v, w, c, resid, impl, bbar, sR, sN)
    return out[:B]
