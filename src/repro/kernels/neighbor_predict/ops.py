"""jit'd wrapper: assemble Eq. (1) operands from a Batch + predict fused.

Drop-in replacement for core.model.predict's forward value — inference /
eval only.  The *training* hot path behind ``FitConfig.use_kernels`` does
not route through here: `sgd.train_epoch_scheduled` uses the fused
`kernels/mf_sgd` step (`apply_culsh_sgd` / `apply_mf_sgd`), which computes
this same forward inside the update kernel.  Gathers happen at XLA level,
the fused reduction in the Pallas kernel.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.model import Batch, Params
from repro.kernels.neighbor_predict.kernel import neighbor_predict


def predict_batch(p: Params, bt: Batch, *, interpret: bool = True):
    bbar = p.mu + p.b[bt.i] + p.bh[bt.j]
    bbar_nb = p.mu + p.b[bt.i][:, None] + p.bh[bt.nb]
    resid = (bt.rnb - bbar_nb) * bt.expl
    nR = jnp.sum(bt.expl, 1)
    nN = jnp.sum(bt.impl, 1)
    sR = jnp.where(nR > 0, 1.0 / jnp.sqrt(jnp.maximum(nR, 1.0)), 0.0)
    sN = jnp.where(nN > 0, 1.0 / jnp.sqrt(jnp.maximum(nN, 1.0)), 0.0)
    return neighbor_predict(
        p.U[bt.i], p.V[bt.j], p.W[bt.j], p.C[bt.j], resid, bt.impl,
        bbar, sR, sN, interpret=interpret)
