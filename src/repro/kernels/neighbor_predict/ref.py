"""Pure-jnp oracle for neighbor_predict (Eq. 1 fused prediction)."""
import jax.numpy as jnp


def neighbor_predict_ref(u, v, w, c, resid, impl, bbar, sR, sN):
    dot = jnp.sum(u * v, axis=-1)
    expl = jnp.sum(resid * w, axis=-1)
    imp = jnp.sum(impl * c, axis=-1)
    return bbar + sR * expl + sN * imp + dot
