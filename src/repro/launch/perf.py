import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# (must precede any jax import — see dryrun.py)

_DOC = """Perf hillclimbing driver (§Perf iteration loop).

Re-derives the roofline terms for one (arch × shape) cell under config
overrides, so each hypothesis→change→measure iteration is one command:

  PYTHONPATH=src python -m repro.launch.perf --arch llama3-405b \
      --shape train_4k --tag mb4 --set microbatches=4 [--mem]

Writes reports/perf/<arch>__<shape>__<tag>.json and prints the terms.
"""

import argparse
import dataclasses
import json
import time

import jax

from repro.configs import base as CB
from repro.launch import roofline as RL
from repro.launch.dryrun import build_cell
from repro.launch.mesh import make_production_mesh, use_mesh
from repro.models import sharding


def parse_override(kv: str):
    k, v = kv.split("=", 1)
    for cast in (int, float):
        try:
            return k, cast(v)
        except ValueError:
            pass
    if v in ("true", "false"):
        return k, v == "true"
    return k, v


def run(arch, shape_name, overrides, tag, do_mem, multi_pod=False):
    cfg = CB.get(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **dict(overrides))
    shape = CB.SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    axes = sharding.mesh_axes(mesh)

    t0 = time.time()
    cost = RL.extract_cost(cfg, shape, mesh, axes)
    mf = RL.model_flops(cfg, shape, axes["ntp"])
    rl = RL.roofline(cost, mesh.size)
    rec = dict(arch=arch, shape=shape_name, tag=tag,
               overrides=dict(overrides), **rl,
               flops=cost["flops"], hbm_bytes=cost["bytes"],
               coll_bytes=cost["coll_bytes"], coll=cost["coll"],
               useful_ratio=(mf / mesh.size) / max(cost["flops"], 1.0),
               mfu_bound=(mf / mesh.size / RL.PEAK_FLOPS)
               / max(rl["t_step"], 1e-12))
    if do_mem:
        fn, in_sh, args, donate = build_cell(cfg, shape, mesh, axes)
        with use_mesh(mesh):
            compiled = jax.jit(fn, in_shardings=in_sh,
                               donate_argnums=donate).lower(*args).compile()
        ma = compiled.memory_analysis()
        rec["peak_gib"] = round((ma.argument_size_in_bytes
                                 + ma.temp_size_in_bytes) / 2**30, 2)
    rec["wall_s"] = round(time.time() - t0, 1)
    os.makedirs("reports/perf", exist_ok=True)
    with open(f"reports/perf/{arch}__{shape_name}__{tag}.json", "w") as f:
        json.dump(rec, f, indent=1, default=str)
    print(f"{arch} {shape_name} [{tag}] bound={rec['bound']} "
          f"t_comp={rec['t_compute']*1e3:.1f}ms t_mem={rec['t_memory']*1e3:.1f}ms "
          f"t_coll={rec['t_collective']*1e3:.1f}ms mfu={rec['mfu_bound']:.3f} "
          + (f"peak={rec.get('peak_gib')}GiB" if do_mem else ""))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--set", action="append", default=[])
    ap.add_argument("--mem", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    run(args.arch, args.shape, [parse_override(s) for s in args.set],
        args.tag, args.mem, args.multi_pod)


if __name__ == "__main__":
    main()
