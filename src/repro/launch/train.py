"""LM training driver (CPU-runnable; production mesh via dry-run flags).

Synthetic zipf token stream → make_train_step(cfg) → Adam, with sharded
checkpoint/restart (kill it mid-run and rerun: it resumes from the last
manifest) and optional straggler mitigation (drop-slowest microbatch
accounting is simulated on CPU; the mechanism is the bounded-staleness
rescale in `train_loop`).

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --reduced \
      --steps 50 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import base as CB
from repro.models import lm, steps
from repro.train import checkpoint as ckpt


def synth_batch(rng, cfg, batch, seq):
    # zipf-distributed token ids over the vocab (padded ids never sampled)
    V = cfg.vocab
    p = 1.0 / np.arange(1, V + 1) ** 1.1
    p /= p.sum()
    toks = rng.choice(V, size=(batch, seq + 1), p=p).astype(np.int32)
    out = {"tokens": jnp.asarray(toks[:, :-1]),
           "labels": jnp.asarray(toks[:, 1:])}
    if cfg.frontend == "embed_stub":
        out["frontend_embeds"] = jnp.asarray(
            rng.normal(0, 0.02, (batch, 16, cfg.d_model)).astype(np.float32))
        if cfg.family == "encdec":
            out["frontend_embeds"] = jnp.asarray(rng.normal(
                0, 0.02, (batch, seq, cfg.d_model)).astype(np.float32))
    return out


def train_loop(cfg, *, steps_n, batch, seq, ckpt_dir=None, ckpt_every=0,
               lr=3e-4, log=print, seed=0):
    rng = np.random.default_rng(seed)
    params = lm.init_params(cfg, jax.random.PRNGKey(seed), model_shards=1)
    opt = steps.init_opt(cfg, params)
    step_fn = jax.jit(steps.make_train_step(cfg, lr=lr), donate_argnums=(0, 1))

    start = 0
    if ckpt_dir:
        restored = ckpt.try_restore(ckpt_dir, (params, opt))
        if restored is not None:
            (params, opt), start = restored
            log(f"resumed from step {start}")

    losses = []
    t0 = time.perf_counter()
    for s in range(start, steps_n):
        b = synth_batch(rng, cfg, batch, seq)
        params, opt, aux = step_fn(params, opt, b)
        losses.append(float(aux["loss"]))
        if s % 10 == 0 or s == steps_n - 1:
            log(f"step {s:5d}  loss {losses[-1]:.4f}  "
                f"({(time.perf_counter()-t0)/(s-start+1):.2f}s/step)")
        if ckpt_dir and ckpt_every and (s + 1) % ckpt_every == 0:
            ckpt.save(ckpt_dir, (params, opt), step=s + 1)
    if ckpt_dir:
        ckpt.save(ckpt_dir, (params, opt), step=steps_n, sync=True)
    return params, opt, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir")
    ap.add_argument("--ckpt-every", type=int, default=0)
    args = ap.parse_args()

    cfg = CB.get(args.arch)
    if args.reduced:
        cfg = CB.reduced(cfg)
    _, _, losses = train_loop(cfg, steps_n=args.steps, batch=args.batch,
                              seq=args.seq, ckpt_dir=args.ckpt_dir,
                              ckpt_every=args.ckpt_every, lr=args.lr)
    print(f"final loss {losses[-1]:.4f} (start {losses[0]:.4f})")


if __name__ == "__main__":
    main()
