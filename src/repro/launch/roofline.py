"""Roofline extraction from compiled dry-run artifacts.

Hardware constants (brief): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

Methodology (DESIGN.md §6): XLA's `cost_analysis()` is post-SPMD per-device
but does NOT multiply `scan`/`while` body cost by trip count.  Cost terms
are therefore extracted from *unrolled marginal* compiles:

    C(L) = fixed + L·layer   ⇒   layer = C(L2) − C(L1),  fixed = C(L1) − layer

with unrolled layers, single-block attention and one microbatch, then
composed:  total = µ · (fixed_fwd + L·layer) + opt  (train)
           total = fixed + L·layer                  (prefill/decode).

Collective bytes are parsed from `compiled.as_text()` of the same unrolled
modules (no while loops ⇒ counts are exact).
"""
from __future__ import annotations

import dataclasses
import re
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeSpec
from repro.launch import specs as SPECS
from repro.launch.mesh import use_mesh
from repro.models import lm, sharding, steps

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\S+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-kind output bytes of collective ops (per device, post-SPMD)."""
    out = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        out[kind] = out.get(kind, 0) + _shape_bytes(shape_str)
    return out


def collective_schedule(hlo_text: str, limit: int = 2000) -> list:
    """(kind, bytes) in program order — the dry-run's collective schedule."""
    sched = []
    for m in _COLL_RE.finditer(hlo_text):
        sched.append((m.group(2), _shape_bytes(m.group(1))))
        if len(sched) >= limit:
            break
    return sched


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict = dataclasses.field(default_factory=dict)

    def __add__(self, o):
        coll = dict(self.coll)
        for k, v in o.coll.items():
            coll[k] = coll.get(k, 0) + v
        return Cost(self.flops + o.flops, self.bytes + o.bytes, coll)

    def __sub__(self, o):
        coll = dict(self.coll)
        for k, v in o.coll.items():
            coll[k] = coll.get(k, 0) - v
        return Cost(self.flops - o.flops, self.bytes - o.bytes, coll)

    def __mul__(self, s):
        return Cost(self.flops * s, self.bytes * s,
                    {k: v * s for k, v in self.coll.items()})

    @property
    def coll_bytes(self):
        return sum(self.coll.values())


def _compile_cost(fn, in_shardings, args, mesh) -> Cost:
    with use_mesh(mesh):
        lowered = jax.jit(fn, in_shardings=in_shardings).lower(*args)
        compiled = lowered.compile()
    ca = compiled.cost_analysis() or {}
    txt = compiled.as_text()
    return Cost(float(ca.get("flops", 0.0)),
                float(ca.get("bytes accessed", 0.0)),
                collective_bytes(txt))


MAX_COST_QC = 2048   # keep chunk tensors < 2^31 elements (XLA int32 paths)


def _cost_cfg(cfg: ArchConfig, L: int, enc: int | None = None,
              shape_seq: int = 0) -> ArchConfig:
    qc = min(max(cfg.query_chunk, shape_seq or 1), MAX_COST_QC)
    return dataclasses.replace(
        cfg, L=L,
        enc_layers=enc if enc is not None else cfg.enc_layers,
        unroll_layers=True, microbatches=1,
        query_chunk=qc,
    )


def _attn_chunk_correction(cfg: ArchConfig, shape: ShapeSpec, axes) -> float:
    """FLOPs per layer of the attention chunks NOT counted by
    cost_analysis (the lax.map body runs nchunks times but is costed once).
    Analytic: per chunk ≈ B_loc·H_loc·qc·T·(4·hd + 8)."""
    S = shape.seq_len
    qc = min(max(cfg.query_chunk, S), MAX_COST_QC)
    if shape.kind == "decode" or S <= qc or not cfg.n_heads:
        return 0.0
    nchunks = -(-S // qc)
    B_loc = max(1, shape.global_batch // axes["ndp"])
    H_loc = max(1, cfg.n_heads // axes["ntp"])
    per_chunk = B_loc * H_loc * qc * S * (4.0 * cfg.hd + 8.0)
    n_attn = 3 if cfg.family == "encdec" else 1
    fwd = (nchunks - 1) * per_chunk * n_attn
    # train backward recomputes (remat) + differentiates: ≈ 3.5× fwd total
    return fwd * (3.5 if shape.kind == "train" else 1.0)


def _mk_args(cfg, shape, mesh, axes, kind):
    """(fn, in_shardings, args) for one cost compile."""
    params = jax.eval_shape(
        partial(lm.init_params, cfg, model_shards=axes["ntp"]),
        jax.random.PRNGKey(0))
    psp = sharding.to_named(sharding.param_specs(cfg, params, axes), mesh)
    if kind == "train":
        b = SPECS.batch_specs_for(cfg, shape)
        bsp = sharding.to_named(sharding.batch_specs(cfg, b, axes), mesh)

        def fwdbwd(p, batch):
            return jax.grad(lambda pp: steps.lm_loss(cfg, pp, batch, mesh, axes))(p)

        return fwdbwd, (psp, bsp), (params, b)
    if kind == "prefill":
        b = SPECS.prefill_specs_for(cfg, shape)
        bsp = sharding.to_named(sharding.batch_specs(cfg, b, axes), mesh)
        fn = steps.make_prefill(cfg, mesh, axes)
        return fn, (psp, bsp), (params, b)
    cache, tokens = SPECS.decode_specs_for(cfg, shape)
    csp = sharding.to_named(sharding.cache_specs(cfg, cache, axes), mesh)
    tsp = sharding.to_named(
        sharding.batch_specs(cfg, {"tokens": tokens}, axes), mesh)["tokens"]
    fn = steps.make_decode_step(cfg, mesh, axes)
    return fn, (psp, csp, tsp), (params, cache, tokens)


def _opt_cost(cfg, mesh, axes) -> Cost:
    params = jax.eval_shape(
        partial(lm.init_params, cfg, model_shards=axes["ntp"]),
        jax.random.PRNGKey(0))
    psp = sharding.to_named(sharding.param_specs(cfg, params, axes), mesh)
    opt = jax.eval_shape(partial(steps.init_opt, cfg), params)
    osp = dict(m=psp, v=psp,
               count=sharding.to_named(jax.sharding.PartitionSpec(), mesh))

    def upd(p, g, o):
        p2, o2, _ = steps.adam_update(cfg, p, g, o)
        return p2, o2

    return _compile_cost(upd, (psp, psp, osp), (params, params, opt), mesh)


def _layer_counts(cfg: ArchConfig):
    """(L1, L2, extra) probe sizes per family."""
    if cfg.family == "hybrid":
        k = cfg.attn_every
        return k, 2 * k, cfg.L % k or None     # group marginals (+ partial)
    return 1, 2, None


def micro_shape(shape: ShapeSpec, cfg: ArchConfig) -> ShapeSpec:
    µ = max(1, cfg.microbatches) if shape.kind == "train" else 1
    return dataclasses.replace(shape, global_batch=max(1, shape.global_batch // µ))


def extract_cost(cfg: ArchConfig, shape: ShapeSpec, mesh, axes) -> dict:
    """Composed per-device cost for the full (arch × shape) cell."""
    kind = shape.kind
    mshape = micro_shape(shape, cfg)
    µ = max(1, cfg.microbatches) if kind == "train" else 1
    L1, L2, Lpart = _layer_counts(cfg)

    def cost_at(L):
        c = _cost_cfg(cfg, L, enc=(L if cfg.family == "encdec" else None),
                      shape_seq=mshape.seq_len)
        return _compile_cost(*_mk_args(c, mshape, mesh, axes, kind), mesh=mesh)

    C1, C2 = cost_at(L1), cost_at(L2)
    layer = C2 - C1
    fixed = C1 - layer
    # analytic add-back of attention chunks hidden inside lax.map (per layer)
    layer = layer + Cost(_attn_chunk_correction(cfg, mshape, axes), 0.0, {})
    if cfg.family == "hybrid":
        ngroups_full = cfg.L // cfg.attn_every
        total_layers = ngroups_full
        body = fixed + layer * ngroups_full
        if Lpart:
            Cp = cost_at(Lpart)
            body = body + (Cp - fixed)
        total = body
    elif cfg.family == "encdec":
        # enc and dec scale together in the probes (enc=dec=L)
        total = fixed + layer * cfg.L
    else:
        total = fixed + layer * cfg.L
    total = total * µ
    if kind == "train":
        total = total + _opt_cost(cfg, mesh, axes)
    corr = bf16_coll_correction(cfg)
    return dict(flops=total.flops,
                bytes=analytic_hbm_bytes(cfg, shape, axes),
                bytes_xla_upper=total.bytes,
                coll=total.coll,
                coll_bytes=total.coll_bytes * corr,
                coll_bytes_raw=total.coll_bytes,
                per_layer_flops=layer.flops, fixed_flops=fixed.flops)


# --------------------------------------------------------------------------
# analytic HBM-traffic model
# --------------------------------------------------------------------------
#
# XLA-CPU's "bytes accessed" counts every op's operands as HBM traffic (no
# fusion model) and stores many bf16 tensors as f32 (CPU emulation), so it
# over-states TPU HBM traffic by ~one order of magnitude.  The *primary*
# memory term is therefore an analytic estimate of per-chip HBM traffic —
# the quantities a TPU actually moves; the XLA number is kept in the record
# as `bytes_xla_upper`.


def _dtype_bytes(name: str) -> int:
    return {"float32": 4, "bfloat16": 2, "float16": 2}.get(name, 4)


def analytic_hbm_bytes(cfg: ArchConfig, shape: ShapeSpec, axes) -> float:
    """Per-chip HBM bytes for one step (documented formulas)."""
    nchips = axes["ndp"] * axes["ntp"]
    total, active = param_counts(cfg, axes["ntp"])
    pb = _dtype_bytes(cfg.param_dtype)
    mb = _dtype_bytes(cfg.moment_dtype)
    gb = _dtype_bytes(cfg.grad_dtype)
    µ = max(1, cfg.microbatches) if shape.kind == "train" else 1
    B, S = shape.global_batch, shape.seq_len
    tokens_local = B * S / axes["ndp"]
    D = cfg.d_model
    act_b = _dtype_bytes(cfg.dtype)
    Lh = cfg.L if cfg.family != "encdec" else cfg.L + cfg.enc_layers

    if shape.kind == "train":
        # params: fwd read + bwd read per µbatch (sharded slice per chip;
        # FSDP gathers count as collective, but the local read still happens)
        p_shard = total * pb / nchips
        t = 2 * µ * p_shard
        # grads: write+read accumulator per µbatch + final read
        t += (2 * µ + 1) * total * gb / nchips
        # optimizer: read m,v + write m,v + read/write params
        t += total * (2 * mb * 2 + 2 * pb) / nchips
        # activations: remat stores carry per layer (SP-sharded if enabled)
        sp_div = axes["ntp"] if cfg.seq_shard_acts else 1
        t += 3 * Lh * tokens_local * D * act_b / sp_div   # write + 2 reads
        # logits: write + read f32, vocab-sharded
        t += 2 * tokens_local * cfg.vocab_padded(axes["ntp"]) / axes["ntp"] * 4
        return t
    if shape.kind == "prefill":
        p_shard = total * pb / nchips
        t = p_shard                                         # one param sweep
        t += 2 * Lh * tokens_local * D * act_b              # acts write+read
        if cfg.n_heads:                                     # KV cache write
            t += 2 * Lh * tokens_local * cfg.n_kv * cfg.hd * 2 / axes["ntp"]
        t += tokens_local / S * cfg.vocab_padded(axes["ntp"]) / axes["ntp"] * 4
        return t
    # decode: param sweep + full KV/state read + tiny activations
    p_shard = active * pb / nchips
    t = p_shard
    B_loc = max(1, B // axes["ndp"])
    if cfg.family in ("dense", "moe", "vlm", "encdec"):
        kv = cfg.L * B_loc * S * cfg.n_kv * cfg.hd * 2 * 2  # k+v bf16
        kv_div = axes["ntp"] if (cfg.n_kv % axes["ntp"] == 0 or True) else 1
        t += kv / axes["ntp"]                               # T- or H-sharded
        if cfg.family == "encdec":
            t *= 1.0
    if cfg.family in ("ssm", "hybrid"):
        H = max(1, SSM_n_heads(cfg))
        t += cfg.L * B_loc * H * cfg.ssm_headdim * cfg.ssm_state * 4 \
            / min(axes["ntp"], H)
        if cfg.family == "hybrid":
            napp = -(-cfg.L // cfg.attn_every)
            Tw = min(S, 8192 if S >= 100_000 else S)
            t += napp * B_loc * Tw * cfg.n_kv * cfg.hd * 2 * 2 \
                / min(axes["ntp"], cfg.n_kv)
    t += B_loc * D * Lh * 2 * 4                             # per-layer io
    return t


def SSM_n_heads(cfg):
    from repro.models import ssm as SSM
    return SSM.n_heads(cfg) if cfg.ssm_state else 0


# bf16 collectives are modelled at f32 width by the CPU backend; correct by
# the compute-dtype ratio (documented in EXPERIMENTS.md §Roofline).
def bf16_coll_correction(cfg: ArchConfig) -> float:
    return 0.5 if cfg.dtype == "bfloat16" else 1.0


# --------------------------------------------------------------------------
# analytic MODEL_FLOPS + roofline terms
# --------------------------------------------------------------------------


def param_counts(cfg: ArchConfig, model_shards: int = 16):
    params = jax.eval_shape(
        partial(lm.init_params, cfg, model_shards=model_shards),
        jax.random.PRNGKey(0))
    total = sum(x.size for x in jax.tree.leaves(params))
    inactive = 0
    if cfg.family == "moe" and cfg.n_experts:
        expert = sum(params["layers"][k].size for k in ("w1", "w2", "w3"))
        inactive = int(expert * (1 - cfg.moe_top_k / cfg.n_experts))
    return total, total - inactive


def model_flops(cfg: ArchConfig, shape: ShapeSpec, model_shards: int = 16):
    """Analytic 'useful' FLOPs (global): 6·N_active·tokens for train,
    2·N_active·tokens (+ attention against the KV/state) for serve."""
    total, active = param_counts(cfg, model_shards)
    B, S = shape.global_batch, shape.seq_len
    hd = cfg.hd if cfg.n_heads else 0
    if shape.kind == "train":
        flops = 6.0 * active * B * S
        if cfg.n_heads:
            flops += 3.0 * 4.0 * cfg.L * B * S * S * cfg.n_heads * hd * 0.5
        return flops
    if shape.kind == "prefill":
        flops = 2.0 * active * B * S
        if cfg.n_heads:
            flops += 4.0 * cfg.L * B * S * S * cfg.n_heads * hd * 0.5
        return flops
    # decode: one token against T of context
    flops = 2.0 * active * B
    if cfg.family in ("dense", "moe", "vlm", "encdec"):
        flops += 4.0 * cfg.L * B * S * cfg.n_heads * hd
    if cfg.family == "hybrid":
        napp = -(-cfg.L // cfg.attn_every)
        T_eff = min(S, 8192 if S >= 100_000 else S)
        flops += 4.0 * napp * B * T_eff * cfg.n_heads * hd
    return flops


def roofline(cost: dict, nchips: int) -> dict:
    t_comp = cost["flops"] / PEAK_FLOPS
    t_mem = cost["bytes"] / HBM_BW
    t_coll = cost["coll_bytes"] / ICI_BW
    dom = max(("compute", t_comp), ("memory", t_mem), ("collective", t_coll),
              key=lambda kv: kv[1])
    return dict(t_compute=t_comp, t_memory=t_mem, t_collective=t_coll,
                bound=dom[0], t_step=max(t_comp, t_mem, t_coll))
