"""Batched serving driver: prefill a prompt batch, decode tokens.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --reduced \
      --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import base as CB
from repro.models import lm, steps


def serve(cfg, *, batch, prompt_len, gen, seed=0, log=print):
    rng = np.random.default_rng(seed)
    params = lm.init_params(cfg, jax.random.PRNGKey(seed), model_shards=1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (batch, prompt_len)),
                       jnp.int32)
    T = prompt_len + gen

    decode = jax.jit(steps.make_decode_step(cfg), donate_argnums=(1,))
    cache = steps.init_cache(cfg, batch, T)

    # prefill by sequential decode for non-dense families; fast path for dense
    t0 = time.perf_counter()
    if cfg.family in ("dense", "moe", "vlm"):
        prefill = jax.jit(steps.make_prefill(cfg))
        logits, pc = prefill(params, {"tokens": toks})
        ks = jnp.zeros_like(cache["k"]).at[:, :, :prompt_len].set(
            pc["k"].astype(cache["k"].dtype))
        vs = jnp.zeros_like(cache["v"]).at[:, :, :prompt_len].set(
            pc["v"].astype(cache["v"].dtype))
        cache = cache | {"k": ks, "v": vs,
                         "pos": jnp.asarray(prompt_len, jnp.int32)}
        last = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    else:
        for t in range(prompt_len):
            logits, cache = decode(params, cache, toks[:, t:t + 1])
        last = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    t_prefill = time.perf_counter() - t0

    out = [last]
    t0 = time.perf_counter()
    for _ in range(gen):
        logits, cache = decode(params, cache, out[-1])
        out.append(jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None])
    jax.block_until_ready(out[-1])
    t_decode = time.perf_counter() - t0
    toks_s = batch * gen / max(t_decode, 1e-9)
    log(f"prefill {t_prefill:.2f}s  decode {t_decode:.2f}s "
        f"({toks_s:.1f} tok/s batched)")
    return jnp.concatenate(out, axis=1), dict(prefill_s=t_prefill,
                                              decode_s=t_decode,
                                              tok_per_s=toks_s)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()
    cfg = CB.get(args.arch)
    if args.reduced:
        cfg = CB.reduced(cfg)
    serve(cfg, batch=args.batch, prompt_len=args.prompt_len, gen=args.gen)


if __name__ == "__main__":
    main()
