import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import anywhere (jax locks the
# device count at first init).  Everything below is ordinary code.

_DOC = """Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell:
  * params/opt/caches enter as ShapeDtypeStruct (zero allocation);
  * jit(step).lower(...).compile() against the production mesh —
    16×16 single-pod and 2×16×16 multi-pod;
  * record memory_analysis() (per-device bytes — proves fit),
    cost_analysis(), the collective schedule parsed from the compiled
    module, and (optionally) the composed roofline cost terms;
  * write reports/dryrun/<mesh>/<arch>__<shape>.json.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--roofline]
"""

import argparse
import dataclasses
import json
import time
import traceback
from functools import partial

import jax

from repro.configs import base as CB
from repro.launch import roofline as RL
from repro.launch import specs as SPECS
from repro.launch.mesh import make_production_mesh, use_mesh
from repro.models import lm, sharding, steps


def build_cell(cfg, shape, mesh, axes):
    """(fn, in_shardings, args) for the FULL-config compile (scan layers)."""
    params = jax.eval_shape(
        partial(lm.init_params, cfg, model_shards=axes["ntp"]),
        jax.random.PRNGKey(0))
    psp = sharding.to_named(sharding.param_specs(cfg, params, axes), mesh)
    if shape.kind == "train":
        opt = jax.eval_shape(partial(steps.init_opt, cfg), params)
        osp = dict(m=psp, v=psp, count=sharding.to_named(
            jax.sharding.PartitionSpec(), mesh))
        batch = SPECS.batch_specs_for(cfg, shape)
        bsp = sharding.to_named(sharding.batch_specs(cfg, batch, axes), mesh)
        fn = steps.make_train_step(cfg, mesh, axes)
        return (fn, (psp, osp, bsp), (params, opt, batch), (0, 1))
    if shape.kind == "prefill":
        batch = SPECS.prefill_specs_for(cfg, shape)
        bsp = sharding.to_named(sharding.batch_specs(cfg, batch, axes), mesh)
        fn = steps.make_prefill(cfg, mesh, axes)
        return (fn, (psp, bsp), (params, batch), ())
    cache, tokens = SPECS.decode_specs_for(cfg, shape)
    csp = sharding.to_named(sharding.cache_specs(cfg, cache, axes), mesh)
    tsp = sharding.to_named(
        sharding.batch_specs(cfg, {"tokens": tokens}, axes), mesh)["tokens"]
    fn = steps.make_decode_step(cfg, mesh, axes)
    return (fn, (psp, csp, tsp), (params, cache, tokens), (1,))


def run_cell(arch: str, shape_name: str, mesh, *, do_roofline: bool,
             outdir: str, mesh_tag: str) -> dict:
    cfg = CB.get(arch)
    shape = CB.SHAPES[shape_name]
    ok, why = CB.runnable(cfg, shape)
    rec = dict(arch=arch, shape=shape_name, mesh=mesh_tag, skipped=not ok,
               skip_reason=why)
    if ok:
        axes = sharding.mesh_axes(mesh)
        t0 = time.time()
        fn, in_sh, args, donate = build_cell(cfg, shape, mesh, axes)
        with use_mesh(mesh):
            lowered = jax.jit(fn, in_shardings=in_sh,
                              donate_argnums=donate).lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        txt = compiled.as_text()
        nchips = mesh.size
        rec |= dict(
            lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
            device_bytes=dict(
                argument=ma.argument_size_in_bytes,
                output=ma.output_size_in_bytes,
                temp=ma.temp_size_in_bytes,
                alias=ma.alias_size_in_bytes,
                peak_gib=round((ma.argument_size_in_bytes
                                + ma.temp_size_in_bytes
                                + ma.output_size_in_bytes
                                - ma.alias_size_in_bytes) / 2**30, 3)),
            cost_analysis=dict(
                flops=float(ca.get("flops", 0.0)),
                bytes_accessed=float(ca.get("bytes accessed", 0.0)),
                note="per-device post-SPMD; scan bodies counted once "
                     "(see roofline for composed totals)"),
            collectives_in_module=RL.collective_bytes(txt),
            collective_schedule_head=RL.collective_schedule(txt, 40),
            nchips=nchips,
        )
        if do_roofline:
            cost = RL.extract_cost(cfg, shape, mesh, axes)
            mf = RL.model_flops(cfg, shape, axes["ntp"])
            total_p, active_p = RL.param_counts(cfg, axes["ntp"])
            rl = RL.roofline(cost, nchips)
            rec |= dict(
                roofline=dict(
                    **rl,
                    hlo_flops_per_chip=cost["flops"],
                    hbm_bytes_per_chip=cost["bytes"],
                    hbm_bytes_xla_upper=cost.get("bytes_xla_upper"),
                    coll_bytes_raw=cost.get("coll_bytes_raw"),
                    coll_bytes_per_chip=cost["coll_bytes"],
                    coll_by_kind=cost["coll"],
                    model_flops_global=mf,
                    params_total=total_p, params_active=active_p,
                    useful_ratio=(mf / nchips) / max(cost["flops"], 1.0),
                    mfu_bound=(mf / nchips / RL.PEAK_FLOPS) / max(rl["t_step"], 1e-12),
                ))
    os.makedirs(f"{outdir}/{mesh_tag}", exist_ok=True)
    path = f"{outdir}/{mesh_tag}/{arch}__{shape_name}.json"
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=str)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--roofline", action="store_true")
    ap.add_argument("--out", default="reports/dryrun")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    mesh_tag = "2x16x16" if args.multi_pod else "16x16"
    cells = (CB.cells(include_skips=True) if args.all
             else [(args.arch, args.shape, *CB.runnable(
                 CB.get(args.arch), CB.SHAPES[args.shape]))])

    for (arch, shape_name, ok, why) in cells:
        t0 = time.time()
        try:
            rec = run_cell(arch, shape_name, mesh, do_roofline=args.roofline,
                           outdir=args.out, mesh_tag=mesh_tag)
            if rec.get("skipped"):
                print(f"SKIP {arch:24s} {shape_name:12s} {why}")
            else:
                r = rec.get("roofline", {})
                print(f"OK   {arch:24s} {shape_name:12s} "
                      f"peak={rec['device_bytes']['peak_gib']:7.2f}GiB "
                      f"compile={rec['compile_s']:6.1f}s "
                      + (f"bound={r.get('bound', '')}" if r else ""),
                      flush=True)
        except Exception as e:
            print(f"FAIL {arch:24s} {shape_name:12s} {type(e).__name__}: {e}",
                  flush=True)
            traceback.print_exc()


if __name__ == "__main__":
    main()
