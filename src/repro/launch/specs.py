"""ShapeDtypeStruct input stand-ins for every (arch × shape) cell.

Nothing here allocates: params come from `jax.eval_shape(init_params)`,
batches/caches are explicit SDS trees.  VLM/audio frontends are stubs —
`frontend_embeds` are precomputed patch/frame embeddings per the brief.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeSpec
from repro.models import lm, steps

VLM_PATCHES = 2880          # anyres: 5 tiles × 576 patches


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs_for(cfg: ArchConfig, shape: ShapeSpec):
    """Train/prefill batch SDS tree."""
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        out = {
            "frontend_embeds": sds((B, S, cfg.d_model), jnp.bfloat16),
            "tokens": sds((B, S), jnp.int32),
            "labels": sds((B, S), jnp.int32),
        }
        if cfg.lsh_softmax:
            out["cands"] = sds((cfg.lsh_candidates,), jnp.int32)
        return out
    if cfg.family == "vlm" or cfg.frontend == "embed_stub":
        npatch = min(VLM_PATCHES, S // 2)    # scale the stub for tiny shapes
        S_txt = S - npatch
        return {
            "frontend_embeds": sds((B, npatch, cfg.d_model), jnp.bfloat16),
            "tokens": sds((B, S_txt), jnp.int32),
            "labels": sds((B, S_txt), jnp.int32),
        }
    out = {"tokens": sds((B, S), jnp.int32),
           "labels": sds((B, S), jnp.int32)}
    if cfg.lsh_softmax:
        out["cands"] = sds((cfg.lsh_candidates,), jnp.int32)
    return out


def prefill_specs_for(cfg: ArchConfig, shape: ShapeSpec):
    b = batch_specs_for(cfg, shape)
    b.pop("labels", None)
    return b


def decode_specs_for(cfg: ArchConfig, shape: ShapeSpec):
    """(cache SDS, tokens SDS) — one new token against a seq_len cache."""
    B, T = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(partial(steps.init_cache, cfg, B, T))
    tokens = sds((B, 1), jnp.int32)
    return cache, tokens


def input_specs(cfg: ArchConfig, shape: ShapeSpec):
    if shape.kind == "train":
        return {"batch": batch_specs_for(cfg, shape)}
    if shape.kind == "prefill":
        return {"batch": prefill_specs_for(cfg, shape)}
    cache, tokens = decode_specs_for(cfg, shape)
    return {"cache": cache, "tokens": tokens}
