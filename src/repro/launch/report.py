"""Render EXPERIMENTS.md tables from the dry-run JSON artifacts.

  PYTHONPATH=src python -m repro.launch.report [--mesh 16x16] [--section roofline|dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json


def load(mesh):
    out = []
    for f in sorted(glob.glob(f"reports/dryrun/{mesh}/*.json")):
        out.append(json.load(open(f)))
    return out


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def dryrun_table(mesh):
    rows = ["| arch | shape | status | peak GiB/chip | compile s | "
            "collectives in module |",
            "|---|---|---|---:|---:|---|"]
    for r in load(mesh):
        if r.get("skipped"):
            rows.append(f"| {r['arch']} | {r['shape']} | SKIP "
                        f"({r['skip_reason'][:40]}…) | | | |")
            continue
        coll = ", ".join(f"{k}:{fmt_bytes(v)}G"
                         for k, v in sorted(r["collectives_in_module"].items())
                         if v > 0)
        rows.append(
            f"| {r['arch']} | {r['shape']} | OK | "
            f"{r['device_bytes']['peak_gib']:.2f} | {r['compile_s']:.0f} | "
            f"{coll} |")
    return "\n".join(rows)


def roofline_table(mesh):
    rows = ["| arch | shape | bound | t_comp ms | t_mem ms | t_coll ms | "
            "useful | roofline-frac |",
            "|---|---|---|---:|---:|---:|---:|---:|"]
    for r in load(mesh):
        if r.get("skipped") or "roofline" not in r:
            continue
        x = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {x['bound']} | "
            f"{x['t_compute']*1e3:.1f} | {x['t_memory']*1e3:.1f} | "
            f"{x['t_collective']*1e3:.1f} | {x['useful_ratio']:.2f} | "
            f"{x['mfu_bound']:.3f} |")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--section", default="roofline")
    a = ap.parse_args()
    if a.section == "dryrun":
        print(dryrun_table(a.mesh))
    else:
        print(roofline_table(a.mesh))


if __name__ == "__main__":
    main()
