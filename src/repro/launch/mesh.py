"""Production mesh construction.

A function (not a module constant) so importing never touches jax device
state.  Single pod: 16×16 = 256 chips ("data","model").  Multi-pod: 2 pods
of 256 ("pod","data","model").  At 1000+-node scale the same axes extend
(pod count grows; the code only ever names axes, never sizes).
"""
from __future__ import annotations

import jax

# ---- jax-version compat -----------------------------------------------------
# The pinned jax (0.4.37) predates three APIs newer call sites use:
# `jax.make_mesh(..., axis_types=...)`, `jax.sharding.set_mesh`, and the
# top-level `jax.shard_map`.  These shims resolve to the modern API when
# present and the 0.4.x equivalent otherwise, so the same code runs on both.

try:
    shard_map = jax.shard_map
except AttributeError:  # 0.4.x: experimental namespace only
    from jax.experimental.shard_map import shard_map  # noqa: F401


def compat_mesh(shape, axes):
    """`jax.make_mesh` with Auto axis_types where the kwarg exists."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def use_mesh(mesh):
    """Context manager installing ``mesh``: `jax.sharding.set_mesh` when it
    exists, else the legacy `with mesh:` global-mesh context."""
    if hasattr(jax.sharding, "set_mesh"):
        return jax.sharding.set_mesh(mesh)
    return mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_mesh(shape, axes)


def make_host_mesh(data: int = 2, model: int = 2):
    """Small mesh over whatever devices exist (tests on CPU hosts)."""
    return compat_mesh((data, model), ("data", "model"))


def make_shard_mesh(shards: int | None = None):
    """1-D mesh for the block-aligned conflict-free training tier.

    `sgd.train_epoch_scheduled` shard_maps the D×D-blocked tier over the
    single ``"shard"`` axis (one device per col block, row blocks ring-
    rotating).  Defaults to all local devices; the trainer falls back to
    the single-device replay when only one device exists.  Built without
    axis_types (this jax version's `make_mesh` predates them)."""
    shards = shards or jax.device_count()
    return jax.make_mesh((shards,), ("shard",))


def serve_shard_count(request: int | str) -> int:
    """Resolve `ServeConfig.shards` to a device count for the sharded
    serving tier.

    ``0`` → 1 (single-device oracle path); ``"auto"`` → the largest
    power of two ≤ the local device count; an explicit int must be a
    power of two ≤ the device count.  Power-of-two only: the serving
    top-N tree reduce is an XOR-partner butterfly (`service.recommend`'s
    ppermute halving merge), whose disjoint-coverage invariant — no
    candidate ever counted twice — needs 2^k participants."""
    avail = jax.device_count()
    if request == "auto":
        return 1 << max(avail.bit_length() - 1, 0)
    d = int(request)
    if d == 0:
        return 1
    if d < 1 or d & (d - 1):
        raise ValueError(f"serve shards must be a power of two, got {d}")
    if d > avail:
        raise ValueError(f"serve shards={d} exceeds the {avail} local "
                         f"device(s)")
    return d
