"""Production mesh construction.

A function (not a module constant) so importing never touches jax device
state.  Single pod: 16×16 = 256 chips ("data","model").  Multi-pod: 2 pods
of 256 ("pod","data","model").  At 1000+-node scale the same axes extend
(pod count grows; the code only ever names axes, never sizes).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 2, model: int = 2):
    """Small mesh over whatever devices exist (tests on CPU hosts)."""
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
