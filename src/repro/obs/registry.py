"""Metrics registry + nested spans — the repo's single source of timing
truth (ISSUE 6).

A `Registry` holds three metric families plus two event logs:

  * **counters** — monotonically increasing floats (`counter_add`);
  * **gauges**   — last-value-wins floats (`gauge_set`);
  * **histograms** — fixed log-spaced buckets (`observe`): p50/p95/p99
    come from bucket interpolation, so no samples are retained and a
    histogram's memory is constant regardless of observation count.
    Exact count/sum/min/max ride along, so means are exact even though
    quantiles are bucket-resolution (one bucket per 1/16 decade —
    ≤ ~15.5% relative quantile error, verified against numpy in
    tests/test_obs.py).
  * **spans** — nested wall-time intervals (`with reg.span("flush.retrieve")`)
    on the monotonic clock (`perf_counter_ns`), kept in a bounded log for
    Chrome-trace export (export.py) and per-name duration queries
    (`span_durations`).  Every span completion also feeds the histogram
    of the same name, so quantiles survive after the span log wraps.
  * **events** — timestamped dict records (`event("eval", rmse=...)`) for
    JSONL time-series export (recall/RMSE-over-time, queue depth).

Disabled-mode contract (the default for the module-level registry in
`repro.obs`): every recording call is a cheap no-op — `span()` returns a
shared singleton context manager and counter/gauge/observe/event return
before touching any dict — so instrumentation can stay in hot paths
unconditionally.  `tests/test_obs.py::test_disabled_mode_no_alloc`
asserts the no-allocation property.

Spans can optionally mirror into `jax.profiler.TraceAnnotation`
(``jax_annotations=True``) so the same stage names appear on the host
timeline of XLA device profiles captured with `jax.profiler.trace` on
real hardware.
"""
from __future__ import annotations

import math
import threading
import time

# ---------------------------------------------------------------------------
# histograms
# ---------------------------------------------------------------------------

# bucket grid: 16 buckets per decade, 1e-9 .. 1e6 (covers ns spans to
# ~11-day counters); two overflow buckets catch everything outside
_B_PER_DECADE = 16
_LO_EXP, _HI_EXP = -9, 6
_N_BUCKETS = (_HI_EXP - _LO_EXP) * _B_PER_DECADE
_LOG_LO = float(_LO_EXP)
_SCALE = _B_PER_DECADE  # buckets per unit of log10


def bucket_bounds() -> list:
    """Upper bound of every finite bucket (length _N_BUCKETS)."""
    return [10.0 ** (_LO_EXP + (i + 1) / _SCALE) for i in range(_N_BUCKETS)]


class Histogram:
    """Fixed-bucket log-spaced histogram; O(1) observe, O(buckets) quantile."""

    __slots__ = ("counts", "under", "over", "count", "sum", "min", "max")

    def __init__(self):
        self.counts = [0] * _N_BUCKETS
        self.under = 0          # values ≤ 1e-9 (incl. zero/negative)
        self.over = 0           # values > 1e6
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v: float) -> None:
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        if v <= 10.0 ** _LO_EXP:
            self.under += 1
        elif v > 10.0 ** _HI_EXP:
            self.over += 1
        else:
            # idx such that bound[idx-1] < v <= bound[idx]
            idx = int(math.ceil((math.log10(v) - _LOG_LO) * _SCALE)) - 1
            self.counts[min(max(idx, 0), _N_BUCKETS - 1)] += 1

    def quantile(self, q: float) -> float:
        """Approximate q-quantile by log-linear interpolation inside the
        target bucket, clamped to the exact observed [min, max]."""
        if not self.count:
            return math.nan
        rank = q * (self.count - 1) + 1          # 1-based target rank
        seen = self.under
        if rank <= seen:                          # inside the under bucket
            return self.min
        for i, c in enumerate(self.counts):
            if not c:
                continue
            if rank <= seen + c:
                lo = 10.0 ** (_LO_EXP + i / _SCALE)
                hi = 10.0 ** (_LO_EXP + (i + 1) / _SCALE)
                frac = (rank - seen) / c
                val = lo * (hi / lo) ** frac
                return min(max(val, self.min), self.max)
            seen += c
        return self.max                           # over bucket / tail

    def summary(self) -> dict:
        if not self.count:
            return dict(count=0)
        return dict(count=self.count, sum=self.sum,
                    mean=self.sum / self.count, min=self.min, max=self.max,
                    p50=self.quantile(0.50), p95=self.quantile(0.95),
                    p99=self.quantile(0.99))


# ---------------------------------------------------------------------------
# spans
# ---------------------------------------------------------------------------


class _NullSpan:
    """Shared do-nothing context manager for disabled registries."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("reg", "name", "t0", "_ann")

    def __init__(self, reg: "Registry", name: str):
        self.reg = reg
        self.name = name
        self._ann = None

    def __enter__(self):
        reg = self.reg
        if reg._jax_ann:
            from jax.profiler import TraceAnnotation
            self._ann = TraceAnnotation(self.name)
            self._ann.__enter__()
        reg._stack().append(self.name)
        self.t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc):
        dur = time.perf_counter_ns() - self.t0
        reg = self.reg
        stack = reg._stack()
        stack.pop()
        reg._end_span(self.name, self.t0, dur, len(stack))
        if self._ann is not None:
            self._ann.__exit__(*exc)
        return False


# ---------------------------------------------------------------------------
# the registry
# ---------------------------------------------------------------------------


class Registry:
    """Thread-safe metrics + span + event store.  See the module docstring
    for the metric families and the disabled-mode contract."""

    def __init__(self, enabled: bool = False, *, max_spans: int = 200_000,
                 max_events: int = 200_000, jax_annotations: bool = False,
                 mirror: "Registry | None" = None):
        self.enabled = enabled
        self.max_spans = max_spans
        self.max_events = max_events
        self._jax_ann = jax_annotations
        # span mirror: completed spans are *also* appended to this
        # registry's span log whenever it is enabled — the pattern for a
        # component (e.g. RecsysService) that needs private metrics
        # (counters/histograms that must not blend with other components
        # reading the same names) while still contributing its spans to
        # the process-wide --trace timeline.  Only the span log mirrors;
        # the mirror's metric plane is untouched.
        self.mirror = mirror
        self._lock = threading.Lock()
        self._tls = threading.local()
        self.counters: dict = {}
        self.gauges: dict = {}
        self.hists: dict = {}
        # span log entries: (name, t_start_ns, dur_ns, tid, depth)
        self.spans: list = []
        self.spans_dropped = 0
        # event log entries: (wall_ts, name, fields-dict)
        self.events: list = []
        self.events_dropped = 0
        self.origin_ns = time.perf_counter_ns()
        self.origin_wall = time.time()

    # -- lifecycle ----------------------------------------------------------

    def enable(self, *, jax_annotations: bool | None = None) -> "Registry":
        self.enabled = True
        if jax_annotations is not None:
            self._jax_ann = jax_annotations
        return self

    def disable(self) -> "Registry":
        self.enabled = False
        return self

    def reset(self) -> "Registry":
        """Drop all recorded state (enabled flag untouched)."""
        with self._lock:
            self.counters.clear()
            self.gauges.clear()
            self.hists.clear()
            self.spans.clear()
            self.events.clear()
            self.spans_dropped = self.events_dropped = 0
            self.origin_ns = time.perf_counter_ns()
            self.origin_wall = time.time()
        return self

    # -- metric plane -------------------------------------------------------

    def counter_add(self, name: str, value: float = 1.0) -> None:
        if not self.enabled:
            return
        with self._lock:
            self.counters[name] = self.counters.get(name, 0.0) + value

    def gauge_set(self, name: str, value: float) -> None:
        if not self.enabled:
            return
        with self._lock:
            self.gauges[name] = value

    def observe(self, name: str, value: float) -> None:
        if not self.enabled:
            return
        with self._lock:
            h = self.hists.get(name)
            if h is None:
                h = self.hists[name] = Histogram()
            h.observe(value)

    def event(self, name: str, **fields) -> None:
        if not self.enabled:
            return
        with self._lock:
            if len(self.events) >= self.max_events:
                self.events_dropped += 1
                return
            self.events.append((time.time(), name, fields))

    # -- span plane ---------------------------------------------------------

    def span(self, name: str):
        """Nested timing scope: ``with reg.span("flush.retrieve"): ...``.
        Returns a shared no-op when the registry is disabled."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name)

    def _stack(self) -> list:
        s = getattr(self._tls, "stack", None)
        if s is None:
            s = self._tls.stack = []
        return s

    def _end_span(self, name, t0, dur_ns, depth) -> None:
        tid = threading.get_ident()
        with self._lock:
            if len(self.spans) < self.max_spans:
                self.spans.append((name, t0, dur_ns, tid, depth))
            else:
                self.spans_dropped += 1
            h = self.hists.get(name)
            if h is None:
                h = self.hists[name] = Histogram()
            h.observe(dur_ns * 1e-9)
        # span t0s are absolute perf_counter_ns, so a mirrored entry stays
        # consistent under the mirror's own origin; taken outside our lock
        # (mirrors are acyclic by construction — the process default never
        # mirrors anywhere)
        m = self.mirror
        if m is not None and m is not self and m.enabled:
            with m._lock:
                if len(m.spans) < m.max_spans:
                    m.spans.append((name, t0, dur_ns, tid, depth))
                else:
                    m.spans_dropped += 1

    def record_span(self, name: str, t0_ns: int, dur_ns: int,
                    depth: int = 0) -> None:
        """Record an externally-timed interval as a completed span — for
        intervals that overlap or cross function boundaries (e.g. the
        dispatch-ahead flush latency, measured dispatch → result
        readiness while the next flush is already in flight)."""
        if not self.enabled:
            return
        self._end_span(name, t0_ns, dur_ns, depth)

    def span_durations(self, name: str) -> list:
        """Seconds of every retained completed span named ``name``, in
        completion order (subject to the max_spans retention cap; the
        histogram of the same name never drops observations)."""
        with self._lock:
            return [s[2] * 1e-9 for s in self.spans if s[0] == name]

    # -- read plane ---------------------------------------------------------

    def counter(self, name: str, default: float = 0.0) -> float:
        return self.counters.get(name, default)

    def gauge(self, name: str, default: float = math.nan) -> float:
        return self.gauges.get(name, default)

    def hist_summary(self, name: str) -> dict:
        h = self.hists.get(name)
        return h.summary() if h is not None else dict(count=0)

    def snapshot(self) -> dict:
        """One dict with everything: counters, gauges, histogram summaries,
        span/event log occupancy.  The unified export every consumer
        (stats(), benchmarks, exporters) reads."""
        with self._lock:
            return dict(
                counters=dict(self.counters),
                gauges=dict(self.gauges),
                histograms={k: h.summary() for k, h in self.hists.items()},
                spans=dict(retained=len(self.spans),
                           dropped=self.spans_dropped),
                events=dict(retained=len(self.events),
                            dropped=self.events_dropped),
            )
