"""Exporters for `repro.obs` registries.

Three formats, one source of truth (`Registry`):

  * `chrome_trace` / `write_trace` — Chrome trace-event JSON ("X"
    complete events on the monotonic timebase).  Load in Perfetto
    (https://ui.perfetto.dev) or chrome://tracing; span nesting is
    reconstructed from interval containment per thread track.
  * `events_jsonl` / `write_events_jsonl` — one JSON object per line for
    time-series (`{"ts": <unix seconds>, "event": <name>, ...fields}`):
    recall/RMSE-over-time, queue depth, ΔΩ sizes.
  * `prometheus_text` — Prometheus text exposition (counters, gauges,
    and histogram summaries as quantile gauges), for scraping or diffing.
"""
from __future__ import annotations

import json
import re

from repro.obs.registry import Registry

_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")


def chrome_trace(reg: Registry) -> dict:
    """The registry's span log as a Chrome trace-event document."""
    with reg._lock:
        spans = list(reg.spans)
        origin = reg.origin_ns
    tids = {}
    events = [dict(name="process_name", ph="M", pid=0, tid=0,
                   args=dict(name="repro.obs"))]
    for name, t0, dur, tid, depth in spans:
        track = tids.setdefault(tid, len(tids))
        events.append(dict(
            name=name, ph="X", pid=0, tid=track,
            ts=(t0 - origin) / 1e3,        # µs, monotonic, origin-relative
            dur=dur / 1e3,
            args=dict(depth=depth)))
    return dict(traceEvents=events, displayTimeUnit="ms")


def write_trace(reg: Registry, path: str) -> str:
    with open(path, "w") as f:
        json.dump(chrome_trace(reg), f)
        f.write("\n")
    return path


def events_jsonl(reg: Registry) -> str:
    with reg._lock:
        events = list(reg.events)
    lines = [json.dumps(dict({"ts": ts, "event": name}, **fields))
             for ts, name, fields in events]
    return "\n".join(lines) + ("\n" if lines else "")


def write_events_jsonl(reg: Registry, path: str) -> str:
    with open(path, "w") as f:
        f.write(events_jsonl(reg))
    return path


def _prom_name(name: str) -> str:
    return _NAME_RE.sub("_", name)


def prometheus_text(reg: Registry) -> str:
    """Prometheus text exposition of the registry's metric plane."""
    snap = reg.snapshot()
    out = []
    for name, v in sorted(snap["counters"].items()):
        n = _prom_name(name)
        out += [f"# TYPE {n} counter", f"{n} {v:.9g}"]
    for name, v in sorted(snap["gauges"].items()):
        n = _prom_name(name)
        out += [f"# TYPE {n} gauge", f"{n} {v:.9g}"]
    for name, s in sorted(snap["histograms"].items()):
        n = _prom_name(name)
        out.append(f"# TYPE {n} summary")
        if s.get("count"):
            for q in ("p50", "p95", "p99"):
                out.append(f'{n}{{quantile="0.{q[1:]}"}} {s[q]:.9g}')
            out.append(f"{n}_sum {s['sum']:.9g}")
        out.append(f"{n}_count {s.get('count', 0)}")
    return "\n".join(out) + "\n"
