"""`repro.obs` — unified metrics, spans, and trace export (ISSUE 6).

One module-level default `Registry`, **disabled** unless a process opts
in (`obs.enable()`), so library code can instrument unconditionally:

    from repro import obs
    obs.enable()                       # or leave disabled: all no-ops
    with obs.span("train.epoch"):
        ...
    obs.counter_add("train.updates", nnz)
    obs.event("eval", epoch=3, rmse=0.81)
    obs.write_trace("/tmp/trace.json")     # → Perfetto / chrome://tracing

Components that must always keep stats have two patterns.  A singleton
per process (a `fit()` call) uses `obs.scoped()`: the shared default
registry when enabled — so everything lands on one timeline — or a
*private enabled* registry otherwise, so its own stats work while the
rest of the process pays the disabled-mode no-op cost only.  A component
that can have same-named siblings (a `RecsysService` — two services both
write `serve.users`, `serve.busy_seconds`, `serve.flush`) instead keeps
a private registry with ``Registry(enabled=True, mirror=obs.get())``:
its metric plane never blends with a sibling's, while completed spans
are mirrored onto the default registry's timeline whenever that is
enabled (`--trace`).

Naming scheme (see docs/ARCHITECTURE.md §7): dot-separated
`<subsystem>.<stage>[.<substage>]` — e.g. `serve.flush.retrieve.dedup`,
`train.epoch.eval`, `online.merge`.  A span's histogram shares its name;
counters/gauges use the same prefixes (`serve.users`,
`serve.queue_depth`).
"""
from __future__ import annotations

from repro.obs import export as _export
from repro.obs.registry import Histogram, Registry

__all__ = [
    "Registry", "Histogram", "get", "scoped", "enable", "disable",
    "enabled", "reset", "span", "counter_add", "gauge_set", "observe",
    "event", "snapshot", "span_durations", "chrome_trace", "write_trace",
    "events_jsonl", "write_events_jsonl", "prometheus_text",
]

_DEFAULT = Registry(enabled=False)


def get() -> Registry:
    """The process-wide default registry."""
    return _DEFAULT


def scoped() -> Registry:
    """The default registry when enabled, else a fresh private *enabled*
    one — for components whose stats must work regardless of the global
    opt-in (their recording cost is theirs alone in that case)."""
    return _DEFAULT if _DEFAULT.enabled else Registry(enabled=True)


def enable(*, jax_annotations: bool | None = None) -> Registry:
    return _DEFAULT.enable(jax_annotations=jax_annotations)


def disable() -> Registry:
    return _DEFAULT.disable()


def enabled() -> bool:
    return _DEFAULT.enabled


def reset() -> Registry:
    return _DEFAULT.reset()


# -- recording conveniences on the default registry -------------------------

def span(name: str):
    return _DEFAULT.span(name)


def counter_add(name: str, value: float = 1.0) -> None:
    _DEFAULT.counter_add(name, value)


def gauge_set(name: str, value: float) -> None:
    _DEFAULT.gauge_set(name, value)


def observe(name: str, value: float) -> None:
    _DEFAULT.observe(name, value)


def event(name: str, **fields) -> None:
    _DEFAULT.event(name, **fields)


def snapshot() -> dict:
    return _DEFAULT.snapshot()


def span_durations(name: str) -> list:
    return _DEFAULT.span_durations(name)


# -- exporters (any registry; default to the shared one) --------------------

def chrome_trace(reg: Registry | None = None) -> dict:
    return _export.chrome_trace(reg or _DEFAULT)


def write_trace(path: str, reg: Registry | None = None) -> str:
    return _export.write_trace(reg or _DEFAULT, path)


def events_jsonl(reg: Registry | None = None) -> str:
    return _export.events_jsonl(reg or _DEFAULT)


def write_events_jsonl(path: str, reg: Registry | None = None) -> str:
    return _export.write_events_jsonl(reg or _DEFAULT, path)


def prometheus_text(reg: Registry | None = None) -> str:
    return _export.prometheus_text(reg or _DEFAULT)
