"""repro.loop — the always-on online loop (ISSUE 10).

The paper's headline claim is *online* learning: CULSH-MF keeps serving
while rating deltas stream in and the model keeps training.  PR 7 built
the resilience primitives (WAL-backed updates, fault injection,
validate-then-swap rebuilds, load shedding); this package is the
supervisor that composes them into one always-on process:

  * `OnlineLoop`   — a cooperative supervisor that time-slices one
    device budget between `RecsysService` flushes and scheduled training
    micro-epochs, with bounded staleness, ingest-queue backpressure, a
    watchdog that degrades to frozen-model serving, drift-triggered
    index rebuilds, and crash-safe `recover()` (bit-identical
    `OnlineState` after kill -9 at any fault site);
  * `LoopConfig`   — the slice scheduler's knobs.

Failure semantics and the slice state machine are documented in
docs/ARCHITECTURE.md §10; benchmarks/bench_online.py measures the loop
under a zipf-drift stream with injected slice faults.
"""
from repro.loop.supervisor import LoopConfig, OnlineLoop

__all__ = ["LoopConfig", "OnlineLoop"]
