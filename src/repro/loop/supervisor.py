"""The always-on supervisor: serve / train / drift / publish, one device.

`OnlineLoop` runs the paper's online claim as a single cooperative
process.  Time is cut into **slices**; each slice walks a fixed state
machine over the one device budget:

    serve ─→ train ─→ drift ─→ publish ─→ checkpoint ─→ watchdog
      │        │        │         │           │
      │        │        │         │           └ every ckpt_every slices:
      │        │        │         │             atomic progress cut + WAL prune
      │        │        │         └ bounded staleness: push the trained
      │        │        │           state into the service when the
      │        │        │           serve-behind-train lag or wall-clock
      │        │        │           staleness crosses its cap
      │        │        └ every drift_every slices: held-out RMSE window;
      │        │          a trip publishes + rebuilds the index
      │        └ apply queued ΔΩ + bounded micro-epochs (skipped under
      │          ingest backpressure), one atomic WAL "slice" entry
      └ at most serve_flushes micro-batches, then a full device sync —
        the explicit phase hand-off of Tan et al.'s interleaved budget

Crash safety is the design center.  A slice's mutations — the ΔΩ deltas
it applies and the micro-epochs it runs — are logged as **one** WAL
entry *before* they are applied (append-then-apply, the
`resil.wal.OnlineUpdater` discipline), so at every kill point the log
covers at least the in-memory state.  The entry is the slice's atomic
unit on both sides:

  * **live**: the slice-boundary divergence guard (satellite: a
    diverging micro-epoch rolls back the *slice*, not one update)
    rejects the whole entry — ``updater.state`` is left exactly the
    pre-slice `OnlineState`, the seq still advances;
  * **replay**: `recover()` re-runs the entry through the same
    `_apply_slice` — same state, same triples, same keys, same epoch
    cursor, same deterministic program — so guard trips re-trip
    identically and the recovered state is **bit-identical** to an
    uninterrupted run (asserted in tests/test_resil.py).

Loop progress (slice counter, micro-epoch cursor) rides in the same
crash-atomic checkpoint as the model state (`loop_slice`/`loop_micro`
leaves next to `wal.state_tree`), cut at the current WAL seq — the
pending-delta watermark — so resume starts from a consistent
(state, log, cursor) triple.  The loop owns the checkpoint cadence: the
embedded updater's own periodic checkpoints are disabled (they would
write a state-only tree the loop template cannot restore).

Failure handling is degrade-not-die: a failed or stalled slice trips
the watchdog and the loop serves the **frozen** model for
``freeze_slices`` slices (training suspended, serving answers from the
last published params) instead of exiting.  The three fault sites
compiled into the loop body — ``loop.slice`` / ``loop.drift`` /
``loop.ckpt`` — are pure crash windows: no state mutation is in flight
at any of them, which is what makes kill -9 there recoverable
bit-identically (the chaos suite kills at each).
"""
from __future__ import annotations

import collections
import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import model, simlsh
from repro.core.online import (OnlineState, build_micro_schedule, micro_epoch,
                               online_update)
from repro.resil import faults
from repro.resil.guard import DivergenceError, GuardConfig, check_divergence
from repro.resil.validate import PoisonBatchError, check_delta
from repro.resil.wal import OnlineUpdater, state_from_tree, state_tree
from repro.serve import index as lsh_index
from repro.serve.service import RecsysService, ServeConfig
from repro.train import checkpoint


@dataclasses.dataclass(frozen=True)
class LoopConfig:
    """Slice scheduler knobs.  Defaults target the bench/test scale;
    production tunes ``serve_flushes``/``micro_epochs`` to the actual
    flush-vs-epoch cost ratio on the device."""
    serve_flushes: int = 2       # micro-batches dispatched per slice
    micro_epochs: int = 1        # scheduled training rounds per slice
    micro_batch: int = 4096      # schedule batch for the micro-epochs
    deltas_per_slice: int = 4    # ΔΩ updates applied per slice (the rest
                                 # stay queued → backpressure)
    backpressure_queue: int = 4  # queue depth at which micro-epochs are
                                 # skipped in favour of draining ΔΩ
    max_lag: int = 2             # publish after this many unpublished
                                 # slice mutations (serve-behind-train cap)
    max_staleness_s: float = 30.0  # …or after this much wall-clock
    ckpt_every: int = 2          # slices between atomic progress cuts
    drift_every: int = 2         # slices between held-out RMSE probes
    drift_window: int = 8        # RMSE window the trip compares against
    drift_tol: float = 0.10      # trip when rmse > (1+tol) × window min
    watchdog_s: float = 60.0     # slice wall-time budget before freezing
    freeze_slices: int = 2       # slices served frozen after a trip
    tail_cap: int = 128          # index tail for `build_service`
    seed: int = 0                # micro-epoch PRNG stream (keys are
                                 # WAL-logged, so replay never re-derives)


def _loop_template() -> dict:
    """Checkpoint structure: the state tree + the loop cursors.  Loop
    checkpoints and `wal._template` ones are not interchangeable — the
    leaf sets differ — which is why the loop disables the updater's own
    cadence and owns every checkpoint under its root."""
    from repro.resil.wal import _template
    return dict(_template(), loop_slice=0, loop_micro=0)


def _slice_guard(p_new, p_old, guard: GuardConfig) -> None:
    """Slice-boundary divergence check: the micro-epochs train *all*
    params (not just grown slices), so compare whole-param RMS
    (``M_old=N_old=0``) against the pre-micro scale."""
    probs = check_divergence(p_new, p_old, M_old=0, N_old=0, cfg=guard)
    if probs:
        raise DivergenceError(
            "slice-boundary guard tripped after micro-epochs — slice "
            "rolled back: " + "; ".join(probs))


def _apply_slice(state: OnlineState, deltas: list, *, rounds: int,
                 epoch0: int, mkey, lsh, hp, K: int, epochs: int,
                 batch: int, micro_batch: int,
                 guard: GuardConfig | None,
                 registry: obs.Registry | None = None, sched=None):
    """One slice's training work, shared verbatim by the live path and
    WAL replay (the replay contract *is* this function).

    ``deltas`` is ``[(rows, cols, vals, key, M_new, N_new), ...]`` —
    already validated (poison batches are quarantined before logging).
    Applies each ΔΩ through `online_update` (per-delta guard trips are
    replay-stable rejections, counted and skipped), then runs ``rounds``
    micro-epochs over the merged Ω̂ from the logged key/epoch cursor,
    then the slice-boundary guard.  Raises `DivergenceError` with the
    caller's ``state`` untouched; returns ``(new_state, sched)`` with
    the (possibly rebuilt) micro schedule for reuse while Ω̂ is stable.
    """
    reg = registry if registry is not None else obs.scoped()
    st = state
    for (r, c, v, k, M_new, N_new) in deltas:
        try:
            st = online_update(st, r, c, v, lsh, hp, jnp.asarray(k),
                               M_new=int(M_new), N_new=int(N_new), K=K,
                               epochs=epochs, batch=batch, guard=guard,
                               registry=reg)
        except DivergenceError:
            reg.counter_add("resil.guard_trips")
    if rounds:
        if sched is None or sched.sp is not st.sp:
            sched = build_micro_schedule(st.sp, st.JK, batch=micro_batch)
        pre = st
        for i in range(rounds):
            st = micro_epoch(st, hp, jax.random.fold_in(jnp.asarray(mkey), i),
                             epoch=epoch0 + i, sched=sched, batch=micro_batch,
                             registry=reg)
        if guard is not None:
            _slice_guard(st.params, pre.params, guard)
    return st, sched


class OnlineLoop:
    """Cooperative serve/train supervisor over one `OnlineUpdater` (the
    crash-safe state) and one single-device `RecsysService` (the request
    plane).  See the module docstring for the slice state machine.

    The loop takes ownership of the updater's persistence root: its
    periodic checkpoints are disabled (``ckpt_every`` → ∞) and every
    durable cut goes through `OnlineLoop.checkpoint` so restore always
    sees the loop template.  Direct `updater.update()` calls between
    slices remain safe (same WAL, same seq space) but `recover()` must
    then replay them too — which it does, dispatching on entry kind.
    """

    def __init__(self, updater: OnlineUpdater, service: RecsysService,
                 cfg: LoopConfig = LoopConfig(), *, holdout=None,
                 registry: obs.Registry | None = None,
                 _slice: int = 0, _micro: int = 0):
        if service._shard_state is not None:
            raise ValueError(
                "OnlineLoop needs a single-device RecsysService — sharded "
                "serving is read-only (ShardedIngestUnsupported) and cannot "
                "adopt published states; run the loop on a shards=0 service "
                "and rebuild the sharded tier from its checkpoints")
        self.updater = updater
        self.svc = service
        self.cfg = cfg
        self.holdout = holdout          # (rows, cols, vals) held-out stream
        self.obs = registry if registry is not None else obs.scoped()
        # the loop owns the checkpoint cadence (loop template, see above)
        self.updater.ckpt_every = 10 ** 9
        self._slice = _slice            # completed-slice counter
        self._micro = _micro            # micro-epoch cursor (lr schedule)
        self._deltas: collections.deque = collections.deque()
        self._sched = None              # cached MicroSchedule for stable Ω̂
        self._frozen = 0                # slices left in frozen-model serving
        self._lag = 0                   # applied-but-unpublished mutations
        self._stale_t0: float | None = None
        self._published_N = int(service.params.V.shape[0])
        self._drift_rmse: collections.deque = collections.deque(
            maxlen=cfg.drift_window)

    # ---- public surface ---------------------------------------------------

    @property
    def state(self) -> OnlineState:
        return self.updater.state

    @property
    def slice_count(self) -> int:
        return self._slice

    def staleness_s(self) -> float:
        """Wall-clock age of the oldest applied-but-unpublished mutation
        (0.0 when serving is fully caught up with training)."""
        return (0.0 if self._stale_t0 is None
                else time.perf_counter() - self._stale_t0)

    def offer_delta(self, rows, cols, vals, key, *, M_new: int,
                    N_new: int) -> None:
        """Queue a ΔΩ batch for the next train phase (host-side, never
        blocks the serve phase).  Depth feeds backpressure."""
        self._deltas.append((np.asarray(rows), np.asarray(cols),
                             np.asarray(vals), np.asarray(key),
                             int(M_new), int(N_new)))
        self.obs.gauge_set("loop.ingest_queue", float(len(self._deltas)))

    def run(self, n_slices: int, *, degrade: bool = True) -> "OnlineLoop":
        """Run ``n_slices`` slices.  With ``degrade`` (the production
        default) a failed slice — injected fault, real bug — trips the
        watchdog and the loop keeps serving frozen; ``degrade=False``
        propagates (the chaos suite's simulated kill -9)."""
        for _ in range(n_slices):
            try:
                self.run_slice()
            except Exception:  # noqa: BLE001 — degrade, never die
                if not degrade:
                    raise
                self.obs.counter_add("loop.slice_failures")
                self._freeze()
        return self

    def run_slice(self) -> "OnlineLoop":
        """One slice of the state machine.  Exceptions propagate (callers
        wanting degrade-not-die semantics go through `run`)."""
        cfg, reg = self.cfg, self.obs
        t0 = time.perf_counter()
        # crash window: nothing is in flight between slices — a kill here
        # recovers bit-identically (nothing to redo past the WAL)
        faults.fire("loop.slice")
        with reg.span("loop.slice"):
            self._serve_phase()
            if self._frozen > 0:
                self._frozen -= 1
                reg.gauge_set("loop.frozen", float(self._frozen > 0))
            else:
                try:
                    with reg.span("loop.train"):
                        self._train_phase()
                except DivergenceError:
                    # slice-boundary rollback: state is pre-slice, the WAL
                    # entry re-trips on replay (replay-stable rejection)
                    reg.counter_add("loop.guard_trips")
                except Exception:  # noqa: BLE001 — poisoned slice:
                    # degrade to frozen-model serving instead of dying
                    reg.counter_add("loop.slice_failures")
                    self._freeze()
            self._drift_phase()
            self._maybe_publish()
            if cfg.ckpt_every and (self._slice + 1) % cfg.ckpt_every == 0:
                self.checkpoint()
        self._slice += 1
        reg.gauge_set("loop.slice", float(self._slice))
        dur = time.perf_counter() - t0
        if cfg.watchdog_s and dur > cfg.watchdog_s and not self._frozen:
            # stalled slice (e.g. an injected stall at a serve site):
            # suspend training before the stall compounds into lag
            reg.counter_add("loop.watchdog_trips")
            self._freeze()
        return self

    # ---- phases -----------------------------------------------------------

    def _serve_phase(self) -> None:
        reg = self.obs
        with reg.span("loop.serve"):
            self.svc.flush_some(self.cfg.serve_flushes)
        stale = self.staleness_s()
        reg.observe("loop.staleness_s", stale)      # p99 over the run
        reg.gauge_set("loop.staleness_s", stale)
        reg.gauge_set("loop.lag", float(self._lag))
        reg.gauge_set("loop.frozen", float(self._frozen > 0))

    def _train_phase(self) -> None:
        cfg, up, reg = self.cfg, self.updater, self.obs
        # backpressure: a deep ingest queue steals this slice's micro-epoch
        # budget — drain ΔΩ first, train again once the queue is shallow
        rounds = (0 if len(self._deltas) >= cfg.backpressure_queue
                  else cfg.micro_epochs)
        take = []
        while self._deltas and len(take) < cfg.deltas_per_slice:
            take.append(self._deltas.popleft())
        reg.gauge_set("loop.ingest_queue", float(len(self._deltas)))
        # quarantine before logging: poison ΔΩ never enters the redo log
        good, cur_m, cur_n = [], up.state.M, up.state.N
        for d in take:
            r, c, v, k, m_new, n_new = d
            try:
                check_delta(r, c, v, M_new=m_new, N_new=n_new,
                            M_old=cur_m, N_old=cur_n)
            except PoisonBatchError:
                reg.counter_add("loop.quarantined")
                continue
            good.append(d)
            cur_m, cur_n = m_new, n_new
        if not good and not rounds:
            return
        # one atomic WAL entry for the whole slice, logged before applying
        seq = up.seq + 1
        epoch0 = self._micro
        mkey = np.asarray(
            jax.random.fold_in(jax.random.PRNGKey(cfg.seed), seq))
        meta = dict(up._static_meta(), kind="slice", seq=seq,
                    slice=self._slice, n_deltas=len(good),
                    deltas=[dict(M_new=d[4], N_new=d[5]) for d in good],
                    rounds=rounds, epoch0=epoch0,
                    micro_batch=cfg.micro_batch)
        arrays = {"mkey": mkey}
        for i, (r, c, v, k, _, _) in enumerate(good):
            arrays.update({f"d{i}_rows": r, f"d{i}_cols": c,
                           f"d{i}_vals": v, f"d{i}_key": k})
        with reg.span("resil.wal.append"):
            up.wal.append(seq, arrays, meta)
        reg.counter_add("resil.wal.appends")
        # the entry is durable from here: the seq advances no matter how
        # applying it ends, because replay owns the entry's outcome (a
        # guard trip re-trips; only a *transient* mid-apply fault can make
        # replay succeed where live failed — recovery then keeps the WAL's
        # version, preferring no data loss over mirroring a degraded run)
        up.seq = seq
        try:
            st2, sched = _apply_slice(
                up.state, [(r, c, v, k, m, n) for (r, c, v, k, m, n) in good],
                rounds=rounds, epoch0=epoch0, mkey=mkey, lsh=up.lsh,
                hp=up.hp, K=up.K, epochs=up.epochs, batch=up.batch,
                micro_batch=cfg.micro_batch, guard=up.guard, registry=reg,
                sched=self._sched)
        finally:
            self._micro += rounds       # cursor advances on every outcome,
                                        # matching what replay will do
        up.state = st2
        self._sched = sched
        self._note_mutation()
        reg.counter_add("loop.slices_trained")

    def _drift_phase(self) -> None:
        cfg, reg = self.cfg, self.obs
        if self.holdout is None or not cfg.drift_every:
            return
        if (self._slice + 1) % cfg.drift_every:
            return
        # crash window: drift only *reads* state (the probe, the window)
        faults.fire("loop.drift")
        st = self.updater.state
        r, c, v = self.holdout
        with reg.span("loop.drift"):
            rmse = float(model.rmse(st.params, st.sp, st.JK,
                                    jnp.asarray(r), jnp.asarray(c),
                                    jnp.asarray(v)))
        reg.gauge_set("loop.drift_rmse", rmse)
        window = self._drift_rmse
        tripped = (len(window) >= 2
                   and rmse > min(window) * (1.0 + cfg.drift_tol))
        window.append(rmse)
        if tripped:
            reg.counter_add("loop.drift_rebuilds")
            reg.event("loop.drift_trip", rmse=rmse, slice=self._slice)
            # the stream moved under the model: make serving current, then
            # rebuild the index from today's accumulators (validate-then-
            # swap on the rebuilder thread; serving never pauses)
            self._publish()
            self.svc.request_rebuild(simlsh.pack_bits(st.S >= 0))
            window.clear()              # re-baseline after the rebuild

    def _maybe_publish(self) -> None:
        cfg = self.cfg
        if not self._lag:
            return
        if (self._lag >= cfg.max_lag
                or (cfg.max_staleness_s
                    and self.staleness_s() >= cfg.max_staleness_s)):
            self._publish()

    def _publish(self) -> None:
        """Hand the trained state to the service (drain → re-sign → swap →
        tail-ingest → re-warm, all inside `ingest_online_update`)."""
        if not self._lag:
            return
        st = self.updater.state
        with self.obs.span("loop.publish"):
            self.svc.ingest_online_update(st, N_old=self._published_N)
        self._published_N = st.N
        self._lag = 0
        self._stale_t0 = None
        self.obs.counter_add("loop.publishes")
        self.obs.gauge_set("loop.lag", 0.0)
        self.obs.gauge_set("loop.staleness_s", 0.0)

    def checkpoint(self) -> None:
        """Atomic progress cut: model state + loop cursors in one
        crash-atomic `train.checkpoint` step at the current WAL seq (the
        pending-delta watermark), then prune the entries it covers."""
        up, reg = self.updater, self.obs
        # crash window: before the durable cut — a kill here recovers from
        # the *previous* checkpoint plus the unpruned WAL suffix
        faults.fire("loop.ckpt")
        with reg.span("loop.ckpt"):
            tree = dict(state_tree(up.state),
                        loop_slice=np.int64(self._slice + 1),
                        loop_micro=np.int64(self._micro))
            checkpoint.save(up.ckpt_dir, tree, step=up.seq, sync=True)
        up.wal.prune(up.seq)
        up._ckpt_seq = up.seq
        reg.counter_add("loop.ckpts")

    def _note_mutation(self) -> None:
        self._lag += 1
        if self._stale_t0 is None:
            self._stale_t0 = time.perf_counter()

    def _freeze(self) -> None:
        """Degrade to frozen-model serving: the next ``freeze_slices``
        slices skip the train phase entirely; the service keeps answering
        from the last published params."""
        self._frozen = max(self._frozen, self.cfg.freeze_slices)
        self.obs.counter_add("loop.freezes")
        self.obs.gauge_set("loop.frozen", 1.0)

    # ---- construction / recovery ------------------------------------------

    @staticmethod
    def build_service(state: OnlineState, serve_cfg: ServeConfig, *,
                      tail_cap: int = 128,
                      registry: obs.Registry | None = None) -> RecsysService:
        """A warm single-device service from an `OnlineState`: re-sign the
        accumulators, build the index, warm the pipelines.  Used at first
        construction and by `recover` (the request plane is rebuilt fresh
        — only the model state is durable)."""
        sigs = simlsh.pack_bits(state.S >= 0)
        idx = lsh_index.build_index(sigs, tail_cap=tail_cap)
        return RecsysService(state.params, idx, state.sp, serve_cfg,
                             JK=state.JK, registry=registry).warmup()

    @classmethod
    def recover(cls, root: str, lsh, hp, serve_cfg: ServeConfig, *, K: int,
                epochs: int = 3, batch: int = 4096,
                cfg: LoopConfig = LoopConfig(),
                guard: GuardConfig | None = GuardConfig(),
                base_state: OnlineState | None = None, holdout=None,
                registry: obs.Registry | None = None) -> "OnlineLoop":
        """Resume after a crash: newest complete loop checkpoint + WAL
        replay (slice entries through `_apply_slice`, plain updater
        entries through `online_update`), then a fresh warm service from
        the recovered state.  The static arguments must match what the
        entries were logged with — `recover` refuses a mismatch rather
        than replay a different program.  ``base_state`` seeds a run that
        crashed before its first checkpoint."""
        reg = registry if registry is not None else obs.scoped()
        ckpt_dir = os.path.join(root, "ckpt")
        restored = checkpoint.try_restore(ckpt_dir, _loop_template())
        if restored is not None:
            tree, step = restored
            slice_ = int(tree.pop("loop_slice"))
            micro = int(tree.pop("loop_micro"))
            state = state_from_tree(tree)
        elif base_state is not None:
            state, step, slice_, micro = base_state, 0, 0, 0
        else:
            raise FileNotFoundError(
                f"no complete loop checkpoint under {ckpt_dir} and no "
                f"base_state to replay from")
        up = OnlineUpdater(state, lsh, hp, root=root, K=K, epochs=epochs,
                           batch=batch, ckpt_every=10 ** 9, guard=guard,
                           registry=reg, _seq=step, _ckpt_seq=step)
        want = up._static_meta()
        for e in up.wal.entries(after=step):
            for k, v in want.items():
                if e.meta.get(k) != v:
                    raise ValueError(
                        f"WAL entry {e.seq} was logged with {k}="
                        f"{e.meta.get(k)!r} but recover() got {v!r} — "
                        f"replay with the original static arguments")
            kind = e.meta.get("kind")
            if kind == "slice":
                deltas = [(e.arrays[f"d{i}_rows"], e.arrays[f"d{i}_cols"],
                           e.arrays[f"d{i}_vals"], e.arrays[f"d{i}_key"],
                           e.meta["deltas"][i]["M_new"],
                           e.meta["deltas"][i]["N_new"])
                          for i in range(e.meta["n_deltas"])]
                with reg.span("resil.wal.replay"):
                    try:
                        up.state, _ = _apply_slice(
                            up.state, deltas, rounds=e.meta["rounds"],
                            epoch0=e.meta["epoch0"],
                            mkey=e.arrays["mkey"], lsh=lsh, hp=hp, K=K,
                            epochs=epochs, batch=batch,
                            micro_batch=e.meta["micro_batch"], guard=guard,
                            registry=reg)
                    except DivergenceError:
                        reg.counter_add("loop.guard_trips")  # replay-stable
                micro = e.meta["epoch0"] + e.meta["rounds"]
                slice_ = max(slice_, e.meta["slice"] + 1)
            elif kind is None:
                # a plain OnlineUpdater.update entry in the shared seq space
                with reg.span("resil.wal.replay"):
                    try:
                        up.state = online_update(
                            up.state, e.arrays["rows"], e.arrays["cols"],
                            e.arrays["vals"], lsh, hp,
                            jnp.asarray(e.arrays["key"]),
                            M_new=e.meta["M_new"], N_new=e.meta["N_new"],
                            K=K, epochs=epochs, batch=batch, guard=guard,
                            registry=reg)
                    except DivergenceError:
                        reg.counter_add("resil.guard_trips")
            else:
                raise ValueError(f"WAL entry {e.seq} has unknown kind "
                                 f"{kind!r} — written by a newer layout?")
            up.seq = e.seq
            reg.counter_add("resil.wal.replayed")
        svc = cls.build_service(up.state, serve_cfg, tail_cap=cfg.tail_cap)
        return cls(up, svc, cfg, holdout=holdout, registry=reg,
                   _slice=slice_, _micro=micro)
