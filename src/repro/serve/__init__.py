"""repro.serve — LSH retrieval + serving subsystem.

Turns the training-side simLSH signatures into a production retrieval
stack: persistent bucketed index (`index`), batched candidate retrieval
(`retrieve`), and a micro-batching serving loop with candidate-only
scoring through the fused Pallas kernel (`service`).  The serving loop
is hardened by `repro.resil`: bounded admission with load shedding,
degraded popularity fallback, background validate-then-swap index
rebuilds, and poison-batch quarantine (docs/ARCHITECTURE.md §8).
"""
from repro.serve.index import (LSHIndex, build_index, insert, lookup_items,
                               lookup_signatures, needs_rebuild, rebuild)
from repro.serve.retrieve import (compact_pool, dedup_candidates,
                                  retrieve_for_items, retrieve_for_users,
                                  seed_items)
from repro.serve.service import (RecsysService, ServeConfig, full_topn,
                                 popular_shortlist, recommend_candidates)

__all__ = [
    "LSHIndex", "build_index", "insert", "lookup_items", "lookup_signatures",
    "needs_rebuild", "rebuild", "compact_pool", "dedup_candidates",
    "retrieve_for_items", "retrieve_for_users", "seed_items", "RecsysService",
    "ServeConfig", "full_topn", "popular_shortlist", "recommend_candidates",
]
