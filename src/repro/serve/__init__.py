"""repro.serve — LSH retrieval + serving subsystem.

Turns the training-side simLSH signatures into a production retrieval
stack: persistent bucketed index (`index`), batched candidate retrieval
(`retrieve` — the legacy pool+dedup pipeline and the window-walk path
that feeds the `lsh_retrieve` kernel), and a micro-batching serving loop
with candidate-only scoring through the fused Pallas kernels
(`service`).  The serving loop is hardened by `repro.resil`: bounded
admission with load shedding, degraded popularity fallback, background
validate-then-swap index rebuilds, and poison-batch quarantine
(docs/ARCHITECTURE.md §8).
"""
from repro.serve.index import (LSHIndex, ShardedLSHIndex, build_index,
                               build_sharded_index, insert, lookup_items,
                               lookup_signatures, needs_rebuild,
                               padded_flat_ids, rebuild, shard_bounds,
                               shard_local_view, signatures_of,
                               window_slices)
from repro.serve.retrieve import (compact_pool, dedup_candidates,
                                  enumerate_windows, retrieve_for_items,
                                  retrieve_for_users, seed_items,
                                  shard_seed_sigs, shard_walk_local,
                                  sig_window_descriptors, tail_hits,
                                  translate_local_ids, walk_candidates,
                                  window_descriptors)
from repro.serve.service import (RecsysService, ServeConfig,
                                 ShardedIngestUnsupported, full_topn,
                                 merge_topn, popular_shortlist,
                                 recommend_candidates, recommend_walked,
                                 recommend_walked_kernel)

__all__ = [
    "LSHIndex", "ShardedLSHIndex", "build_index", "build_sharded_index",
    "insert", "lookup_items", "lookup_signatures", "needs_rebuild",
    "padded_flat_ids", "rebuild", "shard_bounds", "shard_local_view",
    "signatures_of", "window_slices", "compact_pool", "dedup_candidates",
    "enumerate_windows", "retrieve_for_items", "retrieve_for_users",
    "seed_items", "shard_seed_sigs", "shard_walk_local",
    "sig_window_descriptors", "tail_hits", "translate_local_ids",
    "walk_candidates", "window_descriptors", "RecsysService", "ServeConfig",
    "ShardedIngestUnsupported",
    "full_topn", "merge_topn", "popular_shortlist", "recommend_candidates",
    "recommend_walked", "recommend_walked_kernel",
]
