"""Request-batching serving loop: retrieval → candidate scoring → top-N.

A `RecsysService` owns the trained parameters (packed once into the
`model.ServePlanes` scoring layout), the persistent `LSHIndex`, and two
serving pipelines:

  * ``candidate`` — one fused, jitted program (`recommend_candidates`):
    `retrieve.retrieve_for_users` (ANN candidates, single-sort dedup)
    feeding `kernels/candidate_score` with in-kernel plane gather — O(C)
    work per user, no host hop between retrieval and scoring.
  * ``full``      — exact `μ + b_i + b̂ + U V^T` top-N: O(N) work per
    user, kept as the exactness baseline (and for recall measurement).

Requests are micro-batched: `submit` accumulates user ids (a `deque` —
PR 1's ``list.pop(0)`` was O(n) per flush) and flushes a fixed-shape
batch whenever ``micro_batch`` are pending (padding keeps every flush
the same shape, so the jit cache stays warm after the first call).

Flushes are **dispatch-ahead** (double-buffered): `_flush_one` enqueues
flush k+1 onto the device before syncing flush k, so the host-side batch
assembly and result copy-out of one flush overlap the device compute of
the next.  Latency is measured per flush from dispatch to *result
readiness* (the sync), so p50/p95 stay honest — an overlapped flush's
latency includes any time it spent queued behind its predecessor — and
QPS divides by non-overlapping busy wall-time, never double-counting the
overlap.

Online ingestion (paper Alg. 4): `ingest_online_update` re-signs the
accumulator cache from `core.online.online_update` and *inserts* the new
columns into the index tail — no rebuild, no cold jit caches — falling back
to a rebuild only when the tail overflows.

Resilience (ISSUE 7, see docs/ARCHITECTURE.md §8): tail-overflow rebuilds
run on a background thread behind a validate-then-swap gate
(`resil.rebuild`) while index v keeps serving; the admission queue is
bounded (``max_pending``) with deadline-aware load shedding
(``deadline_s``) into a host-side popularity answer; hot-path failures
fall back to the exact `full_topn` baseline; and poison ingest batches
are quarantined (`resil.validate`) before any state is touched.  All of
it is observable — shed/degraded/fallback/quarantine counters live in
the service registry and surface through `stats()`.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import model, simlsh
from repro.core.model import Params
from repro.core.topk import SENTINEL
from repro.data.sparse import SparseMatrix
from repro.kernels.candidate_score.kernel import NEG
from repro.kernels.candidate_score.ops import score_candidates
from repro.kernels.lsh_retrieve.kernel import lsh_retrieve_topc
from repro.launch.mesh import make_shard_mesh, serve_shard_count, shard_map
from repro.resil import faults
from repro.resil.rebuild import IndexRebuilder
from repro.resil.validate import (PoisonBatchError, check_accumulators,
                                  check_ingest_batch)
from repro.serve import index as lsh_index
from repro.serve.retrieve import (candidate_pool, enumerate_windows,
                                  finalize_candidates, retrieve_for_users,
                                  seed_items, shard_seed_sigs,
                                  shard_walk_local, tail_hits,
                                  translate_local_ids, walk_candidates,
                                  window_descriptors)


class ShardedIngestUnsupported(NotImplementedError):
    """Online ingestion was attempted on a sharded service.  Sharded
    serving is deliberately read-only — the per-shard index/col-plane
    partitions are built once from a complete catalog.  Either run the
    ingest on a single-device service (``dataclasses.replace(cfg,
    shards=0)``) whose tail + rebuild path absorbs it and construct a
    fresh sharded service from the grown state, or hand the full
    signature set to `RecsysService.request_rebuild` on that
    single-device service and re-shard from the swapped index.
    Rejections are counted in ``serve.ingest_rejected`` (see `stats`)."""


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    mode: str = "candidate"   # candidate | full
    topn: int = 10
    micro_batch: int = 256
    # retrieval knobs
    C: int = 512              # candidate slots per user
    n_seeds: int = 8          # seed items per user
    cap: int = 8              # bucket-mates taken per band per seed
    n_popular: int = 64       # global popularity shortlist size (0 = off)
    seed_window: int = 64
    use_jk: bool = True       # include seeds' training Top-K lists
    fold_mates: bool = True   # fold per-(seed, band) bucket runs pairwise
                              # (halves the dedup sort width; see
                              # retrieve._fold_prefix_runs)
    pool_width: int = 0       # generic pre-dedup pool compaction width
                              # (0 = off — a wash on CPU, see
                              # retrieve.compact_pool; knob for TPU)
    band_budget: int = 512    # > 0 = the window-walk retrieval path (the
                              # default pipeline): merged per-band bucket
                              # intervals enumerated under this shared
                              # per-user slot budget
                              # (retrieve.walk_candidates) — no host-side
                              # dedup sort; duplicates are folded at top-n
                              # selection (CPU) or in the lsh_retrieve
                              # kernel's VMEM (accelerators).  0 = legacy
                              # pool+dedup retrieval (kept as the exact
                              # oracle).  Size it near the p90
                              # merged-interval mass (~q·n_seeds·3 at
                              # cap=8 on zipf catalogs) — budget
                              # truncation drops whole trailing windows,
                              # which costs recall fast
    shards: int | str = 0     # sharded serving data path (million-item
                              # catalogs): 0 = off — the single-device
                              # oracle path, unchanged; "auto" = the
                              # largest power of two ≤ the local device
                              # count; an int = exactly that many shards
                              # (power of two).  The col plane and LSH
                              # index partition into nnz-balanced item
                              # ranges; each flush runs the walk + score
                              # per shard under shard_map and tree-merges
                              # the per-shard top-N partials (log₂D
                              # ppermute rounds, no candidate gather).
                              # Walk path only (requires band_budget > 0)
                              # and read-only: online ingest goes through
                              # the single-device tail + rebuild path
    shard_budget: int = 0     # per-shard walk slot budget (0 = auto:
                              # 1.5×band_budget/D rounded up to 32, ≥64 —
                              # per-shard window mass is ≈1/D of the
                              # global one on nnz-balanced cuts, and the
                              # 1.5× slack absorbs shard skew before
                              # truncation starts costing recall)
    route_full_below: int = 0 # candidate-mode routing escape hatch: serve
                              # via exact full_topn when the catalog has at
                              # most this many items (candidate retrieval
                              # has a fixed per-user cost that exceeds the
                              # O(N) scan on small catalogs — measured
                              # crossover ≈ 48·C items on CPU).  -1 = that
                              # auto threshold; 0 = off (the default: tiny-
                              # catalog tests rely on candidate mode
                              # answering strictly from retrieved
                              # candidates)
    # resilience knobs (ISSUE 7)
    max_pending: int = 0      # admission bound on queued users (0 = off);
                              # overflow sheds the *oldest* chunks into the
                              # degraded popularity path.  Keep it ≥ a few
                              # micro_batches or steady traffic sheds too
    deadline_s: float = 0.0   # queue-wait deadline (0 = off): chunks older
                              # than this at dispatch time are shed instead
                              # of scored — bounded staleness over stalls
    background_rebuild: bool = True  # overflow rebuilds run on a worker
                              # thread behind a validate-then-swap gate
                              # (resil.rebuild); False = legacy synchronous
                              # rebuild on the ingest path
    rebuild_retries: int = 3  # failed/invalid background builds are retried
                              # this many times before giving up (the old
                              # index keeps serving either way)
    # kernel knobs
    tile_b: int = 8
    walk_tile_b: int = 16     # scan tile for the walk path's pool scoring
                              # (pure XLA gather+einsum; distinct from the
                              # Pallas kernel's tile_b).  16 won a paired
                              # interleaved A/B against 32 at B=256, W≈600
                              # on CPU — non-interleaved runs flip the
                              # verdict inside the ±25% container noise.
                              # Batches are padded up to a multiple, so
                              # any B works
    interpret: bool | None = None  # None = auto (interpret only on CPU);
                                   # never leave True on TPU — it would run
                                   # the hot path in the Pallas interpreter
    impl: str = "auto"        # auto | pallas | ref — 'auto' picks the pure-
                              # XLA ref on CPU (Pallas only interprets there)
                              # and the fused kernel elsewhere

    def scorer_impl(self) -> str:
        if self.impl != "auto":
            return self.impl
        return "ref" if jax.default_backend() == "cpu" else "pallas"

    def interpret_mode(self) -> bool:
        if self.interpret is not None:
            return self.interpret
        return jax.default_backend() == "cpu"

    def resolved_pool_width(self) -> int:
        return self.pool_width

    def resolved_shard_budget(self, shards: int) -> int:
        # 2× the per-shard share of the single-device walk budget: a
        # shard's bucket-head windows don't center on the seed, so parity
        # needs more enumeration slack than budget/D — at 1.5× the
        # planted-catalog recall sits ~0.02 below the single-device walk,
        # at 2× it is back within ±0.001 (multidev_checks::sharded_serve)
        if self.shard_budget:
            return self.shard_budget
        per = -(-2 * self.band_budget // max(shards, 1))
        return max(64, -(-per // 32) * 32)


@partial(jax.jit, static_argnames=("topn",))
def full_topn(params: Params, user_ids: jax.Array, *, topn: int):
    """Exact dense scoring — every item, every user.  The O(N) baseline."""
    scores = (params.mu + params.b[user_ids][:, None] + params.bh[None, :]
              + params.U[user_ids] @ params.V.T)
    return jax.lax.top_k(scores, topn)


@partial(jax.jit,
         static_argnames=("n_seeds", "cap", "C", "window", "pool_width",
                          "fold_mates", "tail_scan", "topn", "tile_b",
                          "interpret", "impl"))
def recommend_candidates(planes: model.ServePlanes, index, sp, user_ids,
                         JK, popular, *, n_seeds: int, cap: int, C: int,
                         window: int, pool_width: int, fold_mates: bool,
                         tail_scan: bool, topn: int,
                         tile_b: int, interpret: bool, impl: str):
    """The whole candidate hot path as ONE jitted program — retrieval and
    scoring fuse into a single dispatch with no host round-trip between
    them, and every intermediate (pools, sort keys, the candidate table)
    is program-local, so XLA reuses those buffers across the
    retrieval/scoring boundary instead of holding two jit outputs live
    (the PR 1 layout donated nothing and kept `cand` alive between two
    dispatches)."""
    # named_scope: the stage names below group the fused program's ops in
    # XLA device profiles (the in-jit mirror of the host-side obs spans)
    with jax.named_scope("serve.flush.retrieve"):
        cand = retrieve_for_users(index, sp, user_ids, n_seeds=n_seeds,
                                  cap=cap, C=C, JK=JK, popular=popular,
                                  window=window, pool_width=pool_width,
                                  fold_mates=fold_mates, tail_scan=tail_scan)
    with jax.named_scope("serve.flush.score"):
        return score_candidates(planes, user_ids, cand, topn=topn,
                                tile_b=tile_b, interpret=interpret, impl=impl)


def _pool_scores(urow, plane, cand, *, tile_b: int):
    """Scores of a [B, W] id pool with duplicates intact — tiled
    gather+einsum `lax.scan` (the candidate_score ref idiom: per-tile rows
    stay cache-resident, no [B, W, F] cube).  SENTINEL slots score NEG."""
    B, W = cand.shape
    F = plane.shape[1] - 1

    def tile(carry, args):
        u, c = args
        rows = plane[jnp.clip(c, 0, plane.shape[0] - 1)]
        s = (jnp.einsum("bf,bcf->bc", u[:, :F], rows[..., :F])
             + rows[..., F] + u[:, F][:, None])
        return carry, jnp.where(c == SENTINEL, NEG, s)

    _, s = jax.lax.scan(
        tile, 0, (urow.reshape(B // tile_b, tile_b, F + 1),
                  cand.reshape(B // tile_b, tile_b, W)))
    return s.reshape(B, W)


def _score_pool(planes: model.ServePlanes, user_ids, cand, popular, *,
                tile_b: int):
    """Walked pool + popularity shortlist → (scores [B, W(+P)],
    cand [B, W(+P)]).  The shortlist is batch-constant, so its scores are
    ONE [B, F]·[F, P] matmul — never a per-user gather."""
    B = cand.shape[0]
    F = planes.F
    pad = (-B) % tile_b
    urow = planes.row[user_ids].at[:, F].add(planes.mu)
    if pad:
        urow = jnp.pad(urow, ((0, pad), (0, 0)))
        cand = jnp.pad(cand, ((0, pad), (0, 0)),
                       constant_values=int(SENTINEL))
    s = _pool_scores(urow, planes.col, cand, tile_b=tile_b)[:B]
    cand = cand[:B]
    urow = urow[:B]
    if popular is None:
        return s, cand
    prow = planes.col[popular]                                   # [P, F+1]
    ps = (urow[:, :F] @ prow[:, :F].T
          + prow[None, :, F] + urow[:, F][:, None])
    cand = jnp.concatenate(
        [cand, jnp.broadcast_to(popular[None, :], (B, popular.shape[0]))],
        axis=1)
    return jnp.concatenate([s, ps], axis=1), cand


def _select_topn_masked(s, cand, *, topn: int):
    """Duplicate-masked top-n over a pool that was never deduplicated.

    n rounds of full-width argmax; each round masks every slot holding
    the picked *id*, so cross-band duplicates (and the popular∩walk
    overlap) collapse here, at O(n·W) elementwise cost, instead of in a
    [B, W] sort.  Full width is deliberate: a `top_k` slack only helps
    when the slack holds n distinct ids, and on zipf catalogs it usually
    does not — one id can occupy a slot in *every* band, and the measured
    rank of the 10th distinct id is p50 ≈ 4·topn, max ≈ 8·topn (N=100k,
    q=10), so a slack path degrades into an always-firing full-width
    fallback that costs strictly more than starting there.  Ties pick the
    lowest slot (`argmax`'s first-index rule), so the returned id *set*
    matches dedup-then-score exactly; only the order among equal-scored
    distinct ids can differ from a hashed-dedup pipeline."""
    bi = jnp.arange(s.shape[0])
    outs, outi = [], []
    for _ in range(topn):
        i = jnp.argmax(s, axis=1)
        sv = s[bi, i]
        picked = cand[bi, i]
        outs.append(sv)
        # an exhausted row (sv ≤ NEG) emits SENTINEL; masking `picked`
        # below is then harmless — every remaining score is already NEG
        outi.append(jnp.where(sv > NEG, picked, SENTINEL))
        s = jnp.where(cand == picked[:, None], NEG, s)
    return jnp.stack(outs, 1), jnp.stack(outi, 1)


def merge_topn(sa, ia, sb, ib, *, topn: int):
    """Merge two top-n partial lists into the top-n of their union.

    (scores, ids) pairs [B, n] → [B, topn].  The total order is (score
    descending, id ascending) — one two-key `lax.sort` over the [B, 2n]
    concatenation — which makes the merge associative and commutative, so
    the butterfly tree reduce below is shard-split-invariant (the
    property suite checks exactly this against a numpy lexsort oracle).
    Rows with fewer than n real candidates carry (NEG, SENTINEL) padding,
    which sinks below every real score; the two sides' real ids must be
    disjoint (shards partition the catalog), otherwise a duplicate id
    could occupy two output slots.

    Tie semantics vs the single-device path: `_select_topn_masked` breaks
    equal scores by pool position, this merge by id — the returned id
    *set* can differ only when distinct items tie exactly at the n-th
    score, where both answers are equally exact.
    """
    s = jnp.concatenate([sa, sb], axis=1)
    i = jnp.concatenate([ia, ib], axis=1)
    ns, ii = jax.lax.sort((-s, i), dimension=1, num_keys=2)
    return -ns[:, :topn], ii[:, :topn]


def _build_sharded_recommend(mesh, *, D: int, F: int, topn: int,
                             n_seeds: int, cap: int, budget: int,
                             window: int, tile_b: int, has_popular: bool):
    """The sharded flush as ONE jitted shard_map program.

    Per device: owner-compute + psum-share the seeds' band signatures
    (each seed lives in exactly one shard; the exchange is a [q, B, S]
    int32 psum — the only all-to-all in the program), walk the shard's
    local buckets by signature, score the local pool against the shard's
    col-plane slice, select a per-shard top-N in global ids, then merge
    partials with a log₂(D) XOR-partner butterfly of `ppermute`s — at
    round k partners' coverage sets are disjoint by construction, so no
    candidate is ever counted twice and no [B, pool] candidate set ever
    leaves its device.  After the butterfly every device holds the global
    answer; the host takes shard 0's copy.
    """
    spec_shard = jax.sharding.PartitionSpec("shard")
    spec_rep = jax.sharding.PartitionSpec()

    def body(urow, seeds, col, ssig, sids, slot, n_local, bounds, popular):
        # sharded operands arrive with a leading [1] shard slice
        col, ssig, sids, slot = col[0], ssig[0], sids[0], slot[0]
        n_loc = n_local[0]
        lo = bounds[jax.lax.axis_index("shard")]
        contrib = shard_seed_sigs(ssig, slot, seeds, lo, n_loc)
        qsigs = jax.lax.psum(contrib, "shard")
        qsigs = jnp.where((seeds != SENTINEL)[None], qsigs,
                          lsh_index._EMPTY_SIG)
        local = shard_walk_local(ssig, sids, qsigs, n_loc, cap=cap,
                                 budget=budget)
        B = urow.shape[0]
        if has_popular:
            # the shard scores only the shortlist items it owns; the
            # union over shards restores the full reserved shortlist
            plocal = popular - lo
            plocal = jnp.where((plocal >= 0) & (plocal < n_loc), plocal,
                               SENTINEL)
            local = jnp.concatenate(
                [local,
                 jnp.broadcast_to(plocal[None], (B, plocal.shape[0]))],
                axis=1)
        pad = (-B) % tile_b
        u = jnp.pad(urow, ((0, pad), (0, 0))) if pad else urow
        c = (jnp.pad(local, ((0, pad), (0, 0)),
                     constant_values=int(SENTINEL)) if pad else local)
        s = _pool_scores(u, col, c, tile_b=tile_b)[:B]
        ps, pi = _select_topn_masked(s, translate_local_ids(local, lo),
                                     topn=topn)
        k = 1
        while k < D:
            perm = [(i, i ^ k) for i in range(D)]
            qs = jax.lax.ppermute(ps, "shard", perm)
            qi = jax.lax.ppermute(pi, "shard", perm)
            ps, pi = merge_topn(ps, pi, qs, qi, topn=topn)
            k *= 2
        return ps[None], pi[None]

    smapped = shard_map(
        body, mesh=mesh,
        in_specs=(spec_rep, spec_rep, spec_shard, spec_shard, spec_shard,
                  spec_shard, spec_shard, spec_rep, spec_rep),
        out_specs=(spec_shard, spec_shard),
        check_rep=False)

    @jax.jit
    def run(row, mu, col_stack, ssig, sids, slot, n_local, bounds, sp,
            user_ids, popular):
        with jax.named_scope("serve.flush.sharded"):
            seeds = seed_items(sp, user_ids, n_seeds=n_seeds, window=window)
            urow = row[user_ids].at[:, F].add(mu)
            ps, pi = smapped(urow, seeds, col_stack, ssig, sids, slot,
                             n_local, bounds, popular)
        return ps[0], pi[0]

    return run


@partial(jax.jit,
         static_argnames=("n_seeds", "cap", "budget", "window", "tail_k",
                          "topn", "tile_b"))
def recommend_walked(planes: model.ServePlanes, index, sp, user_ids,
                     popular, *, n_seeds: int, cap: int, budget: int,
                     window: int, tail_k: int, topn: int, tile_b: int):
    """The walk-path hot path as ONE jitted program (CPU/XLA flavour of
    the `lsh_retrieve` fusion): window descriptors → budgeted slot
    enumeration → pool scoring with duplicates intact → duplicate-masked
    top-n.  No [B, pool] dedup sort anywhere — the only sorts left are
    the static bitonic network over each band's S intervals and the
    argmax tournament inside selection.  ``tail_k`` is the static tail
    scan width (`RecsysService._tail_k`); 0 skips the tail entirely."""
    with jax.named_scope("serve.flush.retrieve"):
        ids, seeds = walk_candidates(index, sp, user_ids, n_seeds=n_seeds,
                                     cap=cap, budget=budget, window=window)
        if tail_k:
            ids = jnp.concatenate(
                [ids, tail_hits(index, seeds, k=tail_k)], axis=1)
    with jax.named_scope("serve.flush.score"):
        s, cand = _score_pool(planes, user_ids, ids, popular, tile_b=tile_b)
    with jax.named_scope("serve.flush.select"):
        return _select_topn_masked(s, cand, topn=topn)


@partial(jax.jit,
         static_argnames=("n_seeds", "cap", "C", "window", "tail_scan",
                          "topn", "tile_b", "interpret", "impl"))
def recommend_walked_kernel(planes: model.ServePlanes, index, sp, user_ids,
                            popular, ids_flat, *, n_seeds: int, cap: int,
                            C: int, window: int, tail_scan: bool, topn: int,
                            tile_b: int, interpret: bool, impl: str):
    """Accelerator flavour of the walk path: the `lsh_retrieve` kernel
    walks + dedups bucket windows in VMEM and hands its [B, C] ids
    straight to the `candidate_score` kernel's scalar-prefetch operand —
    two chained kernels in one jitted program, no [B, pool] intermediate
    and no host-side dedup.  ``ids_flat`` is the service-cached
    `padded_flat_ids` plane."""
    # deferred: ops.py imports repro.serve.index, so a module-level import
    # here would close an import cycle for anyone importing ops first
    from repro.kernels.lsh_retrieve.ops import retrieve_candidates
    with jax.named_scope("serve.flush.retrieve"):
        cand = retrieve_candidates(index, sp, user_ids, n_seeds=n_seeds,
                                   cap=cap, C=C, popular=popular,
                                   window=window, tail_scan=tail_scan,
                                   interpret=interpret, impl=impl,
                                   ids_flat=ids_flat)
    with jax.named_scope("serve.flush.score"):
        return score_candidates(planes, user_ids, cand, topn=topn,
                                tile_b=tile_b, interpret=interpret, impl=impl)


def popular_shortlist(params: Params, n: int) -> jax.Array:
    """Items with the highest baseline offset b̂_j — the candidates the bias
    part of Eq. (1) can rank high regardless of the user's neighbourhood."""
    _, ids = jax.lax.top_k(params.bh, n)
    return ids.astype(jnp.int32)


# staged (un-fused) flavours of the walk-path stages, for profile_flush —
# the fused programs above inline the same functions
@jax.jit
def _walk_gather(index, pos):
    flat = index.sorted_ids.reshape(-1)
    return jnp.where(pos >= 0, flat[jnp.maximum(pos, 0)], SENTINEL)


_score_pool_staged = partial(jax.jit, static_argnames=("tile_b",))(_score_pool)
_select_staged = partial(jax.jit, static_argnames=("topn",))(
    _select_topn_masked)


class RecsysService:
    def __init__(self, params: Params, index: lsh_index.LSHIndex,
                 sp: SparseMatrix, cfg: ServeConfig,
                 JK: jax.Array | None = None,
                 registry: obs.Registry | None = None):
        self.params = params
        self.planes = model.pack_serve_planes(params)   # built once
        self.index = index
        self.sp = sp
        self.cfg = cfg
        self.JK = JK if cfg.use_jk else None
        self.popular = (popular_shortlist(params, cfg.n_popular)
                        if cfg.n_popular else None)
        # all serving metrics live here (ISSUE 6: the registry is the
        # single source of timing truth — stats() only reads it).  Always
        # a PRIVATE registry: two services reading the same metric names
        # ("serve.users", "serve.busy_seconds", the flush spans stats()
        # turns into percentiles) must never blend — sharing the process
        # registry made a full-mode service's traffic deflate a candidate
        # service's reported QPS under --trace.  Completed spans still
        # reach the process-wide timeline via the span mirror whenever
        # the default registry is enabled.
        self.obs = registry if registry is not None else obs.Registry(
            enabled=True, mirror=obs.get())
        # pending request chunks: (user_ids, t_submitted)
        self._pending: collections.deque = collections.deque()
        self._n_pending = 0
        # dispatched-but-unsynced flushes:
        # (user_ids, n_real, t0_ns, outputs, degraded)
        self._inflight: collections.deque = collections.deque()
        self._results: list[tuple[np.ndarray, np.ndarray, np.ndarray]] = []
        self._last_ready_ns = 0
        # when the serving params were adopted (swap on online ingest) —
        # `stats()["model_age_s"]` is the serve-behind-train staleness the
        # always-on loop bounds (ISSUE 10)
        self._params_adopted = time.perf_counter()
        # resilience state (ISSUE 7): background rebuild slot + host-side
        # bias mirror for the degraded popularity path (invalidated on
        # parameter swap)
        self._rebuilder: IndexRebuilder | None = None
        self._rebuild_sigs = None        # full sigs of the build in flight
        self._rebuild_attempts = 0
        self._rebuild_t0 = 0.0
        self._host_bias = None           # (mu, b, bh) numpy mirror
        # walk-kernel path: cached SENTINEL-apron id plane (invalidated
        # whenever self.index is replaced — keyed by index identity)
        self._ids_flat = None
        self._ids_flat_for = None
        # sharded serving tier (ServeConfig.shards): built once from the
        # same (params, index, sp) the single-device path serves, so the
        # two stay answer-comparable
        self._shard_state = None
        self._sharded_fn = None
        shards = serve_shard_count(cfg.shards) if cfg.mode != "full" else 1
        if shards > 1:
            self._init_shards(shards)

    def _init_shards(self, shards: int) -> None:
        """Cut the item space into nnz-balanced shards and build the
        per-shard serving state: the block-padded col-plane stack, the
        sharded index (local bucket CSR per shard), and the jitted
        shard_map program over `make_shard_mesh`."""
        cfg = self.cfg
        if not cfg.band_budget:
            raise ValueError("sharded serving requires the walk path "
                             "(band_budget > 0); the legacy pool+dedup "
                             "pipeline is single-device only")
        if self.index.tail_fill:
            raise ValueError("sharded serving requires an empty index tail "
                             "— rebuild before sharding (online ingest is "
                             "single-device only)")
        counts = np.bincount(np.asarray(self.sp.cols),
                             minlength=self.planes.n_items)
        bounds = lsh_index.shard_bounds(counts, shards)
        sidx = lsh_index.build_sharded_index(
            lsh_index.signatures_of(self.index), shards=shards,
            bounds=bounds)
        col_stack = model.shard_col_plane(self.planes.col, bounds)
        mesh = make_shard_mesh(shards)
        self._shard_state = (sidx, col_stack, mesh, shards)
        self._sharded_fn = _build_sharded_recommend(
            mesh, D=shards, F=self.planes.F, topn=cfg.topn,
            n_seeds=cfg.n_seeds, cap=cfg.cap,
            budget=cfg.resolved_shard_budget(shards),
            window=cfg.seed_window, tile_b=cfg.walk_tile_b,
            has_popular=self.popular is not None)

    # ---- core pipelines (fixed [micro_batch] shapes → warm jit caches) ----

    def route_decision(self) -> dict:
        """The small-catalog routing verdict, exposed for `stats()` and
        the bench: candidate retrieval costs a fixed ~C-proportional
        amount per user, so below a catalog-size crossover the exact O(N)
        scan is simply faster *and* exact.  ``decision`` reports what the
        heuristic would pick even when routing is disabled
        (``enabled=False``) — the bench records the verdict without
        turning it on."""
        cfg = self.cfg
        thr = cfg.route_full_below if cfg.route_full_below > 0 else 48 * cfg.C
        n = self.planes.n_items
        decision = ("full" if cfg.mode == "candidate" and n <= thr
                    else cfg.mode)
        return dict(enabled=cfg.route_full_below != 0, threshold=int(thr),
                    n_items=int(n), decision=decision)

    def _flat_ids(self) -> jax.Array:
        if self._ids_flat_for is not self.index:
            self._ids_flat = lsh_index.padded_flat_ids(self.index,
                                                       cap=self.cfg.cap)
            self._ids_flat_for = self.index
        return self._ids_flat

    def _tail_k(self) -> int:
        """Static tail-scan width for the walk path: the resident tail
        prefix (slots fill strictly in insertion order) rounded up to 16,
        so a burst of inserts retraces at most once per 16 — and the
        steady state between ingests (empty tail) skips the scan and its
        dead SENTINEL score columns entirely."""
        n = self.index.tail_fill
        return 0 if not n else min(self.index.tail_cap, -(-n // 16) * 16)

    def _recommend(self, user_ids: jax.Array):
        cfg = self.cfg
        if cfg.mode == "full":
            return full_topn(self.params, user_ids, topn=cfg.topn)
        if cfg.route_full_below and self.route_decision()["decision"] == "full":
            return full_topn(self.params, user_ids, topn=cfg.topn)
        if self._shard_state is not None:
            sidx, col_stack, _, _ = self._shard_state
            popular = (self.popular if self.popular is not None else
                       jnp.zeros((1,), jnp.int32))
            return self._sharded_fn(
                self.planes.row, self.planes.mu, col_stack,
                sidx.sorted_sigs, sidx.sorted_ids, sidx.slot_of,
                sidx.n_local, sidx.bounds, self.sp, user_ids, popular)
        if cfg.band_budget:
            if cfg.scorer_impl() == "ref":       # CPU: pure-XLA walk path
                return recommend_walked(
                    self.planes, self.index, self.sp, user_ids, self.popular,
                    n_seeds=cfg.n_seeds, cap=cfg.cap, budget=cfg.band_budget,
                    window=cfg.seed_window, tail_k=self._tail_k(),
                    topn=cfg.topn, tile_b=cfg.walk_tile_b)
            return recommend_walked_kernel(
                self.planes, self.index, self.sp, user_ids, self.popular,
                self._flat_ids(), n_seeds=cfg.n_seeds, cap=cfg.cap, C=cfg.C,
                window=cfg.seed_window,
                tail_scan=self.index.tail_fill > 0, topn=cfg.topn,
                tile_b=cfg.tile_b, interpret=cfg.interpret_mode(),
                impl=cfg.scorer_impl())
        return recommend_candidates(
            self.planes, self.index, self.sp, user_ids, self.JK,
            self.popular, n_seeds=cfg.n_seeds, cap=cfg.cap, C=cfg.C,
            window=cfg.seed_window, pool_width=cfg.resolved_pool_width(),
            fold_mates=cfg.fold_mates,
            # host-side tail mirror: an empty tail (the steady state
            # between ingests) skips the all-miss tail scan; the first
            # insert flips the static flag → one retrace, which the
            # ingestion path absorbs
            tail_scan=self.index.tail_fill > 0,
            topn=cfg.topn, tile_b=cfg.tile_b,
            interpret=cfg.interpret_mode(), impl=cfg.scorer_impl())

    def warmup(self):
        """Trace + compile both shapes before the timed traffic."""
        ids = jnp.zeros((self.cfg.micro_batch,), jnp.int32)
        jax.block_until_ready(self._recommend(ids))
        return self

    # ---- request plane ----

    def submit(self, user_ids) -> None:
        """Queue a request (any shape); flushes whole micro-batches.

        Admission control (``cfg.max_pending``): when the queue exceeds
        the bound, the *oldest* queued users are shed into the degraded
        popularity path — under overload the service answers with bounded
        staleness instead of letting queue wait grow without limit."""
        self._poll_rebuild()
        arr = np.atleast_1d(np.asarray(user_ids, np.int32))
        self._pending.append((arr, time.perf_counter()))
        self._n_pending += arr.shape[0]
        if self.cfg.max_pending and self._n_pending > self.cfg.max_pending:
            self._shed_over_bound()
        self.obs.gauge_set("serve.queue_depth", self._n_pending)
        while self._n_pending >= self.cfg.micro_batch:
            self._flush_one()

    def flush(self) -> None:
        """Drain everything pending (final partial batch is padded) and
        sync every dispatched flush."""
        self._poll_rebuild()
        while self._n_pending:
            self._flush_one()
        while self._inflight:
            self._sync_oldest()

    def flush_some(self, max_flushes: int) -> int:
        """Slice-aware flush (ISSUE 10): dispatch at most ``max_flushes``
        micro-batches, then sync everything in flight so the device is
        idle when the caller's next phase (a training micro-epoch) starts
        — the cooperative yield of the shared device budget.  Work beyond
        the budget stays queued for the next slice; returns the number of
        flushes dispatched."""
        self._poll_rebuild()
        n = 0
        while self._n_pending and n < max_flushes:
            self._flush_one()
            n += 1
        while self._inflight:
            self._sync_oldest()
        return n

    # ---- load shedding / degraded serving (ISSUE 7) ----

    def _host_degraded(self, users: np.ndarray):
        """Host-side popularity answer: items = the global shortlist,
        scores = the bias part of Eq. (1) (μ + b_u + b̂_j) — no retrieval,
        no device dispatch.  None when ``n_popular`` is off (callers then
        drop instead of degrading)."""
        if self.popular is None:
            return None
        if self._host_bias is None:
            p = self.params
            self._host_bias = (float(p.mu), np.asarray(p.b), np.asarray(p.bh))
        mu, b, bh = self._host_bias
        topn = self.cfg.topn
        pop = np.asarray(self.popular)[:topn]
        n, w = users.shape[0], pop.shape[0]
        safe_u = np.clip(users, 0, b.shape[0] - 1)
        items = np.full((n, topn), SENTINEL, np.int32)
        items[:, :w] = pop[None, :]
        scores = np.full((n, topn), -np.inf, np.float32)
        scores[:, :w] = mu + b[safe_u][:, None] + bh[pop][None, :]
        return scores, items

    def _shed_chunks(self, chunks: list) -> None:
        """Turn shed request chunks into one degraded pseudo-flush so
        `take_results` keeps submission order (shed chunks are always a
        FIFO prefix of the queue, so enqueueing the entry now — before
        the next real dispatch — preserves ordering)."""
        users = chunks[0] if len(chunks) == 1 else np.concatenate(chunks)
        reg = self.obs
        reg.counter_add("serve.shed_users", users.shape[0])
        res = self._host_degraded(users)
        if res is None:          # no popularity shortlist → drop, loudly
            reg.counter_add("serve.dropped_users", users.shape[0])
            return
        scores, items = res
        reg.counter_add("serve.degraded_users", users.shape[0])
        self._inflight.append((users, users.shape[0],
                               time.perf_counter_ns(), (scores, items), True))

    def _shed_over_bound(self) -> None:
        bound = self.cfg.max_pending
        shed: list = []
        while self._pending and self._n_pending > bound:
            a, t_sub = self._pending.popleft()
            excess = self._n_pending - bound
            if a.shape[0] > excess:      # split: shed only the overflow
                self._pending.appendleft((a[excess:], t_sub))
                a = a[:excess]
            shed.append(a)
            self._n_pending -= a.shape[0]
        if shed:
            self._shed_chunks(shed)

    def _shed_expired(self, now: float) -> None:
        """Deadline shedding: queue-wait is monotone along the FIFO, so
        expired chunks are exactly the queue prefix."""
        dl = self.cfg.deadline_s
        shed: list = []
        while self._pending and now - self._pending[0][1] > dl:
            a, _ = self._pending.popleft()
            self._n_pending -= a.shape[0]
            shed.append(a)
        if shed:
            self._shed_chunks(shed)

    def _flush_one(self) -> None:
        """Dispatch one micro-batch; sync the *previous* flush only after
        this one is enqueued (double-buffered dispatch-ahead).

        Resilience: expired chunks are shed *before* filling the batch
        (deadline shedding), and a hot-path failure — injected or real —
        falls back to the exact O(N) `full_topn` baseline instead of
        failing the flush (counter ``serve.fallback_full``)."""
        mb = self.cfg.micro_batch
        reg = self.obs
        with reg.span("serve.flush.dispatch"):
            # consume only as many queued arrays as one micro-batch needs —
            # a huge submit is sliced by view, not re-concatenated per flush
            now = time.perf_counter()
            if self.cfg.deadline_s:
                self._shed_expired(now)
            chunks, n, t_last = [], 0, now
            while self._pending and n < mb:
                a, t_sub = self._pending.popleft()
                reg.observe("serve.queue_wait", now - t_sub)
                chunks.append(a)
                n += a.shape[0]
                t_last = t_sub
            if not chunks:           # everything this flush would have
                return               # taken was shed past its deadline
            flat = chunks[0] if len(chunks) == 1 else np.concatenate(chunks)
            take = flat[:mb]
            if flat.size > mb:
                # overflow comes entirely from the last chunk popped
                self._pending.appendleft((flat[mb:], t_last))
            n_real = take.size
            self._n_pending -= n_real
            reg.gauge_set("serve.queue_depth", self._n_pending)
            if n_real < mb:  # pad the final partial batch to the jitted shape
                take = np.concatenate([take, np.zeros(mb - n_real, np.int32)])

            try:
                faults.fire("serve.flush")    # before the timer: injected
                # stalls read as queue wait, not scoring latency
                t0_ns = time.perf_counter_ns()
                out = self._recommend(jnp.asarray(take))  # async dispatch
            except Exception:  # noqa: BLE001 — degrade, never stall
                reg.counter_add("serve.fallback_full")
                t0_ns = time.perf_counter_ns()
                out = full_topn(self.params, jnp.asarray(take),
                                topn=self.cfg.topn)
        self._inflight.append((take, n_real, t0_ns, out, False))
        reg.counter_add("serve.flushes")
        while len(self._inflight) > 1:
            self._sync_oldest()

    def _sync_oldest(self) -> None:
        take, n_real, t0_ns, (scores, items), degraded = \
            self._inflight.popleft()
        reg = self.obs
        if degraded:
            # shed pseudo-flush: results were computed host-side at shed
            # time; it never touched the device, so it contributes no
            # flush latency / busy time (keeping p50/p95/p99 about the
            # real pipeline)
            reg.counter_add("serve.users", n_real)
            self._results.append((take[:n_real], scores[:n_real],
                                  items[:n_real]))
            return
        try:
            jax.block_until_ready(items)
        except Exception:  # noqa: BLE001 — deferred device failure:
            # recompute through the exact baseline rather than lose a
            # dispatched batch
            reg.counter_add("serve.fallback_full")
            scores, items = full_topn(self.params, jnp.asarray(take),
                                      topn=self.cfg.topn)
            jax.block_until_ready(items)
        now_ns = time.perf_counter_ns()
        # latency: dispatch → result readiness (includes time queued
        # behind the previous flush); busy wall: overlap counted once
        reg.record_span("serve.flush", t0_ns, now_ns - t0_ns)
        reg.counter_add("serve.busy_seconds",
                        (now_ns - max(self._last_ready_ns, t0_ns)) * 1e-9)
        self._last_ready_ns = now_ns
        reg.counter_add("serve.users", n_real)
        self._results.append((take[:n_real],
                              np.asarray(scores)[:n_real],
                              np.asarray(items)[:n_real]))

    def take_results(self):
        """[(user_ids, scores, items)] for every flush since the last take.

        Results are appended at sync time in dispatch order, so the k-th
        tuple is the k-th flushed micro-batch and its rows line up with
        the user ids that were submitted (padding already stripped).
        Shed chunks appear as degraded pseudo-flushes in the same
        submission order (they are always a queue prefix, enqueued before
        the next real dispatch); only fully *dropped* requests
        (``n_popular == 0`` under shedding) produce no rows."""
        out, self._results = self._results, []
        return out

    def stats(self) -> dict:
        """Serving stats, read *entirely* from the obs registry (ISSUE 6:
        one source of timing truth).  Keys `mode/batches/users/qps/
        p50_ms/p95_ms` keep their pre-obs semantics; `p99_ms`, `queue`
        and `ingest_to_servable_s` (0.0 until the first ingest) are new."""
        reg = self.obs
        flush_s = reg.span_durations("serve.flush")
        secs = np.asarray(flush_s) if flush_s else np.zeros((1,))
        busy = reg.counter("serve.busy_seconds")
        users = int(reg.counter("serve.users"))
        return dict(
            mode=self.cfg.mode,
            batches=int(reg.counter("serve.flushes")),
            users=users,
            qps=users / busy if busy else 0.0,
            p50_ms=float(np.percentile(secs, 50) * 1e3),
            p95_ms=float(np.percentile(secs, 95) * 1e3),
            p99_ms=float(np.percentile(secs, 99) * 1e3),
            queue=self._n_pending,
            ingest_to_servable_s=reg.gauge("serve.ingest_to_servable_s", 0.0),
            # resilience counters (ISSUE 7): shed = admission/deadline
            # victims, degraded = shed users answered via the popularity
            # path, dropped = shed with no fallback, fallbacks = flushes
            # rescued by exact full scoring, quarantined = poison ingest
            # batches rejected, index_stale = overflow awaiting a
            # background rebuild swap
            shed=int(reg.counter("serve.shed_users")),
            degraded=int(reg.counter("serve.degraded_users")),
            dropped=int(reg.counter("serve.dropped_users")),
            fallbacks=int(reg.counter("serve.fallback_full")),
            quarantined=int(reg.counter("serve.quarantined")),
            ingest_rejected=int(reg.counter("serve.ingest_rejected")),
            index_stale=bool(reg.gauge("serve.index_stale", 0.0)),
            # staleness (ISSUE 10): wall-clock age of the serving params —
            # what the always-on loop's publish cadence bounds
            model_age_s=time.perf_counter() - self._params_adopted,
            # small-catalog routing (PR 8): the verdict is always
            # reported; `enabled` says whether _recommend acts on it
            route=self.route_decision(),
            # sharded tier (PR 9): 1 = the single-device oracle path
            shards=(self._shard_state[3] if self._shard_state is not None
                    else 1),
        )

    def profile_flush(self, user_ids=None) -> dict:
        """One *staged* flush with nested host spans — the observability
        view of the hot path.

        The production pipeline fuses retrieval and scoring into a single
        jitted dispatch (host spans cannot subdivide it; only the
        `jax.named_scope` stage names inside the program show up, and only
        in XLA device profiles).  This path runs the same stages as
        separate dispatches with a readiness barrier after each, so the
        span tree — serve.flush → retrieve(.desc → .walk) → score →
        select on the walk path, retrieve(.pool → .dedup) → score on the
        legacy pool path — carries real wall times into the Chrome trace
        export.  Slower than the
        fused path by the un-fused dispatch overhead — a profiling tool,
        not a serving mode.  Returns {span name: seconds} for this run.
        """
        cfg = self.cfg
        reg = self.obs
        if user_ids is None:
            user_ids = np.arange(cfg.micro_batch, dtype=np.int32)
        ids = jnp.asarray(np.atleast_1d(np.asarray(user_ids, np.int32)))
        names = ["serve.flush"]
        with reg.span("serve.flush"):
            if cfg.mode == "full":
                with reg.span("serve.flush.score"):
                    jax.block_until_ready(
                        full_topn(self.params, ids, topn=cfg.topn))
                names += ["serve.flush.score"]
            elif self._shard_state is not None:
                # the sharded flush is one shard_map dispatch — host
                # spans cannot subdivide its collectives; time it whole
                with reg.span("serve.flush.sharded"):
                    jax.block_until_ready(self._recommend(ids))
                names += ["serve.flush.sharded"]
            elif cfg.band_budget and cfg.scorer_impl() == "ref":
                # CPU walk path: desc → walk → score → select (dedup
                # happens inside select; there is no dedup stage to time)
                tail_k = self._tail_k()
                with reg.span("serve.flush.retrieve"):
                    with reg.span("serve.flush.retrieve.desc"):
                        seeds = seed_items(self.sp, ids, n_seeds=cfg.n_seeds,
                                           window=cfg.seed_window)
                        starts, counts = window_descriptors(
                            self.index, seeds, cap=cfg.cap)
                        jax.block_until_ready(counts)
                    with reg.span("serve.flush.retrieve.walk"):
                        pos = enumerate_windows(starts, counts,
                                                budget=cfg.band_budget)
                        walked = _walk_gather(self.index, pos)
                        if tail_k:
                            walked = jnp.concatenate(
                                [walked, tail_hits(self.index, seeds,
                                                   k=tail_k)], axis=1)
                        jax.block_until_ready(walked)
                with reg.span("serve.flush.score"):
                    s, cand = _score_pool_staged(self.planes, ids, walked,
                                                 self.popular,
                                                 tile_b=cfg.walk_tile_b)
                    jax.block_until_ready(s)
                with reg.span("serve.flush.select"):
                    jax.block_until_ready(
                        _select_staged(s, cand, topn=cfg.topn))
                names += ["serve.flush.retrieve",
                          "serve.flush.retrieve.desc",
                          "serve.flush.retrieve.walk",
                          "serve.flush.score", "serve.flush.select"]
            elif cfg.band_budget:
                # accelerator walk path: the lsh_retrieve kernel IS the
                # walk+dedup stage
                tail = self.index.tail_fill > 0 and self.index.tail_cap > 0
                with reg.span("serve.flush.retrieve"):
                    with reg.span("serve.flush.retrieve.desc"):
                        seeds = seed_items(self.sp, ids, n_seeds=cfg.n_seeds,
                                           window=cfg.seed_window)
                        starts, lens = lsh_index.window_slices(
                            self.index, seeds, cap=cfg.cap)
                        extra = (tail_hits(self.index, seeds) if tail else
                                 jnp.full((ids.shape[0], 1), SENTINEL,
                                          jnp.int32))
                        jax.block_until_ready(lens)
                    with reg.span("serve.flush.retrieve.walk"):
                        if self.popular is not None:
                            exclude, core_C = self.popular, \
                                cfg.C - self.popular.shape[0]
                        else:
                            exclude = jnp.full((1,), SENTINEL, jnp.int32)
                            core_C = cfg.C
                        cand = lsh_retrieve_topc(
                            starts, lens, extra, self._flat_ids(), exclude,
                            C=core_C, cap=cfg.cap,
                            interpret=cfg.interpret_mode())
                        if self.popular is not None:
                            cand = jnp.concatenate(
                                [cand, jnp.broadcast_to(
                                    self.popular[None, :],
                                    (ids.shape[0],
                                     self.popular.shape[0]))], axis=1)
                        jax.block_until_ready(cand)
                with reg.span("serve.flush.score"):
                    jax.block_until_ready(score_candidates(
                        self.planes, ids, cand, topn=cfg.topn,
                        tile_b=cfg.tile_b, interpret=cfg.interpret_mode(),
                        impl=cfg.scorer_impl()))
                names += ["serve.flush.retrieve",
                          "serve.flush.retrieve.desc",
                          "serve.flush.retrieve.walk", "serve.flush.score"]
            else:
                with reg.span("serve.flush.retrieve"):
                    with reg.span("serve.flush.retrieve.pool"):
                        pool = candidate_pool(
                            self.index, self.sp, ids, n_seeds=cfg.n_seeds,
                            cap=cfg.cap, JK=self.JK, window=cfg.seed_window,
                            fold_mates=cfg.fold_mates,
                            tail_scan=self.index.tail_fill > 0)
                        jax.block_until_ready(pool)
                    with reg.span("serve.flush.retrieve.dedup"):
                        cand = finalize_candidates(
                            pool, C=cfg.C, popular=self.popular,
                            pool_width=cfg.resolved_pool_width())
                        jax.block_until_ready(cand)
                with reg.span("serve.flush.score"):
                    jax.block_until_ready(score_candidates(
                        self.planes, ids, cand, topn=cfg.topn,
                        tile_b=cfg.tile_b, interpret=cfg.interpret_mode(),
                        impl=cfg.scorer_impl()))
                names += ["serve.flush.retrieve",
                          "serve.flush.retrieve.pool",
                          "serve.flush.retrieve.dedup", "serve.flush.score"]
        return {n: reg.span_durations(n)[-1] for n in names}

    # ---- ingestion plane (paper Alg. 4) ----

    # ---- background rebuild (ISSUE 7: double-buffered validate-then-swap) --

    def _start_rebuild(self, full_sigs) -> None:
        if self._rebuilder is None:
            self._rebuilder = IndexRebuilder(self.obs)
        self._rebuild_sigs = full_sigs       # kept for bounded auto-retry
        self._rebuild_attempts = 0
        self._rebuild_t0 = time.perf_counter()
        # stale: the tail overflowed, so items past base+tail are not yet
        # retrievable — cleared when the validated v+1 swaps in
        self.obs.gauge_set("serve.index_stale", 1.0)
        self._rebuilder.submit(full_sigs, tail_cap=self.index.tail_cap)

    def _poll_rebuild(self) -> None:
        """Called at the serving-loop edges (submit/flush/ingest): swap in
        a validated rebuild, or retry/roll back a failed one.  Serving
        index v continues uninterrupted in every branch — in-flight
        flushes captured v (jax arrays are immutable), and a failed or
        invalid build is simply never taken."""
        if self._rebuilder is None:
            return
        status, idx, err = self._rebuilder.take()
        if status == "ready":
            self.index = idx
            self._rebuild_sigs = None
            with self.obs.span("serve.rebuild.swap"):
                self.warmup()        # n_base changed → one retrace, absorbed
            self.obs.counter_add("serve.rebuild.swaps")
            self.obs.gauge_set("serve.index_stale", 0.0)
            self.obs.gauge_set("serve.ingest_to_servable_s",
                               time.perf_counter() - self._rebuild_t0)
        elif status == "failed":
            self._rebuild_attempts += 1
            if (self._rebuild_sigs is not None
                    and self._rebuild_attempts < self.cfg.rebuild_retries):
                self.obs.counter_add("serve.rebuild.retries")
                self._rebuilder.submit(self._rebuild_sigs,
                                       tail_cap=self.index.tail_cap)
            else:
                # rollback is the default: keep serving v; the index stays
                # stale (missing post-overflow items) and says so loudly
                self.obs.counter_add("serve.rebuild.gave_up")
                self._rebuild_sigs = None

    def request_rebuild(self, full_sigs) -> None:
        """Supervisor-triggered rebuild (ISSUE 10 drift detection): hand
        the full [q, N] signature set to the background rebuilder;
        serving continues on index v and the validated v+1 swaps in at a
        later flush boundary (`_poll_rebuild`).  Single-device only —
        the sharded tier is rebuilt by constructing a new service."""
        if self._shard_state is not None:
            self.obs.counter_add("serve.ingest_rejected")
            raise ShardedIngestUnsupported(
                "sharded serving is read-only: request the rebuild on a "
                "single-device service and construct a new sharded "
                "service from the swapped index")
        self._poll_rebuild()
        self._start_rebuild(full_sigs)

    # ---- ingestion entry points ----

    def ingest(self, new_sigs: jax.Array, new_ids: jax.Array,
               full_sigs: jax.Array | None = None) -> None:
        """Insert new items into the index tail; rebuild on overflow
        (rebuild requires ``full_sigs`` [q, N_total]).

        With ``cfg.background_rebuild`` (default) an overflow hands
        ``full_sigs`` — which already contain the new items — to the
        background rebuilder and returns immediately: the service keeps
        serving index v (marked stale) and swaps in the validated v+1 at
        a later flush boundary.  Poison batches (wrong dtype, NaN rows,
        negative/duplicate ids) raise `PoisonBatchError` before any state
        is touched.

        Crossing the empty-tail boundary (first insert, or a rebuild
        folding the tail away) flips the static tail fast path in
        `_recommend`, so re-warm here — the retrace lands in ingestion
        time, not in the next request's latency window."""
        if self._shard_state is not None:
            self.obs.counter_add("serve.ingest_rejected")
            raise ShardedIngestUnsupported(
                "sharded serving is read-only: apply this ingest on a "
                "single-device service (tail insert + rebuild on "
                "overflow) and construct a new sharded service from the "
                "rebuilt index, or hand full_sigs to request_rebuild() "
                "on that single-device service")
        t0_ns = time.perf_counter_ns()
        try:
            check_ingest_batch(new_sigs, new_ids, q=self.index.q)
        except PoisonBatchError:
            self.obs.counter_add("serve.quarantined")
            raise
        faults.fire("serve.ingest")
        self._poll_rebuild()
        with self.obs.span("serve.ingest"):
            had_tail = self.index.tail_fill > 0
            rebuilt = lsh_index.needs_rebuild(self.index,
                                              int(new_ids.shape[0]))
            if rebuilt:     # a rebuild also grows n_base → new trace shapes
                if full_sigs is None:
                    raise ValueError(
                        "tail overflow and no full_sigs to rebuild")
                if self.cfg.background_rebuild:
                    self._start_rebuild(full_sigs)
                else:
                    with self.obs.span("serve.ingest.rebuild"):
                        self.index = lsh_index.rebuild(self.index, full_sigs)
            else:
                with self.obs.span("serve.ingest.insert"):
                    self.index = lsh_index.insert(self.index, new_sigs,
                                                  new_ids)
            sync_done = not (rebuilt and self.cfg.background_rebuild)
            if sync_done and (rebuilt
                              or (self.index.tail_fill > 0) != had_tail):
                with self.obs.span("serve.ingest.warmup"):
                    self.warmup()
        self.obs.counter_add("serve.ingests")
        self.obs.counter_add("serve.ingested_items", int(new_ids.shape[0]))
        # ingest→servable: new items are retrievable the moment ingest
        # returns (and any forced retrace has already been re-warmed); on
        # the background-rebuild path _poll_rebuild overwrites this with
        # the overflow→swap latency once v+1 lands
        if sync_done:
            self.obs.gauge_set("serve.ingest_to_servable_s",
                               (time.perf_counter_ns() - t0_ns) * 1e-9)

    def ingest_online_update(self, state, N_old: int) -> None:
        """Adopt a `core.online.online_update` result: swap in the grown
        params/interactions and add only the *new* columns to the index,
        re-signing from the updated accumulator cache (Alg. 4 lines 1–6).
        Old columns keep their buckets (the paper's "remains unchanged").

        The index is never rebuilt, but the grown parameter shapes force
        one retrace of the serving pipelines — re-warm here so the compile
        lands in ingestion time, not in a request's latency window."""
        if self._shard_state is not None:
            self.obs.counter_add("serve.ingest_rejected")
            raise ShardedIngestUnsupported(
                "sharded serving is read-only: run the online-update "
                "handoff on a single-device service (shards=0) and "
                "construct a new sharded service from the grown state — "
                "or route the full re-signed signature set through "
                "request_rebuild() there")
        t0_ns = time.perf_counter_ns()
        # quarantine before touching anything: NaN-poisoned accumulator
        # slabs would re-sign new columns into valid-looking garbage
        # signatures (silent mis-bucketing, not a crash)
        try:
            check_accumulators(state.S, N_old)
        except PoisonBatchError:
            self.obs.counter_add("serve.quarantined")
            raise
        with self.obs.span("serve.ingest_online"):
            self.flush()    # drain in-flight work against the old planes
            with self.obs.span("serve.ingest_online.resign"):
                sigs = simlsh.pack_bits(state.S >= 0)         # [q, N_new]
            # swap the grown state in *before* the index ingest: ingest()'s
            # own tail-boundary warmup must compile against the new plane
            # shapes, not trace a pipeline the swap immediately invalidates
            assert state.N <= 1 << 30, \
                "item ids must stay below 2^30 (the dedup hash mask)"
            with self.obs.span("serve.ingest_online.swap"):
                self.params = state.params
                self._params_adopted = time.perf_counter()
                self.planes = model.pack_serve_planes(state.params)
                self._host_bias = None     # degraded-path mirror is stale
                self.sp = state.sp
                if self.JK is not None:
                    self.JK = state.JK
                if self.cfg.n_popular:
                    self.popular = popular_shortlist(state.params,
                                                     self.cfg.n_popular)
            if state.N > N_old:
                self.ingest(sigs[:, N_old:],
                            jnp.arange(N_old, state.N, dtype=jnp.int32),
                            full_sigs=sigs)
            with self.obs.span("serve.ingest_online.warmup"):
                self.warmup()
        # the full online handoff (drain → re-sign → swap → index →
        # re-warm) is this path's ingest→servable latency; overwrites the
        # inner ingest()'s narrower reading
        self.obs.gauge_set("serve.ingest_to_servable_s",
                           (time.perf_counter_ns() - t0_ns) * 1e-9)
