"""Request-batching serving loop: retrieval → candidate scoring → top-N.

A `RecsysService` owns the trained parameters, the persistent `LSHIndex`,
and two jitted serving pipelines:

  * ``candidate`` — `retrieve.retrieve_for_users` (ANN candidates) feeding
    the fused `kernels/candidate_score` Pallas kernel: O(C) work per user.
  * ``full``      — exact `μ + b_i + b̂ + U V^T` top-N: O(N) work per user,
    kept as the exactness baseline (and for recall measurement).

Requests are micro-batched: `submit` accumulates user ids and flushes a
fixed-shape batch whenever ``micro_batch`` are pending (padding keeps every
flush the same shape, so the jit cache stays warm after the first call).
QPS / latency percentiles are tracked per flush.

Online ingestion (paper Alg. 4): `ingest_online_update` re-signs the
accumulator cache from `core.online.online_update` and *inserts* the new
columns into the index tail — no rebuild, no cold jit caches — falling back
to a rebuild only when the tail overflows.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import simlsh
from repro.core.model import Params
from repro.core.topk import SENTINEL
from repro.data.sparse import SparseMatrix
from repro.kernels.candidate_score.ops import score_candidates
from repro.serve import index as lsh_index
from repro.serve.retrieve import retrieve_for_users


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    mode: str = "candidate"   # candidate | full
    topn: int = 10
    micro_batch: int = 256
    # retrieval knobs
    C: int = 512              # candidate slots per user
    n_seeds: int = 8          # seed items per user
    cap: int = 8              # bucket-mates taken per band per seed
    n_popular: int = 64       # global popularity shortlist size (0 = off)
    seed_window: int = 64
    use_jk: bool = True       # include seeds' training Top-K lists
    # kernel knobs
    tile_b: int = 8
    interpret: bool | None = None  # None = auto (interpret only on CPU);
                                   # never leave True on TPU — it would run
                                   # the hot path in the Pallas interpreter
    impl: str = "auto"        # auto | pallas | ref — 'auto' picks the pure-
                              # XLA ref on CPU (Pallas only interprets there)
                              # and the fused kernel elsewhere

    def scorer_impl(self) -> str:
        if self.impl != "auto":
            return self.impl
        return "ref" if jax.default_backend() == "cpu" else "pallas"

    def interpret_mode(self) -> bool:
        if self.interpret is not None:
            return self.interpret
        return jax.default_backend() == "cpu"


@partial(jax.jit, static_argnames=("topn",))
def full_topn(params: Params, user_ids: jax.Array, *, topn: int):
    """Exact dense scoring — every item, every user.  The O(N) baseline."""
    scores = (params.mu + params.b[user_ids][:, None] + params.bh[None, :]
              + params.U[user_ids] @ params.V.T)
    return jax.lax.top_k(scores, topn)


def popular_shortlist(params: Params, n: int) -> jax.Array:
    """Items with the highest baseline offset b̂_j — the candidates the bias
    part of Eq. (1) can rank high regardless of the user's neighbourhood."""
    _, ids = jax.lax.top_k(params.bh, n)
    return ids.astype(jnp.int32)


class RecsysService:
    def __init__(self, params: Params, index: lsh_index.LSHIndex,
                 sp: SparseMatrix, cfg: ServeConfig,
                 JK: jax.Array | None = None):
        self.params = params
        self.index = index
        self.sp = sp
        self.cfg = cfg
        self.JK = JK if cfg.use_jk else None
        self.popular = (popular_shortlist(params, cfg.n_popular)
                        if cfg.n_popular else None)
        self._pending: list[np.ndarray] = []
        self._n_pending = 0
        self._results: list[tuple[np.ndarray, np.ndarray, np.ndarray]] = []
        self._flush_secs: list[float] = []
        self._users_served = 0

    # ---- core pipelines (fixed [micro_batch] shapes → warm jit caches) ----

    def _recommend(self, user_ids: jax.Array):
        cfg = self.cfg
        if cfg.mode == "full":
            return full_topn(self.params, user_ids, topn=cfg.topn)
        cand = retrieve_for_users(
            self.index, self.sp, user_ids, n_seeds=cfg.n_seeds, cap=cfg.cap,
            C=cfg.C, JK=self.JK, popular=self.popular,
            window=cfg.seed_window)
        return score_candidates(self.params, user_ids, cand, topn=cfg.topn,
                                tile_b=cfg.tile_b,
                                interpret=cfg.interpret_mode(),
                                impl=cfg.scorer_impl())

    def warmup(self):
        """Trace + compile both shapes before the timed traffic."""
        ids = jnp.zeros((self.cfg.micro_batch,), jnp.int32)
        jax.block_until_ready(self._recommend(ids))
        return self

    # ---- request plane ----

    def submit(self, user_ids) -> None:
        """Queue a request (any shape); flushes whole micro-batches."""
        arr = np.atleast_1d(np.asarray(user_ids, np.int32))
        self._pending.append(arr)
        self._n_pending += arr.shape[0]
        while self._n_pending >= self.cfg.micro_batch:
            self._flush_one()

    def flush(self) -> None:
        """Drain everything pending (final partial batch is padded)."""
        while self._n_pending:
            self._flush_one()

    def _flush_one(self) -> None:
        mb = self.cfg.micro_batch
        # consume only as many queued arrays as one micro-batch needs — a
        # huge submit is sliced by view, not re-concatenated per flush
        chunks, n = [], 0
        while self._pending and n < mb:
            a = self._pending.pop(0)
            chunks.append(a)
            n += a.shape[0]
        flat = (chunks[0] if len(chunks) == 1 else
                np.concatenate(chunks) if chunks else np.zeros((0,), np.int32))
        take = flat[:mb]
        if flat.size > mb:
            self._pending.insert(0, flat[mb:])
        n_real = take.size
        self._n_pending -= n_real
        if n_real < mb:  # pad the final partial batch to the jitted shape
            take = np.concatenate([take, np.zeros(mb - n_real, np.int32)])

        t0 = time.perf_counter()
        scores, items = self._recommend(jnp.asarray(take))
        jax.block_until_ready(items)
        dt = time.perf_counter() - t0

        self._flush_secs.append(dt)
        self._users_served += n_real
        self._results.append((take[:n_real],
                              np.asarray(scores)[:n_real],
                              np.asarray(items)[:n_real]))

    def take_results(self):
        """[(user_ids, scores, items)] for every flush since the last take."""
        out, self._results = self._results, []
        return out

    def stats(self) -> dict:
        secs = np.asarray(self._flush_secs) if self._flush_secs else \
            np.zeros((1,))
        total = float(secs.sum())
        return dict(
            mode=self.cfg.mode,
            batches=len(self._flush_secs),
            users=self._users_served,
            qps=self._users_served / total if total else 0.0,
            p50_ms=float(np.percentile(secs, 50) * 1e3),
            p95_ms=float(np.percentile(secs, 95) * 1e3),
        )

    # ---- ingestion plane (paper Alg. 4) ----

    def ingest(self, new_sigs: jax.Array, new_ids: jax.Array,
               full_sigs: jax.Array | None = None) -> None:
        """Insert new items into the index tail; rebuild only on overflow
        (rebuild requires ``full_sigs`` [q, N_total])."""
        if lsh_index.needs_rebuild(self.index, int(new_ids.shape[0])):
            if full_sigs is None:
                raise ValueError("tail overflow and no full_sigs to rebuild")
            self.index = lsh_index.rebuild(self.index, full_sigs)
        else:
            self.index = lsh_index.insert(self.index, new_sigs, new_ids)

    def ingest_online_update(self, state, N_old: int) -> None:
        """Adopt a `core.online.online_update` result: swap in the grown
        params/interactions and add only the *new* columns to the index,
        re-signing from the updated accumulator cache (Alg. 4 lines 1–6).
        Old columns keep their buckets (the paper's "remains unchanged").

        The index is never rebuilt, but the grown parameter shapes force
        one retrace of the serving pipelines — re-warm here so the compile
        lands in ingestion time, not in a request's latency window."""
        sigs = simlsh.pack_bits(state.S >= 0)                 # [q, N_new]
        if state.N > N_old:
            self.ingest(sigs[:, N_old:],
                        jnp.arange(N_old, state.N, dtype=jnp.int32),
                        full_sigs=sigs)
        self.params = state.params
        self.sp = state.sp
        if self.JK is not None:
            self.JK = state.JK
        if self.cfg.n_popular:
            self.popular = popular_shortlist(state.params, self.cfg.n_popular)
        self.warmup()
