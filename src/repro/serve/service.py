"""Request-batching serving loop: retrieval → candidate scoring → top-N.

A `RecsysService` owns the trained parameters (packed once into the
`model.ServePlanes` scoring layout), the persistent `LSHIndex`, and two
serving pipelines:

  * ``candidate`` — one fused, jitted program (`recommend_candidates`):
    `retrieve.retrieve_for_users` (ANN candidates, single-sort dedup)
    feeding `kernels/candidate_score` with in-kernel plane gather — O(C)
    work per user, no host hop between retrieval and scoring.
  * ``full``      — exact `μ + b_i + b̂ + U V^T` top-N: O(N) work per
    user, kept as the exactness baseline (and for recall measurement).

Requests are micro-batched: `submit` accumulates user ids (a `deque` —
PR 1's ``list.pop(0)`` was O(n) per flush) and flushes a fixed-shape
batch whenever ``micro_batch`` are pending (padding keeps every flush
the same shape, so the jit cache stays warm after the first call).

Flushes are **dispatch-ahead** (double-buffered): `_flush_one` enqueues
flush k+1 onto the device before syncing flush k, so the host-side batch
assembly and result copy-out of one flush overlap the device compute of
the next.  Latency is measured per flush from dispatch to *result
readiness* (the sync), so p50/p95 stay honest — an overlapped flush's
latency includes any time it spent queued behind its predecessor — and
QPS divides by non-overlapping busy wall-time, never double-counting the
overlap.

Online ingestion (paper Alg. 4): `ingest_online_update` re-signs the
accumulator cache from `core.online.online_update` and *inserts* the new
columns into the index tail — no rebuild, no cold jit caches — falling back
to a rebuild only when the tail overflows.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import model, simlsh
from repro.core.model import Params
from repro.data.sparse import SparseMatrix
from repro.kernels.candidate_score.ops import score_candidates
from repro.serve import index as lsh_index
from repro.serve.retrieve import retrieve_for_users


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    mode: str = "candidate"   # candidate | full
    topn: int = 10
    micro_batch: int = 256
    # retrieval knobs
    C: int = 512              # candidate slots per user
    n_seeds: int = 8          # seed items per user
    cap: int = 8              # bucket-mates taken per band per seed
    n_popular: int = 64       # global popularity shortlist size (0 = off)
    seed_window: int = 64
    use_jk: bool = True       # include seeds' training Top-K lists
    fold_mates: bool = True   # fold per-(seed, band) bucket runs pairwise
                              # (halves the dedup sort width; see
                              # retrieve._fold_prefix_runs)
    pool_width: int = 0       # generic pre-dedup pool compaction width
                              # (0 = off — a wash on CPU, see
                              # retrieve.compact_pool; knob for TPU)
    # kernel knobs
    tile_b: int = 8
    interpret: bool | None = None  # None = auto (interpret only on CPU);
                                   # never leave True on TPU — it would run
                                   # the hot path in the Pallas interpreter
    impl: str = "auto"        # auto | pallas | ref — 'auto' picks the pure-
                              # XLA ref on CPU (Pallas only interprets there)
                              # and the fused kernel elsewhere

    def scorer_impl(self) -> str:
        if self.impl != "auto":
            return self.impl
        return "ref" if jax.default_backend() == "cpu" else "pallas"

    def interpret_mode(self) -> bool:
        if self.interpret is not None:
            return self.interpret
        return jax.default_backend() == "cpu"

    def resolved_pool_width(self) -> int:
        return self.pool_width


@partial(jax.jit, static_argnames=("topn",))
def full_topn(params: Params, user_ids: jax.Array, *, topn: int):
    """Exact dense scoring — every item, every user.  The O(N) baseline."""
    scores = (params.mu + params.b[user_ids][:, None] + params.bh[None, :]
              + params.U[user_ids] @ params.V.T)
    return jax.lax.top_k(scores, topn)


@partial(jax.jit,
         static_argnames=("n_seeds", "cap", "C", "window", "pool_width",
                          "fold_mates", "tail_scan", "topn", "tile_b",
                          "interpret", "impl"))
def recommend_candidates(planes: model.ServePlanes, index, sp, user_ids,
                         JK, popular, *, n_seeds: int, cap: int, C: int,
                         window: int, pool_width: int, fold_mates: bool,
                         tail_scan: bool, topn: int,
                         tile_b: int, interpret: bool, impl: str):
    """The whole candidate hot path as ONE jitted program — retrieval and
    scoring fuse into a single dispatch with no host round-trip between
    them, and every intermediate (pools, sort keys, the candidate table)
    is program-local, so XLA reuses those buffers across the
    retrieval/scoring boundary instead of holding two jit outputs live
    (the PR 1 layout donated nothing and kept `cand` alive between two
    dispatches)."""
    cand = retrieve_for_users(index, sp, user_ids, n_seeds=n_seeds, cap=cap,
                              C=C, JK=JK, popular=popular, window=window,
                              pool_width=pool_width, fold_mates=fold_mates,
                              tail_scan=tail_scan)
    return score_candidates(planes, user_ids, cand, topn=topn, tile_b=tile_b,
                            interpret=interpret, impl=impl)


def popular_shortlist(params: Params, n: int) -> jax.Array:
    """Items with the highest baseline offset b̂_j — the candidates the bias
    part of Eq. (1) can rank high regardless of the user's neighbourhood."""
    _, ids = jax.lax.top_k(params.bh, n)
    return ids.astype(jnp.int32)


class RecsysService:
    def __init__(self, params: Params, index: lsh_index.LSHIndex,
                 sp: SparseMatrix, cfg: ServeConfig,
                 JK: jax.Array | None = None):
        self.params = params
        self.planes = model.pack_serve_planes(params)   # built once
        self.index = index
        self.sp = sp
        self.cfg = cfg
        self.JK = JK if cfg.use_jk else None
        self.popular = (popular_shortlist(params, cfg.n_popular)
                        if cfg.n_popular else None)
        self._pending: collections.deque[np.ndarray] = collections.deque()
        self._n_pending = 0
        # dispatched-but-unsynced flushes: (user_ids, n_real, t0, outputs)
        self._inflight: collections.deque = collections.deque()
        self._results: list[tuple[np.ndarray, np.ndarray, np.ndarray]] = []
        self._flush_secs: list[float] = []
        self._users_served = 0
        self._dispatched = 0
        self._busy_secs = 0.0
        self._last_ready = 0.0

    # ---- core pipelines (fixed [micro_batch] shapes → warm jit caches) ----

    def _recommend(self, user_ids: jax.Array):
        cfg = self.cfg
        if cfg.mode == "full":
            return full_topn(self.params, user_ids, topn=cfg.topn)
        return recommend_candidates(
            self.planes, self.index, self.sp, user_ids, self.JK,
            self.popular, n_seeds=cfg.n_seeds, cap=cfg.cap, C=cfg.C,
            window=cfg.seed_window, pool_width=cfg.resolved_pool_width(),
            fold_mates=cfg.fold_mates,
            # host-side tail mirror: an empty tail (the steady state
            # between ingests) skips the all-miss tail scan; the first
            # insert flips the static flag → one retrace, which the
            # ingestion path absorbs
            tail_scan=self.index.tail_fill > 0,
            topn=cfg.topn, tile_b=cfg.tile_b,
            interpret=cfg.interpret_mode(), impl=cfg.scorer_impl())

    def warmup(self):
        """Trace + compile both shapes before the timed traffic."""
        ids = jnp.zeros((self.cfg.micro_batch,), jnp.int32)
        jax.block_until_ready(self._recommend(ids))
        return self

    # ---- request plane ----

    def submit(self, user_ids) -> None:
        """Queue a request (any shape); flushes whole micro-batches."""
        arr = np.atleast_1d(np.asarray(user_ids, np.int32))
        self._pending.append(arr)
        self._n_pending += arr.shape[0]
        while self._n_pending >= self.cfg.micro_batch:
            self._flush_one()

    def flush(self) -> None:
        """Drain everything pending (final partial batch is padded) and
        sync every dispatched flush."""
        while self._n_pending:
            self._flush_one()
        while self._inflight:
            self._sync_oldest()

    def _flush_one(self) -> None:
        """Dispatch one micro-batch; sync the *previous* flush only after
        this one is enqueued (double-buffered dispatch-ahead)."""
        mb = self.cfg.micro_batch
        # consume only as many queued arrays as one micro-batch needs — a
        # huge submit is sliced by view, not re-concatenated per flush
        chunks, n = [], 0
        while self._pending and n < mb:
            a = self._pending.popleft()
            chunks.append(a)
            n += a.shape[0]
        flat = (chunks[0] if len(chunks) == 1 else
                np.concatenate(chunks) if chunks else np.zeros((0,), np.int32))
        take = flat[:mb]
        if flat.size > mb:
            self._pending.appendleft(flat[mb:])
        n_real = take.size
        self._n_pending -= n_real
        if n_real < mb:  # pad the final partial batch to the jitted shape
            take = np.concatenate([take, np.zeros(mb - n_real, np.int32)])

        t0 = time.perf_counter()
        out = self._recommend(jnp.asarray(take))      # async dispatch
        self._inflight.append((take, n_real, t0, out))
        self._dispatched += 1
        while len(self._inflight) > 1:
            self._sync_oldest()

    def _sync_oldest(self) -> None:
        take, n_real, t0, (scores, items) = self._inflight.popleft()
        jax.block_until_ready(items)
        now = time.perf_counter()
        # latency: dispatch → result readiness (includes time queued
        # behind the previous flush); busy wall: overlap counted once
        self._flush_secs.append(now - t0)
        self._busy_secs += now - max(self._last_ready, t0)
        self._last_ready = now
        self._users_served += n_real
        self._results.append((take[:n_real],
                              np.asarray(scores)[:n_real],
                              np.asarray(items)[:n_real]))

    def take_results(self):
        """[(user_ids, scores, items)] for every flush since the last take.

        Results are appended at sync time in dispatch order, so the k-th
        tuple is the k-th flushed micro-batch and its rows line up with
        the user ids that were submitted (padding already stripped)."""
        out, self._results = self._results, []
        return out

    def stats(self) -> dict:
        secs = np.asarray(self._flush_secs) if self._flush_secs else \
            np.zeros((1,))
        busy = self._busy_secs
        return dict(
            mode=self.cfg.mode,
            batches=self._dispatched,
            users=self._users_served,
            qps=self._users_served / busy if busy else 0.0,
            p50_ms=float(np.percentile(secs, 50) * 1e3),
            p95_ms=float(np.percentile(secs, 95) * 1e3),
        )

    # ---- ingestion plane (paper Alg. 4) ----

    def ingest(self, new_sigs: jax.Array, new_ids: jax.Array,
               full_sigs: jax.Array | None = None) -> None:
        """Insert new items into the index tail; rebuild only on overflow
        (rebuild requires ``full_sigs`` [q, N_total]).

        Crossing the empty-tail boundary (first insert, or a rebuild
        folding the tail away) flips the static tail fast path in
        `_recommend`, so re-warm here — the retrace lands in ingestion
        time, not in the next request's latency window."""
        had_tail = self.index.tail_fill > 0
        rebuilt = lsh_index.needs_rebuild(self.index, int(new_ids.shape[0]))
        if rebuilt:     # a rebuild also grows n_base → new trace shapes
            if full_sigs is None:
                raise ValueError("tail overflow and no full_sigs to rebuild")
            self.index = lsh_index.rebuild(self.index, full_sigs)
        else:
            self.index = lsh_index.insert(self.index, new_sigs, new_ids)
        if rebuilt or (self.index.tail_fill > 0) != had_tail:
            self.warmup()

    def ingest_online_update(self, state, N_old: int) -> None:
        """Adopt a `core.online.online_update` result: swap in the grown
        params/interactions and add only the *new* columns to the index,
        re-signing from the updated accumulator cache (Alg. 4 lines 1–6).
        Old columns keep their buckets (the paper's "remains unchanged").

        The index is never rebuilt, but the grown parameter shapes force
        one retrace of the serving pipelines — re-warm here so the compile
        lands in ingestion time, not in a request's latency window."""
        self.flush()        # drain in-flight work against the old planes
        sigs = simlsh.pack_bits(state.S >= 0)                 # [q, N_new]
        # swap the grown state in *before* the index ingest: ingest()'s
        # own tail-boundary warmup must compile against the new plane
        # shapes, not trace a pipeline the swap immediately invalidates
        assert state.N <= 1 << 30, \
            "item ids must stay below 2^30 (the dedup hash mask)"
        self.params = state.params
        self.planes = model.pack_serve_planes(state.params)
        self.sp = state.sp
        if self.JK is not None:
            self.JK = state.JK
        if self.cfg.n_popular:
            self.popular = popular_shortlist(state.params, self.cfg.n_popular)
        if state.N > N_old:
            self.ingest(sigs[:, N_old:],
                        jnp.arange(N_old, state.N, dtype=jnp.int32),
                        full_sigs=sigs)
        self.warmup()
