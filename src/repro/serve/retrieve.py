"""Batched candidate retrieval — the ANN stage of the serving pipeline.

For a batch of users the candidate set is the union of
  * bucket-mates (across all bands, `index.lookup_items`) of the user's
    *seed items* — their highest-rated observed items, the serving analogue
    of the paper's "items similar under simLSH to what i liked";
  * the seeds themselves and their precomputed Top-K neighbour lists J^K
    (when provided) — the training-side neighbourhoods reused at serving;
  * tail items (online inserts not yet folded into the sorted core) that
    collide with any seed in any band;
  * a global popularity shortlist (items with the highest baseline b̂_j),
    which covers the bias-dominated part of Eq. (1) that no similarity
    structure can retrieve — it gets *reserved* slots, so it can never be
    crowded out.

Everything is fixed-shape: the union is deduplicated into a [B, C] int32
tensor, SENTINEL-padded, ready for the `candidate_score` kernel.  Dedup is
sort → neighbour-compare → sort (compaction); `lax.top_k` is deliberately
avoided — it is several times slower than a second sort at these shapes.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.topk import SENTINEL
from repro.data.sparse import SparseMatrix
from repro.serve.index import LSHIndex, _sig_of_items, lookup_items

# invertible 30-bit multiplicative hash (2654435761·x mod 2³⁰); item ids
# must stay below 2³⁰ — comfortably above any catalog this serves
_MASK30 = jnp.int32(0x3FFFFFFF)


@partial(jax.jit, static_argnames=("n_seeds", "window"))
def seed_items(sp: SparseMatrix, user_ids: jax.Array, *, n_seeds: int,
               window: int = 64) -> jax.Array:
    """Top-rated observed items per user.  [B] → seeds [B, n_seeds], SENTINEL-
    padded for users with fewer than n_seeds ratings.

    Users' entries are a contiguous run of the row-sorted COO arrays; we
    scan a fixed ``window`` of it (zipf rows longer than the window
    contribute their first `window` ratings — a bounded-cost approximation).
    """
    start = jnp.searchsorted(sp.rows, user_ids, side="left").astype(jnp.int32)
    end = jnp.searchsorted(sp.rows, user_ids, side="right").astype(jnp.int32)
    pos = start[:, None] + jnp.arange(window, dtype=jnp.int32)     # [B, W]
    ok = pos < end[:, None]
    pos = jnp.clip(pos, 0, sp.rows.shape[0] - 1)
    vals = jnp.where(ok, sp.vals[pos], -jnp.inf)
    items = jnp.where(ok, sp.cols[pos], SENTINEL)
    top, idx = jax.lax.top_k(vals, min(n_seeds, window))
    seeds = jnp.take_along_axis(items, idx, axis=1)
    return jnp.where(jnp.isfinite(top), seeds, SENTINEL)


@partial(jax.jit, static_argnames=("C",))
def dedup_candidates(cands: jax.Array, *, C: int,
                     exclude_sorted: jax.Array | None = None) -> jax.Array:
    """[B, L] SENTINEL-padded id lists → [B, C] unique ids, SENTINEL-padded.

    Ids in ``exclude_sorted`` (ascending) are dropped — used to keep the
    reserved popularity slots duplicate-free.  When a row has more than C
    unique candidates the overflow is truncated in *hashed*-id order, so no
    id range is systematically evicted (ascending-id truncation would always
    drop the newest — highest-id — items first).  Callers size C above the
    typical unique count, so truncation is the overflow case, not the norm.
    """
    B, L = cands.shape
    if exclude_sorted is not None:
        p = jnp.clip(jnp.searchsorted(exclude_sorted, cands), 0,
                     exclude_sorted.shape[0] - 1)
        cands = jnp.where(exclude_sorted[p] == cands, SENTINEL, cands)
    c = jnp.sort(cands, axis=1)
    prev = jnp.concatenate([jnp.full((B, 1), -1, c.dtype), c[:, :-1]], axis=1)
    uniq = (c != prev) & (c != SENTINEL)
    # compact uniques to the left in *hashed*-id order: h is an invertible
    # multiplicative hash mod 2³⁰ (odd multiplier), so a plain int32 sort of
    # h — far cheaper than argsort/pair-sort on CPU and TPU — gives an
    # unbiased truncation order, padding (SENTINEL > 2³⁰) still sorts last,
    # and the ids are recovered exactly by the modular inverse.
    h = jnp.where(uniq, (c * jnp.int32(-1640531535)) & _MASK30, SENTINEL)
    h = jnp.sort(h, axis=1)[:, :min(C, L)]
    out = jnp.where(h == SENTINEL, SENTINEL,
                    (h * jnp.int32(244002641)) & _MASK30)
    if C > L:
        out = jnp.pad(out, ((0, 0), (0, C - L)), constant_values=SENTINEL)
    return out


@partial(jax.jit, static_argnames=("n_seeds", "cap", "C", "window"))
def retrieve_for_users(index: LSHIndex, sp: SparseMatrix, user_ids: jax.Array,
                       *, n_seeds: int, cap: int, C: int,
                       JK: jax.Array | None = None,
                       popular: jax.Array | None = None,
                       window: int = 64) -> jax.Array:
    """user_ids [B] → candidate item ids [B, C] int32, SENTINEL-padded."""
    B = user_ids.shape[0]
    seeds = seed_items(sp, user_ids, n_seeds=n_seeds, window=window)  # [B, S]

    mates = lookup_items(index, seeds.reshape(-1), cap=cap,
                         include_tail=False)
    pools = [mates.reshape(B, -1), seeds]
    if JK is not None:
        safe = jnp.clip(seeds, 0, JK.shape[0] - 1)
        nb = jnp.where((seeds != SENTINEL)[:, :, None], JK[safe], SENTINEL)
        pools.append(nb.reshape(B, -1))
    if index.tail_cap:
        # one tail scan per *user*: tail items colliding with any seed/band
        qsigs = _sig_of_items(index, seeds)                   # [q, B, S]
        hit = jnp.any(qsigs[..., None] == index.tail_sigs[:, None, None, :],
                      axis=(0, 2))                            # [B, T]
        pools.append(jnp.where(hit, index.tail_ids[None, :], SENTINEL))

    pool = jnp.concatenate(pools, axis=1)
    if popular is None:
        return dedup_candidates(pool, C=C)
    # popularity shortlist gets reserved slots at the end of the row
    P = popular.shape[0]
    assert C > P, f"candidate budget C={C} must exceed the shortlist P={P}"
    core = dedup_candidates(pool, C=C - P, exclude_sorted=jnp.sort(popular))
    return jnp.concatenate(
        [core, jnp.broadcast_to(popular[None, :], (B, P))], axis=1)


@partial(jax.jit, static_argnames=("cap", "C"))
def retrieve_for_items(index: LSHIndex, item_ids: jax.Array, *, cap: int,
                       C: int) -> jax.Array:
    """Item-to-item retrieval (related-items widgets): [B] → [B, C]."""
    mates = lookup_items(index, item_ids, cap=cap)
    return dedup_candidates(mates, C=C)
