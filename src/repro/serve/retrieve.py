"""Batched candidate retrieval — the ANN stage of the serving pipeline.

For a batch of users the candidate set is the union of
  * bucket-mates (across all bands, `index.lookup_items`) of the user's
    *seed items* — their highest-rated observed items, the serving analogue
    of the paper's "items similar under simLSH to what i liked";
  * the seeds themselves and their precomputed Top-K neighbour lists J^K
    (when provided) — the training-side neighbourhoods reused at serving;
  * tail items (online inserts not yet folded into the sorted core) that
    collide with any seed in any band;
  * a global popularity shortlist (items with the highest baseline b̂_j),
    which covers the bias-dominated part of Eq. (1) that no similarity
    structure can retrieve — it gets *reserved* slots, so it can never be
    crowded out.

Everything is fixed-shape: the union is deduplicated into a [B, C] int32
tensor, SENTINEL-padded, ready for the `candidate_score` kernel.  Dedup is
a **single** sort: ids are pushed through an invertible multiplicative
hash first (exclusion folded in as SENTINEL), the hashed keys are sorted
once — equal ids have equal hashes, so duplicates are still adjacent —
and the surviving uniques are left-compacted by a cumsum + binary-search
gather (O(C·log L) vs the O(L log L) second sort PR 1 used).  `lax.top_k` is
deliberately avoided — it is several times slower than sort at these
shapes — and the mostly-SENTINEL bucket-mate runs are pre-folded
(`_fold_prefix_runs`; generic `compact_pool` as an optional knob) so the
one sort runs at a fraction of the raw union width.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.topk import SENTINEL
from repro.data.sparse import SparseMatrix
from repro.serve.index import LSHIndex, _sig_of_items, lookup_items

# invertible 30-bit multiplicative hash (2654435761·x mod 2³⁰); item ids
# must stay below 2³⁰ — comfortably above any catalog this serves
_MASK30 = jnp.int32(0x3FFFFFFF)


@partial(jax.jit, static_argnames=("n_seeds", "window"))
def seed_items(sp: SparseMatrix, user_ids: jax.Array, *, n_seeds: int,
               window: int = 64) -> jax.Array:
    """Top-rated observed items per user.  [B] → seeds [B, n_seeds], SENTINEL-
    padded for users with fewer than n_seeds ratings.

    Users' entries are a contiguous run of the row-sorted COO arrays; we
    scan a fixed ``window`` of it (zipf rows longer than the window
    contribute their first `window` ratings — a bounded-cost approximation).
    """
    start = jnp.searchsorted(sp.rows, user_ids, side="left").astype(jnp.int32)
    end = jnp.searchsorted(sp.rows, user_ids, side="right").astype(jnp.int32)
    pos = start[:, None] + jnp.arange(window, dtype=jnp.int32)     # [B, W]
    ok = pos < end[:, None]
    pos = jnp.clip(pos, 0, sp.rows.shape[0] - 1)
    vals = jnp.where(ok, sp.vals[pos], -jnp.inf)
    items = jnp.where(ok, sp.cols[pos], SENTINEL)
    top, idx = jax.lax.top_k(vals, min(n_seeds, window))
    seeds = jnp.take_along_axis(items, idx, axis=1)
    return jnp.where(jnp.isfinite(top), seeds, SENTINEL)


def _compact_left(keys: jax.Array, width: int) -> jax.Array:
    """Left-compact each row's non-SENTINEL entries into ``width`` slots,
    preserving order: output slot k gathers the k-th survivor, found by
    binary-searching the survivor-count cumsum (O(width·log L) per row —
    measured ~4 ms at [256, 1552] on CPU where the scatter formulation
    costs 35 ms and a compacting re-sort 10–14 ms).  Entries past
    ``width`` survivors are dropped (callers size ``width`` above the
    typical survivor count)."""
    L = keys.shape[1]
    pos = jnp.cumsum(keys != SENTINEL, axis=1, dtype=jnp.int32)    # [B, L]
    k = jnp.arange(1, width + 1, dtype=jnp.int32)
    src = jax.vmap(lambda p: jnp.searchsorted(p, k, side="left"))(pos)
    out = jnp.take_along_axis(keys, jnp.minimum(src, L - 1), axis=1)
    return jnp.where(k[None, :] <= pos[:, -1:], out, SENTINEL)


def _fold_prefix_runs(runs: jax.Array) -> jax.Array:
    """[B, R, cap] of *prefix-compacted* runs (valid entries contiguous
    from slot 0 — the `lookup_items` output invariant: a bucket window is
    `ok = pos < hi` over an ascending ``pos``) → [B, R/2, 3·cap/2]: each
    pair of runs merges into one ``1.5·cap``-wide run, left run's prefix
    first.  One elementwise index computation + one `take_along_axis` —
    ~1 ms where a generic compaction costs 9–10 ms — because the prefix
    invariant makes the k-th survivor's position *computable* instead of
    searchable.  A pair with more than ``1.5·cap`` combined survivors
    drops the overflow; the 1.5× output width is the measured sweet spot
    (at cap=8, N=100k: same flush time as 1.0×, recall@10 0.9125 vs
    0.8906 — the 1.0× fold evicted true neighbours from dense band
    pairs; no-fold recall is 0.9176 at +28% flush time).  Odd run counts
    pass the last run through, padded to the fold width.
    """
    B, R, cap = runs.shape
    w = 3 * cap // 2
    pairs = runs[:, :R - R % 2, :].reshape(B, -1, 2 * cap)
    c0 = jnp.sum(pairs[..., :cap] != SENTINEL, axis=-1,
                 keepdims=True).astype(jnp.int32)       # left-run survivors
    j = jnp.arange(w, dtype=jnp.int32)
    right = jnp.minimum(cap + j - c0, 2 * cap - 1)      # keep src in bounds
    out = jnp.take_along_axis(pairs, jnp.where(j < c0, j, right), axis=-1)
    out = jnp.where((j < c0) | (cap + j - c0 < 2 * cap), out, SENTINEL)
    if R % 2:
        odd = jnp.pad(runs[:, R - 1:, :], ((0, 0), (0, 0), (0, w - cap)),
                      constant_values=SENTINEL)
        out = jnp.concatenate([out, odd], axis=1)
    return out


@partial(jax.jit, static_argnames=("width",))
def compact_pool(pool: jax.Array, *, width: int) -> jax.Array:
    """[B, L] SENTINEL-strewn id pool → [B, width], valid ids
    left-compacted in pool order.  The retrieval pools are mostly
    SENTINEL (bucket windows shorter than ``cap``, users with fewer than
    ``n_seeds`` seeds leave whole per-seed runs empty), so compacting
    them first lets `dedup_candidates` sort a fraction of the raw union
    width.  Rows with more than ``width`` valid entries drop the
    overflow in pool order — a biased truncation, so callers keep
    ``width`` comfortably above the typical valid count (the unbiased
    hashed truncation still happens in `dedup_candidates`)."""
    return _compact_left(pool, width)


@partial(jax.jit, static_argnames=("C",))
def dedup_candidates(cands: jax.Array, *, C: int,
                     exclude_sorted: jax.Array | None = None) -> jax.Array:
    """[B, L] SENTINEL-padded id lists → [B, C] unique ids, SENTINEL-padded.

    Ids in ``exclude_sorted`` (ascending) are dropped — used to keep the
    reserved popularity slots duplicate-free.  When a row has more than C
    unique candidates the overflow is truncated in *hashed*-id order, so no
    id range is systematically evicted (ascending-id truncation would always
    drop the newest — highest-id — items first).  Callers size C above the
    typical unique count, so truncation is the overflow case, not the norm.

    One sort total (PR 1 used two): the sort key is the invertible
    multiplicative hash mod 2³⁰ (odd multiplier) with the exclude mask
    folded in as SENTINEL, so a single int32 sort simultaneously (a)
    groups duplicates adjacently — the hash is injective on [0, 2³⁰), so
    equal hashes ⇔ equal ids — (b) fixes the unbiased truncation order,
    and (c) pushes padding/excluded slots last.  The surviving first
    occurrences are then left-compacted by the cumsum + binary-search
    gather of `_compact_left` and recovered exactly through the hash's
    modular inverse.
    """
    B, L = cands.shape
    valid = cands != SENTINEL
    if exclude_sorted is not None:
        p = jnp.clip(jnp.searchsorted(exclude_sorted, cands), 0,
                     exclude_sorted.shape[0] - 1)
        valid &= exclude_sorted[p] != cands
    h = jnp.where(valid, (cands * jnp.int32(-1640531535)) & _MASK30, SENTINEL)
    h = jnp.sort(h, axis=1)                        # the single sort
    prev = jnp.concatenate([jnp.full((B, 1), -1, h.dtype), h[:, :-1]], axis=1)
    h = jnp.where(h != prev, h, SENTINEL)          # duplicate runs → padding
    h = _compact_left(h, C)
    return jnp.where(h == SENTINEL, SENTINEL,
                     (h * jnp.int32(244002641)) & _MASK30)


@partial(jax.jit, static_argnames=("n_seeds", "cap", "window",
                                   "fold_mates", "tail_scan"))
def candidate_pool(index: LSHIndex, sp: SparseMatrix, user_ids: jax.Array,
                  *, n_seeds: int, cap: int,
                  JK: jax.Array | None = None,
                  window: int = 64,
                  fold_mates: bool = True,
                  tail_scan: bool = True) -> jax.Array:
    """The pre-dedup candidate union: seeds, their bucket-mates (folded),
    their Top-K lists, and colliding tail items — [B, L] SENTINEL-strewn.
    Exposed separately so the observability profile path
    (`RecsysService.profile_flush`) can time pool building apart from the
    dedup sort; `retrieve_for_users` fuses both into one program."""
    B = user_ids.shape[0]
    seeds = seed_items(sp, user_ids, n_seeds=n_seeds, window=window)  # [B, S]

    # an empty (or absent) tail means every seed id lives in the sorted
    # core — lookup can take the slot-only fast path
    base_only = (not tail_scan) or index.tail_cap == 0
    mates = lookup_items(index, seeds.reshape(-1), cap=cap,
                         include_tail=False, assume_base=base_only)
    mates = mates.reshape(B, -1, cap)             # [B, S·q, cap] prefix runs
    if fold_mates:
        mates = _fold_prefix_runs(mates)
    pools = [mates.reshape(B, -1), seeds]
    if JK is not None:
        safe = jnp.clip(seeds, 0, JK.shape[0] - 1)
        nb = jnp.where((seeds != SENTINEL)[:, :, None], JK[safe], SENTINEL)
        pools.append(nb.reshape(B, -1))
    if index.tail_cap and tail_scan:
        # one tail scan per *user*: tail items colliding with any seed/band
        qsigs = _sig_of_items(index, seeds)                   # [q, B, S]
        hit = jnp.any(qsigs[..., None] == index.tail_sigs[:, None, None, :],
                      axis=(0, 2))                            # [B, T]
        pools.append(jnp.where(hit, index.tail_ids[None, :], SENTINEL))
    return jnp.concatenate(pools, axis=1)


@partial(jax.jit, static_argnames=("C", "pool_width"))
def finalize_candidates(pool: jax.Array, *, C: int,
                        popular: jax.Array | None = None,
                        pool_width: int = 0) -> jax.Array:
    """Pool → [B, C] unique candidates: optional pre-compaction, the
    single-sort dedup, and the reserved popularity slots."""
    B = pool.shape[0]
    if 0 < pool_width < pool.shape[1]:
        pool = compact_pool(pool, width=pool_width)
    if popular is None:
        return dedup_candidates(pool, C=C)
    # popularity shortlist gets reserved slots at the end of the row
    P = popular.shape[0]
    assert C > P, f"candidate budget C={C} must exceed the shortlist P={P}"
    core = dedup_candidates(pool, C=C - P, exclude_sorted=jnp.sort(popular))
    return jnp.concatenate(
        [core, jnp.broadcast_to(popular[None, :], (B, P))], axis=1)


@partial(jax.jit, static_argnames=("n_seeds", "cap", "C", "window",
                                   "pool_width", "fold_mates", "tail_scan"))
def retrieve_for_users(index: LSHIndex, sp: SparseMatrix, user_ids: jax.Array,
                       *, n_seeds: int, cap: int, C: int,
                       JK: jax.Array | None = None,
                       popular: jax.Array | None = None,
                       window: int = 64,
                       pool_width: int = 0,
                       fold_mates: bool = True,
                       tail_scan: bool = True) -> jax.Array:
    """user_ids [B] → candidate item ids [B, C] int32, SENTINEL-padded.

    Pool-width control ahead of the single dedup sort:

    * ``fold_mates`` (default on) halves the bucket-mate pool by folding
      pairs of per-(seed, band) prefix runs (`_fold_prefix_runs`) — the
      dominant pool at ~2–3 valid entries per ``cap``-wide run;
    * ``tail_scan=False`` skips the online-insert tail pool entirely —
      pass it when the tail is known empty on the host
      (``index.tail_fill == 0``), where the scan is all-miss work;
    * ``pool_width > 0`` additionally pre-compacts the concatenated pool
      to that width (`compact_pool`).  Off by default: on CPU the
      generic compaction costs about what the narrower sort saves
      (measured ~9 ms vs ~8 ms at [256, 1552] → 768); the knob exists
      for accelerators where sort is relatively dearer.
    """
    pool = candidate_pool(index, sp, user_ids, n_seeds=n_seeds, cap=cap,
                          JK=JK, window=window, fold_mates=fold_mates,
                          tail_scan=tail_scan)
    return finalize_candidates(pool, C=C, popular=popular,
                               pool_width=pool_width)


@partial(jax.jit, static_argnames=("cap", "C"))
def retrieve_for_items(index: LSHIndex, item_ids: jax.Array, *, cap: int,
                       C: int) -> jax.Array:
    """Item-to-item retrieval (related-items widgets): [B] → [B, C]."""
    mates = lookup_items(index, item_ids, cap=cap)
    return dedup_candidates(mates, C=C)
