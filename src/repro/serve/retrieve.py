"""Batched candidate retrieval — the ANN stage of the serving pipeline.

For a batch of users the candidate set is the union of
  * bucket-mates (across all bands, `index.lookup_items`) of the user's
    *seed items* — their highest-rated observed items, the serving analogue
    of the paper's "items similar under simLSH to what i liked";
  * the seeds themselves and their precomputed Top-K neighbour lists J^K
    (when provided) — the training-side neighbourhoods reused at serving;
  * tail items (online inserts not yet folded into the sorted core) that
    collide with any seed in any band;
  * a global popularity shortlist (items with the highest baseline b̂_j),
    which covers the bias-dominated part of Eq. (1) that no similarity
    structure can retrieve — it gets *reserved* slots, so it can never be
    crowded out.

Everything is fixed-shape: the union is deduplicated into a [B, C] int32
tensor, SENTINEL-padded, ready for the `candidate_score` kernel.  Dedup is
a **single** sort: ids are pushed through an invertible multiplicative
hash first (exclusion folded in as SENTINEL), the hashed keys are sorted
once — equal ids have equal hashes, so duplicates are still adjacent —
and the surviving uniques are left-compacted by a cumsum + binary-search
gather (O(C·log L) vs the O(L log L) second sort PR 1 used).  `lax.top_k` is
deliberately avoided — it is several times slower than sort at these
shapes — and the mostly-SENTINEL bucket-mate runs are pre-folded
(`_fold_prefix_runs`; generic `compact_pool` as an optional knob) so the
one sort runs at a fraction of the raw union width.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.topk import SENTINEL
from repro.data.sparse import SparseMatrix
from repro.serve.index import (LSHIndex, _EMPTY_SIG, _sig_of_items,
                               lookup_items)

# invertible 30-bit multiplicative hash (2654435761·x mod 2³⁰); item ids
# must stay below 2³⁰ — comfortably above any catalog this serves
_MASK30 = jnp.int32(0x3FFFFFFF)


@partial(jax.jit, static_argnames=("n_seeds", "window"))
def seed_items(sp: SparseMatrix, user_ids: jax.Array, *, n_seeds: int,
               window: int = 64) -> jax.Array:
    """Top-rated observed items per user.  [B] → seeds [B, n_seeds], SENTINEL-
    padded for users with fewer than n_seeds ratings.

    Users' entries are a contiguous run of the row-sorted COO arrays; we
    scan a fixed ``window`` of it (zipf rows longer than the window
    contribute their first `window` ratings — a bounded-cost approximation).
    """
    start = jnp.searchsorted(sp.rows, user_ids, side="left").astype(jnp.int32)
    end = jnp.searchsorted(sp.rows, user_ids, side="right").astype(jnp.int32)
    pos = start[:, None] + jnp.arange(window, dtype=jnp.int32)     # [B, W]
    ok = pos < end[:, None]
    pos = jnp.clip(pos, 0, sp.rows.shape[0] - 1)
    vals = jnp.where(ok, sp.vals[pos], -jnp.inf)
    items = jnp.where(ok, sp.cols[pos], SENTINEL)
    top, idx = jax.lax.top_k(vals, min(n_seeds, window))
    seeds = jnp.take_along_axis(items, idx, axis=1)
    return jnp.where(jnp.isfinite(top), seeds, SENTINEL)


def _compact_left(keys: jax.Array, width: int) -> jax.Array:
    """Left-compact each row's non-SENTINEL entries into ``width`` slots,
    preserving order: output slot k gathers the k-th survivor, found by
    binary-searching the survivor-count cumsum (O(width·log L) per row —
    measured ~4 ms at [256, 1552] on CPU where the scatter formulation
    costs 35 ms and a compacting re-sort 10–14 ms).  Entries past
    ``width`` survivors are dropped (callers size ``width`` above the
    typical survivor count)."""
    L = keys.shape[1]
    pos = jnp.cumsum(keys != SENTINEL, axis=1, dtype=jnp.int32)    # [B, L]
    k = jnp.arange(1, width + 1, dtype=jnp.int32)
    src = jax.vmap(lambda p: jnp.searchsorted(p, k, side="left"))(pos)
    out = jnp.take_along_axis(keys, jnp.minimum(src, L - 1), axis=1)
    return jnp.where(k[None, :] <= pos[:, -1:], out, SENTINEL)


def _fold_prefix_runs(runs: jax.Array) -> jax.Array:
    """[B, R, cap] of *prefix-compacted* runs (valid entries contiguous
    from slot 0 — the `lookup_items` output invariant: a bucket window is
    `ok = pos < hi` over an ascending ``pos``) → [B, R/2, 3·cap/2]: each
    pair of runs merges into one ``1.5·cap``-wide run, left run's prefix
    first.  One elementwise index computation + one `take_along_axis` —
    ~1 ms where a generic compaction costs 9–10 ms — because the prefix
    invariant makes the k-th survivor's position *computable* instead of
    searchable.  A pair with more than ``1.5·cap`` combined survivors
    drops the overflow; the 1.5× output width is the measured sweet spot
    (at cap=8, N=100k: same flush time as 1.0×, recall@10 0.9125 vs
    0.8906 — the 1.0× fold evicted true neighbours from dense band
    pairs; no-fold recall is 0.9176 at +28% flush time).  Odd run counts
    pass the last run through, padded to the fold width.
    """
    B, R, cap = runs.shape
    w = 3 * cap // 2
    pairs = runs[:, :R - R % 2, :].reshape(B, -1, 2 * cap)
    c0 = jnp.sum(pairs[..., :cap] != SENTINEL, axis=-1,
                 keepdims=True).astype(jnp.int32)       # left-run survivors
    j = jnp.arange(w, dtype=jnp.int32)
    right = jnp.minimum(cap + j - c0, 2 * cap - 1)      # keep src in bounds
    out = jnp.take_along_axis(pairs, jnp.where(j < c0, j, right), axis=-1)
    out = jnp.where((j < c0) | (cap + j - c0 < 2 * cap), out, SENTINEL)
    if R % 2:
        odd = jnp.pad(runs[:, R - 1:, :], ((0, 0), (0, 0), (0, w - cap)),
                      constant_values=SENTINEL)
        out = jnp.concatenate([out, odd], axis=1)
    return out


@partial(jax.jit, static_argnames=("width",))
def compact_pool(pool: jax.Array, *, width: int) -> jax.Array:
    """[B, L] SENTINEL-strewn id pool → [B, width], valid ids
    left-compacted in pool order.  The retrieval pools are mostly
    SENTINEL (bucket windows shorter than ``cap``, users with fewer than
    ``n_seeds`` seeds leave whole per-seed runs empty), so compacting
    them first lets `dedup_candidates` sort a fraction of the raw union
    width.  Rows with more than ``width`` valid entries drop the
    overflow in pool order — a biased truncation, so callers keep
    ``width`` comfortably above the typical valid count (the unbiased
    hashed truncation still happens in `dedup_candidates`)."""
    return _compact_left(pool, width)


@partial(jax.jit, static_argnames=("C",))
def dedup_candidates(cands: jax.Array, *, C: int,
                     exclude_sorted: jax.Array | None = None) -> jax.Array:
    """[B, L] SENTINEL-padded id lists → [B, C] unique ids, SENTINEL-padded.

    Ids in ``exclude_sorted`` (ascending) are dropped — used to keep the
    reserved popularity slots duplicate-free.  When a row has more than C
    unique candidates the overflow is truncated in *hashed*-id order, so no
    id range is systematically evicted (ascending-id truncation would always
    drop the newest — highest-id — items first).  Callers size C above the
    typical unique count, so truncation is the overflow case, not the norm.

    One sort total (PR 1 used two): the sort key is the invertible
    multiplicative hash mod 2³⁰ (odd multiplier) with the exclude mask
    folded in as SENTINEL, so a single int32 sort simultaneously (a)
    groups duplicates adjacently — the hash is injective on [0, 2³⁰), so
    equal hashes ⇔ equal ids — (b) fixes the unbiased truncation order,
    and (c) pushes padding/excluded slots last.  The surviving first
    occurrences are then left-compacted by the cumsum + binary-search
    gather of `_compact_left` and recovered exactly through the hash's
    modular inverse.
    """
    B, L = cands.shape
    valid = cands != SENTINEL
    if exclude_sorted is not None:
        p = jnp.clip(jnp.searchsorted(exclude_sorted, cands), 0,
                     exclude_sorted.shape[0] - 1)
        valid &= exclude_sorted[p] != cands
    h = jnp.where(valid, (cands * jnp.int32(-1640531535)) & _MASK30, SENTINEL)
    h = jnp.sort(h, axis=1)                        # the single sort
    prev = jnp.concatenate([jnp.full((B, 1), -1, h.dtype), h[:, :-1]], axis=1)
    h = jnp.where(h != prev, h, SENTINEL)          # duplicate runs → padding
    h = _compact_left(h, C)
    return jnp.where(h == SENTINEL, SENTINEL,
                     (h * jnp.int32(244002641)) & _MASK30)


@partial(jax.jit, static_argnames=("n_seeds", "cap", "window",
                                   "fold_mates", "tail_scan"))
def candidate_pool(index: LSHIndex, sp: SparseMatrix, user_ids: jax.Array,
                  *, n_seeds: int, cap: int,
                  JK: jax.Array | None = None,
                  window: int = 64,
                  fold_mates: bool = True,
                  tail_scan: bool = True) -> jax.Array:
    """The pre-dedup candidate union: seeds, their bucket-mates (folded),
    their Top-K lists, and colliding tail items — [B, L] SENTINEL-strewn.
    Exposed separately so the observability profile path
    (`RecsysService.profile_flush`) can time pool building apart from the
    dedup sort; `retrieve_for_users` fuses both into one program."""
    B = user_ids.shape[0]
    seeds = seed_items(sp, user_ids, n_seeds=n_seeds, window=window)  # [B, S]

    # an empty (or absent) tail means every seed id lives in the sorted
    # core — lookup can take the slot-only fast path
    base_only = (not tail_scan) or index.tail_cap == 0
    mates = lookup_items(index, seeds.reshape(-1), cap=cap,
                         include_tail=False, assume_base=base_only)
    mates = mates.reshape(B, -1, cap)             # [B, S·q, cap] prefix runs
    if fold_mates:
        mates = _fold_prefix_runs(mates)
    pools = [mates.reshape(B, -1), seeds]
    if JK is not None:
        safe = jnp.clip(seeds, 0, JK.shape[0] - 1)
        nb = jnp.where((seeds != SENTINEL)[:, :, None], JK[safe], SENTINEL)
        pools.append(nb.reshape(B, -1))
    if index.tail_cap and tail_scan:
        # one tail scan per *user*: tail items colliding with any seed/band
        qsigs = _sig_of_items(index, seeds)                   # [q, B, S]
        hit = jnp.any(qsigs[..., None] == index.tail_sigs[:, None, None, :],
                      axis=(0, 2))                            # [B, T]
        pools.append(jnp.where(hit, index.tail_ids[None, :], SENTINEL))
    return jnp.concatenate(pools, axis=1)


@partial(jax.jit, static_argnames=("C", "pool_width"))
def finalize_candidates(pool: jax.Array, *, C: int,
                        popular: jax.Array | None = None,
                        pool_width: int = 0) -> jax.Array:
    """Pool → [B, C] unique candidates: optional pre-compaction, the
    single-sort dedup, and the reserved popularity slots."""
    B = pool.shape[0]
    if 0 < pool_width < pool.shape[1]:
        pool = compact_pool(pool, width=pool_width)
    if popular is None:
        return dedup_candidates(pool, C=C)
    # popularity shortlist gets reserved slots at the end of the row
    P = popular.shape[0]
    assert C > P, f"candidate budget C={C} must exceed the shortlist P={P}"
    core = dedup_candidates(pool, C=C - P, exclude_sorted=jnp.sort(popular))
    return jnp.concatenate(
        [core, jnp.broadcast_to(popular[None, :], (B, P))], axis=1)


@partial(jax.jit, static_argnames=("n_seeds", "cap", "C", "window",
                                   "pool_width", "fold_mates", "tail_scan"))
def retrieve_for_users(index: LSHIndex, sp: SparseMatrix, user_ids: jax.Array,
                       *, n_seeds: int, cap: int, C: int,
                       JK: jax.Array | None = None,
                       popular: jax.Array | None = None,
                       window: int = 64,
                       pool_width: int = 0,
                       fold_mates: bool = True,
                       tail_scan: bool = True) -> jax.Array:
    """user_ids [B] → candidate item ids [B, C] int32, SENTINEL-padded.

    Pool-width control ahead of the single dedup sort:

    * ``fold_mates`` (default on) halves the bucket-mate pool by folding
      pairs of per-(seed, band) prefix runs (`_fold_prefix_runs`) — the
      dominant pool at ~2–3 valid entries per ``cap``-wide run;
    * ``tail_scan=False`` skips the online-insert tail pool entirely —
      pass it when the tail is known empty on the host
      (``index.tail_fill == 0``), where the scan is all-miss work;
    * ``pool_width > 0`` additionally pre-compacts the concatenated pool
      to that width (`compact_pool`).  Off by default: on CPU the
      generic compaction costs about what the narrower sort saves
      (measured ~9 ms vs ~8 ms at [256, 1552] → 768); the knob exists
      for accelerators where sort is relatively dearer.
    """
    pool = candidate_pool(index, sp, user_ids, n_seeds=n_seeds, cap=cap,
                          JK=JK, window=window, fold_mates=fold_mates,
                          tail_scan=tail_scan)
    return finalize_candidates(pool, C=C, popular=popular,
                               pool_width=pool_width)


@partial(jax.jit, static_argnames=("cap", "C"))
def retrieve_for_items(index: LSHIndex, item_ids: jax.Array, *, cap: int,
                       C: int) -> jax.Array:
    """Item-to-item retrieval (related-items widgets): [B] → [B, C]."""
    mates = lookup_items(index, item_ids, cap=cap)
    return dedup_candidates(mates, C=C)


# ---------------------------------------------------------------------------
# Window-descriptor retrieval (the "walk" path).
#
# The functions above materialise every bucket window as gathered ids and
# dedup with a [B, ~1100]-wide sort — both show up as the hot half of a
# flush.  The walk path keeps retrieval symbolic as long as possible:
# buckets become *interval descriptors* (start slot + count), overlapping
# windows of the same band are merged arithmetically (so the union is
# duplicate-free within a band by construction), and a shared per-user slot
# budget is enumerated across all bands at once.  Cross-band duplicates are
# the only ones left, and they are cheap enough to defer all the way to
# top-n selection (`service` masks them there) or to fold in VMEM inside
# the `lsh_retrieve` kernel.  No [B, pool]-wide sort ever runs on the host.
# ---------------------------------------------------------------------------

# interval sort key for invalid seeds: larger than any flat slot position
# (q·N < 2³⁰ by the build_index id bound), so they sink to the tail
_BIG = jnp.int32(1 << 30)


def _sortpairs_bitonic(st, en):
    """Ascending co-sort of (start, end) interval pairs along the last
    axis — a static bitonic network.  The last axis is tiny (S seeds), so
    ~log²S/2 compare-exchange stages of full-tensor min/max beat the
    generic argsort+gather lowering by ~2.5× on CPU.  Requires a
    power-of-two last axis (callers pad with `_BIG` sink intervals)."""
    W = st.shape[-1]
    assert W & (W - 1) == 0, "bitonic width must be a power of two"
    lead = st.shape[:-1]
    k = 2
    while k <= W:
        j = k // 2
        while j >= 1:
            shp = lead + (W // (2 * j), 2, j)
            sa, ea = st.reshape(shp), en.reshape(shp)
            a_s, b_s = sa[..., 0, :], sa[..., 1, :]
            a_e, b_e = ea[..., 0, :], ea[..., 1, :]
            # element index of a[..., g, t] is g·2j + t; ascending block
            # iff that index has bit k clear (standard bitonic direction)
            idx = (jax.lax.broadcasted_iota(jnp.int32, (W // (2 * j), j), 0)
                   * (2 * j)
                   + jax.lax.broadcasted_iota(jnp.int32, (W // (2 * j), j), 1))
            up = ((idx & k) == 0).reshape((1,) * len(lead) + (W // (2 * j), j))
            swap = (a_s > b_s) == up
            st = jnp.stack([jnp.where(swap, b_s, a_s),
                            jnp.where(swap, a_s, b_s)],
                           axis=-2).reshape(lead + (W,))
            en = jnp.stack([jnp.where(swap, b_e, a_e),
                            jnp.where(swap, a_e, b_e)],
                           axis=-2).reshape(lead + (W,))
            j //= 2
        k *= 2
    return st, en


def _merge_intervals(st: jax.Array, en: jax.Array, base: jax.Array):
    """Sort + overlap-trim per-band interval lists.  st/en [q, B, S]
    (slot-space starts/ends, `_BIG` marking invalid intervals) →
    (starts, counts) [B, q·S] with ``starts`` lifted to flat positions by
    ``base`` [q, 1, 1] (band b's slot offset).  Windows of the same band
    are sorted by start and overlaps trimmed (interval k begins at
    ``max(start_k, max(end_0..k-1))``), so within a band every slot
    appears at most once.  Shared tail of `window_descriptors` (slot-
    addressed) and `sig_window_descriptors` (signature-addressed)."""
    q, B, S = st.shape
    Sp = 1 << max(S - 1, 0).bit_length()       # bitonic needs a pow-2 width
    if Sp > S:
        pad = jnp.full((q, B, Sp - S), _BIG, jnp.int32)
        st = jnp.concatenate([st, pad], axis=2)
        en = jnp.concatenate([en, pad], axis=2)
    st, en = _sortpairs_bitonic(st, en)
    # ascending sort sinks the _BIG pads past every real window, so the
    # first S entries are exactly the real (+invalid) intervals
    st, en = st[:, :, :S], en[:, :, :S]
    run_en = jax.lax.cummax(en, axis=2)
    pmax = jnp.concatenate(
        [jnp.zeros((q, B, 1), jnp.int32), run_en[:, :, :-1]], axis=2)
    ns = jnp.maximum(st, pmax)
    cnt = jnp.maximum(jnp.minimum(en, _BIG) - ns, 0)
    cnt = jnp.where(st >= _BIG, 0, cnt)
    ns = jnp.where(st >= _BIG, 0, ns + base)
    starts = jnp.transpose(ns, (1, 0, 2)).reshape(B, q * S)
    counts = jnp.transpose(cnt, (1, 0, 2)).reshape(B, q * S)
    return starts, counts


@partial(jax.jit, static_argnames=("cap",))
def window_descriptors(index: LSHIndex, seeds: jax.Array, *, cap: int):
    """Merged per-(user, band) bucket-window intervals.

    seeds [B, S] → (starts, counts), both [B, q·S] int32.  Each seed
    contributes its `lookup_items`-geometry window (centred on its slot,
    clipped to its bucket, ≤ ``cap`` wide); overlapping windows of the
    same band are merged (`_merge_intervals`), so within a band every
    slot appears at most once.  ``starts`` are flat positions into
    ``sorted_ids.reshape(-1)``; ``counts`` may be 0 (fully-shadowed or
    invalid windows).  Intervals arrive band-major but NOT globally
    sorted — consumers only need the per-band disjointness.
    """
    B, S = seeds.shape
    q, Nn = index.q, index.n_base
    valid = (seeds != SENTINEL) & (seeds >= 0) & (seeds < Nn)
    safe = jnp.clip(seeds, 0, Nn - 1)
    base = (jnp.arange(q, dtype=jnp.int32) * Nn)[:, None, None]    # [q,1,1]
    slot = index.slot_of.reshape(-1)[base + safe[None]]            # [q,B,S]
    fslot = base + slot
    lo = index.bucket_lo.reshape(-1)[fslot]
    hi = index.bucket_hi.reshape(-1)[fslot]
    st = jnp.clip(slot - cap // 2, lo, jnp.maximum(hi - cap, lo))
    en = jnp.minimum(st + cap, hi)
    st = jnp.where(valid[None], st, _BIG)
    en = jnp.where(valid[None], en, _BIG)
    return _merge_intervals(st, en, base)


@partial(jax.jit, static_argnames=("budget",))
def enumerate_windows(starts: jax.Array, counts: jax.Array, *,
                      budget: int) -> jax.Array:
    """Expand interval descriptors into flat slot positions under a shared
    per-user budget.  (starts, counts) [B, I] → pos [B, budget] int32, −1
    past each user's total.  Users whose intervals sum past ``budget``
    are truncated in interval order (later intervals dropped first).

    Scatter-fill enumeration: each nonempty interval scatters its *index*
    at its output offset (cumsum of counts), a `cummax` extends ownership
    forward — interval indices are monotone in offset, so the running max
    is exactly "which interval owns this slot" — and a gather of the
    owner's (start − offset) turns slot rank into a flat position.  This
    is O(B·(I + budget)) elementwise work; `jnp.repeat` lowers to the
    same shape but ~40% slower on CPU, and a sort-based expansion costs
    more than the dedup sort this path removes.
    """
    B, I = starts.shape
    coff = jnp.cumsum(counts, axis=1)
    coff_ex = coff - counts
    total = coff[:, -1:]
    val = starts - coff_ex                       # per-interval: pos = val + d
    tgt = jnp.where(counts > 0, coff_ex, budget)           # OOB → dropped
    jidx = jnp.broadcast_to(jnp.arange(I, dtype=jnp.int32)[None, :], (B, I))
    bidx = jnp.broadcast_to(jnp.arange(B)[:, None], (B, I))
    own = jnp.zeros((B, budget), jnp.int32)
    own = own.at[bidx, tgt].max(jidx, mode="drop")
    own = jax.lax.cummax(own, axis=1)
    d = jnp.arange(budget, dtype=jnp.int32)[None, :]
    pos = jnp.take_along_axis(val, own, axis=1) + d
    return jnp.where(d < total, pos, -1)


@partial(jax.jit, static_argnames=("k",))
def tail_hits(index: LSHIndex, seeds: jax.Array, *, k: int = 0) -> jax.Array:
    """Online-insert tail items colliding with any seed in any band.
    seeds [B, S] → [B, T] ids, SENTINEL where no collision.  One scan per
    user (not per seed) — same trick as `candidate_pool`'s tail block.

    ``k`` > 0 restricts the scan (and the output width) to the first k
    tail slots: the tail fills strictly in insertion order, so every slot
    ≥ `tail_fill` is empty and scanning it — let alone *scoring* its
    SENTINEL column downstream — is pure waste.  Callers pass a host-side
    occupancy bound rounded up (service rounds to 16) so retraces stay
    rare.  k = 0 scans the whole buffer."""
    T = index.tail_cap
    k = T if k <= 0 else min(k, T)
    qsigs = _sig_of_items(index, seeds)                        # [q, B, S]
    hit = jnp.any(
        qsigs[..., None] == index.tail_sigs[:, :k][:, None, None, :],
        axis=(0, 2))                                           # [B, k]
    return jnp.where(hit, index.tail_ids[None, :k], SENTINEL)


@partial(jax.jit, static_argnames=("n_seeds", "cap", "budget", "window"))
def walk_candidates(index: LSHIndex, sp: SparseMatrix, user_ids: jax.Array,
                    *, n_seeds: int, cap: int, budget: int,
                    window: int = 64):
    """The walk path end to end: seeds → merged descriptors → enumerated
    slots → gathered ids.  [B] → (ids [B, budget], seeds [B, n_seeds]).

    ``ids`` may contain *cross-band* duplicates (each band is internally
    duplicate-free); callers either dedup at top-n selection
    (`service._select_topn_masked`) or route through the `lsh_retrieve`
    kernel.  Seeds are NOT appended — every valid seed's window contains
    the seed itself, so the union already covers them.
    """
    seeds = seed_items(sp, user_ids, n_seeds=n_seeds, window=window)
    starts, counts = window_descriptors(index, seeds, cap=cap)
    pos = enumerate_windows(starts, counts, budget=budget)
    flat = index.sorted_ids.reshape(-1)
    ids = jnp.where(pos >= 0, flat[jnp.maximum(pos, 0)], SENTINEL)
    return ids, seeds


# ---------------------------------------------------------------------------
# Shard-local walk (the per-device half of the sharded serving path).
#
# Under `shard_map` each device holds one shard of a `ShardedLSHIndex`:
# the same walk as above, but addressed by *signature* instead of seed
# slot — a seed's slot only exists in its owning shard, while its band
# signatures (owner-computed, psum-shared; see `service`) let every shard
# binary-search its own local buckets.  All local ids stay local until
# scoring is done; `translate_local_ids` lifts the survivors to global
# ids just before selection, masking the block-padding slots to SENTINEL
# so they can never leak into a merged top-N.
# ---------------------------------------------------------------------------


def shard_seed_sigs(ssig: jax.Array, slot_of: jax.Array, seeds: jax.Array,
                    lo: jax.Array, n_local: jax.Array) -> jax.Array:
    """Owner-computed band signatures of the seeds this shard owns.

    ssig/slot_of [q, block] (one shard's local arrays), seeds [B, S]
    global ids, ``lo`` the shard's first global id, ``n_local`` its real
    item count.  → [q, B, S] int32: the seed's signature where this shard
    owns it, 0 elsewhere.  Summing the contributions over the shard axis
    (each seed has exactly one owner) gives every shard every seed's
    signature; callers must mask seeds owned by *no* shard (SENTINEL /
    out of range) to `_EMPTY_SIG` after the sum — a sum of zeros is a
    legal signature.
    """
    q, block = ssig.shape
    local = seeds - lo
    owned = (seeds != SENTINEL) & (local >= 0) & (local < n_local)
    safe = jnp.clip(local, 0, block - 1)
    slot = slot_of[:, safe.reshape(-1)]                      # [q, B·S]
    sig = jnp.take_along_axis(ssig, slot, axis=1)
    return jnp.where(owned[None], sig.reshape((q,) + seeds.shape), 0)


@partial(jax.jit, static_argnames=("cap",))
def sig_window_descriptors(ssig: jax.Array, qsigs: jax.Array, *, cap: int):
    """Signature-addressed window descriptors over one shard's local CSR.

    ssig [q, block] (ascending per band), qsigs [q, B, S] seed band
    signatures (`_EMPTY_SIG` = invalid) → (starts, counts) [B, q·S] flat
    positions into the shard's ``sorted_ids.reshape(-1)``.

    Geometry: windows take the first ≤ ``cap`` slots of the local bucket
    (bucket-head, not seed-centred — a probing shard has no seed slot to
    centre on).  When a bucket fits in ``cap`` both geometries return the
    whole bucket, so the union over shards equals the single-device
    window union exactly whenever nothing truncates; under truncation the
    shards collectively keep up to D·cap of a bucket family where one
    device keeps cap.  Same-band duplicate windows (two seeds sharing a
    bucket) merge to one via `_merge_intervals`; distinct signatures hit
    disjoint buckets, so per-band disjointness holds by construction.
    """
    q, block = ssig.shape
    _, B, S = qsigs.shape
    flat = qsigs.reshape(q, B * S)
    lo = jax.vmap(partial(jnp.searchsorted, side="left"))(ssig, flat)
    hi = jax.vmap(partial(jnp.searchsorted, side="right"))(ssig, flat)
    lo = lo.astype(jnp.int32).reshape(q, B, S)
    hi = hi.astype(jnp.int32).reshape(q, B, S)
    valid = qsigs != _EMPTY_SIG
    st = jnp.where(valid, lo, _BIG)
    en = jnp.where(valid, jnp.minimum(lo + cap, hi), _BIG)
    base = (jnp.arange(q, dtype=jnp.int32) * block)[:, None, None]
    return _merge_intervals(st, en, base)


@partial(jax.jit, static_argnames=("cap", "budget"))
def shard_walk_local(ssig: jax.Array, sids: jax.Array, qsigs: jax.Array,
                     n_local: jax.Array, *, cap: int, budget: int):
    """One shard's walked candidates in LOCAL ids, SENTINEL-padded.

    ssig/sids [q, block], qsigs [q, B, S] (see `shard_seed_sigs`),
    ``n_local`` the shard's real item count → ids [B, budget].  Block-
    padding slots (local id ≥ n_local) are masked out here — they carry
    `_EMPTY_SIG` and are unreachable by a real probe, but the mask keeps
    the invariant unconditional.  Cross-band duplicates remain (same
    contract as `walk_candidates`).
    """
    starts, counts = sig_window_descriptors(ssig, qsigs, cap=cap)
    pos = enumerate_windows(starts, counts, budget=budget)
    flat = sids.reshape(-1)
    lid = jnp.where(pos >= 0, flat[jnp.maximum(pos, 0)], SENTINEL)
    return jnp.where(lid < n_local, lid, SENTINEL)


def translate_local_ids(local_ids: jax.Array, lo: jax.Array) -> jax.Array:
    """Shard-local → global ids: ``l ↦ lo + l``; SENTINEL stays SENTINEL
    (the local walk already masked padding slots)."""
    return jnp.where(local_ids == SENTINEL, SENTINEL, local_ids + lo)
