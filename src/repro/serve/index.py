"""Bucketed LSH index — the serving-side image of the paper's hash table.

`core/topk.py` finds bucket-mates with a *per-call* argsort of every band's
signatures — fine for one-shot Top-K construction, but serving needs a
persistent structure that is built once and probed millions of times.  This
module stores each band's signatures in sorted order with CSR-style bucket
offsets, so a probe is a binary search (or an O(1) slot lookup for items the
index already contains) instead of an O(N log N) sort.

Layout per band b (all fixed-shape, jit-friendly, int32):

  sorted_sigs[b]  [N]  band signatures ascending      ┐ the "CSR" arrays:
  sorted_ids[b]   [N]  item id occupying each slot    │ a bucket is the
  bucket_lo[b]    [N]  first slot of the slot's bucket│ contiguous slot range
  bucket_hi[b]    [N]  one-past-last slot of bucket   ┘ [lo, hi)
  slot_of[b]      [N]  item id → its slot (inverse permutation)

Online ingestion (paper Alg. 4): new items are appended to a small *tail*
buffer that probes scan linearly; when the tail fills up the index is rebuilt
from the full signature set.  This is the classic main+delta ANN design — the
sorted core stays immutable (warm jit caches, no re-sort per insert) and the
tail bounds the extra probe cost.

All candidate outputs are SENTINEL-padded (same convention as `core/topk.py`).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.topk import SENTINEL

# tail slots that hold no item: their signature must never match a probe.
# Signatures are packed into ≤30 bits (simlsh.SimLSHConfig.__post_init__),
# so int32 min is unreachable as a real signature.
_EMPTY_SIG = jnp.iinfo(jnp.int32).min


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class LSHIndex:
    sorted_sigs: jax.Array   # [q, N] int32
    sorted_ids: jax.Array    # [q, N] int32
    bucket_lo: jax.Array     # [q, N] int32
    bucket_hi: jax.Array     # [q, N] int32
    slot_of: jax.Array       # [q, N] int32
    tail_sigs: jax.Array     # [q, T] int32 (_EMPTY_SIG where unused)
    tail_ids: jax.Array      # [T] int32 (SENTINEL where unused)
    tail_len: jax.Array      # [] int32
    n_base: int = dataclasses.field(metadata=dict(static=True))
    tail_cap: int = dataclasses.field(metadata=dict(static=True))

    @property
    def q(self) -> int:
        return self.sorted_sigs.shape[0]

    @property
    def tail_fill(self) -> int:
        """Host-side tail occupancy.  `build_index`/`insert`/`rebuild`
        maintain a plain-int mirror of ``tail_len`` outside the pytree
        (static fields would retrace every jitted consumer on each
        insert), so the ingestion-plane checks (`needs_rebuild`,
        `n_items`) don't force a device sync per call.  Instances that
        crossed a jit boundary lose the mirror and fall back to one
        sync."""
        t = getattr(self, "_tail_host", None)
        return int(self.tail_len) if t is None else t

    @property
    def n_items(self) -> int:
        """Total items the index can answer for (base + current tail)."""
        return self.n_base + self.tail_fill


def _build_arrays(sigs: jax.Array):
    """The per-band CSR arrays for one signature matrix [q, N] →
    (sorted_sigs, sorted_ids, bucket_lo, bucket_hi, slot_of), all [q, N].
    Shared by the single-device build and the vmapped per-shard build."""
    N = sigs.shape[1]

    def one_band(sig):
        order = jnp.argsort(sig).astype(jnp.int32)
        ssig = sig[order]
        slot_of = jnp.zeros((N,), jnp.int32).at[order].set(
            jnp.arange(N, dtype=jnp.int32))
        lo = jnp.searchsorted(ssig, ssig, side="left").astype(jnp.int32)
        hi = jnp.searchsorted(ssig, ssig, side="right").astype(jnp.int32)
        return ssig, order, lo, hi, slot_of

    return jax.vmap(one_band)(sigs)


@partial(jax.jit, static_argnames=("tail_cap",))
def _build(sigs: jax.Array, tail_cap: int) -> LSHIndex:
    q, N = sigs.shape
    ssig, order, lo, hi, slot_of = _build_arrays(sigs)
    return LSHIndex(
        sorted_sigs=ssig, sorted_ids=order, bucket_lo=lo, bucket_hi=hi,
        slot_of=slot_of,
        tail_sigs=jnp.full((q, tail_cap), _EMPTY_SIG, jnp.int32),
        tail_ids=jnp.full((tail_cap,), SENTINEL, jnp.int32),
        tail_len=jnp.asarray(0, jnp.int32),
        n_base=N, tail_cap=tail_cap)


def build_index(sigs: jax.Array, *, tail_cap: int = 1024) -> LSHIndex:
    """sigs [q, N] int32 (from `core.simlsh.encode`) → persistent index.

    Item ids are the column positions 0..N-1 — the same id space as the
    factor matrix V, so lookups compose directly with scoring.
    """
    # raises (not asserts — these guard data integrity and must survive
    # ``python -O``): a float signature matrix means NaN poisoning
    # upstream; anything non-int32 would be silently reinterpreted by the
    # CSR layout's int32 contract
    if sigs.dtype != jnp.int32:
        hint = (" (float signatures usually mean a NaN-poisoned pipeline "
                "— pass simlsh.pack_bits output)"
                if jnp.issubdtype(sigs.dtype, jnp.floating) else "")
        raise TypeError(f"build_index: signatures must be int32, got "
                        f"{sigs.dtype}{hint}")
    if sigs.ndim != 2:
        raise ValueError(f"build_index: expected [q, N] signatures, got "
                         f"shape {sigs.shape}")
    # retrieve.dedup_candidates runs ids through an invertible
    # multiplicative hash mod 2³⁰ — ids at or above 2³⁰ would silently
    # alias in the dedup, so refuse them at build time
    if sigs.shape[1] > 1 << 30:
        raise ValueError(f"build_index: item ids must stay below 2^30 (the "
                         f"dedup hash mask); got N={sigs.shape[1]}")
    idx = _build(sigs, tail_cap=tail_cap)
    object.__setattr__(idx, "_tail_host", 0)
    return idx


def insert(index: LSHIndex, new_sigs: jax.Array, new_ids: jax.Array) -> LSHIndex:
    """Append new items (Alg. 4 online ingestion) to the tail buffer.

    ``new_sigs`` [q, n] are the re-signed signatures of the *new* columns
    (from `simlsh.update_accumulators`); ``new_ids`` [n] their global ids.
    Raises if the tail would overflow — callers should then `rebuild` with
    the full signature set (see `needs_rebuild`).
    """
    n = int(new_ids.shape[0])
    tl = index.tail_fill
    if tl + n > index.tail_cap:
        raise ValueError(
            f"tail overflow ({tl}+{n} > {index.tail_cap}): rebuild the index")
    # id contract (non-negative ints below the 2^30 dedup hash mask):
    # checked here for host arrays; device arrays skip it rather than
    # force an ingestion-plane sync — their callers assert the bound
    # host-side instead (`build_index`/`rebuild` on N;
    # `ingest_online_update` on state.N, plus the service's
    # check_ingest_batch at the boundary)
    if n and isinstance(new_ids, (np.ndarray, list, tuple)):
        from repro.resil.validate import check_ids   # lazy: keep index.py
        check_ids(new_ids, what="insert new_ids")    # import-light
    if new_sigs is not None and hasattr(new_sigs, "dtype") \
            and np.issubdtype(np.dtype(new_sigs.dtype), np.floating):
        raise TypeError(
            f"insert: signatures must be int32, got {new_sigs.dtype} — "
            f"float signatures usually mean a NaN-poisoned pipeline")
    tail_sigs = jax.lax.dynamic_update_slice(
        index.tail_sigs, jnp.asarray(new_sigs, jnp.int32), (0, tl))
    tail_ids = jax.lax.dynamic_update_slice(
        index.tail_ids, jnp.asarray(new_ids, jnp.int32), (tl,))
    out = dataclasses.replace(
        index, tail_sigs=tail_sigs, tail_ids=tail_ids,
        tail_len=jnp.asarray(tl + n, jnp.int32))
    object.__setattr__(out, "_tail_host", tl + n)
    return out


def needs_rebuild(index: LSHIndex, incoming: int = 0) -> bool:
    return index.tail_fill + incoming > index.tail_cap


def rebuild(index: LSHIndex, sigs: jax.Array) -> LSHIndex:
    """Fold the tail back into the sorted core from the full [q, N'] sigs."""
    return build_index(sigs, tail_cap=index.tail_cap)


def _sig_of_items(index: LSHIndex, ids: jax.Array) -> jax.Array:
    """Band signatures for item ids that live in the index.  ids [...] →
    [q, ...]; unknown/SENTINEL ids get _EMPTY_SIG (match nothing)."""
    in_base = (ids >= 0) & (ids < index.n_base)
    safe = jnp.clip(ids, 0, index.n_base - 1)
    base_sig = index.sorted_sigs[
        jnp.arange(index.q)[:, None], index.slot_of[:, safe.reshape(-1)]
    ].reshape((index.q,) + ids.shape)

    # tail path: linear match over the (small) tail buffer
    tmatch = index.tail_ids[None, :] == ids.reshape(-1)[:, None]   # [Q, T]
    tslot = jnp.argmax(tmatch, axis=1)                             # [Q]
    thit = jnp.any(tmatch, axis=1)
    tail_sig = index.tail_sigs[:, tslot].reshape((index.q,) + ids.shape)
    thit = thit.reshape(ids.shape)

    sig = jnp.where(in_base, base_sig,
                    jnp.where(thit, tail_sig, _EMPTY_SIG))
    return sig


@partial(jax.jit, static_argnames=("cap", "n_probe"))
def lookup_signatures(index: LSHIndex, qsigs: jax.Array, *,
                      cap: int, n_probe: int = 1) -> jax.Array:
    """Probe with explicit band signatures.  qsigs [B, q] → cand [B, L] int32
    with L = q·n_probe·cap + q·cap (tail), SENTINEL-padded.

    Multi-probe: probe t ∈ [0, n_probe) XORs bit (t−1) into the query
    signature (probe 0 is the exact bucket) — the standard single-bit-flip
    probe sequence that trades a few extra binary searches for recall.
    """
    B, q = qsigs.shape
    probe_masks = jnp.asarray(
        [0] + [1 << t for t in range(n_probe - 1)], jnp.int32)    # [n_probe]

    def one_band(ssig, sids, qsig):
        # qsig [B] → probed [B, n_probe]
        probed = qsig[:, None] ^ probe_masks[None, :]
        lo = jnp.searchsorted(ssig, probed.reshape(-1)).astype(jnp.int32)
        pos = lo[:, None] + jnp.arange(cap, dtype=jnp.int32)      # [B·P, cap]
        ok = pos < ssig.shape[0]
        pos = jnp.clip(pos, 0, ssig.shape[0] - 1)
        ok &= ssig[pos] == probed.reshape(-1)[:, None]
        out = jnp.where(ok, sids[pos], SENTINEL)
        return out.reshape(B, n_probe * cap)

    core = jax.vmap(one_band)(index.sorted_sigs, index.sorted_ids,
                              qsigs.T)                            # [q, B, P·cap]
    core = jnp.transpose(core, (1, 0, 2)).reshape(B, -1)

    def one_band_tail(tsig, qsig):
        return _tail_matches(index, tsig, qsig, width=cap)

    tail = jax.vmap(one_band_tail)(index.tail_sigs, qsigs.T)      # [q, B, cap]
    tail = jnp.transpose(tail, (1, 0, 2)).reshape(B, -1)
    return jnp.concatenate([core, tail], axis=1)


@partial(jax.jit, static_argnames=("cap",))
def window_slices(index: LSHIndex, item_ids: jax.Array, *, cap: int):
    """Per-(item, band) bucket-window *descriptors* instead of gathered ids.

    item_ids [B, S] → (starts, lens), both [B, q·S] int32.  ``starts`` are
    flat positions into ``sorted_ids.reshape(-1)`` (band b's slots occupy
    [b·N, (b+1)·N)); ``lens`` ∈ [0, cap] is the number of valid slots from
    the start.  Same geometry as `lookup_items`: the window is centred on
    the item's own slot and clipped to its bucket, so it always contains
    the item itself.  Invalid (SENTINEL / out-of-range / tail-resident)
    items get length 0.

    This is the DMA contract of the `lsh_retrieve` kernel: each descriptor
    is one static ``cap``-sized async copy out of HBM, masked to ``lens``
    in VMEM.  A copy may therefore read up to ``cap − len`` slots past the
    window (and, in the last band's last bucket, past the array) — consumers
    must read ``sorted_ids`` through `padded_flat_ids`, which appends
    ``cap`` SENTINEL slots so the overrun is always in-bounds and inert.
    """
    B, S = item_ids.shape
    q, Nn = index.q, index.n_base
    valid = (item_ids != SENTINEL) & (item_ids >= 0) & (item_ids < Nn)
    safe = jnp.clip(item_ids, 0, Nn - 1)
    base = (jnp.arange(q, dtype=jnp.int32) * Nn)[:, None, None]    # [q,1,1]
    slot = index.slot_of.reshape(-1)[base + safe[None]]            # [q,B,S]
    fslot = base + slot
    lo = index.bucket_lo.reshape(-1)[fslot]
    hi = index.bucket_hi.reshape(-1)[fslot]
    st = jnp.clip(slot - cap // 2, lo, jnp.maximum(hi - cap, lo))
    ln = jnp.where(valid[None], jnp.minimum(st + cap, hi) - st, 0)
    st = jnp.where(valid[None], st + base, 0)
    starts = jnp.transpose(st, (1, 0, 2)).reshape(B, q * S)
    lens = jnp.transpose(ln, (1, 0, 2)).reshape(B, q * S)
    return starts, lens


@partial(jax.jit, static_argnames=("cap",))
def padded_flat_ids(index: LSHIndex, *, cap: int) -> jax.Array:
    """``sorted_ids`` flattened to [q·N + cap] with a SENTINEL apron, so a
    static ``cap``-wide read at any `window_slices` start stays in-bounds
    (the apron slots hash to padding in the dedup even if a mask slips).
    Cache the result per index version — it copies the whole id plane."""
    return jnp.concatenate(
        [index.sorted_ids.reshape(-1),
         jnp.full((cap,), SENTINEL, jnp.int32)])


def _tail_matches(index: LSHIndex, tsig: jax.Array, qsig: jax.Array, *,
                  width: int) -> jax.Array:
    """Up to ``width`` tail ids whose band signature equals qsig.  [B] →
    [B, width].  Sort-compaction (match positions first) — `top_k` is far
    slower than sort on both CPU and TPU for these shapes."""
    T = tsig.shape[0]
    match = tsig[None, :] == qsig[:, None]                        # [B, T]
    key = jnp.where(match, jnp.arange(T, dtype=jnp.int32), T)
    key = jnp.sort(key, axis=1)[:, :min(width, T)]
    ids = index.tail_ids[jnp.clip(key, 0, T - 1)]
    return jnp.where(key < T, ids, SENTINEL)


@partial(jax.jit, static_argnames=("cap", "include_tail", "assume_base"))
def lookup_items(index: LSHIndex, item_ids: jax.Array, *, cap: int,
                 include_tail: bool = True,
                 assume_base: bool = False) -> jax.Array:
    """Bucket-mates of items already in the index.  item_ids [B] →
    cand [B, q·cap (+ q·cap tail)] int32, SENTINEL-padded (includes the item
    itself).  ``include_tail=False`` skips the tail scan — callers that batch
    many queries per user (see `retrieve.retrieve_for_users`) scan the tail
    once per user instead.  ``assume_base=True`` additionally promises every
    valid query id lives in the sorted core (true whenever the tail is
    empty, `index.tail_fill == 0`), which skips the signature-probe
    fallback below — per-query work drops to the O(1) slot lookup.

    For base items the bucket is addressed by the precomputed slot (no
    binary search); the window is centred on the item's own slot so huge
    buckets spread their mates instead of always returning the bucket head —
    the same windowing `topk.band_candidates` applies.
    """
    B = item_ids.shape[0]
    valid_q = item_ids != SENTINEL
    in_base = valid_q & (item_ids >= 0) & (item_ids < index.n_base)
    safe = jnp.clip(item_ids, 0, index.n_base - 1)

    def one_band(ssig, sids, lo_a, hi_a, slot_of):
        slot = slot_of[safe]                                      # [B]
        lo, hi = lo_a[slot], hi_a[slot]
        start = jnp.clip(slot - cap // 2, lo, jnp.maximum(hi - cap, lo))
        pos = start[:, None] + jnp.arange(cap, dtype=jnp.int32)   # [B, cap]
        ok = in_base[:, None] & (pos < hi[:, None])
        pos = jnp.clip(pos, 0, ssig.shape[0] - 1)
        return jnp.where(ok, sids[pos], SENTINEL)

    core = jax.vmap(one_band)(index.sorted_sigs, index.sorted_ids,
                              index.bucket_lo, index.bucket_hi,
                              index.slot_of)                      # [q, B, cap]

    if not assume_base:
        qsigs = _sig_of_items(index, item_ids)                    # [q, B]

        # tail-resident query items have no slot — find their base bucket
        # by binary search on the signature instead
        def one_band_sig(ssig, sids, qsig):
            lo = jnp.searchsorted(ssig, qsig).astype(jnp.int32)
            pos = lo[:, None] + jnp.arange(cap, dtype=jnp.int32)  # [B, cap]
            ok = pos < ssig.shape[0]
            pos = jnp.clip(pos, 0, ssig.shape[0] - 1)
            ok &= ssig[pos] == qsig[:, None]
            return jnp.where(ok, sids[pos], SENTINEL)

        by_sig = jax.vmap(one_band_sig)(index.sorted_sigs, index.sorted_ids,
                                        qsigs)                    # [q, B, cap]
        core = jnp.where(in_base[None, :, None], core, by_sig)
    core = jnp.transpose(core, (1, 0, 2)).reshape(B, -1)
    if not include_tail:
        return core

    if assume_base:                     # tail scan still requested — the
        qsigs = _sig_of_items(index, item_ids)   # promise only covers the
                                                 # query ids, not the tail

    # tail members that share any band signature with the query item
    def one_band_tail(tsig, qsig):
        return _tail_matches(index, tsig, qsig, width=cap)

    tail = jax.vmap(one_band_tail)(index.tail_sigs, qsigs)        # [q, B, cap]
    tail = jnp.transpose(tail, (1, 0, 2)).reshape(B, -1)
    return jnp.concatenate([core, tail], axis=1)


# ---------------------------------------------------------------------------
# Sharded index — the mesh-partitioned image of the structure above.
#
# For catalogs that outgrow one device the item axis is cut into D
# nnz-balanced contiguous ranges (the scheduler's `balanced_bounds` cuts,
# so "balanced" means the same thing in training and serving) and every
# shard builds the SAME per-band CSR layout over its own items in a
# *local* id space 0..n_d−1.  Shards are block-padded to a common extent
# (the `block_id_map` trick from the training tier): padding slots carry
# `_EMPTY_SIG`, which sorts before every real signature and can never
# match a probe, so they form one inert bucket at the front of each band.
# The stacked [D, ...] arrays shard over `launch.mesh.make_shard_mesh`'s
# "shard" axis with no resharding — leading-axis slice d IS device d's
# local index.
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ShardedLSHIndex:
    """Per-shard bucket CSR over local ids, stacked on a leading shard
    axis.  Global id ``g`` of shard ``d`` (``bounds[d] ≤ g < bounds[d+1]``)
    appears as local id ``g − bounds[d]``; local ids ≥ ``n_local[d]`` are
    padding.  No tail: the sharded path serves the offline-bulk regime
    (online inserts go through the single-device tail + rebuild path)."""

    sorted_sigs: jax.Array   # [D, q, block] int32, ascending per band
    sorted_ids: jax.Array    # [D, q, block] int32 local ids
    bucket_lo: jax.Array     # [D, q, block] int32
    bucket_hi: jax.Array     # [D, q, block] int32
    slot_of: jax.Array       # [D, q, block] int32 local id → slot
    n_local: jax.Array       # [D] int32 real (non-padding) items per shard
    bounds: jax.Array        # [D+1] int32 global cut points
    n_items: int = dataclasses.field(metadata=dict(static=True))
    block: int = dataclasses.field(metadata=dict(static=True))

    @property
    def shards(self) -> int:
        return self.sorted_sigs.shape[0]

    @property
    def q(self) -> int:
        return self.sorted_sigs.shape[1]


def shard_bounds(counts: np.ndarray, shards: int) -> np.ndarray:
    """nnz-balanced item cuts for the serving shards.  ``counts [N]`` are
    per-item rating counts (col degrees); returns ``bounds [D+1]``.  The
    extent floor of N/(4·D) bounds the block padding waste at ~4× even on
    zipf catalogs whose head shard would otherwise collapse to a handful
    of very popular items."""
    from repro.data.sparse import balanced_bounds   # lazy: keep index.py
    N, D = len(counts), shards                      # import-light
    return balanced_bounds(np.asarray(counts), D,
                           floor=max(1, N // (4 * max(D, 1))))


def signatures_of(index: LSHIndex) -> jax.Array:
    """Recover the full [q, n_base] signature matrix from a built index
    (``sigs[b, g] = sorted_sigs[b, slot_of[b, g]]``).  Lets the sharded
    build start from an already-built single-device index without the
    caller re-threading the raw `simlsh.encode` output."""
    return jnp.take_along_axis(index.sorted_sigs, index.slot_of, axis=1)


def build_sharded_index(sigs: jax.Array, *, shards: int,
                        counts: np.ndarray | None = None,
                        bounds: np.ndarray | None = None) -> ShardedLSHIndex:
    """sigs [q, N] int32 → block-padded per-shard CSR stack.

    ``bounds`` (explicit cuts) wins over ``counts`` (nnz-balanced cuts via
    `shard_bounds`); with neither, shards cut the id range evenly.  The
    same dtype/id-space guards as `build_index` apply.
    """
    if sigs.dtype != jnp.int32:
        raise TypeError(f"build_sharded_index: signatures must be int32, "
                        f"got {sigs.dtype}")
    if sigs.ndim != 2:
        raise ValueError(f"build_sharded_index: expected [q, N] signatures, "
                         f"got shape {sigs.shape}")
    q, N = sigs.shape
    if N > 1 << 30:
        raise ValueError(f"build_sharded_index: item ids must stay below "
                         f"2^30 (the dedup hash mask); got N={N}")
    if shards < 1 or N < shards:
        raise ValueError(f"build_sharded_index: need 1 ≤ shards ≤ N, got "
                         f"shards={shards}, N={N}")
    if bounds is None:
        bounds = (shard_bounds(counts, shards) if counts is not None else
                  np.linspace(0, N, shards + 1).astype(np.int64))
    bounds = np.asarray(bounds, np.int64)
    if (len(bounds) != shards + 1 or bounds[0] != 0 or bounds[-1] != N
            or np.any(np.diff(bounds) < 1)):
        raise ValueError(f"build_sharded_index: bounds {bounds} must be "
                         f"strictly increasing from 0 to N={N}")
    ext = np.diff(bounds)
    block = int(ext.max())
    parts = [jnp.pad(sigs[:, int(bounds[d]):int(bounds[d + 1])],
                     ((0, 0), (0, block - int(ext[d]))),
                     constant_values=int(_EMPTY_SIG))
             for d in range(shards)]
    ssig, sids, lo, hi, slot = jax.vmap(_build_arrays)(jnp.stack(parts))
    return ShardedLSHIndex(
        sorted_sigs=ssig, sorted_ids=sids, bucket_lo=lo, bucket_hi=hi,
        slot_of=slot, n_local=jnp.asarray(ext, jnp.int32),
        bounds=jnp.asarray(bounds, jnp.int32), n_items=N, block=block)


def shard_local_view(index: ShardedLSHIndex, d: int) -> LSHIndex:
    """Shard ``d``'s arrays as a plain (tail-less) `LSHIndex` over its
    ``block`` local ids — padding slots included as real `_EMPTY_SIG`
    items.  Host-side tool for validation and tests; the serving path
    slices the stack inside `shard_map` instead."""
    idx = LSHIndex(
        sorted_sigs=index.sorted_sigs[d], sorted_ids=index.sorted_ids[d],
        bucket_lo=index.bucket_lo[d], bucket_hi=index.bucket_hi[d],
        slot_of=index.slot_of[d],
        tail_sigs=jnp.full((index.q, 0), _EMPTY_SIG, jnp.int32),
        tail_ids=jnp.full((0,), SENTINEL, jnp.int32),
        tail_len=jnp.asarray(0, jnp.int32),
        n_base=index.block, tail_cap=0)
    object.__setattr__(idx, "_tail_host", 0)
    return idx
