"""repro — LSH-MF: LSH-aggregated nonlinear neighbourhood matrix
factorization (Li et al. 2021) as a multi-pod JAX framework.

Subpackages: core (the paper), data, dist, train, models (LM substrate),
configs, launch, kernels (Pallas TPU). See README.md / DESIGN.md.
"""
__version__ = "1.0.0"
