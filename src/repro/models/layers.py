"""Transformer building blocks — pure-JAX, param-dict style.

Conventions:
  * params are nested dicts of arrays; layer stacks carry a leading L dim
    and are consumed with `lax.scan` (bounded compile time at 126 layers);
  * compute dtype is cfg.dtype (bf16), accumulation/softmax in f32;
  * attention is query-chunked (VMEM-sized score tiles on the target, bounded
    temp memory in the dry-run) and supports GQA, RoPE, qk-norm, biases,
    sliding windows, and decode-with-cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig


def cast(x, cfg: ArchConfig):
    return x.astype(cfg.dtype)


def rms_norm(x, w, eps: float):
    x32 = x.astype(jnp.float32)
    y = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (y * w.astype(jnp.float32)).astype(x.dtype)


def rope(x, positions, theta: float):
    """x [..., S, H, D]; positions [..., S] (broadcastable)."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs      # [..., S, half]
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


def _attend_block(q, k, v, qpos, kpos, window: int, causal: bool):
    """q [B,Sq,Hkv,G,D] vs k/v [B,T,Hkv,D] → [B,Sq,Hkv,G,D]. f32 scores."""
    scores = jnp.einsum("bqhgd,bthd->bhgqt", q, k,
                        preferred_element_type=jnp.float32)
    scores = scores / jnp.sqrt(q.shape[-1]).astype(jnp.float32)
    mask = jnp.ones((), jnp.bool_)
    dq = qpos[:, None]   # [Sq,1]
    dk = kpos[None, :]   # [1,T]
    if causal:
        mask = mask & (dk <= dq)
    if window:
        mask = mask & (dk > dq - window)
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqt,bthd->bqhgd", probs.astype(v.dtype), v)
    return out


def attention(q, k, v, *, q_offset, causal: bool, query_chunk: int,
              window: int = 0):
    """GQA attention, chunked over queries.

    q [B,S,H,D], k/v [B,T,Hkv,D].  q_offset: absolute position of q[0]
    (decode: T_past; train/prefill: 0).
    """
    B, S, H, D = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, S, Hkv, G, D)
    T = k.shape[1]
    kpos = jnp.arange(T)
    qc = min(query_chunk, S)
    nchunks = -(-S // qc)
    if nchunks == 1:
        qpos = q_offset + jnp.arange(S)
        out = _attend_block(qg, k, v, qpos, kpos, window, causal)
        return out.reshape(B, S, H, D)

    pad = nchunks * qc - S
    qg = jnp.pad(qg, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
    qg = qg.reshape(B, nchunks, qc, Hkv, G, D)

    def one(c):
        qpos = q_offset + c * qc + jnp.arange(qc)
        return _attend_block(qg[:, c], k, v, qpos, kpos, window, causal)

    out = jax.lax.map(one, jnp.arange(nchunks))          # [nc, B, qc, Hkv, G, D]
    out = jnp.moveaxis(out, 0, 1).reshape(B, nchunks * qc, H, D)
    return out[:, :S]


def qkv_proj(p, x, cfg: ArchConfig):
    """x [B,S,D] → q [B,S,H,hd], k/v [B,S,Hkv,hd] with RoPE-ready layout."""
    B, S, _ = x.shape
    hd = cfg.hd
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def attn_out(p, o, x_dtype):
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x_dtype))


def mlp(p, x):
    g = jnp.einsum("bsd,df->bsf", x, p["w1"].astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", x, p["w3"].astype(x.dtype))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("bsf,fd->bsd", h, p["w2"].astype(x.dtype))


def shard_acts(x, cfg: ArchConfig, mesh_axes):
    """Sequence-parallel constraint on stored activations (DESIGN.md §5)."""
    if cfg.seq_shard_acts and x.ndim == 3 and mesh_axes:
        return jax.lax.with_sharding_constraint(
            x, P(mesh_axes["dp"], mesh_axes["tp"], None))
    return x


def gather_seq(x, cfg: ArchConfig, mesh_axes):
    """Megatron-SP entry: all-gather the sequence dim before a TP sublayer.

    Without this XLA resolves the S-sharded×ff-sharded conflict by fully
    de-sharding *weight matrices* (measured +26 GiB at 405B)."""
    if cfg.seq_shard_acts and x.ndim == 3 and mesh_axes:
        return jax.lax.with_sharding_constraint(
            x, P(mesh_axes["dp"], None, None))
    return x


def scatter_seq(x, cfg: ArchConfig, mesh_axes):
    """Megatron-SP exit: reduce-scatter back to the S-sharded residual."""
    return shard_acts(x, cfg, mesh_axes)
