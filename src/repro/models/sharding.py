"""Sharding rules: param/optimizer/batch/cache PartitionSpecs per arch.

Mesh contract (launch/mesh.py): axes ``("pod","data","model")`` multi-pod or
``("data","model")`` single-pod.  DP/FSDP over ("pod","data") = ``dp``; TP/EP
over "model" = ``tp``.

Rules (DESIGN.md §5):
  * TP on the natural contraction/output axis (heads, ff, experts, vocab);
  * kv projections: head-sharded when n_kv divides |tp|, else input-sharded
    (contraction all-reduce of a small [B,S,kv,hd] tensor);
  * FSDP (cfg.fsdp): additionally shard the *other* large axis over dp —
    ZeRO-3 semantics, XLA all-gathers at use;
  * optimizer moments follow param specs exactly;
  * KV caches: batch over dp, sequence over tp (sequence-parallel decode:
    softmax/contraction all-reduces [B,H] statistics only);
  * batch dim never sharded when smaller than |dp| (long_500k B=1).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig


def mesh_axes(mesh):
    names = mesh.axis_names
    dp = tuple(n for n in names if n in ("pod", "data"))
    return {"dp": dp if len(dp) > 1 else dp[0], "tp": "model",
            "ndp": int(jnp.prod(jnp.array([mesh.shape[n] for n in dp]))),
            "ntp": mesh.shape["model"]}


def _fs(cfg, axes):
    """fsdp shard axis (or None)."""
    return axes["dp"] if cfg.fsdp else None


def param_specs(cfg: ArchConfig, params, axes):
    """PartitionSpec pytree matching `params` (works on SDS trees too)."""
    tp = axes["tp"]
    fs = _fs(cfg, axes)
    ntp = axes["ntp"]
    kv_on_heads = cfg.n_kv and cfg.n_kv % ntp == 0
    q_on_heads = cfg.n_heads and cfg.n_heads_padded % ntp == 0

    def spec_for(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        stacked = any(getattr(k, "key", None) in ("layers", "enc", "dec", "dec_cross")
                      for k in path[:-1])
        lead = (None,) if stacked else ()

        def sp(*rest):
            return P(*lead, *rest)

        if name in ("embed", "out_embed"):
            # never FSDP-shard the table: a D-sharded table forces a full
            # de-shard all-gather at the logits einsum (measured 8 GiB)
            return P(tp, None)
        if name in ("final_norm", "enc_norm"):
            return P(None)
        # --- dense attention ---
        if name == "wq":
            # heads not divisible by |tp| (arctic: 56): sequence-sharded
            # attention instead — weights fall back to FSDP-on-D
            return sp(fs, tp, None) if q_on_heads else sp(fs, None, None)
        if name in ("wk", "wv"):
            # kv heads < |tp|: keep heads unsharded (small matrices), FSDP
            # the D dim — sharding D on tp causes SPMD involuntary remat
            return sp(None, tp, None) if kv_on_heads else sp(fs, None, None)
        if name == "wo":
            return sp(tp, None, fs) if q_on_heads else sp(None, None, fs)
        if name in ("bq",):
            return sp(tp, None) if q_on_heads else sp(None, None)
        if name in ("bk", "bv"):
            return sp(tp, None) if kv_on_heads else sp(None, None)
        # --- mlp / moe ---
        if name == "router":
            return sp(None, None)
        if name in ("w1", "w3"):
            if leaf.ndim - len(lead) == 3:    # [E, D, ff] expert weights
                return sp(axes["dp"], None, None) if cfg.moe_ep2d \
                    else sp(tp, fs, None)
            return sp(fs, tp)
        if name == "w2":
            if leaf.ndim - len(lead) == 3:
                return sp(axes["dp"], None, None) if cfg.moe_ep2d \
                    else sp(tp, None, fs)
            return sp(tp, fs)
        if name in ("w1d", "w3d"):
            return sp(fs, tp)
        if name == "w2d":
            return sp(tp, fs)
        # --- ssm ---
        if name in ("z_proj", "x_proj", "dt_proj"):
            return sp(fs, tp)
        if name in ("b_proj", "c_proj"):
            return sp(fs, None)
        if name == "conv_x":
            return sp(None, tp)
        if name in ("conv_b", "conv_c"):
            return sp(None, None)
        if name in ("dt_bias", "A_log", "D"):
            return sp(tp)
        if name == "norm_w":
            return sp(tp)
        if name == "out_proj":
            return sp(tp, fs)
        # norms, scalars, anything 1D
        return sp(*([None] * (leaf.ndim - len(lead))))

    return jax.tree_util.tree_map_with_path(spec_for, params)


def batch_specs(cfg: ArchConfig, batch, axes):
    dp = axes["dp"]
    ndp = axes["ndp"]

    def spec_for(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        b_ax = dp if leaf.shape and leaf.shape[0] % ndp == 0 and leaf.shape[0] >= ndp else None
        if name in ("tokens", "labels", "mask"):
            return P(b_ax, None)
        if name == "frontend_embeds":
            return P(b_ax, None, None)
        if name == "cands":
            return P(None)
        return P(*([b_ax] + [None] * (leaf.ndim - 1)))

    return jax.tree_util.tree_map_with_path(spec_for, batch)


def cache_specs(cfg: ArchConfig, cache, axes):
    dp, tp, ndp, ntp = axes["dp"], axes["tp"], axes["ndp"], axes["ntp"]

    def spec_for(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if leaf.ndim == 0:
            return P()
        if name in ("k", "v", "cross_k", "cross_v"):        # [L,B,T,Hkv,hd]
            b_ax = dp if leaf.shape[1] % ndp == 0 and leaf.shape[1] >= ndp else None
            kv_ax = tp if leaf.shape[3] % ntp == 0 else None
            t_ax = tp if kv_ax is None and leaf.shape[2] % ntp == 0 else None
            return P(None, b_ax, t_ax, kv_ax, None)
        if name == "ssm":                                    # [L,B,H,P,N]
            b_ax = dp if leaf.shape[1] % ndp == 0 and leaf.shape[1] >= ndp else None
            h_ax = tp if leaf.shape[2] % ntp == 0 else None
            return P(None, b_ax, h_ax, None, None)
        if name.startswith("conv"):                          # [L,B,K-1,C]
            b_ax = dp if leaf.shape[1] % ndp == 0 and leaf.shape[1] >= ndp else None
            c_ax = tp if leaf.shape[3] % ntp == 0 else None
            return P(None, b_ax, None, c_ax)
        if name == "enc_out":                                # [B,S,D]
            b_ax = dp if leaf.shape[0] % ndp == 0 and leaf.shape[0] >= ndp else None
            return P(b_ax, tp, None)
        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map_with_path(spec_for, cache)


def to_named(tree_specs, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))
