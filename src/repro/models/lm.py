"""Model assembly for every assigned architecture family + train/serve steps.

Families: dense | moe | ssm | hybrid | encdec | vlm   (configs/base.py).
Layer stacks are scanned (`lax.scan`) with per-layer remat; activations can
be sequence-sharded between layers (SP).  The embedding / output head is
vocab-sharded ("model" axis) — logits stay vocab-sharded so the softmax
all-reduces only [B,S] statistics (see sharding.py).

The paper's technique appears here as `lsh_softmax`: simLSH candidate
sampling over the output-embedding rows replaces the full-vocab softmax
(DESIGN.md §4) — the same "avoid the O(N) object" move as LSH-MF itself.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM

# --------------------------------------------------------------------------
# compat: jax 0.4.37 has no autodiff rule for lax.optimization_barrier
# (added upstream in 0.4.38+); wrap it as a custom_vjp identity so the
# barrier still pins scheduling on the forward pass while grads flow.
# --------------------------------------------------------------------------


@jax.custom_vjp
def _opt_barrier(x):
    return jax.lax.optimization_barrier(x)


def _opt_barrier_fwd(x):
    return _opt_barrier(x), None


def _opt_barrier_bwd(_, ct):
    return (ct,)


_opt_barrier.defvjp(_opt_barrier_fwd, _opt_barrier_bwd)


# --------------------------------------------------------------------------
# parameter initialization (pure; dry-run uses jax.eval_shape over this)
# --------------------------------------------------------------------------


def _dense_layer_init(cfg: ArchConfig, key, scale):
    hd, D, ff = cfg.hd, cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 8)
    pd = cfg.param_dtype
    p = dict(
        ln1=jnp.ones((D,), pd),
        ln2=jnp.ones((D,), pd),
        wq=scale * jax.random.normal(ks[0], (D, cfg.n_heads_padded, hd), pd),
        wk=scale * jax.random.normal(ks[1], (D, cfg.n_kv, hd), pd),
        wv=scale * jax.random.normal(ks[2], (D, cfg.n_kv, hd), pd),
        wo=scale * jax.random.normal(ks[3], (cfg.n_heads_padded, hd, D), pd),
    )
    if cfg.qkv_bias:
        p |= dict(bq=jnp.zeros((cfg.n_heads_padded, hd), pd),
                  bk=jnp.zeros((cfg.n_kv, hd), pd),
                  bv=jnp.zeros((cfg.n_kv, hd), pd))
    if cfg.qk_norm:
        p |= dict(q_norm=jnp.ones((hd,), pd), k_norm=jnp.ones((hd,), pd))
    if cfg.family == "moe" and cfg.n_experts:
        E = cfg.n_experts
        p |= dict(
            router=scale * jax.random.normal(ks[4], (D, E), pd),
            w1=scale * jax.random.normal(ks[5], (E, D, ff), pd),
            w3=scale * jax.random.normal(ks[6], (E, D, ff), pd),
            w2=scale * jax.random.normal(ks[7], (E, ff, D), pd),
        )
        if cfg.moe_dense_ff:
            fd = cfg.moe_dense_ff
            p |= dict(
                w1d=scale * jax.random.normal(jax.random.fold_in(key, 11), (D, fd), pd),
                w3d=scale * jax.random.normal(jax.random.fold_in(key, 12), (D, fd), pd),
                w2d=scale * jax.random.normal(jax.random.fold_in(key, 13), (fd, D), pd),
            )
    else:
        p |= dict(
            w1=scale * jax.random.normal(ks[5], (D, ff), pd),
            w3=scale * jax.random.normal(ks[6], (D, ff), pd),
            w2=scale * jax.random.normal(ks[7], (ff, D), pd),
        )
    return p


def _ssm_layer_init(cfg: ArchConfig, key, scale):
    D, di, N = cfg.d_model, SSM.d_inner(cfg), cfg.ssm_state
    H, K = SSM.n_heads(cfg), cfg.ssm_conv
    ks = jax.random.split(key, 10)
    pd = cfg.param_dtype
    return dict(
        ln=jnp.ones((D,), pd),
        z_proj=scale * jax.random.normal(ks[0], (D, di), pd),
        x_proj=scale * jax.random.normal(ks[1], (D, di), pd),
        b_proj=scale * jax.random.normal(ks[2], (D, N), pd),
        c_proj=scale * jax.random.normal(ks[3], (D, N), pd),
        dt_proj=scale * jax.random.normal(ks[4], (D, H), pd),
        conv_x=scale * jax.random.normal(ks[5], (K, di), pd),
        conv_b=scale * jax.random.normal(ks[6], (K, N), pd),
        conv_c=scale * jax.random.normal(ks[7], (K, N), pd),
        dt_bias=jnp.zeros((H,), pd),
        A_log=jnp.zeros((H,), pd),
        D=jnp.ones((H,), pd),
        norm_w=jnp.ones((di,), pd),
        out_proj=scale * jax.random.normal(ks[8], (di, D), pd),
    )


def _stack_init(per_layer_fn, cfg, key, n):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: per_layer_fn(cfg, k, 0.02))(keys)


def init_params(cfg: ArchConfig, key, model_shards: int = 16):
    ks = jax.random.split(key, 6)
    pd = cfg.param_dtype
    V = cfg.vocab_padded(model_shards)
    D = cfg.d_model
    p = dict(
        embed=0.02 * jax.random.normal(ks[0], (V, D), pd),
        final_norm=jnp.ones((D,), pd),
    )
    if not cfg.tie_embeddings:
        p["out_embed"] = 0.02 * jax.random.normal(ks[1], (V, D), pd)

    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        p["layers"] = _stack_init(_dense_layer_init, cfg, ks[2], cfg.L)
    elif fam == "ssm":
        p["layers"] = _stack_init(_ssm_layer_init, cfg, ks[2], cfg.L)
    elif fam == "hybrid":
        p["layers"] = _stack_init(_ssm_layer_init, cfg, ks[2], cfg.L)
        dense_cfg = dataclasses.replace(cfg, family="dense")
        p["shared_attn"] = _dense_layer_init(dense_cfg, ks[3], 0.02)
    elif fam == "encdec":
        p["enc"] = _stack_init(_dense_layer_init, cfg, ks[2], cfg.enc_layers)
        p["dec"] = _stack_init(_dense_layer_init, cfg, ks[3], cfg.L)
        # cross-attention stack for the decoder
        dec_x = _stack_init(_dense_layer_init, cfg, ks[4], cfg.L)
        keys = {"ln1", "wq", "wk", "wv", "wo"}
        if cfg.qkv_bias:
            keys |= {"bq", "bk", "bv"}
        if cfg.qk_norm:
            keys |= {"q_norm", "k_norm"}
        p["dec_cross"] = {k: dec_x[k] for k in keys}
        p["enc_norm"] = jnp.ones((D,), pd)
    else:
        raise ValueError(fam)
    return p


# --------------------------------------------------------------------------
# forward passes
# --------------------------------------------------------------------------


def _attn_sublayer(pl, x, cfg, *, causal, q_offset=0, window=0,
                   kv_cache=None, cache_pos=None, kv_override=None,
                   mesh_axes=None):
    """Attention residual sub-layer.

    Returns (x', info) with info["kv"] = this block's (roped) K/V — what a
    prefill writes to the cache — and info["cache"] = the updated full
    cache when one was passed in (decode).
    """
    xn = L.rms_norm(x, pl["ln1"], cfg.norm_eps)
    xn = L.gather_seq(xn, cfg, mesh_axes)
    q, k, v = L.qkv_proj(pl, xn, cfg)
    S = xn.shape[1]
    if (mesh_axes and cfg.n_heads_padded % mesh_axes["ntp"] != 0 and S > 1):
        # ring-attention layout: queries sequence-sharded over tp, K/V
        # replicated over tp (all-gathered) — used when the head count
        # (arctic: 56) does not divide the model axis
        dp, tp = mesh_axes["dp"], mesh_axes["tp"]
        q = jax.lax.with_sharding_constraint(q, P(dp, tp, None, None))
        k = jax.lax.with_sharding_constraint(k, P(dp, None, None, None))
        v = jax.lax.with_sharding_constraint(v, P(dp, None, None, None))
    if kv_override is not None:                      # cross-attention
        k, v = kv_override
    else:
        pos = q_offset + jnp.arange(S)
        q = L.rope(q, pos, cfg.rope_theta)
        k = L.rope(k, pos, cfg.rope_theta)
    info = {"kv": (k, v), "cache": None}
    if kv_cache is not None:
        ck, cv = kv_cache
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype), cache_pos, 1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype), cache_pos, 1)
        k, v = ck.astype(x.dtype), cv.astype(x.dtype)
        info["cache"] = (ck, cv)
    o = L.attention(q, k, v, q_offset=q_offset, causal=causal,
                    query_chunk=cfg.query_chunk, window=window)
    out = L.scatter_seq(L.attn_out(pl, o, x.dtype), cfg, mesh_axes)
    return x + out, info


def _ffn_sublayer(pl, x, cfg, mesh, mesh_axes, shard_seq=True):
    xn = L.rms_norm(x, pl["ln2"], cfg.norm_eps)
    if cfg.family == "moe" and cfg.n_experts:
        eid, gate = MOE.router(pl, xn, cfg)
        if mesh is None:    # smoke-test path: dense fallback semantics
            y = MOE.moe_dense_ref(pl, xn, eid, gate, cfg)
        elif cfg.moe_ep2d and shard_seq:
            y = MOE.moe_ffn_ep2d(pl, xn, eid, gate, cfg, mesh, mesh_axes,
                                 capacity_factor=cfg.moe_capacity)
        else:
            y = MOE.moe_ffn(pl, xn, eid, gate, cfg, mesh, mesh_axes,
                            capacity_factor=cfg.moe_capacity,
                            shard_seq=shard_seq)
        if cfg.moe_dense_ff:
            xg = L.gather_seq(xn, cfg, mesh_axes)
            y = y + L.scatter_seq(
                L.mlp(dict(w1=pl["w1d"], w3=pl["w3d"], w2=pl["w2d"]), xg),
                cfg, mesh_axes)
        return x + y
    xg = L.gather_seq(xn, cfg, mesh_axes)
    return x + L.scatter_seq(L.mlp(pl, x=xg), cfg, mesh_axes)


def _dense_block(pl, x, cfg, mesh, mesh_axes, *, causal=True, q_offset=0,
                 window=0, kv_cache=None, cache_pos=None, shard_seq=True):
    x, info = _attn_sublayer(pl, x, cfg, causal=causal, q_offset=q_offset,
                             window=window, kv_cache=kv_cache,
                             cache_pos=cache_pos, mesh_axes=mesh_axes)
    x = _ffn_sublayer(pl, x, cfg, mesh, mesh_axes, shard_seq=shard_seq)
    return x, info


def _scan_layers(body, x, stacked, cfg: ArchConfig, mesh_axes):
    """remat'd scan over a stacked layer dict; body(pl, x) → x.

    cfg.unroll_layers uses a python loop instead — identical math, used by
    the roofline extractor because XLA's cost_analysis does not multiply
    scan-body cost by trip count (DESIGN.md §6)."""

    def step(carry, pl):
        if cfg.fsdp:
            # pin the FSDP all-gather of this layer's weights inside the
            # loop body — without the barrier XLA hoists gather-of-slice
            # into slice-of-(gather-of-all-layers): +40 GiB/device at 405B.
            pl = _opt_barrier(pl)
        carry = _opt_barrier(carry)  # save carry @ bf16
        y = body(pl, carry)
        y = L.shard_acts(y, cfg, mesh_axes) if mesh_axes else y
        return y, None

    if cfg.remat:
        step = jax.checkpoint(step)
    if cfg.unroll_layers:
        n = jax.tree.leaves(stacked)[0].shape[0]
        for i in range(n):
            x, _ = step(x, jax.tree.map(lambda a: a[i], stacked))
        return x
    x, _ = jax.lax.scan(step, x, stacked)
    return x


def shard_vocab(x, mesh_axes):
    """Pin a [..., V] tensor to the table's vocab sharding — without this
    XLA may all-gather the 8 GiB table instead (measured at 405B)."""
    if mesh_axes and x.ndim >= 2:
        return jax.lax.with_sharding_constraint(
            x, P(*([mesh_axes["dp"]] + [None] * (x.ndim - 2) + [mesh_axes["tp"]])))
    return x


def embed_tokens(p, cfg, tokens, mesh_axes=None, one_hot=True):
    """Vocab-sharded lookup via one-hot matmul: the one-hot is sharded like
    the table's vocab dim, so the lookup is a local partial matmul + a
    [B,S,D] all-reduce — never a de-shard of the 8 GiB table."""
    if not one_hot:
        return p["embed"][tokens].astype(cfg.dtype)
    V = p["embed"].shape[0]
    oh = shard_vocab(jax.nn.one_hot(tokens, V, dtype=cfg.dtype), mesh_axes)
    return jnp.einsum("bsv,vd->bsd", oh, p["embed"].astype(cfg.dtype))


def out_embedding(p, cfg):
    return p["embed"] if cfg.tie_embeddings else p["out_embed"]


def forward(cfg: ArchConfig, p, batch, mesh=None, mesh_axes=None):
    """Token/embedding inputs → final hidden states [B, S, D] (normed)."""
    fam = cfg.family
    if fam in ("vlm",) or cfg.frontend == "embed_stub" and fam != "encdec":
        # stub frontend: precomputed patch/frame embeddings are prepended
        x = embed_tokens(p, cfg, batch["tokens"], mesh_axes)
        if "frontend_embeds" in batch:
            fe = batch["frontend_embeds"].astype(cfg.dtype)
            x = jnp.concatenate([fe, x], axis=1)
    elif fam == "encdec":
        return _forward_encdec(cfg, p, batch, mesh, mesh_axes)
    else:
        x = embed_tokens(p, cfg, batch["tokens"], mesh_axes)

    if fam in ("dense", "moe", "vlm"):
        body = lambda pl, h: _dense_block(pl, h, cfg, mesh, mesh_axes)[0]
        x = _scan_layers(body, x, p["layers"], cfg, mesh_axes)
    elif fam == "ssm":
        def body(pl, h):
            xn = L.gather_seq(L.rms_norm(h, pl["ln"], cfg.norm_eps),
                              cfg, mesh_axes)
            y = SSM.mamba_block(pl, xn, cfg)[0]
            return h + L.scatter_seq(y, cfg, mesh_axes)
        x = _scan_layers(body, x, p["layers"], cfg, mesh_axes)
    elif fam == "hybrid":
        x = _forward_hybrid(cfg, p, x, mesh, mesh_axes)
    return L.rms_norm(x, p["final_norm"], cfg.norm_eps)


def _hybrid_groups(cfg: ArchConfig):
    """[(start, size), ...] — shared attn block runs before each group."""
    k = cfg.attn_every
    out, s = [], 0
    while s < cfg.L:
        out.append((s, min(k, cfg.L - s)))
        s += k
    return out


def _forward_hybrid(cfg, p, x, mesh, mesh_axes, window=0):
    def body(pl, h):
        xn = L.gather_seq(L.rms_norm(h, pl["ln"], cfg.norm_eps),
                          cfg, mesh_axes)
        y = SSM.mamba_block(pl, xn, cfg)[0]
        return h + L.scatter_seq(y, cfg, mesh_axes)
    for (start, size) in _hybrid_groups(cfg):
        x, _ = _dense_block(p["shared_attn"], x, cfg, mesh, mesh_axes,
                            causal=True, window=window)
        stacked = jax.tree.map(lambda a: jax.lax.slice_in_dim(a, start, start + size),
                               p["layers"])
        x = _scan_layers(body, x, stacked, cfg, mesh_axes)
    return x


def _forward_encdec(cfg, p, batch, mesh, mesh_axes):
    # encoder: frontend embeddings in, bidirectional
    xe = batch["frontend_embeds"].astype(cfg.dtype)
    enc_body = lambda pl, h: _dense_block(pl, h, cfg, mesh, mesh_axes,
                                          causal=False)[0]
    xe = _scan_layers(enc_body, xe, p["enc"], cfg, mesh_axes)
    xe = L.rms_norm(xe, p["enc_norm"], cfg.norm_eps)

    # decoder: self-attn (causal) + cross-attn + mlp, scanned
    xd = embed_tokens(p, cfg, batch["tokens"], mesh_axes)

    def dec_body(pl_pair, h):
        pl, plx = pl_pair
        h, _info = _attn_sublayer(pl, h, cfg, causal=True,
                                  mesh_axes=mesh_axes)
        # cross-attention: KV from encoder output
        xn = L.rms_norm(h, plx["ln1"], cfg.norm_eps)
        q, _, _ = L.qkv_proj(plx, xn, cfg)
        k = jnp.einsum("bsd,dhk->bshk", xe, plx["wk"].astype(xe.dtype))
        v = jnp.einsum("bsd,dhk->bshk", xe, plx["wv"].astype(xe.dtype))
        o = L.attention(q, k, v, q_offset=0, causal=False,
                        query_chunk=cfg.query_chunk)
        h = h + L.attn_out(plx, o, h.dtype)
        return _ffn_sublayer(pl, h, cfg, mesh, mesh_axes)

    def step(carry, pls):
        y = dec_body(pls, carry)
        y = L.shard_acts(y, cfg, mesh_axes) if mesh_axes else y
        return y, None

    if cfg.remat:
        step = jax.checkpoint(step)
    if cfg.unroll_layers:
        for i in range(cfg.L):
            xd, _ = step(xd, jax.tree.map(lambda a: a[i],
                                          (p["dec"], p["dec_cross"])))
        return L.rms_norm(xd, p["final_norm"], cfg.norm_eps)
    xd, _ = jax.lax.scan(step, xd, (p["dec"], p["dec_cross"]))
    return L.rms_norm(xd, p["final_norm"], cfg.norm_eps)
