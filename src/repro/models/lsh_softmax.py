"""simLSH candidate selection for the LM softmax (the paper's technique
applied to the vocabulary — DESIGN.md §4).

The output-embedding table E [V, D] is the "item" side of an MF: simLSH
hashes its rows exactly like LSH-MF hashes item columns (random ±1
projections + sign, p·G-bit signatures, q bands).  A training step's
candidate set is the union of the label tokens' bucket-mates (the tokens
most confusable with the targets — the ones whose logits matter for the
normalizer) padded with frequency-sampled negatives.

Signatures refresh every `refresh_every` steps (embeddings drift slowly —
the same amortization the paper uses for its hash tables; the online
accumulator trick in core/simlsh makes the refresh incremental where only
a few rows changed).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import topk
from repro.core.simlsh import SimLSHConfig, pack_bits


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class LSHSoftmaxState:
    sigs: jax.Array       # [q, V] band signatures of embedding rows
    nbrs: jax.Array       # [V, K] bucket-mates per token
    step: jax.Array       # refresh bookkeeping


def hash_embeddings(E: jax.Array, cfg: SimLSHConfig, key) -> jax.Array:
    """Dense-row simLSH: sig[b, v] = pack(sign(E[v] @ Phi_b)).  [q, V]."""
    V, D = E.shape

    def one_band(band):
        kb = jax.random.fold_in(key, band)
        phi = jax.random.rademacher(kb, (D, cfg.sig_bits), jnp.float32)
        S = E.astype(jnp.float32) @ phi
        return pack_bits(S >= 0)

    return jax.lax.map(one_band, jnp.arange(cfg.q))


@partial(jax.jit, static_argnames=("K", "band_cap"))
def refresh(E, key, *, K: int = 8, band_cap: int = 8,
            q: int = 8, G: int = 8, p: int = 2) -> LSHSoftmaxState:
    cfg = SimLSHConfig(G=G, p=p, q=q, band_cap=band_cap)
    sigs = hash_embeddings(E, cfg, key)
    nbrs = topk.topk_from_signatures(sigs, key, K=K, band_cap=band_cap)
    return LSHSoftmaxState(sigs=sigs, nbrs=nbrs, step=jnp.zeros((), jnp.int32))


@partial(jax.jit, static_argnames=("n_cands",))
def candidates_for(state: LSHSoftmaxState, labels: jax.Array, key,
                   *, n_cands: int) -> jax.Array:
    """Union of the labels' bucket-mates, padded with random negatives.

    labels [B, S] → cands [n_cands] (shared across the batch — one gather
    of E rows per step, the same shape the dry-run lowers)."""
    V = state.nbrs.shape[0]
    lab = labels.reshape(-1)
    mates = state.nbrs[lab].reshape(-1)                 # [B·S·K]
    # dedupe-ish: sort then pick a strided sample to n_cands (cheap union)
    mates = jnp.sort(mates)
    take = min(n_cands // 2, mates.shape[0])
    idx = jnp.linspace(0, mates.shape[0] - 1, take).astype(jnp.int32)
    picked = mates[idx]
    rand = jax.random.randint(key, (n_cands - take,), 0, V, jnp.int32)
    return jnp.concatenate([picked, rand])
