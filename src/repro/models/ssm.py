"""Mamba2 — SSD (state-space duality) block, chunked scan formulation.

Faithful to Dao & Gu 2024 (arXiv:2405.21060) §6: within chunks of length Q
the recurrence is computed as a masked attention-like quadratic form; across
chunks a [H, P, N] state is carried by a (short) sequential scan.  This is
the TPU-friendly formulation: all heavy math is MXU einsums, the serial
dimension is S/Q.

Decode is the O(1) recurrence: S ← S·exp(dt·A) + dt·(B ⊗ x);  y = C·S + D·x.
That constant-size state is why the ssm/hybrid archs run the long_500k cell.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


def d_inner(cfg: ArchConfig) -> int:
    return cfg.ssm_expand * cfg.d_model


def n_heads(cfg: ArchConfig) -> int:
    return d_inner(cfg) // cfg.ssm_headdim


def _conv1d(x, w, state=None):
    """Depthwise causal conv. x [B,S,C], w [K,C]. state [B,K-1,C] for decode."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype)
              for i in range(K))
    new_state = xp[:, -(K - 1):]
    return out, new_state


def ssd_chunked(xs, dt, A, B, C, D, chunk: int):
    """SSD over a sequence.

    xs [B,S,H,P], dt [B,S,H] (post-softplus), A [H] (negative), B/C [B,S,N]
    (single group, broadcast over heads), D [H].  Returns y [B,S,H,P].
    """
    b, S, H, Pd = xs.shape
    N = B.shape[-1]
    Q = min(chunk, S)
    nc = S // Q
    assert nc * Q == S, "seq must divide the ssd chunk"
    f32 = jnp.float32

    xs_c = xs.reshape(b, nc, Q, H, Pd)
    dt_c = dt.reshape(b, nc, Q, H).astype(f32)
    B_c = B.reshape(b, nc, Q, N).astype(f32)
    C_c = C.reshape(b, nc, Q, N).astype(f32)

    dA = dt_c * A.astype(f32)[None, None, None, :]           # [b,nc,Q,H] (≤0)
    cum = jnp.cumsum(dA, axis=2)                             # within-chunk
    seg_end = jnp.exp(cum[:, :, -1:, :] - cum)               # decay t→chunk end
    chunk_decay = jnp.exp(cum[:, :, -1, :])                  # whole-chunk decay

    # ---- intra-chunk (quadratic, masked) --------------------------------
    # L[s,t] = exp(cum_s − cum_t) for s ≥ t
    Ldec = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])  # [b,nc,Q,Q,H]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    Ldec = jnp.where(mask[None, None, :, :, None], Ldec, 0.0)
    scores = jnp.einsum("bcsn,bctn->bcst", C_c, B_c)         # [b,nc,Q,Q]
    G = scores[..., None] * Ldec * dt_c[:, :, None, :, :]    # [b,nc,s,t,H]
    y_intra = jnp.einsum("bcsth,bcthp->bcshp", G, xs_c.astype(f32))

    # ---- chunk states + inter-chunk scan --------------------------------
    # state contribution of chunk c: Σ_t seg_end[t]·dt_t·(B_t ⊗ x_t)
    Sc = jnp.einsum("bcth,bctn,bcthp->bchpn",
                    seg_end * dt_c, B_c, xs_c.astype(f32))   # [b,nc,H,P,N]

    def scan_fn(carry, inp):
        Sc_c, decay_c = inp                                  # [b,H,P,N], [b,H]
        prev = carry
        new = prev * decay_c[:, :, None, None] + Sc_c
        return new, prev

    init = jnp.zeros((b, H, Pd, N), f32)
    _, S_prev = jax.lax.scan(
        scan_fn, init,
        (jnp.moveaxis(Sc, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    S_prev = jnp.moveaxis(S_prev, 0, 1)                      # [b,nc,H,P,N]

    # y_inter[s] = exp(cum_s) · C_s · S_prev
    in_decay = jnp.exp(cum)                                  # [b,nc,Q,H]
    y_inter = jnp.einsum("bcsn,bchpn,bcsh->bcshp", C_c, S_prev, in_decay)

    y = (y_intra + y_inter).reshape(b, S, H, Pd)
    y = y + xs.astype(f32) * D.astype(f32)[None, None, :, None]
    return y.astype(xs.dtype)


def ssd_decode(x1, dt1, A, B1, C1, D, state):
    """One-token recurrence.  x1 [B,H,P], dt1 [B,H], B1/C1 [B,N],
    state [B,H,P,N] (f32).  Returns (y [B,H,P], state')."""
    f32 = jnp.float32
    dA = jnp.exp(dt1.astype(f32) * A.astype(f32)[None, :])    # [B,H]
    upd = jnp.einsum("bh,bn,bhp->bhpn", dt1.astype(f32), B1.astype(f32),
                     x1.astype(f32))
    state = state * dA[:, :, None, None] + upd
    y = jnp.einsum("bn,bhpn->bhp", C1.astype(f32), state)
    y = y + x1.astype(f32) * D.astype(f32)[None, :, None]
    return y.astype(x1.dtype), state


def mamba_block(p, x, cfg: ArchConfig, *, chunk: int = 256, state=None,
                conv_state=None):
    """Full Mamba2 block.  Train/prefill: state=None, returns (y, None).
    Decode: x [B,1,D] with (state, conv_state) carried.

    The fused mamba2 in_proj is split into per-output projections (z, x, B,
    C, dt) — column-block identical to the fused matmul, but each output
    gets a clean TP sharding (z/x/dt head-sharded, B/C replicated).  The
    depthwise conv splits the same way exactly.
    """
    di, N, H = d_inner(cfg), cfg.ssm_state, n_heads(cfg)
    w = lambda name: p[name].astype(x.dtype)
    z = jnp.einsum("bsd,de->bse", x, w("z_proj"))
    xs = jnp.einsum("bsd,de->bse", x, w("x_proj"))
    B_ = jnp.einsum("bsd,dn->bsn", x, w("b_proj"))
    C_ = jnp.einsum("bsd,dn->bsn", x, w("c_proj"))
    dt = jnp.einsum("bsd,dh->bsh", x, w("dt_proj"))

    cs = conv_state if conv_state is not None else (None, None, None)
    xs, ncx = _conv1d(xs, p["conv_x"], cs[0])
    B_, ncb = _conv1d(B_, p["conv_b"], cs[1])
    C_, ncc = _conv1d(C_, p["conv_c"], cs[2])
    new_conv = (ncx, ncb, ncc)
    silu = lambda t: jax.nn.silu(t.astype(jnp.float32)).astype(x.dtype)
    xs, B_, C_ = silu(xs), silu(B_), silu(C_)

    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    bsz, S, _ = x.shape
    xs_h = xs.reshape(bsz, S, H, cfg.ssm_headdim)
    if state is None:
        y = ssd_chunked(xs_h, dt, A, B_, C_, p["D"], chunk)
        new_state = None
    else:
        y1, new_state = ssd_decode(xs_h[:, 0], dt[:, 0], A, B_[:, 0],
                                   C_[:, 0], p["D"], state)
        y = y1[:, None]

    y = y.reshape(bsz, S, di)
    # gated RMSNorm (mamba2's norm-then-gate)
    y = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    yn = y * jax.lax.rsqrt(jnp.mean(y * y, axis=-1, keepdims=True) + cfg.norm_eps)
    y = (yn * p["norm_w"].astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(x.dtype))
    return out, (new_state, new_conv)
