"""train_step / prefill / decode builders + Adam — what dryrun/train lower.

All steps are pure functions over (params, opt/caches, batch); builders
close over (cfg, mesh, mesh_axes) and return functions suitable for
``jax.jit(..., in_shardings=..., out_shardings=...)``.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import lm
from repro.models import moe as MOE
from repro.models import sharding as SH
from repro.models import ssm as SSM

# --------------------------------------------------------------------------
# loss
# --------------------------------------------------------------------------


def lm_loss(cfg: ArchConfig, p, batch, mesh=None, mesh_axes=None):
    h = lm.forward(cfg, p, batch, mesh, mesh_axes)          # [B, S_all, D]
    S_txt = batch["labels"].shape[1]
    if h.shape[1] != S_txt:                                  # frontend prefix
        h = h[:, h.shape[1] - S_txt:]
    E = lm.out_embedding(p, cfg)
    labels = batch["labels"]
    mask = batch.get("mask", jnp.ones(labels.shape, jnp.float32))

    if cfg.lsh_softmax and "cands" in batch:
        # paper-technique softmax: loss over {label} ∪ simLSH candidates
        Ec = E[batch["cands"]].astype(cfg.dtype)             # [C, D]
        logits_c = jnp.einsum("bsd,cd->bsc", h, Ec,
                              preferred_element_type=jnp.float32)
        e_lab = E[labels].astype(cfg.dtype)                  # [B, S, D]
        logit_lab = jnp.einsum("bsd,bsd->bs", h, e_lab,
                               preferred_element_type=jnp.float32)
        # exclude accidental label hits among candidates
        hit = (batch["cands"][None, None, :] == labels[..., None])
        logits_c = jnp.where(hit, -1e30, logits_c)
        lse = jnp.logaddexp(jax.nn.logsumexp(logits_c, -1), logit_lab)
        nll = lse - logit_lab
    else:
        logits = lm.shard_vocab(
            jnp.einsum("bsd,vd->bsv", h, E.astype(cfg.dtype),
                       preferred_element_type=jnp.float32), mesh_axes)
        lse = jax.nn.logsumexp(logits, axis=-1)
        # label logit via masked reduction — vocab stays sharded (no gather)
        V = logits.shape[-1]
        oh = lm.shard_vocab(jax.nn.one_hot(labels, V, dtype=logits.dtype),
                            mesh_axes)
        logit_lab = jnp.sum(logits * oh, axis=-1)
        nll = lse - logit_lab

    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# --------------------------------------------------------------------------
# Adam (moments in cfg.moment_dtype — bf16 = optimizer-state compression)
# --------------------------------------------------------------------------


def init_opt(cfg: ArchConfig, params):
    md = cfg.moment_dtype
    zeros = lambda x: jnp.zeros(x.shape, md)
    return dict(m=jax.tree.map(zeros, params),
                v=jax.tree.map(zeros, params),
                count=jnp.zeros((), jnp.int32))


def adam_update(cfg: ArchConfig, params, grads, opt, *, lr=3e-4, b1=0.9,
                b2=0.95, eps=1e-8, wd=0.0, clip=1.0):
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, clip / jnp.maximum(gnorm, 1e-12))
    count = opt["count"] + 1
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(p_, g_, m_, v_):
        g32 = g_.astype(jnp.float32) * scale
        m32 = b1 * m_.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v_.astype(jnp.float32) + (1 - b2) * g32 * g32
        step = (m32 / c1) / (jnp.sqrt(v32 / c2) + eps)
        p32 = p_.astype(jnp.float32) * (1 - lr * wd) - lr * step
        return (p32.astype(p_.dtype), m32.astype(m_.dtype),
                v32.astype(v_.dtype))

    out = jax.tree.map(upd, params, grads, opt["m"], opt["v"])
    params2 = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    m2 = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    v2 = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return params2, dict(m=m2, v=v2, count=count), gnorm


# --------------------------------------------------------------------------
# train step (microbatched gradient accumulation)
# --------------------------------------------------------------------------


def make_train_step(cfg: ArchConfig, mesh=None, mesh_axes=None, lr=3e-4):
    nmicro = max(1, cfg.microbatches)

    def loss_fn(params, mb):
        return lm_loss(cfg, params, mb, mesh, mesh_axes)

    def pin_grads(params, g):
        if mesh_axes is None:
            return g
        specs = SH.param_specs(cfg, params, mesh_axes)
        return jax.tree.map(jax.lax.with_sharding_constraint, g, specs)

    def train_step(params, opt, batch):
        if nmicro == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            grads = pin_grads(params, grads)
        else:
            # straggler mitigation (bounded staleness): "mb_mask" [µ] zeroes
            # late microbatches; gradients renormalize over the survivors
            batch = dict(batch)
            mb_mask = batch.pop("mb_mask", None)
            if mb_mask is None:
                mb_mask = jnp.ones((nmicro,), jnp.float32)

            def split(x):
                return x.reshape(nmicro, x.shape[0] // nmicro, *x.shape[1:])

            mbs = {k: split(v) for k, v in batch.items()
                   if v.ndim > 0 and v.shape[0] >= nmicro
                   and v.shape[0] % nmicro == 0}
            rest = {k: v for k, v in batch.items() if k not in mbs}
            gd = cfg.grad_dtype
            zeros = jax.tree.map(
                lambda x: jnp.zeros(x.shape, gd), params)

            def body(carry, inp):
                mb, w = inp
                g_acc, l_acc = carry
                loss, g = jax.value_and_grad(loss_fn)(params, mb | rest)
                g = pin_grads(params, g)
                g_acc = jax.tree.map(
                    lambda a, b: a + (w * b).astype(a.dtype), g_acc, g)
                return (g_acc, l_acc + w * loss), None

            (grads, loss), _ = jax.lax.scan(body, (zeros, 0.0),
                                            (mbs, mb_mask))
            denom = jnp.maximum(jnp.sum(mb_mask), 1.0)
            grads = jax.tree.map(lambda g: g / denom, grads)
            loss = loss / denom
        params, opt, gnorm = adam_update(cfg, params, grads, opt, lr=lr)
        return params, opt, dict(loss=loss, gnorm=gnorm)

    return train_step


# --------------------------------------------------------------------------
# serving: prefill + decode with caches
# --------------------------------------------------------------------------


def init_cache(cfg: ArchConfig, B: int, T: int, dtype=jnp.bfloat16):
    """Empty caches sized for total context T (what decode cells lower)."""
    fam = cfg.family
    cache = {"pos": jnp.zeros((), jnp.int32)}
    if fam in ("dense", "moe", "vlm"):
        hd = cfg.hd
        cache["k"] = jnp.zeros((cfg.L, B, T, cfg.n_kv, hd), dtype)
        cache["v"] = jnp.zeros((cfg.L, B, T, cfg.n_kv, hd), dtype)
    elif fam in ("ssm", "hybrid"):
        H, Pd, N = SSM.n_heads(cfg), cfg.ssm_headdim, cfg.ssm_state
        K, di = cfg.ssm_conv, SSM.d_inner(cfg)
        cache["ssm"] = jnp.zeros((cfg.L, B, H, Pd, N), jnp.float32)
        cache["conv_x"] = jnp.zeros((cfg.L, B, K - 1, di), dtype)
        cache["conv_b"] = jnp.zeros((cfg.L, B, K - 1, N), dtype)
        cache["conv_c"] = jnp.zeros((cfg.L, B, K - 1, N), dtype)
        if fam == "hybrid":
            napp = len(lm._hybrid_groups(cfg))
            Tw = min(T, _hybrid_window(cfg, T) or T)
            hd = cfg.hd
            cache["k"] = jnp.zeros((napp, B, Tw, cfg.n_kv, hd), dtype)
            cache["v"] = jnp.zeros((napp, B, Tw, cfg.n_kv, hd), dtype)
    elif fam == "encdec":
        hd = cfg.hd
        cache["k"] = jnp.zeros((cfg.L, B, T, cfg.n_kv, hd), dtype)
        cache["v"] = jnp.zeros((cfg.L, B, T, cfg.n_kv, hd), dtype)
        cache["cross_k"] = jnp.zeros((cfg.L, B, T, cfg.n_kv, hd), dtype)
        cache["cross_v"] = jnp.zeros((cfg.L, B, T, cfg.n_kv, hd), dtype)
    return cache


def _hybrid_window(cfg: ArchConfig, T: int):
    """Windowed attention for the shared blocks at extreme context
    (long_500k) — the documented sub-quadratic adaptation."""
    return 8192 if T >= 100_000 else 0



def _scan_or_unroll(body, carry, xs, unroll: bool):
    """scan unless `unroll` (exact cost_analysis; see lm._scan_layers)."""
    if not unroll:
        return jax.lax.scan(body, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        carry, y = body(carry, jax.tree.map(lambda a: a[i], xs))
        ys.append(y)
    stack = jax.tree.map(lambda *ls: jnp.stack(ls), *ys)
    return carry, stack


def make_decode_step(cfg: ArchConfig, mesh=None, mesh_axes=None):
    fam = cfg.family

    def logits_of(p, h):
        E = lm.out_embedding(p, cfg)
        return lm.shard_vocab(
            jnp.einsum("bsd,vd->bsv", h, E.astype(cfg.dtype),
                       preferred_element_type=jnp.float32), mesh_axes)

    def decode_dense(params, cache, tokens):
        x = lm.embed_tokens(params, cfg, tokens, mesh_axes)             # [B,1,D]
        pos = cache["pos"]

        def body(carry, xs):
            h = carry
            pl, ck, cv = xs
            h, info = lm._attn_sublayer(
                pl, h, cfg, causal=True, q_offset=pos,
                kv_cache=(ck, cv), cache_pos=pos)
            h = lm._ffn_sublayer(pl, h, cfg, mesh, mesh_axes, shard_seq=False)
            return h, info["cache"]

        h, (k2, v2) = _scan_or_unroll(
            body, x, (params["layers"], cache["k"], cache["v"]),
            cfg.unroll_layers)
        h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
        cache2 = cache | {"k": k2, "v": v2, "pos": pos + 1}
        return logits_of(params, h), cache2

    def decode_ssm_layer(pl, h, cfg, st, cx, cb, cc):
        xn = L.rms_norm(h, pl["ln"], cfg.norm_eps)
        y, (new_state, new_conv) = SSM.mamba_block(
            pl, xn, cfg, state=st, conv_state=(cx, cb, cc))
        return h + y, (new_state, *new_conv)

    def decode_ssm(params, cache, tokens):
        x = lm.embed_tokens(params, cfg, tokens, mesh_axes)

        def body(carry, xs):
            h = carry
            pl, st, cx, cb, cc = xs
            h, new = decode_ssm_layer(pl, h, cfg, st, cx, cb, cc)
            return h, new

        h, (st2, cx2, cb2, cc2) = _scan_or_unroll(
            body, x, (params["layers"], cache["ssm"], cache["conv_x"],
                      cache["conv_b"], cache["conv_c"]), cfg.unroll_layers)
        h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
        cache2 = cache | {"ssm": st2, "conv_x": cx2, "conv_b": cb2,
                          "conv_c": cc2, "pos": cache["pos"] + 1}
        return logits_of(params, h), cache2

    def decode_hybrid(params, cache, tokens):
        x = lm.embed_tokens(params, cfg, tokens, mesh_axes)
        pos = cache["pos"]
        Tw = cache["k"].shape[2]
        win = _hybrid_window(cfg, Tw) or 0
        groups = lm._hybrid_groups(cfg)
        h = x
        new_k, new_v = [], []
        new_ssm = [None] * cfg.L
        new_cx, new_cb, new_cc = ([None] * cfg.L for _ in range(3))
        for gi, (start, size) in enumerate(groups):
            # shared attention block with ring-buffer window cache
            wpos = jnp.mod(pos, Tw)
            h, info = lm._attn_sublayer(
                params["shared_attn"], h, cfg, causal=True, q_offset=pos,
                kv_cache=(cache["k"][gi], cache["v"][gi]), cache_pos=wpos)
            kv = info["cache"]
            h = lm._ffn_sublayer(params["shared_attn"], h, cfg, mesh,
                                 mesh_axes, shard_seq=False)
            new_k.append(kv[0])
            new_v.append(kv[1])
            for li in range(start, start + size):
                pl = jax.tree.map(lambda a: a[li], params["layers"])
                h, new = decode_ssm_layer(
                    pl, h, cfg, cache["ssm"][li], cache["conv_x"][li],
                    cache["conv_b"][li], cache["conv_c"][li])
                new_ssm[li], new_cx[li], new_cb[li], new_cc[li] = new
        h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
        cache2 = cache | {
            "k": jnp.stack(new_k), "v": jnp.stack(new_v),
            "ssm": jnp.stack(new_ssm), "conv_x": jnp.stack(new_cx),
            "conv_b": jnp.stack(new_cb), "conv_c": jnp.stack(new_cc),
            "pos": pos + 1}
        return logits_of(params, h), cache2

    def decode_encdec(params, cache, tokens):
        x = lm.embed_tokens(params, cfg, tokens, mesh_axes)
        pos = cache["pos"]

        def body(carry, xs):
            h = carry
            pl, plx, ck, cv, xk, xv = xs
            h, info = lm._attn_sublayer(
                pl, h, cfg, causal=True, q_offset=pos,
                kv_cache=(ck, cv), cache_pos=pos)
            new_kv = info["cache"]
            # cross-attention against precomputed encoder KV
            xn = L.rms_norm(h, plx["ln1"], cfg.norm_eps)
            q, _, _ = L.qkv_proj(plx, xn, cfg)
            o = L.attention(q, xk.astype(h.dtype), xv.astype(h.dtype),
                            q_offset=0, causal=False,
                            query_chunk=cfg.query_chunk)
            h = h + L.attn_out(plx, o, h.dtype)
            h = lm._ffn_sublayer(pl, h, cfg, mesh, mesh_axes, shard_seq=False)
            return h, new_kv

        h, (k2, v2) = _scan_or_unroll(
            body, x, (params["dec"], params["dec_cross"], cache["k"],
                      cache["v"], cache["cross_k"], cache["cross_v"]),
            cfg.unroll_layers)
        h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
        cache2 = cache | {"k": k2, "v": v2, "pos": pos + 1}
        return logits_of(params, h), cache2

    return {"dense": decode_dense, "moe": decode_dense, "vlm": decode_dense,
            "ssm": decode_ssm, "hybrid": decode_hybrid,
            "encdec": decode_encdec}[fam]


def make_prefill(cfg: ArchConfig, mesh=None, mesh_axes=None):
    """Forward over the prompt; returns (last-token logits, filled cache).

    For the prefill_32k dry-run cell the interesting artifact is the full
    forward at S=32k with cache writes; decode cells consume init_cache-
    shaped inputs directly.
    """
    fam = cfg.family

    def prefill_dense(params, batch):
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = lm.embed_tokens(params, cfg, tokens, mesh_axes)
        if "frontend_embeds" in batch:
            fe = batch["frontend_embeds"].astype(cfg.dtype)
            x = jnp.concatenate([fe, x], axis=1)
        T = x.shape[1]

        def body(carry, pl):
            h = carry
            h, info = lm._attn_sublayer(pl, h, cfg, causal=True)
            k, v = info["kv"]
            h = lm._ffn_sublayer(pl, h, cfg, mesh, mesh_axes)
            h = L.shard_acts(h, cfg, mesh_axes) if mesh_axes else h
            return h, (k.astype(jnp.bfloat16), v.astype(jnp.bfloat16))

        h, (ks, vs) = _scan_or_unroll(body, x, params["layers"],
                                      cfg.unroll_layers)
        h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
        E = lm.out_embedding(params, cfg)
        logits = jnp.einsum("bd,vd->bv", h[:, -1], E.astype(cfg.dtype),
                            preferred_element_type=jnp.float32)
        cache = {"k": ks, "v": vs, "pos": jnp.asarray(T, jnp.int32)}
        return logits, cache

    def prefill_generic(params, batch):
        # ssm/hybrid/encdec prefill: run forward; caches via decode-shaped
        # recomputation are family-specific; the dry-run artifact is the
        # forward itself.
        h = lm.forward(cfg, params, batch, mesh, mesh_axes)
        E = lm.out_embedding(params, cfg)
        logits = jnp.einsum("bd,vd->bv", h[:, -1], E.astype(cfg.dtype),
                            preferred_element_type=jnp.float32)
        return logits, {"pos": jnp.asarray(batch["tokens"].shape[1], jnp.int32)}

    if fam in ("dense", "moe", "vlm"):
        return prefill_dense
    return prefill_generic
