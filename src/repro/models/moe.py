"""Expert-parallel MoE with explicit all-to-all dispatch (shard_map).

Production pattern (DESIGN.md §5): experts are sharded over the "model"
axis (EP).  Two dispatch paths:

* ``shard_seq=True`` (train/prefill): tokens are sharded over data axes AND
  split along "model" (sequence split) for routing, then exchanged with two
  `all_to_all`s:   route → a2a(dispatch) → grouped expert FFN (local
  experts) → a2a(return) → weighted combine.
* ``shard_seq=False`` (decode, S=1): tokens are replicated over "model";
  each device computes only its own experts' contributions and a `psum`
  over "model" combines — the standard small-batch decode path (no a2a).

Fixed capacities keep every shape static: per-destination-device send slots
``C_send`` and per-local-expert slots ``C_exp``; overflow tokens are dropped
(capacity-factor semantics, gradient-safe).

Router logits/top-k run at pjit level (replicated math, so router-weight
gradients are correct without manual psums); the shard_map region only
touches expert weights (sharded on "model", per-shard local grads, with
`check_vma` inserting the data-axis psum on the backward pass).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.launch.mesh import shard_map


def router(p, x, cfg: ArchConfig):
    """x [B,S,D] → (eid [B,S,k] int32, gate [B,S,k] f32). pjit-level."""
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    top, eid = jax.lax.top_k(logits, cfg.moe_top_k)
    gate = jax.nn.softmax(top, axis=-1)
    return eid.astype(jnp.int32), gate


def _expert_ffn(w1, w3, w2, xb):
    """xb [E_loc, C, D] through the local experts."""
    g = jnp.einsum("ecd,edf->ecf", xb, w1.astype(xb.dtype))
    u = jnp.einsum("ecd,edf->ecf", xb, w3.astype(xb.dtype))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(xb.dtype) * u
    return jnp.einsum("ecf,efd->ecd", h, w2.astype(xb.dtype))


def _group_and_ffn(recv_x, recv_e, E_loc, C_exp, w1, w3, w2):
    """Group slots by local expert id (−1 = invalid), run the FFN, return
    outputs aligned with the incoming slot order (zeros for dropped)."""
    R, D = recv_x.shape
    order = jnp.argsort(recv_e)                    # −1s first
    se = recv_e[order]
    first = jnp.searchsorted(se, jnp.arange(E_loc, dtype=se.dtype))
    rank = jnp.arange(R) - first[jnp.clip(se, 0, E_loc - 1)]
    ok = (se >= 0) & (rank < C_exp)
    addr = jnp.where(ok, se * C_exp + rank, E_loc * C_exp)

    buf = jnp.zeros((E_loc * C_exp + 1, D), recv_x.dtype)
    buf = buf.at[addr].set(recv_x[order])[: E_loc * C_exp]
    yb = _expert_ffn(w1, w3, w2, buf.reshape(E_loc, C_exp, D))
    yb = yb.reshape(E_loc * C_exp, D)

    back = jnp.zeros((R, D), recv_x.dtype)
    got = jnp.where(ok, addr, 0)
    back = back.at[order].set(jnp.where(ok[:, None], yb[got], 0.0))
    return back


def moe_dense_ref(p, x, eid, gate, cfg: ArchConfig):
    """Reference semantics (single device / tests): every token through its
    top-k experts via gather — exact, no capacity drops."""
    B, S, D = x.shape
    xt = x.reshape(-1, D)
    eidf = eid.reshape(-1, cfg.moe_top_k)
    gatef = gate.reshape(-1, cfg.moe_top_k).astype(x.dtype)

    def per_slot(kk):
        w1 = p["w1"][eidf[:, kk]].astype(x.dtype)     # [T, D, ff]
        w3 = p["w3"][eidf[:, kk]].astype(x.dtype)
        w2 = p["w2"][eidf[:, kk]].astype(x.dtype)
        g = jnp.einsum("td,tdf->tf", xt, w1)
        u = jnp.einsum("td,tdf->tf", xt, w3)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
        return jnp.einsum("tf,tfd->td", h, w2) * gatef[:, kk][:, None]

    out = sum(per_slot(kk) for kk in range(cfg.moe_top_k))
    return out.reshape(B, S, D)


def moe_ffn(p, x, eid, gate, cfg: ArchConfig, mesh, mesh_axes,
            capacity_factor: float = 2.0, shard_seq: bool = True):
    """1D EP: experts sharded over tp; FSDP (if on) gathers weights."""
    tp = mesh_axes["tp"]
    dp = mesh_axes["dp"]
    ntp = mesh.shape[tp]
    E = cfg.n_experts
    assert E % ntp == 0, "experts must divide the model axis"
    E_loc = E // ntp
    k = cfg.moe_top_k

    def _flat(x, eid, gate):
        b, s_loc, D = x.shape
        T = b * s_loc
        xt = x.reshape(T, D)
        slot_tok = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
        slot_eid = eid.reshape(T * k)
        slot_gate = gate.reshape(T * k).astype(x.dtype)
        return xt, slot_tok, slot_eid, slot_gate, T, D

    def local_a2a(x, eid, gate, w1, w3, w2):
        xt, slot_tok, slot_eid, slot_gate, T, D = _flat(x, eid, gate)
        S = T * k
        dst = slot_eid // E_loc
        C_send = max(1, int(round(S / ntp * capacity_factor)))

        order = jnp.argsort(dst)
        sdst = dst[order]
        first = jnp.searchsorted(sdst, jnp.arange(ntp, dtype=sdst.dtype))
        rank = jnp.arange(S) - first[sdst]
        keep = rank < C_send
        addr = jnp.where(keep, sdst * C_send + rank, ntp * C_send)

        send_x = jnp.zeros((ntp * C_send + 1, D), x.dtype)
        send_e = jnp.full((ntp * C_send + 1,), -1, jnp.int32)
        send_src = jnp.zeros((ntp * C_send + 1,), jnp.int32)
        send_x = send_x.at[addr].set(xt[slot_tok[order]])[: ntp * C_send]
        send_e = send_e.at[addr].set(slot_eid[order] % E_loc)[: ntp * C_send]
        send_src = send_src.at[addr].set(order)[: ntp * C_send]

        recv_x = jax.lax.all_to_all(send_x.reshape(ntp, C_send, D), tp, 0, 0
                                    ).reshape(ntp * C_send, D)
        recv_e = jax.lax.all_to_all(send_e.reshape(ntp, C_send), tp, 0, 0
                                    ).reshape(ntp * C_send)

        R = ntp * C_send
        C_exp = max(1, int(round(R / max(E_loc, 1) * capacity_factor)))
        back = _group_and_ffn(recv_x, recv_e, E_loc, C_exp, w1, w3, w2)

        ret = jax.lax.all_to_all(back.reshape(ntp, C_send, D), tp, 0, 0
                                 ).reshape(ntp * C_send, D)

        # ret[a] is the processed token for the slot placed at address a
        out = jnp.zeros((T, D), x.dtype)
        valid = (send_e >= 0).astype(x.dtype)
        contrib = ret * (slot_gate[send_src] * valid)[:, None]
        out = out.at[slot_tok[send_src]].add(contrib)
        return out.reshape(x.shape)

    def local_rep(x, eid, gate, w1, w3, w2):
        # tokens replicated over tp: compute only my experts, psum combine
        xt, slot_tok, slot_eid, slot_gate, T, D = _flat(x, eid, gate)
        my = jax.lax.axis_index(tp)
        e_loc = slot_eid - my * E_loc
        mine = (e_loc >= 0) & (e_loc < E_loc)
        recv_e = jnp.where(mine, e_loc, -1)
        C_exp = max(1, int(round(T * k / max(E_loc, 1) * capacity_factor)))
        back = _group_and_ffn(xt[slot_tok], recv_e, E_loc, C_exp, w1, w3, w2)
        out = jnp.zeros((T, D), x.dtype)
        out = out.at[slot_tok].add(back * slot_gate[:, None])
        return jax.lax.psum(out.reshape(x.shape), tp)

    seq_axis = tp if shard_seq else None
    spec_x = P(dp, seq_axis, None)
    spec_w = P(tp, None, None)
    fn = shard_map(
        local_a2a if shard_seq else local_rep, mesh=mesh,
        in_specs=(spec_x, spec_x, spec_x, spec_w, spec_w, spec_w),
        out_specs=spec_x)
    return fn(x, eid, gate, p["w1"], p["w3"], p["w2"])


def moe_ffn_ep2d(p, x, eid, gate, cfg: ArchConfig, mesh, mesh_axes,
                 capacity_factor: float = 2.0):
    """EP-over-data (beyond-paper optimization, §Perf): experts sharded over
    the *data* axes, replicated over tp.

    The FSDP weight all-gathers that dominate 1D-EP prefill (measured 73% of
    collective bytes at arctic-480b) disappear entirely: per-chip expert
    weights are E/|dp| experts (arctic: 8 → 1.6 GiB bf16, resident), and the
    only MoE collective is a token all-to-all over the data axes whose
    payload is activations (hundreds of MB), not weights (tens of GB).
    Tokens on mesh cell (d, m) route to expert-owner row r = e // E_per_row
    at cell (r, m); the gate-weighted combine returns over the same path.
    """
    tp = mesh_axes["tp"]
    dp = mesh_axes["dp"]
    ndp = mesh_axes["ndp"]
    E = cfg.n_experts
    assert E % ndp == 0, "experts must divide the data axes for 2D EP"
    E_loc = E // ndp
    k = cfg.moe_top_k

    def local(x, eid, gate, w1, w3, w2):
        b, s_loc, D = x.shape
        T = b * s_loc
        xt = x.reshape(T, D)
        slot_tok = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
        slot_eid = eid.reshape(T * k)
        slot_gate = gate.reshape(T * k).astype(x.dtype)
        S = T * k
        dst = slot_eid // E_loc                        # destination dp row
        C_send = max(1, int(round(S / ndp * capacity_factor)))

        order = jnp.argsort(dst)
        sdst = dst[order]
        first = jnp.searchsorted(sdst, jnp.arange(ndp, dtype=sdst.dtype))
        rank = jnp.arange(S) - first[sdst]
        keep = rank < C_send
        addr = jnp.where(keep, sdst * C_send + rank, ndp * C_send)

        send_x = jnp.zeros((ndp * C_send + 1, D), x.dtype)
        send_e = jnp.full((ndp * C_send + 1,), -1, jnp.int32)
        send_src = jnp.zeros((ndp * C_send + 1,), jnp.int32)
        send_x = send_x.at[addr].set(xt[slot_tok[order]])[: ndp * C_send]
        send_e = send_e.at[addr].set(slot_eid[order] % E_loc)[: ndp * C_send]
        send_src = send_src.at[addr].set(order)[: ndp * C_send]

        recv_x = jax.lax.all_to_all(send_x.reshape(ndp, C_send, D), dp, 0, 0
                                    ).reshape(ndp * C_send, D)
        recv_e = jax.lax.all_to_all(send_e.reshape(ndp, C_send), dp, 0, 0
                                    ).reshape(ndp * C_send)

        R = ndp * C_send
        C_exp = max(1, int(round(R / max(E_loc, 1) * capacity_factor)))
        back = _group_and_ffn(recv_x, recv_e, E_loc, C_exp, w1, w3, w2)

        ret = jax.lax.all_to_all(back.reshape(ndp, C_send, D), dp, 0, 0
                                 ).reshape(ndp * C_send, D)
        out = jnp.zeros((T, D), x.dtype)
        valid = (send_e >= 0).astype(x.dtype)
        contrib = ret * (slot_gate[send_src] * valid)[:, None]
        out = out.at[slot_tok[send_src]].add(contrib)
        return out.reshape(x.shape)

    spec_x = P(dp, tp, None)
    spec_w = P(dp, None, None)   # experts over dp, replicated over tp
    fn = shard_map(
        local, mesh=mesh,
        in_specs=(spec_x, spec_x, spec_x, spec_w, spec_w, spec_w),
        out_specs=spec_x)
    return fn(x, eid, gate, p["w1"], p["w3"], p["w2"])
