"""Sharded, async, mesh-elastic checkpointing (fault-tolerance substrate).

Design for the 1000+-node posture (DESIGN.md §5):
  * every host writes only its addressable shards → one ``.npz`` per host
    plus a tiny JSON manifest (step, pytree structure, global shapes);
  * saves run on a background thread (overlap with the next step's compute);
    ``wait()`` joins before the next save or at exit;
  * ``restore`` takes the *current* mesh/sharding: a checkpoint written on
    a 512-chip mesh restores onto 256 or 1024 chips (elastic restart after
    node loss) because shards are reassembled from the global array view;
  * atomic rename (tmp dir → step dir) so a crash mid-save never corrupts
    the latest complete checkpoint.

Crash-atomicity (ISSUE 7): every file inside the staging dir is written
to a temp name and ``os.replace``d (so even the staging dir never holds
a torn file), the manifest is written **last** (its presence certifies
the step), and the staging→final directory rename is the commit point.
The read side treats the manifest as the completeness marker:
``latest_step``/``restore``/``try_restore`` *skip* torn or partial step
dirs (no manifest, unreadable manifest, missing/unloadable shard)
instead of raising, falling back to the newest complete step — a crash
mid-save can delay recovery by one checkpoint, never corrupt it.

On this single-process container "per host" degenerates to one file, but the
code paths (manifest, atomic rename, reshard-on-restore, async) are the real
ones and are exercised by tests/test_resil.py including a simulated
kill-and-restart, a torn-directory recovery, and injected save crashes.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zipfile

import jax
import jax.numpy as jnp
import numpy as np

_save_thread: threading.Thread | None = None


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _replace_write(path: str, write_fn) -> None:
    """Write via temp file + fsync + ``os.replace`` so ``path`` either
    doesn't exist or is complete — never torn."""
    tmp = f"{path}.tmp-{os.getpid()}"
    with open(tmp, "wb") as f:
        write_fn(f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def wait():
    global _save_thread
    if _save_thread is not None:
        _save_thread.join()
        _save_thread = None


def save(directory: str, tree, *, step: int, sync: bool = False):
    """Async sharded save of an arbitrary pytree of jax/np arrays."""
    wait()
    leaves, treedef = _flatten(tree)
    # materialize host-local views before handing off to the thread
    host_leaves = [np.asarray(x) for x in leaves]

    def _write():
        from repro.resil import faults   # lazy: no import cycle via resil.wal
        tmp = os.path.join(directory, f".tmp-{step}")
        final = os.path.join(directory, f"step-{step:08d}")
        os.makedirs(tmp, exist_ok=True)
        _replace_write(os.path.join(tmp, f"shard-{jax.process_index()}.npz"),
                       lambda f: np.savez(f, **{f"a{i}": a for i, a in
                                                enumerate(host_leaves)}))
        # injected-crash window: shard written, manifest not — readers must
        # treat the resulting dir (if it ever escaped) as torn
        faults.fire("ckpt.save")
        # manifest last: its presence certifies every shard landed
        _replace_write(
            os.path.join(tmp, "manifest.json"),
            lambda f: f.write(json.dumps(
                {"step": step, "nleaves": len(host_leaves)}).encode()))
        if os.path.isdir(final):
            shutil.rmtree(final)
        os.rename(tmp, final)            # the commit point
        _prune(directory, keep=3)

    global _save_thread
    t = threading.Thread(target=_write, daemon=True)
    t.start()
    _save_thread = t
    if sync:
        wait()


def _prune(directory: str, keep: int):
    steps = sorted(d for d in os.listdir(directory) if d.startswith("step-"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)
    # saves are serialized (save() joins the previous writer), so any
    # remaining staging dir is a crash remnant — our own was just renamed
    for d in os.listdir(directory):
        if d.startswith(".tmp-"):
            shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def _complete(directory: str, step: int) -> bool:
    """True iff the step dir has a parseable manifest and a loadable shard
    for this process — the torn-checkpoint filter (ISSUE 7)."""
    d = os.path.join(directory, f"step-{step:08d}")
    try:
        with open(os.path.join(d, "manifest.json")) as f:
            man = json.load(f)
        with np.load(os.path.join(d, f"shard-{jax.process_index()}.npz"),
                     allow_pickle=False) as data:
            names = set(data.files)
        return all(f"a{i}" in names for i in range(int(man["nleaves"])))
    except (OSError, ValueError, KeyError, json.JSONDecodeError, zipfile.BadZipFile):
        return False


def _steps(directory: str) -> list:
    if not os.path.isdir(directory):
        return []
    out = []
    for d in os.listdir(directory):
        if d.startswith("step-"):
            try:
                out.append(int(d.split("-")[1]))
            except ValueError:
                continue
    return sorted(out)


def latest_step(directory: str) -> int | None:
    """Newest *complete* step — torn or partial step dirs (crash between
    shard and manifest, truncated shard) are skipped, not raised on."""
    for s in reversed(_steps(directory)):
        if _complete(directory, s):
            return s
    return None


def restore(directory: str, tree_like, *, step: int | None = None,
            shardings=None):
    """Restore into the structure (and optionally shardings) of `tree_like`.

    `shardings` may be a pytree of NamedShardings for a *different* mesh than
    the one that saved — elastic restart path.  With ``step=None`` the
    newest complete step wins; an explicit torn ``step`` raises with the
    torn dir named.
    """
    wait()
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no complete checkpoint under "
                                    f"{directory}")
    elif not _complete(directory, step):
        raise FileNotFoundError(
            f"checkpoint step {step} under {directory} is missing or torn "
            f"(no manifest / unloadable shard) — pass step=None to fall "
            f"back to the newest complete step")
    d = os.path.join(directory, f"step-{step:08d}")
    data = np.load(os.path.join(d, f"shard-{jax.process_index()}.npz"))
    leaves, treedef = _flatten(tree_like)
    new_leaves = [data[f"a{i}"] for i in range(len(leaves))]
    if shardings is not None:
        sleaves, _ = _flatten(shardings)
        new_leaves = [jax.device_put(a, s) for a, s in zip(new_leaves, sleaves)]
    else:
        new_leaves = [jnp.asarray(a) for a in new_leaves]
    return jax.tree_util.tree_unflatten(treedef, new_leaves), step


def try_restore(directory: str, tree_like, shardings=None):
    try:
        return restore(directory, tree_like, shardings=shardings)
    except (FileNotFoundError, OSError):
        return None
