"""Sharded, async, mesh-elastic checkpointing (fault-tolerance substrate).

Design for the 1000+-node posture (DESIGN.md §5):
  * every host writes only its addressable shards → one ``.npz`` per host
    plus a tiny JSON manifest (step, pytree structure, global shapes);
  * saves run on a background thread (overlap with the next step's compute);
    ``wait()`` joins before the next save or at exit;
  * ``restore`` takes the *current* mesh/sharding: a checkpoint written on
    a 512-chip mesh restores onto 256 or 1024 chips (elastic restart after
    node loss) because shards are reassembled from the global array view;
  * atomic rename (tmp dir → step dir) so a crash mid-save never corrupts
    the latest complete checkpoint.

On this single-process container "per host" degenerates to one file, but the
code paths (manifest, atomic rename, reshard-on-restore, async) are the real
ones and are exercised by tests/test_checkpoint.py including a simulated
kill-and-restart and a mesh-size change.
"""
from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import jax.numpy as jnp
import numpy as np

_save_thread: threading.Thread | None = None


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def wait():
    global _save_thread
    if _save_thread is not None:
        _save_thread.join()
        _save_thread = None


def save(directory: str, tree, *, step: int, sync: bool = False):
    """Async sharded save of an arbitrary pytree of jax/np arrays."""
    wait()
    leaves, treedef = _flatten(tree)
    # materialize host-local views before handing off to the thread
    host_leaves = [np.asarray(x) for x in leaves]

    def _write():
        tmp = os.path.join(directory, f".tmp-{step}")
        final = os.path.join(directory, f"step-{step:08d}")
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, f"shard-{jax.process_index()}.npz"),
                 **{f"a{i}": a for i, a in enumerate(host_leaves)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "nleaves": len(host_leaves)}, f)
        if os.path.isdir(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _prune(directory, keep=3)

    global _save_thread
    t = threading.Thread(target=_write, daemon=True)
    t.start()
    _save_thread = t
    if sync:
        wait()


def _prune(directory: str, keep: int):
    steps = sorted(d for d in os.listdir(directory) if d.startswith("step-"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = sorted(d for d in os.listdir(directory) if d.startswith("step-"))
    return int(steps[-1].split("-")[1]) if steps else None


def restore(directory: str, tree_like, *, step: int | None = None,
            shardings=None):
    """Restore into the structure (and optionally shardings) of `tree_like`.

    `shardings` may be a pytree of NamedShardings for a *different* mesh than
    the one that saved — elastic restart path.
    """
    wait()
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {directory}")
    d = os.path.join(directory, f"step-{step:08d}")
    data = np.load(os.path.join(d, f"shard-{jax.process_index()}.npz"))
    leaves, treedef = _flatten(tree_like)
    new_leaves = [data[f"a{i}"] for i in range(len(leaves))]
    if shardings is not None:
        sleaves, _ = _flatten(shardings)
        new_leaves = [jax.device_put(a, s) for a, s in zip(new_leaves, sleaves)]
    else:
        new_leaves = [jnp.asarray(a) for a in new_leaves]
    return jax.tree_util.tree_unflatten(treedef, new_leaves), step


def try_restore(directory: str, tree_like, shardings=None):
    try:
        return restore(directory, tree_like, shardings=shardings)
    except (FileNotFoundError, OSError):
        return None
