"""End-to-end trainer for LSH-MF / CULSH-MF (single host or multi-device).

Wires the pipeline of paper Fig. 2:
  R (COO) → neighbour search (simLSH | GSM | RP_cos | minHash | rand)
          → J^K → fused Eq.(5) SGD epochs → RMSE eval,
with checkpoint/restart fault tolerance and optional multi-device rotation.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import baselines as bl
from repro.core import gsm, model, sgd, simlsh, topk
from repro.data.sparse import SparseMatrix, conflict_free_schedule, from_coo
from repro.kernels.mf_sgd.ops import resolve_impl
from repro.launch.mesh import make_shard_mesh
from repro.train import checkpoint as ckpt


@dataclasses.dataclass
class FitConfig:
    F: int = 32
    K: int = 32
    epochs: int = 12
    batch: int = 4096
    method: str = "simlsh"      # simlsh | gsm | rand | rp_cos | minhash | none(mf)
    lsh: simlsh.SimLSHConfig = dataclasses.field(default_factory=simlsh.SimLSHConfig)
    hp: sgd.Hyper = dataclasses.field(default_factory=sgd.Hyper)
    seed: int = 0
    ckpt_dir: str | None = None
    ckpt_every: int = 0          # epochs; 0 = off
    eval_every: int = 1
    loss: str = "l2"             # l2 | bce (implicit feedback, paper §5.4)
    schedule: str = "auto"       # auto | conflict_free | none — 'none' is the
                                 # legacy per-batch-search path (bench
                                 # baseline); 'auto' currently == conflict_free
                                 # (reserved for a backend/shape heuristic)
    cf_batch: int = 512          # conflict-free batch width (≤ min(M, N) useful)
    tiers: int = 4               # schedule width tiers (full/half/…) — a
                                 # modest default; reaching cf_frac ≥ 0.85 on
                                 # heavy zipf tails takes deeper tuned ladders
                                 # (7–9 tiers at tier_shrink≈0.71 — see
                                 # benchmarks/bench_train.py SCALES)
    tier_shrink: float = 0.5     # tier width ratio; ~0.71 packs rounds ≥71%
                                 # full at the cost of more tiers/scans
    min_fill_frac: float = 0.5   # last-tier re-pack threshold
    shards: int | str = "auto"   # block-aligned shard-map tier width: 'auto'
                                 # = jax.device_count() (single-device path
                                 # when 1), or an explicit device count
    use_kernels: bool = False    # route conflict-free batches through the
                                 # fused kernels/mf_sgd training step
    kernel_impl: str = "auto"    # auto | pallas | ref — 'auto' picks the
                                 # pure-jnp ref on CPU (Pallas only
                                 # interprets there), the kernel elsewhere


@dataclasses.dataclass
class FitResult:
    params: model.Params
    JK: jax.Array | None
    history: list            # [(epoch, seconds, rmse)] — seconds are the
                             # accumulated `train.epoch` span times from
                             # the obs registry, excluding jit compilation
                             # (see compile_seconds)
    neighbour_seconds: float
    S: jax.Array | None = None  # simLSH accumulators (online cache)
    hash_key: jax.Array | None = None  # key S was encoded with (Alg. 4 needs
                                       # the same Φ family for ΔΩ)
    compile_seconds: float = 0.0  # AOT epoch-fn compile (one-off)
    prep_seconds: float = 0.0     # gather cache + conflict-free schedule
    schedule_stats: dict | None = None
    registry: obs.Registry | None = None  # the registry every timing above
                                          # was read from (ISSUE 6)


def build_neighbours(sp: SparseMatrix, cfg: FitConfig, key):
    """Neighbour search stage — (JK or None, seconds, S or None, sig key)."""
    t0 = time.perf_counter()
    S = None
    k_sig, k_top = jax.random.split(key)
    if cfg.method == "none":
        return None, 0.0, None, k_sig
    if cfg.method == "simlsh":
        sigs, S = simlsh.encode(sp, cfg.lsh, k_sig, return_accumulators=True)
        JK = topk.topk_from_signatures(sigs, k_top, K=cfg.K, band_cap=cfg.lsh.band_cap)
    elif cfg.method == "gsm":
        JK = gsm.gsm_topk(sp, K=cfg.K)
    elif cfg.method == "rand":
        JK = bl.rand_topk(k_top, sp.N, cfg.K)
    elif cfg.method == "rp_cos":
        sigs = bl.rp_cos_signatures(sp, cfg.lsh, k_sig)
        JK = bl.signatures_topk(sigs, k_top, K=cfg.K, band_cap=cfg.lsh.band_cap)
    elif cfg.method == "minhash":
        sigs = bl.minhash_signatures(sp, cfg.lsh, k_sig)
        JK = bl.signatures_topk(sigs, k_top, K=cfg.K, band_cap=cfg.lsh.band_cap)
    else:
        raise ValueError(f"unknown method {cfg.method}")
    JK = jax.block_until_ready(JK)
    return JK, time.perf_counter() - t0, S, k_sig


def fit(train_coo, test_coo, shape, cfg: FitConfig,
        log: Callable[[str], None] | None = None,
        registry: obs.Registry | None = None) -> FitResult:
    # all fit timings live in one obs registry (ISSUE 6) — the shared
    # process registry when enabled (so train spans land on the unified
    # timeline next to serve/online ones), else a private enabled one so
    # FitResult timing always works.  Every FitResult timing field below
    # is *read back* from the registry's spans, never from a second
    # stopwatch.
    reg = registry if registry is not None else obs.scoped()
    key = jax.random.PRNGKey(cfg.seed)
    k_nb, k_init, k_ep = jax.random.split(key, 3)
    sp = from_coo(*train_coo, shape)
    te_r, te_c, te_v = (jnp.asarray(a) for a in test_coo)

    with reg.span("train.neighbours"):
        JK, _, S, k_sig = build_neighbours(sp, cfg, k_nb)
    nb_secs = reg.span_durations("train.neighbours")[-1]
    mf_only = cfg.method == "none"
    if JK is None:  # plain MF still needs a JK placeholder for batch assembly
        JK = jnp.zeros((sp.N, cfg.K), jnp.int32)

    params = model.init_from_data(k_init, sp, cfg.F, cfg.K)

    start_epoch = 0
    if cfg.ckpt_dir:
        restored = ckpt.try_restore(cfg.ckpt_dir, params)
        if restored is not None:
            params, start_epoch = restored

    if cfg.schedule not in ("auto", "conflict_free", "none"):
        raise ValueError(f"unknown schedule {cfg.schedule}")
    scheduled = cfg.schedule != "none"
    bce = cfg.loss == "bce"

    # shard resolution: block-aligned shard-map tier only when the host
    # actually has multiple devices (single-device path otherwise)
    shards = jax.device_count() if cfg.shards == "auto" else int(cfg.shards)
    shards = max(1, min(shards, jax.device_count(), sp.M, sp.N))
    mesh = make_shard_mesh(shards) if scheduled and shards > 1 else None

    # once-per-fit precomputation: tiered conflict-free schedule + the
    # schedule-ordered training data (+ dense shard-tier cells) + eval
    # gather cache (Ω, J^K and the test set are fixed for the whole
    # offline fit).  Prep is a one-off cost amortized over epochs —
    # schedule_stats reports both.
    prep_secs = 0.0
    sched_stats = None
    ec = None
    shd = None
    if scheduled:
        with reg.span("train.prep"):
            with reg.span("train.prep.schedule"):
                sched = conflict_free_schedule(
                    np.asarray(sp.rows), np.asarray(sp.cols),
                    batch=min(cfg.cf_batch, cfg.batch), tiers=cfg.tiers,
                    tier_shrink=cfg.tier_shrink,
                    min_fill_frac=cfg.min_fill_frac,
                    shards=shards, M=sp.M, N=sp.N, seed=cfg.seed)
            with reg.span("train.prep.pack"):
                sd = model.build_scheduled_data(sp, JK, sched,
                                                mf_only=mf_only)
                shd = model.build_shard_data(sp, JK, sched, mf_only=mf_only)
            if cfg.eval_every:
                with reg.span("train.prep.eval_cache"):
                    ec = model.build_eval_cache(sp, JK, te_r, te_c,
                                                mf_only=mf_only)
            jax.block_until_ready(sd.r)
        prep_secs = reg.span_durations("train.prep")[-1]
        sched_stats = dict(
            sched.stats(), prep_sec=prep_secs,
            prep_per_epoch=prep_secs / max(cfg.epochs - start_epoch, 1))
        if log:
            log(f"schedule: {sched_stats['nb_cf']} cf + "
                f"{sched_stats['nb_lo']} leftover batches "
                f"(cf_frac={sched_stats['cf_frac']:.2f}, "
                f"fill={sched_stats['fill']:.2f}, prep={prep_secs:.2f}s "
                f"= {sched_stats['prep_per_epoch']:.3f}s/epoch)")

    # impl resolution needs the backend, so it happens here, outside jit
    # (mirrors the candidate_score impl="auto" pattern)
    impl = resolve_impl(cfg.kernel_impl) if cfg.use_kernels else "ref"
    interpret = jax.default_backend() == "cpu"

    # AOT-compile the epoch fn so jit compilation is charged to
    # compile_seconds, never to history / benchmark training time — the
    # `train.compile` span keeps the compile/steady-state separation
    # visible in the trace, too
    with reg.span("train.compile"):
        ep0 = jnp.asarray(start_epoch)
        k0 = jax.random.fold_in(k_ep, start_epoch)
        if scheduled:
            # training state: block-padded id space (shard schedules relay
            # every id through sched.row_map/col_map) + the two packed
            # planes; unpacked original-id Params only at the
            # eval/ckpt/result boundary
            state = model.pack_params(model.remap_params(params, sched))
            to_public = lambda q: model.unmap_params(model.unpack_params(q),
                                                     sched)
            epoch_fn = sgd.train_epoch_scheduled.lower(
                state, sd, sched, k0, ep0, cfg.hp, shd=shd, mf_only=mf_only,
                bce=bce, use_kernels=cfg.use_kernels, impl=impl,
                interpret=interpret, mesh=mesh).compile()
            run = lambda qq, kk, ee: epoch_fn(qq, sd, sched, kk, ee, cfg.hp,
                                              shd=shd)
        else:
            state = params
            to_public = lambda q: q
            epoch_fn = sgd.train_epoch.lower(
                state, sp, JK, k0, ep0, cfg.hp, batch=cfg.batch,
                mf_only=mf_only, bce=bce).compile()
            run = lambda qq, kk, ee: epoch_fn(qq, sp, JK, kk, ee, cfg.hp)
    compile_secs = reg.span_durations("train.compile")[-1]

    history = []
    t_train = 0.0
    for ep in range(start_epoch, cfg.epochs):
        with reg.span("train.epoch"):
            state = run(state, jax.random.fold_in(k_ep, ep), jnp.asarray(ep))
            jax.block_until_ready(jax.tree.leaves(state)[0])
        t_train += reg.span_durations("train.epoch")[-1]
        reg.counter_add("train.epochs")
        if cfg.eval_every and (ep + 1) % cfg.eval_every == 0:
            with reg.span("train.epoch.eval"):
                p_eval = to_public(state)
                if ec is not None:  # per-epoch eval is a cached gather scan
                    r = float(model.rmse_cached(p_eval, ec, te_r, te_c, te_v,
                                                mf_only=mf_only))
                else:
                    r = float(model.rmse(p_eval, sp, JK, te_r, te_c, te_v,
                                         mf_only=mf_only))
            history.append((ep, t_train, r))
            reg.event("train.eval", epoch=ep, t_train=t_train, rmse=r)
            if log:
                log(f"epoch {ep:3d}  t={t_train:7.2f}s  rmse={r:.4f}")
        if cfg.ckpt_dir and cfg.ckpt_every and (ep + 1) % cfg.ckpt_every == 0:
            with reg.span("train.ckpt"):
                ckpt.save(cfg.ckpt_dir, to_public(state), step=ep + 1)

    params = to_public(state)
    return FitResult(params, JK, history, nb_secs, S, hash_key=k_sig,
                     compile_seconds=compile_secs, prep_seconds=prep_secs,
                     schedule_stats=sched_stats, registry=reg)
