"""End-to-end trainer for LSH-MF / CULSH-MF (single host or multi-device).

Wires the pipeline of paper Fig. 2:
  R (COO) → neighbour search (simLSH | GSM | RP_cos | minHash | rand)
          → J^K → fused Eq.(5) SGD epochs → RMSE eval,
with checkpoint/restart fault tolerance and optional multi-device rotation.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines as bl
from repro.core import gsm, model, sgd, simlsh, topk
from repro.data.sparse import SparseMatrix, conflict_free_schedule, from_coo
from repro.kernels.mf_sgd.ops import resolve_impl
from repro.train import checkpoint as ckpt


@dataclasses.dataclass
class FitConfig:
    F: int = 32
    K: int = 32
    epochs: int = 12
    batch: int = 4096
    method: str = "simlsh"      # simlsh | gsm | rand | rp_cos | minhash | none(mf)
    lsh: simlsh.SimLSHConfig = dataclasses.field(default_factory=simlsh.SimLSHConfig)
    hp: sgd.Hyper = dataclasses.field(default_factory=sgd.Hyper)
    seed: int = 0
    ckpt_dir: str | None = None
    ckpt_every: int = 0          # epochs; 0 = off
    eval_every: int = 1
    loss: str = "l2"             # l2 | bce (implicit feedback, paper §5.4)
    schedule: str = "auto"       # auto | conflict_free | none — 'none' is the
                                 # legacy per-batch-search path (bench
                                 # baseline); 'auto' currently == conflict_free
                                 # (reserved for a backend/shape heuristic)
    cf_batch: int = 512          # conflict-free batch width (≤ min(M, N) useful)
    use_kernels: bool = False    # route conflict-free batches through the
                                 # fused kernels/mf_sgd training step
    kernel_impl: str = "auto"    # auto | pallas | ref — 'auto' picks the
                                 # pure-jnp ref on CPU (Pallas only
                                 # interprets there), the kernel elsewhere


@dataclasses.dataclass
class FitResult:
    params: model.Params
    JK: jax.Array | None
    history: list            # [(epoch, seconds, rmse)] — seconds exclude
                             # jit compilation (see compile_seconds)
    neighbour_seconds: float
    S: jax.Array | None = None  # simLSH accumulators (online cache)
    hash_key: jax.Array | None = None  # key S was encoded with (Alg. 4 needs
                                       # the same Φ family for ΔΩ)
    compile_seconds: float = 0.0  # AOT epoch-fn compile (one-off)
    prep_seconds: float = 0.0     # gather cache + conflict-free schedule
    schedule_stats: dict | None = None


def build_neighbours(sp: SparseMatrix, cfg: FitConfig, key):
    """Neighbour search stage — (JK or None, seconds, S or None, sig key)."""
    t0 = time.perf_counter()
    S = None
    k_sig, k_top = jax.random.split(key)
    if cfg.method == "none":
        return None, 0.0, None, k_sig
    if cfg.method == "simlsh":
        sigs, S = simlsh.encode(sp, cfg.lsh, k_sig, return_accumulators=True)
        JK = topk.topk_from_signatures(sigs, k_top, K=cfg.K, band_cap=cfg.lsh.band_cap)
    elif cfg.method == "gsm":
        JK = gsm.gsm_topk(sp, K=cfg.K)
    elif cfg.method == "rand":
        JK = bl.rand_topk(k_top, sp.N, cfg.K)
    elif cfg.method == "rp_cos":
        sigs = bl.rp_cos_signatures(sp, cfg.lsh, k_sig)
        JK = bl.signatures_topk(sigs, k_top, K=cfg.K, band_cap=cfg.lsh.band_cap)
    elif cfg.method == "minhash":
        sigs = bl.minhash_signatures(sp, cfg.lsh, k_sig)
        JK = bl.signatures_topk(sigs, k_top, K=cfg.K, band_cap=cfg.lsh.band_cap)
    else:
        raise ValueError(f"unknown method {cfg.method}")
    JK = jax.block_until_ready(JK)
    return JK, time.perf_counter() - t0, S, k_sig


def fit(train_coo, test_coo, shape, cfg: FitConfig,
        log: Callable[[str], None] | None = None) -> FitResult:
    key = jax.random.PRNGKey(cfg.seed)
    k_nb, k_init, k_ep = jax.random.split(key, 3)
    sp = from_coo(*train_coo, shape)
    te_r, te_c, te_v = (jnp.asarray(a) for a in test_coo)

    JK, nb_secs, S, k_sig = build_neighbours(sp, cfg, k_nb)
    mf_only = cfg.method == "none"
    if JK is None:  # plain MF still needs a JK placeholder for batch assembly
        JK = jnp.zeros((sp.N, cfg.K), jnp.int32)

    params = model.init_from_data(k_init, sp, cfg.F, cfg.K)

    start_epoch = 0
    if cfg.ckpt_dir:
        restored = ckpt.try_restore(cfg.ckpt_dir, params)
        if restored is not None:
            params, start_epoch = restored

    if cfg.schedule not in ("auto", "conflict_free", "none"):
        raise ValueError(f"unknown schedule {cfg.schedule}")
    scheduled = cfg.schedule != "none"
    bce = cfg.loss == "bce"

    # once-per-fit precomputation: neighbour-gather cache + conflict-free
    # schedule (Ω and J^K are fixed for the whole offline fit)
    prep_secs = 0.0
    sched_stats = None
    if scheduled:
        t0 = time.perf_counter()
        if mf_only:  # mf_step never reads neighbour slots — zero-width
            z = jnp.zeros((sp.nnz, 0), jnp.float32)  # cache, no allocation
            cache = model.NeighbourCache(z, z)
        else:
            cache = model.build_gather_cache(sp, JK)
        sched = conflict_free_schedule(
            np.asarray(sp.rows), np.asarray(sp.cols),
            batch=min(cfg.cf_batch, cfg.batch), seed=cfg.seed)
        jax.block_until_ready(cache.rnb)
        prep_secs = time.perf_counter() - t0
        sched_stats = sched.stats()
        if log:
            log(f"schedule: {sched_stats['nb_cf']} cf + "
                f"{sched_stats['nb_lo']} leftover batches "
                f"(cf_frac={sched_stats['cf_frac']:.2f}, "
                f"fill={sched_stats['fill']:.2f}, prep={prep_secs:.2f}s)")

    # impl resolution needs the backend, so it happens here, outside jit
    # (mirrors the candidate_score impl="auto" pattern)
    impl = resolve_impl(cfg.kernel_impl) if cfg.use_kernels else "ref"
    interpret = jax.default_backend() == "cpu"

    # AOT-compile the epoch fn so jit compilation is charged to
    # compile_seconds, never to history / benchmark training time
    t0 = time.perf_counter()
    ep0 = jnp.asarray(start_epoch)
    k0 = jax.random.fold_in(k_ep, start_epoch)
    if scheduled:
        epoch_fn = sgd.train_epoch_scheduled.lower(
            params, sp, JK, cache, sched, k0, ep0, cfg.hp, mf_only=mf_only,
            bce=bce, use_kernels=cfg.use_kernels, impl=impl,
            interpret=interpret).compile()
        run = lambda pp, kk, ee: epoch_fn(pp, sp, JK, cache, sched, kk, ee,
                                          cfg.hp)
    else:
        epoch_fn = sgd.train_epoch.lower(
            params, sp, JK, k0, ep0, cfg.hp, batch=cfg.batch,
            mf_only=mf_only, bce=bce).compile()
        run = lambda pp, kk, ee: epoch_fn(pp, sp, JK, kk, ee, cfg.hp)
    compile_secs = time.perf_counter() - t0

    history = []
    t_train = 0.0
    for ep in range(start_epoch, cfg.epochs):
        t0 = time.perf_counter()
        params = run(params, jax.random.fold_in(k_ep, ep), jnp.asarray(ep))
        jax.block_until_ready(params.U)
        t_train += time.perf_counter() - t0
        if cfg.eval_every and (ep + 1) % cfg.eval_every == 0:
            r = float(model.rmse(params, sp, JK, te_r, te_c, te_v, mf_only=mf_only))
            history.append((ep, t_train, r))
            if log:
                log(f"epoch {ep:3d}  t={t_train:7.2f}s  rmse={r:.4f}")
        if cfg.ckpt_dir and cfg.ckpt_every and (ep + 1) % cfg.ckpt_every == 0:
            ckpt.save(cfg.ckpt_dir, params, step=ep + 1)

    return FitResult(params, JK, history, nb_secs, S, hash_key=k_sig,
                     compile_seconds=compile_secs, prep_seconds=prep_secs,
                     schedule_stats=sched_stats)
