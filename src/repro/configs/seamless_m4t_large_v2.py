"""seamless-m4t-large-v2 — enc-dec, audio frontend stub, 256k vocab
[arXiv:2308.11596].  Encoder inputs are precomputed frame embeddings."""
from repro.configs.base import ArchConfig, register

CFG = register(ArchConfig(
    name="seamless-m4t-large-v2", family="encdec",
    L=24, enc_layers=24, d_model=1024, n_heads=16, n_kv=16, head_dim=64,
    d_ff=8192, vocab=256206, frontend="embed_stub", rope_theta=10_000.0,
    seq_shard_acts=True,
))
