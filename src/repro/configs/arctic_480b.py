"""arctic-480b — 128-expert top-2 MoE + dense residual MLP
[hf:Snowflake/snowflake-arctic-base]."""
from repro.configs.base import ArchConfig, register

CFG = register(ArchConfig(
    name="arctic-480b", family="moe",
    L=35, d_model=7168, n_heads=56, n_kv=8, head_dim=128,
    d_ff=4864, vocab=32000, n_experts=128, moe_top_k=2, moe_dense_ff=4864,
    fsdp=True, seq_shard_acts=True, microbatches=8,
    param_dtype="bfloat16", moment_dtype="bfloat16", grad_dtype="bfloat16", query_chunk=512,
))
