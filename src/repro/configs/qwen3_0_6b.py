"""qwen3-0.6b — dense GQA with qk_norm, 152k vocab [hf:Qwen/Qwen3-0.6B]."""
from repro.configs.base import ArchConfig, register

CFG = register(ArchConfig(
    name="qwen3-0.6b", family="dense",
    L=28, d_model=1024, n_heads=16, n_kv=8, head_dim=128,
    d_ff=3072, vocab=151936, qk_norm=True, rope_theta=1_000_000.0,
    seq_shard_acts=True, tie_embeddings=True,
))
