"""mamba2-370m — attention-free SSD state-space model [arXiv:2405.21060]."""
from repro.configs.base import ArchConfig, register

CFG = register(ArchConfig(
    name="mamba2-370m", family="ssm",
    L=48, d_model=1024, n_heads=0, n_kv=0, d_ff=0, vocab=50280,
    ssm_state=128, ssm_expand=2, ssm_headdim=64,
    seq_shard_acts=True,
))
