"""zamba2-7b — Mamba2 backbone + shared attention blocks [arXiv:2411.15242]."""
from repro.configs.base import ArchConfig, register

CFG = register(ArchConfig(
    name="zamba2-7b", family="hybrid",
    L=81, d_model=3584, n_heads=32, n_kv=32, head_dim=112,
    d_ff=14336, vocab=32000,
    ssm_state=64, ssm_expand=2, ssm_headdim=64, attn_every=6,
    seq_shard_acts=True, microbatches=2,
))
