"""llama3-405b — dense GQA, 128k vocab [arXiv:2407.21783]."""
from repro.configs.base import ArchConfig, register

CFG = register(ArchConfig(
    name="llama3-405b", family="dense",
    L=126, d_model=16384, n_heads=128, n_kv=8, head_dim=128,
    d_ff=53248, vocab=128256, rope_theta=500_000.0,
    fsdp=True, seq_shard_acts=True, microbatches=8,
    param_dtype="bfloat16", moment_dtype="bfloat16", grad_dtype="bfloat16", query_chunk=512,
))
