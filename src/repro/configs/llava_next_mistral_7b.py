"""llava-next-mistral-7b — Mistral-7B backbone, anyres vision stub
[hf:llava-hf/llava-v1.6-mistral-7b-hf].  Patch embeddings are a stub input."""
from repro.configs.base import ArchConfig, register

CFG = register(ArchConfig(
    name="llava-next-mistral-7b", family="vlm",
    L=32, d_model=4096, n_heads=32, n_kv=8, head_dim=128,
    d_ff=14336, vocab=32000, frontend="embed_stub", rope_theta=10_000.0,
    seq_shard_acts=True, microbatches=2,
))
