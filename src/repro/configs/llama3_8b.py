"""llama3-8b — dense GQA, 128k vocab [arXiv:2407.21783]."""
from repro.configs.base import ArchConfig, register

CFG = register(ArchConfig(
    name="llama3-8b", family="dense",
    L=32, d_model=4096, n_heads=32, n_kv=8, head_dim=128,
    d_ff=14336, vocab=128256, rope_theta=500_000.0,
    seq_shard_acts=True, microbatches=2,
))
