"""Config system: architecture + shape + parallelism descriptors.

Every assigned architecture is a frozen ``ArchConfig`` registered under its
public id (``--arch <id>``).  Shapes are the four global input geometries
from the brief; ``cells()`` enumerates the runnable (arch × shape) grid with
the documented skips (long_500k needs sub-quadratic attention).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

_REGISTRY: dict[str, "ArchConfig"] = {}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                # dense | moe | ssm | hybrid | encdec | vlm
    L: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 0          # 0 → d_model // n_heads
    head_pad: int = 0          # pad q-head count for TP divisibility (perf)
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 500_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    moe_top_k: int = 0
    moe_dense_ff: int = 0      # arctic-style parallel dense residual MLP
    moe_capacity: float = 2.0  # a2a dispatch capacity factor
    moe_ep2d: bool = False     # experts over data axes (no FSDP gathers)
    # SSM / hybrid
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_conv: int = 4
    attn_every: int = 0        # hybrid: shared attn block before every k ssm layers
    # enc-dec
    enc_layers: int = 0        # family == encdec: L is decoder layers
    # frontend stub (audio/vision): inputs are precomputed embeddings
    frontend: str = "none"     # none | embed_stub
    # numerics / memory policy
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    moment_dtype: str = "float32"   # bf16 = optimizer-state compression
    grad_dtype: str = "float32"     # bf16 = gradient-accumulator compression
    remat: bool = True
    unroll_layers: bool = False     # python-loop layers (exact cost_analysis)
    fsdp: bool = False              # shard params/opt over data axis too
    seq_shard_acts: bool = False    # sequence-parallel stored activations
    microbatches: int = 1           # per train step (grad accumulation)
    query_chunk: int = 1024         # chunked attention block size
    attn_window: int = 0            # 0 = full causal; >0 = sliding window
    # paper technique at the LM softmax (beyond-paper integration)
    lsh_softmax: bool = False
    lsh_candidates: int = 16384

    @property
    def n_heads_padded(self) -> int:
        return max(self.n_heads, self.head_pad) if self.head_pad else self.n_heads

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def subquadratic(self) -> bool:
        return self.family in ("ssm", "hybrid")

    def vocab_padded(self, model_shards: int = 16) -> int:
        v = self.vocab
        return ((v + model_shards - 1) // model_shards) * model_shards


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        # import side-effect registration
        import repro.configs.all  # noqa: F401
    return _REGISTRY[name]


def names() -> list[str]:
    import repro.configs.all  # noqa: F401
    return sorted(_REGISTRY)


def runnable(cfg: ArchConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(ok, reason-if-skipped) — the documented cell skips."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "long_500k needs sub-quadratic attention (DESIGN.md §4)"
    return True, ""


def cells(include_skips: bool = False):
    import repro.configs.all  # noqa: F401
    out = []
    for a in sorted(_REGISTRY):
        for s in SHAPES.values():
            ok, why = runnable(_REGISTRY[a], s)
            if ok or include_skips:
                out.append((a, s.name, ok, why))
    return out


def reduced(cfg: ArchConfig) -> ArchConfig:
    """Tiny same-family config for CPU smoke tests."""
    return dataclasses.replace(
        cfg,
        L=min(cfg.L, 2 if cfg.family != "hybrid" else 4),
        d_model=128,
        n_heads=4,
        n_kv=min(cfg.n_kv, 4) if cfg.n_kv else 0,
        head_dim=32,
        d_ff=256,
        vocab=512,
        n_experts=min(cfg.n_experts, 4),
        moe_dense_ff=128 if cfg.moe_dense_ff else 0,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_headdim=16,
        enc_layers=min(cfg.enc_layers, 2),
        attn_every=2 if cfg.attn_every else 0,
        microbatches=1,
        param_dtype="float32",
        moment_dtype="float32",
        grad_dtype="float32",
        fsdp=False,
        seq_shard_acts=False,
        query_chunk=64,
        lsh_candidates=64,
    )
