"""Import side-effect registration of every assigned architecture."""
from repro.configs import (  # noqa: F401
    arctic_480b,
    dbrx_132b,
    llama3_405b,
    llama3_8b,
    llava_next_mistral_7b,
    mamba2_370m,
    qwen1_5_0_5b,
    qwen3_0_6b,
    seamless_m4t_large_v2,
    zamba2_7b,
)
