"""qwen1.5-0.5b — dense, QKV bias, 152k vocab [hf:Qwen/Qwen1.5-0.5B]."""
from repro.configs.base import ArchConfig, register

CFG = register(ArchConfig(
    name="qwen1.5-0.5b", family="dense",
    L=24, d_model=1024, n_heads=16, n_kv=16, head_dim=64,
    d_ff=2816, vocab=151936, qkv_bias=True, rope_theta=1_000_000.0,
    seq_shard_acts=True, tie_embeddings=True,
))
