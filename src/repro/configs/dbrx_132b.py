"""dbrx-132b — 16-expert top-4 fine-grained MoE [hf:databricks/dbrx-base]."""
from repro.configs.base import ArchConfig, register

CFG = register(ArchConfig(
    name="dbrx-132b", family="moe",
    L=40, d_model=6144, n_heads=48, n_kv=8, head_dim=128,
    d_ff=10752, vocab=100352, n_experts=16, moe_top_k=4,
    fsdp=True, seq_shard_acts=True, microbatches=4,
    moment_dtype="bfloat16", query_chunk=512,
))
