"""GSM — the paper's O(N²) baseline (Definition 3.1, Table 1).

S_{j1,j2} = n/(n+λ_ρ) · ρ_{j1,j2}, with ρ the Pearson similarity over
co-rating rows and n = |Ω̂_{j1} ∩ Ω̂_{j2}|.

Implemented *blocked*: the N×N similarity is produced tile-by-tile and only
a running Top-K per row is kept, so the quadratic memory the paper complains
about is streamed, never materialized (but the quadratic FLOPs remain — that
is the point of the comparison in bench_topk_methods).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.data.sparse import SparseMatrix


def _dense_cols(sp: SparseMatrix):
    """Dense [M, N] value and indicator matrices (column-analysis layout)."""
    X = jnp.zeros((sp.M, sp.N), jnp.float32).at[sp.rows, sp.cols].set(sp.vals)
    B = jnp.zeros((sp.M, sp.N), jnp.float32).at[sp.rows, sp.cols].set(1.0)
    return X, B


@partial(jax.jit, static_argnames=("K", "block"))
def gsm_topk(sp: SparseMatrix, *, K: int, lam_rho: float = 100.0,
             block: int = 512) -> jax.Array:
    """Exact shrunk-Pearson Top-K (J^K [N, K]) via blocked tiles."""
    X, B = _dense_cols(sp)
    cnt = jnp.maximum(B.sum(0), 1.0)
    mean = X.sum(0) / cnt
    Xc = (X - mean[None, :]) * B                       # centered, 0 at missing
    X2 = Xc * Xc
    N = sp.N
    nblk = -(-N // block)
    pad = nblk * block - N

    Xc_p = jnp.pad(Xc, ((0, 0), (0, pad)))
    B_p = jnp.pad(B, ((0, 0), (0, pad)))
    X2_p = jnp.pad(X2, ((0, 0), (0, pad)))

    def tile(start):
        sl = jax.lax.dynamic_slice_in_dim(Xc_p, start, block, 1)   # [M, blk]
        bl = jax.lax.dynamic_slice_in_dim(B_p, start, block, 1)
        num = sl.T @ Xc                                 # Σ co-rated centered prod
        n = bl.T @ B                                    # co-rating counts
        d1 = bl.T @ X2                                  # Σ (r−m)² over co-rated, j2 side
        d2 = jax.lax.dynamic_slice_in_dim(X2_p, start, block, 1).T @ B
        # careful: denominator needs co-rated-only sums on both sides:
        # d_j1 = Σ_{i∈both} (r_{i,j1}−m1)² = (X2 col j1)ᵀ B col j2  → that's d2[j1-row, j2]
        rho = num / jnp.sqrt(jnp.maximum(d2 * d1, 1e-12))
        S = n / (n + lam_rho) * rho
        col_ids = jnp.arange(N)
        row_ids = start + jnp.arange(block)
        S = jnp.where(col_ids[None, :] == row_ids[:, None], -jnp.inf, S)  # no self
        _, idx = jax.lax.top_k(S, K)
        return idx.astype(jnp.int32)

    idx = jax.lax.map(tile, jnp.arange(nblk) * block)   # [nblk, blk, K]
    return idx.reshape(nblk * block, K)[:N]


def gsm_flops_bytes(M: int, N: int, K: int):
    """Hypothetical full-GSM cost (paper Fig. 1 / Table 7 'space overhead')."""
    flops = 2.0 * M * N * N * 3           # three N×N gram products
    bytes_full = 4.0 * N * N              # the materialized GSM the paper charges
    return flops, bytes_full
