"""Neighbour-selection baselines the paper compares simLSH against (Fig. 7 /
Table 7): random-K, RP_cos (cosine random-projection LSH), and minHash
(Jaccard).  All emit the same J^K [N, K] interface as simLSH so they drop
into the identical CULSH-MF trainer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import topk
from repro.core.simlsh import SimLSHConfig, pack_bits, phi_rows
from repro.data.sparse import SparseMatrix


def rand_topk(key: jax.Array, N: int, K: int) -> jax.Array:
    """The paper's randomized control group: K uniform items per row."""
    self_id = jnp.arange(N, dtype=jnp.int32)[:, None]
    r = jax.random.randint(key, (N, K), 0, N, jnp.int32)
    return jnp.where(r == self_id, (r + 1) % N, r)


def rp_cos_signatures(sp: SparseMatrix, cfg: SimLSHConfig, key: jax.Array):
    """RP_cos: sign(Σ_{i∈Ω̂_j} r_ij · g_i) with *unweighted* Gaussian-like
    projections (Ψ = identity, Φ ~ Rademacher ≈ sign-of-Gaussian) — i.e.
    simLSH without the Ψ rating-gap weighting.  [q, N] signatures."""

    def one_band(band):
        phi = phi_rows(key, band, sp.rows, cfg.sig_bits)
        contrib = sp.vals[:, None] * phi
        S = jax.ops.segment_sum(contrib, sp.cols, num_segments=sp.N)
        return pack_bits(S >= 0)

    return jax.lax.map(one_band, jnp.arange(cfg.q))


def minhash_signatures(sp: SparseMatrix, cfg: SimLSHConfig, key: jax.Array):
    """minHash over the *support* of each column (value-blind, the drawback
    the paper calls out).  Each elementary hash = min over i∈Ω̂_j of a random
    permutation value π(i); p such minima are packed into the band signature
    (each min bucketed to G bits)."""

    def one_hash(h):
        kb = jax.random.fold_in(key, h)

        def row_val(i):
            return jax.random.randint(jax.random.fold_in(kb, i), (), 0,
                                      jnp.iinfo(jnp.int32).max, jnp.int32)

        pi = jax.vmap(row_val)(sp.rows)                 # [nnz]
        mins = jax.ops.segment_min(pi, sp.cols, num_segments=sp.N)
        return mins & ((1 << cfg.G) - 1)                # bucket to G bits

    def one_band(band):
        hs = jax.vmap(one_hash)(band * cfg.p + jnp.arange(cfg.p))  # [p, N]
        shift = (2 ** (cfg.G * jnp.arange(cfg.p, dtype=jnp.int32)))[:, None]
        return jnp.sum(hs.astype(jnp.int32) * shift, axis=0)

    return jax.lax.map(one_band, jnp.arange(cfg.q))


def signatures_topk(sigs: jax.Array, key: jax.Array, *, K: int, band_cap: int):
    return topk.topk_from_signatures(sigs, key, K=K, band_cap=band_cap)
