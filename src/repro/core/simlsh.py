"""simLSH — the paper's C1 contribution (Eq. 3 + coarse/fine amplification).

Encoding: for item (column) j,  H̄_j = Υ( Σ_{i∈Ω̂_j} Ψ(r_ij) · Φ(H_i) )
where H_i is a random G-bit string per row i, Φ maps {0,1}→{−1,+1},
Ψ is a rating-weighting (r^ψ, paper uses ψ∈{1,2,4}), and Υ = sign→bit.

Amplification: a *coarse* group ANDs p independent hashes (concatenated into
one p·G-bit signature → collision prob P₂ᵖ for dissimilar pairs), and q such
groups are ORed *fine*-grained (collision prob 1−(1−P₁ᵖ)^q for similar pairs).

TPU adaptation (DESIGN.md §2): the per-row random bits are generated
*functionally* — Φ-row(i) = rademacher(fold_in(key, band, i)) — so any row id
(including rows that arrive later, Alg. 4 online) maps to a fixed hash row
without storing H.  Encoding is a rating-weighted segment-sum, the same
computation the Pallas kernel `kernels/simlsh_encode` tiles into VMEM.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.data.sparse import SparseMatrix


@dataclasses.dataclass(frozen=True)
class SimLSHConfig:
    G: int = 8          # bits per elementary hash
    p: int = 3          # coarse-grained: hashes ANDed into one signature
    q: int = 20         # fine-grained: signature bands ORed
    psi_pow: float = 2.0  # Ψ(r) = r^psi_pow  (paper: ψ ∈ {1, 2, 4})
    # "centered": Ψ(r) = sign(r−μ)·|r−μ|^ψ — beyond-paper variant; the paper
    # only requires Ψ to put "a suitable interval between different r_ij"
    # and the signed form extracts preference rather than popularity.
    psi_mode: str = "pow"  # pow | centered
    psi_center: float = 0.0
    band_cap: int = 8   # max candidates contributed per band (sorted-bucket window)

    @property
    def sig_bits(self) -> int:
        return self.G * self.p

    def __post_init__(self):
        # int32-safe packing (jax default x64-disabled); p·G ≤ 30
        assert self.sig_bits <= 30, "signature must pack into int32 (p·G ≤ 30)"


def psi(vals: jax.Array, psi_pow: float, psi_mode: str = "pow",
        psi_center: float = 0.0) -> jax.Array:
    if psi_mode == "centered":
        d = vals - psi_center
        return jnp.sign(d) * jnp.power(jnp.abs(d), psi_pow)
    return jnp.power(vals, psi_pow)


def phi_rows(key: jax.Array, band: jax.Array, ids: jax.Array, bits: int) -> jax.Array:
    """±1 hash rows Φ(H_i) for arbitrary row ids (online-safe, stateless)."""
    kb = jax.random.fold_in(key, band)

    def one(i):
        return jax.random.rademacher(jax.random.fold_in(kb, i), (bits,), jnp.float32)

    return jax.vmap(one)(ids)


def pack_bits(bits: jax.Array) -> jax.Array:
    """[..., nbits] bool → int32 signature (nbits ≤ 30)."""
    w = (2 ** jnp.arange(bits.shape[-1], dtype=jnp.int32))
    return jnp.sum(bits.astype(jnp.int32) * w, axis=-1)


@partial(jax.jit, static_argnames=("N", "bits", "psi_pow", "psi_mode", "psi_center"))
def band_accumulate(sp_rows, sp_cols, sp_vals, key, band, *, N, bits, psi_pow,
                    psi_mode="pow", psi_center=0.0):
    """Pre-sign accumulator S_j = Σ Ψ(r_ij) Φ(H_i) for one band.  [N, bits]."""
    phi = phi_rows(key, band, sp_rows, bits)           # [nnz, bits]
    contrib = psi(sp_vals, psi_pow, psi_mode, psi_center)[:, None] * phi
    return jax.ops.segment_sum(contrib, sp_cols, num_segments=N)


def encode(sp: SparseMatrix, cfg: SimLSHConfig, key: jax.Array,
           return_accumulators: bool = False):
    """All q band signatures.  Returns sigs [q, N] int32 (`pack_bits` packs
    into int32, which is why `__post_init__` enforces p·G ≤ 30) and, when
    requested, the accumulators [q, N, p·G] float32 — the Alg. 4 online
    cache."""

    def one_band(band):
        S = band_accumulate(sp.rows, sp.cols, sp.vals, key, band,
                            N=sp.N, bits=cfg.sig_bits, psi_pow=cfg.psi_pow,
                            psi_mode=cfg.psi_mode, psi_center=cfg.psi_center)
        return S

    bands = jnp.arange(cfg.q)
    S = jax.lax.map(one_band, bands)                   # [q, N, bits]
    sigs = pack_bits(S >= 0)
    if return_accumulators:
        return sigs, S
    return sigs


def update_accumulators(S: jax.Array, new_rows, new_cols, new_vals,
                        cfg: SimLSHConfig, key: jax.Array, N_total: int):
    """Alg. 4 lines 1–6: fold ΔΩ into cached accumulators; re-sign.

    ``S`` is [q, N_old, bits]; columns ≥ N_old are new items (appended).
    Returns (S', sigs' [q, N_total]).
    """
    q, N_old, bits = S.shape
    if N_total > N_old:
        S = jnp.concatenate(
            [S, jnp.zeros((q, N_total - N_old, bits), S.dtype)], axis=1)

    def one_band(band_S, band):
        dS = band_accumulate(new_rows, new_cols, new_vals, key, band,
                             N=N_total, bits=bits, psi_pow=cfg.psi_pow,
                             psi_mode=cfg.psi_mode, psi_center=cfg.psi_center)
        return band_S + dS

    S2 = jax.vmap(one_band)(S, jnp.arange(q))
    return S2, pack_bits(S2 >= 0)
