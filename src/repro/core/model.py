"""The nonlinear neighbourhood MF model — paper Eq. (1).

r̂_ij = b̄_ij + |R^K(i;j)|^{-1/2} Σ_{j1∈R^K} (r_ij1 − b̄_ij1)·w_{j,k1}
              + |N^K(i;j)|^{-1/2} Σ_{j2∈N^K} c_{j,k2}
              + u_i·v_jᵀ

with the CULSH-MF complement trick (paper §4.2(2)):
S^K(j) = R^K(i;j) ⊎ N^K(i;j) — each of the K neighbours of j is *either*
explicit (i rated it) or implicit, so every sample touches exactly K of the
2K parameters {w_j, c_j}, the load-balance property the CUDA kernel relies
on and that our fused Pallas kernel/TPU batch exploit identically.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.data.sparse import SparseMatrix, baselines, lookup


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Params:
    U: jax.Array   # [M, F]
    V: jax.Array   # [N, F]
    b: jax.Array   # [M]
    bh: jax.Array  # [N]
    W: jax.Array   # [N, K]
    C: jax.Array   # [N, K]
    mu: jax.Array  # []


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Batch:
    i: jax.Array        # [B] row ids
    j: jax.Array        # [B] col ids
    r: jax.Array        # [B] ratings
    nb: jax.Array       # [B, K] neighbour ids (J^K[j])
    rnb: jax.Array      # [B, K] r_{i, nb} (0 where unobserved)
    expl: jax.Array     # [B, K] float mask: neighbour in R^K(i;j)
    impl: jax.Array     # [B, K] float mask: neighbour in N^K(i;j)
    valid: jax.Array    # [B] float mask (padding)


def init_params(key, M, N, F, K, mu=0.0, scale=None) -> Params:
    ku, kv = jax.random.split(key)
    scale = scale if scale is not None else 1.0 / jnp.sqrt(F)
    return Params(
        U=jax.random.normal(ku, (M, F), jnp.float32) * scale,
        V=jax.random.normal(kv, (N, F), jnp.float32) * scale,
        b=jnp.zeros((M,), jnp.float32),
        bh=jnp.zeros((N,), jnp.float32),
        W=jnp.zeros((N, K), jnp.float32),
        C=jnp.zeros((N, K), jnp.float32),
        mu=jnp.asarray(mu, jnp.float32),
    )


def init_from_data(key, sp: SparseMatrix, F, K) -> Params:
    mu, b, bh = baselines(sp)
    p = init_params(key, sp.M, sp.N, F, K, mu=0.0)
    return dataclasses.replace(p, mu=mu, b=b, bh=bh)


def assemble(sp: SparseMatrix, JK: jax.Array, idx: jax.Array,
             valid: jax.Array, lookup_sp: SparseMatrix | None = None) -> Batch:
    """Gather everything a training batch needs (rating lookups via the
    sorted-key binary search — the TPU answer to the GPU hash probe).

    ``idx`` indexes ``sp``'s triples; neighbour-rating lookups go against
    ``lookup_sp`` when given (Alg. 4 online: sample ΔΩ, look up in Ω̂)."""
    i, j, r = sp.rows[idx], sp.cols[idx], sp.vals[idx]
    nb = JK[j]                                              # [B, K]
    src = sp if lookup_sp is None else lookup_sp
    rnb, hit = lookup(src, jnp.broadcast_to(i[:, None], nb.shape), nb)
    expl = hit.astype(jnp.float32)
    impl = 1.0 - expl
    return Batch(i, j, r, nb, rnb, expl, impl, valid.astype(jnp.float32))


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ScheduledData:
    """Training data laid out in `EpochSchedule` order (once per fit).

    Every batch of every schedule tier is a contiguous window of these
    arrays, so batch assembly is a `dynamic_slice` + the schedule's valid
    mask — no per-batch gather at all (`slice_batch`).  Arrays are padded
    by ``sched.pad_width`` slots past nnz so a window that reads past the
    last batch's fill stays in bounds (the overread is masked).

    For ``mf_only`` fits the neighbour planes are built zero-width: the
    MF step never reads them and the [nnz, K] cache memory is skipped.
    """

    i: jax.Array     # [P] int32 row ids
    j: jax.Array     # [P] int32 col ids
    r: jax.Array     # [P] float32 ratings
    nb: jax.Array    # [P, K] int32 neighbour ids (J^K[j])
    rnb: jax.Array   # [P, K] float32 r_{i, nb} (0 where unobserved)
    expl: jax.Array  # [P, K] float32 explicit-slot mask


def build_scheduled_data(sp: SparseMatrix, JK: jax.Array, sched, *,
                         mf_only: bool = False,
                         chunk: int = 65536) -> ScheduledData:
    """One binary-search sweep over the schedule-ordered triples →
    `ScheduledData` (chunked so the [chunk, K, log nnz] search
    intermediates stay off the high-water mark; written in schedule order
    directly so no second permutation pass is needed)."""
    order = sched.order
    pad = sched.pad_width
    padded = lambda a: jnp.concatenate(
        [a, jnp.zeros((pad,) + a.shape[1:], a.dtype)])
    i = padded(sp.rows[order])
    j = padded(sp.cols[order])
    r = padded(sp.vals[order])
    if mf_only:
        z2 = jnp.zeros((i.shape[0], 0), jnp.float32)
        return ScheduledData(i, j, r, z2.astype(jnp.int32), z2, z2)
    K = JK.shape[1]
    nb = JK[sp.cols[order]]
    rnb_parts, expl_parts = [], []
    for c0 in range(0, sp.nnz, chunk):
        ii = sp.rows[order[c0:c0 + chunk]]
        nn = nb[c0:c0 + chunk]
        rnb, hit = lookup(sp, jnp.broadcast_to(ii[:, None], nn.shape), nn)
        rnb_parts.append(rnb)
        expl_parts.append(hit.astype(jnp.float32))
    z = jnp.zeros((0, K), jnp.float32)
    rnb = jnp.concatenate(rnb_parts) if rnb_parts else z
    expl = jnp.concatenate(expl_parts) if expl_parts else z
    return ScheduledData(i, j, r, padded(nb), padded(rnb), padded(expl))


def slice_batch(sd: ScheduledData, start: jax.Array, width: int,
                valid: jax.Array) -> Batch:
    """Assemble a schedule-window batch: contiguous slices, zero gathers."""
    sl = lambda a: jax.lax.dynamic_slice_in_dim(a, start, width, axis=0)
    expl = sl(sd.expl)
    return Batch(sl(sd.i), sl(sd.j), sl(sd.r), sl(sd.nb), sl(sd.rnb),
                 expl, 1.0 - expl, valid.astype(jnp.float32))


def predict(p: Params, bt: Batch, bh_nb: jax.Array | None = None):
    """Eq. (1). Returns (pred [B], aux) with aux reused by the manual SGD.

    ``bh_nb`` optionally substitutes pre-gathered neighbour baselines
    b̂[nb] — the shard-tier scan passes an epoch-start snapshot because
    neighbour cols cross device block boundaries (cuMF-style stale read;
    b̂ drifts one epoch at most)."""
    bbar = p.mu + p.b[bt.i] + p.bh[bt.j]                    # [B]
    bh_of_nb = p.bh[bt.nb] if bh_nb is None else bh_nb
    bbar_nb = p.mu + p.b[bt.i][:, None] + bh_of_nb          # [B, K]
    resid = (bt.rnb - bbar_nb) * bt.expl                    # [B, K]
    nR = jnp.sum(bt.expl, 1)
    nN = jnp.sum(bt.impl, 1)
    sR = jnp.where(nR > 0, jax.lax.rsqrt(jnp.maximum(nR, 1.0)), 0.0)
    sN = jnp.where(nN > 0, jax.lax.rsqrt(jnp.maximum(nN, 1.0)), 0.0)
    w_j, c_j = p.W[bt.j], p.C[bt.j]                         # [B, K]
    expl_term = sR * jnp.sum(resid * w_j, 1)
    impl_term = sN * jnp.sum(bt.impl * c_j, 1)
    dot = jnp.sum(p.U[bt.i] * p.V[bt.j], 1)
    pred = bbar + expl_term + impl_term + dot
    return pred, dict(resid=resid, sR=sR, sN=sN)


def predict_mf(p: Params, bt: Batch):
    """Plain-MF prediction (the CUSGD++ model): r̂ = u_i·v_j."""
    return jnp.sum(p.U[bt.i] * p.V[bt.j], 1)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class EvalCache:
    """Test-set neighbour gathers, precomputed once per fit.

    `rmse` re-runs the [B, K] binary-search rating lookup against the
    train matrix on every eval — but the test triples and J^K are fixed
    for the whole fit, so it is the same work re-done every epoch (the
    `ScheduledData` trick applied to the eval loop).  `rmse_cached` then
    reduces per-epoch eval to plain slices."""

    nb: jax.Array    # [T, K] int32 — J^K[test cols]
    rnb: jax.Array   # [T, K] float32 — r_{i, nb} from the *train* matrix
    expl: jax.Array  # [T, K] float32


def build_eval_cache(sp_train: SparseMatrix, JK: jax.Array, rows, cols, *,
                     mf_only: bool = False, chunk: int = 65536) -> EvalCache:
    """One lookup sweep over the test triples → EvalCache."""
    if mf_only:   # MF never reads neighbour slots — zero-width planes
        z = jnp.zeros((rows.shape[0], 0), jnp.float32)
        return EvalCache(z.astype(jnp.int32), z, z)
    nb_parts, rnb_parts, expl_parts = [], [], []
    for c0 in range(0, int(rows.shape[0]), chunk):
        i = rows[c0:c0 + chunk]
        nb = JK[cols[c0:c0 + chunk]]
        rnb, hit = lookup(sp_train, jnp.broadcast_to(i[:, None], nb.shape), nb)
        nb_parts.append(nb)
        rnb_parts.append(rnb)
        expl_parts.append(hit.astype(jnp.float32))
    z = jnp.zeros((0, JK.shape[1]), jnp.float32)
    cat = lambda ps, zz: jnp.concatenate(ps) if ps else zz
    return EvalCache(cat(nb_parts, z.astype(jnp.int32)),
                     cat(rnb_parts, z), cat(expl_parts, z))


@partial(jax.jit, static_argnames=("batch", "mf_only"))
def rmse_cached(p: Params, ec: EvalCache, rows, cols, vals, *,
                batch: int = 8192, mf_only: bool = False):
    """Test RMSE (Eq. 6) from the per-fit `EvalCache` — per-epoch eval is
    a scan of plain slices, no binary search."""
    n = rows.shape[0]
    nb_batches = max(1, -(-n // batch))
    pad = nb_batches * batch - n
    padv = lambda a: jnp.concatenate(
        [a, jnp.zeros((pad,) + a.shape[1:], a.dtype)])
    rows_p, cols_p, vals_p = padv(rows), padv(cols), padv(vals)
    nb_p, rnb_p, expl_p = padv(ec.nb), padv(ec.rnb), padv(ec.expl)
    valid = (jnp.arange(nb_batches * batch) < n).astype(jnp.float32)

    def body(carry, s):
        sl = lambda a: jax.lax.dynamic_slice_in_dim(a, s, batch, axis=0)
        expl = sl(expl_p)
        r = sl(vals_p)
        v = sl(valid)
        bt = Batch(sl(rows_p), sl(cols_p), r, sl(nb_p), sl(rnb_p),
                   expl, 1.0 - expl, v)
        pred = predict_mf(p, bt) if mf_only else predict(p, bt)[0]
        return carry + jnp.sum((r - pred) ** 2 * v), None

    sse, _ = jax.lax.scan(body, 0.0, jnp.arange(nb_batches) * batch)
    return jnp.sqrt(sse / n)


@partial(jax.jit, static_argnames=("batch", "mf_only"))
def rmse(p: Params, sp_train: SparseMatrix, JK, rows, cols, vals, *,
         batch: int = 8192, mf_only: bool = False):
    """Test RMSE (Eq. 6).  Neighbour ratings come from the *train* matrix."""
    n = rows.shape[0]
    nb_batches = -(-n // batch)
    pad = nb_batches * batch - n
    rows_p = jnp.concatenate([rows, rows[:1].repeat(pad)])
    cols_p = jnp.concatenate([cols, cols[:1].repeat(pad)])
    vals_p = jnp.concatenate([vals, vals[:1].repeat(pad)])
    valid = (jnp.arange(nb_batches * batch) < n).astype(jnp.float32)

    def body(carry, s):
        i = jax.lax.dynamic_slice_in_dim(rows_p, s, batch)
        j = jax.lax.dynamic_slice_in_dim(cols_p, s, batch)
        r = jax.lax.dynamic_slice_in_dim(vals_p, s, batch)
        v = jax.lax.dynamic_slice_in_dim(valid, s, batch)
        nb = JK[j]
        rnb, hit = lookup(sp_train, jnp.broadcast_to(i[:, None], nb.shape), nb)
        expl = hit.astype(jnp.float32)
        bt = Batch(i, j, r, nb, rnb, expl, 1.0 - expl, v)
        pred = predict_mf(p, bt) if mf_only else predict(p, bt)[0]
        err = (r - pred) ** 2 * v
        return carry + jnp.sum(err), None

    sse, _ = jax.lax.scan(body, 0.0, jnp.arange(nb_batches) * batch)
    return jnp.sqrt(sse / n)
