"""The nonlinear neighbourhood MF model — paper Eq. (1).

r̂_ij = b̄_ij + |R^K(i;j)|^{-1/2} Σ_{j1∈R^K} (r_ij1 − b̄_ij1)·w_{j,k1}
              + |N^K(i;j)|^{-1/2} Σ_{j2∈N^K} c_{j,k2}
              + u_i·v_jᵀ

with the CULSH-MF complement trick (paper §4.2(2)):
S^K(j) = R^K(i;j) ⊎ N^K(i;j) — each of the K neighbours of j is *either*
explicit (i rated it) or implicit, so every sample touches exactly K of the
2K parameters {w_j, c_j}, the load-balance property the CUDA kernel relies
on and that our fused Pallas kernel/TPU batch exploit identically.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.sparse import SparseMatrix, baselines, lookup


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Params:
    """Unpacked parameters — the public API layout.

    `FitResult`, checkpoints, `repro.serve` and the online Alg.-4 path all
    speak this layout; the scheduled training hot path packs it into the
    two-plane `PackedParams` (see `pack_params`) and unpacks at the eval /
    checkpoint / result boundary."""

    U: jax.Array   # [M, F]
    V: jax.Array   # [N, F]
    b: jax.Array   # [M]
    bh: jax.Array  # [N]
    W: jax.Array   # [N, K]
    C: jax.Array   # [N, K]
    mu: jax.Array  # []


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PackedParams:
    """Packed-plane training layout: all row-side parameters in one
    ``[M, F+1]`` plane and all col-side parameters in one ``[N, F+2K+1]``
    plane, so an SGD step is **two** gather/scatter pairs instead of six.

    Column layout (scalars last, so U/V start lane-aligned at 0):

    * ``row[:, :F]`` = U,   ``row[:, F]`` = b
    * ``col[:, :F]`` = V,   ``col[:, F:F+K]`` = W,
      ``col[:, F+K:F+2K]`` = C,   ``col[:, F+2K]`` = b̂

    Every per-sample CULSH-MF update touches one row of each plane (the
    §4.2(2) load-balance property: exactly K of the 2K {w, c} slots, plus
    V/b̂ — all living in the same col-plane row), so the packed scatter
    moves the same payload as the six separate ones in one op each.  Under
    the rotation shard tier the whole row plane ring-`ppermute`s as one
    array (U and b together — one collective per sub-epoch, not two).
    """

    row: jax.Array  # [M, F+1] float32 — U ‖ b
    col: jax.Array  # [N, F+2K+1] float32 — V ‖ W ‖ C ‖ b̂
    mu: jax.Array   # []
    F: int = dataclasses.field(metadata=dict(static=True))
    K: int = dataclasses.field(metadata=dict(static=True))

    @property
    def bh(self) -> jax.Array:
        """The b̂ column (neighbour-baseline snapshots gather from it)."""
        return self.col[:, self.F + 2 * self.K]


def pack_params(p: Params) -> PackedParams:
    """Params → the two training planes (one concatenate per side)."""
    F = int(p.U.shape[1])
    K = int(p.W.shape[1])
    return PackedParams(
        row=jnp.concatenate([p.U, p.b[:, None]], axis=1),
        col=jnp.concatenate([p.V, p.W, p.C, p.bh[:, None]], axis=1),
        mu=p.mu, F=F, K=K)


def unpack_params(pp: PackedParams) -> Params:
    """The inverse of `pack_params` (six column slices)."""
    F, K = pp.F, pp.K
    return Params(U=pp.row[:, :F], V=pp.col[:, :F], b=pp.row[:, F],
                  bh=pp.col[:, F + 2 * K], W=pp.col[:, F:F + K],
                  C=pp.col[:, F + K:F + 2 * K], mu=pp.mu)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ServePlanes:
    """Packed *serving* layout: the scoring-relevant parameters as two
    planes, built once per `RecsysService` (the serving analogue of
    `PackedParams`; W/C/μ-neighbour terms never enter the serving score,
    so the col plane is just ``[N, F+1]``).

    * ``row[:, :F]`` = U,  ``row[:, F]`` = b   — one gather per user
      fetches factors *and* bias;
    * ``col[:, :F]`` = V,  ``col[:, F]`` = b̂  — one gather (or one
      in-kernel DMA) per candidate fetches factors *and* item bias.

    `kernels/candidate_score` consumes these directly: the col plane is
    the HBM-resident operand whose rows are gathered *inside* the kernel
    by candidate id, so no ``[B, C, F]`` cube is ever materialized.
    """

    row: jax.Array  # [M, F+1] float32 — U ‖ b
    col: jax.Array  # [N, F+1] float32 — V ‖ b̂
    mu: jax.Array   # []
    F: int = dataclasses.field(metadata=dict(static=True))

    @property
    def n_items(self) -> int:
        return self.col.shape[0]


def pack_serve_planes(p: Params) -> ServePlanes:
    """Params → the two serving planes (one concatenate per side)."""
    return ServePlanes(
        row=jnp.concatenate([p.U, p.b[:, None]], axis=1),
        col=jnp.concatenate([p.V, p.bh[:, None]], axis=1),
        mu=p.mu, F=int(p.U.shape[1]))


def unpack_serve_planes(sp: ServePlanes) -> Params:
    """Inverse of `pack_serve_planes` — back to the public layout, with
    zero-width W/C planes (the serving score never uses them)."""
    F = sp.F
    N = sp.col.shape[0]
    z = jnp.zeros((N, 0), jnp.float32)
    return Params(U=sp.row[:, :F], V=sp.col[:, :F], b=sp.row[:, F],
                  bh=sp.col[:, F], W=z, C=z, mu=sp.mu)


def shard_col_plane(col: jax.Array, bounds) -> jax.Array:
    """Partition a ``[N, W]`` item plane into block-padded shards.

    ``bounds [D+1]`` are nnz-balanced item cuts (`data.sparse.
    balanced_bounds`): shard ``d`` owns global ids ``[bounds[d],
    bounds[d+1])``.  Returns ``[D, block, W]`` with ``block = max shard
    extent`` — the equal-shape stack `jax.shard_map` needs — where local
    row ``l`` of shard ``d`` is global row ``bounds[d] + l`` and rows past
    the shard's extent are zero (never gathered: the sharded retrieval
    masks local ids ≥ the shard's item count to SENTINEL before scoring).
    """
    bounds = np.asarray(bounds)
    D = len(bounds) - 1
    ext = np.diff(bounds)
    block = int(ext.max())
    parts = [jnp.pad(col[int(bounds[d]):int(bounds[d + 1])],
                     ((0, block - int(ext[d])), (0, 0)))
             for d in range(D)]
    return jnp.stack(parts)


def unshard_col_plane(stack: jax.Array, bounds) -> jax.Array:
    """Inverse of `shard_col_plane`: drop each shard's padding rows and
    concatenate back to the original ``[N, W]`` id order."""
    bounds = np.asarray(bounds)
    ext = np.diff(bounds)
    return jnp.concatenate(
        [stack[d, :int(ext[d])] for d in range(len(ext))])


def remap_params(p: Params, sched) -> Params:
    """Re-lay params from original ids into the schedule's block-padded id
    space (`EpochSchedule.row_map`/``col_map``) — required before training
    on a ``shards > 1`` schedule, whose `ScheduledData`/`ShardData` store
    remapped ids so every parameter block is a contiguous equal-size range
    (the shape `jax.shard_map` needs).  Padded slots (ids no map hits) are
    zero and touched by no triple.  No-op on unsharded schedules."""
    if sched.row_map.size == 0:
        return p
    rm, cm = sched.row_map, sched.col_map
    Mp = sched.shards * sched.block_rows
    Np = sched.shards * sched.block_cols
    scat = lambda a, m, n: jnp.zeros((n,) + a.shape[1:], a.dtype).at[m].set(a)
    return Params(U=scat(p.U, rm, Mp), V=scat(p.V, cm, Np),
                  b=scat(p.b, rm, Mp), bh=scat(p.bh, cm, Np),
                  W=scat(p.W, cm, Np), C=scat(p.C, cm, Np), mu=p.mu)


def unmap_params(p: Params, sched) -> Params:
    """Inverse of `remap_params`: gather the original-id rows back out of
    the block-padded layout (drops the padding slots)."""
    if sched.row_map.size == 0:
        return p
    rm, cm = sched.row_map, sched.col_map
    return Params(U=p.U[rm], V=p.V[cm], b=p.b[rm], bh=p.bh[cm],
                  W=p.W[cm], C=p.C[cm], mu=p.mu)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Batch:
    i: jax.Array        # [B] row ids
    j: jax.Array        # [B] col ids
    r: jax.Array        # [B] ratings
    nb: jax.Array       # [B, K] neighbour ids (J^K[j])
    rnb: jax.Array      # [B, K] r_{i, nb} (0 where unobserved)
    expl: jax.Array     # [B, K] float mask: neighbour in R^K(i;j)
    impl: jax.Array     # [B, K] float mask: neighbour in N^K(i;j)
    valid: jax.Array    # [B] float mask (padding)


def init_params(key, M, N, F, K, mu=0.0, scale=None) -> Params:
    ku, kv = jax.random.split(key)
    scale = scale if scale is not None else 1.0 / jnp.sqrt(F)
    return Params(
        U=jax.random.normal(ku, (M, F), jnp.float32) * scale,
        V=jax.random.normal(kv, (N, F), jnp.float32) * scale,
        b=jnp.zeros((M,), jnp.float32),
        bh=jnp.zeros((N,), jnp.float32),
        W=jnp.zeros((N, K), jnp.float32),
        C=jnp.zeros((N, K), jnp.float32),
        mu=jnp.asarray(mu, jnp.float32),
    )


def init_from_data(key, sp: SparseMatrix, F, K) -> Params:
    mu, b, bh = baselines(sp)
    p = init_params(key, sp.M, sp.N, F, K, mu=0.0)
    return dataclasses.replace(p, mu=mu, b=b, bh=bh)


def assemble(sp: SparseMatrix, JK: jax.Array, idx: jax.Array,
             valid: jax.Array, lookup_sp: SparseMatrix | None = None) -> Batch:
    """Gather everything a training batch needs (rating lookups via the
    sorted-key binary search — the TPU answer to the GPU hash probe).

    ``idx`` indexes ``sp``'s triples; neighbour-rating lookups go against
    ``lookup_sp`` when given (Alg. 4 online: sample ΔΩ, look up in Ω̂)."""
    i, j, r = sp.rows[idx], sp.cols[idx], sp.vals[idx]
    nb = JK[j]                                              # [B, K]
    src = sp if lookup_sp is None else lookup_sp
    rnb, hit = lookup(src, jnp.broadcast_to(i[:, None], nb.shape), nb)
    expl = hit.astype(jnp.float32)
    impl = 1.0 - expl
    return Batch(i, j, r, nb, rnb, expl, impl, valid.astype(jnp.float32))


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ScheduledData:
    """Cf-region training data laid out in `EpochSchedule` order (once per
    fit).

    Every width-tier / leftover batch is a contiguous window of these
    arrays, so batch assembly is a `dynamic_slice` + the schedule's valid
    mask — no per-batch gather at all (`slice_batch`).  Arrays are padded
    by ``sched.pad_width`` slots past the region fill so a window that
    reads past the last batch's fill stays in bounds (the overread is
    masked).  Shard-tier triples (schedule positions ``< shard_span``) are
    **not** here — they live in the dense, device-shardable `ShardData` —
    so on a multi-device mesh the replicated arrays only hold the
    cf-region triples.

    With ``sched.shards > 1`` the ``i``/``j``/``nb`` ids are in the
    schedule's block-padded id space (see `EpochSchedule` — train against
    `remap_params`-relaid parameters).

    For ``mf_only`` fits the neighbour planes are built zero-width: the
    MF step never reads them and the [nnz, K] cache memory is skipped.
    """

    i: jax.Array     # [P] int32 row ids
    j: jax.Array     # [P] int32 col ids
    r: jax.Array     # [P] float32 ratings
    nb: jax.Array    # [P, K] int32 neighbour ids (J^K[j])
    rnb: jax.Array   # [P, K] float32 r_{i, nb} (0 where unobserved)
    expl: jax.Array  # [P, K] float32 explicit-slot mask


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ShardData:
    """Shard-tier cells as dense ``[D, S, R, Wsh]`` slot arrays.

    Cell ``(d, s, r)`` *is* the batch — no window slicing — and the
    leading axis is the device axis, so under `jax.shard_map` the arrays
    shard with ``P("shard")`` and each device holds exactly its own
    cells' triples (the `ScheduledData` backing arrays used to be
    replicated across the mesh; ROADMAP "shard-tier data sharding").
    Empty slots are masked by ``sched.shard_valid``.  Ids are in the
    block-padded space whenever the schedule's are.
    """

    i: jax.Array     # [D, S, R, W] int32
    j: jax.Array     # [D, S, R, W] int32
    r: jax.Array     # [D, S, R, W] float32
    nb: jax.Array    # [D, S, R, W, K] int32
    rnb: jax.Array   # [D, S, R, W, K] float32
    expl: jax.Array  # [D, S, R, W, K] float32


def _ordered_planes(sp: SparseMatrix, JK: jax.Array, sched, order_ids,
                    pad: int, *, mf_only: bool, chunk: int):
    """One binary-search sweep over ``order_ids``-ordered triples → the
    (i, j, r, nb, rnb, expl) planes padded by ``pad`` zero slots (chunked
    so the [chunk, K, log nnz] search intermediates stay off the
    high-water mark; written in schedule order directly so no second
    permutation pass is needed).  Ids are remapped into the schedule's
    block-padded space when the schedule carries maps; rating lookups
    always use the original ids."""
    n = int(order_ids.shape[0])
    has_map = sched.row_map.size > 0
    padded = lambda a: jnp.concatenate(
        [a, jnp.zeros((pad,) + a.shape[1:], a.dtype)])
    ri, cj = sp.rows[order_ids], sp.cols[order_ids]
    i = padded(sched.row_map[ri] if has_map else ri)
    j = padded(sched.col_map[cj] if has_map else cj)
    r = padded(sp.vals[order_ids])
    if mf_only:
        z2 = jnp.zeros((i.shape[0], 0), jnp.float32)
        return i, j, r, z2.astype(jnp.int32), z2, z2
    K = JK.shape[1]
    nb = JK[cj]                      # original col ids (for the lookup)
    rnb_parts, expl_parts = [], []
    for c0 in range(0, n, chunk):
        ii = ri[c0:c0 + chunk]
        nn = nb[c0:c0 + chunk]
        rnb, hit = lookup(sp, jnp.broadcast_to(ii[:, None], nn.shape), nn)
        rnb_parts.append(rnb)
        expl_parts.append(hit.astype(jnp.float32))
    z = jnp.zeros((0, K), jnp.float32)
    rnb = jnp.concatenate(rnb_parts) if rnb_parts else z
    expl = jnp.concatenate(expl_parts) if expl_parts else z
    nb_stored = sched.col_map[nb] if has_map else nb
    return i, j, r, padded(nb_stored), padded(rnb), padded(expl)


def build_scheduled_data(sp: SparseMatrix, JK: jax.Array, sched, *,
                         mf_only: bool = False,
                         chunk: int = 65536) -> ScheduledData:
    """Cf-region (width tiers + leftovers) planes in schedule order —
    see `_ordered_planes`.  Pair with `build_shard_data` when the
    schedule has a shard tier."""
    return ScheduledData(*_ordered_planes(
        sp, JK, sched, sched.order[sched.shard_span:], sched.pad_width,
        mf_only=mf_only, chunk=chunk))


def build_shard_data(sp: SparseMatrix, JK: jax.Array, sched, *,
                     mf_only: bool = False,
                     chunk: int = 65536) -> ShardData | None:
    """Shard-tier cells gathered into the dense ``[D, S, R, Wsh]`` layout
    (None when the schedule has no shard tier)."""
    if sched.shard_span == 0:
        return None
    Wsh = sched.shard_width
    planes = _ordered_planes(sp, JK, sched, sched.order[:sched.shard_span],
                             Wsh, mf_only=mf_only, chunk=chunk)
    idx = sched.shard_starts[..., None] + jnp.arange(Wsh)   # [D, S, R, W]
    return ShardData(*(p[idx] for p in planes))


def slice_batch(sd: ScheduledData, start: jax.Array, width: int,
                valid: jax.Array) -> Batch:
    """Assemble a schedule-window batch: contiguous slices, zero gathers."""
    sl = lambda a: jax.lax.dynamic_slice_in_dim(a, start, width, axis=0)
    expl = sl(sd.expl)
    return Batch(sl(sd.i), sl(sd.j), sl(sd.r), sl(sd.nb), sl(sd.rnb),
                 expl, 1.0 - expl, valid.astype(jnp.float32))


def predict_gathered(mu, b_i, bh_j, ui, vj, wj, cj, bh_of_nb,
                     rnb, expl, impl):
    """Eq. (1) on pre-gathered row-aligned operands — the single forward
    shared by the unpacked `predict`, the packed-plane SGD steps and the
    `kernels/mf_sgd` jnp ref, so the layouts stay bit-identical by
    construction (only the in-Pallas kernel keeps an inline copy)."""
    bbar = mu + b_i + bh_j                                  # [B]
    bbar_nb = mu + b_i[:, None] + bh_of_nb                  # [B, K]
    resid = (rnb - bbar_nb) * expl                          # [B, K]
    nR = jnp.sum(expl, 1)
    nN = jnp.sum(impl, 1)
    sR = jnp.where(nR > 0, jax.lax.rsqrt(jnp.maximum(nR, 1.0)), 0.0)
    sN = jnp.where(nN > 0, jax.lax.rsqrt(jnp.maximum(nN, 1.0)), 0.0)
    expl_term = sR * jnp.sum(resid * wj, 1)
    impl_term = sN * jnp.sum(impl * cj, 1)
    dot = jnp.sum(ui * vj, 1)
    pred = bbar + expl_term + impl_term + dot
    return pred, dict(resid=resid, sR=sR, sN=sN)


def predict(p: Params, bt: Batch, bh_nb: jax.Array | None = None):
    """Eq. (1). Returns (pred [B], aux) with aux reused by the manual SGD.

    ``bh_nb`` optionally substitutes pre-gathered neighbour baselines
    b̂[nb] — the shard-tier scan passes an epoch-start snapshot because
    neighbour cols cross device block boundaries (cuMF-style stale read;
    b̂ drifts one epoch at most)."""
    bh_of_nb = p.bh[bt.nb] if bh_nb is None else bh_nb
    return predict_gathered(p.mu, p.b[bt.i], p.bh[bt.j], p.U[bt.i],
                            p.V[bt.j], p.W[bt.j], p.C[bt.j], bh_of_nb,
                            bt.rnb, bt.expl, bt.impl)


def predict_mf(p: Params, bt: Batch):
    """Plain-MF prediction (the CUSGD++ model): r̂ = u_i·v_j."""
    return jnp.sum(p.U[bt.i] * p.V[bt.j], 1)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class EvalCache:
    """Test-set neighbour gathers, precomputed once per fit.

    `rmse` re-runs the [B, K] binary-search rating lookup against the
    train matrix on every eval — but the test triples and J^K are fixed
    for the whole fit, so it is the same work re-done every epoch (the
    `ScheduledData` trick applied to the eval loop).  `rmse_cached` then
    reduces per-epoch eval to plain slices."""

    nb: jax.Array    # [T, K] int32 — J^K[test cols]
    rnb: jax.Array   # [T, K] float32 — r_{i, nb} from the *train* matrix
    expl: jax.Array  # [T, K] float32


def build_eval_cache(sp_train: SparseMatrix, JK: jax.Array, rows, cols, *,
                     mf_only: bool = False, chunk: int = 65536) -> EvalCache:
    """One lookup sweep over the test triples → EvalCache."""
    if mf_only:   # MF never reads neighbour slots — zero-width planes
        z = jnp.zeros((rows.shape[0], 0), jnp.float32)
        return EvalCache(z.astype(jnp.int32), z, z)
    nb_parts, rnb_parts, expl_parts = [], [], []
    for c0 in range(0, int(rows.shape[0]), chunk):
        i = rows[c0:c0 + chunk]
        nb = JK[cols[c0:c0 + chunk]]
        rnb, hit = lookup(sp_train, jnp.broadcast_to(i[:, None], nb.shape), nb)
        nb_parts.append(nb)
        rnb_parts.append(rnb)
        expl_parts.append(hit.astype(jnp.float32))
    z = jnp.zeros((0, JK.shape[1]), jnp.float32)
    cat = lambda ps, zz: jnp.concatenate(ps) if ps else zz
    return EvalCache(cat(nb_parts, z.astype(jnp.int32)),
                     cat(rnb_parts, z), cat(expl_parts, z))


@partial(jax.jit, static_argnames=("batch", "mf_only"))
def rmse_cached(p: Params, ec: EvalCache, rows, cols, vals, *,
                batch: int = 8192, mf_only: bool = False):
    """Test RMSE (Eq. 6) from the per-fit `EvalCache` — per-epoch eval is
    a scan of plain slices, no binary search."""
    n = rows.shape[0]
    nb_batches = max(1, -(-n // batch))
    pad = nb_batches * batch - n
    padv = lambda a: jnp.concatenate(
        [a, jnp.zeros((pad,) + a.shape[1:], a.dtype)])
    rows_p, cols_p, vals_p = padv(rows), padv(cols), padv(vals)
    nb_p, rnb_p, expl_p = padv(ec.nb), padv(ec.rnb), padv(ec.expl)
    valid = (jnp.arange(nb_batches * batch) < n).astype(jnp.float32)

    def body(carry, s):
        sl = lambda a: jax.lax.dynamic_slice_in_dim(a, s, batch, axis=0)
        expl = sl(expl_p)
        r = sl(vals_p)
        v = sl(valid)
        bt = Batch(sl(rows_p), sl(cols_p), r, sl(nb_p), sl(rnb_p),
                   expl, 1.0 - expl, v)
        pred = predict_mf(p, bt) if mf_only else predict(p, bt)[0]
        return carry + jnp.sum((r - pred) ** 2 * v), None

    sse, _ = jax.lax.scan(body, 0.0, jnp.arange(nb_batches) * batch)
    return jnp.sqrt(sse / n)


@partial(jax.jit, static_argnames=("batch", "mf_only"))
def rmse(p: Params, sp_train: SparseMatrix, JK, rows, cols, vals, *,
         batch: int = 8192, mf_only: bool = False):
    """Test RMSE (Eq. 6).  Neighbour ratings come from the *train* matrix."""
    n = rows.shape[0]
    nb_batches = -(-n // batch)
    pad = nb_batches * batch - n
    rows_p = jnp.concatenate([rows, rows[:1].repeat(pad)])
    cols_p = jnp.concatenate([cols, cols[:1].repeat(pad)])
    vals_p = jnp.concatenate([vals, vals[:1].repeat(pad)])
    valid = (jnp.arange(nb_batches * batch) < n).astype(jnp.float32)

    def body(carry, s):
        i = jax.lax.dynamic_slice_in_dim(rows_p, s, batch)
        j = jax.lax.dynamic_slice_in_dim(cols_p, s, batch)
        r = jax.lax.dynamic_slice_in_dim(vals_p, s, batch)
        v = jax.lax.dynamic_slice_in_dim(valid, s, batch)
        nb = JK[j]
        rnb, hit = lookup(sp_train, jnp.broadcast_to(i[:, None], nb.shape), nb)
        expl = hit.astype(jnp.float32)
        bt = Batch(i, j, r, nb, rnb, expl, 1.0 - expl, v)
        pred = predict_mf(p, bt) if mf_only else predict(p, bt)[0]
        err = (r - pred) ** 2 * v
        return carry + jnp.sum(err), None

    sse, _ = jax.lax.scan(body, 0.0, jnp.arange(nb_batches) * batch)
    return jnp.sqrt(sse / n)
