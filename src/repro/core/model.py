"""The nonlinear neighbourhood MF model — paper Eq. (1).

r̂_ij = b̄_ij + |R^K(i;j)|^{-1/2} Σ_{j1∈R^K} (r_ij1 − b̄_ij1)·w_{j,k1}
              + |N^K(i;j)|^{-1/2} Σ_{j2∈N^K} c_{j,k2}
              + u_i·v_jᵀ

with the CULSH-MF complement trick (paper §4.2(2)):
S^K(j) = R^K(i;j) ⊎ N^K(i;j) — each of the K neighbours of j is *either*
explicit (i rated it) or implicit, so every sample touches exactly K of the
2K parameters {w_j, c_j}, the load-balance property the CUDA kernel relies
on and that our fused Pallas kernel/TPU batch exploit identically.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.data.sparse import SparseMatrix, baselines, lookup


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Params:
    U: jax.Array   # [M, F]
    V: jax.Array   # [N, F]
    b: jax.Array   # [M]
    bh: jax.Array  # [N]
    W: jax.Array   # [N, K]
    C: jax.Array   # [N, K]
    mu: jax.Array  # []


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Batch:
    i: jax.Array        # [B] row ids
    j: jax.Array        # [B] col ids
    r: jax.Array        # [B] ratings
    nb: jax.Array       # [B, K] neighbour ids (J^K[j])
    rnb: jax.Array      # [B, K] r_{i, nb} (0 where unobserved)
    expl: jax.Array     # [B, K] float mask: neighbour in R^K(i;j)
    impl: jax.Array     # [B, K] float mask: neighbour in N^K(i;j)
    valid: jax.Array    # [B] float mask (padding)


def init_params(key, M, N, F, K, mu=0.0, scale=None) -> Params:
    ku, kv = jax.random.split(key)
    scale = scale if scale is not None else 1.0 / jnp.sqrt(F)
    return Params(
        U=jax.random.normal(ku, (M, F), jnp.float32) * scale,
        V=jax.random.normal(kv, (N, F), jnp.float32) * scale,
        b=jnp.zeros((M,), jnp.float32),
        bh=jnp.zeros((N,), jnp.float32),
        W=jnp.zeros((N, K), jnp.float32),
        C=jnp.zeros((N, K), jnp.float32),
        mu=jnp.asarray(mu, jnp.float32),
    )


def init_from_data(key, sp: SparseMatrix, F, K) -> Params:
    mu, b, bh = baselines(sp)
    p = init_params(key, sp.M, sp.N, F, K, mu=0.0)
    return dataclasses.replace(p, mu=mu, b=b, bh=bh)


def assemble(sp: SparseMatrix, JK: jax.Array, idx: jax.Array,
             valid: jax.Array, lookup_sp: SparseMatrix | None = None) -> Batch:
    """Gather everything a training batch needs (rating lookups via the
    sorted-key binary search — the TPU answer to the GPU hash probe).

    ``idx`` indexes ``sp``'s triples; neighbour-rating lookups go against
    ``lookup_sp`` when given (Alg. 4 online: sample ΔΩ, look up in Ω̂)."""
    i, j, r = sp.rows[idx], sp.cols[idx], sp.vals[idx]
    nb = JK[j]                                              # [B, K]
    src = sp if lookup_sp is None else lookup_sp
    rnb, hit = lookup(src, jnp.broadcast_to(i[:, None], nb.shape), nb)
    expl = hit.astype(jnp.float32)
    impl = 1.0 - expl
    return Batch(i, j, r, nb, rnb, expl, impl, valid.astype(jnp.float32))


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class NeighbourCache:
    """Per-triple neighbour gathers, precomputed once per fit.

    Ω and J^K are fixed for a whole offline fit, so the [B, K] binary-search
    rating lookup `assemble` does per batch is the same work re-done every
    epoch.  This caches ``r_{i, JK[j]}`` and the explicit-slot mask for all
    nnz triples up front; `assemble_cached` then reduces batch assembly to
    plain `take` gathers.  The Alg.-4 online path keeps the search
    (`assemble` with ``lookup_sp``) because there Ω̂ differs from the
    sampled ΔΩ triples.
    """

    rnb: jax.Array   # [nnz, K] float32 — r_{i, nb} (0 where unobserved)
    expl: jax.Array  # [nnz, K] float32 — 1 where nb ∈ R^K(i;j)


def build_gather_cache(sp: SparseMatrix, JK: jax.Array, *,
                       chunk: int = 65536) -> NeighbourCache:
    """One lookup sweep over all triples → NeighbourCache (chunked so the
    [chunk, K, log nnz] search intermediates stay off the high-water mark)."""
    K = JK.shape[1]
    rnb_parts, expl_parts = [], []
    for c0 in range(0, sp.nnz, chunk):
        i = sp.rows[c0:c0 + chunk]
        nb = JK[sp.cols[c0:c0 + chunk]]
        rnb, hit = lookup(sp, jnp.broadcast_to(i[:, None], nb.shape), nb)
        rnb_parts.append(rnb)
        expl_parts.append(hit.astype(jnp.float32))
    if not rnb_parts:
        z = jnp.zeros((0, K), jnp.float32)
        return NeighbourCache(z, z)
    return NeighbourCache(jnp.concatenate(rnb_parts),
                          jnp.concatenate(expl_parts))


def assemble_cached(sp: SparseMatrix, JK: jax.Array, cache: NeighbourCache,
                    idx: jax.Array, valid: jax.Array) -> Batch:
    """`assemble` with the rating lookups replaced by cache gathers —
    bit-identical output, O(K) instead of O(K log nnz) per sample."""
    i, j, r = sp.rows[idx], sp.cols[idx], sp.vals[idx]
    expl = cache.expl[idx]
    return Batch(i, j, r, JK[j], cache.rnb[idx], expl, 1.0 - expl,
                 valid.astype(jnp.float32))


def predict(p: Params, bt: Batch):
    """Eq. (1). Returns (pred [B], aux) with aux reused by the manual SGD."""
    bbar = p.mu + p.b[bt.i] + p.bh[bt.j]                    # [B]
    bbar_nb = p.mu + p.b[bt.i][:, None] + p.bh[bt.nb]       # [B, K]
    resid = (bt.rnb - bbar_nb) * bt.expl                    # [B, K]
    nR = jnp.sum(bt.expl, 1)
    nN = jnp.sum(bt.impl, 1)
    sR = jnp.where(nR > 0, jax.lax.rsqrt(jnp.maximum(nR, 1.0)), 0.0)
    sN = jnp.where(nN > 0, jax.lax.rsqrt(jnp.maximum(nN, 1.0)), 0.0)
    w_j, c_j = p.W[bt.j], p.C[bt.j]                         # [B, K]
    expl_term = sR * jnp.sum(resid * w_j, 1)
    impl_term = sN * jnp.sum(bt.impl * c_j, 1)
    dot = jnp.sum(p.U[bt.i] * p.V[bt.j], 1)
    pred = bbar + expl_term + impl_term + dot
    return pred, dict(resid=resid, sR=sR, sN=sN)


def predict_mf(p: Params, bt: Batch):
    """Plain-MF prediction (the CUSGD++ model): r̂ = u_i·v_j."""
    return jnp.sum(p.U[bt.i] * p.V[bt.j], 1)


@partial(jax.jit, static_argnames=("batch", "mf_only"))
def rmse(p: Params, sp_train: SparseMatrix, JK, rows, cols, vals, *,
         batch: int = 8192, mf_only: bool = False):
    """Test RMSE (Eq. 6).  Neighbour ratings come from the *train* matrix."""
    n = rows.shape[0]
    nb_batches = -(-n // batch)
    pad = nb_batches * batch - n
    rows_p = jnp.concatenate([rows, rows[:1].repeat(pad)])
    cols_p = jnp.concatenate([cols, cols[:1].repeat(pad)])
    vals_p = jnp.concatenate([vals, vals[:1].repeat(pad)])
    valid = (jnp.arange(nb_batches * batch) < n).astype(jnp.float32)

    def body(carry, s):
        i = jax.lax.dynamic_slice_in_dim(rows_p, s, batch)
        j = jax.lax.dynamic_slice_in_dim(cols_p, s, batch)
        r = jax.lax.dynamic_slice_in_dim(vals_p, s, batch)
        v = jax.lax.dynamic_slice_in_dim(valid, s, batch)
        nb = JK[j]
        rnb, hit = lookup(sp_train, jnp.broadcast_to(i[:, None], nb.shape), nb)
        expl = hit.astype(jnp.float32)
        bt = Batch(i, j, r, nb, rnb, expl, 1.0 - expl, v)
        pred = predict_mf(p, bt) if mf_only else predict(p, bt)[0]
        err = (r - pred) ** 2 * v
        return carry + jnp.sum(err), None

    sse, _ = jax.lax.scan(body, 0.0, jnp.arange(nb_batches) * batch)
    return jnp.sqrt(sse / n)
