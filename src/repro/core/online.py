"""Online learning for incremental data — paper Alg. 4.

New rows Ī and new columns J̄ arrive with interactions ΔΩ (new rows may rate
old *and* new columns).  The update:

  1. fold ΔΩ into the cached pre-sign accumulators S_j (old cols re-sign;
     new cols get fresh accumulators) — `simlsh.update_accumulators`;
  2. re-bucket → Top-K for *new* columns over the whole set Ĵ (old columns
     keep their neighbours, per the paper);
  3. grow {U, b} by M̄ rows and {V, b̂, W, C} by N̄ cols;
  4. train only the new parameters on ΔΩ — old parameters are *frozen*
     (the paper's "remains unchanged"), implemented by masking the scatter
     updates to ids ≥ the old sizes.

Unlike the offline `sgd.train_epoch_scheduled` hot path, this keeps the
binary-search `assemble` (neighbour ratings come from Ω̂ via ``lookup_sp``,
which no per-fit cache covers) and the collision-scaled step (ΔΩ batches
are not conflict-free-scheduled).  The merged matrix is maintained
incrementally (`sparse.merge_coo`, a sorted-array union) instead of
re-sorting Ω̂ ∪ ΔΩ from scratch; see ``OnlineState.stats``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro import obs
from repro.core import simlsh, topk
from repro.core.model import (Params, assemble, build_scheduled_data,
                              pack_params, unpack_params)
from repro.core.sgd import Hyper, culsh_step, lr_decay, train_epoch_scheduled
from repro.data.sparse import (SparseMatrix, conflict_free_schedule,
                               epoch_batches, from_coo, merge_coo)
# direct submodule imports — repro.resil's package __init__ pulls in the WAL
# machinery, which imports back into repro.core
from repro.resil.guard import DivergenceError, GuardConfig, check_divergence
from repro.resil.validate import check_delta


@dataclasses.dataclass
class OnlineState:
    params: Params
    S: jax.Array          # [q, N, p·G] simLSH accumulators
    JK: jax.Array         # [N, K]
    sp: SparseMatrix      # all interactions seen so far
    M: int
    N: int
    # the PRNG key the accumulators were *encoded* with — ΔΩ contributions
    # must come from the same Φ hash family or incremental signatures are
    # meaningless (new items would land in random buckets)
    hash_key: jax.Array | None = None
    # per-update bookkeeping from the last `online_update` (merge_seconds:
    # the Ω̂ ∪ ΔΩ sorted-array union — Alg. 4's dominant host cost at
    # large Ω̂ now that the full re-sort is gone)
    stats: dict = dataclasses.field(default_factory=dict)


def grow_params(p: Params, M_new: int, N_new: int, key) -> Params:
    F = p.U.shape[1]
    K = p.W.shape[1]
    dM, dN = M_new - p.U.shape[0], N_new - p.V.shape[0]
    ku, kv = jax.random.split(key)
    s = 1.0 / jnp.sqrt(F)
    return Params(
        U=jnp.concatenate([p.U, s * jax.random.normal(ku, (dM, F))]),
        V=jnp.concatenate([p.V, s * jax.random.normal(kv, (dN, F))]),
        b=jnp.concatenate([p.b, jnp.zeros((dM,))]),
        bh=jnp.concatenate([p.bh, jnp.zeros((dN,))]),
        W=jnp.concatenate([p.W, jnp.zeros((dN, K))]),
        C=jnp.concatenate([p.C, jnp.zeros((dN, K))]),
        mu=p.mu,
    )


def masked_culsh_step(p: Params, bt, hp: Hyper, decay, M_old: int, N_old: int):
    """Eq. (5) step that only moves parameters of *new* rows/cols.

    Stays on the scaled (``conflict_free=False``) path: ΔΩ batches are
    plain shuffles, not scheduler output, so a new row/col can repeat
    within a batch and the collision rescaling is load-bearing here."""
    p2 = culsh_step(p, bt, hp, decay, conflict_free=False)
    rm = (jnp.arange(p.U.shape[0]) >= M_old).astype(jnp.float32)
    cm = (jnp.arange(p.V.shape[0]) >= N_old).astype(jnp.float32)
    mix = lambda new, old, m: old + m * (new - old)
    return Params(
        U=mix(p2.U, p.U, rm[:, None]),
        V=mix(p2.V, p.V, cm[:, None]),
        b=mix(p2.b, p.b, rm),
        bh=mix(p2.bh, p.bh, cm),
        W=mix(p2.W, p.W, cm[:, None]),
        C=mix(p2.C, p.C, cm[:, None]),
        mu=p.mu,
    )


def online_update(st: OnlineState, new_rows, new_cols, new_vals,
                  cfg: simlsh.SimLSHConfig, hp: Hyper, key, *,
                  M_new: int, N_new: int, K: int, epochs: int = 3,
                  batch: int = 4096,
                  guard: GuardConfig | None = GuardConfig(),
                  registry: obs.Registry | None = None) -> OnlineState:
    """Alg. 4 end-to-end.  ``new_*`` are ΔΩ triples in the grown id space.

    Stage timings (re-sign/merge/topk/train) are recorded as nested obs
    spans under ``online.update``; `OnlineState.stats` reads them back
    from the registry (ISSUE 6 — no second stopwatch), and the ΔΩ sizes
    land in the registry's event log for JSONL time-series export.

    Resilience (ISSUE 7): the ΔΩ triples are validated at the boundary —
    a poison batch (NaN values, negative or out-of-range ids, shrinking
    M/N) raises `PoisonBatchError` before any state is touched.  After
    training, ``guard`` runs a divergence watchdog over the newly trained
    parameter slices; a trip raises `DivergenceError` *before* the new
    state is constructed, so the caller's ``st`` is the rollback."""
    if st.hash_key is None:
        raise ValueError(
            "OnlineState.hash_key is unset — pass the key the accumulators "
            "were encoded with (FitResult.hash_key), else ΔΩ is hashed with "
            "a different Φ family and incremental signatures are garbage")
    # poison quarantine at the boundary — raises PoisonBatchError; nothing
    # downstream (accumulators, merged Ω̂, params) sees a bad batch
    check_delta(new_rows, new_cols, new_vals,
                M_new=M_new, N_new=N_new, M_old=st.M, N_old=st.N)
    reg = registry if registry is not None else obs.scoped()
    k_grow, k_topk, k_train = jax.random.split(key, 3)

    with reg.span("online.update"):
        # (1)(2) incremental hashing + re-sign — lines 1–6 (same Φ family!)
        with reg.span("online.resign"):
            S2, sigs = simlsh.update_accumulators(
                st.S, new_rows, new_cols, new_vals, cfg, st.hash_key, N_new)
            jax.block_until_ready(sigs)

        # merged interaction matrix: sorted-array union of Ω̂ and ΔΩ — the
        # old from_coo rebuild re-lexsorted all of Ω̂ per update, O(n log n)
        # for a d-sized delta; the merge is O(d log d + d log n) + one
        # linear scatter
        with reg.span("online.merge"):
            sp_all = merge_coo(st.sp, new_rows, new_cols, new_vals,
                               (M_new, N_new))
            jax.block_until_ready(sp_all.rows)

        # (3) Top-K: old cols keep their lists; new cols search Ĵ — lines 7–9
        with reg.span("online.topk"):
            JK_all = topk.topk_from_signatures(sigs, k_topk, K=K,
                                               band_cap=cfg.band_cap)
            JK = (jnp.concatenate([st.JK, JK_all[st.N:]], axis=0)
                  if N_new > st.N else st.JK)
            jax.block_until_ready(JK)

        # (4)(5) train only new params on ΔΩ — lines 10–15
        with reg.span("online.train"):
            p = grow_params(st.params, M_new, N_new, k_grow)
            delta = from_coo(new_rows, new_cols, new_vals, (M_new, N_new))

            for ep in range(epochs):
                kk = jax.random.fold_in(k_train, ep)
                idx, valid = epoch_batches(kk, delta.nnz,
                                           min(batch, delta.nnz))
                decay = lr_decay(hp, jnp.asarray(ep))

                def body(pp, ib):
                    bidx, bvalid = ib
                    # bidx indexes ΔΩ's own triples — indexing sp_all here
                    # would train on whatever sorts first in the merged
                    # matrix instead of the new interactions; neighbour
                    # ratings still come from Ω̂
                    bt = assemble(delta, JK, bidx, bvalid, lookup_sp=sp_all)
                    return (masked_culsh_step(pp, bt, hp, decay,
                                              st.M, st.N), None)

                p, _ = jax.lax.scan(body, p, (idx, valid))
            jax.block_until_ready(p.U)

        # divergence watchdog: inspect the trained slices before building
        # the new state — on a trip the caller keeps `st` (the snapshot)
        if guard is not None:
            probs = check_divergence(p, st.params, M_old=st.M, N_old=st.N,
                                     cfg=guard)
            if probs:
                reg.counter_add("online.guard_trips")
                raise DivergenceError(
                    "online update rolled back — trained parameters "
                    "diverged: " + "; ".join(probs))

    reg.counter_add("online.updates")
    reg.counter_add("online.delta_nnz", int(delta.nnz))
    reg.event("online.update", delta_nnz=int(delta.nnz),
              merged_nnz=int(sp_all.nnz), M_new=M_new, N_new=N_new,
              new_cols=N_new - st.N, new_rows=M_new - st.M)
    last = lambda name: reg.span_durations(name)[-1]
    return OnlineState(params=p, S=S2, JK=JK, sp=sp_all, M=M_new, N=N_new,
                       hash_key=st.hash_key,
                       stats=dict(merge_seconds=last("online.merge"),
                                  resign_seconds=last("online.resign"),
                                  topk_seconds=last("online.topk"),
                                  train_seconds=last("online.train"),
                                  update_seconds=last("online.update"),
                                  delta_nnz=int(delta.nnz),
                                  merged_nnz=int(sp_all.nnz)))


# ---------------------------------------------------------------------------
# micro-epochs over the merged Ω̂ — the always-on loop's training workload
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class MicroSchedule:
    """Conflict-free schedule + schedule-ordered data for micro-epochs
    over one merged Ω̂ snapshot.  Valid only for the exact `SparseMatrix`
    it was built from (``sp`` is kept as the cache token: Ω̂ changes
    identity on every delta merge, so the loop rebuilds lazily).  The
    build is deterministic given (sp, batch, seed) — part of the replay
    contract for crash-safe resume."""
    sched: object          # data.sparse.EpochSchedule
    sd: object             # model.ScheduledData
    sp: SparseMatrix
    batch: int
    seed: int


def build_micro_schedule(sp: SparseMatrix, JK: jax.Array, *,
                         batch: int = 4096, seed: int = 0) -> MicroSchedule:
    """Schedule the merged matrix for `micro_epoch` (no shard tier: the
    loop shares one device budget with serving, so micro-epochs stay
    single-device)."""
    sched = conflict_free_schedule(
        jnp.asarray(sp.rows), jnp.asarray(sp.cols),
        batch=min(batch, max(int(sp.nnz), 1)),
        shards=0, M=sp.M, N=sp.N, seed=seed)
    sd = build_scheduled_data(sp, JK, sched)
    return MicroSchedule(sched=sched, sd=sd, sp=sp, batch=batch, seed=seed)


def micro_epoch(st: OnlineState, hp: Hyper, key, *, epoch: int = 0,
                sched: MicroSchedule | None = None, batch: int = 4096,
                registry: obs.Registry | None = None) -> OnlineState:
    """One bounded scheduled training round over the merged Ω̂ — the
    always-on loop's per-slice training unit (ISSUE 10).

    Unlike `online_update` (Alg. 4: train only the grown slices on ΔΩ,
    old parameters frozen), a micro-epoch continues training *all*
    parameters on everything seen so far, through the offline hot path
    (`sgd.train_epoch_scheduled` on a conflict-free schedule).  This is
    the half of the paper's online claim Alg. 4 alone doesn't cover: the
    model keeps converging between deltas while the service keeps
    serving.

    Deterministic given (state, key, epoch, schedule): same inputs, same
    CPU/XLA program ⇒ bit-identical parameters — the loop logs (key,
    epoch, rounds) to the WAL and replays micro-epochs exactly on
    recovery.  S/JK/Ω̂ are untouched (training moves no ids), so the
    returned state shares them with ``st``.
    """
    reg = registry if registry is not None else obs.scoped()
    if sched is None or sched.sp is not st.sp:
        with reg.span("online.micro.schedule"):
            sched = build_micro_schedule(st.sp, st.JK, batch=batch)
    with reg.span("online.micro"):
        pp = pack_params(st.params)
        # train_epoch_scheduled donates its input planes; row/col are
        # fresh concatenates but mu aliases st.params.mu — copy it so the
        # donation cannot delete the caller's buffer
        pp = dataclasses.replace(pp, mu=pp.mu.copy())
        pp = train_epoch_scheduled(pp, sched.sd, sched.sched,
                                   jnp.asarray(key), jnp.asarray(epoch), hp)
        p = unpack_params(pp)
        jax.block_until_ready(p.U)
    reg.counter_add("online.micro_epochs")
    return OnlineState(params=p, S=st.S, JK=st.JK, sp=st.sp, M=st.M, N=st.N,
                       hash_key=st.hash_key,
                       stats=dict(st.stats,
                                  micro_seconds=reg.span_durations(
                                      "online.micro")[-1]))
