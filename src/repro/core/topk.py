"""Top-K nearest-neighbour extraction from LSH band signatures.

Replaces the paper's GPU hash-table probe (Alg. 1 lines 10–12) with a
sort-based pipeline that is fixed-shape and TPU-friendly (DESIGN.md §2):

  1. per band: argsort signatures; items adjacent in sort order with *equal*
     signature are bucket-mates.  Each item takes up to `band_cap` mates
     (window around its sorted position) — the bucket cap the paper's
     fixed-size hash table also implies.
  2. across bands: per item, sort the q·band_cap candidate ids; run-length
     count equal ids ("K most frequent variables in the hash table"); take
     the K highest counts; random-fill any deficit (paper: "make a random
     supplement if the number is less than K").
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

SENTINEL = jnp.iinfo(jnp.int32).max


@partial(jax.jit, static_argnames=("band_cap",))
def band_candidates(sig: jax.Array, *, band_cap: int) -> jax.Array:
    """One band's candidates.  sig [N] int32 → cand [N, band_cap] int32.

    cand entries are item ids sharing this band's signature, SENTINEL-padded.
    """
    N = sig.shape[0]
    order = jnp.argsort(sig)
    ssig = sig[order]
    half = band_cap // 2
    offs = jnp.concatenate([jnp.arange(1, half + 1), -jnp.arange(1, band_cap - half + 1)])

    def at_offset(off):
        pos = jnp.arange(N) + off
        ok = (pos >= 0) & (pos < N)
        pos = jnp.clip(pos, 0, N - 1)
        same = ok & (ssig[pos] == ssig)
        return jnp.where(same, order[pos], SENTINEL)

    cand_sorted = jax.vmap(at_offset, out_axes=1)(offs)      # [N, band_cap]
    # scatter back to original item order
    out = jnp.full((N, band_cap), SENTINEL, jnp.int32)
    return out.at[order].set(cand_sorted.astype(jnp.int32))


@partial(jax.jit, static_argnames=("K",))
def topk_frequent(cands: jax.Array, key: jax.Array, *, K: int) -> jax.Array:
    """cands [N, L] (SENTINEL-padded) → Top-K most frequent per row [N, K].

    Deficit rows are filled with random items ≠ self (and de-duplication of
    the random fill against found neighbours is *not* attempted, matching the
    paper's cheap "random supplement").
    """
    N, L = cands.shape
    self_id = jnp.arange(N, dtype=jnp.int32)[:, None]
    cands = jnp.where(cands == self_id, SENTINEL, cands)
    c = jnp.sort(cands, axis=1)                               # [N, L]

    def row_counts(row):
        first = jnp.searchsorted(row, row, side="left")
        last = jnp.searchsorted(row, row, side="right")
        count = (last - first).astype(jnp.int32)
        is_head = first == jnp.arange(L)
        valid = row != SENTINEL
        score = jnp.where(is_head & valid, count, -1)
        return score

    scores = jax.vmap(row_counts)(c)
    top_scores, top_idx = jax.lax.top_k(scores, K)            # [N, K]
    nbrs = jnp.take_along_axis(c, top_idx, axis=1)
    found = top_scores > 0

    rand = jax.random.randint(key, (N, K), 0, N, jnp.int32)
    rand = jnp.where(rand == self_id, (rand + 1) % N, rand)
    return jnp.where(found, nbrs, rand)


def topk_from_signatures(sigs: jax.Array, key: jax.Array, *, K: int,
                         band_cap: int) -> jax.Array:
    """sigs [q, N] int32 → J^K [N, K] int32 (the paper's Top-K matrix).

    Signatures are int32 by construction (`simlsh.pack_bits`; p·G ≤ 30) —
    int64 would silently widen every sort/compare on x64-enabled hosts.
    """
    assert sigs.dtype == jnp.int32, f"signatures must be int32, got {sigs.dtype}"
    cands = jax.vmap(lambda s: band_candidates(s, band_cap=band_cap))(sigs)
    cands = jnp.transpose(cands, (1, 0, 2)).reshape(sigs.shape[1], -1)
    return topk_frequent(cands, key, K=K)
