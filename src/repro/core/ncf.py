"""NCF baselines the paper compares against in Table 10 — GMF, MLP, NeuMF
(He et al. 2017), implicit feedback with BCE loss and HR@K evaluation.

Small, honest JAX implementations (autograd + Adam) — the point of the
paper's Table 10 is wall-clock-to-quality vs CULSH-MF, reproduced by
bench_ncf.py on synthetic implicit data.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class NCFConfig:
    M: int
    N: int
    F: int = 16
    mlp_layers: tuple = (64, 32, 16)
    kind: str = "neumf"  # gmf | mlp | neumf


def init(cfg: NCFConfig, key):
    ks = jax.random.split(key, 8)
    s = 0.01
    p = {}
    if cfg.kind in ("gmf", "neumf"):
        p["gmf_u"] = s * jax.random.normal(ks[0], (cfg.M, cfg.F))
        p["gmf_v"] = s * jax.random.normal(ks[1], (cfg.N, cfg.F))
        p["gmf_h"] = s * jax.random.normal(ks[2], (cfg.F,))
    if cfg.kind in ("mlp", "neumf"):
        p["mlp_u"] = s * jax.random.normal(ks[3], (cfg.M, cfg.F))
        p["mlp_v"] = s * jax.random.normal(ks[4], (cfg.N, cfg.F))
        dims = (2 * cfg.F,) + cfg.mlp_layers
        # He-scaled tower init: the seed's flat s=0.01 starved the relu
        # stack of signal (logits ~1e-6 → the MLP barely moved off the
        # 0.693 BCE plateau in hundreds of Adam steps)
        p["mlp_w"] = [jnp.sqrt(2.0 / dims[li])
                      * jax.random.normal(jax.random.fold_in(ks[5], li),
                                          (dims[li], dims[li + 1]))
                      for li in range(len(dims) - 1)]
        p["mlp_b"] = [jnp.zeros((d,)) for d in dims[1:]]
        p["mlp_h"] = (jnp.sqrt(1.0 / cfg.mlp_layers[-1])
                      * jax.random.normal(ks[6], (cfg.mlp_layers[-1],)))
    return p


def logits(p, cfg: NCFConfig, i, j):
    parts = []
    if cfg.kind in ("gmf", "neumf"):
        parts.append((p["gmf_u"][i] * p["gmf_v"][j]) @ p["gmf_h"])
    if cfg.kind in ("mlp", "neumf"):
        x = jnp.concatenate([p["mlp_u"][i], p["mlp_v"][j]], axis=-1)
        for w, b in zip(p["mlp_w"], p["mlp_b"]):
            x = jax.nn.relu(x @ w + b)
        parts.append(x @ p["mlp_h"])
    return sum(parts)


def bce_loss(p, cfg: NCFConfig, i, j, y):
    z = logits(p, cfg, i, j)
    return jnp.mean(jnp.maximum(z, 0) - z * y + jnp.log1p(jnp.exp(-jnp.abs(z))))


@partial(jax.jit, static_argnames=("cfg",))
def adam_step(p, m, v, t, cfg: NCFConfig, i, j, y, lr=1e-3, b1=0.9, b2=0.999):
    g = jax.grad(bce_loss)(p, cfg, i, j, y)
    m = jax.tree.map(lambda a, b: b1 * a + (1 - b1) * b, m, g)
    v = jax.tree.map(lambda a, b: b2 * a + (1 - b2) * b * b, v, g)
    mh = jax.tree.map(lambda a: a / (1 - b1 ** t), m)
    vh = jax.tree.map(lambda a: a / (1 - b2 ** t), v)
    p = jax.tree.map(lambda a, mm, vv: a - lr * mm / (jnp.sqrt(vv) + 1e-8), p, mh, vh)
    return p, m, v


@partial(jax.jit, static_argnames=("cfg", "topk"))
def hit_ratio(p, cfg: NCFConfig, users, pos_items, cand_items, topk=10):
    """HR@K with the standard 1-positive + sampled-negatives protocol."""
    def one(u, pos, cands):
        items = jnp.concatenate([pos[None], cands])
        z = logits(p, cfg, jnp.full_like(items, u), items)
        rank = jnp.sum(z > z[0])
        return (rank < topk).astype(jnp.float32)

    return jnp.mean(jax.vmap(one)(users, pos_items, cand_items))
