"""Stochastic optimization — paper Eq. (4)/(5) updates + Eq. (7) dynamic LR.

Two engines, mirroring the paper's two contributions:

* ``mf_step``        — CUSGD++ analogue: plain MF {U, V} only.
* ``culsh_step``     — CULSH-MF: the full six-parameter fused update.

Both exist in two layouts.  The *unpacked* steps above take `model.Params`
and scatter each parameter separately — they are the reference semantics
and the engine of the general path (`train_epoch`, the online Alg.-4
building block).  The *packed* steps (``mf_step_packed`` /
``culsh_step_packed``) take `model.PackedParams` — row-side parameters in
one [M, F+1] plane, col-side in one [N, F+2K+1] plane — and emit **two**
gather/scatter pairs per step instead of six; they are bit-identical to
the unpacked steps (shared forward + shared delta computation) and power
the scheduled hot path.

TPU adaptation (DESIGN.md §2/§8.1): updates are applied to a *mini-batch*
with scatter-add (`.at[].add`).  When the batch is conflict-free (each i and
each j at most once — the invariant the paper's D×D blocking provides) this
is *exactly* Eq. (5) applied in parallel; with collisions it is the summed
batch-SGD step.  Both engines are pure functions scanned over an epoch.

Two epoch drivers:

* ``train_epoch``            — general case: binary-search batch assembly +
  collision rescaling every batch (also the Alg.-4 online building block).
  Unpacked `Params` in, unpacked out.
* ``train_epoch_scheduled``  — offline hot path: contiguous-slice assembly
  from the schedule-ordered `ScheduledData`, width-tiered conflict-free
  scans over packed planes (+ optional fused Pallas kernels), an optional
  shard_map block-rotation tier over the dense `ShardData` cells,
  precomputed leftover collision scales, params donated across epochs.
  `PackedParams` in, `PackedParams` out.  See bench_train.py.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.model import (Batch, PackedParams, Params, ScheduledData,
                              ShardData, assemble, predict, predict_gathered,
                              predict_mf, slice_batch)
from repro.data.sparse import EpochSchedule, SparseMatrix, epoch_batches
from repro.kernels.mf_sgd.ops import apply_culsh_sgd, apply_mf_sgd


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Hyper:
    # initial learning rates (paper Table 3/5 names)
    a_b: float = 0.02
    a_bh: float = 0.02
    a_u: float = 0.02
    a_v: float = 0.02
    a_w: float = 0.001
    a_c: float = 0.001
    # regularization
    l_b: float = 0.01
    l_bh: float = 0.01
    l_u: float = 0.01
    l_v: float = 0.01
    l_w: float = 0.05
    l_c: float = 0.05
    # Eq. (7) decay
    beta: float = 0.3


def lr_decay(hp: Hyper, t: jax.Array) -> jax.Array:
    """γ_t = α / (1 + β·t^1.5) — Eq. (7); returns the *decay factor*."""
    return 1.0 / (1.0 + hp.beta * jnp.power(t.astype(jnp.float32), 1.5))


def _batch_scales(M: int, N: int, bt: Batch, conflict_free: bool, scales):
    """(si, sj, si_col, sj_col) — collision normalizers and their [B, 1]
    broadcasts, so rows hit k× in a batch get the *mean* update (zipf
    heads would otherwise receive k summed steps and diverge).

    ``conflict_free`` (a static promise that each i and j appears at most
    once, the D×D-block invariant) elides the two O(M)+O(N) scatter-add
    allocations entirely: all counts are 1.  ``scales`` optionally
    supplies host-precomputed (si, sj) — the scheduled leftover batches
    have fixed composition per fit, so their counts are schedule
    constants (`EpochSchedule.lo_scale_*`), not per-batch work."""
    if scales is not None:
        si, sj = scales
        return si, sj, si[:, None], sj[:, None]
    if conflict_free:
        one = jnp.ones((), jnp.float32)
        return one, one, one, one
    ci = jnp.zeros((M,), jnp.float32).at[bt.i].add(bt.valid)
    cj = jnp.zeros((N,), jnp.float32).at[bt.j].add(bt.valid)
    si = 1.0 / jnp.maximum(ci[bt.i], 1.0)
    sj = 1.0 / jnp.maximum(cj[bt.j], 1.0)
    return si, sj, si[:, None], sj[:, None]


def _error(r, pred, bce: bool):
    """e_ij: residual (L2) or r − σ(pred) (BCE — the paper's implicit-
    feedback variant: "we change the loss function ... to cross entropy,
    and the update formula will follow the corresponding change")."""
    return r - (jax.nn.sigmoid(pred) if bce else pred)


def _mf_deltas(bt: Batch, e, ui, vj, hp: Hyper, decay, si_c, sj_c):
    """(du, dv) for the CUSGD++ update — shared by both layouts."""
    gu = hp.a_u * decay
    gv = hp.a_v * decay
    vmask = bt.valid[:, None]
    du = gu * (e[:, None] * vj - hp.l_u * ui) * vmask * si_c
    dv = gv * (e[:, None] * ui - hp.l_v * vj) * vmask * sj_c
    return du, dv


def _culsh_deltas(bt: Batch, e, aux, b_i, bh_j, ui, vj, wj, cj, hp: Hyper,
                  decay, si, sj, si_c, sj_c):
    """The six Eq. (5) parameter deltas from row-aligned gathered operands
    — shared by the unpacked and packed steps so the two layouts are
    bit-identical by construction."""
    d = decay
    vmask = bt.valid[:, None]
    db = hp.a_b * d * (e - hp.l_b * b_i) * bt.valid * si
    dbh = hp.a_bh * d * (e - hp.l_bh * bh_j) * bt.valid * sj
    du = hp.a_u * d * (e[:, None] * vj - hp.l_u * ui) * vmask * si_c
    dv = hp.a_v * d * (e[:, None] * ui - hp.l_v * vj) * vmask * sj_c
    # w_{j,k} ← w + γw(|R|^{-1/2}·e·(r_nb − b̄_nb) − λw·w) on explicit slots
    dw = (aux["sR"][:, None] * e[:, None] * aux["resid"] - hp.l_w * wj) * bt.expl
    dc = (aux["sN"][:, None] * e[:, None] - hp.l_c * cj) * bt.impl
    dw = hp.a_w * d * dw * vmask * sj_c
    dc = hp.a_c * d * dc * vmask * sj_c
    return db, dbh, du, dv, dw, dc


def mf_step(p: Params, bt: Batch, hp: Hyper, decay, bce: bool = False,
            conflict_free: bool = False) -> Params:
    """CUSGD++: u_i ← u_i + γ(e·v_j − λu·u_i);  v symmetric.  Unpacked
    reference layout — the hot path is `mf_step_packed`."""
    e = _error(bt.r, predict_mf(p, bt), bce) * bt.valid
    ui, vj = p.U[bt.i], p.V[bt.j]
    _, _, si_c, sj_c = _batch_scales(p.U.shape[0], p.V.shape[0], bt,
                                     conflict_free, None)
    du, dv = _mf_deltas(bt, e, ui, vj, hp, decay, si_c, sj_c)
    return dataclasses.replace(p, U=p.U.at[bt.i].add(du),
                               V=p.V.at[bt.j].add(dv))


def mf_step_packed(pp: PackedParams, bt: Batch, hp: Hyper, decay,
                   bce: bool = False, conflict_free: bool = False,
                   scales=None) -> PackedParams:
    """CUSGD++ on the packed planes: one gather + one scatter per side,
    touching only the U/V columns.  Bit-identical to `mf_step` (same
    delta computation on the same gathered values)."""
    F = pp.F
    ui = pp.row[bt.i, :F]
    vj = pp.col[bt.j, :F]
    e = _error(bt.r, jnp.sum(ui * vj, 1), bce) * bt.valid
    _, _, si_c, sj_c = _batch_scales(pp.row.shape[0], pp.col.shape[0], bt,
                                     conflict_free, scales)
    du, dv = _mf_deltas(bt, e, ui, vj, hp, decay, si_c, sj_c)
    return dataclasses.replace(pp, row=pp.row.at[bt.i, :F].add(du),
                               col=pp.col.at[bt.j, :F].add(dv))


def culsh_step(p: Params, bt: Batch, hp: Hyper, decay,
               bce: bool = False, conflict_free: bool = False,
               bh_nb: jax.Array | None = None) -> Params:
    """CULSH-MF: the fused Eq. (5) update of {b, b̂, U, V, W, C}.

    Unpacked reference layout (six scatters) — the scheduled hot path is
    `culsh_step_packed`, which shares this function's forward and delta
    computation and must stay bit-identical to it (tested).

    With ``conflict_free`` (static) the batch is promised to touch each i
    and each j at most once (the D×D-block invariant), making the summed
    scatter exactly the parallel Eq. (5) with no rescaling.  ``bh_nb``
    optionally substitutes pre-gathered neighbour baselines (see
    `model.predict` — the shard-tier stale-read)."""
    pred, aux = predict(p, bt, bh_nb=bh_nb)
    e = _error(bt.r, pred, bce) * bt.valid
    si, sj, si_c, sj_c = _batch_scales(p.U.shape[0], p.V.shape[0], bt,
                                       conflict_free, None)
    db, dbh, du, dv, dw, dc = _culsh_deltas(
        bt, e, aux, p.b[bt.i], p.bh[bt.j], p.U[bt.i], p.V[bt.j],
        p.W[bt.j], p.C[bt.j], hp, decay, si, sj, si_c, sj_c)
    return dataclasses.replace(
        p, b=p.b.at[bt.i].add(db), bh=p.bh.at[bt.j].add(dbh),
        U=p.U.at[bt.i].add(du), V=p.V.at[bt.j].add(dv),
        W=p.W.at[bt.j].add(dw), C=p.C.at[bt.j].add(dc))


def culsh_step_packed(pp: PackedParams, bt: Batch, hp: Hyper, decay,
                      bce: bool = False, conflict_free: bool = False,
                      bh_nb: jax.Array | None = None,
                      scales=None) -> PackedParams:
    """CULSH-MF on the packed planes: the six scatters of `culsh_step`
    become one [B, F+1] row-plane scatter and one [B, F+2K+1] col-plane
    scatter (the per-sample payload is identical — packing only fuses the
    ops).  Bit-identical to `culsh_step` by shared-helper construction.

    ``scales`` optionally supplies the precomputed (si, sj) collision
    normalizers (`EpochSchedule.lo_scale_*`) for the scheduled leftover
    batches; ``bh_nb`` is the shard-tier epoch-start b̂ snapshot gather."""
    F, K = pp.F, pp.K
    row = pp.row[bt.i]                                     # [B, F+1]
    col = pp.col[bt.j]                                     # [B, F+2K+1]
    ui, b_i = row[:, :F], row[:, F]
    vj, wj = col[:, :F], col[:, F:F + K]
    cj, bh_j = col[:, F + K:F + 2 * K], col[:, F + 2 * K]
    bh_of_nb = pp.col[bt.nb, F + 2 * K] if bh_nb is None else bh_nb
    pred, aux = predict_gathered(pp.mu, b_i, bh_j, ui, vj, wj, cj,
                                 bh_of_nb, bt.rnb, bt.expl, bt.impl)
    e = _error(bt.r, pred, bce) * bt.valid
    si, sj, si_c, sj_c = _batch_scales(pp.row.shape[0], pp.col.shape[0], bt,
                                       conflict_free, scales)
    db, dbh, du, dv, dw, dc = _culsh_deltas(
        bt, e, aux, b_i, bh_j, ui, vj, wj, cj, hp, decay, si, sj, si_c, sj_c)
    return dataclasses.replace(
        pp,
        row=pp.row.at[bt.i].add(jnp.concatenate([du, db[:, None]], axis=1)),
        col=pp.col.at[bt.j].add(
            jnp.concatenate([dv, dw, dc, dbh[:, None]], axis=1)))


@partial(jax.jit, static_argnames=("batch", "mf_only", "bce"),
         donate_argnames=("p",))
def train_epoch(p: Params, sp: SparseMatrix, JK: jax.Array, key: jax.Array,
                epoch: jax.Array, hp: Hyper, *, batch: int = 4096,
                mf_only: bool = False, bce: bool = False) -> Params:
    """One epoch: shuffled mini-batches scanned with the fused step.

    The general-case engine: per-batch binary-search assembly and collision
    rescaling, correct for any batching.  Offline fits should prefer
    `train_epoch_scheduled`, which precomputes both.  ``p`` is donated —
    U/V/… update in place across epochs instead of ping-ponging buffers.
    """
    idx, valid = epoch_batches(key, sp.nnz, batch)
    decay = lr_decay(hp, epoch)

    def body(pp, ib):
        bidx, bvalid = ib
        bt = assemble(sp, JK, bidx, bvalid)
        pp = (mf_step(pp, bt, hp, decay, bce) if mf_only
              else culsh_step(pp, bt, hp, decay, bce))
        return pp, None

    p, _ = jax.lax.scan(body, p, (idx, valid))
    return p


def _cf_scan(pp: PackedParams, sd: ScheduledData, starts, valid, hp, decay, *,
             width: int, mf_only: bool, bce: bool, conflict_free: bool,
             use_kernels: bool, impl: str, interpret: bool, tile_b: int,
             bh_nb_src: jax.Array | None = None,
             scales=None) -> PackedParams:
    """Scan one schedule tier: contiguous window assembly + packed step.

    ``bh_nb_src`` (an epoch-start b̂ snapshot) switches the neighbour
    baselines to the shard-tier stale-read semantics — the single-device
    replay of a block-aligned tier must match `jax.shard_map` bit-for-bit,
    and under sharding the live b̂ of other devices' col blocks simply
    does not exist locally.  ``scales`` carries the per-batch precomputed
    collision normalizers for the leftover tier."""

    valid = valid.astype(jnp.float32)   # once per tier, not per scan step
    xs = ((starts, valid) if scales is None
          else (starts, valid, scales[0], scales[1]))

    def body(p_, sv):
        if scales is None:
            s, val = sv
            sc = None
        else:
            s, val, si, sj = sv
            sc = (si, sj)
        bt = slice_batch(sd, s, width, val)
        bh_nb = None if bh_nb_src is None else bh_nb_src[bt.nb]
        if use_kernels and conflict_free and bh_nb is None:
            if mf_only:
                p_ = apply_mf_sgd(p_, bt, hp, decay, impl=impl,
                                  tile_b=tile_b, interpret=interpret, bce=bce)
            else:
                p_ = apply_culsh_sgd(p_, bt, hp, decay, impl=impl,
                                     tile_b=tile_b, interpret=interpret,
                                     bce=bce)
        elif mf_only:
            p_ = mf_step_packed(p_, bt, hp, decay, bce,
                                conflict_free=conflict_free, scales=sc)
        else:
            p_ = culsh_step_packed(p_, bt, hp, decay, bce,
                                   conflict_free=conflict_free, bh_nb=bh_nb,
                                   scales=sc)
        return p_, None

    pp, _ = jax.lax.scan(body, pp, xs)
    return pp


_SHD_FIELDS = ("i", "j", "r", "nb", "rnb", "expl")


def _shard_round_shuffle(shd: ShardData, sched: EpochSchedule, key):
    """Per-epoch round permutation for the block-aligned tier.

    Rounds are permuted *within* each sub-epoch, identically across
    devices: batches at the same (s, r) touch disjoint blocks by
    construction, so any common round order preserves both
    conflict-freedom and single-device/shard-map parity.  Returns the
    round-permuted (ShardData, valid)."""
    D, S, R = sched.shard_starts.shape
    if R == 0:
        return shd, sched.shard_valid
    perms = jax.vmap(lambda k: jax.random.permutation(k, R))(
        jax.random.split(key, S))                      # [S, R]

    def prm(a):
        idx = perms.reshape((1, S, R) + (1,) * (a.ndim - 3))
        return jnp.take_along_axis(a, idx, axis=2)

    return jax.tree.map(prm, shd), prm(sched.shard_valid)


def _cell_batch(bi, bj, br, bnb, brnb, bexpl, val) -> Batch:
    """A dense ShardData cell *is* the batch — no window slicing."""
    return Batch(i=bi, j=bj, r=br, nb=bnb, rnb=brnb, expl=bexpl,
                 impl=1.0 - bexpl, valid=val)


def _shard_replay(pp: PackedParams, shd: ShardData, valid,
                  sched: EpochSchedule, hp: Hyper, decay, *,
                  mf_only: bool, bce: bool) -> PackedParams:
    """Single-device replay of the shard tier in the identical (s, r, d)
    cell order and with the identical epoch-start b̂ snapshot — bit-equal
    to the `jax.shard_map` path (a step's D cells touch disjoint
    parameter blocks, so sequential scatter == parallel block update)."""
    D, S, R = sched.shard_starts.shape
    bh0 = None if mf_only else pp.bh
    flat = lambda a: jnp.moveaxis(a, 0, 2).reshape((S * R * D,) + a.shape[3:])
    xs = tuple(flat(getattr(shd, f)) for f in _SHD_FIELDS) + (
        flat(valid.astype(jnp.float32)),)

    def body(p_, sv):
        bt = _cell_batch(*sv)
        if mf_only:
            p_ = mf_step_packed(p_, bt, hp, decay, bce, conflict_free=True)
        else:
            p_ = culsh_step_packed(p_, bt, hp, decay, bce, conflict_free=True,
                                   bh_nb=bh0[bt.nb])
        return p_, None

    pp, _ = jax.lax.scan(body, pp, xs)
    return pp


def _sharded_tier(pp: PackedParams, shd: ShardData, valid,
                  sched: EpochSchedule, hp: Hyper, decay, mesh, *,
                  mf_only: bool, bce: bool) -> PackedParams:
    """Run the block-aligned tier under `jax.shard_map` (cuMF rotation).

    Device ``d`` scans sub-epoch ``s``'s rounds for block ``((d+s)%D, d)``:
    the col plane (V/W/C/b̂ blocks) stays put, the row plane (U/b blocks)
    ring-rotates once per sub-epoch — a *single* `ppermute` per rotation
    now that U and b travel in one packed plane, and after D rotations
    every row block is back home so the out-specs reassemble the planes
    positionally.  The `ShardData` cells shard with the device axis
    (``P("shard")``): each device holds only its own cells' triples.
    Neighbour baselines b̂[nb] use the epoch-start snapshot ``bh0`` since
    neighbour cols cross block boundaries.  Planes must be in the
    schedule's block-padded id space (`model.remap_params`)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    D = sched.shards
    mB, nB = sched.block_rows, sched.block_cols
    F, K = pp.F, pp.K
    bh0 = pp.bh
    blocks = lambda a, nb: a.reshape((D, nb) + a.shape[1:])

    def device_fn(rowb, colb, mu, bh0, decay, shd_d, valid_d):
        d = jax.lax.axis_index("shard")
        rowb, colb = rowb[0], colb[0]
        data = jax.tree.map(lambda a: a[0], shd_d)
        valid_d = valid_d[0].astype(jnp.float32)
        col0 = d * nB

        def make_step(row0):
            def step(carry, sv):
                rowp, colp = carry
                bt = _cell_batch(*sv)
                ok = ((bt.i >= row0) & (bt.i < row0 + mB)
                      & (bt.j >= col0) & (bt.j < col0 + nB))
                bt = dataclasses.replace(
                    bt, i=jnp.clip(bt.i - row0, 0, mB - 1),
                    j=jnp.clip(bt.j - col0, 0, nB - 1),
                    valid=bt.valid * ok)
                pl = PackedParams(row=rowp, col=colp, mu=mu, F=F, K=K)
                if mf_only:
                    pl = mf_step_packed(pl, bt, hp, decay, bce,
                                        conflict_free=True)
                else:
                    pl = culsh_step_packed(pl, bt, hp, decay, bce,
                                           conflict_free=True,
                                           bh_nb=bh0[bt.nb])
                return (pl.row, pl.col), None
            return step

        ring = [(i, (i - 1) % D) for i in range(D)]
        for s in range(D):
            row0 = ((d + s) % D) * mB
            xs = tuple(getattr(data, f)[s] for f in _SHD_FIELDS) + (
                valid_d[s],)
            (rowb, colb), _ = jax.lax.scan(make_step(row0), (rowb, colb), xs)
            rowb = jax.lax.ppermute(rowb, "shard", ring)
        return rowb[None], colb[None]

    sh = P("shard")
    fn = shard_map(
        device_fn, mesh=mesh,
        in_specs=(sh, sh, P(), P(), P(), sh, sh),
        out_specs=(sh, sh))
    row, col = fn(blocks(pp.row, mB), blocks(pp.col, nB), pp.mu, bh0, decay,
                  shd, valid)
    unb = lambda a: a.reshape((-1,) + a.shape[2:])
    return dataclasses.replace(pp, row=unb(row), col=unb(col))


@partial(jax.jit,
         static_argnames=("mf_only", "bce", "use_kernels", "impl",
                          "interpret", "tile_b", "mesh"),
         donate_argnames=("pp",))
def train_epoch_scheduled(pp: PackedParams, sd: ScheduledData,
                          sched: EpochSchedule, key: jax.Array,
                          epoch: jax.Array, hp: Hyper, *,
                          shd: ShardData | None = None,
                          mf_only: bool = False, bce: bool = False,
                          use_kernels: bool = False, impl: str = "ref",
                          interpret: bool = True, tile_b: int = 256,
                          mesh=None) -> PackedParams:
    """One epoch over a tiered conflict-free schedule (the offline hot path).

    cuMF_SGD's conflict-free fine-grained SGD, tiered and laid out for the
    compiler:

    * parameters live in the two packed planes (`model.PackedParams`), so
      every step is two gather/scatter pairs, not six;
    * batch assembly is a contiguous `dynamic_slice` of the schedule-
      ordered `ScheduledData` — no per-batch gather or binary search;
    * the block-aligned shard tier (if the schedule has one) runs first
      over the dense `ShardData` cells (pass ``shd``) — under
      `jax.shard_map` over ``mesh`` when given (cells sharded with the
      device axis), otherwise replayed sequentially in the identical
      (s, r, d) order (exact parity: the D batches of a step touch
      disjoint parameter blocks);
    * each width tier is one `lax.scan` of exact Eq. (5) steps (static
      shapes per tier), optionally through the fused `kernels/mf_sgd`
      step (``use_kernels``; ``impl`` pre-resolved via `ops.resolve_impl`
      outside jit, tile auto-clamped to the tier width);
    * leftover batches (zipf heads) fall back to the scaled summed step
      with their collision normalizers precomputed in the schedule
      (`lo_scale_*`) — no per-batch O(M)+O(N) recount;
    * ``pp`` is donated so parameters update in place across epochs.

    Batch order is reshuffled every epoch (conflict-freedom is invariant
    under batch permutation); within-batch composition is fixed per fit.
    """
    decay = lr_decay(hp, epoch)
    keys = jax.random.split(key, 2 + len(sched.tier_starts))
    kw = dict(mf_only=mf_only, bce=bce, use_kernels=use_kernels, impl=impl,
              interpret=interpret)

    if sched.shard_span:
        if shd is None:
            raise ValueError("schedule has a shard tier — pass "
                             "shd=model.build_shard_data(...)")
        shd_p, valid_p = _shard_round_shuffle(shd, sched, keys[0])
        if mesh is not None:
            pp = _sharded_tier(pp, shd_p, valid_p, sched, hp, decay, mesh,
                               mf_only=mf_only, bce=bce)
        else:
            # same cells, same (s, r, d) order, same b̂ snapshot → parity
            pp = _shard_replay(pp, shd_p, valid_p, sched, hp, decay,
                               mf_only=mf_only, bce=bce)

    for t, (starts, valid) in enumerate(zip(sched.tier_starts,
                                            sched.tier_valid)):
        if not starts.shape[0]:
            continue
        order = jax.random.permutation(keys[2 + t], starts.shape[0])
        # tile_b passes through unclamped: kernel._clamp_tile aligns the
        # tile to the batch rounded up to the sublane multiple, which a
        # min() against a non-power-of-two tier width would defeat
        pp = _cf_scan(pp, sd, starts[order], valid[order], hp, decay,
                      width=sched.widths[t], conflict_free=True,
                      tile_b=tile_b, **kw)

    if sched.lo_starts.shape[0]:
        order = jax.random.permutation(keys[1], sched.lo_starts.shape[0])
        pp = _cf_scan(pp, sd, sched.lo_starts[order], sched.lo_valid[order],
                      hp, decay, width=sched.widths[0], conflict_free=False,
                      tile_b=tile_b,
                      scales=(sched.lo_scale_i[order],
                              sched.lo_scale_j[order]),
                      **kw | dict(use_kernels=False))
    return pp
