"""Stochastic optimization — paper Eq. (4)/(5) updates + Eq. (7) dynamic LR.

Two engines, mirroring the paper's two contributions:

* ``mf_step``        — CUSGD++ analogue: plain MF {U, V} only.
* ``culsh_step``     — CULSH-MF: the full six-parameter fused update.

TPU adaptation (DESIGN.md §2/§8.1): updates are applied to a *mini-batch*
with scatter-add (`.at[].add`).  When the batch is conflict-free (each i and
each j at most once — the invariant the paper's D×D blocking provides) this
is *exactly* Eq. (5) applied in parallel; with collisions it is the summed
batch-SGD step.  Both engines are pure functions scanned over an epoch.

Two epoch drivers:

* ``train_epoch``            — general case: binary-search batch assembly +
  collision rescaling every batch (also the Alg.-4 online building block).
* ``train_epoch_scheduled``  — offline hot path: contiguous-slice assembly
  from the schedule-ordered `ScheduledData`, width-tiered conflict-free
  scans (+ optional fused Pallas kernels), an optional shard_map
  block-rotation tier, params donated across epochs.  See bench_train.py.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.model import (Batch, Params, ScheduledData, assemble,
                              predict, predict_mf, slice_batch)
from repro.data.sparse import EpochSchedule, SparseMatrix, epoch_batches
from repro.kernels.mf_sgd.ops import apply_culsh_sgd, apply_mf_sgd


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Hyper:
    # initial learning rates (paper Table 3/5 names)
    a_b: float = 0.02
    a_bh: float = 0.02
    a_u: float = 0.02
    a_v: float = 0.02
    a_w: float = 0.001
    a_c: float = 0.001
    # regularization
    l_b: float = 0.01
    l_bh: float = 0.01
    l_u: float = 0.01
    l_v: float = 0.01
    l_w: float = 0.05
    l_c: float = 0.05
    # Eq. (7) decay
    beta: float = 0.3


def lr_decay(hp: Hyper, t: jax.Array) -> jax.Array:
    """γ_t = α / (1 + β·t^1.5) — Eq. (7); returns the *decay factor*."""
    return 1.0 / (1.0 + hp.beta * jnp.power(t.astype(jnp.float32), 1.5))


def _collision_scales(p: Params, bt: Batch):
    """1/count normalizers so rows hit k× in a batch get the *mean* update
    (zipf heads would otherwise receive k summed steps and diverge).
    Conflict-free batches have all counts = 1 → exact Eq. (5)."""
    ci = jnp.zeros((p.U.shape[0],), jnp.float32).at[bt.i].add(bt.valid)
    cj = jnp.zeros((p.V.shape[0],), jnp.float32).at[bt.j].add(bt.valid)
    si = 1.0 / jnp.maximum(ci[bt.i], 1.0)
    sj = 1.0 / jnp.maximum(cj[bt.j], 1.0)
    return si, sj


def _error(r, pred, bce: bool):
    """e_ij: residual (L2) or r − σ(pred) (BCE — the paper's implicit-
    feedback variant: "we change the loss function ... to cross entropy,
    and the update formula will follow the corresponding change")."""
    return r - (jax.nn.sigmoid(pred) if bce else pred)


def _scales(p: Params, bt: Batch, conflict_free: bool):
    """(si, sj, si_col, sj_col) — collision normalizers and their [B, 1]
    broadcasts.  ``conflict_free`` (a static promise that each i and j
    appears at most once, the D×D-block invariant) elides the two
    O(M)+O(N) scatter-add allocations entirely: all counts are 1."""
    if conflict_free:
        one = jnp.ones((), jnp.float32)
        return one, one, one, one
    si, sj = _collision_scales(p, bt)
    return si, sj, si[:, None], sj[:, None]


def mf_step(p: Params, bt: Batch, hp: Hyper, decay, bce: bool = False,
            conflict_free: bool = False) -> Params:
    """CUSGD++: u_i ← u_i + γ(e·v_j − λu·u_i);  v symmetric."""
    e = _error(bt.r, predict_mf(p, bt), bce) * bt.valid
    ui, vj = p.U[bt.i], p.V[bt.j]
    _, _, si_c, sj_c = _scales(p, bt, conflict_free)
    gu = hp.a_u * decay
    gv = hp.a_v * decay
    vmask = bt.valid[:, None]
    U = p.U.at[bt.i].add(gu * (e[:, None] * vj - hp.l_u * ui) * vmask * si_c)
    V = p.V.at[bt.j].add(gv * (e[:, None] * ui - hp.l_v * vj) * vmask * sj_c)
    return dataclasses.replace(p, U=U, V=V)


def culsh_step(p: Params, bt: Batch, hp: Hyper, decay,
               bce: bool = False, conflict_free: bool = False,
               bh_nb: jax.Array | None = None) -> Params:
    """CULSH-MF: the fused Eq. (5) update of {b, b̂, U, V, W, C}.

    With ``conflict_free`` (static) the batch is promised to touch each i
    and each j at most once (the D×D-block invariant), making the summed
    scatter exactly the parallel Eq. (5) with no rescaling.  ``bh_nb``
    optionally substitutes pre-gathered neighbour baselines (see
    `model.predict` — the shard-tier stale-read)."""
    pred, aux = predict(p, bt, bh_nb=bh_nb)
    e = _error(bt.r, pred, bce) * bt.valid
    vmask = bt.valid[:, None]
    ui, vj = p.U[bt.i], p.V[bt.j]
    si, sj, si_c, sj_c = _scales(p, bt, conflict_free)

    d = decay
    b = p.b.at[bt.i].add(hp.a_b * d * (e - hp.l_b * p.b[bt.i]) * bt.valid * si)
    bh = p.bh.at[bt.j].add(hp.a_bh * d * (e - hp.l_bh * p.bh[bt.j])
                           * bt.valid * sj)
    U = p.U.at[bt.i].add(hp.a_u * d * (e[:, None] * vj - hp.l_u * ui) * vmask
                         * si_c)
    V = p.V.at[bt.j].add(hp.a_v * d * (e[:, None] * ui - hp.l_v * vj) * vmask
                         * sj_c)
    # w_{j,k} ← w + γw(|R|^{-1/2}·e·(r_nb − b̄_nb) − λw·w) on explicit slots
    wj, cj = p.W[bt.j], p.C[bt.j]
    dw = (aux["sR"][:, None] * e[:, None] * aux["resid"] - hp.l_w * wj) * bt.expl
    dc = (aux["sN"][:, None] * e[:, None] - hp.l_c * cj) * bt.impl
    W = p.W.at[bt.j].add(hp.a_w * d * dw * vmask * sj_c)
    C = p.C.at[bt.j].add(hp.a_c * d * dc * vmask * sj_c)
    return dataclasses.replace(p, b=b, bh=bh, U=U, V=V, W=W, C=C)


@partial(jax.jit, static_argnames=("batch", "mf_only", "bce"),
         donate_argnames=("p",))
def train_epoch(p: Params, sp: SparseMatrix, JK: jax.Array, key: jax.Array,
                epoch: jax.Array, hp: Hyper, *, batch: int = 4096,
                mf_only: bool = False, bce: bool = False) -> Params:
    """One epoch: shuffled mini-batches scanned with the fused step.

    The general-case engine: per-batch binary-search assembly and collision
    rescaling, correct for any batching.  Offline fits should prefer
    `train_epoch_scheduled`, which precomputes both.  ``p`` is donated —
    U/V/… update in place across epochs instead of ping-ponging buffers.
    """
    idx, valid = epoch_batches(key, sp.nnz, batch)
    decay = lr_decay(hp, epoch)

    def body(pp, ib):
        bidx, bvalid = ib
        bt = assemble(sp, JK, bidx, bvalid)
        pp = (mf_step(pp, bt, hp, decay, bce) if mf_only
              else culsh_step(pp, bt, hp, decay, bce))
        return pp, None

    p, _ = jax.lax.scan(body, p, (idx, valid))
    return p


def _cf_scan(p: Params, sd: ScheduledData, starts, valid, hp, decay, *,
             width: int, mf_only: bool, bce: bool, conflict_free: bool,
             use_kernels: bool, impl: str, interpret: bool, tile_b: int,
             bh_nb_src: jax.Array | None = None) -> Params:
    """Scan one schedule tier: contiguous window assembly + fused step.

    ``bh_nb_src`` (an epoch-start b̂ snapshot) switches the neighbour
    baselines to the shard-tier stale-read semantics — the single-device
    replay of a block-aligned tier must match `jax.shard_map` bit-for-bit,
    and under sharding the live b̂ of other devices' col blocks simply
    does not exist locally."""

    valid = valid.astype(jnp.float32)   # once per tier, not per scan step

    def body(pp, sv):
        s, val = sv
        bt = slice_batch(sd, s, width, val)
        bh_nb = None if bh_nb_src is None else bh_nb_src[bt.nb]
        if use_kernels and conflict_free and bh_nb is None:
            if mf_only:
                pp = apply_mf_sgd(pp, bt.i, bt.j, bt.r, bt.valid, hp, decay,
                                  impl=impl, tile_b=tile_b,
                                  interpret=interpret, bce=bce)
            else:
                pp = apply_culsh_sgd(pp, bt, hp, decay, impl=impl,
                                     tile_b=tile_b, interpret=interpret,
                                     bce=bce)
        elif mf_only:
            pp = mf_step(pp, bt, hp, decay, bce, conflict_free=conflict_free)
        else:
            pp = culsh_step(pp, bt, hp, decay, bce,
                            conflict_free=conflict_free, bh_nb=bh_nb)
        return pp, None

    p, _ = jax.lax.scan(body, p, (starts, valid))
    return p


def _shard_round_shuffle(sched: EpochSchedule, key: jax.Array):
    """Per-epoch round permutation for the block-aligned tier.

    Rounds are permuted *within* each sub-epoch, identically across
    devices: batches at the same (s, r) touch disjoint blocks by
    construction, so any common round order preserves both
    conflict-freedom and single-device/shard-map parity."""
    D, S, R = sched.shard_starts.shape
    if R == 0:
        return sched.shard_starts, sched.shard_valid
    perms = jax.vmap(lambda k: jax.random.permutation(k, R))(
        jax.random.split(key, S))                      # [S, R]
    starts = jnp.take_along_axis(sched.shard_starts, perms[None], axis=2)
    valid = jnp.take_along_axis(
        sched.shard_valid, perms[None, :, :, None], axis=2)
    return starts, valid


def _sharded_tier(p: Params, sd: ScheduledData, sched: EpochSchedule,
                  starts, valid, hp: Hyper, decay, mesh, *,
                  mf_only: bool, bce: bool) -> Params:
    """Run the block-aligned tier under `jax.shard_map` (cuMF rotation).

    Device ``d`` scans sub-epoch ``s``'s rounds for block ``((d+s)%D, d)``:
    V/b̂/W/C col blocks stay put, U/b row blocks ring-rotate once per
    sub-epoch (`ppermute` — the only collective; no psum anywhere, and
    after D rotations every row block is back home so the out-specs
    reassemble the params positionally).  The schedule data stays
    replicated (windows are cheap slices); neighbour baselines b̂[nb] use
    the epoch-start snapshot ``bh0`` since neighbour cols cross block
    boundaries.  Params must be padded to D·block_rows / D·block_cols.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    D = sched.shards
    mB, nB = sched.block_rows, sched.block_cols
    Wsh = sched.shard_width
    bh0 = p.bh
    blocks = lambda a, nb: a.reshape((D, nb) + a.shape[1:])

    def device_fn(Ub, bb, Vb, bhb, Wb, Cb, mu, bh0, decay, starts_d, valid_d):
        d = jax.lax.axis_index("shard")
        Ub, bb, Vb, bhb, Wb, Cb = (a[0] for a in (Ub, bb, Vb, bhb, Wb, Cb))
        starts_d, valid_d = starts_d[0], valid_d[0]
        col0 = d * nB

        def make_step(row0):
            def step(carry, sv):
                Ub, bb, Vb, bhb, Wb, Cb = carry
                s, val = sv
                bt = slice_batch(sd, s, Wsh, val)
                ok = ((bt.i >= row0) & (bt.i < row0 + mB)
                      & (bt.j >= col0) & (bt.j < col0 + nB))
                bt = dataclasses.replace(
                    bt, i=jnp.clip(bt.i - row0, 0, mB - 1),
                    j=jnp.clip(bt.j - col0, 0, nB - 1),
                    valid=bt.valid * ok)
                pl = Params(U=Ub, V=Vb, b=bb, bh=bhb, W=Wb, C=Cb, mu=mu)
                if mf_only:
                    pl = mf_step(pl, bt, hp, decay, bce, conflict_free=True)
                else:
                    pl = culsh_step(pl, bt, hp, decay, bce,
                                    conflict_free=True, bh_nb=bh0[bt.nb])
                return (pl.U, pl.b, pl.V, pl.bh, pl.W, pl.C), None
            return step

        ring = [(i, (i - 1) % D) for i in range(D)]
        for s in range(D):
            row0 = ((d + s) % D) * mB
            (Ub, bb, Vb, bhb, Wb, Cb), _ = jax.lax.scan(
                make_step(row0), (Ub, bb, Vb, bhb, Wb, Cb),
                (starts_d[s], valid_d[s]))
            Ub = jax.lax.ppermute(Ub, "shard", ring)
            bb = jax.lax.ppermute(bb, "shard", ring)
        return tuple(a[None] for a in (Ub, bb, Vb, bhb, Wb, Cb))

    sh = lambda *rest: P("shard", *rest)
    fn = shard_map(
        device_fn, mesh=mesh,
        in_specs=(sh(None, None), sh(None), sh(None, None), sh(None),
                  sh(None, None), sh(None, None), P(), P(), P(),
                  sh(None, None), sh(None, None, None)),
        out_specs=(sh(None, None), sh(None), sh(None, None), sh(None),
                   sh(None, None), sh(None, None)))
    U, b, V, bh, W, C = fn(blocks(p.U, mB), blocks(p.b, mB),
                           blocks(p.V, nB), blocks(p.bh, nB),
                           blocks(p.W, nB), blocks(p.C, nB),
                           p.mu, bh0, decay, starts, valid)
    unb = lambda a: a.reshape((-1,) + a.shape[2:])
    return Params(U=unb(U), V=unb(V), b=unb(b), bh=unb(bh),
                  W=unb(W), C=unb(C), mu=p.mu)


@partial(jax.jit,
         static_argnames=("mf_only", "bce", "use_kernels", "impl",
                          "interpret", "tile_b", "mesh"),
         donate_argnames=("p",))
def train_epoch_scheduled(p: Params, sd: ScheduledData,
                          sched: EpochSchedule, key: jax.Array,
                          epoch: jax.Array, hp: Hyper, *,
                          mf_only: bool = False, bce: bool = False,
                          use_kernels: bool = False, impl: str = "ref",
                          interpret: bool = True, tile_b: int = 256,
                          mesh=None) -> Params:
    """One epoch over a tiered conflict-free schedule (the offline hot path).

    cuMF_SGD's conflict-free fine-grained SGD, tiered and laid out for the
    compiler:

    * batch assembly is a contiguous `dynamic_slice` of the schedule-
      ordered `ScheduledData` — no per-batch gather or binary search;
    * the block-aligned shard tier (if `sched.shards > 1`) runs first —
      under `jax.shard_map` over ``mesh`` when given, otherwise replayed
      sequentially in the identical (s, r, d) order (exact parity: the D
      batches of a step touch disjoint parameter blocks);
    * each width tier is one `lax.scan` of exact Eq. (5) steps (static
      shapes per tier), optionally through the fused `kernels/mf_sgd`
      step (``use_kernels``; ``impl`` pre-resolved via `ops.resolve_impl`
      outside jit, tile auto-clamped to the tier width);
    * leftover batches (zipf heads) fall back to the scaled summed step;
    * ``p`` is donated so parameters update in place across epochs.

    Batch order is reshuffled every epoch (conflict-freedom is invariant
    under batch permutation); within-batch composition is fixed per fit.
    """
    decay = lr_decay(hp, epoch)
    keys = jax.random.split(key, 2 + len(sched.tier_starts))
    kw = dict(mf_only=mf_only, bce=bce, use_kernels=use_kernels, impl=impl,
              interpret=interpret)

    if sched.shard_starts.size:
        starts, valid = _shard_round_shuffle(sched, keys[0])
        if mesh is not None:
            p = _sharded_tier(p, sd, sched, starts, valid, hp, decay, mesh,
                              mf_only=mf_only, bce=bce)
        else:
            # same cells, same (s, r, d) order, same b̂ snapshot → parity
            D, S, R = starts.shape
            flat_s = jnp.transpose(starts, (1, 2, 0)).reshape(S * R * D)
            flat_v = jnp.transpose(valid, (1, 2, 0, 3)).reshape(
                S * R * D, sched.shard_width)
            p = _cf_scan(p, sd, flat_s, flat_v, hp, decay,
                         width=sched.shard_width, conflict_free=True,
                         tile_b=tile_b,
                         bh_nb_src=None if mf_only else p.bh,
                         **kw | dict(use_kernels=False))

    for t, (starts, valid) in enumerate(zip(sched.tier_starts,
                                            sched.tier_valid)):
        if not starts.shape[0]:
            continue
        order = jax.random.permutation(keys[2 + t], starts.shape[0])
        # tile_b passes through unclamped: kernel._clamp_tile aligns the
        # tile to the batch rounded up to the sublane multiple, which a
        # min() against a non-power-of-two tier width would defeat
        p = _cf_scan(p, sd, starts[order], valid[order], hp, decay,
                     width=sched.widths[t], conflict_free=True,
                     tile_b=tile_b, **kw)

    if sched.lo_starts.shape[0]:
        order = jax.random.permutation(keys[1], sched.lo_starts.shape[0])
        p = _cf_scan(p, sd, sched.lo_starts[order], sched.lo_valid[order],
                     hp, decay, width=sched.widths[0], conflict_free=False,
                     tile_b=tile_b, **kw | dict(use_kernels=False))
    return p
