"""Stochastic optimization — paper Eq. (4)/(5) updates + Eq. (7) dynamic LR.

Two engines, mirroring the paper's two contributions:

* ``mf_step``        — CUSGD++ analogue: plain MF {U, V} only.
* ``culsh_step``     — CULSH-MF: the full six-parameter fused update.

TPU adaptation (DESIGN.md §2/§8.1): updates are applied to a *mini-batch*
with scatter-add (`.at[].add`).  When the batch is conflict-free (each i and
each j at most once — the invariant the paper's D×D blocking provides) this
is *exactly* Eq. (5) applied in parallel; with collisions it is the summed
batch-SGD step.  Both engines are pure functions scanned over an epoch.

Two epoch drivers:

* ``train_epoch``            — general case: binary-search batch assembly +
  collision rescaling every batch (also the Alg.-4 online building block).
* ``train_epoch_scheduled``  — offline hot path: per-fit `NeighbourCache`
  gathers + `EpochSchedule` conflict-free batches (+ optional fused Pallas
  kernels), with params donated across epochs.  See bench_train.py.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.model import (Batch, NeighbourCache, Params, assemble,
                              assemble_cached, predict, predict_mf)
from repro.data.sparse import EpochSchedule, SparseMatrix, epoch_batches
from repro.kernels.mf_sgd.ops import apply_culsh_sgd, apply_mf_sgd


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Hyper:
    # initial learning rates (paper Table 3/5 names)
    a_b: float = 0.02
    a_bh: float = 0.02
    a_u: float = 0.02
    a_v: float = 0.02
    a_w: float = 0.001
    a_c: float = 0.001
    # regularization
    l_b: float = 0.01
    l_bh: float = 0.01
    l_u: float = 0.01
    l_v: float = 0.01
    l_w: float = 0.05
    l_c: float = 0.05
    # Eq. (7) decay
    beta: float = 0.3


def lr_decay(hp: Hyper, t: jax.Array) -> jax.Array:
    """γ_t = α / (1 + β·t^1.5) — Eq. (7); returns the *decay factor*."""
    return 1.0 / (1.0 + hp.beta * jnp.power(t.astype(jnp.float32), 1.5))


def _collision_scales(p: Params, bt: Batch):
    """1/count normalizers so rows hit k× in a batch get the *mean* update
    (zipf heads would otherwise receive k summed steps and diverge).
    Conflict-free batches have all counts = 1 → exact Eq. (5)."""
    ci = jnp.zeros((p.U.shape[0],), jnp.float32).at[bt.i].add(bt.valid)
    cj = jnp.zeros((p.V.shape[0],), jnp.float32).at[bt.j].add(bt.valid)
    si = 1.0 / jnp.maximum(ci[bt.i], 1.0)
    sj = 1.0 / jnp.maximum(cj[bt.j], 1.0)
    return si, sj


def _error(r, pred, bce: bool):
    """e_ij: residual (L2) or r − σ(pred) (BCE — the paper's implicit-
    feedback variant: "we change the loss function ... to cross entropy,
    and the update formula will follow the corresponding change")."""
    return r - (jax.nn.sigmoid(pred) if bce else pred)


def _scales(p: Params, bt: Batch, conflict_free: bool):
    """(si, sj, si_col, sj_col) — collision normalizers and their [B, 1]
    broadcasts.  ``conflict_free`` (a static promise that each i and j
    appears at most once, the D×D-block invariant) elides the two
    O(M)+O(N) scatter-add allocations entirely: all counts are 1."""
    if conflict_free:
        one = jnp.ones((), jnp.float32)
        return one, one, one, one
    si, sj = _collision_scales(p, bt)
    return si, sj, si[:, None], sj[:, None]


def mf_step(p: Params, bt: Batch, hp: Hyper, decay, bce: bool = False,
            conflict_free: bool = False) -> Params:
    """CUSGD++: u_i ← u_i + γ(e·v_j − λu·u_i);  v symmetric."""
    e = _error(bt.r, predict_mf(p, bt), bce) * bt.valid
    ui, vj = p.U[bt.i], p.V[bt.j]
    _, _, si_c, sj_c = _scales(p, bt, conflict_free)
    gu = hp.a_u * decay
    gv = hp.a_v * decay
    vmask = bt.valid[:, None]
    U = p.U.at[bt.i].add(gu * (e[:, None] * vj - hp.l_u * ui) * vmask * si_c)
    V = p.V.at[bt.j].add(gv * (e[:, None] * ui - hp.l_v * vj) * vmask * sj_c)
    return dataclasses.replace(p, U=U, V=V)


def culsh_step(p: Params, bt: Batch, hp: Hyper, decay,
               bce: bool = False, conflict_free: bool = False) -> Params:
    """CULSH-MF: the fused Eq. (5) update of {b, b̂, U, V, W, C}.

    With ``conflict_free`` (static) the batch is promised to touch each i
    and each j at most once (the D×D-block invariant), making the summed
    scatter exactly the parallel Eq. (5) with no rescaling."""
    pred, aux = predict(p, bt)
    e = _error(bt.r, pred, bce) * bt.valid
    vmask = bt.valid[:, None]
    ui, vj = p.U[bt.i], p.V[bt.j]
    si, sj, si_c, sj_c = _scales(p, bt, conflict_free)

    d = decay
    b = p.b.at[bt.i].add(hp.a_b * d * (e - hp.l_b * p.b[bt.i]) * bt.valid * si)
    bh = p.bh.at[bt.j].add(hp.a_bh * d * (e - hp.l_bh * p.bh[bt.j])
                           * bt.valid * sj)
    U = p.U.at[bt.i].add(hp.a_u * d * (e[:, None] * vj - hp.l_u * ui) * vmask
                         * si_c)
    V = p.V.at[bt.j].add(hp.a_v * d * (e[:, None] * ui - hp.l_v * vj) * vmask
                         * sj_c)
    # w_{j,k} ← w + γw(|R|^{-1/2}·e·(r_nb − b̄_nb) − λw·w) on explicit slots
    wj, cj = p.W[bt.j], p.C[bt.j]
    dw = (aux["sR"][:, None] * e[:, None] * aux["resid"] - hp.l_w * wj) * bt.expl
    dc = (aux["sN"][:, None] * e[:, None] - hp.l_c * cj) * bt.impl
    W = p.W.at[bt.j].add(hp.a_w * d * dw * vmask * sj_c)
    C = p.C.at[bt.j].add(hp.a_c * d * dc * vmask * sj_c)
    return dataclasses.replace(p, b=b, bh=bh, U=U, V=V, W=W, C=C)


@partial(jax.jit, static_argnames=("batch", "mf_only", "bce"),
         donate_argnames=("p",))
def train_epoch(p: Params, sp: SparseMatrix, JK: jax.Array, key: jax.Array,
                epoch: jax.Array, hp: Hyper, *, batch: int = 4096,
                mf_only: bool = False, bce: bool = False) -> Params:
    """One epoch: shuffled mini-batches scanned with the fused step.

    The general-case engine: per-batch binary-search assembly and collision
    rescaling, correct for any batching.  Offline fits should prefer
    `train_epoch_scheduled`, which precomputes both.  ``p`` is donated —
    U/V/… update in place across epochs instead of ping-ponging buffers.
    """
    idx, valid = epoch_batches(key, sp.nnz, batch)
    decay = lr_decay(hp, epoch)

    def body(pp, ib):
        bidx, bvalid = ib
        bt = assemble(sp, JK, bidx, bvalid)
        pp = (mf_step(pp, bt, hp, decay, bce) if mf_only
              else culsh_step(pp, bt, hp, decay, bce))
        return pp, None

    p, _ = jax.lax.scan(body, p, (idx, valid))
    return p


@partial(jax.jit,
         static_argnames=("mf_only", "bce", "use_kernels", "impl",
                          "interpret", "tile_b"),
         donate_argnames=("p",))
def train_epoch_scheduled(p: Params, sp: SparseMatrix, JK: jax.Array,
                          cache: NeighbourCache, sched: EpochSchedule,
                          key: jax.Array, epoch: jax.Array, hp: Hyper, *,
                          mf_only: bool = False, bce: bool = False,
                          use_kernels: bool = False, impl: str = "ref",
                          interpret: bool = True,
                          tile_b: int = 256) -> Params:
    """One epoch over a precomputed conflict-free schedule + gather cache.

    The optimized hot path (cf. cuMF_SGD's conflict-free fine-grained SGD):

    * batch assembly is plain `take` gathers from the per-fit
      `NeighbourCache` — no B×K binary search per batch;
    * conflict-free batches run the exact Eq. (5) step with no collision
      rescaling, optionally through the fused `kernels/mf_sgd` step
      (``use_kernels``; ``impl`` pre-resolved via `ops.resolve_impl` —
      resolution needs the backend, so it cannot happen under jit);
    * leftover batches (zipf heads) fall back to the scaled summed step;
    * ``p`` is donated so parameters update in place across epochs.

    Batch order is reshuffled every epoch (conflict-freedom is invariant
    under batch permutation); within-batch composition is fixed per fit.
    """
    decay = lr_decay(hp, epoch)
    k_cf, k_lo = jax.random.split(key)

    def cf_body(pp, ib):
        bidx, bvalid = ib
        bt = assemble_cached(sp, JK, cache, bidx, bvalid)
        if use_kernels and mf_only:
            pp = apply_mf_sgd(pp, bt.i, bt.j, bt.r, bt.valid, hp, decay,
                              impl=impl, tile_b=tile_b, interpret=interpret,
                              bce=bce)
        elif use_kernels:
            pp = apply_culsh_sgd(pp, bt, hp, decay, impl=impl, tile_b=tile_b,
                                 interpret=interpret, bce=bce)
        elif mf_only:
            pp = mf_step(pp, bt, hp, decay, bce, conflict_free=True)
        else:
            pp = culsh_step(pp, bt, hp, decay, bce, conflict_free=True)
        return pp, None

    def lo_body(pp, ib):
        bidx, bvalid = ib
        bt = assemble_cached(sp, JK, cache, bidx, bvalid)
        pp = (mf_step(pp, bt, hp, decay, bce) if mf_only
              else culsh_step(pp, bt, hp, decay, bce))
        return pp, None

    if sched.cf_idx.shape[0]:
        order = jax.random.permutation(k_cf, sched.cf_idx.shape[0])
        p, _ = jax.lax.scan(cf_body, p,
                            (sched.cf_idx[order], sched.cf_valid[order]))
    if sched.lo_idx.shape[0]:
        order = jax.random.permutation(k_lo, sched.lo_idx.shape[0])
        p, _ = jax.lax.scan(lo_body, p,
                            (sched.lo_idx[order], sched.lo_valid[order]))
    return p
