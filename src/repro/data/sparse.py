"""Sparse interaction-matrix substrate.

The paper's object of study is a sparse matrix ``R ∈ R^{M×N}`` between two
entity sets ``I`` (rows, e.g. users) and ``J`` (cols, e.g. items), stored as
COO triples.  Everything downstream (simLSH encoding, neighbour lookup,
conflict-free batching, rotation sharding) consumes this type.

Fixed-shape, jit-friendly by construction: all ragged structures are either
sorted flat arrays addressed with ``searchsorted`` or padded to static width.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SparseMatrix:
    """COO sparse matrix, (row, col)-lexicographically sorted.

    Rating lookup is a vectorized binary search over the sorted pair —
    int32-safe at any (M, N) scale (no M·N key that would overflow 2³¹),
    which turns the paper's per-row hash-table probe into a TPU-friendly
    O(log nnz) gather loop.
    """

    rows: jax.Array  # [nnz] int32, sorted (major)
    cols: jax.Array  # [nnz] int32, sorted within row (minor)
    vals: jax.Array  # [nnz] float32
    shape: tuple[int, int] = dataclasses.field(metadata=dict(static=True))

    @property
    def M(self) -> int:
        return self.shape[0]

    @property
    def N(self) -> int:
        return self.shape[1]

    @property
    def nnz(self) -> int:
        return int(self.rows.shape[0])


def from_coo(rows, cols, vals, shape) -> SparseMatrix:
    """Build a SparseMatrix from (unsorted, unique) COO triples."""
    M, N = shape
    rows = jnp.asarray(rows, jnp.int32)
    cols = jnp.asarray(cols, jnp.int32)
    vals = jnp.asarray(vals, jnp.float32)
    order = jnp.lexsort((cols, rows))
    return SparseMatrix(rows[order], cols[order], vals[order], (M, N))


@jax.jit
def lookup(sp: SparseMatrix, qi: jax.Array, qj: jax.Array):
    """Vectorized rating lookup r_{i,j} for query id arrays of any shape.

    Returns ``(vals, mask)`` where ``mask`` says whether (i, j) is observed.
    A hand-rolled binary search over the lexsorted (row, col) pair — int32
    overflow-safe, fully parallel over queries, O(log nnz) gathers each.
    """
    nnz = sp.rows.shape[0]
    steps = max(1, int(np.ceil(np.log2(max(nnz, 2)))) + 1)
    lo = jnp.zeros(qi.shape, jnp.int32)
    hi = jnp.full(qi.shape, nnz, jnp.int32)

    def body(_, lohi):
        lo, hi = lohi
        mid = (lo + hi) >> 1
        rm, cm = sp.rows[mid], sp.cols[mid]
        less = (rm < qi) | ((rm == qi) & (cm < qj))
        return jnp.where(less, mid + 1, lo), jnp.where(less, hi, mid)

    lo, hi = jax.lax.fori_loop(0, steps, body, (lo, hi))
    pos = jnp.clip(lo, 0, nnz - 1)
    hit = (sp.rows[pos] == qi) & (sp.cols[pos] == qj)
    return jnp.where(hit, sp.vals[pos], 0.0), hit


def degrees(sp: SparseMatrix):
    """(row_degree [M], col_degree [N]) — |Ω_i| and |Ω̂_j|."""
    dr = jnp.zeros((sp.M,), jnp.int32).at[sp.rows].add(1)
    dc = jnp.zeros((sp.N,), jnp.int32).at[sp.cols].add(1)
    return dr, dc


def baselines(sp: SparseMatrix, eps: float = 1e-9):
    """Paper §3.2 part ①: (μ, b_i [M], b̂_j [N]) from the observed entries."""
    mu = jnp.sum(sp.vals) / (sp.nnz + eps)
    dr, dc = degrees(sp)
    sr = jnp.zeros((sp.M,), jnp.float32).at[sp.rows].add(sp.vals)
    sc = jnp.zeros((sp.N,), jnp.float32).at[sp.cols].add(sp.vals)
    b = jnp.where(dr > 0, sr / jnp.maximum(dr, 1) - mu, 0.0)
    bh = jnp.where(dc > 0, sc / jnp.maximum(dc, 1) - mu, 0.0)
    return mu, b, bh


def train_test_split(rng: np.random.Generator, rows, cols, vals, test_frac=0.1):
    """Host-side split of COO triples into train/test index sets."""
    nnz = len(vals)
    perm = rng.permutation(nnz)
    ntest = int(nnz * test_frac)
    te, tr = perm[:ntest], perm[ntest:]
    return (rows[tr], cols[tr], vals[tr]), (rows[te], cols[te], vals[te])


def epoch_batches(key: jax.Array, nnz: int, batch: int):
    """Shuffled sample indices padded to a whole number of batches.

    Returns ``idx [nb, batch]`` int32 and ``valid [nb, batch]`` bool —
    padding repeats samples but is masked out of the update.
    """
    perm = jax.random.permutation(key, nnz)
    nb = -(-nnz // batch)
    pad = nb * batch - nnz
    idx = jnp.concatenate([perm, perm[:pad]]).astype(jnp.int32)
    valid = jnp.arange(nb * batch) < nnz
    return idx.reshape(nb, batch), valid.reshape(nb, batch)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class EpochSchedule:
    """Conflict-free epoch schedule (device arrays, built once per fit).

    ``cf_idx[b]`` is a batch of triple indices in which every row id and
    every col id appears **at most once** — the invariant the paper's D×D
    blocking (cuMF_SGD-style, Fig. 5) provides per CUDA block, here enforced
    per SIMD mini-batch so the scatter update is race-free and exactly
    Eq. (5) (no collision rescaling needed).  ``lo_idx`` holds the
    unschedulable leftovers (zipf heads whose degree exceeds the number of
    conflict-free batches a width permits); they run through the scaled
    fallback step.  Padding slots repeat index 0 with ``valid`` False.
    """

    cf_idx: jax.Array    # [nb_cf, W] int32
    cf_valid: jax.Array  # [nb_cf, W] bool
    lo_idx: jax.Array    # [nb_lo, B] int32
    lo_valid: jax.Array  # [nb_lo, B] bool

    def stats(self) -> dict:
        n_cf = int(jnp.sum(self.cf_valid)) if self.cf_idx.size else 0
        n_lo = int(jnp.sum(self.lo_valid)) if self.lo_idx.size else 0
        slots = self.cf_idx.size + self.lo_idx.size
        return dict(
            n_cf=n_cf, n_lo=n_lo,
            nb_cf=int(self.cf_idx.shape[0]), nb_lo=int(self.lo_idx.shape[0]),
            cf_frac=n_cf / max(n_cf + n_lo, 1),
            fill=(n_cf + n_lo) / max(slots, 1))


def conflict_free_schedule(rows, cols, *, batch: int = 512,
                           min_fill: int | None = None, slack: float = 1.0,
                           seed: int = 0) -> EpochSchedule:
    """Greedy conflict-free batch scheduler (host side, O(nnz·R/64)).

    The exact-colouring refinement of MCULSH-MF's D×D rotation: a
    first-fit edge colouring of the bipartite interaction graph with a
    *round budget* ``R ≈ slack · nnz / batch`` and per-round capacity
    ``batch``.  Triples are placed heaviest-endpoint-first into the lowest
    round where (a) the round isn't full and (b) neither their row nor
    col already appears — so every round is a conflict-free batch.  A col
    of degree d can occupy at most min(d, R) rounds, so zipf heads
    overflow: the unplaceable residue goes to the leftover pool, packed
    into ordinary scaled-fallback batches.  Together the conflict-free and
    leftover batches cover every triple exactly once per epoch.

    Row/col occupancy is one python-int bitmask per id (R bits); first
    free round = lowest zero bit — fast enough to rebuild per fit.
    """
    rows = np.asarray(rows)
    cols = np.asarray(cols)
    nnz = int(rows.shape[0])
    if min_fill is None:
        # half-full is the measured break-even on CPU: a sparser cf batch
        # costs more in padded step work than the leftover path's collision
        # rescaling does (see benchmarks/bench_train.py)
        min_fill = max(1, batch // 2)
    rng = np.random.default_rng(seed)

    dr = np.bincount(rows, minlength=int(rows.max(initial=-1)) + 1)
    dc = np.bincount(cols, minlength=int(cols.max(initial=-1)) + 1)
    # a conflict-free batch holds each row/col at most once, so width beyond
    # min(M, N) can only ever be padding — clamp
    batch = max(1, min(batch, len(dr), len(dc)))
    if min_fill > batch:
        min_fill = max(1, batch // 2)
    R = max(1, int(np.ceil(slack * nnz / batch)))
    full = (1 << R) - 1
    # heaviest endpoints first (they need the most distinct rounds),
    # random tiebreak so batch composition stays decorrelated
    order = np.lexsort((rng.random(nnz), -(dr[rows] + dc[cols])))
    ri = rows[order].tolist()
    ci = cols[order].tolist()

    row_used = [0] * len(dr)
    col_used = [0] * len(dc)
    closed = 0                      # rounds at capacity
    counts = [0] * R
    cf_members: list[list[int]] = [[] for _ in range(R)]
    leftovers: list[int] = []
    for t in range(nnz):
        i, j = ri[t], ci[t]
        free = ~(row_used[i] | col_used[j] | closed) & full
        if not free:
            leftovers.append(order[t])
            continue
        low = free & -free
        r = low.bit_length() - 1
        cf_members[r].append(order[t])
        row_used[i] |= low
        col_used[j] |= low
        cnt = counts[r] + 1
        counts[r] = cnt
        if cnt == batch:
            closed |= low

    # sparse tail rounds aren't worth a padded batch — divert to leftovers
    cf_batches = []
    for members in cf_members:
        if len(members) >= min_fill:
            cf_batches.append(np.asarray(members, np.int64))
        else:
            leftovers.extend(members)

    def pack(chunks, width):
        if not chunks:
            z = np.zeros((0, width), np.int32)
            return z, np.zeros((0, width), bool)
        idx = np.zeros((len(chunks), width), np.int32)
        valid = np.zeros((len(chunks), width), bool)
        for b, chunk in enumerate(chunks):
            idx[b, :len(chunk)] = chunk
            valid[b, :len(chunk)] = True
        return idx, valid

    cf_idx, cf_valid = pack(cf_batches, batch)
    lo = np.asarray(leftovers, np.int64)
    rng.shuffle(lo)
    lo_idx, lo_valid = pack(
        [lo[c0:c0 + batch] for c0 in range(0, len(lo), batch)], batch)
    return EpochSchedule(jnp.asarray(cf_idx), jnp.asarray(cf_valid),
                         jnp.asarray(lo_idx), jnp.asarray(lo_valid))


def block_partition(rows, cols, M, N, D):
    """MCULSH-MF Fig.5 D×D blocking (host side).

    Returns per-sample (row_block, col_block) ids with contiguous equal-size
    index ranges, used by the rotation trainer to build its D sub-epoch
    schedule where device d at step s trains block (d+s mod D, d).
    """
    rb = np.minimum(rows * D // M, D - 1)
    cb = np.minimum(cols * D // N, D - 1)
    return rb.astype(np.int32), cb.astype(np.int32)
