"""Sparse interaction-matrix substrate.

The paper's object of study is a sparse matrix ``R ∈ R^{M×N}`` between two
entity sets ``I`` (rows, e.g. users) and ``J`` (cols, e.g. items), stored as
COO triples.  Everything downstream (simLSH encoding, neighbour lookup,
conflict-free batching, rotation sharding) consumes this type.

Fixed-shape, jit-friendly by construction: all ragged structures are either
sorted flat arrays addressed with ``searchsorted`` or padded to static width.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SparseMatrix:
    """COO sparse matrix, (row, col)-lexicographically sorted.

    Rating lookup is a vectorized binary search over the sorted pair —
    int32-safe at any (M, N) scale (no M·N key that would overflow 2³¹),
    which turns the paper's per-row hash-table probe into a TPU-friendly
    O(log nnz) gather loop.
    """

    rows: jax.Array  # [nnz] int32, sorted (major)
    cols: jax.Array  # [nnz] int32, sorted within row (minor)
    vals: jax.Array  # [nnz] float32
    shape: tuple[int, int] = dataclasses.field(metadata=dict(static=True))

    @property
    def M(self) -> int:
        return self.shape[0]

    @property
    def N(self) -> int:
        return self.shape[1]

    @property
    def nnz(self) -> int:
        return int(self.rows.shape[0])


def from_coo(rows, cols, vals, shape) -> SparseMatrix:
    """Build a SparseMatrix from (unsorted, unique) COO triples."""
    M, N = shape
    rows = jnp.asarray(rows, jnp.int32)
    cols = jnp.asarray(cols, jnp.int32)
    vals = jnp.asarray(vals, jnp.float32)
    order = jnp.lexsort((cols, rows))
    return SparseMatrix(rows[order], cols[order], vals[order], (M, N))


@jax.jit
def lookup(sp: SparseMatrix, qi: jax.Array, qj: jax.Array):
    """Vectorized rating lookup r_{i,j} for query id arrays of any shape.

    Returns ``(vals, mask)`` where ``mask`` says whether (i, j) is observed.
    A hand-rolled binary search over the lexsorted (row, col) pair — int32
    overflow-safe, fully parallel over queries, O(log nnz) gathers each.
    """
    nnz = sp.rows.shape[0]
    steps = max(1, int(np.ceil(np.log2(max(nnz, 2)))) + 1)
    lo = jnp.zeros(qi.shape, jnp.int32)
    hi = jnp.full(qi.shape, nnz, jnp.int32)

    def body(_, lohi):
        lo, hi = lohi
        mid = (lo + hi) >> 1
        rm, cm = sp.rows[mid], sp.cols[mid]
        less = (rm < qi) | ((rm == qi) & (cm < qj))
        return jnp.where(less, mid + 1, lo), jnp.where(less, hi, mid)

    lo, hi = jax.lax.fori_loop(0, steps, body, (lo, hi))
    pos = jnp.clip(lo, 0, nnz - 1)
    hit = (sp.rows[pos] == qi) & (sp.cols[pos] == qj)
    return jnp.where(hit, sp.vals[pos], 0.0), hit


def degrees(sp: SparseMatrix):
    """(row_degree [M], col_degree [N]) — |Ω_i| and |Ω̂_j|."""
    dr = jnp.zeros((sp.M,), jnp.int32).at[sp.rows].add(1)
    dc = jnp.zeros((sp.N,), jnp.int32).at[sp.cols].add(1)
    return dr, dc


def baselines(sp: SparseMatrix, eps: float = 1e-9):
    """Paper §3.2 part ①: (μ, b_i [M], b̂_j [N]) from the observed entries."""
    mu = jnp.sum(sp.vals) / (sp.nnz + eps)
    dr, dc = degrees(sp)
    sr = jnp.zeros((sp.M,), jnp.float32).at[sp.rows].add(sp.vals)
    sc = jnp.zeros((sp.N,), jnp.float32).at[sp.cols].add(sp.vals)
    b = jnp.where(dr > 0, sr / jnp.maximum(dr, 1) - mu, 0.0)
    bh = jnp.where(dc > 0, sc / jnp.maximum(dc, 1) - mu, 0.0)
    return mu, b, bh


def train_test_split(rng: np.random.Generator, rows, cols, vals, test_frac=0.1):
    """Host-side split of COO triples into train/test index sets."""
    nnz = len(vals)
    perm = rng.permutation(nnz)
    ntest = int(nnz * test_frac)
    te, tr = perm[:ntest], perm[ntest:]
    return (rows[tr], cols[tr], vals[tr]), (rows[te], cols[te], vals[te])


def epoch_batches(key: jax.Array, nnz: int, batch: int):
    """Shuffled sample indices padded to a whole number of batches.

    Returns ``idx [nb, batch]`` int32 and ``valid [nb, batch]`` bool —
    padding repeats samples but is masked out of the update.
    """
    perm = jax.random.permutation(key, nnz)
    nb = -(-nnz // batch)
    pad = nb * batch - nnz
    idx = jnp.concatenate([perm, perm[:pad]]).astype(jnp.int32)
    valid = jnp.arange(nb * batch) < nnz
    return idx.reshape(nb, batch), valid.reshape(nb, batch)


def block_partition(rows, cols, M, N, D):
    """MCULSH-MF Fig.5 D×D blocking (host side).

    Returns per-sample (row_block, col_block) ids with contiguous equal-size
    index ranges, used by the rotation trainer to build its D sub-epoch
    schedule where device d at step s trains block (d+s mod D, d).
    """
    rb = np.minimum(rows * D // M, D - 1)
    cb = np.minimum(cols * D // N, D - 1)
    return rb.astype(np.int32), cb.astype(np.int32)
