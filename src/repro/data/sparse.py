"""Sparse interaction-matrix substrate.

The paper's object of study is a sparse matrix ``R ∈ R^{M×N}`` between two
entity sets ``I`` (rows, e.g. users) and ``J`` (cols, e.g. items), stored as
COO triples.  Everything downstream (simLSH encoding, neighbour lookup,
conflict-free batching, rotation sharding) consumes this type.

Fixed-shape, jit-friendly by construction: all ragged structures are either
sorted flat arrays addressed with ``searchsorted`` or padded to static width.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SparseMatrix:
    """COO sparse matrix, (row, col)-lexicographically sorted.

    Rating lookup is a vectorized binary search over the sorted pair —
    int32-safe at any (M, N) scale (no M·N key that would overflow 2³¹),
    which turns the paper's per-row hash-table probe into a TPU-friendly
    O(log nnz) gather loop.
    """

    rows: jax.Array  # [nnz] int32, sorted (major)
    cols: jax.Array  # [nnz] int32, sorted within row (minor)
    vals: jax.Array  # [nnz] float32
    shape: tuple[int, int] = dataclasses.field(metadata=dict(static=True))

    @property
    def M(self) -> int:
        return self.shape[0]

    @property
    def N(self) -> int:
        return self.shape[1]

    @property
    def nnz(self) -> int:
        return int(self.rows.shape[0])


def from_coo(rows, cols, vals, shape) -> SparseMatrix:
    """Build a SparseMatrix from (unsorted, unique) COO triples."""
    M, N = shape
    rows = jnp.asarray(rows, jnp.int32)
    cols = jnp.asarray(cols, jnp.int32)
    vals = jnp.asarray(vals, jnp.float32)
    order = jnp.lexsort((cols, rows))
    return SparseMatrix(rows[order], cols[order], vals[order], (M, N))


def merge_coo(sp: SparseMatrix, rows, cols, vals,
              shape: tuple[int, int]) -> SparseMatrix:
    """Sorted-array union merge of Ω̂ and ΔΩ (host side).

    The Alg.-4 online path used to rebuild `from_coo` per update — a full
    O((n+d)·log(n+d)) re-sort of the merged matrix.  Since ``sp`` is
    already (row, col)-lexsorted, merging d new triples only needs the
    delta sorted plus two `searchsorted` passes: O(d·log d + d·log n) and
    one linear scatter into the output.  ``shape`` may be larger than
    ``sp.shape`` (grown id space); keys use the *new* N, which preserves
    the lexicographic order of the old entries for any N ≥ max col + 1.
    Assumes ΔΩ does not duplicate observed entries (new interactions);
    equal keys land old-first.
    """
    M, N = shape
    r0 = np.asarray(sp.rows, np.int64)
    c0 = np.asarray(sp.cols, np.int64)
    v0 = np.asarray(sp.vals)
    rd = np.asarray(rows, np.int64)
    cd = np.asarray(cols, np.int64)
    vd = np.asarray(vals, np.float32)
    k0 = r0 * N + c0
    kd = rd * N + cd
    o = np.argsort(kd, kind="stable")
    rd, cd, vd, kd = rd[o], cd[o], vd[o], kd[o]
    n, d = len(k0), len(kd)
    out_r = np.empty(n + d, np.int32)
    out_c = np.empty(n + d, np.int32)
    out_v = np.empty(n + d, np.float32)
    pos0 = np.arange(n) + np.searchsorted(kd, k0, side="left")
    posd = np.arange(d) + np.searchsorted(k0, kd, side="right")
    out_r[pos0], out_c[pos0], out_v[pos0] = r0, c0, v0
    out_r[posd], out_c[posd], out_v[posd] = rd, cd, vd
    return SparseMatrix(jnp.asarray(out_r), jnp.asarray(out_c),
                        jnp.asarray(out_v), (int(M), int(N)))


@jax.jit
def lookup(sp: SparseMatrix, qi: jax.Array, qj: jax.Array):
    """Vectorized rating lookup r_{i,j} for query id arrays of any shape.

    Returns ``(vals, mask)`` where ``mask`` says whether (i, j) is observed.
    A hand-rolled binary search over the lexsorted (row, col) pair — int32
    overflow-safe, fully parallel over queries, O(log nnz) gathers each.
    """
    nnz = sp.rows.shape[0]
    steps = max(1, int(np.ceil(np.log2(max(nnz, 2)))) + 1)
    lo = jnp.zeros(qi.shape, jnp.int32)
    hi = jnp.full(qi.shape, nnz, jnp.int32)

    def body(_, lohi):
        lo, hi = lohi
        mid = (lo + hi) >> 1
        rm, cm = sp.rows[mid], sp.cols[mid]
        less = (rm < qi) | ((rm == qi) & (cm < qj))
        return jnp.where(less, mid + 1, lo), jnp.where(less, hi, mid)

    lo, hi = jax.lax.fori_loop(0, steps, body, (lo, hi))
    pos = jnp.clip(lo, 0, nnz - 1)
    hit = (sp.rows[pos] == qi) & (sp.cols[pos] == qj)
    return jnp.where(hit, sp.vals[pos], 0.0), hit


def degrees(sp: SparseMatrix):
    """(row_degree [M], col_degree [N]) — |Ω_i| and |Ω̂_j|."""
    dr = jnp.zeros((sp.M,), jnp.int32).at[sp.rows].add(1)
    dc = jnp.zeros((sp.N,), jnp.int32).at[sp.cols].add(1)
    return dr, dc


def baselines(sp: SparseMatrix, eps: float = 1e-9):
    """Paper §3.2 part ①: (μ, b_i [M], b̂_j [N]) from the observed entries."""
    mu = jnp.sum(sp.vals) / (sp.nnz + eps)
    dr, dc = degrees(sp)
    sr = jnp.zeros((sp.M,), jnp.float32).at[sp.rows].add(sp.vals)
    sc = jnp.zeros((sp.N,), jnp.float32).at[sp.cols].add(sp.vals)
    b = jnp.where(dr > 0, sr / jnp.maximum(dr, 1) - mu, 0.0)
    bh = jnp.where(dc > 0, sc / jnp.maximum(dc, 1) - mu, 0.0)
    return mu, b, bh


def train_test_split(rng: np.random.Generator, rows, cols, vals, test_frac=0.1):
    """Host-side split of COO triples into train/test index sets."""
    nnz = len(vals)
    perm = rng.permutation(nnz)
    ntest = int(nnz * test_frac)
    te, tr = perm[:ntest], perm[ntest:]
    return (rows[tr], cols[tr], vals[tr]), (rows[te], cols[te], vals[te])


def epoch_batches(key: jax.Array, nnz: int, batch: int):
    """Shuffled sample indices padded to a whole number of batches.

    Returns ``idx [nb, batch]`` int32 and ``valid [nb, batch]`` bool —
    padding repeats samples but is masked out of the update.
    """
    perm = jax.random.permutation(key, nnz)
    nb = -(-nnz // batch)
    pad = nb * batch - nnz
    idx = jnp.concatenate([perm, perm[:pad]]).astype(jnp.int32)
    valid = jnp.arange(nb * batch) < nnz
    return idx.reshape(nb, batch), valid.reshape(nb, batch)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class EpochSchedule:
    """Tiered conflict-free epoch schedule (device arrays, built once per fit).

    The schedule is a *layout*, not just a batching: ``order`` permutes the
    triple indices so that every batch of every tier is a **contiguous
    window** of the schedule-ordered arrays.  Batch assembly at train time
    is a `dynamic_slice` + mask, never a gather — on CPU that is the
    difference between streaming 34 MB of neighbour cache per epoch and
    random-probing it.

    Three kinds of batches, each a conflict-free set (every row id and
    every col id at most once — the invariant the paper's D×D blocking
    provides per CUDA block) except the leftovers:

    * ``shard_*``   — the block-aligned tier (present when ``shards > 1``):
      cell ``(d, s, r)`` is round ``r`` of sub-epoch ``s`` on device ``d``
      and only contains triples of block ``((d+s) % D, d)`` of the D×D
      grid cut at ``row_bounds``/``col_bounds`` (equal-**nnz** partitions
      by default, see `conflict_free_schedule(balance_blocks=...)`), so
      the D batches of a step touch disjoint parameter blocks —
      `sgd.train_epoch_scheduled` scans them under `jax.shard_map` with
      one packed-row-plane ring-rotation per sub-epoch and no per-step
      collective.  Shard-tier triples occupy schedule positions
      ``[0, shard_span)`` and are materialized as the **dense, device-
      shardable** `model.ShardData` cells, not as windows of the
      replicated `model.ScheduledData`.
    * ``tier_*``    — width-tiered conflict-free batches (``widths[t]``
      shrinking per tier) so sparse tail rounds are re-packed narrow
      instead of being diverted to the scaled fallback.
    * ``lo_*``      — the unschedulable residue (zipf heads whose degree
      exceeds the total round budget); scaled-fallback batches at full
      width, with their collision normalizers precomputed into
      ``lo_scale_*`` (batch composition is fixed per fit, so the counts
      are schedule constants — no per-batch O(M)+O(N) scatter-count).

    Together the three cover every triple exactly once per epoch (``order``
    is a permutation).  Windows may read past a batch's fill into the next
    batch's triples; ``*_valid`` masks them out.

    **Id spaces.**  With ``shards = D > 1`` every consumer of the schedule
    works in the *block-padded* id space: ``row_map``/``col_map`` send an
    original id ``g`` of block ``d`` to ``d·block + (g − bounds[d])``, so
    each block is a contiguous, equal-size ``block_rows``/``block_cols``
    range (the shape `jax.shard_map` needs) regardless of how unequal the
    nnz-balanced *original* ranges are.  `model.build_scheduled_data` /
    `model.build_shard_data` store remapped ids, and parameters must be
    relaid with `model.remap_params` before training (and `unmap_params`
    after).  With ``shards == 1`` the maps are empty and ids are the
    original ones.
    """

    order: jax.Array          # [nnz] int32 — schedule position → triple id
    shard_starts: jax.Array   # [D, S, R] int32 into [0, shard_span) (S == D)
    shard_valid: jax.Array    # [D, S, R, Wsh] bool
    tier_starts: tuple        # per tier: [nb_t] int32 into the cf region
    tier_valid: tuple         # per tier: [nb_t, widths[t]] bool
    lo_starts: jax.Array      # [nb_lo] int32 into the cf region
    lo_valid: jax.Array       # [nb_lo, widths[0]] bool
    lo_scale_i: jax.Array     # [nb_lo, widths[0]] float32 1/row-count
    lo_scale_j: jax.Array     # [nb_lo, widths[0]] float32 1/col-count
    row_bounds: jax.Array     # [D+1] int32 original-id block cuts ([] if D==1)
    col_bounds: jax.Array     # [D+1] int32 ([] if D==1)
    row_map: jax.Array        # [M] int32 original → block-padded ([] if D==1)
    col_map: jax.Array        # [N] int32 ([] if D==1)
    widths: tuple[int, ...] = dataclasses.field(metadata=dict(static=True))
    shard_width: int = dataclasses.field(metadata=dict(static=True))
    shards: int = dataclasses.field(metadata=dict(static=True))
    block_rows: int = dataclasses.field(metadata=dict(static=True))
    block_cols: int = dataclasses.field(metadata=dict(static=True))
    shard_span: int = dataclasses.field(metadata=dict(static=True))

    @property
    def pad_width(self) -> int:
        """Slack the schedule-ordered arrays need past their fill so every
        window slice stays in bounds (widest batch)."""
        return self.widths[0]

    def stats(self) -> dict:
        """Self-describing occupancy breakdown (host side, for bench JSON).

        Reports fill for *every* tier and for the leftovers — a 0.5-fill
        narrow tier and a 0.99-fill leftover pool are different perf
        stories even at equal cf_frac.  Fields:

        * ``n_cf`` / ``n_lo``   — triples scheduled conflict-free (shard +
          width tiers) vs diverted to the scaled leftover fallback.
        * ``nb_cf`` / ``nb_lo`` — batch (= scan-step) counts for each.
        * ``cf_frac``           — n_cf / nnz; the fraction of updates that
          take the *exact* Eq. (5) step (the bench gate floor is 0.8).
        * ``fill`` / ``cf_fill`` / ``lo_fill`` — occupied slots / padded
          slots overall, over the conflict-free batches only, and over
          the leftover batches only.
        * ``tiers``             — per width tier: width, rounds, n, fill.
        * ``shard``             — the block-aligned tier: device count,
          cell width, rounds (total over the D×D×R grid), n, fill, and
          ``extent_rows``/``extent_cols`` — the per-block *original* id
          extents (equal-nnz partitions make these unequal on zipf data;
          their spread is what the balancing trades for round fill).
        """
        tiers = []
        n_cf = slots_cf = nb_cf = 0
        if self.shard_valid.size:
            n_sh = int(jnp.sum(self.shard_valid))
            nb_sh = int(np.prod(self.shard_valid.shape[:3]))
            n_cf += n_sh
            slots_cf += self.shard_valid.size
            nb_cf += nb_sh
            shard = dict(shards=self.shards, width=self.shard_width,
                         rounds=nb_sh, n=n_sh,
                         fill=n_sh / max(self.shard_valid.size, 1),
                         extent_rows=np.diff(
                             np.asarray(self.row_bounds)).tolist(),
                         extent_cols=np.diff(
                             np.asarray(self.col_bounds)).tolist())
        else:
            shard = dict(shards=self.shards, width=self.shard_width,
                         rounds=0, n=0, fill=0.0)
        for w, valid in zip(self.widths, self.tier_valid):
            n_t = int(jnp.sum(valid)) if valid.size else 0
            nb_t = int(valid.shape[0])
            tiers.append(dict(width=w, rounds=nb_t, n=n_t,
                              fill=n_t / max(valid.size, 1)))
            n_cf += n_t
            slots_cf += valid.size
            nb_cf += nb_t
        n_lo = int(jnp.sum(self.lo_valid)) if self.lo_valid.size else 0
        slots = slots_cf + self.lo_valid.size
        return dict(
            n_cf=n_cf, n_lo=n_lo, nb_cf=nb_cf,
            nb_lo=int(self.lo_valid.shape[0]),
            cf_frac=n_cf / max(n_cf + n_lo, 1),
            fill=(n_cf + n_lo) / max(slots, 1),
            cf_fill=n_cf / max(slots_cf, 1),
            lo_fill=n_lo / max(self.lo_valid.size, 1),
            tiers=tiers, shard=shard)


class _PriorityPool:
    """Unscheduled triples in (fixed) priority order, with O(window)
    round extraction — the vectorized replacement for PR 2's per-triple
    python-int bitmask probes."""

    def __init__(self, ids):
        self.arr = np.asarray(ids, np.int64)
        self.alive = np.ones(len(self.arr), bool)
        self.cursor = 0
        self.n = int(len(self.arr))

    def window(self, want: int):
        """Positions of the first ≤``want`` alive candidates."""
        want = min(want, self.n)
        if want == 0:
            return np.empty(0, np.int64)
        pos = self.cursor + np.flatnonzero(
            self.alive[self.cursor:self.cursor + 4 * want])
        if len(pos) < want:  # prefix too diluted — compact the pool
            live = self.cursor + np.flatnonzero(self.alive[self.cursor:])
            self.arr = self.arr[live]
            self.alive = np.ones(len(live), bool)
            self.cursor = 0
            pos = np.arange(min(want, len(live)), dtype=np.int64)
        return pos[:want]

    def take(self, positions):
        self.alive[positions] = False
        self.n -= len(positions)
        while self.cursor < len(self.alive):
            seg = np.flatnonzero(self.alive[self.cursor:self.cursor + 1024])
            if len(seg):
                self.cursor += int(seg[0])
                break
            self.cursor += 1024

    def drain(self):
        out = self.arr[self.cursor:][self.alive[self.cursor:]]
        self.alive[:] = False
        self.n = 0
        return out


def _match_round(rr, cc, width, passes, row_used, col_used):
    """Greedy conflict-free matching over a candidate window (vectorized).

    Each pass keeps the first occurrence of every row AND every col among
    the still-available candidates (`np.unique` return_index — the
    vectorized form of the old per-triple bitmask probe), removes their
    row/col peers, and repeats; ≤ ``width`` selections.  Returns positions
    into the window.  ``row_used``/``col_used`` are reusable scratch —
    reset before returning.
    """
    sel = []
    avail = np.ones(len(rr), bool)
    got = 0
    for _ in range(passes):
        cand = np.flatnonzero(avail)
        if not len(cand) or got >= width:
            break
        mr = np.zeros(len(cand), bool)
        mr[np.unique(rr[cand], return_index=True)[1]] = True
        mc = np.zeros(len(cand), bool)
        mc[np.unique(cc[cand], return_index=True)[1]] = True
        take = cand[mr & mc][:width - got]
        if not len(take):
            break
        sel.append(take)
        got += len(take)
        row_used[rr[take]] = True
        col_used[cc[take]] = True
        avail[cand] &= ~(row_used[rr[cand]] | col_used[cc[cand]])
    out = np.concatenate(sel) if sel else np.empty(0, np.int64)
    row_used[rr[out]] = False
    col_used[cc[out]] = False
    return out


def _pack_width(pool, rows, cols, width, min_fill, *, passes, window,
                row_used, col_used, budget):
    """Extract rounds at one width until a round comes up short of
    ``min_fill`` (the re-pack-narrower signal) or the budget runs out."""
    rounds = []
    while pool.n and budget > 0:
        pos = pool.window(window * width)
        ids = pool.arr[pos]
        sel = _match_round(rows[ids], cols[ids], width, passes,
                           row_used, col_used)
        if len(sel) < min_fill:
            break
        rounds.append(ids[sel])
        pool.take(pos[sel])
        budget -= 1
    return rounds, budget


def _balanced_bounds(counts: np.ndarray, D: int, floor: int = 1) -> np.ndarray:
    """Equal-weight partition cuts over an id range (host side).

    Returns ``bounds [D+1]`` with block ``d`` = ids ``[bounds[d],
    bounds[d+1])`` carrying ≈ total/D of ``counts``'s mass (cumsum
    quantile cuts), subject to every block spanning ≥ ``floor`` ids.

    The floor is load-bearing, not a degenerate-case guard: a conflict-
    free round inside a block can never be wider than the block's
    distinct-id extent, so an unconstrained nnz cut on zipf data — whose
    head block collapses to a handful of ids — would cap head-cell
    matchings at that handful and blow up the grid-wide round count the
    other cells are padded to.  Balancing *subject to* extent ≥ the shard
    round width keeps every cell able to fill its rounds (requires
    ``len(counts) ≥ D·floor``; the caller clamps).
    """
    size = len(counts)
    floor = max(1, min(floor, size // max(D, 1)))
    cum = np.cumsum(counts, dtype=np.int64)
    total = int(cum[-1]) if size else 0
    bounds = np.zeros(D + 1, np.int64)
    bounds[D] = size
    for d in range(1, D):
        cut = int(np.searchsorted(cum, d * (total / D), side="left")) + 1
        bounds[d] = min(max(cut, bounds[d - 1] + floor),
                        size - (D - d) * floor)
    return bounds


def _block_id_map(bounds: np.ndarray, size: int, extent: int) -> np.ndarray:
    """Original id → block-padded id: ``g ∈ block d ↦ d·extent + (g −
    bounds[d])``.  Strictly monotone (blocks keep their internal order and
    never overflow into the next block's range since every block extent
    ≤ ``extent``)."""
    ids = np.arange(size, dtype=np.int64)
    blk = np.searchsorted(bounds, ids, side="right") - 1
    return (blk * extent + ids - bounds[blk]).astype(np.int64)


# public names for the block-partitioning primitives: the serving shard
# tier (`serve.index.build_sharded_index`, `model.shard_col_plane`) cuts
# the *item* axis with exactly the machinery the scheduler uses for its
# D×D parameter blocks, so the two tiers can never drift apart on what
# "nnz-balanced" means
balanced_bounds = _balanced_bounds
block_id_map = _block_id_map


def conflict_free_schedule(rows, cols, *, batch: int = 512, tiers: int = 4,
                           tier_shrink: float = 0.5,
                           min_fill_frac: float = 0.5, shards: int = 1,
                           M: int | None = None, N: int | None = None,
                           seed: int = 0, passes: int = 5, window: int = 6,
                           max_rounds: int | None = None,
                           balance_blocks: bool = True) -> EpochSchedule:
    """Tiered conflict-free scheduler (host side, vectorized round-major).

    Round-major greedy edge colouring of the bipartite interaction graph:
    each round takes a near-maximal conflict-free matching (capped at the
    tier width) from the priority-ordered pool of unscheduled triples.

    Knobs:

    * ``batch``        — tier-0 (widest) conflict-free batch width; auto-
      clamped to ``min(M, N)`` since a conflict-free batch holds each
      row/col at most once.
    * ``tiers`` / ``tier_shrink`` — the width ladder: a round is emitted
      at a tier only when it would not fit the next tier's width (its
      fill is therefore ≥ ``tier_shrink``); smaller rounds step the tier
      down by ``tier_shrink`` instead of being diverted to leftovers.
      Finer ladders (``tier_shrink`` ≈ 0.7) trade a few extra scans for
      tighter packing; the bench scales use 7–9 tiers at 0.71.
    * ``min_fill_frac`` — the *last* tier keeps rounds down to
      ``min_fill_frac·width`` (the measured CPU break-even between padded
      conflict-free work and the leftover path's collision rescaling);
      only below it does the residue (zipf heads whose degree exceeds the
      total round count) become scaled-fallback leftovers, whose
      per-batch collision normalizers are precomputed here into
      ``lo_scale_*``.
    * ``passes`` / ``window`` — matching effort per round: how many
      `np.unique` first-occurrence sweeps over how many candidate
      triples (``window × width``).
    * ``max_rounds``   — hard budget on emitted rounds (default: generous
      multiple of nnz/width; a safety valve, not a tuning knob).

    Priority = (arrival rank within the triple's row/col under a random
    shuffle, heaviest endpoints first): a window prefix then spans many
    distinct rows/cols (so matchings are wide) while heads — which need
    the most distinct rounds — always get a slot first.  Input order must
    NOT leak into the ranking: lexsorted input + zipf-sorted ids would
    hand every low rank to head rows and starve the matching.

    With ``shards = D > 1`` a block-aligned tier is carved first: row/col
    ids are cut into D ranges at ``row_bounds``/``col_bounds`` —
    **equal-nnz** cumsum quantiles by default (``balance_blocks=True``),
    equal-id-range otherwise — and cell ``(s, d)`` (sub-epoch, device) is
    scheduled independently at the shard width so device ``d`` processes
    block ``((d+s) % D, d)``: the cuMF_SGD rotation that lets
    `jax.shard_map` scan all D cells of a step in parallel with no
    collective.  Cells are padded to the max round count over the grid,
    so equal-id-range cuts on zipf data leave head-block rounds empty;
    nnz balancing equalizes per-cell round counts and recovers that fill.
    The unequal original ranges are then re-laid as equal ``block_rows``/
    ``block_cols`` ranges in the block-padded id space (``row_map``/
    ``col_map``).  Cell residue falls through to the ordinary tiers.
    """
    rows = np.asarray(rows)
    cols = np.asarray(cols)
    nnz = int(rows.shape[0])
    rng = np.random.default_rng(seed)
    M = int(M) if M is not None else int(rows.max(initial=-1)) + 1
    N = int(N) if N is not None else int(cols.max(initial=-1)) + 1
    # a conflict-free batch holds each row/col at most once, so width
    # beyond min(M, N) can only ever be padding — clamp
    batch = max(1, min(batch, M, N))
    widths = []
    w = batch
    for _ in range(max(1, int(tiers))):
        widths.append(w)
        if w == 1:
            break
        w = max(1, min(w - 1, int(w * tier_shrink)))
    widths = tuple(widths)
    # emit a round at tier t only if it can't fit tier t+1's width — fill
    # per emitted round is then ≥ tier_shrink; the last tier uses the
    # padded-work vs collision-rescaling break-even
    min_fills = tuple(widths[1:]) + (max(1, int(widths[-1] * min_fill_frac)),)

    dr = np.bincount(rows, minlength=M)
    dc = np.bincount(cols, minlength=N)
    # arrival rank within each row/col under a *random* arrival order
    # (input order must not leak in: lexsorted input + zipf-sorted ids
    # would hand every low rank to head rows and starve the matching)
    shuffle = rng.permutation(nnz)

    def arrival_rank(ids, size):
        a = ids[shuffle]
        o = np.argsort(a, kind="stable")
        counts = np.bincount(a, minlength=size)
        starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
        r = np.empty(nnz, np.int64)
        r[o] = np.arange(nnz) - np.repeat(starts, counts)
        out = np.empty(nnz, np.int64)
        out[shuffle] = r
        return out

    if nnz:
        rank = np.maximum(arrival_rank(rows, M), arrival_rank(cols, N))
        priority = np.lexsort((rng.random(nnz), -(dr[rows] + dc[cols]), rank))
    else:
        priority = np.empty(0, np.int64)

    row_used = np.zeros(M, bool)
    col_used = np.zeros(N, bool)
    order_parts: list[np.ndarray] = []
    pos = 0

    def layout(chunks, width, starts_shape=None):
        """Append chunks to the layout; rows sorted within each batch for
        scatter locality.  Returns (starts, valid)."""
        nonlocal pos
        starts = np.zeros(len(chunks), np.int32)
        valid = np.zeros((len(chunks), width), bool)
        for b, m in enumerate(chunks):
            m = m[np.argsort(rows[m], kind="stable")]
            order_parts.append(m)
            starts[b] = pos
            valid[b, :len(m)] = True
            pos += len(m)
        return starts, valid

    # ---- block-aligned shard tier (cuMF-style D×D rotation) --------------
    D = max(1, int(shards))
    mB = nB = 0
    Wsh = widths[0]
    row_bounds = np.zeros(0, np.int64)
    col_bounds = np.zeros(0, np.int64)
    row_map = np.zeros(0, np.int64)
    col_map = np.zeros(0, np.int64)
    if D > 1 and nnz:
        if balance_blocks:
            # equal-nnz cumsum quantile cuts, floored at the round width
            # so no block's matching is extent-limited (see _balanced_bounds)
            row_bounds = _balanced_bounds(dr, D, floor=min(batch, M // D))
            col_bounds = _balanced_bounds(dc, D, floor=min(batch, N // D))
            Wsh = max(1, min(batch, int(np.diff(row_bounds).min()),
                             int(np.diff(col_bounds).min())))
        else:                # legacy equal-id-range cuts
            row_bounds = np.minimum(np.arange(D + 1) * (-(-M // D)), M)
            col_bounds = np.minimum(np.arange(D + 1) * (-(-N // D)), N)
            Wsh = max(1, min(batch, -(-M // D), -(-N // D)))
        mB = int(np.diff(row_bounds).max())      # block-padded extents
        nB = int(np.diff(col_bounds).max())
        row_map = _block_id_map(row_bounds, M, mB)
        col_map = _block_id_map(col_bounds, N, nB)
        rb = np.searchsorted(row_bounds, rows, side="right") - 1
        cb = np.searchsorted(col_bounds, cols, side="right") - 1
        cell_of = ((rb - cb) % D) * D + cb       # cell = (s, d) flattened
        fill_sh = max(1, int(Wsh * min_fill_frac))
        by_cell = np.argsort(cell_of[priority], kind="stable")
        grouped = priority[by_cell]              # cell-major, priority kept
        cbounds = np.searchsorted(cell_of[grouped], np.arange(D * D + 1))
        cells = []
        for c0 in range(D * D):
            pool = _PriorityPool(grouped[cbounds[c0]:cbounds[c0 + 1]])
            n_cell = pool.n
            rounds, _ = _pack_width(
                pool, rows, cols, Wsh, fill_sh, passes=passes, window=window,
                row_used=row_used, col_used=col_used,
                budget=4 * n_cell // Wsh + 8)
            cells.append(rounds)
        R = max((len(r) for r in cells), default=0)
        shard_starts = np.zeros((D, D, R), np.int32)
        shard_valid = np.zeros((D, D, R, Wsh), bool)
        scheduled = np.zeros(nnz, bool)
        for s in range(D):
            for r in range(R):
                for d in range(D):
                    cell = cells[s * D + d]
                    chunk = [cell[r]] if r < len(cell) else [np.empty(0, np.int64)]
                    st, va = layout(chunk, Wsh)
                    shard_starts[d, s, r] = st[0]
                    shard_valid[d, s, r] = va[0]
                    scheduled[chunk[0]] = True
        priority = priority[~scheduled[priority]]
    else:
        shard_starts = np.zeros((D, D, 0), np.int32)
        shard_valid = np.zeros((D, D, 0, Wsh), bool)
    shard_span = pos   # schedule positions [0, shard_span) are shard cells

    # ---- width-tiered conflict-free rounds -------------------------------
    # tier/lo starts are rebased to the cf region (positions − shard_span):
    # shard cells live in the dense, shardable `model.ShardData`, so the
    # replicated `model.ScheduledData` only holds the cf-region triples
    pool = _PriorityPool(priority)
    budget = max_rounds if max_rounds is not None else 8 * max(nnz, 1) // widths[-1] + 64
    tier_starts, tier_valid = [], []
    for w, mf in zip(widths, min_fills):
        rounds, budget = _pack_width(
            pool, rows, cols, w, max(1, min(mf, w)),
            passes=passes, window=window, row_used=row_used,
            col_used=col_used, budget=budget)
        st, va = layout(rounds, w)
        tier_starts.append(jnp.asarray(st - shard_span))
        tier_valid.append(jnp.asarray(va))

    # ---- scaled-fallback leftovers ---------------------------------------
    lo = pool.drain()
    rng.shuffle(lo)   # decorrelate: priority order packs same-head runs
    W0 = widths[0]
    # pre-sort each chunk by row (the sort `layout` would apply) so the
    # precomputed collision normalizers stay slot-aligned with the layout
    chunks = [m[np.argsort(rows[m], kind="stable")]
              for c0 in range(0, len(lo), W0)
              for m in (lo[c0:c0 + W0],)]
    lo_si = np.ones((len(chunks), W0), np.float32)
    lo_sj = np.ones((len(chunks), W0), np.float32)
    for b, m in enumerate(chunks):
        # 1/count per slot — the same normalizer `sgd._collision_scales`
        # computed per batch on device, now a schedule constant
        _, inv, cnt = np.unique(rows[m], return_inverse=True,
                                return_counts=True)
        lo_si[b, :len(m)] = np.float32(1.0) / cnt.astype(np.float32)[inv]
        _, inv, cnt = np.unique(cols[m], return_inverse=True,
                                return_counts=True)
        lo_sj[b, :len(m)] = np.float32(1.0) / cnt.astype(np.float32)[inv]
    lo_starts, lo_valid = layout(chunks, W0)
    lo_starts = lo_starts - shard_span

    assert pos == nnz
    order = (np.concatenate(order_parts) if order_parts
             else np.empty(0, np.int64))
    return EpochSchedule(
        order=jnp.asarray(order, jnp.int32),
        shard_starts=jnp.asarray(shard_starts),
        shard_valid=jnp.asarray(shard_valid),
        tier_starts=tuple(tier_starts), tier_valid=tuple(tier_valid),
        lo_starts=jnp.asarray(lo_starts), lo_valid=jnp.asarray(lo_valid),
        lo_scale_i=jnp.asarray(lo_si), lo_scale_j=jnp.asarray(lo_sj),
        row_bounds=jnp.asarray(row_bounds, jnp.int32),
        col_bounds=jnp.asarray(col_bounds, jnp.int32),
        row_map=jnp.asarray(row_map, jnp.int32),
        col_map=jnp.asarray(col_map, jnp.int32),
        widths=widths, shard_width=int(Wsh), shards=D,
        block_rows=int(mB), block_cols=int(nB), shard_span=int(shard_span))
