"""Synthetic sparse-interaction data with MovieLens/Netflix-like statistics.

The paper evaluates on Netflix / MovieLens / Yahoo!Music, none of which are
redistributable in this container (DESIGN.md §8.4).  This generator matches
the *structural* statistics that drive the algorithms: zipf-tailed item/user
popularity (which drives LSH bucket skew and load balance), a planted
low-rank + neighbourhood signal (so RMSE orderings between methods are
meaningful), bounded rating ranges, and the paper's train/test split shape.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    M: int
    N: int
    nnz: int
    rmin: float = 1.0
    rmax: float = 5.0
    rank: int = 8
    zipf_a: float = 1.2
    noise: float = 0.35
    neigh_groups: int = 0  # planted item-cluster count; 0 = N // 50


# Reduced-scale analogues of the paper's Table 2 (full sizes are reachable by
# passing scale=1.0; tests/benches default to small fractions to stay CPU-fast).
MOVIELENS_LIKE = DatasetSpec("movielens-like", 69_878, 10_677, 9_900_054)
NETFLIX_LIKE = DatasetSpec("netflix-like", 480_189, 17_770, 99_072_112)
YAHOO_LIKE = DatasetSpec("yahoo-like", 586_250, 12_658, 91_970_212, rmax=100.0)


def scaled(spec: DatasetSpec, scale: float) -> DatasetSpec:
    return dataclasses.replace(
        spec,
        M=max(64, int(spec.M * scale)),
        N=max(32, int(spec.N * scale)),
        nnz=int(spec.nnz * scale * scale),
    )


def generate(spec: DatasetSpec, seed: int = 0):
    """Returns COO triples (rows, cols, vals) with a planted signal.

    Ground truth: r = clip(mu + b_i + b_j + u_i·v_j + group(j) bump, rmin, rmax)
    where items within a group share a latent direction — this is the
    neighbourhood structure that Top-K methods are supposed to exploit, so
    GSM/simLSH beat Rand-K on RMSE exactly as in the paper's Fig. 7.
    """
    rng = np.random.default_rng(seed)
    M, N, nnz = spec.M, spec.N, spec.nnz

    # zipf popularity for both sides (sorted → id 0 most popular)
    pu = 1.0 / np.arange(1, M + 1) ** spec.zipf_a
    pi = 1.0 / np.arange(1, N + 1) ** spec.zipf_a
    pu /= pu.sum()
    pi /= pi.sum()

    # oversample until nnz unique pairs (zipf heads collide a lot)
    rows_l, cols_l, seen = [], [], 0
    want = nnz
    while seen < want:
        take = int((want - seen) * 2.0) + 1024
        r = rng.choice(M, size=take, p=pu).astype(np.int32)
        c = rng.choice(N, size=take, p=pi).astype(np.int32)
        rows_l.append(r)
        cols_l.append(c)
        key = np.concatenate(rows_l).astype(np.int64) * N + np.concatenate(cols_l)
        seen = len(np.unique(key))
    rows = np.concatenate(rows_l)
    cols = np.concatenate(cols_l)
    key = rows.astype(np.int64) * N + cols
    _, uniq = np.unique(key, return_index=True)
    rng.shuffle(uniq)
    uniq = uniq[: nnz]
    rows, cols = rows[uniq], cols[uniq]

    G = spec.neigh_groups or max(4, N // 50)
    group = rng.integers(0, G, size=N)

    F = spec.rank
    u = rng.normal(0, 1.0 / np.sqrt(F), (M, F))
    v = rng.normal(0, 1.0 / np.sqrt(F), (N, F))
    gdir = rng.normal(0, 1.0 / np.sqrt(F), (G, F))
    v = v + 1.5 * gdir[group]  # planted neighbourhood signal

    mid = 0.5 * (spec.rmin + spec.rmax)
    amp = 0.5 * (spec.rmax - spec.rmin)
    bi = rng.normal(0, 0.25, M)
    bj = rng.normal(0, 0.25, N)
    raw = (u[rows] * v[cols]).sum(-1) + bi[rows] + bj[cols]
    raw = raw + rng.normal(0, spec.noise, raw.shape)
    vals = np.clip(mid + amp * np.tanh(raw), spec.rmin, spec.rmax).astype(np.float32)
    return rows, cols, vals, group


def add_noise(rng: np.random.Generator, vals, rate: float, rmin: float, rmax: float):
    """Paper Table 8 robustness protocol: corrupt `rate` of ratings uniformly."""
    vals = vals.copy()
    k = int(len(vals) * rate)
    idx = rng.choice(len(vals), size=k, replace=False)
    vals[idx] = rng.uniform(rmin, rmax, size=k).astype(np.float32)
    return vals
