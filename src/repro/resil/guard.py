"""Divergence guard — param-norm watchdog with snapshot rollback.

The online path (`core.online.online_update`) trains new rows/cols with
plain SGD on whatever ΔΩ arrived.  A hostile or buggy delta (huge
ratings that slipped past validation, a mis-set learning rate) can blow
the new parameters up to inf/NaN; because serving packs params into
planes wholesale, one diverged update poisons every subsequent score.

`check_divergence` compares the trained parameters against the
pre-training snapshot:

  * any non-finite entry in a *touched* slice trips immediately;
  * the RMS of each grown slice (U rows ≥ M_old, V/W/C/b̂ cols ≥ N_old)
    must stay within ``max_ratio`` × the RMS scale of the corresponding
    *old* parameters (floored at ``eps`` so a cold start with tiny old
    norms can't trip spuriously).

On a trip the caller raises `DivergenceError` **before** the new state
is constructed — the input `OnlineState` is unmodified, so rollback is
simply "keep what you had" (and the WAL entry for the update stays
replayable: a replay re-trips deterministically, converging to the same
rejected-update state — see `resil.wal.recover`).
"""
from __future__ import annotations

import dataclasses

import numpy as np


class DivergenceError(RuntimeError):
    """An online update trained diverged parameters and was rolled back —
    the caller's pre-update state is unmodified."""


@dataclasses.dataclass(frozen=True)
class GuardConfig:
    """``max_ratio`` is deliberately loose (legit new-user vectors train
    from ~1/√F noise up to the old-param scale; 100× beyond that scale is
    never a converged model) — the guard is a watchdog, not a metric."""
    max_ratio: float = 100.0
    eps: float = 1e-3


def _rms(a) -> float:
    a = np.asarray(a, np.float64)
    return float(np.sqrt(np.mean(np.square(a)))) if a.size else 0.0


def check_divergence(p_new, p_old, *, M_old: int, N_old: int,
                     cfg: GuardConfig = GuardConfig()) -> list:
    """Problem strings for the grown slices of ``p_new`` vs the old-param
    scale of ``p_old`` (empty = healthy).  Host-side; the online path
    calls it once per update, after training, before state swap."""
    probs: list = []
    slices = (
        ("U", np.asarray(p_new.U)[M_old:], np.asarray(p_old.U)),
        ("b", np.asarray(p_new.b)[M_old:], np.asarray(p_old.b)),
        ("V", np.asarray(p_new.V)[N_old:], np.asarray(p_old.V)),
        ("bh", np.asarray(p_new.bh)[N_old:], np.asarray(p_old.bh)),
        ("W", np.asarray(p_new.W)[N_old:], np.asarray(p_old.W)),
        ("C", np.asarray(p_new.C)[N_old:], np.asarray(p_old.C)),
    )
    for name, new, old in slices:
        if new.size == 0:
            continue
        if not np.isfinite(new).all():
            probs.append(f"{name}: non-finite entries in the newly trained "
                         f"slice")
            continue
        scale = max(_rms(old), cfg.eps)
        r = _rms(new)
        if r > cfg.max_ratio * scale:
            probs.append(f"{name}: new-slice RMS {r:.3g} exceeds "
                         f"{cfg.max_ratio:g}× the old-param scale "
                         f"{scale:.3g}")
    return probs
