"""Double-buffered background index rebuild with validate-then-swap.

The serving loop's weak point was the rebuild: `RecsysService.ingest`
built index v+1 *on the request path* (every pending flush waited behind
an O(q·N log N) build) and swapped it in unvalidated — a corrupt build
(crash mid-way, poisoned signatures, buggy refactor) would be served.

`IndexRebuilder` moves the build off the hot path and gates the swap:

  1. ``submit(sigs, tail_cap)`` hands the *full* signature set to a
     daemon worker thread; the caller keeps serving index **v**
     unblocked (jax arrays are immutable, so in-flight flushes that
     captured v are safe regardless of when the swap lands);
  2. the worker builds v+1 (`serve.index.build_index`), then runs
     `resil.validate.validate_index` — CSR bucket invariants plus a
     self-retrieval recall smoke on a seeded probe set;
  3. the owner polls ``take()`` at flush boundaries: a validated index
     comes back exactly once ("ready"); a failed build or failed
     validation comes back as "failed" with the error — the owner keeps
     serving v (**rollback is the default**, not an action) and may
     ``submit`` again to retry.

Only one build runs at a time; a ``submit`` while busy stages the newest
signature set and the worker picks it up next ("latest wins" — rebuilt
indexes are snapshots, intermediate ones are never worth finishing).

Fault-injection sites: ``serve.rebuild`` (before the build — exc/stall)
and ``serve.rebuild.index`` (the built index, before validation —
corrupt here to exercise the validation gate).
"""
from __future__ import annotations

import threading
import time

from repro import obs
from repro.resil import faults
from repro.resil.validate import IndexValidationError, validate_index


class IndexRebuilder:
    """One background build slot + validation gate.  Thread model: any
    number of ``submit``/``take``/``status`` callers (they lock); one
    worker thread at a time."""

    def __init__(self, registry: obs.Registry | None = None, *,
                 probe: int = 64, seed: int = 0,
                 validate: bool = True):
        self.obs = registry if registry is not None else obs.get()
        self.probe = probe
        self.seed = seed
        self.validate = validate
        self._lock = threading.Lock()
        self._thread: threading.Thread | None = None
        self._staged = None          # (sigs, tail_cap) newest pending request
        self._result = None          # validated index awaiting take()
        self._error: Exception | None = None
        self.builds = 0              # attempts started (public counters —
        self.failures = 0            # chaos tests assert on these)
        self.swaps_ready = 0

    # -- owner side ---------------------------------------------------------

    def submit(self, sigs, *, tail_cap: int) -> bool:
        """Request a rebuild from the full [q, N'] signature set.  Returns
        True if a worker started now, False if staged behind a running
        build (latest submission wins)."""
        with self._lock:
            if self._thread is not None and self._thread.is_alive():
                self._staged = (sigs, tail_cap)
                return False
            self._staged = None
            self._result, self._error = None, None
            self.builds += 1
            self._thread = threading.Thread(
                target=self._work, args=(sigs, tail_cap), daemon=True)
            self._thread.start()
            return True

    def status(self) -> str:
        """idle | building | ready | failed"""
        with self._lock:
            if self._thread is not None and self._thread.is_alive():
                return "building"
            if self._result is not None:
                return "ready"
            if self._error is not None:
                return "failed"
            return "idle"

    def take(self):
        """(status, index_or_None, error_or_None); "ready" hands the
        validated index over exactly once and, if a newer signature set
        was staged meanwhile, immediately starts building it."""
        with self._lock:
            if self._thread is not None and self._thread.is_alive():
                return "building", None, None
            idx, err = self._result, self._error
            self._result, self._error = None, None
            staged, self._staged = self._staged, None
        if staged is not None:           # latest-wins restart outside lock
            self.submit(staged[0], tail_cap=staged[1])
        if idx is not None:
            return "ready", idx, None
        if err is not None:
            return "failed", None, err
        return "idle", None, None

    def join(self, timeout: float | None = None) -> None:
        """Block until the current build (if any) finishes — for tests and
        synchronous callers; the serving loop never calls this."""
        t = self._thread
        if t is not None:
            t.join(timeout)

    # -- worker side --------------------------------------------------------

    def _work(self, sigs, tail_cap: int) -> None:
        from repro.serve.index import build_index   # import off the hot path
        t0 = time.perf_counter()
        try:
            with self.obs.span("serve.rebuild.bg"):
                faults.fire("serve.rebuild")
                idx = build_index(sigs, tail_cap=tail_cap)
                idx = faults.fire("serve.rebuild.index", idx)
                if self.validate:
                    with self.obs.span("serve.rebuild.bg.validate"):
                        probs = validate_index(idx, probe=self.probe,
                                               seed=self.seed)
                    if probs:
                        raise IndexValidationError(
                            "rebuilt index failed validation: "
                            + "; ".join(probs[:3]))
        except Exception as e:   # noqa: BLE001 — any failure means rollback
            with self._lock:
                self._error, self._result = e, None
                self.failures += 1
            self.obs.counter_add("serve.rebuild.failed")
            return
        with self._lock:
            self._result, self._error = idx, None
            self.swaps_ready += 1
        self.obs.counter_add("serve.rebuild.built")
        self.obs.gauge_set("serve.rebuild.last_build_s",
                           time.perf_counter() - t0)
