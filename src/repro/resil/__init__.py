"""repro.resil — always-on resilience layer (ISSUE 7).

The paper's claim is *online* learning: a service that keeps serving
while ΔΩ streams in.  This package is the machinery that keeps it
serving through the failures a long-running loop actually meets:

  * `faults`    — deterministic fault injection (the chaos substrate);
  * `validate`  — poison-batch quarantine (`PoisonBatchError`) and
    index invariant/recall-smoke validation (`validate_index`);
  * `rebuild`   — background double-buffered index rebuild with a
    validate-then-swap gate and rollback-by-default (`IndexRebuilder`);
  * `guard`     — divergence watchdog with snapshot rollback
    (`DivergenceError`, `GuardConfig`);
  * `wal`       — write-ahead log + crash-safe `OnlineUpdater` whose
    `recover()` replays to a bit-identical `OnlineState`.

Consumers: `serve.service` (admission control, degraded modes, swap),
`core.online` (boundary validation + guard), `train.checkpoint`
(crash-atomic saves), the chaos suite (tests/test_resil.py), and the
bench fault arm (benchmarks/bench_serve.py).  Failure semantics are
documented in docs/ARCHITECTURE.md §8.
"""
from repro.resil import faults
from repro.resil.faults import FaultPlan, FaultSpec, InjectedFault
from repro.resil.guard import DivergenceError, GuardConfig, check_divergence
from repro.resil.rebuild import IndexRebuilder
from repro.resil.validate import (IndexValidationError, PoisonBatchError,
                                  check_delta, check_ingest_batch,
                                  validate_index,
                                  validate_sharded_index)
from repro.resil.wal import OnlineUpdater, WriteAheadLog

__all__ = [
    "faults", "FaultPlan", "FaultSpec", "InjectedFault",
    "DivergenceError", "GuardConfig", "check_divergence",
    "IndexRebuilder", "IndexValidationError", "PoisonBatchError",
    "check_delta", "check_ingest_batch", "validate_index",
    "validate_sharded_index",
    "OnlineUpdater", "WriteAheadLog",
]
