"""Boundary validation: poison-batch quarantine and index invariants.

Two failure families the always-on loop must survive (ISSUE 7):

* **Poison ingest batches** — NaN values, negative / out-of-range ids,
  wrong dtypes.  Without a boundary check these don't crash: a NaN rating
  trains NaN into the packed planes, a float id silently truncates, an
  id ≥ 2³⁰ aliases in the dedup hash — all *corrupt state and keep
  serving garbage*.  `check_ingest_batch` / `check_delta` raise
  `PoisonBatchError` with an actionable message *before* any state is
  touched, so the caller's state is untouched by construction
  (quarantine = reject, not repair).

* **Corrupt indexes** — a rebuild that produced a structurally broken
  `LSHIndex` (crashed mid-build, bit-flipped buffer, buggy refactor)
  must never be swapped in.  `validate_index` checks the CSR bucket
  invariants host-side and runs a recall smoke test (every probe item
  must retrieve itself through `lookup_signatures` — self-recall is 1.0
  on a correct index by construction).  The double-buffered swap
  (`resil.rebuild`) gates on it; a failure rolls back to index v.

All checks are host-side numpy: they run on the ingestion plane (between
flushes), never inside a jitted program.
"""
from __future__ import annotations

import numpy as np

_MAX_ID = 1 << 30   # the serve-side dedup hash mask contract


class PoisonBatchError(ValueError):
    """An ingest batch failed boundary validation and was quarantined —
    no state was modified.  The message says which check failed and what
    the caller should fix."""


class IndexValidationError(RuntimeError):
    """A freshly built index failed its invariant / recall-smoke checks
    and must not be swapped in."""


def _np(x):
    return np.asarray(x)


def check_ids(ids, *, what: str, upper: int | None = None) -> np.ndarray:
    """Ids must be an integer array, non-negative, below 2³⁰ (and below
    ``upper`` when given).  Returns the host array for reuse."""
    a = _np(ids)
    if a.dtype.kind == "f":
        bad = "NaN values" if np.isnan(a).any() else "fractional ids"
        raise PoisonBatchError(
            f"{what}: float dtype {a.dtype} ({bad} would silently corrupt "
            f"integer ids) — cast to int32 after validating upstream")
    if a.dtype.kind not in "iu":
        raise PoisonBatchError(
            f"{what}: expected an integer dtype, got {a.dtype}")
    if a.size and int(a.min()) < 0:
        raise PoisonBatchError(
            f"{what}: negative id {int(a.min())} — ids are 0-based "
            f"positions in the catalog/user space")
    if a.size and int(a.max()) >= _MAX_ID:
        raise PoisonBatchError(
            f"{what}: id {int(a.max())} ≥ 2^30 breaks the serve-side dedup "
            f"hash contract (retrieve.dedup_candidates)")
    if upper is not None and a.size and int(a.max()) >= upper:
        raise PoisonBatchError(
            f"{what}: id {int(a.max())} out of range (expected < {upper})")
    return a


def check_ingest_batch(new_sigs, new_ids, *, q: int) -> None:
    """Validate one `RecsysService.ingest` batch: signatures [q, n] int32
    (no NaN-poisoned float rows), ids [n] integer, non-negative, < 2³⁰.
    Raises `PoisonBatchError`; touches no state."""
    sigs = _np(new_sigs)
    ids = check_ids(new_ids, what="ingest new_ids")
    if sigs.dtype.kind == "f":
        nan_rows = (np.isnan(sigs).any(axis=0).sum()
                    if sigs.ndim == 2 else int(np.isnan(sigs).any()))
        raise PoisonBatchError(
            f"ingest new_sigs: float dtype {sigs.dtype} "
            f"({nan_rows} NaN-poisoned columns) — signatures must be the "
            f"packed int32 output of simlsh.pack_bits / encode")
    if sigs.dtype != np.int32:
        raise PoisonBatchError(
            f"ingest new_sigs: expected int32 signatures, got {sigs.dtype}")
    if sigs.ndim != 2 or sigs.shape[0] != q:
        raise PoisonBatchError(
            f"ingest new_sigs: expected shape [q={q}, n], got "
            f"{sigs.shape} — one row per LSH band")
    if ids.ndim != 1 or sigs.shape[1] != ids.shape[0]:
        raise PoisonBatchError(
            f"ingest batch mismatch: {sigs.shape[1]} signature columns vs "
            f"{ids.shape} ids — one id per new item")
    if ids.shape[0] and np.unique(ids).shape[0] != ids.shape[0]:
        raise PoisonBatchError(
            "ingest new_ids: duplicate ids in one batch — each item may "
            "be inserted once")


def check_delta(new_rows, new_cols, new_vals, *, M_new: int, N_new: int,
                M_old: int, N_old: int) -> None:
    """Validate ΔΩ triples at the `online_update` boundary.  Raises
    `PoisonBatchError` before any accumulator / merge / training work."""
    if M_new < M_old or N_new < N_old:
        raise PoisonBatchError(
            f"online_update: grown sizes must not shrink — "
            f"M {M_old}→{M_new}, N {N_old}→{N_new}")
    rows = check_ids(new_rows, what="online_update new_rows", upper=M_new)
    cols = check_ids(new_cols, what="online_update new_cols", upper=N_new)
    vals = _np(new_vals)
    if vals.dtype.kind not in "fiu":
        raise PoisonBatchError(
            f"online_update new_vals: non-numeric dtype {vals.dtype}")
    if not (rows.shape == cols.shape == vals.shape) or rows.ndim != 1:
        raise PoisonBatchError(
            f"online_update ΔΩ: triple arrays must be equal-length 1-D, "
            f"got rows {rows.shape}, cols {cols.shape}, vals {vals.shape}")
    if rows.size == 0:
        raise PoisonBatchError("online_update ΔΩ: empty batch")
    if vals.dtype.kind == "f" and not np.isfinite(vals).all():
        n_bad = int((~np.isfinite(_np(vals))).sum())
        raise PoisonBatchError(
            f"online_update new_vals: {n_bad} non-finite ratings (NaN/inf) "
            f"— a single NaN trains NaN into every touched parameter; "
            f"filter or impute upstream")


def check_accumulators(S, N_old: int) -> None:
    """New-column accumulator slabs must be finite — a NaN-poisoned S row
    signs as garbage (NaN ≥ 0 is False, so pack_bits silently produces a
    *valid-looking* signature that lands the item in a wrong bucket)."""
    s = _np(S)
    new = s[:, N_old:] if s.ndim >= 2 else s
    if new.size and not np.isfinite(new).all():
        if new.ndim == 3:         # [q, N̄, p·G] → first poisoned column
            bad = int(np.argmax(~np.isfinite(new).all(axis=(0, 2))))
        else:
            bad = 0
        raise PoisonBatchError(
            f"online state: non-finite simLSH accumulators for new column "
            f"{N_old + bad} — re-signing would bucket it randomly; "
            f"quarantine the update that produced it")


def validate_index(index, *, probe: int = 64, seed: int = 0) -> list:
    """Structural + behavioural checks on a (candidate) `LSHIndex`.
    Returns a list of problem strings — empty means the index may be
    swapped in.  Cost is O(q·N) host-side numpy plus one jitted probe
    batch; a rebuild already paid O(q·N log N), so validation is cheap
    relative to the build it gates.  Sharded indexes dispatch to
    `validate_sharded_index` (same contract, per-shard checks)."""
    from repro.serve.index import lookup_signatures   # cycle-free at call

    if hasattr(index, "bounds"):           # ShardedLSHIndex
        return validate_sharded_index(index, probe=probe, seed=seed)

    probs: list = []
    ss = _np(index.sorted_sigs)
    si = _np(index.sorted_ids)
    lo = _np(index.bucket_lo)
    hi = _np(index.bucket_hi)
    so = _np(index.slot_of)
    q, N = ss.shape
    if N != index.n_base:
        probs.append(f"n_base {index.n_base} != array width {N}")
    for a, name in ((ss, "sorted_sigs"), (si, "sorted_ids"),
                    (lo, "bucket_lo"), (hi, "bucket_hi"), (so, "slot_of")):
        if a.shape != (q, N):
            probs.append(f"{name}: shape {a.shape} != ({q}, {N})")
        if a.dtype != np.int32:
            probs.append(f"{name}: dtype {a.dtype} != int32")
    if probs:                      # shape/dtype broken — stop before indexing
        return probs

    ar = np.arange(N, dtype=np.int64)
    for b in range(q):
        if np.any(np.diff(ss[b].astype(np.int64)) < 0):
            probs.append(f"band {b}: sorted_sigs not ascending")
        if not np.array_equal(np.sort(si[b]), ar):
            probs.append(f"band {b}: sorted_ids is not a permutation")
        elif not np.array_equal(so[b, si[b]], ar):
            probs.append(f"band {b}: slot_of is not the inverse of "
                         f"sorted_ids")
        l_ref = np.searchsorted(ss[b], ss[b], side="left")
        h_ref = np.searchsorted(ss[b], ss[b], side="right")
        if not (np.array_equal(lo[b], l_ref) and np.array_equal(hi[b], h_ref)):
            probs.append(f"band {b}: bucket_lo/hi inconsistent with "
                         f"sorted_sigs")
        if probs:
            break                  # one broken band is enough to refuse

    # recall smoke: every probed item must retrieve itself when queried
    # with its own band signatures (self-recall is exactly 1.0 on a
    # correct index — any miss is structural corruption, not ANN noise)
    if not probs and N and probe:
        rng = np.random.default_rng(seed)
        ids = rng.choice(N, size=min(probe, N), replace=False)
        qsigs = ss[np.arange(q)[:, None], so[:, ids]].T       # [P, q]
        import jax.numpy as jnp
        cand = np.asarray(lookup_signatures(
            index, jnp.asarray(qsigs, jnp.int32), cap=4))
        miss = [int(i) for k, i in enumerate(ids) if i not in cand[k]]
        if miss:
            probs.append(f"recall smoke: {len(miss)}/{len(ids)} probe items "
                         f"failed self-retrieval (e.g. id {miss[0]})")
    return probs


def validate_sharded_index(index, *, probe: int = 64, seed: int = 0) -> list:
    """`validate_index` for a `ShardedLSHIndex`: the same CSR bucket
    invariants hold *per shard* on each `shard_local_view`, plus the
    sharded-only geometry — bounds strictly increasing and covering
    [0, n_items], per-shard `n_local` consistent with the cuts and the
    common block extent, and the block-padding contract (every padded
    slot carries `_EMPTY_SIG`, so it sorts ahead of any real signature
    and no probe can land on it).

    The self-retrieval smoke is restricted to *real* local ids
    (< n_local): padding slots share one giant `_EMPTY_SIG` bucket, so
    probing them with cap=4 would report false misses on a perfectly
    healthy index."""
    from repro.serve.index import (_EMPTY_SIG, lookup_signatures,
                                   shard_local_view)

    probs: list = []
    bounds = _np(index.bounds)
    n_local = _np(index.n_local)
    D = int(index.shards)
    if bounds.shape != (D + 1,):
        return [f"bounds: shape {bounds.shape} != ({D + 1},)"]
    if bounds[0] != 0 or bounds[-1] != index.n_items:
        probs.append(f"bounds: [{bounds[0]}, {bounds[-1]}] does not cover "
                     f"[0, {index.n_items}]")
    if np.any(np.diff(bounds) <= 0):
        probs.append("bounds: not strictly increasing")
    if not np.array_equal(n_local, np.diff(bounds)):
        probs.append(f"n_local {n_local.tolist()} != diff(bounds)")
    if n_local.size and int(n_local.max()) != index.block:
        probs.append(f"block {index.block} != max shard extent "
                     f"{int(n_local.max())}")
    if probs:
        return probs

    rng = np.random.default_rng(seed)
    per = max(1, probe // D)
    for d in range(D):
        view = shard_local_view(index, d)
        for p in validate_index(view, probe=0):
            probs.append(f"shard {d}: {p}")
        ss = _np(view.sorted_sigs)
        nl = int(n_local[d])
        # padding slots: exactly block - n_local of them, all _EMPTY_SIG,
        # and no real item may carry the padding sentinel signature
        n_pad = int((ss == int(_EMPTY_SIG)).sum())
        if n_pad != (index.block - nl) * ss.shape[0]:
            probs.append(f"shard {d}: {n_pad} padding signatures, expected "
                         f"{(index.block - nl) * ss.shape[0]} "
                         f"(block {index.block} - n_local {nl} per band)")
        if probs:
            break
        if nl and per:
            ids = rng.choice(nl, size=min(per, nl), replace=False)
            so = _np(view.slot_of)
            q = ss.shape[0]
            qsigs = ss[np.arange(q)[:, None], so[:, ids]].T      # [P, q]
            import jax.numpy as jnp
            cand = np.asarray(lookup_signatures(
                view, jnp.asarray(qsigs, jnp.int32), cap=4))
            miss = [int(i) for k, i in enumerate(ids) if i not in cand[k]]
            if miss:
                probs.append(f"shard {d}: recall smoke {len(miss)}/"
                             f"{len(ids)} real items failed self-retrieval "
                             f"(e.g. local id {miss[0]})")
    return probs
