"""Deterministic fault injection — the chaos-testing substrate (ISSUE 7).

Production code is sprinkled with **injection points**:

    from repro.resil import faults
    ...
    payload = faults.fire("serve.rebuild", payload)

With no plan installed (the default, and the only state production ever
runs in) `fire` is a two-instruction no-op: one global load and a
``None`` check.  A chaos test or the bench fault arm installs a
`FaultPlan` mapping site names to `FaultSpec`s; the plan then decides
**deterministically** — per-site call counters plus a seeded hash, never
wall-clock or global RNG state — whether call *n* at a site

  * raises `InjectedFault`              (``kind="exc"``),
  * sleeps ``stall_s`` then proceeds    (``kind="stall"``),
  * returns ``mutate(payload)``         (``kind="corrupt"``).

Determinism is the point: a chaos test that fails replays exactly, and
the bench fault arm measures the *same* fault sequence every run.

Registered sites (grep for ``faults.fire`` to audit):

  ``serve.flush``          before a micro-batch dispatch (service)
  ``serve.ingest``         entry of `RecsysService.ingest`
  ``serve.rebuild``        in the rebuild worker, before building v+1
  ``serve.rebuild.index``  the built index, before validation (corrupt
                           here to prove validation catches it)
  ``ckpt.save``            inside the checkpoint writer, before the
                           atomic rename (a "crash" leaves only tmp files)
  ``online.update``        between WAL append and the state update
                           (crash-mid-ingest for WAL-replay tests)
  ``loop.slice``           top of `OnlineLoop.run_slice`, before any
                           phase runs (the between-slices crash window)
  ``loop.drift``           before the drift-detection RMSE probe (reads
                           state, never mutates it)
  ``loop.ckpt``            before the loop's atomic progress checkpoint
                           (a "crash" recovers from the previous cut
                           plus the unpruned WAL suffix)

Use as a context manager so a failing test can never leak a plan into
the next one:

    with faults.injected({"serve.rebuild": faults.FaultSpec(at_calls=(0,))}):
        ...
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
import time
import zlib
from typing import Callable


class InjectedFault(RuntimeError):
    """The exception every ``kind="exc"`` injection raises — distinct from
    any real error type so production handlers can't mask a genuine bug by
    catching it specifically (they should catch broadly and degrade)."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """What to do at one site.  ``at_calls`` lists 0-based call indices
    that fire (the deterministic workhorse); ``rate`` adds a seeded
    Bernoulli per call for soak-style runs.  ``stall_s`` applies to
    ``kind="stall"`` (and also to "exc"/"corrupt" when > 0: stall first,
    then fault — models a slow failure)."""
    kind: str = "exc"                     # exc | stall | corrupt
    at_calls: tuple = ()
    rate: float = 0.0
    stall_s: float = 0.0
    mutate: Callable | None = None        # payload transformer for corrupt
    message: str = "injected fault"

    def __post_init__(self):
        if self.kind not in ("exc", "stall", "corrupt"):
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.kind == "corrupt" and self.mutate is None:
            raise ValueError("kind='corrupt' needs a mutate= callable")


class FaultPlan:
    """Seeded, thread-safe decision table.  ``calls``/``fired`` counters
    are public so tests can assert exactly which injections happened."""

    def __init__(self, specs: dict, seed: int = 0):
        self.specs = {k: (v if isinstance(v, FaultSpec) else FaultSpec(**v))
                      for k, v in specs.items()}
        self.seed = seed
        self.calls: dict = {}
        self.fired: dict = {}
        self._lock = threading.Lock()     # rebuild/ckpt threads fire too

    def _decide(self, site: str):
        """(call index, spec-or-None, fire?) — counter bump under lock."""
        with self._lock:
            n = self.calls.get(site, 0)
            self.calls[site] = n + 1
            spec = self.specs.get(site)
            if spec is None:
                return n, None, False
            fire = n in spec.at_calls
            if not fire and spec.rate > 0.0:
                # seeded per-(site, call) hash → Bernoulli; no global RNG
                h = zlib.crc32(f"{self.seed}:{site}:{n}".encode())
                fire = (h / 0xFFFFFFFF) < spec.rate
            if fire:
                self.fired[site] = self.fired.get(site, 0) + 1
            return n, spec, fire

    def fire(self, site: str, payload=None):
        n, spec, fire = self._decide(site)
        if not fire:
            return payload
        if spec.stall_s > 0.0:
            time.sleep(spec.stall_s)
        if spec.kind == "exc":
            raise InjectedFault(f"{site}: {spec.message} (call {n})")
        if spec.kind == "corrupt":
            return spec.mutate(payload)
        return payload                    # stall: already slept


_PLAN: FaultPlan | None = None
_INSTALL_LOCK = threading.Lock()


def install(plan: FaultPlan) -> FaultPlan:
    """Install a plan process-wide.  Refuses to stack plans — overlapping
    chaos scenarios would make each other's counters meaningless."""
    global _PLAN
    with _INSTALL_LOCK:
        if _PLAN is not None:
            raise RuntimeError("a FaultPlan is already installed")
        _PLAN = plan
    return plan


def uninstall() -> None:
    global _PLAN
    with _INSTALL_LOCK:
        _PLAN = None


def active() -> FaultPlan | None:
    return _PLAN


@contextlib.contextmanager
def injected(specs_or_plan, seed: int = 0):
    """``with faults.injected({...}): ...`` — install for the block only."""
    plan = (specs_or_plan if isinstance(specs_or_plan, FaultPlan)
            else FaultPlan(specs_or_plan, seed=seed))
    install(plan)
    try:
        yield plan
    finally:
        uninstall()


def fire(site: str, payload=None):
    """The injection point.  No plan installed → returns payload untouched
    (the production fast path: one global read + None check)."""
    plan = _PLAN
    if plan is None:
        return payload
    return plan.fire(site, payload)
