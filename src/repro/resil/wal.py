"""Write-ahead log + crash-safe wrapper for the Alg.-4 online path.

`core.online.online_update` is a pure function — state in, state out —
which makes crash safety a logging problem, not a locking problem:

  1. **append** the ΔΩ triples, the PRNG key, and the static update
     arguments to the WAL (atomic: temp file + ``os.replace``, one file
     per entry, so a torn append is invisible);
  2. apply the update in memory;
  3. every ``ckpt_every`` updates, **checkpoint** the full `OnlineState`
     through `train.checkpoint` (itself crash-atomic) and prune WAL
     entries the checkpoint now covers.

A crash anywhere in (2)–(3) loses only process memory.  `recover()`
restores the newest complete checkpoint and **replays** every WAL entry
past it through the same `online_update` — same state, same triples,
same key, same deterministic CPU/XLA program ⇒ the recovered
`OnlineState` is **bit-identical** to what an uninterrupted run would
hold (asserted leaf-for-leaf in tests/test_resil.py).  Entries that
tripped the divergence guard live re-trip identically on replay and stay
rejected, so guard rollbacks are replay-stable too.

The WAL stores *inputs*, not states: an entry is a few KB of triples
versus the full factor planes, so logging cost is O(|ΔΩ|) per update and
the checkpoint cadence alone controls recovery time.

The always-on loop (`repro.loop`, ISSUE 10) shares this log: it appends
``kind="slice"`` entries (a slice's ΔΩ batches plus its micro-epoch
spec) into the same seq space and owns the checkpoint cadence with a
wider template (state + loop cursors).  `OnlineUpdater.recover` refuses
such entries and points at `OnlineLoop.recover`, which replays both.
"""
from __future__ import annotations

import dataclasses
import json
import os

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.model import Params
from repro.data.sparse import SparseMatrix
from repro.resil import faults
from repro.resil.guard import DivergenceError, GuardConfig
from repro.train import checkpoint

_PREFIX = "wal-"


@dataclasses.dataclass(frozen=True)
class WalEntry:
    seq: int
    arrays: dict      # rows, cols, vals, key (host numpy)
    meta: dict        # M_new, N_new, K, epochs, batch


class WriteAheadLog:
    """One ``wal-{seq:012d}.npz`` per entry under ``directory``.  Appends
    are atomic (temp + ``os.replace``); readers therefore never see a
    torn entry — a crash mid-append leaves only a ``.tmp-`` file, which
    is ignored and cleaned lazily."""

    def __init__(self, directory: str):
        self.directory = directory
        os.makedirs(directory, exist_ok=True)

    def _path(self, seq: int) -> str:
        return os.path.join(self.directory, f"{_PREFIX}{seq:012d}.npz")

    def seqs(self) -> list:
        out = []
        for f in os.listdir(self.directory):
            if f.startswith(_PREFIX) and f.endswith(".npz"):
                try:
                    out.append(int(f[len(_PREFIX):-4]))
                except ValueError:
                    continue
        return sorted(out)

    def last_seq(self) -> int:
        s = self.seqs()
        return s[-1] if s else 0

    def append(self, seq: int, arrays: dict, meta: dict) -> str:
        faults.fire("wal.append")
        final = self._path(seq)
        if os.path.exists(final):
            raise ValueError(f"WAL entry {seq} already exists — sequence "
                             f"numbers must be unique and increasing")
        tmp = os.path.join(self.directory, f".tmp-{seq:012d}-{os.getpid()}")
        with open(tmp, "wb") as f:
            np.savez(f, __meta__=json.dumps(meta),
                     **{k: np.asarray(v) for k, v in arrays.items()})
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, final)
        return final

    def read(self, seq: int) -> WalEntry:
        with np.load(self._path(seq), allow_pickle=False) as data:
            meta = json.loads(str(data["__meta__"]))
            arrays = {k: data[k] for k in data.files if k != "__meta__"}
        return WalEntry(seq=seq, arrays=arrays, meta=meta)

    def entries(self, after: int = 0) -> list:
        """All entries with seq > ``after``, ascending — the redo set."""
        return [self.read(s) for s in self.seqs() if s > after]

    def prune(self, upto: int) -> int:
        """Drop entries with seq ≤ ``upto`` (covered by a checkpoint) and
        any stale temp files.  Returns how many entries were removed."""
        n = 0
        for s in self.seqs():
            if s <= upto:
                os.remove(self._path(s))
                n += 1
        for f in os.listdir(self.directory):
            if f.startswith(".tmp-"):
                try:
                    os.remove(os.path.join(self.directory, f))
                except OSError:
                    pass
        return n


# ---------------------------------------------------------------------------
# OnlineState <-> checkpoint tree
# ---------------------------------------------------------------------------

_PARAM_FIELDS = ("U", "V", "b", "bh", "W", "C", "mu")


def state_tree(st) -> dict:
    """`OnlineState` → flat dict-of-arrays pytree for `train.checkpoint`.
    M/N/shape are recovered from array shapes; ``stats`` is transient and
    deliberately not persisted."""
    if st.hash_key is None:
        raise ValueError("OnlineState.hash_key is unset — a state without "
                         "its Φ-family key cannot be restored usefully")
    tree = {f: getattr(st.params, f) for f in _PARAM_FIELDS}
    tree.update(S=st.S, JK=st.JK, sp_rows=st.sp.rows, sp_cols=st.sp.cols,
                sp_vals=st.sp.vals, hash_key=st.hash_key)
    return tree


def state_from_tree(tree: dict):
    from repro.core.online import OnlineState   # import cycle: wal ← online
    params = Params(**{f: jnp.asarray(tree[f]) for f in _PARAM_FIELDS})
    M, N = int(params.U.shape[0]), int(params.V.shape[0])
    sp = SparseMatrix(jnp.asarray(tree["sp_rows"]),
                      jnp.asarray(tree["sp_cols"]),
                      jnp.asarray(tree["sp_vals"]), (M, N))
    return OnlineState(params=params, S=jnp.asarray(tree["S"]),
                       JK=jnp.asarray(tree["JK"]), sp=sp, M=M, N=N,
                       hash_key=jnp.asarray(tree["hash_key"]))


def _template() -> dict:
    keys = _PARAM_FIELDS + ("S", "JK", "sp_rows", "sp_cols", "sp_vals",
                            "hash_key")
    return {k: 0 for k in keys}     # structure only; leaves are replaced


# ---------------------------------------------------------------------------
# the crash-safe updater
# ---------------------------------------------------------------------------


class OnlineUpdater:
    """WAL-logged, checkpointed, divergence-guarded `online_update` loop.

    Layout under ``root``: ``root/wal/`` (redo log) and ``root/ckpt/``
    (crash-atomic `train.checkpoint` steps, step number = update seq).

    The static update arguments (lsh config, hyper-params, K, epochs,
    batch) are fixed per updater — they are part of the replay contract,
    so `recover` takes the same constructor arguments and refuses meta
    that disagrees with what an entry was logged with.
    """

    def __init__(self, state, lsh, hp, *, root: str, K: int,
                 epochs: int = 3, batch: int = 4096, ckpt_every: int = 4,
                 guard: GuardConfig | None = GuardConfig(),
                 registry: obs.Registry | None = None,
                 _seq: int = 0, _ckpt_seq: int = 0):
        self.state = state
        self.lsh, self.hp = lsh, hp
        self.K, self.epochs, self.batch = K, epochs, batch
        self.ckpt_every = ckpt_every
        self.guard = guard
        self.obs = registry if registry is not None else obs.scoped()
        self.root = root
        self.wal = WriteAheadLog(os.path.join(root, "wal"))
        self.ckpt_dir = os.path.join(root, "ckpt")
        os.makedirs(self.ckpt_dir, exist_ok=True)
        self.seq = _seq
        self._ckpt_seq = _ckpt_seq

    def _static_meta(self) -> dict:
        return dict(K=self.K, epochs=self.epochs, batch=self.batch,
                    lsh=dataclasses.asdict(self.lsh),
                    hp=dataclasses.asdict(self.hp))

    def update(self, new_rows, new_cols, new_vals, key, *,
               M_new: int, N_new: int):
        """Validate → WAL append → apply → (periodic) checkpoint.

        Raises `PoisonBatchError` *before* logging (quarantined batches
        never enter the redo log) and `DivergenceError` *after* logging
        (the guard rollback is replay-stable — see module docstring); in
        both cases ``self.state`` is unchanged."""
        from repro.core.online import online_update
        from repro.resil.validate import check_delta
        # quarantine before logging: a poison batch must not enter the redo
        # log, or recovery would replay the rejection forever
        check_delta(new_rows, new_cols, new_vals, M_new=M_new, N_new=N_new,
                    M_old=self.state.M, N_old=self.state.N)
        seq = self.seq + 1
        meta = dict(self._static_meta(), M_new=M_new, N_new=N_new, seq=seq)
        with self.obs.span("resil.wal.append"):
            self.wal.append(seq, dict(rows=new_rows, cols=new_cols,
                                      vals=new_vals, key=np.asarray(key)),
                            meta)
        self.obs.counter_add("resil.wal.appends")
        faults.fire("online.update")      # the crash-mid-ingest window
        try:
            st2 = online_update(self.state, new_rows, new_cols, new_vals,
                                self.lsh, self.hp, jnp.asarray(key),
                                M_new=M_new, N_new=N_new, K=self.K,
                                epochs=self.epochs, batch=self.batch,
                                guard=self.guard, registry=self.obs)
        except DivergenceError:
            # rejected update: seq still advances (the entry is logged and
            # will re-trip on replay), state stays rolled back
            self.seq = seq
            self.obs.counter_add("resil.guard_trips")
            raise
        self.state, self.seq = st2, seq
        if seq - self._ckpt_seq >= self.ckpt_every:
            self.checkpoint()
        return self.state

    def checkpoint(self) -> None:
        """Durable cut: crash-atomic state checkpoint at the current seq,
        then prune the WAL entries it covers."""
        with self.obs.span("resil.ckpt"):
            checkpoint.save(self.ckpt_dir, state_tree(self.state),
                            step=self.seq, sync=True)
        self.wal.prune(self.seq)
        self._ckpt_seq = self.seq
        self.obs.counter_add("resil.ckpts")

    @classmethod
    def recover(cls, root: str, lsh, hp, *, K: int, epochs: int = 3,
                batch: int = 4096, base_state=None, ckpt_every: int = 4,
                guard: GuardConfig | None = GuardConfig(),
                registry: obs.Registry | None = None) -> "OnlineUpdater":
        """Rebuild the pre-crash updater: newest complete checkpoint (torn
        steps are skipped by `train.checkpoint`) + WAL replay of every
        entry past it.  ``base_state`` seeds a run that crashed before its
        first checkpoint (required then; ignored when a checkpoint
        exists)."""
        from repro.core.online import online_update
        reg = registry if registry is not None else obs.scoped()
        ckpt_dir = os.path.join(root, "ckpt")
        restored = checkpoint.try_restore(ckpt_dir, _template())
        if restored is not None:
            tree, step = restored
            state = state_from_tree(tree)
        elif base_state is not None:
            state, step = base_state, 0
        else:
            raise FileNotFoundError(
                f"no complete checkpoint under {ckpt_dir} and no "
                f"base_state to replay from")
        up = cls(state, lsh, hp, root=root, K=K, epochs=epochs, batch=batch,
                 ckpt_every=ckpt_every, guard=guard, registry=reg,
                 _seq=step, _ckpt_seq=step)
        want = dict(K=K, epochs=epochs, batch=batch,
                    lsh=dataclasses.asdict(lsh), hp=dataclasses.asdict(hp))
        for e in up.wal.entries(after=step):
            if e.meta.get("kind") is not None:
                raise ValueError(
                    f"WAL entry {e.seq} is a {e.meta['kind']!r} entry "
                    f"written by the always-on loop — recover with "
                    f"repro.loop.OnlineLoop.recover(), which also replays "
                    f"micro-epochs and loop cursors")
            for k, v in want.items():
                if e.meta.get(k) != v:
                    raise ValueError(
                        f"WAL entry {e.seq} was logged with {k}="
                        f"{e.meta.get(k)!r} but recover() got {v!r} — "
                        f"replay with the original static arguments")
            with reg.span("resil.wal.replay"):
                try:
                    up.state = online_update(
                        up.state, e.arrays["rows"], e.arrays["cols"],
                        e.arrays["vals"], lsh, hp,
                        jnp.asarray(e.arrays["key"]),
                        M_new=e.meta["M_new"], N_new=e.meta["N_new"],
                        K=K, epochs=epochs, batch=batch, guard=guard,
                        registry=reg)
                except DivergenceError:
                    reg.counter_add("resil.guard_trips")   # replay-stable
            up.seq = e.seq
            reg.counter_add("resil.wal.replayed")
        return up
