"""Bench-artifact gate: validate BENCH_*.json documents against the
schema the rest of the repo (CI, docs, PR claims) relies on.

The benchmarks write structured JSON (``bench_train.py`` →
BENCH_train.json, ``bench_serve.py`` → BENCH_serve.json,
``bench_online.py`` → BENCH_online.json).  Their shape is
a contract: `--check` floors read them, docs/ARCHITECTURE.md cites them,
and cross-PR speedup claims diff them.  This tool fails fast when a
refactor silently drops or renames a field, so a bench JSON that CI
archives is always a complete one.

Checks per document (dependency-free, stdlib json only):

  * top-level metadata: ``benchmark``, ``backend``, ``jax_version`` and a
    ``protocol`` dict that records the timing methodology and the
    ``floors`` the --check gate enforces (a floor that isn't recorded
    next to the numbers it gates is a floor nobody can audit);
  * per-entry requireds — every ``scales[]`` entry (train) must carry the
    base/sched/kernel timing blocks, schedule stats and the obs-overhead
    section; every ``sizes[]`` entry (serve) the full/cand QPS blocks,
    recall, the staged breakdown and the obs-overhead section;
  * type/range sanity: timings positive and finite, recall in [0, 1],
    counters non-negative — a NaN that sneaks into a JSON would otherwise
    pass every `>=` floor (NaN comparisons are False, so `--check`
    style gates silently approve it);
  * ``fault_scenario`` (serve, required): the ISSUE 7 fault arm must ship
    with every serve bench — ``shed_rate``/``recall_under_fault`` in
    [0, 1], ``recover_seconds`` ≥ 0, a ``recovered`` bool;
  * ``sharded`` (serve, required): the ISSUE 9 sharded arm — per-D QPS
    dict including the same-window D=1 re-measure, ``scaling_ratio``,
    recall parity fields in [0, 1], and the ``hardware_bound`` bool the
    scaling floor keys on;
  * ``bench_online`` (ISSUE 10): the fault-free drift arm (monotone
    ``rmse_over_time`` windows, staleness p99 ≥ 0), the per-site kill +
    recover arm (``recovered``/``state_bit_identical`` bools,
    ``rejoin_slices`` ≥ 0, ``dropped`` == 0) and the oracle recall trio
    in [0, 1];
  * ``pr1_same_window`` / ``pr7_same_window`` (serve, optional): when
    present, every size entry must carry the re-measured baseline QPS
    fields — a same-window claim without numbers is not a claim.  Serve
    size entries also require the walk-path breakdown fields
    (``retrieve_kernel_ms``, ``dedup_in_kernel``) and the ``route``
    verdict dict.

Exit non-zero listing every violation.  Run as (CI does, right after the
smoke benches):

    python tools/check_bench.py BENCH_train.json BENCH_serve.json
"""
from __future__ import annotations

import json
import math
import sys


def _num(doc, path, lo=None, hi=None, errs=None):
    """Fetch a dotted path; record an error if missing/non-finite/out of
    range.  Returns the value (or None)."""
    cur = doc
    for part in path.split("."):
        if not isinstance(cur, dict) or part not in cur:
            errs.append(f"missing field: {path}")
            return None
        cur = cur[part]
    if isinstance(cur, bool) or not isinstance(cur, (int, float)):
        errs.append(f"{path}: expected number, got {type(cur).__name__}")
        return None
    if isinstance(cur, float) and not math.isfinite(cur):
        errs.append(f"{path}: non-finite value {cur}")
        return None
    if lo is not None and cur < lo:
        errs.append(f"{path}: {cur} < {lo}")
    if hi is not None and cur > hi:
        errs.append(f"{path}: {cur} > {hi}")
    return cur


def _meta(doc, name, errs):
    for f in ("benchmark", "backend", "jax_version"):
        if not isinstance(doc.get(f), str) or not doc.get(f):
            errs.append(f"missing/empty metadata: {f}")
    if doc.get("benchmark") != name:
        errs.append(f"benchmark field is {doc.get('benchmark')!r}, "
                    f"expected {name!r}")
    proto = doc.get("protocol")
    if not isinstance(proto, dict):
        errs.append("missing protocol dict")
    else:
        if not isinstance(proto.get("timing"), str):
            errs.append("protocol.timing missing (methodology must be "
                        "recorded next to the numbers)")
        floors = proto.get("floors")
        if not isinstance(floors, dict) or not floors:
            errs.append("protocol.floors missing (the --check floors must "
                        "be recorded in the artifact they gate)")
        else:
            for k, v in floors.items():
                if not isinstance(v, (int, float)) or isinstance(v, bool):
                    errs.append(f"protocol.floors.{k}: not a number")


def _obs_overhead(entry, prefix, errs, *, time_like):
    ov = entry.get("obs_overhead")
    if not isinstance(ov, dict):
        errs.append(f"{prefix}: missing obs_overhead section (ISSUE 6: "
                    f"the instrumentation-cost measurement ships with "
                    f"every bench run)")
        return
    keys = (("enabled_sec_per_epoch", "disabled_sec_per_epoch")
            if time_like else ("enabled_qps", "disabled_qps"))
    for k in keys:
        _num(ov, k, lo=0.0, errs=errs)
    # the overhead itself is noise-bounded, not floor-gated: assert only
    # that it was measured and is sane (|frac| < 0.5 catches a broken
    # measurement, not an unlucky container window)
    f = _num(ov, "overhead_frac", errs=errs)
    if f is not None and abs(f) > 0.5:
        errs.append(f"{prefix}: obs_overhead.overhead_frac {f:+.3f} "
                    f"implausible (broken measurement?)")


def check_train(doc) -> list:
    errs: list = []
    _meta(doc, "bench_train", errs)
    scales = doc.get("scales")
    if not isinstance(scales, list) or not scales:
        return errs + ["scales: missing or empty"]
    for e in scales:
        p = f"scales[{e.get('name', '?')}]"
        for f in ("name",):
            if not isinstance(e.get(f), str):
                errs.append(f"{p}: missing {f}")
        for f in ("M", "N", "nnz", "epochs"):
            _num(e, f, lo=1, errs=errs)
        for path_ in ("base", "sched", "kernel"):
            for f in ("sec_per_epoch", "updates_per_sec", "compile_sec",
                      "rmse"):
                _num(e, f"{path_}.{f}", lo=0.0, errs=errs)
        _num(e, "schedule.cf_frac", lo=0.0, hi=1.0, errs=errs)
        _num(e, "schedule.prep_sec", lo=0.0, errs=errs)
        _num(e, "speedup_sched", lo=0.0, errs=errs)
        _num(e, "speedup_kernel", lo=0.0, errs=errs)
        _obs_overhead(e, p, errs, time_like=True)
    return errs


def check_serve(doc) -> list:
    errs: list = []
    _meta(doc, "bench_serve", errs)
    sizes = doc.get("sizes")
    if not isinstance(sizes, list) or not sizes:
        return errs + ["sizes: missing or empty"]
    for e in sizes:
        p = f"sizes[N={e.get('N', '?')}]"
        for f in ("N", "M", "nnz", "topn", "batch", "C"):
            _num(e, f, lo=1, errs=errs)
        for mode in ("full", "cand"):
            for f in ("qps", "p50_ms", "p95_ms", "batches"):
                _num(e, f"{mode}.{f}", lo=0.0, errs=errs)
        _num(e, "qps_ratio", lo=0.0, errs=errs)
        _num(e, "recall", lo=0.0, hi=1.0, errs=errs)
        for f in ("retrieve_ms", "score_ms", "pool_ms", "dedup_ms",
                  "retrieve_kernel_ms", "flush_ms"):
            _num(e, f"breakdown.{f}", lo=0.0, errs=errs)
        bd = e.get("breakdown")
        if not isinstance(bd, dict) or not isinstance(
                bd.get("dedup_in_kernel"), bool):
            errs.append(f"{p}: breakdown.dedup_in_kernel missing/not bool")
        if not isinstance(e.get("scorer_hlo_cube_free"), bool):
            errs.append(f"{p}: scorer_hlo_cube_free missing/not bool")
        route = e.get("route")
        if not isinstance(route, dict):
            errs.append(f"{p}: route missing (the small-catalog routing "
                        f"verdict ships with every size entry)")
        else:
            _num(route, "threshold", lo=0, errs=errs)
            _num(route, "n_items", lo=1, errs=errs)
            if not isinstance(route.get("enabled"), bool):
                errs.append(f"{p}: route.enabled missing/not bool")
            if route.get("decision") not in ("full", "candidate"):
                errs.append(f"{p}: route.decision "
                            f"{route.get('decision')!r} invalid")
        _obs_overhead(e, p, errs, time_like=False)
    fs = doc.get("fault_scenario")
    if not isinstance(fs, dict):
        errs.append("fault_scenario: missing section (ISSUE 7: every serve "
                    "bench run includes the fault arm — shed rate, recall "
                    "under fault, time-to-recover)")
    else:
        _num(fs, "shed_rate", lo=0.0, hi=1.0, errs=errs)
        _num(fs, "recover_seconds", lo=0.0, errs=errs)
        _num(fs, "recall_under_fault", lo=0.0, hi=1.0, errs=errs)
        _num(fs, "recall_fault_free", lo=0.0, hi=1.0, errs=errs)
        _num(fs, "p99_ratio", lo=0.0, errs=errs)
        if not isinstance(fs.get("recovered"), bool):
            errs.append("fault_scenario: recovered missing/not bool")
    sh = doc.get("sharded")
    if not isinstance(sh, dict):
        errs.append("sharded: missing section (ISSUE 9: every serve bench "
                    "run includes the D-sharded arm — per-D QPS with a "
                    "same-window D=1 re-measure, scaling ratio, recall "
                    "parity vs the single-device walk path)")
    else:
        _num(sh, "N", lo=1, errs=errs)
        D = _num(sh, "D", lo=1, errs=errs)
        _num(sh, "cpu_count", lo=1, errs=errs)
        qps = sh.get("qps")
        if not isinstance(qps, dict) or not qps:
            errs.append("sharded.qps: missing/empty per-D QPS dict")
        else:
            for k in qps:
                _num(qps, k, lo=0.0, errs=errs)
            if "1" not in qps:
                errs.append("sharded.qps: missing the same-window D=1 "
                            "re-measure (scaling claims need it)")
            if D is not None and str(int(D)) not in qps:
                errs.append(f"sharded.qps: missing the D={int(D)} arm")
        _num(sh, "scaling_ratio", lo=0.0, errs=errs)
        _num(sh, "recall_sharded", lo=0.0, hi=1.0, errs=errs)
        _num(sh, "recall_single", lo=0.0, hi=1.0, errs=errs)
        _num(sh, "recall_delta", lo=-1.0, hi=1.0, errs=errs)
        if not isinstance(sh.get("hardware_bound"), bool):
            errs.append("sharded: hardware_bound missing/not bool (the "
                        "scaling floor's meaning depends on it)")
    for section in ("pr1_same_window", "pr7_same_window"):
        base = doc.get(section)
        if base is None:
            continue
        if not isinstance(base, dict):
            errs.append(f"{section}: not a dict")
            continue
        for k, v in base.items():
            if not isinstance(v, dict):
                continue        # metadata (baseline commit)
            for f in ("full_qps", "cand_qps", "recall"):
                _num(v, f, lo=0.0, errs=errs)
    return errs


def _rmse_curve(owner, curve, prefix, errs) -> None:
    """``rmse_over_time`` contract: a non-empty list of {slice, rmse}
    windows whose slice indices are strictly increasing (a shuffled or
    duplicated curve means two arms got merged) and whose RMSEs are
    finite positives."""
    if not isinstance(curve, list) or not curve:
        errs.append(f"{prefix}.rmse_over_time: missing or empty")
        return
    prev = None
    for i, c in enumerate(curve):
        if not isinstance(c, dict):
            errs.append(f"{prefix}.rmse_over_time[{i}]: not an object")
            continue
        s = _num(c, "slice", lo=0, errs=errs)
        _num(c, "rmse", lo=0.0, errs=errs)
        if s is not None and prev is not None and s <= prev:
            errs.append(f"{prefix}.rmse_over_time[{i}]: slice {s} not "
                        f"after {prev} (windows must be monotone)")
        prev = s if s is not None else prev


def check_online(doc) -> list:
    errs: list = []
    _meta(doc, "bench_online", errs)
    ff = doc.get("fault_free")
    if not isinstance(ff, dict):
        errs.append("fault_free: missing section (the drift-arm baseline "
                    "every fault comparison is made against)")
    else:
        for f in ("slices", "publishes", "micro_epochs"):
            _num(ff, f, lo=1, errs=errs)
        for f in ("seconds", "staleness_p99_s", "staleness_max_s",
                  "rmse_first", "rmse_last", "ckpts", "drift_rebuilds",
                  "users", "qps", "degraded", "dropped"):
            _num(ff, f, lo=0.0, errs=errs)
        _rmse_curve(ff, ff.get("rmse_over_time"), "fault_free", errs)
    fa = doc.get("fault")
    sites = fa.get("sites") if isinstance(fa, dict) else None
    if not isinstance(sites, list) or not sites:
        errs.append("fault.sites: missing or empty (ISSUE 10: the kill + "
                    "recover arm ships with every online bench)")
    else:
        for e in sites:
            p = f"fault.sites[{e.get('site', '?')}]"
            if not isinstance(e.get("site"), str):
                errs.append(f"{p}: site missing/not str")
            for f in ("killed", "recovered", "state_bit_identical"):
                if not isinstance(e.get(f), bool):
                    errs.append(f"{p}: {f} missing/not bool")
            if e.get("recovered"):
                _num(e, "recover_seconds", lo=0.0, errs=errs)
                _num(e, "rejoin_slices", lo=0, errs=errs)
                _num(e, "wal_replayed", lo=0, errs=errs)
                _num(e, "dropped", lo=0, hi=0, errs=errs)
                _rmse_curve(e, e.get("rmse_over_time"), p, errs)
    _num(doc, "recall_under_drift", lo=0.0, hi=1.0, errs=errs)
    _num(doc, "recall_oracle", lo=0.0, hi=1.0, errs=errs)
    _num(doc, "recall_delta", lo=0.0, hi=1.0, errs=errs)
    return errs


CHECKERS = {"bench_train": check_train, "bench_serve": check_serve,
            "bench_online": check_online}


def check_file(path: str) -> list:
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"unreadable: {e}"]
    if not isinstance(doc, dict):
        return ["top level is not an object"]
    checker = CHECKERS.get(doc.get("benchmark"))
    if checker is None:
        return [f"unknown benchmark field {doc.get('benchmark')!r} "
                f"(expected one of {sorted(CHECKERS)})"]
    return checker(doc)


def main(argv=None) -> int:
    paths = (argv if argv is not None else sys.argv[1:])
    if not paths:
        print("usage: check_bench.py BENCH_train.json [BENCH_serve.json ...]",
              file=sys.stderr)
        return 2
    bad = 0
    for path in paths:
        errs = check_file(path)
        for e in errs:
            print(f"SCHEMA FAIL {path}: {e}", file=sys.stderr)
        bad += bool(errs)
        if not errs:
            print(f"# {path}: schema OK")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
