"""Docs gate: execute the docs' code blocks and verify their links.

Two checks over the repo's Markdown docs (README.md, docs/, benchmarks/):

1. every fenced ```python block containing ``>>>`` prompts is run
   through `doctest` (so the architecture walkthrough can't silently rot
   as the API moves), and
2. every relative Markdown link resolves to an existing file, and every
   in-repo ``#anchor`` matches a heading in the target file (GitHub
   slug rules, approximated).

Exit non-zero on any failure.  Run as:
    PYTHONPATH=src python tools/check_docs.py
"""
from __future__ import annotations

import doctest
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
DOCS = [ROOT / "README.md", ROOT / "docs" / "ARCHITECTURE.md",
        ROOT / "benchmarks" / "README.md"]

FENCE = re.compile(r"```python\n(.*?)```", re.S)
LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING = re.compile(r"^#{1,6}\s+(.*)$", re.M)


def slugify(heading: str) -> str:
    """GitHub-style heading anchor (approximate: enough for these docs)."""
    s = re.sub(r"[`*_]", "", heading.strip().lower())
    s = re.sub(r"[^\w\- ]", "", s)
    return s.replace(" ", "-")


def run_doctests(doc: pathlib.Path) -> list[str]:
    fails = []
    text = doc.read_text()
    for n, block in enumerate(FENCE.findall(text)):
        if ">>>" not in block:
            continue
        runner = doctest.DocTestRunner(verbose=False,
                                       optionflags=doctest.ELLIPSIS)
        test = doctest.DocTestParser().get_doctest(
            block, {}, f"{doc.name}[block {n}]", str(doc), 0)
        out = []
        runner.run(test, out=out.append)
        if runner.failures:
            fails.append(f"{doc.name} python block {n}: "
                         f"{runner.failures} doctest failure(s)\n"
                         + "".join(out))
    return fails


def check_links(doc: pathlib.Path) -> list[str]:
    fails = []
    text = doc.read_text()
    for target in LINK.findall(text):
        if re.match(r"^[a-z]+://|^mailto:", target):
            continue                      # external — not checked offline
        path_part, _, anchor = target.partition("#")
        dest = (doc.parent / path_part).resolve() if path_part else doc
        if path_part and not dest.exists():
            fails.append(f"{doc.name}: broken link -> {target}")
            continue
        if anchor and dest.suffix == ".md":
            slugs = {slugify(h) for h in HEADING.findall(dest.read_text())}
            if anchor.lower() not in slugs:
                fails.append(f"{doc.name}: broken anchor -> {target}")
    return fails


def main() -> int:
    fails = []
    for doc in DOCS:
        if not doc.exists():
            fails.append(f"missing doc: {doc.relative_to(ROOT)}")
            continue
        fails += run_doctests(doc)
        fails += check_links(doc)
    for f in fails:
        print(f"DOCS FAIL: {f}", file=sys.stderr)
    if not fails:
        print(f"docs ok: {len(DOCS)} files, doctests + links clean")
    return 1 if fails else 0


if __name__ == "__main__":
    sys.exit(main())
