"""Online learning (paper Alg. 4): new users/items arrive, the model
updates incrementally — no retraining of existing parameters.

    PYTHONPATH=src python examples/online_learning.py
"""
import dataclasses
import time

import jax
import numpy as np

from repro.core import model, online
from repro.core.sgd import Hyper
from repro.core.simlsh import SimLSHConfig
from repro.data import synthetic as syn
from repro.data.sparse import from_coo, train_test_split
from repro.train.trainer import FitConfig, fit


def main():
    spec = dataclasses.replace(syn.MOVIELENS_LIKE, M=3000, N=500,
                               nnz=150_000)
    rows, cols, vals, _ = syn.generate(spec, seed=0)
    (tr_r, tr_c, tr_v), te = train_test_split(
        np.random.default_rng(0), rows, cols, vals)

    # "original" world = ids below the cut; the rest arrives later
    M0, N0 = spec.M - 100, spec.N - 16
    old = (tr_r < M0) & (tr_c < N0)
    lsh = SimLSHConfig(G=8, p=1, q=10, band_cap=16)
    cfg = FitConfig(F=32, K=16, epochs=6, method="simlsh", lsh=lsh,
                    eval_every=6)
    print("training on the original set...")
    res = fit((tr_r[old], tr_c[old], tr_v[old]), te, (M0, N0), cfg)

    st = online.OnlineState(
        params=res.params, S=res.S, JK=res.JK,
        sp=from_coo(tr_r[old], tr_c[old], tr_v[old], (M0, N0)),
        M=M0, N=N0, hash_key=res.hash_key)

    print(f"{int((~old).sum()):,} new interactions arrive "
          f"(new users ≥ {M0}, new items ≥ {N0})")
    t0 = time.time()
    st2 = online.online_update(
        st, tr_r[~old], tr_c[~old], tr_v[~old], lsh, Hyper(),
        jax.random.PRNGKey(0), M_new=spec.M, N_new=spec.N, K=16, epochs=3)
    t_online = time.time() - t0

    te_r, te_c, te_v = (np.asarray(a) for a in te)
    import jax.numpy as jnp
    rmse = float(model.rmse(st2.params, st2.sp, st2.JK,
                            jnp.asarray(te_r), jnp.asarray(te_c),
                            jnp.asarray(te_v)))
    print(f"online update: {t_online:.2f}s → rmse {rmse:.4f} "
          f"(retrain-from-scratch rmse for reference: run quickstart)")


if __name__ == "__main__":
    main()
