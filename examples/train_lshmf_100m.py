"""End-to-end driver: train a ~100M-parameter LSH-MF model for a few
hundred steps, with checkpointing (deliverable (b): the ~100M train run).

Model size: (M + N)·F + 3·N·K + M + N ≈ 100M params at
M=700k, N=30k, F=128, K=64 — the netflix-scale geometry of the paper.
Data is a matched synthetic sparse matrix (~2M interactions here to keep
the CPU run in minutes; the trainer streams epochs of conflict-averaged
mini-batches, each jit-compiled once).

    PYTHONPATH=src python examples/train_lshmf_100m.py [--small]
        [--trace /tmp/train_trace.json]
"""
import argparse
import dataclasses
import time

import numpy as np

from repro import obs
from repro.core.simlsh import SimLSHConfig
from repro.data import synthetic as syn
from repro.data.sparse import train_test_split
from repro.train.trainer import FitConfig, fit


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--small", action="store_true",
                    help="10M-param variant (fast CI-style run)")
    ap.add_argument("--resume", action="store_true",
                    help="resume from the checkpoint dir instead of fresh")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write the fit's obs spans as Chrome trace-event "
                         "JSON (load in https://ui.perfetto.dev)")
    args = ap.parse_args()

    if args.small:
        M, N, F, K, nnz, epochs = 80_000, 6_000, 64, 32, 400_000, 3
    else:
        M, N, F, K, nnz, epochs = 700_000, 30_000, 128, 64, 2_000_000, 3

    nparams = (M + N) * F + 3 * N * K + M + N
    print(f"model: M={M:,} N={N:,} F={F} K={K} → {nparams/1e6:.1f}M params")

    spec = dataclasses.replace(syn.MOVIELENS_LIKE, M=M, N=N, nnz=nnz)
    t0 = time.time()
    rows, cols, vals, _ = syn.generate(spec, seed=0)
    tr, te = train_test_split(np.random.default_rng(0), rows, cols, vals)
    print(f"data: {len(vals):,} interactions ({time.time()-t0:.1f}s)")

    steps_per_epoch = -(-len(tr[0]) // 8192)
    print(f"{epochs} epochs × {steps_per_epoch} steps "
          f"= {epochs * steps_per_epoch} optimizer steps")

    ckpt_dir = f"/tmp/lshmf_100m_ckpt_{'small' if args.small else 'full'}"
    if not args.resume:
        import shutil
        shutil.rmtree(ckpt_dir, ignore_errors=True)
    cfg = FitConfig(
        F=F, K=K, epochs=epochs, batch=8192, method="simlsh",
        lsh=SimLSHConfig(G=8, p=1, q=10, band_cap=16),
        ckpt_dir=ckpt_dir, ckpt_every=1,
    )
    res = fit(tr, te, (M, N), cfg, log=print)
    print(f"done: rmse={res.history[-1][2]:.4f}, "
          f"neighbour stage {res.neighbour_seconds:.1f}s")

    # --- observability summary (ISSUE 6): every number below is read
    # back from the fit's obs registry — the same spans a --trace export
    # shows in Perfetto, so the printed summary and the trace can't drift
    reg = res.registry
    snap = reg.snapshot()
    print("\nobs summary (from the fit registry):")
    for name in ("train.neighbours", "train.prep", "train.compile",
                 "train.epoch", "train.epoch.eval", "train.ckpt"):
        s = snap["histograms"].get(name)
        if not s or not s["count"]:
            continue
        print(f"  {name:<18} n={s['count']:>3}  total={s['sum']:7.2f}s  "
              f"p50={s['p50'] * 1e3:8.1f}ms  p95={s['p95'] * 1e3:8.1f}ms")
    steady = reg.hist_summary("train.epoch")
    if steady["count"]:
        print(f"  steady-state epoch min={steady['min']:.3f}s "
              f"(compile {res.compile_seconds:.2f}s charged separately)")
    if args.trace:
        obs.write_trace(args.trace, reg)
        print(f"  trace → {args.trace} "
              f"({snap['spans']['retained']} spans; open in Perfetto)")


if __name__ == "__main__":
    main()
