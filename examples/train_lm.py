"""Train one of the assigned LM architectures (reduced config) end-to-end,
with the paper's lsh_softmax feature toggled on/off for comparison.

    PYTHONPATH=src python examples/train_lm.py --arch qwen3-0.6b --steps 40
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import base as CB
from repro.launch.train import synth_batch, train_loop
from repro.models import lm, steps


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--lsh-softmax", action="store_true")
    args = ap.parse_args()

    cfg = CB.reduced(CB.get(args.arch))
    print(f"arch={args.arch} family={cfg.family} (reduced) "
          f"lsh_softmax={args.lsh_softmax}")

    if not args.lsh_softmax:
        _, _, losses = train_loop(cfg, steps_n=args.steps, batch=8, seq=128)
        print(f"loss {losses[0]:.3f} → {losses[-1]:.3f}")
        return

    # paper-technique softmax: simLSH over output-embedding rows selects
    # the candidate vocabulary; signatures refresh every 10 steps
    from repro.models import lsh_softmax as LS
    cfg = dataclasses.replace(cfg, lsh_softmax=True, lsh_candidates=128)
    rng = np.random.default_rng(0)
    params = lm.init_params(cfg, jax.random.PRNGKey(0), model_shards=1)
    opt = steps.init_opt(cfg, params)
    step_fn = jax.jit(steps.make_train_step(cfg), donate_argnums=(0, 1))
    st = None
    for s in range(args.steps):
        b = synth_batch(rng, cfg, 8, 128)
        if s % 10 == 0:
            st = LS.refresh(lm.out_embedding(params, cfg),
                            jax.random.fold_in(jax.random.PRNGKey(7), s))
        b["cands"] = LS.candidates_for(
            st, b["labels"], jax.random.fold_in(jax.random.PRNGKey(9), s),
            n_cands=cfg.lsh_candidates)
        params, opt, aux = step_fn(params, opt, b)
        if s % 10 == 0 or s == args.steps - 1:
            print(f"step {s:4d} simLSH-softmax loss {float(aux['loss']):.3f}")


if __name__ == "__main__":
    main()
