"""Quickstart: train LSH-MF (the paper's model) on synthetic sparse data.

    PYTHONPATH=src python examples/quickstart.py

Builds a MovieLens-like sparse matrix, finds Top-K item neighbours with
simLSH (no GSM!), trains the nonlinear neighbourhood MF with the fused
Eq.(5) SGD, and prints test RMSE per epoch — compare `method="rand"` or
`method="gsm"` to reproduce the paper's Fig. 7 orderings.
"""
import dataclasses

import numpy as np

from repro.core.simlsh import SimLSHConfig
from repro.data import synthetic as syn
from repro.data.sparse import train_test_split
from repro.train.trainer import FitConfig, fit


def main():
    spec = dataclasses.replace(syn.MOVIELENS_LIKE, M=3000, N=500,
                               nnz=150_000)
    rows, cols, vals, _ = syn.generate(spec, seed=0)
    tr, te = train_test_split(np.random.default_rng(0), rows, cols, vals)

    cfg = FitConfig(
        F=32, K=16, epochs=8, batch=4096,
        method="simlsh",                      # try: gsm | rand | rp_cos | minhash | none
        lsh=SimLSHConfig(G=8, p=1, q=20, band_cap=16, psi_pow=2.0),
    )
    res = fit(tr, te, (spec.M, spec.N), cfg, log=print)
    print(f"neighbour search took {res.neighbour_seconds:.2f}s "
          f"(GSM would be O(N²) = {spec.N ** 2:,} similarities)")


if __name__ == "__main__":
    main()
