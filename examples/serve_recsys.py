"""Recommendation serving through `repro.serve`: train LSH-MF, build the
bucketed LSH index from the training signatures, then serve top-N requests
with candidate-only scoring — and fold an online update (paper Alg. 4) into
the running service without rebuilding the index.

    PYTHONPATH=src python examples/serve_recsys.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import online, simlsh, topk
from repro.core.simlsh import SimLSHConfig
from repro.data import synthetic as syn
from repro.data.sparse import from_coo, train_test_split
from repro.serve import RecsysService, ServeConfig, build_index
from repro.train.trainer import FitConfig, fit


def main():
    spec = dataclasses.replace(syn.MOVIELENS_LIKE, M=3000, N=500,
                               nnz=150_000)
    rows, cols, vals, _ = syn.generate(spec, seed=0)
    tr, te = train_test_split(np.random.default_rng(0), rows, cols, vals)
    lsh = SimLSHConfig(G=8, p=1, q=10)
    cfg = FitConfig(F=32, K=16, epochs=6, method="simlsh", lsh=lsh,
                    eval_every=6)
    res = fit(tr, te, (spec.M, spec.N), cfg, log=print)

    # ---- build the serving stack from the training byproducts ----
    sp = from_coo(*tr, (spec.M, spec.N))
    sigs = simlsh.pack_bits(res.S >= 0)          # re-sign the Alg.4 cache
    index = build_index(sigs, tail_cap=256)
    scfg = ServeConfig(topn=10, micro_batch=256, C=128, n_seeds=8, cap=8,
                       n_popular=32)
    svc = RecsysService(res.params, index, sp, scfg, JK=res.JK).warmup()

    # ---- serve a request stream ----
    rng = np.random.default_rng(1)
    for _ in range(20):
        svc.submit(rng.integers(0, spec.M, 256).astype(np.int32))
    svc.flush()
    st = svc.stats()
    print(f"candidate serving: {st['users']} users in {st['batches']} "
          f"batches → {st['qps']:,.0f} users/s (p50 {st['p50_ms']:.1f} ms)")

    # exactness check vs the dense full-scoring mode on one batch
    full = RecsysService(res.params, index, sp,
                         dataclasses.replace(scfg, mode="full")).warmup()
    probe = rng.integers(0, spec.M, 256).astype(np.int32)
    svc.take_results()
    svc.submit(probe); svc.flush()
    full.submit(probe); full.flush()
    got = svc.take_results()[0][2]
    want = full.take_results()[0][2]
    overlap = np.mean([len(set(got[u]) & set(want[u])) / got.shape[1]
                       for u in range(probe.shape[0])])
    print(f"recall@10 of candidate-only vs full scoring: {overlap:.3f}")
    print(f"full-scoring baseline: {full.stats()['qps']:,.0f} users/s")
    print("sample recommendations for user", int(probe[0]), ":", got[0])

    # ---- online ingestion: new users/items arrive (paper Alg. 4) ----
    st0 = online.OnlineState(params=res.params, S=res.S, JK=res.JK, sp=sp,
                             M=spec.M, N=spec.N, hash_key=res.hash_key)
    M2, N2 = spec.M + 100, spec.N + 20
    n_new = 2000
    nr = rng.integers(0, M2, n_new).astype(np.int32)
    nc = rng.integers(0, N2, n_new).astype(np.int32)
    pair = np.unique(nr.astype(np.int64) * N2 + nc)
    # ΔΩ must be disjoint from the already-observed pairs (from_coo wants
    # unique triples in the merged matrix)
    seen = np.asarray(sp.rows).astype(np.int64) * N2 + np.asarray(sp.cols)
    pair = np.setdiff1d(pair, seen, assume_unique=True)
    nr, nc = (pair // N2).astype(np.int32), (pair % N2).astype(np.int32)
    nv = rng.uniform(1, 5, nr.shape[0]).astype(np.float32)
    st1 = online.online_update(
        st0, jnp.asarray(nr), jnp.asarray(nc), jnp.asarray(nv), lsh,
        cfg.hp, jax.random.PRNGKey(7), M_new=M2, N_new=N2, K=cfg.K, epochs=2)
    svc.ingest_online_update(st1, N_old=spec.N)
    print(f"ingested ΔΩ: catalog {spec.N} → {svc.index.n_items} items "
          f"(tail occupancy {int(svc.index.tail_len)}/{svc.index.tail_cap})")

    svc.submit(rng.integers(0, M2, 256).astype(np.int32))
    svc.flush()
    items = svc.take_results()[-1][2]
    new_hits = int(((items >= spec.N) & (items != topk.SENTINEL)).sum())
    print(f"post-ingest serving OK; new items in recommendations: {new_hits}")


if __name__ == "__main__":
    main()
