"""Recommendation serving through `repro.serve`: train LSH-MF, build the
bucketed LSH index from the training signatures, then serve top-N requests
with candidate-only scoring — and fold an online update (paper Alg. 4) into
the running service without rebuilding the index.

    PYTHONPATH=src python examples/serve_recsys.py

With ``--online-loop`` the example instead runs the always-on supervisor
(ISSUE 10): a drifting rating stream in, recommendations out, training
micro-epochs interleaved with serving on one device budget.  Interrupt it
(ctrl-C) and run the same command again — the loop resumes from its
crash-safe checkpoint + WAL under ``--root``, exactly where it left off:

    PYTHONPATH=src python examples/serve_recsys.py --online-loop
    ^C
    PYTHONPATH=src python examples/serve_recsys.py --online-loop   # resumes
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import online, simlsh, topk
from repro.core.simlsh import SimLSHConfig
from repro.data import synthetic as syn
from repro.data.sparse import from_coo, train_test_split
from repro.serve import RecsysService, ServeConfig, build_index
from repro.train.trainer import FitConfig, fit


def main():
    spec = dataclasses.replace(syn.MOVIELENS_LIKE, M=3000, N=500,
                               nnz=150_000)
    rows, cols, vals, _ = syn.generate(spec, seed=0)
    tr, te = train_test_split(np.random.default_rng(0), rows, cols, vals)
    lsh = SimLSHConfig(G=8, p=1, q=10)
    cfg = FitConfig(F=32, K=16, epochs=6, method="simlsh", lsh=lsh,
                    eval_every=6)
    res = fit(tr, te, (spec.M, spec.N), cfg, log=print)

    # ---- build the serving stack from the training byproducts ----
    sp = from_coo(*tr, (spec.M, spec.N))
    sigs = simlsh.pack_bits(res.S >= 0)          # re-sign the Alg.4 cache
    index = build_index(sigs, tail_cap=256)
    scfg = ServeConfig(topn=10, micro_batch=256, C=128, n_seeds=8, cap=8,
                       n_popular=32)
    svc = RecsysService(res.params, index, sp, scfg, JK=res.JK).warmup()

    # ---- serve a request stream ----
    rng = np.random.default_rng(1)
    for _ in range(20):
        svc.submit(rng.integers(0, spec.M, 256).astype(np.int32))
    svc.flush()
    st = svc.stats()
    print(f"candidate serving: {st['users']} users in {st['batches']} "
          f"batches → {st['qps']:,.0f} users/s (p50 {st['p50_ms']:.1f} ms)")

    # exactness check vs the dense full-scoring mode on one batch
    full = RecsysService(res.params, index, sp,
                         dataclasses.replace(scfg, mode="full")).warmup()
    probe = rng.integers(0, spec.M, 256).astype(np.int32)
    svc.take_results()
    svc.submit(probe); svc.flush()
    full.submit(probe); full.flush()
    got = svc.take_results()[0][2]
    want = full.take_results()[0][2]
    overlap = np.mean([len(set(got[u]) & set(want[u])) / got.shape[1]
                       for u in range(probe.shape[0])])
    print(f"recall@10 of candidate-only vs full scoring: {overlap:.3f}")
    print(f"full-scoring baseline: {full.stats()['qps']:,.0f} users/s")
    print("sample recommendations for user", int(probe[0]), ":", got[0])

    # ---- online ingestion: new users/items arrive (paper Alg. 4) ----
    st0 = online.OnlineState(params=res.params, S=res.S, JK=res.JK, sp=sp,
                             M=spec.M, N=spec.N, hash_key=res.hash_key)
    M2, N2 = spec.M + 100, spec.N + 20
    n_new = 2000
    nr = rng.integers(0, M2, n_new).astype(np.int32)
    nc = rng.integers(0, N2, n_new).astype(np.int32)
    pair = np.unique(nr.astype(np.int64) * N2 + nc)
    # ΔΩ must be disjoint from the already-observed pairs (from_coo wants
    # unique triples in the merged matrix)
    seen = np.asarray(sp.rows).astype(np.int64) * N2 + np.asarray(sp.cols)
    pair = np.setdiff1d(pair, seen, assume_unique=True)
    nr, nc = (pair // N2).astype(np.int32), (pair % N2).astype(np.int32)
    nv = rng.uniform(1, 5, nr.shape[0]).astype(np.float32)
    st1 = online.online_update(
        st0, jnp.asarray(nr), jnp.asarray(nc), jnp.asarray(nv), lsh,
        cfg.hp, jax.random.PRNGKey(7), M_new=M2, N_new=N2, K=cfg.K, epochs=2)
    svc.ingest_online_update(st1, N_old=spec.N)
    print(f"ingested ΔΩ: catalog {spec.N} → {svc.index.n_items} items "
          f"(tail occupancy {int(svc.index.tail_len)}/{svc.index.tail_cap})")

    svc.submit(rng.integers(0, M2, 256).astype(np.int32))
    svc.flush()
    items = svc.take_results()[-1][2]
    new_hits = int(((items >= spec.N) & (items != topk.SENTINEL)).sum())
    print(f"post-ingest serving OK; new items in recommendations: {new_hits}")


def _disjoint_delta(st, M_new, N_new, rng, n=400):
    """ΔΩ triples disjoint from the already-observed pairs (the merge
    wants unique triples)."""
    nr = rng.integers(0, M_new, n).astype(np.int32)
    nc = rng.integers(0, N_new, n).astype(np.int32)
    pair = np.unique(nr.astype(np.int64) * N_new + nc)
    seen = (np.asarray(st.sp.rows).astype(np.int64) * N_new
            + np.asarray(st.sp.cols))
    pair = np.setdiff1d(pair, seen, assume_unique=True)
    return ((pair // N_new).astype(np.int32),
            (pair % N_new).astype(np.int32),
            rng.uniform(1, 5, pair.shape[0]).astype(np.float32))


def online_loop_main(args):
    """The always-on loop: train once, then slice serve/train/publish
    forever-ish, crash-safe under ``args.root``.  The drift schedule is
    keyed on the loop's own slice counter, so a restart continues the
    same stream the interrupted run was on."""
    from repro.loop import LoopConfig, OnlineLoop

    spec = dataclasses.replace(syn.MOVIELENS_LIKE, M=1500, N=300,
                               nnz=60_000)
    rows, cols, vals, _ = syn.generate(spec, seed=0)
    tr, te = train_test_split(np.random.default_rng(0), rows, cols, vals)
    lsh = SimLSHConfig(G=8, p=1, q=10)
    cfg = FitConfig(F=32, K=8, epochs=3, method="simlsh", lsh=lsh,
                    eval_every=3)
    print(f"training the base model ({spec.M}×{spec.N}, "
          f"{len(tr[0]):,} ratings) …")
    res = fit(tr, te, (spec.M, spec.N), cfg, log=lambda *a, **k: None)
    sp = from_coo(*tr, (spec.M, spec.N))
    base = online.OnlineState(params=res.params, S=res.S, JK=res.JK, sp=sp,
                              M=spec.M, N=spec.N, hash_key=res.hash_key)
    scfg = ServeConfig(topn=10, micro_batch=128, C=128, n_seeds=8, cap=8,
                       n_popular=32)
    lcfg = LoopConfig(serve_flushes=2, micro_epochs=1, micro_batch=2048,
                      deltas_per_slice=2, max_lag=2, ckpt_every=2,
                      drift_every=4, tail_cap=128, seed=0)
    hold = tuple(np.asarray(a)[:500] for a in te)

    # resume if the root holds a previous run's checkpoint + WAL; the
    # deterministically re-trained `base` seeds a first run (or one
    # interrupted before its first checkpoint)
    loop = OnlineLoop.recover(args.root, lsh, cfg.hp, scfg, K=cfg.K,
                              epochs=2, batch=4096, cfg=lcfg,
                              base_state=base, holdout=hold)
    if loop.slice_count:
        print(f"resumed from {args.root}: slice {loop.slice_count}, "
              f"WAL seq {loop.updater.seq}, catalog {loop.state.N} items")
    else:
        print(f"fresh run (state under {args.root})")

    rng = np.random.default_rng(99)         # request traffic (not resumed)
    try:
        for _ in range(args.slices):
            s = loop.slice_count
            loop.svc.submit(rng.integers(0, spec.M, 128).astype(np.int32))
            if s % 2 == 0:                  # the stream grows the catalog
                drng = np.random.default_rng(1000 + s)   # keyed on slice
                M2, N2 = loop.state.M + 8, loop.state.N + 4
                nr, nc, nv = _disjoint_delta(loop.state, M2, N2, drng)
                loop.offer_delta(nr, nc, nv,
                                 np.asarray(jax.random.PRNGKey(70 + s)),
                                 M_new=M2, N_new=N2)
            loop.run_slice()
            st = loop.svc.stats()
            print(f"slice {s}: {loop.state.M}×{loop.state.N} | "
                  f"{st['users']} users served | staleness "
                  f"{loop.staleness_s():.2f}s | "
                  f"publishes {int(loop.obs.counter('loop.publishes'))} | "
                  f"drift rmse "
                  f"{loop.obs.gauge('loop.drift_rmse', float('nan')):.3f}")
            res_batch = loop.svc.take_results()
            if res_batch:
                u, _, items = res_batch[-1][:3]
                print(f"  user {int(u[0])} → {items[0]}")
    except KeyboardInterrupt:
        print(f"\ninterrupted at slice {loop.slice_count} — run the same "
              f"command again to resume (checkpoint + WAL in {args.root})")
        return
    print(f"done: {args.slices} slices, catalog "
          f"{spec.N} → {loop.state.N} items; rerun to continue, or rm -r "
          f"{args.root} to start over")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--online-loop", action="store_true",
                    help="run the crash-safe always-on loop demo instead")
    ap.add_argument("--root", default="/tmp/repro_online_loop",
                    help="persistence root for the loop's checkpoint + WAL")
    ap.add_argument("--slices", type=int, default=10,
                    help="slices to run this invocation (loop mode)")
    a = ap.parse_args()
    online_loop_main(a) if a.online_loop else main()
