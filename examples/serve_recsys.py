"""Batched recommendation serving: train LSH-MF, then serve top-N
recommendations for request batches (the paper's online-platform setting).

    PYTHONPATH=src python examples/serve_recsys.py
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.simlsh import SimLSHConfig
from repro.data import synthetic as syn
from repro.data.sparse import train_test_split
from repro.train.trainer import FitConfig, fit


@jax.jit
def recommend(params, user_ids, topn=10):
    """Scores = full Eq.(1) baseline+latent terms for every item."""
    scores = (params.mu + params.b[user_ids][:, None] + params.bh[None, :]
              + params.U[user_ids] @ params.V.T)
    return jax.lax.top_k(scores, topn)


def main():
    spec = dataclasses.replace(syn.MOVIELENS_LIKE, M=3000, N=500,
                               nnz=150_000)
    rows, cols, vals, _ = syn.generate(spec, seed=0)
    tr, te = train_test_split(np.random.default_rng(0), rows, cols, vals)
    cfg = FitConfig(F=32, K=16, epochs=6, method="simlsh",
                    lsh=SimLSHConfig(G=8, p=1, q=10), eval_every=6)
    res = fit(tr, te, (spec.M, spec.N), cfg, log=print)

    rng = np.random.default_rng(1)
    reqs = [jnp.asarray(rng.integers(0, spec.M, 256), jnp.int32)
            for _ in range(20)]
    # warmup + timed serving loop
    recommend(res.params, reqs[0])
    t0 = time.time()
    for r in reqs:
        scores, items = recommend(res.params, r)
    jax.block_until_ready(items)
    dt = time.time() - t0
    qps = len(reqs) * 256 / dt
    print(f"served {len(reqs)} batches × 256 users in {dt*1e3:.1f} ms "
          f"→ {qps:,.0f} users/s")
    print("sample recommendations for user", int(reqs[-1][0]), ":",
          np.asarray(items[0]))


if __name__ == "__main__":
    main()
