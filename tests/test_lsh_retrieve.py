"""`lsh_retrieve` kernel vs jnp oracle + walk-path building blocks.

Interpret-mode parity sweeps across cap/C/seed-count × empty/nonempty
tail × exclusion sets, plus property tests that the emitted candidates
are unique, come only from the probed bucket windows (∪ tail extras),
and never contain excluded ids.  The walk path that feeds the kernel —
`window_descriptors` (bitonic interval merge), `enumerate_windows`
(budgeted scatter-fill expansion), `tail_hits` (static prefix scan) and
`_select_topn_masked` (duplicate-masked top-n) — each get a brute-force
numpy oracle, and `recommend_walked` is checked end to end against
dedup-then-exact-score.  The candidate-routing heuristic rides along.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import simlsh, topk
from repro.core.model import init_from_data, pack_serve_planes
from repro.core.simlsh import SimLSHConfig
from repro.data.sparse import from_coo
from repro.kernels.candidate_score.kernel import NEG
from repro.kernels.lsh_retrieve.kernel import lsh_retrieve_topc
from repro.kernels.lsh_retrieve.ops import retrieve_candidates
from repro.kernels.lsh_retrieve.ref import lsh_retrieve_topc_ref
from repro.serve import (RecsysService, ServeConfig, build_index,
                         enumerate_windows, full_topn, insert,
                         padded_flat_ids, recommend_walked, seed_items,
                         tail_hits, walk_candidates, window_descriptors,
                         window_slices)
from repro.serve.service import _select_topn_masked

SENTINEL = topk.SENTINEL


def _sparse(M=200, N=60, seed=0):
    rng = np.random.default_rng(seed)
    rows = np.repeat(np.arange(M), 6).astype(np.int32)
    cols = rng.integers(0, N, M * 6).astype(np.int32)
    vals = rng.integers(1, 6, M * 6).astype(np.float32)
    keys = rows.astype(np.int64) * N + cols
    _, uniq = np.unique(keys, return_index=True)
    return from_coo(rows[uniq], cols[uniq], vals[uniq], (M, N))


@pytest.fixture(scope="module")
def indexed():
    sp = _sparse()
    cfg = SimLSHConfig(G=8, p=2, q=8)
    sigs = simlsh.encode(sp, cfg, jax.random.PRNGKey(0))
    return sp, cfg, sigs, build_index(sigs, tail_cap=32)


@pytest.fixture(scope="module")
def indexed_tail(indexed):
    """Same catalog with five cloned items resident in the insert tail."""
    sp, cfg, sigs, index = indexed
    src = np.asarray([0, 3, 7, 11, 19])
    idx2 = insert(index, sigs[:, src],
                  jnp.asarray(sp.N + np.arange(5), jnp.int32))
    return sp, cfg, sigs, idx2


def _kernel_inputs(sp, index, *, B, n_seeds, cap, tail):
    users = jnp.arange(B, dtype=jnp.int32)
    seeds = seed_items(sp, users, n_seeds=n_seeds, window=32)
    starts, lens = window_slices(index, seeds, cap=cap)
    extra = (tail_hits(index, seeds) if tail
             else jnp.full((B, 1), SENTINEL, jnp.int32))
    return starts, lens, extra, padded_flat_ids(index, cap=cap)


def _pool_sets(starts, lens, extra, ids_flat):
    """Brute-force per-user candidate universe: every id inside the valid
    window prefixes, union the valid extras."""
    st, ln = np.asarray(starts), np.asarray(lens)
    ex, flat = np.asarray(extra), np.asarray(ids_flat)
    out = []
    for u in range(st.shape[0]):
        s = set()
        for i in range(st.shape[1]):
            s |= set(flat[st[u, i]:st[u, i] + ln[u, i]].tolist())
        s |= {int(x) for x in ex[u] if x != SENTINEL and x >= 0}
        out.append(s - {int(SENTINEL)})
    return out


# ------------------------------------------------------- kernel parity

@pytest.mark.parametrize("n_seeds,cap,C", [
    (4, 8, 32), (4, 8, 16), (8, 4, 64), (2, 16, 24), (5, 8, 48)])
@pytest.mark.parametrize("tail", [False, True])
@pytest.mark.parametrize("excl", [(), (1, 9), (SENTINEL,)])
def test_kernel_matches_ref_sweep(indexed, indexed_tail, n_seeds, cap, C,
                                  tail, excl):
    """Interpret-mode kernel ≡ jnp oracle, bit for bit, across descriptor
    geometries, tail occupancy, and exclusion sets (incl. the inert
    SENTINEL-only one the wrapper passes when there is no shortlist)."""
    sp, cfg, sigs, index = indexed_tail if tail else indexed
    starts, lens, extra, ids_flat = _kernel_inputs(
        sp, index, B=12, n_seeds=n_seeds, cap=cap, tail=tail)
    exclude = jnp.asarray(list(excl) or [SENTINEL], jnp.int32)
    got = lsh_retrieve_topc(starts, lens, extra, ids_flat, exclude,
                            C=C, cap=cap)
    want = lsh_retrieve_topc_ref(starts, lens, extra, ids_flat, exclude,
                                 C=C, cap=cap)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("tail", [False, True])
def test_kernel_property_unique_subset_excluded(indexed, indexed_tail, tail):
    """Emitted ids are duplicate-free, drawn only from the probed windows
    ∪ tail extras, never excluded, SENTINEL-padded after an exhausted
    pool — and when the unique pool fits in C, it is covered exactly."""
    sp, cfg, sigs, index = indexed_tail if tail else indexed
    starts, lens, extra, ids_flat = _kernel_inputs(
        sp, index, B=16, n_seeds=4, cap=8, tail=tail)
    exclude = jnp.asarray([2, 5, 41], jnp.int32)
    C = 64
    got = np.asarray(lsh_retrieve_topc(starts, lens, extra, ids_flat,
                                       exclude, C=C, cap=8))
    pools = _pool_sets(starts, lens, extra, ids_flat)
    for u in range(16):
        ids = got[u][got[u] != SENTINEL]
        assert len(ids) == len(set(ids)), "duplicate candidate"
        want = pools[u] - {2, 5, 41}
        assert set(ids) <= want
        assert len(ids) == min(C, len(want)), "unique pool not covered"
        k = len(ids)
        assert np.all(got[u][k:] == SENTINEL), "padding must be trailing"


@pytest.mark.parametrize("tail", [False, True])
def test_retrieve_candidates_impls_agree_and_reserve_popular(
        indexed, indexed_tail, tail):
    """`ops.retrieve_candidates` pallas(interpret) ≡ ref, with the
    popularity shortlist in reserved trailing slots and excluded from
    the walked core (in-kernel, not via a second dedup)."""
    sp, cfg, sigs, index = indexed_tail if tail else indexed
    users = jnp.arange(12, dtype=jnp.int32)
    popular = jnp.asarray([2, 11, 17], jnp.int32)
    kw = dict(n_seeds=4, cap=8, C=48, popular=popular, window=32,
              tail_scan=tail)
    a = np.asarray(retrieve_candidates(index, sp, users, impl="pallas", **kw))
    b = np.asarray(retrieve_candidates(index, sp, users, impl="ref", **kw))
    np.testing.assert_array_equal(a, b)
    assert a.shape == (12, 48)
    np.testing.assert_array_equal(a[:, 45:],
                                  np.broadcast_to([2, 11, 17], (12, 3)))
    core = a[:, :45]
    assert not np.isin(core, [2, 11, 17]).any(), "shortlist id in core"
    for u in range(12):
        v = core[u][core[u] != SENTINEL]
        assert len(v) == len(set(v))


# ------------------------------------------------- walk-path components

@pytest.mark.parametrize("n_seeds", [3, 4, 5, 8])   # 3, 5 hit the pad path
def test_window_descriptors_match_bruteforce(indexed, n_seeds):
    """Merged intervals cover exactly the union of per-seed bucket
    windows, and are disjoint within each band (counts sum to the union
    size).  Non-power-of-two seed counts exercise the bitonic pad."""
    sp, cfg, sigs, index = indexed
    cap, B = 8, 16
    users = jnp.arange(B, dtype=jnp.int32)
    seeds = seed_items(sp, users, n_seeds=n_seeds, window=32)
    starts, counts = window_descriptors(index, seeds, cap=cap)
    st, cnt = np.asarray(starts), np.asarray(counts)
    q, Nn = index.q, index.n_base
    slot_of = np.asarray(index.slot_of).reshape(q, -1)
    lo_a = np.asarray(index.bucket_lo).reshape(q, -1)
    hi_a = np.asarray(index.bucket_hi).reshape(q, -1)
    sd = np.asarray(seeds)
    for u in range(B):
        for g in range(q):
            want = set()
            for s in sd[u]:
                if s == SENTINEL or s < 0 or s >= Nn:
                    continue
                slot = int(slot_of[g, s])
                lo, hi = int(lo_a[g, slot]), int(hi_a[g, slot])
                w0 = int(np.clip(slot - cap // 2, lo, max(hi - cap, lo)))
                w1 = min(w0 + cap, hi)
                want |= set(range(g * Nn + w0, g * Nn + w1))
            got, total = set(), 0
            for i in range(g * n_seeds, (g + 1) * n_seeds):
                got |= set(range(st[u, i], st[u, i] + cnt[u, i]))
                total += cnt[u, i]
            assert got == want, f"user {u} band {g}: interval union wrong"
            assert total == len(want), "overlapping intervals in a band"


def test_enumerate_windows_budget_and_truncation():
    starts = jnp.asarray([[5, 100, 40], [7, 0, 0]], jnp.int32)
    counts = jnp.asarray([[3, 4, 2], [2, 0, 0]], jnp.int32)
    pos = np.asarray(enumerate_windows(starts, counts, budget=6))
    # row 0 totals 9 > 6: truncated in interval order, mid-interval
    np.testing.assert_array_equal(pos[0], [5, 6, 7, 100, 101, 102])
    # row 1: zero-count intervals skipped, −1 past the total
    np.testing.assert_array_equal(pos[1], [7, 8, -1, -1, -1, -1])
    # generous budget: exact expansion, nothing dropped
    pos = np.asarray(enumerate_windows(starts, counts, budget=12))
    np.testing.assert_array_equal(
        pos[0], [5, 6, 7, 100, 101, 102, 103, 40, 41, -1, -1, -1])


def test_tail_hits_static_prefix_slice(indexed_tail):
    """k-restricted scan sees every resident hit (the tail fills strictly
    in insertion order, so the prefix is the whole occupancy) and shrinks
    the output width; the full buffer past `tail_fill` is all misses."""
    sp, cfg, sigs, index = indexed_tail
    users = jnp.arange(24, dtype=jnp.int32)
    seeds = seed_items(sp, users, n_seeds=4, window=32)
    full = np.asarray(tail_hits(index, seeds))            # k=0 → whole buffer
    part = np.asarray(tail_hits(index, seeds, k=16))
    assert full.shape == (24, index.tail_cap) and part.shape == (24, 16)
    assert np.all(full[:, index.tail_fill:] == SENTINEL)
    for u in range(24):
        assert (set(part[u][part[u] != SENTINEL])
                == set(full[u][full[u] != SENTINEL]))
    # the clones collide with their sources: a user seeded on item 0
    # must see clone id N in its tail hits
    hit_rows = [u for u in range(24) if 0 in set(np.asarray(seeds)[u])]
    assert hit_rows, "fixture lost its seeded-on-item-0 users"
    for u in hit_rows:
        assert sp.N in set(part[u]), "clone unreachable through the tail"


def test_select_topn_masked_matches_dedup_oracle():
    """Duplicate-masked argmax selection ≡ numpy dedup-then-sort, across
    random pools with heavy duplication, SENTINEL slots, and rows holding
    fewer distinct ids than topn (exhaustion → SENTINEL fill)."""
    rng = np.random.default_rng(3)
    for trial in range(20):
        B = int(rng.integers(1, 6))
        W = int(rng.integers(4, 40))
        topn = int(rng.integers(1, 8))
        cand = rng.integers(0, 12, (B, W)).astype(np.int32)   # dense dups
        cand[rng.random((B, W)) < 0.25] = SENTINEL
        score_of = rng.permutation(12).astype(np.float32)     # distinct
        s = np.where(cand != SENTINEL, score_of[np.clip(cand, 0, 11)],
                     float(NEG)).astype(np.float32)
        gs, gi = _select_topn_masked(jnp.asarray(s), jnp.asarray(cand),
                                     topn=topn)
        gs, gi = np.asarray(gs), np.asarray(gi)
        for u in range(B):
            uniq = sorted({int(c) for c in cand[u] if c != SENTINEL},
                          key=lambda c: -score_of[c])[:topn]
            np.testing.assert_array_equal(gi[u, :len(uniq)], uniq)
            np.testing.assert_array_equal(gi[u, len(uniq):], SENTINEL)
            np.testing.assert_allclose(gs[u, :len(uniq)],
                                       score_of[uniq], rtol=1e-6)


@pytest.mark.parametrize("tail", [False, True])
def test_recommend_walked_matches_dedup_then_score(indexed, indexed_tail,
                                                   tail):
    """The fused walk path (duplicates deferred to selection) returns the
    same top-n id set and scores as dedup-first + exact scoring."""
    sp, cfg, sigs, index = indexed_tail if tail else indexed
    # params sized past the tail clones (ids N..N+4) so they score with
    # their own rows rather than the clipped last base row
    params = init_from_data(jax.random.PRNGKey(1), _sparse(N=sp.N + 5),
                            16, 8)
    planes = pack_serve_planes(params)
    users = jnp.arange(16, dtype=jnp.int32)
    popular = jnp.asarray([2, 11, 17, 40], jnp.int32)
    tail_k = 16 if tail else 0
    topn = 5
    gs, gi = recommend_walked(planes, index, sp, users, popular,
                              n_seeds=4, cap=8, budget=128, window=32,
                              tail_k=tail_k, topn=topn, tile_b=8)
    gs, gi = np.asarray(gs), np.asarray(gi)
    ids, seeds = walk_candidates(index, sp, users, n_seeds=4, cap=8,
                                 budget=128, window=32)
    pool = np.asarray(ids)
    if tail_k:
        pool = np.concatenate(
            [pool, np.asarray(tail_hits(index, seeds, k=tail_k))], axis=1)
    mu, b, bh = (np.asarray(params.mu), np.asarray(params.b),
                 np.asarray(params.bh))
    U, V = np.asarray(params.U), np.asarray(params.V)
    for u in range(16):
        cand = sorted(({int(c) for c in pool[u] if c != SENTINEL}
                       | {2, 11, 17, 40}))
        exact = (mu + b[u] + bh[cand] + V[cand] @ U[u])
        order = np.argsort(-exact)[:topn]
        want_ids = [cand[j] for j in order]
        assert set(gi[u]) - {SENTINEL} <= set(cand)
        np.testing.assert_array_equal(gi[u], want_ids)
        np.testing.assert_allclose(gs[u], exact[order], rtol=1e-4,
                                   atol=1e-4)


# ------------------------------------------------------------- routing

def test_route_decision_and_full_fallback(indexed):
    """Small-catalog routing: auto threshold is 48·C, the verdict is
    reported even when disabled, and a routed service serves exact
    full-scan results."""
    sp, cfg, sigs, index = indexed
    params = init_from_data(jax.random.PRNGKey(1), sp, 16, 8)
    base = ServeConfig(topn=5, micro_batch=8, C=48, n_seeds=4, cap=8,
                       n_popular=0)

    off = RecsysService(params, index, sp, base)
    rd = off.route_decision()
    assert not rd["enabled"] and rd["threshold"] == 48 * 48
    assert rd["decision"] == "full", "verdict must report even when off"

    auto = RecsysService(params, index, sp,
                         dataclasses.replace(base, route_full_below=-1))
    rd = auto.route_decision()
    assert rd["enabled"] and rd["n_items"] == sp.N
    assert rd["decision"] == "full"
    users = np.arange(8, dtype=np.int32)
    auto.submit(users); auto.flush()
    _, s_r, i_r = auto.take_results()[0]
    s_f, i_f = full_topn(params, jnp.asarray(users), topn=5)
    np.testing.assert_array_equal(i_r, np.asarray(i_f))
    np.testing.assert_allclose(s_r, np.asarray(s_f), rtol=1e-5, atol=1e-5)
    assert auto.stats()["route"]["decision"] == "full"

    tight = RecsysService(params, index, sp,
                          dataclasses.replace(base, route_full_below=10))
    assert tight.route_decision()["decision"] == "candidate"
