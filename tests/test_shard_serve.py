"""Parity/property suite for the sharded serving tier (ISSUE 9).

Three families, all single-process (the 4-device end-to-end parity run
lives in tests/helpers/multidev_checks.py::check_sharded_serve):

* **merge_topn / tree reduce** — the per-user top-N merge must equal the
  exact top-N of the concatenated shard partials under random splits,
  ties, SENTINEL padding and users with fewer than N candidates, and the
  XOR-butterfly fold must converge every participant to that same answer
  (numpy `lexsort` oracle; hypothesis path when installed, shimmed by
  conftest otherwise).

* **sharded index invariants** — shard-local bucket membership
  round-trips to the single-device `build_index` buckets after the
  global→local remap, per-shard CSR invariants hold
  (`validate_sharded_index`), padding slots are inert.

* **shard-local walk** — owner-computes signature exchange sums to the
  true seed signatures, and the union of per-shard walks at
  truncation-free settings equals the single-device `walk_candidates`
  retrieval set.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import simlsh
from repro.core.topk import SENTINEL
from repro.data.sparse import from_coo
from repro.kernels.candidate_score.kernel import NEG
from repro.launch.mesh import serve_shard_count
from repro.resil import validate_index, validate_sharded_index
from repro.serve import (ServeConfig, build_index, build_sharded_index,
                         merge_topn, shard_bounds, shard_local_view,
                         shard_seed_sigs, shard_walk_local, signatures_of,
                         translate_local_ids, walk_candidates)
from repro.serve.index import _EMPTY_SIG
from repro.serve.retrieve import seed_items

TOPN = 8


# ---------------------------------------------------------------------------
# oracle + partial generators
# ---------------------------------------------------------------------------

def oracle_topn(scores: np.ndarray, ids: np.ndarray, topn: int):
    """Exact top-N of one user's candidate list under the serving total
    order (score desc, id asc); rows with < topn real entries padded
    with (NEG, SENTINEL) exactly like `_select_topn_masked`."""
    real = ids != SENTINEL
    s, i = scores[real], ids[real]
    order = np.lexsort((i, -s))[:topn]
    out_s = np.full(topn, NEG, np.float32)
    out_i = np.full(topn, SENTINEL, np.int32)
    out_s[:order.size] = s[order]
    out_i[:order.size] = i[order]
    return out_s, out_i


def random_partials(rng, *, B, D, topn, n_ids=200, tie_prob=0.0,
                    empty_prob=0.0):
    """D disjoint-id shard partials [B, topn] — the butterfly invariant
    (each candidate counted once) holds by construction, so every id
    appears in at most one shard."""
    sa, ia = [], []
    for _ in range(D):
        sa.append(np.full((B, topn), NEG, np.float32))
        ia.append(np.full((B, topn), SENTINEL, np.int32))
    for b in range(B):
        ids = rng.choice(n_ids, size=min(n_ids, D * topn), replace=False)
        scores = rng.normal(size=ids.size).astype(np.float32)
        if tie_prob:
            tied = rng.random(ids.size) < tie_prob
            scores[tied] = np.float32(0.5)
        take = rng.integers(0, topn + 1, D) if empty_prob else \
            np.full(D, topn)
        if empty_prob:
            take[rng.random(D) < empty_prob] = 0
        pos = 0
        for d in range(D):
            k = min(int(take[d]), ids.size - pos)
            if k <= 0:
                continue
            s, i = oracle_topn(scores[pos:pos + k], ids[pos:pos + k], topn)
            sa[d][b], ia[d][b] = s, i
            pos += k
    return sa, ia


def merged_oracle(sa, ia, topn):
    B = sa[0].shape[0]
    s = np.concatenate(sa, axis=1)
    i = np.concatenate(ia, axis=1)
    outs = [oracle_topn(s[b], i[b], topn) for b in range(B)]
    return (np.stack([o[0] for o in outs]), np.stack([o[1] for o in outs]))


def assert_topn_equal(got_s, got_i, ref_s, ref_i):
    got_s, got_i = np.asarray(got_s), np.asarray(got_i)
    # ids must match exactly (the order is total: score desc, id asc)
    np.testing.assert_array_equal(got_i, ref_i)
    real = ref_i != SENTINEL
    np.testing.assert_allclose(got_s[real], ref_s[real], rtol=1e-6)
    assert np.all(got_s[~real] <= NEG)


# ---------------------------------------------------------------------------
# merge_topn: oracle equivalence, ties, padding, algebra
# ---------------------------------------------------------------------------

class TestMergeTopn:
    def test_two_shards_match_oracle(self):
        rng = np.random.default_rng(0)
        sa, ia = random_partials(rng, B=16, D=2, topn=TOPN)
        ms, mi = merge_topn(jnp.asarray(sa[0]), jnp.asarray(ia[0]),
                            jnp.asarray(sa[1]), jnp.asarray(ia[1]),
                            topn=TOPN)
        ref_s, ref_i = merged_oracle(sa, ia, TOPN)
        assert_topn_equal(ms, mi, ref_s, ref_i)

    def test_ties_break_by_lower_id(self):
        sa = jnp.asarray([[3.0, 1.0]]); ia = jnp.asarray([[7, 9]], jnp.int32)
        sb = jnp.asarray([[3.0, 3.0]]); ib = jnp.asarray([[2, 5]], jnp.int32)
        ms, mi = merge_topn(sa, ia, sb, ib, topn=3)
        np.testing.assert_array_equal(np.asarray(mi), [[2, 5, 7]])
        np.testing.assert_allclose(np.asarray(ms), [[3.0, 3.0, 3.0]])

    def test_all_tied_scores_sort_ids(self):
        rng = np.random.default_rng(1)
        sa, ia = random_partials(rng, B=8, D=2, topn=TOPN, tie_prob=1.0)
        ms, mi = merge_topn(jnp.asarray(sa[0]), jnp.asarray(ia[0]),
                            jnp.asarray(sa[1]), jnp.asarray(ia[1]),
                            topn=TOPN)
        ref_s, ref_i = merged_oracle(sa, ia, TOPN)
        assert_topn_equal(ms, mi, ref_s, ref_i)

    def test_sentinel_padded_shard_is_identity(self):
        rng = np.random.default_rng(2)
        sa, ia = random_partials(rng, B=8, D=1, topn=TOPN)
        pad_s = jnp.full((8, TOPN), NEG, jnp.float32)
        pad_i = jnp.full((8, TOPN), SENTINEL, jnp.int32)
        ms, mi = merge_topn(jnp.asarray(sa[0]), jnp.asarray(ia[0]),
                            pad_s, pad_i, topn=TOPN)
        assert_topn_equal(ms, mi, sa[0], ia[0])

    def test_fewer_than_topn_candidates_pad(self):
        sa = jnp.asarray([[4.0] + [NEG] * (TOPN - 1)])
        ia = jnp.asarray([[3] + [SENTINEL] * (TOPN - 1)], jnp.int32)
        sb = jnp.asarray([[2.0] + [NEG] * (TOPN - 1)])
        ib = jnp.asarray([[11] + [SENTINEL] * (TOPN - 1)], jnp.int32)
        ms, mi = merge_topn(sa, ia, sb, ib, topn=TOPN)
        np.testing.assert_array_equal(np.asarray(mi)[0, :2], [3, 11])
        assert np.all(np.asarray(mi)[0, 2:] == SENTINEL)
        assert np.all(np.asarray(ms)[0, 2:] <= NEG)

    def test_both_shards_empty(self):
        pad_s = jnp.full((4, TOPN), NEG, jnp.float32)
        pad_i = jnp.full((4, TOPN), SENTINEL, jnp.int32)
        ms, mi = merge_topn(pad_s, pad_i, pad_s, pad_i, topn=TOPN)
        assert np.all(np.asarray(mi) == SENTINEL)
        assert np.all(np.asarray(ms) <= NEG)

    def test_commutative(self):
        rng = np.random.default_rng(3)
        sa, ia = random_partials(rng, B=8, D=2, topn=TOPN, tie_prob=0.3)
        ab = merge_topn(jnp.asarray(sa[0]), jnp.asarray(ia[0]),
                        jnp.asarray(sa[1]), jnp.asarray(ia[1]), topn=TOPN)
        ba = merge_topn(jnp.asarray(sa[1]), jnp.asarray(ia[1]),
                        jnp.asarray(sa[0]), jnp.asarray(ia[0]), topn=TOPN)
        np.testing.assert_array_equal(np.asarray(ab[1]), np.asarray(ba[1]))
        np.testing.assert_allclose(np.asarray(ab[0]), np.asarray(ba[0]))

    def test_associative(self):
        rng = np.random.default_rng(4)
        sa, ia = random_partials(rng, B=8, D=3, topn=TOPN, tie_prob=0.2)
        j = [(jnp.asarray(s), jnp.asarray(i)) for s, i in zip(sa, ia)]
        left = merge_topn(*merge_topn(*j[0], *j[1], topn=TOPN), *j[2],
                          topn=TOPN)
        right = merge_topn(*j[0], *merge_topn(*j[1], *j[2], topn=TOPN),
                           topn=TOPN)
        np.testing.assert_array_equal(np.asarray(left[1]),
                                      np.asarray(right[1]))
        np.testing.assert_allclose(np.asarray(left[0]), np.asarray(right[0]))

    @pytest.mark.parametrize("D", [2, 4, 8])
    def test_butterfly_fold_matches_oracle(self, D):
        """The serving tree reduce: after log2(D) XOR-partner rounds every
        participant holds the exact top-N of all D partials."""
        rng = np.random.default_rng(D)
        sa, ia = random_partials(rng, B=8, D=D, topn=TOPN, tie_prob=0.2,
                                 empty_prob=0.2)
        parts = [(jnp.asarray(s), jnp.asarray(i)) for s, i in zip(sa, ia)]
        k = 1
        while k < D:
            parts = [merge_topn(*parts[d], *parts[d ^ k], topn=TOPN)
                     for d in range(D)]
            k *= 2
        ref_s, ref_i = merged_oracle(sa, ia, TOPN)
        for d in range(D):
            assert_topn_equal(parts[d][0], parts[d][1], ref_s, ref_i)

    @settings(max_examples=25)
    @given(st.integers(0, 10_000), st.integers(2, 6), st.integers(1, 12))
    def test_property_random_splits(self, seed, D, topn):
        rng = np.random.default_rng(seed)
        sa, ia = random_partials(rng, B=4, D=D, topn=topn, tie_prob=0.3,
                                 empty_prob=0.3)
        acc = (jnp.asarray(sa[0]), jnp.asarray(ia[0]))
        for d in range(1, D):
            acc = merge_topn(*acc, jnp.asarray(sa[d]), jnp.asarray(ia[d]),
                             topn=topn)
        ref_s, ref_i = merged_oracle(sa, ia, topn)
        assert_topn_equal(acc[0], acc[1], ref_s, ref_i)


# ---------------------------------------------------------------------------
# sharded index: bounds, CSR invariants, bucket round-trip
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_catalog():
    rng = np.random.default_rng(0)
    M, N, deg = 200, 300, 8
    rows = np.repeat(np.arange(M), deg)
    cols = rng.integers(0, N, M * deg)
    vals = rng.uniform(1, 5, M * deg).astype(np.float32)
    order = np.lexsort((cols, rows))
    sp = from_coo(rows[order], cols[order], vals[order], (M, N))
    cfg = simlsh.SimLSHConfig(G=4, p=2, q=4)
    sigs = simlsh.encode(sp, cfg, jax.random.PRNGKey(0))
    counts = np.bincount(np.asarray(sp.cols), minlength=N)
    return sp, sigs, counts


@pytest.fixture(scope="module")
def sharded4(small_catalog):
    _, sigs, counts = small_catalog
    bounds = shard_bounds(counts, 4)
    return build_sharded_index(sigs, shards=4, bounds=bounds)


class TestShardedIndex:
    def test_shard_bounds_cover_and_monotone(self, small_catalog):
        _, _, counts = small_catalog
        for D in (1, 2, 4, 8):
            b = shard_bounds(counts, D)
            assert b[0] == 0 and b[-1] == counts.size
            assert np.all(np.diff(b) > 0)

    def test_shard_bounds_nnz_balanced(self, small_catalog):
        _, _, counts = small_catalog
        b = shard_bounds(counts, 4)
        per = [counts[b[d]:b[d + 1]].sum() for d in range(4)]
        naive = [counts[i * 75:(i + 1) * 75].sum() for i in range(4)]
        # balanced cuts must not be worse than the even split
        assert max(per) <= max(naive)

    def test_geometry(self, sharded4, small_catalog):
        _, sigs, _ = small_catalog
        assert sharded4.shards == 4
        assert sharded4.q == int(sigs.shape[0])
        assert sharded4.n_items == int(sigs.shape[1])
        nl = np.asarray(sharded4.n_local)
        assert nl.sum() == sharded4.n_items
        assert nl.max() == sharded4.block
        assert sharded4.sorted_sigs.shape == (4, sharded4.q, sharded4.block)

    def test_validate_sharded_index_clean(self, sharded4):
        assert validate_sharded_index(sharded4) == []

    def test_validate_index_dispatches_on_sharded(self, sharded4):
        assert validate_index(sharded4) == []

    def test_validate_sharded_index_catches_corruption(self, sharded4):
        bad = np.asarray(sharded4.sorted_ids).copy()
        bad[1, 0, :2] = bad[1, 0, 0]          # duplicate local id in band 0
        broken = dataclasses.replace(sharded4, sorted_ids=jnp.asarray(bad))
        probs = validate_sharded_index(broken)
        assert probs and any("shard 1" in p for p in probs)

    def test_validate_sharded_index_catches_bad_bounds(self, sharded4):
        bad = np.asarray(sharded4.bounds).copy()
        bad[1] = bad[2]                        # zero-width shard
        broken = dataclasses.replace(sharded4, bounds=jnp.asarray(bad))
        assert any("strictly increasing" in p
                   for p in validate_sharded_index(broken))

    def test_local_ids_partition_catalog(self, sharded4):
        bounds = np.asarray(sharded4.bounds)
        nl = np.asarray(sharded4.n_local)
        seen = []
        for d in range(4):
            ids = np.asarray(sharded4.sorted_ids[d, 0])
            real = ids[ids < nl[d]]            # padding local ids sort high
            assert np.array_equal(np.sort(real), np.arange(nl[d]))
            seen.append(real + bounds[d])
        got = np.sort(np.concatenate(seen))
        assert np.array_equal(got, np.arange(sharded4.n_items))

    def test_bucket_membership_roundtrips(self, sharded4, small_catalog):
        """Per band: an item's shard-local bucket (same signature, same
        shard) is exactly the single-device bucket ∩ the shard — the
        satellite's global→local round-trip property."""
        _, sigs, _ = small_catalog
        sigs = np.asarray(sigs)
        bounds = np.asarray(sharded4.bounds)
        nl = np.asarray(sharded4.n_local)
        for d in range(4):
            view = shard_local_view(sharded4, d)
            ss = np.asarray(view.sorted_sigs)
            si = np.asarray(view.sorted_ids)
            lo_ = np.asarray(view.bucket_lo)
            hi_ = np.asarray(view.bucket_hi)
            so = np.asarray(view.slot_of)
            for b in range(sharded4.q):
                for g in range(bounds[d], bounds[d + 1]):
                    local = g - bounds[d]
                    slot = so[b, local]
                    assert ss[b, slot] == sigs[b, g]
                    members = si[b, lo_[b, slot]:hi_[b, slot]]
                    members = members[members < nl[d]] + bounds[d]
                    ref = np.flatnonzero(sigs[b] == sigs[b, g])
                    ref = ref[(ref >= bounds[d]) & (ref < bounds[d + 1])]
                    assert np.array_equal(np.sort(members), ref), (d, b, g)

    def test_padding_slots_inert(self, sharded4):
        ss = np.asarray(sharded4.sorted_sigs)
        nl = np.asarray(sharded4.n_local)
        for d in range(4):
            n_pad = sharded4.block - nl[d]
            # every padded slot carries _EMPTY_SIG and sorts first
            assert np.all((ss[d] == int(_EMPTY_SIG)).sum(axis=1) == n_pad)
            if n_pad:
                assert np.all(ss[d, :, :n_pad] == int(_EMPTY_SIG))

    def test_single_shard_equals_plain_index(self, small_catalog):
        _, sigs, _ = small_catalog
        plain = build_index(sigs, tail_cap=0)
        one = build_sharded_index(sigs, shards=1)
        view = shard_local_view(one, 0)
        for f in ("sorted_sigs", "sorted_ids", "bucket_lo", "bucket_hi",
                  "slot_of"):
            np.testing.assert_array_equal(np.asarray(getattr(view, f)),
                                          np.asarray(getattr(plain, f)), f)

    def test_signatures_of_roundtrip(self, small_catalog):
        _, sigs, _ = small_catalog
        idx = build_index(sigs, tail_cap=0)
        np.testing.assert_array_equal(np.asarray(signatures_of(idx)),
                                      np.asarray(sigs))

    def test_build_guards(self, small_catalog):
        _, sigs, _ = small_catalog
        with pytest.raises(TypeError):
            build_sharded_index(sigs.astype(jnp.float32), shards=2)
        with pytest.raises(ValueError):
            build_sharded_index(sigs, shards=0)
        with pytest.raises(ValueError):
            build_sharded_index(sigs, shards=2,
                                bounds=np.asarray([0, 200, 150, 300]))
        with pytest.raises(ValueError):
            build_sharded_index(sigs, shards=2, bounds=np.asarray([0, 300]))


# ---------------------------------------------------------------------------
# shard-local walk: signature exchange + union parity vs single device
# ---------------------------------------------------------------------------

class TestShardWalk:
    # truncation-free settings: cap ≥ any bucket, budget ≥ q·block, so
    # both paths enumerate every bucket in full and parity is exact
    CAP, BUDGET = 512, 2048

    def test_seed_sig_exchange_sums_to_truth(self, small_catalog, sharded4):
        sp, sigs, _ = small_catalog
        users = jnp.arange(32, dtype=jnp.int32)
        seeds = seed_items(sp, users, n_seeds=4, window=32)
        bounds = np.asarray(sharded4.bounds)
        total = np.zeros((sharded4.q,) + seeds.shape, np.int64)
        for d in range(4):
            contrib = shard_seed_sigs(sharded4.sorted_sigs[d],
                                      sharded4.slot_of[d], seeds,
                                      int(bounds[d]),
                                      int(sharded4.n_local[d]))
            total += np.asarray(contrib, np.int64)
        sigs = np.asarray(sigs)
        seeds = np.asarray(seeds)
        valid = seeds != SENTINEL
        ref = sigs[:, np.where(valid, seeds, 0)]
        np.testing.assert_array_equal(total[:, valid], ref[:, valid])
        assert np.all(total[:, ~valid] == 0)

    def test_seed_sig_exchange_disjoint_owners(self, small_catalog,
                                               sharded4):
        """Each valid seed is owned by exactly one shard (its nonzero
        contribution), so the psum is an exchange, not an accumulation."""
        sp, sigs, _ = small_catalog
        users = jnp.arange(16, dtype=jnp.int32)
        seeds = seed_items(sp, users, n_seeds=4, window=32)
        bounds = np.asarray(sharded4.bounds)
        owners = np.zeros(seeds.shape, np.int32)
        for d in range(4):
            contrib = np.asarray(shard_seed_sigs(
                sharded4.sorted_sigs[d], sharded4.slot_of[d], seeds,
                int(bounds[d]), int(sharded4.n_local[d])))
            owners += np.any(contrib != 0, axis=0)
        valid = np.asarray(seeds) != SENTINEL
        # a signature can be legitimately all-zero, so owners ≤ 1 is the
        # invariant (0 only for all-zero-signature or invalid seeds)
        assert np.all(owners[valid] <= 1)
        assert np.all(owners[~valid] == 0)

    def _sharded_union(self, sharded4, sp, users, *, cap, budget,
                       n_seeds=4, window=32):
        seeds = seed_items(sp, users, n_seeds=n_seeds, window=window)
        bounds = np.asarray(sharded4.bounds)
        total = np.zeros((sharded4.q,) + seeds.shape, np.int32)
        for d in range(4):
            total += np.asarray(shard_seed_sigs(
                sharded4.sorted_sigs[d], sharded4.slot_of[d], seeds,
                int(bounds[d]), int(sharded4.n_local[d])))
        qsigs = jnp.where((np.asarray(seeds) != SENTINEL)[None],
                          jnp.asarray(total), _EMPTY_SIG)
        per_user = [set() for _ in range(users.shape[0])]
        for d in range(4):
            local = shard_walk_local(sharded4.sorted_sigs[d],
                                     sharded4.sorted_ids[d], qsigs,
                                     int(sharded4.n_local[d]),
                                     cap=cap, budget=budget)
            glob = np.asarray(translate_local_ids(local, int(bounds[d])))
            for u in range(users.shape[0]):
                per_user[u] |= set(glob[u][glob[u] != SENTINEL].tolist())
        return per_user, seeds

    def test_union_parity_with_single_device_walk(self, small_catalog,
                                                  sharded4):
        sp, sigs, _ = small_catalog
        idx = build_index(sigs, tail_cap=0)
        users = jnp.arange(48, dtype=jnp.int32)
        got, _ = self._sharded_union(sharded4, sp, users, cap=self.CAP,
                                     budget=self.BUDGET)
        ids, _ = walk_candidates(idx, sp, users, n_seeds=4, cap=self.CAP,
                                 budget=self.BUDGET, window=32)
        ids = np.asarray(ids)
        for u in range(users.shape[0]):
            ref = set(ids[u][ids[u] != SENTINEL].tolist())
            assert got[u] == ref, f"user {u}"

    def test_walk_never_emits_padding_or_foreign_ids(self, small_catalog,
                                                     sharded4):
        sp, _, _ = small_catalog
        users = jnp.arange(32, dtype=jnp.int32)
        bounds = np.asarray(sharded4.bounds)
        seeds = seed_items(sp, users, n_seeds=4, window=32)
        total = np.zeros((sharded4.q,) + seeds.shape, np.int32)
        for d in range(4):
            total += np.asarray(shard_seed_sigs(
                sharded4.sorted_sigs[d], sharded4.slot_of[d], seeds,
                int(bounds[d]), int(sharded4.n_local[d])))
        qsigs = jnp.where((np.asarray(seeds) != SENTINEL)[None],
                          jnp.asarray(total), _EMPTY_SIG)
        for d in range(4):
            local = np.asarray(shard_walk_local(
                sharded4.sorted_sigs[d], sharded4.sorted_ids[d], qsigs,
                int(sharded4.n_local[d]), cap=8, budget=64))
            real = local[local != SENTINEL]
            assert np.all((real >= 0) & (real < int(sharded4.n_local[d])))

    def test_empty_sig_probes_retrieve_nothing(self, sharded4):
        qsigs = jnp.full((sharded4.q, 4, 4), _EMPTY_SIG, jnp.int32)
        local = np.asarray(shard_walk_local(
            sharded4.sorted_sigs[0], sharded4.sorted_ids[0], qsigs,
            int(sharded4.n_local[0]), cap=8, budget=64))
        assert np.all(local == SENTINEL)

    def test_translate_local_ids(self):
        local = jnp.asarray([[0, 5, SENTINEL], [SENTINEL, 2, 1]], jnp.int32)
        out = np.asarray(translate_local_ids(local, 100))
        np.testing.assert_array_equal(
            out, [[100, 105, SENTINEL], [SENTINEL, 102, 101]])


# ---------------------------------------------------------------------------
# config / resolution
# ---------------------------------------------------------------------------

class TestShardConfig:
    def test_serve_shard_count_resolution(self):
        assert serve_shard_count(0) == 1
        assert serve_shard_count(1) == 1
        assert serve_shard_count("auto") >= 1    # largest pow2 ≤ devices
        with pytest.raises(ValueError):
            serve_shard_count(3)                  # not a power of two
        with pytest.raises(ValueError):
            serve_shard_count(2 * jax.device_count())   # exceeds devices

    def test_resolved_shard_budget(self):
        cfg = ServeConfig(band_budget=512)
        # auto: 2× the per-shard share of the single-device budget,
        # rounded up to a lane multiple, never below 64
        assert cfg.resolved_shard_budget(4) == 256
        assert cfg.resolved_shard_budget(16) == 64
        assert dataclasses.replace(
            cfg, shard_budget=96).resolved_shard_budget(4) == 96

    def test_sharded_service_is_read_only(self, small_catalog):
        """ingest on a sharded service must refuse (the satellite's
        read-only contract) — exercised via the state flag the flush
        path keys on, since >1 host device needs a subprocess."""
        from repro.core import model
        from repro.serve import RecsysService
        sp, sigs, _ = small_catalog
        idx = build_index(sigs, tail_cap=0)
        M, N = sp.shape
        params = model.init_params(jax.random.PRNGKey(0), M, N, 8, 4)
        svc = RecsysService(params, idx, sp,
                            ServeConfig(topn=4, micro_batch=8, n_seeds=4,
                                        cap=8, band_budget=64, n_popular=0,
                                        use_jk=False))
        assert svc._shard_state is None          # 1 device → oracle path
        svc._shard_state = (None, None, None, 2)
        with pytest.raises(NotImplementedError):
            svc.ingest(sigs[:, :1], jnp.asarray([N], jnp.int32))
        with pytest.raises(NotImplementedError):
            svc.ingest_online_update(object(), N)
