"""Shared fixtures. NOTE: device count is NOT forced here — multi-device
tests spawn subprocesses with their own XLA_FLAGS (see tests/helpers/)."""
import dataclasses

import numpy as np
import pytest

try:  # hypothesis is optional (see requirements-dev.txt) — shim if absent
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import functools
    import inspect
    import random
    import sys
    import types

    class _Strategy:
        """Minimal stand-in: only the draw rules our tests use."""

        def __init__(self, draw):
            self.draw = draw

    def _integers(lo, hi):
        return _Strategy(lambda rng: rng.randint(lo, hi))

    def _floats(lo, hi):
        return _Strategy(lambda rng: rng.uniform(lo, hi))

    def _settings(max_examples=10, **_kw):
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn
        return deco

    def _given(*strategies):
        def deco(fn):
            sig = inspect.signature(fn)
            params = list(sig.parameters.values())
            drawn = [p.name for p in params[len(params) - len(strategies):]]

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                rng = random.Random(0)
                for _ in range(getattr(wrapper, "_shim_max_examples", 10)):
                    kw = dict(kwargs)
                    for name, s in zip(drawn, strategies):
                        kw[name] = s.draw(rng)
                    fn(*args, **kw)

            # hide drawn params from pytest's fixture resolution
            wrapper.__signature__ = sig.replace(
                parameters=params[:len(params) - len(strategies)])
            return wrapper
        return deco

    _hyp = types.ModuleType("hypothesis")
    _st = types.ModuleType("hypothesis.strategies")
    _st.integers, _st.floats = _integers, _floats
    _hyp.given, _hyp.settings, _hyp.strategies = _given, _settings, _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st


@pytest.fixture(scope="session")
def tiny_dataset():
    from repro.data import synthetic as syn
    spec = dataclasses.replace(syn.MOVIELENS_LIKE, M=600, N=120, nnz=12_000)
    rows, cols, vals, group = syn.generate(spec, seed=0)
    return spec, rows, cols, vals, group


@pytest.fixture(scope="session")
def tiny_sparse(tiny_dataset):
    from repro.data.sparse import from_coo
    spec, rows, cols, vals, _ = tiny_dataset
    return from_coo(rows, cols, vals, (spec.M, spec.N))
