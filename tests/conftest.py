"""Shared fixtures. NOTE: device count is NOT forced here — multi-device
tests spawn subprocesses with their own XLA_FLAGS (see tests/helpers/)."""
import dataclasses

import numpy as np
import pytest


@pytest.fixture(scope="session")
def tiny_dataset():
    from repro.data import synthetic as syn
    spec = dataclasses.replace(syn.MOVIELENS_LIKE, M=600, N=120, nnz=12_000)
    rows, cols, vals, group = syn.generate(spec, seed=0)
    return spec, rows, cols, vals, group


@pytest.fixture(scope="session")
def tiny_sparse(tiny_dataset):
    from repro.data.sparse import from_coo
    spec, rows, cols, vals, _ = tiny_dataset
    return from_coo(rows, cols, vals, (spec.M, spec.N))
