"""Pallas kernels vs pure-jnp oracles — shape/dtype sweeps, interpret mode."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.mf_sgd.kernel import culsh_sgd_step, mf_sgd_step
from repro.kernels.mf_sgd.ref import culsh_sgd_step_ref, mf_sgd_step_ref
from repro.kernels.neighbor_predict.kernel import neighbor_predict
from repro.kernels.neighbor_predict.ref import neighbor_predict_ref
from repro.kernels.simlsh_encode.kernel import simlsh_encode
from repro.kernels.simlsh_encode.ref import simlsh_encode_ref

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("N,deg,bits,tile", [
    (8, 16, 16, 8), (37, 64, 24, 8), (128, 32, 30, 16), (5, 8, 8, 8),
])
def test_simlsh_encode_shapes(N, deg, bits, tile):
    psi = jnp.asarray(RNG.normal(size=(N, deg)).astype(np.float32))
    phi = jnp.asarray(RNG.choice([-1., 1.], size=(N, deg, bits)).astype(np.float32))
    np.testing.assert_allclose(
        np.asarray(simlsh_encode(psi, phi, tile_n=tile)),
        np.asarray(simlsh_encode_ref(psi, phi)), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [np.float32])
@pytest.mark.parametrize("B,F,K,tile", [
    (64, 16, 8, 32), (100, 32, 16, 128), (3, 8, 4, 8), (256, 128, 32, 64),
])
def test_neighbor_predict_shapes(B, F, K, tile, dtype):
    a = lambda *s: jnp.asarray(RNG.normal(size=s).astype(dtype))
    args = (a(B, F), a(B, F), a(B, K), a(B, K), a(B, K), a(B, K),
            a(B), a(B), a(B))
    np.testing.assert_allclose(
        np.asarray(neighbor_predict(*args, tile_b=tile)),
        np.asarray(neighbor_predict_ref(*args)), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("B,F,tile", [(32, 8, 16), (200, 32, 64), (7, 16, 8)])
def test_mf_sgd_shapes(B, F, tile):
    a = lambda *s: jnp.asarray(RNG.normal(size=s).astype(np.float32))
    u, v, r = a(B, F), a(B, F), a(B)
    valid = jnp.asarray(RNG.integers(0, 2, B).astype(np.float32))
    got = mf_sgd_step(u, v, r, valid, 0.02, 0.03, 0.01, 0.02, tile_b=tile)
    want = mf_sgd_step_ref(u, v, r, valid, 0.02, 0.03, 0.01, 0.02)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-5, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 50), st.integers(1, 20), st.integers(0, 10**6))
def test_neighbor_predict_property(B, K, seed):
    rng = np.random.default_rng(seed)
    F = 8
    a = lambda *s: jnp.asarray(rng.normal(size=s).astype(np.float32))
    args = (a(B, F), a(B, F), a(B, K), a(B, K), a(B, K), a(B, K),
            a(B), a(B), a(B))
    np.testing.assert_allclose(
        np.asarray(neighbor_predict(*args, tile_b=16)),
        np.asarray(neighbor_predict_ref(*args)), rtol=1e-4, atol=1e-4)


def _culsh_args(B, F, K, rng):
    """Packed-plane operands: (row [B,F+1], col [B,F+2K+1], rnb, bh_nb,
    expl, r, valid, hp[13]) — see `mf_sgd.ref.culsh_sgd_step_ref`."""
    a = lambda *s: jnp.asarray(rng.normal(size=s).astype(np.float32))
    expl = jnp.asarray(rng.integers(0, 2, (B, K)).astype(np.float32))
    valid = jnp.asarray(rng.integers(0, 2, B).astype(np.float32))
    hp = jnp.concatenate([jnp.abs(a(12)) * 0.05, a(1) * 0.1])
    return (a(B, F + 1), a(B, F + 2 * K + 1), a(B, K), a(B, K), expl,
            a(B), valid, hp)


@pytest.mark.parametrize("bce", [False, True])
@pytest.mark.parametrize("B,F,K,tile", [
    (64, 16, 8, 32), (100, 32, 16, 128), (3, 8, 4, 8),
])
def test_culsh_sgd_shapes(B, F, K, tile, bce):
    args = _culsh_args(B, F, K, np.random.default_rng(B * 7 + K))
    got = culsh_sgd_step(*args, tile_b=tile, bce=bce)
    want = culsh_sgd_step_ref(*args, bce=bce)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-5, atol=1e-6)


def test_culsh_sgd_invalid_rows_untouched():
    args = _culsh_args(16, 8, 4, np.random.default_rng(0))
    args = args[:6] + (jnp.zeros((16,), jnp.float32),) + args[7:]
    row2, col2 = culsh_sgd_step(*args)
    np.testing.assert_allclose(np.asarray(row2), np.asarray(args[0]))
    np.testing.assert_allclose(np.asarray(col2), np.asarray(args[1]))


def test_mf_sgd_invalid_rows_untouched():
    a = lambda *s: jnp.asarray(RNG.normal(size=s).astype(np.float32))
    u, v, r = a(16, 8), a(16, 8), a(16)
    valid = jnp.zeros((16,), jnp.float32)
    u2, v2, e = mf_sgd_step(u, v, r, valid, 0.1, 0.1, 0.1, 0.1)
    np.testing.assert_allclose(np.asarray(u2), np.asarray(u))
    np.testing.assert_allclose(np.asarray(v2), np.asarray(v))
    np.testing.assert_allclose(np.asarray(e), 0.0)


def test_ops_encode_band_matches_core(tiny_sparse):
    from repro.core.simlsh import SimLSHConfig, band_accumulate
    from repro.kernels.simlsh_encode.ops import encode_band
    sp = tiny_sparse
    maxdeg = int(np.bincount(np.asarray(sp.cols), minlength=sp.N).max())
    deg = ((maxdeg + 7) // 8) * 8
    cfg = SimLSHConfig(G=8, p=2, q=2)
    key = jax.random.PRNGKey(0)
    S_k = encode_band(sp, cfg, key, jnp.asarray(1), deg=deg)
    S_r = band_accumulate(sp.rows, sp.cols, sp.vals, key, jnp.asarray(1),
                          N=sp.N, bits=cfg.sig_bits, psi_pow=cfg.psi_pow)
    np.testing.assert_allclose(np.asarray(S_k), np.asarray(S_r),
                               rtol=1e-4, atol=1e-3)


def test_ops_predict_matches_model(tiny_sparse):
    from repro.core import model
    from repro.core.model import assemble
    from repro.kernels.neighbor_predict.ops import predict_batch
    sp = tiny_sparse
    p = model.init_from_data(jax.random.PRNGKey(0), sp, 8, 4)
    JK = jnp.asarray(RNG.integers(0, sp.N, (sp.N, 4)), jnp.int32)
    idx = jnp.arange(256, dtype=jnp.int32)
    bt = assemble(sp, JK, idx, jnp.ones((256,), bool))
    got = predict_batch(p, bt)
    want, _ = model.predict(p, bt)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
