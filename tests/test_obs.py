"""repro.obs: histogram quantiles, span nesting, disabled-mode no-ops,
exporters, and the RecsysService.stats() single-source-of-truth parity
(ISSUE 6)."""
import json
import sys
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.core import simlsh
from repro.core.model import init_from_data
from repro.core.simlsh import SimLSHConfig
from repro.data.sparse import from_coo
from repro.obs.registry import (_B_PER_DECADE, _NULL_SPAN, Histogram,
                                Registry)
from repro.serve import RecsysService, ServeConfig, build_index


# ---------------------------------------------------------------- histogram

def test_histogram_quantiles_match_numpy_within_bucket_error():
    """p50/p95/p99 from the fixed log-bucket histogram vs exact numpy
    percentiles on lognormal samples (latency-shaped).  The bucket grid
    is 16/decade → ratio 10^(1/16) between bounds, so the log-linear
    interpolation is off by at most that ratio (~15.5%); in practice it
    lands ~1% out."""
    rng = np.random.default_rng(0)
    xs = rng.lognormal(mean=-6.0, sigma=1.0, size=20_000)   # ~ms spans
    h = Histogram()
    for x in xs:
        h.observe(float(x))
    bound = 10.0 ** (1.0 / _B_PER_DECADE) - 1.0
    for q in (0.50, 0.95, 0.99):
        exact = float(np.quantile(xs, q))
        got = h.quantile(q)
        assert abs(got - exact) / exact < bound, (q, got, exact)
    assert h.count == xs.size
    np.testing.assert_allclose(h.sum, xs.sum(), rtol=1e-9)
    assert h.min == xs.min() and h.max == xs.max()


def test_histogram_exact_stats_and_edge_cases():
    h = Histogram()
    assert h.summary() == dict(count=0)
    assert np.isnan(h.quantile(0.5))
    for v in (0.0, 1e-12, 1e9):          # under/over the bucket range
        h.observe(v)
    s = h.summary()
    assert s["count"] == 3 and s["min"] == 0.0 and s["max"] == 1e9
    # quantiles stay clamped to observed extremes, never a bucket bound
    assert 0.0 <= h.quantile(0.01) <= 1e9
    assert h.quantile(0.999) == 1e9


def test_histogram_single_value_all_quantiles_equal():
    h = Histogram()
    h.observe(0.25)
    for q in (0.0, 0.5, 0.99):
        assert h.quantile(q) == pytest.approx(0.25, rel=1e-12)


# ---------------------------------------------------------------- spans

def test_span_nesting_depth_and_chrome_trace_containment():
    reg = Registry(enabled=True)
    with reg.span("outer"):
        time.sleep(0.002)
        with reg.span("inner.a"):
            time.sleep(0.002)
        with reg.span("inner.b"):
            time.sleep(0.002)
    # completion order: children first; depths from the thread stack
    names = [s[0] for s in reg.spans]
    depths = {s[0]: s[4] for s in reg.spans}
    assert names == ["inner.a", "inner.b", "outer"]
    assert depths == {"outer": 0, "inner.a": 1, "inner.b": 1}

    doc = obs.chrome_trace(reg)
    evs = {e["name"]: e for e in doc["traceEvents"] if e["ph"] == "X"}
    assert doc["displayTimeUnit"] == "ms"
    out, a, b = evs["outer"], evs["inner.a"], evs["inner.b"]
    # Perfetto reconstructs nesting from interval containment per tid:
    # both children inside the parent, siblings disjoint and ordered
    assert out["tid"] == a["tid"] == b["tid"]
    assert out["ts"] <= a["ts"] and a["ts"] + a["dur"] <= out["ts"] + out["dur"]
    assert out["ts"] <= b["ts"] and b["ts"] + b["dur"] <= out["ts"] + out["dur"]
    assert a["ts"] + a["dur"] <= b["ts"]
    json.dumps(doc)     # must be valid JSON end to end


def test_span_durations_and_histogram_feed():
    reg = Registry(enabled=True)
    for _ in range(3):
        with reg.span("work"):
            time.sleep(0.001)
    durs = reg.span_durations("work")
    assert len(durs) == 3 and all(d >= 0.001 for d in durs)
    # every span completion also lands in the same-named histogram
    assert reg.hist_summary("work")["count"] == 3


def test_record_span_for_overlapping_intervals():
    """Externally-timed (dispatch-ahead) intervals may overlap — the
    registry must keep both verbatim."""
    reg = Registry(enabled=True)
    t0 = time.perf_counter_ns()
    reg.record_span("flush", t0, 5_000_000)
    reg.record_span("flush", t0 + 1_000_000, 5_000_000)   # overlaps the 1st
    assert len(reg.span_durations("flush")) == 2
    assert reg.hist_summary("flush")["count"] == 2


def test_span_log_cap_drops_but_histogram_never_does():
    reg = Registry(enabled=True, max_spans=4)
    for i in range(10):
        reg.record_span("s", i * 100, 50)
    assert len(reg.spans) == 4 and reg.spans_dropped == 6
    assert reg.hist_summary("s")["count"] == 10


def test_spans_thread_local_stacks():
    reg = Registry(enabled=True)

    def worker():
        with reg.span("t.outer"):
            with reg.span("t.inner"):
                pass

    with reg.span("main.outer"):
        th = threading.Thread(target=worker)
        th.start()
        th.join()
    d = {s[0]: s[4] for s in reg.spans}
    # each thread nests against its own stack, not a shared one
    assert d == {"t.inner": 1, "t.outer": 0, "main.outer": 0}
    tids = {s[0]: s[3] for s in reg.spans}
    assert tids["t.outer"] != tids["main.outer"]


# ---------------------------------------------------------------- disabled

def test_disabled_mode_is_noop_and_allocation_free():
    reg = Registry(enabled=False)
    # warm up any lazy state (method binding caches etc.)
    for _ in range(3):
        with reg.span("x"):
            pass
        reg.counter_add("c")
        reg.gauge_set("g", 1.0)
        reg.observe("h", 0.5)
        reg.event("e", k=1)
    assert reg.span("x") is _NULL_SPAN          # shared singleton, no alloc
    before = sys.getallocatedblocks()
    for _ in range(5_000):
        with reg.span("x"):
            pass
        reg.counter_add("c")
        reg.gauge_set("g", 1.0)
        reg.observe("h", 0.5)
    after = sys.getallocatedblocks()
    # zero net allocation across 20k recording calls (tolerance for
    # interpreter-internal churn unrelated to the registry)
    assert after - before < 16, (before, after)
    assert not reg.counters and not reg.gauges and not reg.hists
    assert not reg.spans and not reg.events
    s = reg.snapshot()
    assert s["counters"] == {} and s["histograms"] == {}


def test_module_default_disabled_and_scoped():
    assert not obs.enabled()    # library default: opted out
    r = obs.scoped()
    assert r is not obs.get() and r.enabled
    try:
        obs.enable()
        assert obs.scoped() is obs.get()
    finally:
        obs.disable()
        obs.reset()


# ---------------------------------------------------------------- exporters

def test_events_jsonl_roundtrip():
    reg = Registry(enabled=True)
    reg.event("eval", epoch=1, rmse=0.91)
    reg.event("eval", epoch=2, rmse=0.88)
    lines = obs.events_jsonl(reg).strip().splitlines()
    recs = [json.loads(ln) for ln in lines]
    assert [r["event"] for r in recs] == ["eval", "eval"]
    assert recs[1]["rmse"] == 0.88 and "ts" in recs[0]


def test_prometheus_text_exposition():
    reg = Registry(enabled=True)
    reg.counter_add("serve.users", 42)
    reg.gauge_set("serve.queue_depth", 3)
    reg.observe("serve.flush", 0.01)
    txt = obs.prometheus_text(reg)
    assert "# TYPE serve_users counter\nserve_users 42" in txt
    assert "# TYPE serve_queue_depth gauge\nserve_queue_depth 3" in txt
    assert '# TYPE serve_flush summary' in txt
    assert 'serve_flush{quantile="0.50"}' in txt
    assert "serve_flush_count 1" in txt


# ------------------------------------------------- service stats() parity

@pytest.fixture(scope="module")
def tiny_service():
    rng = np.random.default_rng(0)
    M, N = 200, 60
    rows = np.repeat(np.arange(M), 5).astype(np.int32)
    cols = rng.integers(0, N, M * 5).astype(np.int32)
    vals = rng.integers(1, 6, M * 5).astype(np.float32)
    keys = rows.astype(np.int64) * N + cols
    _, uniq = np.unique(keys, return_index=True)
    sp = from_coo(rows[uniq], cols[uniq], vals[uniq], (M, N))
    cfg = SimLSHConfig(G=8, p=2, q=8)
    sigs = simlsh.encode(sp, cfg, jax.random.PRNGKey(0))
    index = build_index(sigs, tail_cap=32)
    params = init_from_data(jax.random.PRNGKey(1), sp, 16, 8)
    scfg = ServeConfig(topn=5, micro_batch=16, C=48, n_seeds=4, cap=8,
                       n_popular=8)
    return params, index, sp, scfg, sigs, cfg


def test_service_stats_parity_with_registry(tiny_service):
    """stats() is a pure read of the obs registry: same counters, same
    span histogram, pre-obs key semantics preserved."""
    params, index, sp, scfg, _, _ = tiny_service
    svc = RecsysService(params, index, sp, scfg).warmup()
    for _ in range(3):
        svc.submit(np.arange(16, dtype=np.int32))
    svc.flush()
    st = svc.stats()
    reg = svc.obs
    assert st["mode"] == "candidate"
    assert st["batches"] == int(reg.counter("serve.flushes")) == 3
    assert st["users"] == int(reg.counter("serve.users")) == 48
    busy = reg.counter("serve.busy_seconds")
    assert st["qps"] == pytest.approx(st["users"] / busy)
    secs = np.asarray(reg.span_durations("serve.flush"))
    assert secs.shape[0] == 3
    for key, q in (("p50_ms", 50), ("p95_ms", 95), ("p99_ms", 99)):
        assert st[key] == pytest.approx(float(np.percentile(secs, q) * 1e3))
    assert st["queue"] == 0
    assert st["ingest_to_servable_s"] == 0.0    # no ingest yet
    # queue-wait observations: one per consumed submit chunk
    assert reg.hist_summary("serve.queue_wait")["count"] == 3


def test_sibling_services_isolated_but_spans_mirror(tiny_service):
    """Two services must never blend each other's stats() (the shared-
    registry regression: a full-mode service's users/busy deflated a
    candidate service's reported QPS under --trace), while both still
    contribute their flush spans to an enabled process-wide registry via
    the span mirror."""
    params, index, sp, scfg, _, _ = tiny_service
    shared = Registry(enabled=True)
    a = RecsysService(params, index, sp, scfg,
                      registry=Registry(enabled=True, mirror=shared))
    b = RecsysService(params, index, sp, scfg,
                      registry=Registry(enabled=True, mirror=shared))
    a.warmup()
    b.warmup()
    for _ in range(2):
        a.submit(np.arange(16, dtype=np.int32))
    a.flush()
    b.submit(np.arange(16, dtype=np.int32))
    b.flush()
    sa, sb = a.stats(), b.stats()
    # isolation: each service reports only its own traffic
    assert sa["batches"] == 2 and sa["users"] == 32
    assert sb["batches"] == 1 and sb["users"] == 16
    assert sa["qps"] == pytest.approx(
        32 / a.obs.counter("serve.busy_seconds"))
    # mirror: the shared timeline carries every flush span from both,
    # but none of their metric planes
    assert len(shared.span_durations("serve.flush")) == 3
    assert shared.counter("serve.users") == 0.0
    assert shared.hist_summary("serve.flush")["count"] == 0
    # a disabled mirror target records nothing
    off = Registry(enabled=False)
    c = RecsysService(params, index, sp, scfg,
                      registry=Registry(enabled=True, mirror=off))
    c.warmup()
    c.submit(np.arange(16, dtype=np.int32))
    c.flush()
    assert c.stats()["batches"] == 1
    assert off.spans == []


def test_service_empty_stats():
    """Zero-traffic stats must not divide by zero or produce NaN."""
    rng = np.random.default_rng(3)
    M, N = 64, 32
    rows = np.repeat(np.arange(M), 3).astype(np.int32)
    cols = rng.integers(0, N, M * 3).astype(np.int32)
    vals = np.ones(M * 3, np.float32)
    keys = rows.astype(np.int64) * N + cols
    _, uniq = np.unique(keys, return_index=True)
    sp = from_coo(rows[uniq], cols[uniq], vals[uniq], (M, N))
    sigs = simlsh.encode(sp, SimLSHConfig(G=8, p=2, q=4),
                         jax.random.PRNGKey(0))
    svc = RecsysService(init_from_data(jax.random.PRNGKey(1), sp, 8, 4),
                        build_index(sigs, tail_cap=8), sp,
                        ServeConfig(micro_batch=8, C=16, n_seeds=2,
                                    n_popular=0))
    st = svc.stats()
    assert st["batches"] == 0 and st["users"] == 0 and st["qps"] == 0.0
    assert st["p50_ms"] == 0.0 and st["p95_ms"] == 0.0


def test_service_ingest_sets_servable_latency_and_trace(tiny_service):
    """The acceptance path: ingest → stats()['ingest_to_servable_s'] > 0,
    and a profiled flush exports nested retrieve/walk/score spans that
    a Chrome-trace consumer can reconstruct (walk-path layout: dedup is
    in-kernel/at-select, so there is no dedup span)."""
    params, index, sp, scfg, sigs, lshcfg = tiny_service
    svc = RecsysService(params, index, sp, scfg).warmup()
    svc.profile_flush()
    sig2 = simlsh.encode(sp, lshcfg, jax.random.PRNGKey(7))
    svc.ingest(sig2[:, :4], jnp.arange(sp.N, sp.N + 4, dtype=jnp.int32))
    st = svc.stats()
    assert st["ingest_to_servable_s"] > 0.0

    doc = obs.chrome_trace(svc.obs)
    evs = {}
    for e in doc["traceEvents"]:
        if e["ph"] == "X":
            evs.setdefault(e["name"], e)
    for name in ("serve.flush", "serve.flush.retrieve",
                 "serve.flush.retrieve.desc", "serve.flush.retrieve.walk",
                 "serve.flush.score", "serve.flush.select", "serve.ingest"):
        assert name in evs, name
    fl, rt, wk = (evs["serve.flush"], evs["serve.flush.retrieve"],
                  evs["serve.flush.retrieve.walk"])
    inside = lambda a, b: (b["ts"] <= a["ts"]
                           and a["ts"] + a["dur"] <= b["ts"] + b["dur"])
    assert inside(rt, fl) and inside(wk, rt)
    assert inside(evs["serve.flush.score"], fl)
    assert inside(evs["serve.flush.select"], fl)


def test_service_profile_flush_matches_fused_results(tiny_service):
    """The staged profiling path must run the same retrieval+scoring as
    the fused hot path (same candidates in, same top-N out)."""
    params, index, sp, scfg, _, _ = tiny_service
    svc = RecsysService(params, index, sp, scfg).warmup()
    users = np.arange(16, dtype=np.int32)
    svc.submit(users)
    svc.flush()
    _, fused_scores, fused_items = svc.take_results()[0]
    svc.profile_flush(users)   # records spans; results discarded
    # re-run the staged path manually for output parity
    from repro.kernels.candidate_score.ops import score_candidates
    from repro.serve.retrieve import candidate_pool, finalize_candidates
    ids = jnp.asarray(users)
    pool = candidate_pool(index, sp, ids, n_seeds=scfg.n_seeds,
                          cap=scfg.cap, JK=svc.JK, window=scfg.seed_window,
                          fold_mates=scfg.fold_mates,
                          tail_scan=svc.index.tail_fill > 0)
    cand = finalize_candidates(pool, C=scfg.C, popular=svc.popular,
                               pool_width=scfg.resolved_pool_width())
    s, it = score_candidates(svc.planes, ids, cand, topn=scfg.topn,
                             tile_b=scfg.tile_b,
                             interpret=scfg.interpret_mode(),
                             impl=scfg.scorer_impl())
    np.testing.assert_array_equal(np.asarray(it), fused_items)
    np.testing.assert_allclose(np.asarray(s), fused_scores,
                               rtol=1e-5, atol=1e-5)
