"""Multi-device checks run in a subprocess (own XLA device count).

Invoked by tests/test_multidevice.py as:
    python tests/helpers/multidev_checks.py <check-name>
Prints "PASS <name>" on success, raises otherwise.
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import dataclasses  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))
from repro.launch.mesh import compat_mesh, shard_map, use_mesh  # noqa: E402



def check_sharded_epoch():
    """Block-aligned shard-map tier (4 host devices, nnz-balanced blocks,
    packed planes, device-sharded ShardData cells) == single-device replay
    of the same schedule, params and RMSE within 1e-5."""
    from repro.core import model, sgd
    from repro.data import synthetic as syn
    from repro.data.sparse import conflict_free_schedule, from_coo
    from repro.launch.mesh import make_shard_mesh

    M, N, D, K = 240, 96, 4, 8
    spec = dataclasses.replace(syn.MOVIELENS_LIKE, M=M, N=N, nnz=4000)
    rows, cols, vals, _ = syn.generate(spec, seed=0)
    sp = from_coo(rows, cols, vals, (M, N))
    rng = np.random.default_rng(0)
    JK = jnp.asarray(rng.integers(0, N, (N, K)), jnp.int32)
    sched = conflict_free_schedule(np.asarray(sp.rows), np.asarray(sp.cols),
                                   batch=64, M=M, N=N, shards=D, seed=0)
    assert sched.shard_starts.size, "shard tier empty"
    sd = model.build_scheduled_data(sp, JK, sched)
    shd = model.build_shard_data(sp, JK, sched)
    assert shd is not None
    p0 = model.init_from_data(jax.random.PRNGKey(0), sp, 8, K)
    pp0 = model.pack_params(model.remap_params(p0, sched))
    hp = sgd.Hyper()
    mesh = make_shard_mesh(D)
    key = jax.random.PRNGKey(1)
    copy = lambda p: jax.tree.map(jnp.copy, p)
    pp1, pp2 = copy(pp0), copy(pp0)
    for ep in range(2):
        kk, ee = jax.random.fold_in(key, ep), jnp.asarray(ep)
        pp1 = sgd.train_epoch_scheduled(pp1, sd, sched, kk, ee, hp, shd=shd)
        pp2 = sgd.train_epoch_scheduled(pp2, sd, sched, kk, ee, hp, shd=shd,
                                        mesh=mesh)
    p1 = model.unmap_params(model.unpack_params(pp1), sched)
    p2 = model.unmap_params(model.unpack_params(pp2), sched)
    for f in ("U", "V", "b", "bh", "W", "C"):
        np.testing.assert_allclose(np.asarray(getattr(p1, f)),
                                   np.asarray(getattr(p2, f)),
                                   rtol=1e-5, atol=1e-5, err_msg=f)
    te_r = jnp.asarray(rng.integers(0, M, 500), jnp.int32)
    te_c = jnp.asarray(rng.integers(0, N, 500), jnp.int32)
    te_v = jnp.asarray(rng.uniform(1, 5, 500), jnp.float32)
    r1 = float(model.rmse(p1, sp, JK, te_r, te_c, te_v))
    r2 = float(model.rmse(p2, sp, JK, te_r, te_c, te_v))
    assert abs(r1 - r2) <= 1e-5, (r1, r2)
    print(f"sharded rmse {r2:.6f} == single-device {r1:.6f}")


def check_sharded_serve():
    """Sharded serving tier (ISSUE 9) on 4 host devices vs the
    single-device walk oracle.  Two regimes:

    * truncation-free (cap ≥ any bucket, budgets ≥ q·N): both paths
      enumerate every probed bucket in full, so the top-N must be
      *bit-exact* — identical id sets at equal scores for every user;
    * bench-like truncating settings on a planted catalog: the window
      geometries legitimately differ (seed-centred vs per-shard
      bucket-head), so the gate is recall parity — recall@10 of the
      sharded path within ±0.01 of the single-device walk path.
    """
    from repro.core import simlsh, topk
    from repro.data.sparse import from_coo
    from repro.serve import (RecsysService, ServeConfig, build_index,
                             full_topn)

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", ".."))
    from benchmarks.bench_serve import CatalogSpec, make_catalog

    assert jax.device_count() == 4, jax.device_count()
    spec = CatalogSpec(N=4000)
    params, sp, _ = make_catalog(spec, seed=0)
    M = params.U.shape[0]
    lsh = simlsh.SimLSHConfig(G=8, p=2, q=10, band_cap=16)
    key = jax.random.PRNGKey(0)
    sigs = simlsh.encode(sp, lsh, key)
    JK = topk.topk_from_signatures(sigs, jax.random.fold_in(key, 1), K=16,
                                   band_cap=lsh.band_cap)
    index = build_index(sigs, tail_cap=0)
    rng = np.random.default_rng(1)
    users = jnp.asarray(rng.integers(0, M, 128), jnp.int32)

    def top_sets(s, i):
        s, i = np.asarray(s), np.asarray(i)
        sent = np.iinfo(np.int32).max
        return [(frozenset(i[u][i[u] != sent].tolist()),
                 np.sort(s[u][i[u] != sent])) for u in range(i.shape[0])]

    # regime 1: truncation-free → bit-exact parity
    exact = dict(topn=10, micro_batch=128, n_seeds=8, cap=4096,
                 band_budget=16384, shard_budget=16384, n_popular=0,
                 use_jk=False)
    svc_s = RecsysService(params, index, sp, ServeConfig(**exact, shards=4))
    assert svc_s._shard_state is not None and svc_s.stats()["shards"] == 4
    svc_1 = RecsysService(params, index, sp, ServeConfig(**exact))
    for (ids_a, s_a), (ids_b, s_b) in zip(
            top_sets(*svc_s._recommend(users)),
            top_sets(*svc_1._recommend(users))):
        assert ids_a == ids_b, (sorted(ids_a - ids_b), sorted(ids_b - ids_a))
        np.testing.assert_allclose(s_a, s_b, rtol=1e-5, atol=1e-5)

    # regime 2: bench-like truncation → recall parity ±0.01
    bench = dict(topn=10, micro_batch=128, C=512, n_seeds=16, cap=8,
                 n_popular=64, tile_b=16, band_budget=512)
    _, exact_i = full_topn(params, users, topn=10)
    exact_i = np.asarray(exact_i)

    def recall(svc):
        got = np.asarray(svc._recommend(users)[1])
        hits = sum(len(set(got[u]) & set(exact_i[u]))
                   for u in range(got.shape[0]))
        return hits / exact_i.size

    rec_s = recall(RecsysService(params, index, sp,
                                 ServeConfig(**bench, shards=4), JK=JK))
    rec_1 = recall(RecsysService(params, index, sp, ServeConfig(**bench),
                                 JK=JK))
    assert rec_s >= rec_1 - 0.01, (rec_s, rec_1)
    print(f"sharded recall {rec_s:.3f} vs single-device {rec_1:.3f} "
          f"(bit-exact at truncation-free settings on 128 users)")


def check_rotation():
    from repro.core.sgd import Hyper
    from repro.data import synthetic as syn
    from repro.dist.rotation import (make_rotation_epoch,
                                     reference_rotation_epoch, stage_blocks)
    D, M, N, F = 4, 64, 32, 8
    spec = dataclasses.replace(syn.MOVIELENS_LIKE, M=M, N=N, nnz=1500)
    rows, cols, vals, _ = syn.generate(spec, 0)
    staged = stage_blocks(rows, cols, vals, M, N, D)
    rng = np.random.default_rng(0)
    U0 = (rng.normal(size=(M, F)) * 0.1).astype(np.float32)
    V0 = (rng.normal(size=(N, F)) * 0.1).astype(np.float32)
    hp = Hyper()
    mesh = compat_mesh((4,), ("data",))
    epoch_fn = make_rotation_epoch(mesh, D, M, N, hp, batch=128)
    with use_mesh(mesh):
        U1, V1 = epoch_fn(jnp.asarray(U0), jnp.asarray(V0),
                          jnp.asarray(staged["i"]), jnp.asarray(staged["j"]),
                          jnp.asarray(staged["r"]),
                          jnp.asarray(staged["valid"]), jnp.asarray(0))
        txt = jax.jit(epoch_fn).lower(
            jnp.asarray(U0), jnp.asarray(V0), jnp.asarray(staged["i"]),
            jnp.asarray(staged["j"]), jnp.asarray(staged["r"]),
            jnp.asarray(staged["valid"]), jnp.asarray(0)).compile().as_text()
    U2, V2 = reference_rotation_epoch(U0, V0, staged, D, M, N, hp, 0,
                                      batch=128)
    np.testing.assert_allclose(np.asarray(U1), np.asarray(U2),
                               rtol=2e-5, atol=2e-6)
    np.testing.assert_allclose(np.asarray(V1), np.asarray(V2),
                               rtol=2e-5, atol=2e-6)
    assert "collective-permute" in txt, "ring permute missing from HLO"


def check_moe_a2a():
    """shard_map a2a MoE == dense reference (values AND expert-weight grads)."""
    from repro.configs import base as CB
    from repro.models import moe as MOE
    cfg = dataclasses.replace(
        CB.reduced(CB.get("dbrx-132b")), n_experts=4, moe_top_k=2)
    mesh = compat_mesh((2, 2), ("data", "model"))
    axes = {"dp": "data", "tp": "model", "ndp": 2, "ntp": 2}
    rng = np.random.default_rng(0)
    B, S, D = 4, 8, cfg.d_model
    x = jnp.asarray(rng.normal(0, 0.5, (B, S, D)).astype(np.float32))
    pl = {
        "router": jnp.asarray(rng.normal(size=(D, 4)).astype(np.float32)),
        "w1": jnp.asarray(rng.normal(0, 0.05, (4, D, cfg.d_ff)).astype(np.float32)),
        "w3": jnp.asarray(rng.normal(0, 0.05, (4, D, cfg.d_ff)).astype(np.float32)),
        "w2": jnp.asarray(rng.normal(0, 0.05, (4, cfg.d_ff, D)).astype(np.float32)),
    }
    eid, gate = MOE.router(pl, x, cfg)

    y_a2a = MOE.moe_ffn(pl, x, eid, gate, cfg, mesh, axes,
                        capacity_factor=16.0)
    y_ref = MOE.moe_dense_ref(pl, x, eid, gate, cfg)
    np.testing.assert_allclose(np.asarray(y_a2a), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)

    # gradient equivalence (checks shard_map transpose/psum correctness)
    def loss_a2a(w):
        y = MOE.moe_ffn(pl | w, x, eid, gate, cfg, mesh, axes,
                        capacity_factor=16.0)
        return jnp.sum(y ** 2)

    def loss_ref(w):
        return jnp.sum(MOE.moe_dense_ref(pl | w, x, eid, gate, cfg) ** 2)

    w = {"w1": pl["w1"], "w2": pl["w2"], "w3": pl["w3"]}
    g_a2a = jax.grad(loss_a2a)(w)
    g_ref = jax.grad(loss_ref)(w)
    for k in w:
        np.testing.assert_allclose(np.asarray(g_a2a[k]), np.asarray(g_ref[k]),
                                   rtol=5e-3, atol=5e-3)

    # decode path (tokens replicated over tp)
    x1 = x[:, :1]
    eid1, gate1 = MOE.router(pl, x1, cfg)
    y1 = MOE.moe_ffn(pl, x1, eid1, gate1, cfg, mesh, axes,
                     capacity_factor=16.0, shard_seq=False)
    y1_ref = MOE.moe_dense_ref(pl, x1, eid1, gate1, cfg)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y1_ref),
                               rtol=2e-4, atol=2e-4)


def check_compression():
    from repro.dist.compression import compressed_psum_mean
    mesh = compat_mesh((4,), ("data",))
    rng = np.random.default_rng(0)
    g = rng.normal(size=(4, 256)).astype(np.float32)

    def f(gl, res):
        m, r = compressed_psum_mean(gl[0], "data", res[0])
        return m[None], r[None]

    fn = shard_map(f, mesh=mesh,
                       in_specs=(P("data", None), P("data", None)),
                       out_specs=(P("data", None), P("data", None)))
    with use_mesh(mesh):
        mean_c, resid = fn(jnp.asarray(g), jnp.zeros_like(g))
    true = g.mean(0)
    err = np.abs(np.asarray(mean_c)[0] - true).max() / np.abs(true).max()
    assert err < 0.05, err
    # error feedback: residual equals the quantization error exactly
    np.testing.assert_allclose(np.asarray(resid).sum(), np.asarray(resid).sum())

    # error feedback drives the *accumulated* estimate to the truth
    res = jnp.zeros_like(g)
    acc = np.zeros_like(true)
    for _ in range(30):
        with use_mesh(mesh):
            m, res = fn(jnp.asarray(g), res)
        acc += np.asarray(m)[0]
    np.testing.assert_allclose(acc / 30, true, rtol=2e-3, atol=2e-4)


def check_small_dryrun():
    """Reduced-config lower+compile on a 2×2 mesh for one arch per family —
    the dry-run machinery itself, cheap."""
    from repro.configs import base as CB
    from repro.launch.dryrun import build_cell
    from repro.models import sharding
    mesh = compat_mesh((2, 2), ("data", "model"))
    axes = sharding.mesh_axes(mesh)
    shape = dataclasses.replace(CB.SHAPES["train_4k"], seq_len=64,
                                global_batch=4)
    dshape = dataclasses.replace(CB.SHAPES["decode_32k"], seq_len=64,
                                 global_batch=4)
    for arch in ("llama3-8b", "dbrx-132b", "mamba2-370m", "zamba2-7b",
                 "seamless-m4t-large-v2", "llava-next-mistral-7b"):
        cfg = dataclasses.replace(CB.reduced(CB.get(arch)), vocab=512)
        for sh in (shape, dshape):
            fn, in_sh, args, donate = build_cell(cfg, sh, mesh, axes)
            with use_mesh(mesh):
                c = jax.jit(fn, in_shardings=in_sh,
                            donate_argnums=donate).lower(*args).compile()
            assert c.cost_analysis() is not None
    print("all families compile on 2x2 mesh")




def check_moe_ep2d():
    """EP-over-data MoE == dense reference (the §Perf beyond-paper path)."""
    from repro.configs import base as CB
    from repro.models import moe as MOE
    cfg = dataclasses.replace(
        CB.reduced(CB.get("arctic-480b")), n_experts=4, moe_top_k=2,
        moe_dense_ff=0)
    mesh = compat_mesh((2, 2), ("data", "model"))
    axes = {"dp": "data", "tp": "model", "ndp": 2, "ntp": 2}
    rng = np.random.default_rng(0)
    B, S, D = 4, 8, cfg.d_model
    x = jnp.asarray(rng.normal(0, 0.5, (B, S, D)).astype(np.float32))
    pl = {
        "router": jnp.asarray(rng.normal(size=(D, 4)).astype(np.float32)),
        "w1": jnp.asarray(rng.normal(0, 0.05, (4, D, cfg.d_ff)).astype(np.float32)),
        "w3": jnp.asarray(rng.normal(0, 0.05, (4, D, cfg.d_ff)).astype(np.float32)),
        "w2": jnp.asarray(rng.normal(0, 0.05, (4, cfg.d_ff, D)).astype(np.float32)),
    }
    eid, gate = MOE.router(pl, x, cfg)
    y = MOE.moe_ffn_ep2d(pl, x, eid, gate, cfg, mesh, axes,
                         capacity_factor=16.0)
    y_ref = MOE.moe_dense_ref(pl, x, eid, gate, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)

    def loss(w):
        return jnp.sum(MOE.moe_ffn_ep2d(pl | w, x, eid, gate, cfg, mesh,
                                        axes, capacity_factor=16.0) ** 2)

    def loss_ref(w):
        return jnp.sum(MOE.moe_dense_ref(pl | w, x, eid, gate, cfg) ** 2)

    w = {"w1": pl["w1"], "w2": pl["w2"], "w3": pl["w3"]}
    g, g_ref = jax.grad(loss)(w), jax.grad(loss_ref)(w)
    for k in w:
        np.testing.assert_allclose(np.asarray(g[k]), np.asarray(g_ref[k]),
                                   rtol=5e-3, atol=5e-3)


def check_elastic_restore():
    """Checkpoint written under one sharding restores onto a *different*
    mesh (elastic restart after node loss — DESIGN.md §5)."""
    import tempfile
    from jax.sharding import NamedSharding
    from repro.train import checkpoint as ckpt
    tree = {"w": jnp.arange(64.0).reshape(8, 8),
            "b": jnp.arange(8.0)}
    mesh4 = compat_mesh((4,), ("data",))
    sh4 = {"w": NamedSharding(mesh4, P("data", None)),
           "b": NamedSharding(mesh4, P("data"))}
    tree4 = jax.tree.map(jax.device_put, tree, sh4)
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, tree4, step=1, sync=True)
        # "cluster shrinks": restore onto a 2×2 mesh with different layout
        mesh22 = compat_mesh((2, 2), ("data", "model"))
        sh22 = {"w": NamedSharding(mesh22, P("data", "model")),
                "b": NamedSharding(mesh22, P("data"))}
        tree22, step = ckpt.restore(d, tree, shardings=sh22)
        assert step == 1
        np.testing.assert_array_equal(np.asarray(tree22["w"]),
                                      np.asarray(tree["w"]))
        assert tree22["w"].sharding == sh22["w"]


if __name__ == "__main__":
    name = sys.argv[1]
    {"rotation": check_rotation, "moe_a2a": check_moe_a2a,
     "moe_ep2d": check_moe_ep2d, "compression": check_compression,
     "elastic": check_elastic_restore,
     "small_dryrun": check_small_dryrun,
     "sharded_epoch": check_sharded_epoch,
     "sharded_serve": check_sharded_serve}[name]()
    print(f"PASS {name}")
