"""Conflict-free scheduler + cached gathers + fast-path/kernel parity."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import model, sgd
from repro.data.sparse import conflict_free_schedule, from_coo
from repro.kernels.mf_sgd.ops import apply_culsh_sgd, apply_mf_sgd

RNG = np.random.default_rng(0)


def _check_schedule(rows, cols, sched):
    """Every cf batch conflict-free; cf + leftover cover each triple once."""
    rows, cols = np.asarray(rows), np.asarray(cols)
    seen = []
    for b in range(sched.cf_idx.shape[0]):
        v = np.asarray(sched.cf_valid[b])
        ids = np.asarray(sched.cf_idx[b])[v]
        assert len(np.unique(rows[ids])) == len(ids), "row conflict"
        assert len(np.unique(cols[ids])) == len(ids), "col conflict"
        seen.append(ids)
    for b in range(sched.lo_idx.shape[0]):
        v = np.asarray(sched.lo_valid[b])
        seen.append(np.asarray(sched.lo_idx[b])[v])
    seen = np.concatenate(seen) if seen else np.zeros((0,), np.int64)
    assert sorted(seen.tolist()) == list(range(len(rows))), "not an exact cover"


@settings(max_examples=10, deadline=None)
@given(st.integers(5, 200), st.integers(3, 60), st.integers(16, 256),
       st.integers(0, 10**6))
def test_schedule_conflict_free_exact_cover(M, N, batch, seed):
    rng = np.random.default_rng(seed)
    nnz = min(M * N, int(rng.integers(1, 4 * (M + N))))
    pairs = rng.choice(M * N, size=nnz, replace=False)
    rows = (pairs // N).astype(np.int32)
    cols = (pairs % N).astype(np.int32)
    sched = conflict_free_schedule(rows, cols, batch=batch, seed=seed)
    _check_schedule(rows, cols, sched)


def test_schedule_zipf_dataset(tiny_sparse):
    sp = tiny_sparse
    sched = conflict_free_schedule(np.asarray(sp.rows), np.asarray(sp.cols),
                                   batch=128, seed=0)
    _check_schedule(sp.rows, sp.cols, sched)
    st_ = sched.stats()
    # zipf heads overflow to leftovers, but the bulk must be conflict-free
    assert st_["cf_frac"] > 0.5
    assert st_["n_cf"] + st_["n_lo"] == sp.nnz


def test_assemble_cached_bit_identical(tiny_sparse):
    sp = tiny_sparse
    K = 8
    JK = jnp.asarray(RNG.integers(0, sp.N, (sp.N, K)), jnp.int32)
    cache = model.build_gather_cache(sp, JK, chunk=1000)  # force chunking
    idx = jnp.asarray(RNG.permutation(sp.nnz)[:512], jnp.int32)
    valid = jnp.asarray(RNG.integers(0, 2, 512), bool)
    want = model.assemble(sp, JK, idx, valid)
    got = model.assemble_cached(sp, JK, cache, idx, valid)
    for f in ("i", "j", "r", "nb", "rnb", "expl", "impl", "valid"):
        np.testing.assert_array_equal(np.asarray(getattr(got, f)),
                                      np.asarray(getattr(want, f)), err_msg=f)


def _conflict_free_batch(sp, K, B=64, seed=0):
    """A batch with each row/col at most once, assembled from real triples."""
    rng = np.random.default_rng(seed)
    rows, cols = np.asarray(sp.rows), np.asarray(sp.cols)
    order = rng.permutation(sp.nnz)
    take, ri, ci = [], set(), set()
    for t in order:
        if rows[t] not in ri and cols[t] not in ci:
            take.append(t)
            ri.add(rows[t])
            ci.add(cols[t])
        if len(take) == B:
            break
    idx = jnp.asarray(take, jnp.int32)
    JK = jnp.asarray(rng.integers(0, sp.N, (sp.N, K)), jnp.int32)
    return JK, idx, jnp.ones((len(take),), bool)


def test_conflict_free_step_matches_scaled(tiny_sparse):
    """On a conflict-free batch all collision counts are 1, so the fast
    path must agree with the scaled path exactly."""
    sp = tiny_sparse
    JK, idx, valid = _conflict_free_batch(sp, K=4)
    bt = model.assemble(sp, JK, idx, valid)
    p = model.init_from_data(jax.random.PRNGKey(0), sp, 8, 4)
    hp = sgd.Hyper()
    d = jnp.float32(1.0)
    for step in (sgd.culsh_step, sgd.mf_step):
        fast = step(p, bt, hp, d, conflict_free=True)
        scaled = step(p, bt, hp, d, conflict_free=False)
        for leaf_f, leaf_s in zip(jax.tree.leaves(fast), jax.tree.leaves(scaled)):
            np.testing.assert_allclose(np.asarray(leaf_f), np.asarray(leaf_s),
                                       rtol=1e-6, atol=1e-7)


def test_fused_kernel_matches_culsh_step(tiny_sparse):
    sp = tiny_sparse
    JK, idx, valid = _conflict_free_batch(sp, K=4)
    bt = model.assemble(sp, JK, idx, valid)
    p = model.init_from_data(jax.random.PRNGKey(1), sp, 8, 4)
    hp = sgd.Hyper()
    d = jnp.float32(0.7)
    want = sgd.culsh_step(p, bt, hp, d, conflict_free=True)
    for impl in ("ref", "pallas"):
        got = apply_culsh_sgd(p, bt, hp, d, impl=impl, interpret=True)
        for f in ("b", "bh", "U", "V", "W", "C"):
            np.testing.assert_allclose(
                np.asarray(getattr(got, f)), np.asarray(getattr(want, f)),
                rtol=1e-5, atol=1e-5, err_msg=f"{impl}:{f}")


def test_mf_kernel_matches_mf_step(tiny_sparse):
    sp = tiny_sparse
    JK, idx, valid = _conflict_free_batch(sp, K=4, seed=3)
    bt = model.assemble(sp, JK, idx, valid)
    p = model.init_from_data(jax.random.PRNGKey(2), sp, 8, 4)
    hp = sgd.Hyper()
    d = jnp.float32(1.0)
    want = sgd.mf_step(p, bt, hp, d, conflict_free=True)
    for impl in ("ref", "pallas"):
        got = apply_mf_sgd(p, bt.i, bt.j, bt.r, bt.valid, hp, d,
                           impl=impl, interpret=True)
        np.testing.assert_allclose(np.asarray(got.U), np.asarray(want.U),
                                   rtol=1e-5, atol=1e-6, err_msg=impl)
        np.testing.assert_allclose(np.asarray(got.V), np.asarray(want.V),
                                   rtol=1e-5, atol=1e-6, err_msg=impl)


def test_scheduled_epoch_learns_and_matches_unscheduled(tiny_sparse):
    """train_epoch_scheduled drops the loss like train_epoch does, and the
    kernel path is bit-identical to the jnp scheduled path on CPU."""
    sp = tiny_sparse
    K = 4
    JK = jnp.asarray(RNG.integers(0, sp.N, (sp.N, K)), jnp.int32)
    cache = model.build_gather_cache(sp, JK)
    sched = conflict_free_schedule(np.asarray(sp.rows), np.asarray(sp.cols),
                                   batch=128, seed=0)
    hp = sgd.Hyper()
    p0 = model.init_from_data(jax.random.PRNGKey(0), sp, 8, K)
    copy = lambda p: jax.tree.map(jnp.copy, p)
    key = jax.random.PRNGKey(1)

    def sse(p):
        pred, _ = model.predict(p, model.assemble(
            sp, JK, jnp.arange(sp.nnz, dtype=jnp.int32),
            jnp.ones((sp.nnz,), bool)))
        return float(jnp.mean((sp.vals - pred) ** 2))

    base = sse(p0)
    p1 = p2 = None
    for ep in range(2):
        kk = jax.random.fold_in(key, ep)
        ee = jnp.asarray(ep)
        p1 = sgd.train_epoch_scheduled(copy(p0) if p1 is None else p1,
                                       sp, JK, cache, sched, kk, ee, hp)
        p2 = sgd.train_epoch_scheduled(copy(p0) if p2 is None else p2,
                                       sp, JK, cache, sched, kk, ee, hp,
                                       use_kernels=True, impl="ref")
    assert sse(p1) < base
    for l1, l2 in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                   rtol=1e-5, atol=1e-6)
