"""Tiered conflict-free scheduler + schedule-ordered assembly + parity."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import model, sgd
from repro.data.sparse import conflict_free_schedule, from_coo
from repro.kernels.mf_sgd.ops import apply_culsh_sgd, apply_mf_sgd

RNG = np.random.default_rng(0)


def _batches(sched):
    """Yield (kind, width, ids) for every batch of every tier, decoded
    through the schedule-order layout.  Shard cells live at positions
    [0, shard_span); tier/leftover starts are relative to the cf region
    that follows."""
    order = np.asarray(sched.order)
    span = sched.shard_span

    def window(start, width, valid):
        start = int(start)
        v = np.asarray(valid)
        ids = order[start:start + width]
        return ids[v[:len(ids)]]

    ss = np.asarray(sched.shard_starts)
    for d in range(ss.shape[0]):
        for s in range(ss.shape[1]):
            for r in range(ss.shape[2]):
                yield ("shard", (d, s, r), sched.shard_width,
                       window(ss[d, s, r], sched.shard_width,
                              sched.shard_valid[d, s, r]))
    for t, (starts, valid) in enumerate(zip(sched.tier_starts,
                                            sched.tier_valid)):
        for b in range(starts.shape[0]):
            yield ("tier", t, sched.widths[t],
                   window(span + starts[b], sched.widths[t], valid[b]))
    for b in range(sched.lo_starts.shape[0]):
        yield ("lo", b, sched.widths[0],
               window(span + sched.lo_starts[b], sched.widths[0],
                      sched.lo_valid[b]))


def _check_schedule(rows, cols, sched):
    """order is a permutation; every conflict-free batch is conflict-free;
    all batches together cover each triple exactly once."""
    rows, cols = np.asarray(rows), np.asarray(cols)
    order = np.asarray(sched.order)
    assert sorted(order.tolist()) == list(range(len(rows))), "not a cover"
    seen = 0
    for kind, _, _, ids in _batches(sched):
        seen += len(ids)
        if kind != "lo" and len(ids):
            assert len(np.unique(rows[ids])) == len(ids), "row conflict"
            assert len(np.unique(cols[ids])) == len(ids), "col conflict"
    assert seen == len(rows), "batches don't partition the triples"


@settings(max_examples=10, deadline=None)
@given(st.integers(5, 200), st.integers(3, 60), st.integers(16, 256),
       st.integers(0, 10**6))
def test_schedule_conflict_free_exact_cover(M, N, batch, seed):
    rng = np.random.default_rng(seed)
    nnz = min(M * N, int(rng.integers(1, 4 * (M + N))))
    pairs = rng.choice(M * N, size=nnz, replace=False)
    rows = (pairs // N).astype(np.int32)
    cols = (pairs % N).astype(np.int32)
    sched = conflict_free_schedule(rows, cols, batch=batch, M=M, N=N,
                                   seed=seed)
    _check_schedule(rows, cols, sched)


def test_tier_widths_monotone(tiny_sparse):
    sp = tiny_sparse
    for tiers in (1, 2, 3, 4):
        sched = conflict_free_schedule(
            np.asarray(sp.rows), np.asarray(sp.cols), batch=128,
            tiers=tiers, M=sp.M, N=sp.N, seed=0)
        assert len(sched.widths) == tiers
        assert all(a > b for a, b in zip(sched.widths, sched.widths[1:])), \
            "tier widths must strictly decrease"
        assert all(w == max(1, sched.widths[0] >> t)
                   for t, w in enumerate(sched.widths))
        assert sched.pad_width == sched.widths[0]


def test_schedule_zipf_dataset(tiny_sparse):
    sp = tiny_sparse
    sched = conflict_free_schedule(np.asarray(sp.rows), np.asarray(sp.cols),
                                   batch=128, M=sp.M, N=sp.N, seed=0)
    _check_schedule(sp.rows, sp.cols, sched)
    st_ = sched.stats()
    # tiering recovers the zipf tail: the single-width scheduler managed
    # cf_frac ≈ 0.5–0.6 here, the tiered one must clear the bench floor
    assert st_["cf_frac"] >= 0.8
    assert st_["n_cf"] + st_["n_lo"] == sp.nnz
    # stats are self-describing: every tier + leftover fill reported
    assert len(st_["tiers"]) == len(sched.widths)
    assert 0.0 <= st_["lo_fill"] <= 1.0
    for t in st_["tiers"]:
        assert t["n"] <= t["rounds"] * t["width"]


def test_sharded_schedule_block_aligned(tiny_sparse):
    """Shard-tier batches only touch block ((d+s) % D, d) — the disjointness
    that lets shard_map scan a step's D batches with no collective.  Blocks
    are cut at the (nnz-balanced) row/col bounds and every id remaps into
    a contiguous equal-size block-padded range."""
    sp = tiny_sparse
    D = 4
    sched = conflict_free_schedule(np.asarray(sp.rows), np.asarray(sp.cols),
                                   batch=64, M=sp.M, N=sp.N, shards=D, seed=0)
    _check_schedule(sp.rows, sp.cols, sched)
    rb_bounds = np.asarray(sched.row_bounds)
    cb_bounds = np.asarray(sched.col_bounds)
    assert sched.shards == D and rb_bounds.shape == (D + 1,)
    assert rb_bounds[-1] == sp.M and cb_bounds[-1] == sp.N
    assert sched.block_rows == np.diff(rb_bounds).max()
    rows, cols = np.asarray(sp.rows), np.asarray(sp.cols)
    n_shard = 0
    for kind, key, _, ids in _batches(sched):
        if kind != "shard" or not len(ids):
            continue
        d, s, _ = key
        n_shard += len(ids)
        blk_r = np.searchsorted(rb_bounds, rows[ids], side="right") - 1
        blk_c = np.searchsorted(cb_bounds, cols[ids], side="right") - 1
        assert (blk_r == (d + s) % D).all()
        assert (blk_c == d).all()
    assert n_shard > 0, "shard tier empty on zipf data"
    # the id maps re-lay each block into [d·block, d·block + extent):
    # strictly monotone (order-preserving), block-contiguous, injective
    rm = np.asarray(sched.row_map)
    assert rm.shape == (sp.M,) and (np.diff(rm) > 0).all()
    for d in range(D):
        seg = rm[rb_bounds[d]:rb_bounds[d + 1]]
        assert seg[0] == d * sched.block_rows
        assert seg[-1] < (d + 1) * sched.block_rows


def test_nnz_balanced_blocks_beat_equal_range(tiny_sparse):
    """Equal-nnz block bounds on zipf data: still an exact conflict-free
    cover, and the shard tier schedules more triples at better fill than
    the legacy equal-id-range cut (whose head blocks hog the round budget
    and leave tail-block rounds empty)."""
    sp = tiny_sparse
    rows, cols = np.asarray(sp.rows), np.asarray(sp.cols)
    kw = dict(batch=64, M=sp.M, N=sp.N, shards=4, seed=0)
    bal = conflict_free_schedule(rows, cols, balance_blocks=True, **kw)
    eq = conflict_free_schedule(rows, cols, balance_blocks=False, **kw)
    _check_schedule(rows, cols, bal)
    _check_schedule(rows, cols, eq)
    s_bal, s_eq = bal.stats()["shard"], eq.stats()["shard"]
    assert s_bal["fill"] > s_eq["fill"], (s_bal["fill"], s_eq["fill"])
    # fewer padded rounds = fewer scan steps for the same coverage
    assert s_bal["rounds"] < s_eq["rounds"], (s_bal["rounds"], s_eq["rounds"])
    assert s_bal["n"] >= 0.98 * s_eq["n"], (s_bal["n"], s_eq["n"])
    # balanced cuts strictly shrink the heaviest block's nnz share (full
    # equality is unreachable: extents are floored at the round width so
    # head-cell matchings aren't extent-capped)
    dr = np.bincount(rows, minlength=sp.M)
    heaviest = lambda sched_: max(
        dr[a:b].sum() for a, b in zip(np.asarray(sched_.row_bounds)[:-1],
                                      np.asarray(sched_.row_bounds)[1:]))
    assert heaviest(bal) < heaviest(eq), (heaviest(bal), heaviest(eq))


def test_scheduled_data_matches_assemble(tiny_sparse):
    """slice_batch over ScheduledData == assemble on the same triples."""
    sp = tiny_sparse
    K = 8
    JK = jnp.asarray(RNG.integers(0, sp.N, (sp.N, K)), jnp.int32)
    sched = conflict_free_schedule(np.asarray(sp.rows), np.asarray(sp.cols),
                                   batch=128, M=sp.M, N=sp.N, seed=0)
    sd = model.build_scheduled_data(sp, JK, sched)
    order = jnp.asarray(sched.order)
    for t, (starts, valid) in enumerate(zip(sched.tier_starts,
                                            sched.tier_valid)):
        if not starts.shape[0]:
            continue
        b = int(RNG.integers(0, starts.shape[0]))
        W = sched.widths[t]
        got = model.slice_batch(sd, starts[b], W, valid[b])
        idx = jax.lax.dynamic_slice_in_dim(
            jnp.concatenate([order, jnp.zeros(W, jnp.int32)]), starts[b], W)
        want = model.assemble(sp, JK, idx, valid[b])
        for f in ("i", "j", "r", "nb", "rnb", "expl", "impl"):
            np.testing.assert_array_equal(
                np.asarray(getattr(got, f)) * np.asarray(valid[b]).reshape(
                    (-1,) + (1,) * (getattr(got, f).ndim - 1)),
                np.asarray(getattr(want, f)) * np.asarray(valid[b]).reshape(
                    (-1,) + (1,) * (getattr(want, f).ndim - 1)),
                err_msg=f"tier {t} field {f}")


def test_eval_cache_matches_rmse(tiny_sparse):
    sp = tiny_sparse
    K = 8
    JK = jnp.asarray(RNG.integers(0, sp.N, (sp.N, K)), jnp.int32)
    p = model.init_from_data(jax.random.PRNGKey(0), sp, 8, K)
    n = 700
    te_r = jnp.asarray(RNG.integers(0, sp.M, n), jnp.int32)
    te_c = jnp.asarray(RNG.integers(0, sp.N, n), jnp.int32)
    te_v = jnp.asarray(RNG.uniform(1, 5, n), jnp.float32)
    ec = model.build_eval_cache(sp, JK, te_r, te_c, chunk=256)
    want = float(model.rmse(p, sp, JK, te_r, te_c, te_v))
    got = float(model.rmse_cached(p, ec, te_r, te_c, te_v))
    np.testing.assert_allclose(got, want, rtol=1e-6)
    # mf_only path: zero-width cache, predict_mf only
    ec0 = model.build_eval_cache(sp, JK, te_r, te_c, mf_only=True)
    want0 = float(model.rmse(p, sp, JK, te_r, te_c, te_v, mf_only=True))
    got0 = float(model.rmse_cached(p, ec0, te_r, te_c, te_v, mf_only=True))
    np.testing.assert_allclose(got0, want0, rtol=1e-6)


def _conflict_free_batch(sp, K, B=64, seed=0):
    """A batch with each row/col at most once, assembled from real triples."""
    rng = np.random.default_rng(seed)
    rows, cols = np.asarray(sp.rows), np.asarray(sp.cols)
    order = rng.permutation(sp.nnz)
    take, ri, ci = [], set(), set()
    for t in order:
        if rows[t] not in ri and cols[t] not in ci:
            take.append(t)
            ri.add(rows[t])
            ci.add(cols[t])
        if len(take) == B:
            break
    idx = jnp.asarray(take, jnp.int32)
    JK = jnp.asarray(rng.integers(0, sp.N, (sp.N, K)), jnp.int32)
    return JK, idx, jnp.ones((len(take),), bool)


def test_conflict_free_step_matches_scaled(tiny_sparse):
    """On a conflict-free batch all collision counts are 1, so the fast
    path must agree with the scaled path exactly."""
    sp = tiny_sparse
    JK, idx, valid = _conflict_free_batch(sp, K=4)
    bt = model.assemble(sp, JK, idx, valid)
    p = model.init_from_data(jax.random.PRNGKey(0), sp, 8, 4)
    hp = sgd.Hyper()
    d = jnp.float32(1.0)
    for step in (sgd.culsh_step, sgd.mf_step):
        fast = step(p, bt, hp, d, conflict_free=True)
        scaled = step(p, bt, hp, d, conflict_free=False)
        for leaf_f, leaf_s in zip(jax.tree.leaves(fast), jax.tree.leaves(scaled)):
            np.testing.assert_allclose(np.asarray(leaf_f), np.asarray(leaf_s),
                                       rtol=1e-6, atol=1e-7)


def test_packed_step_bit_identical(tiny_sparse):
    """The packed-plane steps are *bit-identical* to the unpacked
    reference steps — on conflict-free batches, on collision-scaled
    batches, and with the schedule-precomputed collision normalizers."""
    sp = tiny_sparse
    hp = sgd.Hyper()
    d = jnp.float32(0.9)
    # conflict-free batch
    JK, idx, valid = _conflict_free_batch(sp, K=4, seed=11)
    bt = model.assemble(sp, JK, idx, valid)
    p = model.init_from_data(jax.random.PRNGKey(3), sp, 8, 4)
    pp = model.pack_params(p)
    for f in ("U", "V", "b", "bh", "W", "C"):   # pack∘unpack round-trips
        np.testing.assert_array_equal(
            np.asarray(getattr(model.unpack_params(pp), f)),
            np.asarray(getattr(p, f)), err_msg=f"roundtrip:{f}")
    cases = [
        (sgd.culsh_step(p, bt, hp, d, conflict_free=True),
         sgd.culsh_step_packed(pp, bt, hp, d, conflict_free=True), "cf"),
        (sgd.mf_step(p, bt, hp, d, conflict_free=True),
         sgd.mf_step_packed(pp, bt, hp, d, conflict_free=True), "mf"),
    ]
    # collision-ful batch (repeated rows/cols) — the scaled path
    rng = np.random.default_rng(5)
    ridx = jnp.asarray(rng.integers(0, sp.nnz, 96), jnp.int32)
    btc = model.assemble(sp, JK, ridx, jnp.ones((96,), bool))
    cases.append((sgd.culsh_step(p, btc, hp, d, conflict_free=False),
                  sgd.culsh_step_packed(pp, btc, hp, d, conflict_free=False),
                  "scaled"))
    # precomputed normalizers (host 1/count, as in EpochSchedule.lo_scale_*)
    ri, ci = np.asarray(btc.i), np.asarray(btc.j)
    inv_count = lambda ids: jnp.asarray(
        (np.float32(1.0)
         / np.unique(ids, return_counts=True)[1].astype(np.float32)[
             np.unique(ids, return_inverse=True)[1]]))
    cases.append((sgd.culsh_step(p, btc, hp, d, conflict_free=False),
                  sgd.culsh_step_packed(pp, btc, hp, d,
                                        scales=(inv_count(ri),
                                                inv_count(ci))),
                  "precomputed-scales"))
    for want, got_pp, tag in cases:
        got = model.unpack_params(got_pp)
        for f in ("U", "V", "b", "bh", "W", "C"):
            np.testing.assert_array_equal(
                np.asarray(getattr(got, f)), np.asarray(getattr(want, f)),
                err_msg=f"{tag}:{f}")


def test_fused_kernel_matches_culsh_step(tiny_sparse):
    sp = tiny_sparse
    JK, idx, valid = _conflict_free_batch(sp, K=4)
    bt = model.assemble(sp, JK, idx, valid)
    p = model.init_from_data(jax.random.PRNGKey(1), sp, 8, 4)
    pp = model.pack_params(p)
    hp = sgd.Hyper()
    d = jnp.float32(0.7)
    want = sgd.culsh_step(p, bt, hp, d, conflict_free=True)
    for impl in ("ref", "pallas"):
        got = model.unpack_params(
            apply_culsh_sgd(pp, bt, hp, d, impl=impl, interpret=True))
        for f in ("b", "bh", "U", "V", "W", "C"):
            np.testing.assert_allclose(
                np.asarray(getattr(got, f)), np.asarray(getattr(want, f)),
                rtol=1e-5, atol=1e-5, err_msg=f"{impl}:{f}")


def test_kernels_width_generic(tiny_sparse):
    """Every tier width routes through the fused kernels: narrow batches
    (width ≪ tile) stay exact with the tile clamped to the batch."""
    sp = tiny_sparse
    hp = sgd.Hyper()
    d = jnp.float32(1.0)
    for B in (7, 24, 96, 250):
        JK, idx, valid = _conflict_free_batch(sp, K=4, B=B, seed=B)
        bt = model.assemble(sp, JK, idx, valid)
        p = model.init_from_data(jax.random.PRNGKey(B), sp, 8, 4)
        pp = model.pack_params(p)
        want = sgd.culsh_step(p, bt, hp, d, conflict_free=True)
        for impl in ("ref", "pallas"):
            got = model.unpack_params(
                apply_culsh_sgd(pp, bt, hp, d, impl=impl, tile_b=256,
                                interpret=True))
            for f in ("b", "bh", "U", "V", "W", "C"):
                np.testing.assert_allclose(
                    np.asarray(getattr(got, f)), np.asarray(getattr(want, f)),
                    rtol=1e-5, atol=1e-5, err_msg=f"B={B} {impl}:{f}")
        got_mf = model.unpack_params(
            apply_mf_sgd(pp, bt, hp, d, impl="pallas", tile_b=256,
                         interpret=True))
        want_mf = sgd.mf_step(p, bt, hp, d, conflict_free=True)
        np.testing.assert_allclose(np.asarray(got_mf.U), np.asarray(want_mf.U),
                                   rtol=1e-5, atol=1e-6, err_msg=f"B={B} mf")


def test_mf_kernel_matches_mf_step(tiny_sparse):
    sp = tiny_sparse
    JK, idx, valid = _conflict_free_batch(sp, K=4, seed=3)
    bt = model.assemble(sp, JK, idx, valid)
    p = model.init_from_data(jax.random.PRNGKey(2), sp, 8, 4)
    pp = model.pack_params(p)
    hp = sgd.Hyper()
    d = jnp.float32(1.0)
    want = sgd.mf_step(p, bt, hp, d, conflict_free=True)
    for impl in ("ref", "pallas"):
        got = model.unpack_params(
            apply_mf_sgd(pp, bt, hp, d, impl=impl, interpret=True))
        np.testing.assert_allclose(np.asarray(got.U), np.asarray(want.U),
                                   rtol=1e-5, atol=1e-6, err_msg=impl)
        np.testing.assert_allclose(np.asarray(got.V), np.asarray(want.V),
                                   rtol=1e-5, atol=1e-6, err_msg=impl)


def test_scheduled_epoch_learns_and_matches_unscheduled(tiny_sparse):
    """train_epoch_scheduled drops the loss like train_epoch does, and the
    kernel path is bit-identical to the jnp scheduled path on CPU."""
    sp = tiny_sparse
    K = 4
    JK = jnp.asarray(RNG.integers(0, sp.N, (sp.N, K)), jnp.int32)
    sched = conflict_free_schedule(np.asarray(sp.rows), np.asarray(sp.cols),
                                   batch=128, M=sp.M, N=sp.N, seed=0)
    sd = model.build_scheduled_data(sp, JK, sched)
    hp = sgd.Hyper()
    p0 = model.init_from_data(jax.random.PRNGKey(0), sp, 8, K)
    pp0 = model.pack_params(p0)
    copy = lambda p: jax.tree.map(jnp.copy, p)
    key = jax.random.PRNGKey(1)

    def sse(pp):
        pred, _ = model.predict(model.unpack_params(pp), model.assemble(
            sp, JK, jnp.arange(sp.nnz, dtype=jnp.int32),
            jnp.ones((sp.nnz,), bool)))
        return float(jnp.mean((sp.vals - pred) ** 2))

    base = sse(pp0)
    p1 = p2 = None
    for ep in range(2):
        kk = jax.random.fold_in(key, ep)
        ee = jnp.asarray(ep)
        p1 = sgd.train_epoch_scheduled(copy(pp0) if p1 is None else p1,
                                       sd, sched, kk, ee, hp)
        p2 = sgd.train_epoch_scheduled(copy(pp0) if p2 is None else p2,
                                       sd, sched, kk, ee, hp,
                                       use_kernels=True, impl="ref")
    assert sse(p1) < base
    for l1, l2 in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                   rtol=1e-5, atol=1e-6)
