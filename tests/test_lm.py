"""Per-arch smoke tests (reduced configs) + family correctness checks."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base as CB
from repro.models import lm, ssm as SSM, steps

ARCHS = CB.names()


def _batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    b = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
         "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)}
    if cfg.frontend == "embed_stub":
        P = S if cfg.family == "encdec" else 8
        b["frontend_embeds"] = jnp.asarray(
            rng.normal(0, 0.02, (B, P, cfg.d_model)).astype(np.float32))
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_forward_train(arch):
    cfg = CB.reduced(CB.get(arch))
    p = lm.init_params(cfg, jax.random.PRNGKey(0), model_shards=1)
    b = _batch(cfg)
    h = lm.forward(cfg, p, b)
    S_expect = b["tokens"].shape[1] + (
        b["frontend_embeds"].shape[1]
        if cfg.frontend == "embed_stub" and cfg.family != "encdec" else 0)
    assert h.shape == (2, S_expect, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(h.astype(jnp.float32))))

    ts = steps.make_train_step(cfg)
    opt = steps.init_opt(cfg, p)
    p2, opt2, aux = jax.jit(ts)(p, opt, b)
    assert bool(jnp.isfinite(aux["loss"]))
    # params actually moved
    moved = jax.tree.reduce(
        lambda a, x: a + float(jnp.sum(jnp.abs(x[0] - x[1]))),
        jax.tree.map(lambda a, b_: (a, b_), p, p2), 0.0)
    assert moved > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_decode(arch):
    cfg = CB.reduced(CB.get(arch))
    p = lm.init_params(cfg, jax.random.PRNGKey(0), model_shards=1)
    cache = steps.init_cache(cfg, 2, 16)
    dec = jax.jit(steps.make_decode_step(cfg))
    logits, cache2 = dec(p, cache, jnp.ones((2, 1), jnp.int32))
    assert logits.shape[0] == 2 and logits.shape[-1] == cfg.vocab_padded(1)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert int(cache2["pos"]) == 1


def test_dense_decode_matches_forward():
    """Token-by-token decode logits == full forward logits (cache logic)."""
    cfg = CB.reduced(CB.get("llama3-8b"))
    p = lm.init_params(cfg, jax.random.PRNGKey(0), model_shards=1)
    rng = np.random.default_rng(0)
    B, S = 2, 12
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    h = lm.forward(cfg, p, {"tokens": toks})
    E = lm.out_embedding(p, cfg)
    full_logits = jnp.einsum("bsd,vd->bsv", h, E.astype(cfg.dtype),
                             preferred_element_type=jnp.float32)
    dec = jax.jit(steps.make_decode_step(cfg))
    cache = steps.init_cache(cfg, B, S)
    outs = []
    for t in range(S):
        lg, cache = dec(p, cache, toks[:, t:t + 1])
        outs.append(lg[:, 0])
    got = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full_logits),
                               rtol=3e-2, atol=3e-2)


def test_ssm_decode_matches_forward():
    """SSD chunked scan == one-token recurrence (strong Mamba2 check)."""
    cfg = dataclasses.replace(CB.reduced(CB.get("mamba2-370m")), L=2)
    p = lm.init_params(cfg, jax.random.PRNGKey(0), model_shards=1)
    rng = np.random.default_rng(0)
    B, S = 2, 16
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    h = lm.forward(cfg, p, {"tokens": toks})
    E = lm.out_embedding(p, cfg)
    full_logits = jnp.einsum("bsd,vd->bsv", h, E.astype(cfg.dtype),
                             preferred_element_type=jnp.float32)
    dec = jax.jit(steps.make_decode_step(cfg))
    cache = steps.init_cache(cfg, B, S)
    outs = []
    for t in range(S):
        lg, cache = dec(p, cache, toks[:, t:t + 1])
        outs.append(lg[:, 0])
    got = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full_logits),
                               rtol=4e-2, atol=4e-2)


def test_ssd_chunk_invariance():
    """ssd_chunked must not depend on the chunk size."""
    rng = np.random.default_rng(0)
    B, S, H, Pd, N = 2, 32, 4, 8, 8
    xs = jnp.asarray(rng.normal(size=(B, S, H, Pd)).astype(np.float32))
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (B, S, H)).astype(np.float32))
    A = -jnp.asarray(rng.uniform(0.1, 1.0, (H,)).astype(np.float32))
    B_ = jnp.asarray(rng.normal(size=(B, S, N)).astype(np.float32))
    C_ = jnp.asarray(rng.normal(size=(B, S, N)).astype(np.float32))
    D = jnp.asarray(rng.normal(size=(H,)).astype(np.float32))
    y1 = SSM.ssd_chunked(xs, dt, A, B_, C_, D, chunk=8)
    y2 = SSM.ssd_chunked(xs, dt, A, B_, C_, D, chunk=32)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)


def test_ssd_matches_naive_recurrence():
    rng = np.random.default_rng(1)
    B, S, H, Pd, N = 1, 12, 2, 4, 4
    xs = rng.normal(size=(B, S, H, Pd)).astype(np.float32)
    dt = rng.uniform(0.01, 0.3, (B, S, H)).astype(np.float32)
    A = -rng.uniform(0.1, 1.0, (H,)).astype(np.float32)
    B_ = rng.normal(size=(B, S, N)).astype(np.float32)
    C_ = rng.normal(size=(B, S, N)).astype(np.float32)
    D = rng.normal(size=(H,)).astype(np.float32)
    got = np.asarray(SSM.ssd_chunked(*(jnp.asarray(a) for a in
                                       (xs, dt, A, B_, C_, D)), chunk=4))
    # naive: h_t = exp(dt·A) h_{t−1} + dt·(B_t ⊗ x_t);  y = C_t·h_t + D·x
    state = np.zeros((B, H, Pd, N))
    want = np.zeros_like(xs)
    for t in range(S):
        dA = np.exp(dt[:, t] * A[None])                       # [B,H]
        upd = np.einsum("bh,bn,bhp->bhpn", dt[:, t], B_[:, t], xs[:, t])
        state = state * dA[:, :, None, None] + upd
        want[:, t] = np.einsum("bn,bhpn->bhp", C_[:, t], state) \
            + xs[:, t] * D[None, :, None]
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_lsh_softmax_loss_close_to_full():
    """With candidates ∪ label covering the distribution mass, the LSH
    softmax loss approximates the full loss from above (subset LSE ≤ LSE)."""
    cfg = dataclasses.replace(CB.reduced(CB.get("qwen3-0.6b")),
                              lsh_softmax=True)
    p = lm.init_params(cfg, jax.random.PRNGKey(0), model_shards=1)
    b = _batch(cfg, S=16)
    V = cfg.vocab_padded(1)
    b["cands"] = jnp.arange(V, dtype=jnp.int32)       # full cover
    loss_lsh = steps.lm_loss(cfg, p, b)
    cfg_full = dataclasses.replace(cfg, lsh_softmax=False)
    loss_full = steps.lm_loss(cfg_full, p, {k: v for k, v in b.items()
                                            if k != "cands"})
    assert abs(float(loss_lsh) - float(loss_full)) < 1e-3
    # subset candidates lower-bound the partition function
    b["cands"] = jnp.arange(64, dtype=jnp.int32)
    assert float(steps.lm_loss(cfg, p, b)) <= float(loss_full) + 1e-4


def test_straggler_drop_microbatch():
    """mb_mask drops a microbatch; surviving grads renormalize (bounded
    staleness straggler mitigation, DESIGN.md §5)."""
    cfg = dataclasses.replace(CB.reduced(CB.get("llama3-8b")),
                              microbatches=2)
    p = lm.init_params(cfg, jax.random.PRNGKey(0), model_shards=1)
    opt = steps.init_opt(cfg, p)
    b = _batch(cfg, B=4, S=16)
    ts = jax.jit(steps.make_train_step(cfg))
    # full batch vs first-µbatch-only
    _, _, aux_full = ts(p, opt, dict(b))
    mask = jnp.asarray([1.0, 0.0])
    p2, _, aux_drop = ts(p, opt, dict(b) | {"mb_mask": mask})
    # dropped run's loss equals the loss of µbatch 0 alone
    cfg1 = dataclasses.replace(cfg, microbatches=1)
    b0 = {k: v[:2] for k, v in b.items()}
    loss0 = steps.lm_loss(cfg1, p, b0)
    np.testing.assert_allclose(float(aux_drop["loss"]), float(loss0),
                               rtol=1e-4)
    assert bool(jnp.isfinite(aux_drop["loss"]))


def test_lsh_softmax_candidates():
    """simLSH over embedding rows: duplicate rows are mutual bucket-mates,
    and candidates_for includes the labels' neighbours."""
    from repro.models import lsh_softmax as LS
    rng = np.random.default_rng(0)
    V, D = 64, 32
    E = rng.normal(size=(V, D)).astype(np.float32)
    E[32:] = E[:32]                       # duplicate rows
    st = LS.refresh(jnp.asarray(E), jax.random.PRNGKey(0), K=4)
    dup_found = jnp.mean((st.nbrs[:32] == (jnp.arange(32)[:, None] + 32))
                         .any(axis=1).astype(jnp.float32))
    assert float(dup_found) > 0.9
    labels = jnp.asarray([[1, 2], [3, 4]], jnp.int32)
    cands = LS.candidates_for(st, labels, jax.random.PRNGKey(1), n_cands=32)
    assert cands.shape == (32,)
    # every label's top bucket-mate is in the candidate set
    mates = np.asarray(st.nbrs[labels.reshape(-1)][:, 0])
    assert np.isin(mates, np.asarray(cands)).mean() > 0.5
