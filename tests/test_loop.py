"""Fast unit tests for the always-on loop (ISSUE 10).

The slice state machine's *scheduling* contracts — backpressure steals
micro-epoch budget, lag triggers publishes, failed slices freeze instead
of dying, the watchdog catches stalls, poison ΔΩ is quarantined before
logging — on a tiny state.  The crash/recover contracts (kill at every
fault site → bit-identical resume) live in tests/test_resil.py, marked
slow with the rest of the chaos suite.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core import online, simlsh, topk
from repro.core.model import init_from_data
from repro.core.sgd import Hyper
from repro.data import synthetic as syn
from repro.data.sparse import from_coo
from repro.loop import LoopConfig, OnlineLoop
from repro.resil import FaultSpec, OnlineUpdater, faults, wal
from repro.serve.service import ServeConfig, ShardedIngestUnsupported

SERVE = ServeConfig(topn=5, micro_batch=8, C=16, n_seeds=2, cap=4,
                    n_popular=8)
CFG = LoopConfig(serve_flushes=1, micro_epochs=1, micro_batch=256,
                 deltas_per_slice=2, backpressure_queue=2, max_lag=1,
                 ckpt_every=0, drift_every=0, watchdog_s=0.0,
                 freeze_slices=2, tail_cap=8, seed=0)


@pytest.fixture(autouse=True)
def _no_leaked_plan():
    yield
    faults.uninstall()


@pytest.fixture(scope="module")
def tiny_state():
    spec = dataclasses.replace(syn.MOVIELENS_LIKE, M=80, N=40, nnz=1200)
    rows, cols, vals, _ = syn.generate(spec, seed=0)
    sp = from_coo(rows, cols, vals, (spec.M, spec.N))
    lsh = simlsh.SimLSHConfig(G=4, p=1, q=4)
    key = jax.random.PRNGKey(0)
    sigs, S = simlsh.encode(sp, lsh, key, return_accumulators=True)
    JK = topk.topk_from_signatures(sigs, jax.random.PRNGKey(1), K=4,
                                   band_cap=lsh.band_cap)
    params = init_from_data(jax.random.PRNGKey(2), sp, 8, 4)
    st = online.OnlineState(params=params, S=S, JK=JK, sp=sp,
                            M=spec.M, N=spec.N, hash_key=key)
    return st, lsh


def _loop(tmp_path, tiny_state, cfg=CFG, **up_kw):
    st0, lsh = tiny_state
    up = OnlineUpdater(st0, lsh, Hyper(), root=str(tmp_path), K=4,
                       epochs=1, batch=256, **up_kw)
    svc = OnlineLoop.build_service(st0, SERVE, tail_cap=cfg.tail_cap)
    return OnlineLoop(up, svc, cfg)


def _delta(st, M_new, N_new, seed, n=120):
    rng = np.random.default_rng(seed)
    nr = rng.integers(0, M_new, n).astype(np.int32)
    nc = rng.integers(0, N_new, n).astype(np.int32)
    pair = np.unique(nr.astype(np.int64) * N_new + nc)
    old = set((np.asarray(st.sp.rows).astype(np.int64) * N_new
               + np.asarray(st.sp.cols)).tolist())
    pair = np.asarray([p for p in pair.tolist() if p not in old])
    return ((pair // N_new).astype(np.int32),
            (pair % N_new).astype(np.int32),
            rng.uniform(1, 5, pair.shape[0]).astype(np.float32))


def _offer(loop, seed, grow=(4, 2)):
    M, N = loop.state.M + grow[0], loop.state.N + grow[1]
    nr, nc, nv = _delta(loop.state, M, N, seed=seed)
    loop.offer_delta(nr, nc, nv, np.asarray(jax.random.PRNGKey(seed)),
                     M_new=M, N_new=N)
    return M, N


def test_loop_trains_and_publishes_on_lag(tiny_state, tmp_path):
    loop = _loop(tmp_path, tiny_state)
    M, N = _offer(loop, seed=10)
    loop.svc.submit(np.arange(8, dtype=np.int32))
    loop.run_slice()
    # max_lag=1: the slice's mutation was published within the slice
    assert int(loop.obs.counter("loop.publishes")) == 1
    assert int(loop.svc.params.U.shape[0]) == M
    assert int(loop.obs.counter("online.micro_epochs")) == 1
    assert loop.updater.seq == 1 and loop.slice_count == 1
    assert loop.staleness_s() == 0.0
    st = loop.svc.stats()
    assert st["users"] == 8 and st["dropped"] == 0


def test_loop_backpressure_steals_micro_epoch_budget(tiny_state, tmp_path):
    loop = _loop(tmp_path, tiny_state)
    for i in range(3):                      # depth 3 ≥ backpressure_queue 2
        _offer(loop, seed=20 + i)
    loop.run_slice()
    # the slice drained ΔΩ (deltas_per_slice=2) but skipped training
    assert int(loop.obs.counter("online.micro_epochs")) == 0
    assert int(loop.obs.counter("online.updates")) == 2
    loop.run_slice()                        # queue is shallow again → train
    assert int(loop.obs.counter("online.micro_epochs")) == 1


def test_loop_degrades_to_frozen_serving_on_fault(tiny_state, tmp_path):
    loop = _loop(tmp_path, tiny_state)
    loop.svc.submit(np.arange(8, dtype=np.int32))
    with faults.injected({"loop.slice": FaultSpec(at_calls=(1,))}):
        loop.run(3, degrade=True)           # slice 1 dies → freeze
    assert int(loop.obs.counter("loop.slice_failures")) == 1
    assert int(loop.obs.counter("loop.freezes")) == 1
    assert loop.slice_count == 2            # the failed slice didn't count
    st = loop.svc.stats()
    assert st["users"] == 8 and st["dropped"] == 0
    # the freeze expires and training resumes
    _offer(loop, seed=30)
    loop.run(3, degrade=True)
    assert int(loop.obs.counter("online.micro_epochs")) >= 1


def test_loop_watchdog_trips_on_stalled_slice(tiny_state, tmp_path):
    cfg = dataclasses.replace(CFG, watchdog_s=0.005)
    loop = _loop(tmp_path, tiny_state, cfg=cfg)
    with faults.injected({"loop.slice": FaultSpec(
            kind="stall", stall_s=0.05, at_calls=(0,))}):
        loop.run_slice()
    assert int(loop.obs.counter("loop.watchdog_trips")) == 1
    assert loop._frozen > 0


def test_loop_quarantines_poison_delta_before_logging(tiny_state, tmp_path):
    loop = _loop(tmp_path, tiny_state)
    st0 = loop.state
    nr = np.array([1, 2], np.int32)
    loop.offer_delta(nr, nr, np.array([np.nan, 1.0], np.float32),
                     np.asarray(jax.random.PRNGKey(0)),
                     M_new=st0.M, N_new=st0.N)
    loop.run_slice()
    assert int(loop.obs.counter("loop.quarantined")) == 1
    assert loop.state.M == st0.M            # the poison never applied …
    entries = loop.updater.wal.entries(after=0)
    assert all(e.meta["n_deltas"] == 0 for e in entries)  # … nor logged


def test_flush_some_bounds_dispatches(tiny_state, tmp_path):
    loop = _loop(tmp_path, tiny_state)
    svc = loop.svc
    svc.submit(np.arange(4, dtype=np.int32))   # below micro_batch: queued
    assert svc.stats()["queue"] == 4
    assert svc.flush_some(2) == 1              # one padded partial dispatch
    assert svc.flush_some(2) == 0              # nothing left pending
    assert svc.stats()["queue"] == 0
    assert svc.stats()["users"] == 4


def test_loop_refuses_sharded_service_and_typed_ingest_error(
        tiny_state, tmp_path):
    loop = _loop(tmp_path, tiny_state)
    svc, st0 = loop.svc, loop.state
    svc._shard_state = (None, None, None, 2)         # pose as the D=2 tier
    with pytest.raises(ValueError, match="single-device"):
        OnlineLoop(loop.updater, svc, CFG)
    with pytest.raises(ShardedIngestUnsupported):
        svc.ingest_online_update(st0, N_old=st0.N)
    with pytest.raises(ShardedIngestUnsupported):
        svc.request_rebuild(simlsh.pack_bits(st0.S >= 0))
    assert svc.stats()["ingest_rejected"] == 2


def test_online_updater_recover_refuses_loop_entries(tiny_state, tmp_path):
    st0, lsh = tiny_state
    loop = _loop(tmp_path, tiny_state)
    _offer(loop, seed=40)
    loop.run_slice()                        # writes one kind="slice" entry
    with pytest.raises(ValueError, match="OnlineLoop.recover"):
        OnlineUpdater.recover(str(tmp_path), lsh, Hyper(), K=4, epochs=1,
                              batch=256, base_state=st0)


def test_loop_checkpoint_carries_cursors(tiny_state, tmp_path):
    cfg = dataclasses.replace(CFG, ckpt_every=1)
    loop = _loop(tmp_path, tiny_state, cfg=cfg)
    _offer(loop, seed=50)
    loop.run_slice()
    assert int(loop.obs.counter("loop.ckpts")) == 1
    assert loop.updater.wal.seqs() == []    # pruned up to the cut
    st0, lsh = tiny_state
    rec = OnlineLoop.recover(str(tmp_path), lsh, Hyper(), SERVE, K=4,
                             epochs=1, batch=256, cfg=cfg)
    assert rec.slice_count == 1 and rec._micro == 1
    for k, a in wal.state_tree(loop.state).items():
        b = wal.state_tree(rec.state)[k]
        assert np.array_equal(np.asarray(a), np.asarray(b)), k
