"""Chaos suite for the resilience layer (ISSUE 7).

Every scenario injects a deterministic fault (`repro.resil.faults`) and
asserts the always-on contract:

  * a corrupt or failed index rebuild is NEVER swapped in (serving rolls
    back to index v by default);
  * under overload the service SHEDS (degraded popularity answers, in
    submission order) instead of stalling;
  * a crash mid-ingest replays from the WAL to a state bit-identical to
    the uninterrupted run;
  * a diverged online update is rolled back, and the rollback is
    replay-stable;
  * a crash mid-checkpoint never corrupts the newest complete step.
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.resil as resil
from repro.core import online, simlsh, topk
from repro.core.model import init_from_data
from repro.core.sgd import Hyper
from repro.data import synthetic as syn
from repro.data.sparse import from_coo
from repro.resil import faults, wal
from repro.resil.validate import check_accumulators, check_ids
from repro.serve import build_index, insert, lookup_signatures
from repro.serve.service import RecsysService, ServeConfig
from repro.train import checkpoint

# chaos / subprocess-heavy: CI splits these into their own step
pytestmark = pytest.mark.slow

SENTINEL = topk.SENTINEL


@pytest.fixture(autouse=True)
def _no_leaked_plan():
    """A failing chaos test must not poison the next one."""
    yield
    faults.uninstall()


# ---------------------------------------------------------------- faults

def test_fault_plan_is_deterministic_and_counts():
    spec = resil.FaultSpec(kind="exc", at_calls=(1,), rate=0.25)
    seqs = []
    for _ in range(2):
        plan = resil.FaultPlan({"site": spec}, seed=7)
        hits = []
        for i in range(40):
            try:
                plan.fire("site")
                hits.append(0)
            except resil.InjectedFault:
                hits.append(1)
        seqs.append(hits)
    assert seqs[0] == seqs[1], "same seed must give the same fault sequence"
    assert seqs[0][1] == 1, "at_calls=(1,) must fire on the second call"
    assert 1 <= sum(seqs[0]) < 40
    plan = resil.FaultPlan({"site": spec}, seed=7)
    for _ in range(3):
        try:
            plan.fire("site")
        except resil.InjectedFault:
            pass
    assert plan.calls["site"] == 3 and plan.fired["site"] >= 1


def test_injected_context_never_leaks_and_refuses_stacking():
    with faults.injected({"x": resil.FaultSpec(at_calls=(0,))}):
        assert faults.active() is not None
        with pytest.raises(RuntimeError, match="already installed"):
            faults.install(resil.FaultPlan({}))
    assert faults.active() is None
    assert faults.fire("x", payload=41) == 41   # no plan → pass-through


def test_fault_kinds_corrupt_and_stall():
    with faults.injected({"c": resil.FaultSpec(kind="corrupt",
                                               mutate=lambda p: p + 1,
                                               at_calls=(0,)),
                          "s": resil.FaultSpec(kind="stall", stall_s=0.02,
                                               at_calls=(0,))}):
        assert faults.fire("c", payload=1) == 2
        t0 = time.perf_counter()
        faults.fire("s")
        assert time.perf_counter() - t0 >= 0.02


# ---------------------------------------------------------------- validate

def test_check_ids_rejects_poison():
    with pytest.raises(resil.PoisonBatchError, match="NaN"):
        check_ids(np.array([1.0, np.nan]), what="t")
    with pytest.raises(resil.PoisonBatchError, match="negative"):
        check_ids(np.array([3, -1]), what="t")
    with pytest.raises(resil.PoisonBatchError, match="2\\^30"):
        check_ids(np.array([1 << 30]), what="t")
    with pytest.raises(resil.PoisonBatchError, match="out of range"):
        check_ids(np.array([5]), what="t", upper=5)
    assert check_ids(np.array([0, 4], np.int32), what="t").dtype == np.int32


def test_check_delta_rejects_poison():
    ok = dict(M_new=10, N_new=10, M_old=8, N_old=8)
    r = np.array([1, 2], np.int32)
    with pytest.raises(resil.PoisonBatchError, match="non-finite"):
        resil.check_delta(r, r, np.array([1.0, np.inf], np.float32), **ok)
    with pytest.raises(resil.PoisonBatchError, match="shrink"):
        resil.check_delta(r, r, np.ones(2, np.float32),
                          M_new=4, N_new=10, M_old=8, N_old=8)
    with pytest.raises(resil.PoisonBatchError, match="equal-length"):
        resil.check_delta(r, r[:1], np.ones(2, np.float32), **ok)
    with pytest.raises(resil.PoisonBatchError, match="empty"):
        resil.check_delta(r[:0], r[:0], np.ones(0, np.float32), **ok)


def test_check_accumulators_names_poisoned_column():
    S = np.zeros((2, 6, 4), np.float32)
    S[:, 0, :] = np.nan
    check_accumulators(S, N_old=5)          # old columns: not our problem
    S[0, 4, 1] = np.nan
    with pytest.raises(resil.PoisonBatchError, match="column 4"):
        check_accumulators(S, N_old=3)


@pytest.fixture(scope="module")
def small_index():
    rng = np.random.default_rng(0)
    rows = np.repeat(np.arange(60), 4).astype(np.int32)
    cols = rng.integers(0, 40, 240).astype(np.int32)
    vals = rng.integers(1, 6, 240).astype(np.float32)
    sp = from_coo(rows, cols, vals, (60, 40))
    cfg = simlsh.SimLSHConfig(G=8, p=2, q=8)
    sigs = simlsh.encode(sp, cfg, jax.random.PRNGKey(0))
    return sp, cfg, sigs, build_index(sigs, tail_cap=8)


def test_validate_index_passes_good_and_catches_corruption(small_index):
    _, _, sigs, index = small_index
    assert resil.validate_index(index) == []
    # corrupt one band's permutation → caught structurally
    bad = dataclasses.replace(
        index, sorted_ids=index.sorted_ids.at[0, 0].set(index.sorted_ids[0, 1]))
    object.__setattr__(bad, "_tail_host", 0)
    assert any("permutation" in p for p in resil.validate_index(bad))
    # corrupt bucket offsets → caught against searchsorted reference
    bad2 = dataclasses.replace(
        index, bucket_hi=index.bucket_hi.at[2].set(0))
    object.__setattr__(bad2, "_tail_host", 0)
    assert any("bucket" in p for p in resil.validate_index(bad2))
    # shuffled signatures → not ascending
    bad3 = dataclasses.replace(
        index, sorted_sigs=index.sorted_sigs[:, ::-1])
    object.__setattr__(bad3, "_tail_host", 0)
    assert any("ascending" in p for p in resil.validate_index(bad3))


def test_index_build_and_insert_reject_poison(small_index):
    _, _, sigs, index = small_index
    with pytest.raises(TypeError, match="int32"):
        build_index(jnp.asarray(np.zeros((8, 4), np.float32)))
    with pytest.raises(resil.PoisonBatchError, match="negative"):
        insert(index, np.asarray(sigs)[:, :1], np.array([-3]))
    with pytest.raises(TypeError, match="int32"):
        insert(index, np.zeros((8, 1), np.float32), np.array([40]))


# ---------------------------------------------------------------- rebuild

def test_rebuilder_validates_then_swaps(small_index):
    _, _, sigs, _ = small_index
    rb = resil.IndexRebuilder()
    assert rb.submit(sigs, tail_cap=8)
    rb.join(60)
    status, idx, err = rb.take()
    assert status == "ready" and err is None
    assert idx.n_base == sigs.shape[1] and idx.tail_fill == 0
    # handed over exactly once
    assert rb.take()[0] == "idle"


def test_rebuilder_failed_build_is_never_handed_over(small_index):
    _, _, sigs, _ = small_index
    rb = resil.IndexRebuilder()
    with faults.injected({"serve.rebuild": resil.FaultSpec(at_calls=(0,))}):
        rb.submit(sigs, tail_cap=8)
        rb.join(60)
    status, idx, err = rb.take()
    assert status == "failed" and idx is None
    assert isinstance(err, resil.InjectedFault)
    assert rb.failures == 1


def test_rebuilder_rejects_corrupt_build(small_index):
    _, _, sigs, _ = small_index

    def corrupt(idx):
        bad = dataclasses.replace(
            idx, sorted_ids=idx.sorted_ids.at[0, 0].set(idx.sorted_ids[0, 1]))
        object.__setattr__(bad, "_tail_host", 0)
        return bad

    rb = resil.IndexRebuilder()
    with faults.injected({"serve.rebuild.index":
                          resil.FaultSpec(kind="corrupt", mutate=corrupt,
                                          at_calls=(0,))}):
        rb.submit(sigs, tail_cap=8)
        rb.join(60)
    status, idx, err = rb.take()
    assert status == "failed" and idx is None
    assert isinstance(err, resil.IndexValidationError)


def test_rebuilder_latest_submission_wins(small_index):
    _, _, sigs, _ = small_index
    rb = resil.IndexRebuilder()
    with faults.injected({"serve.rebuild":
                          resil.FaultSpec(kind="stall", stall_s=0.2,
                                          at_calls=(0,))}):
        assert rb.submit(sigs, tail_cap=8)
        # staged while busy: only the newest survives
        assert not rb.submit(sigs[:, :10], tail_cap=8)
        assert not rb.submit(sigs[:, :20], tail_cap=8)
        rb.join(60)
        status, idx, _ = rb.take()      # first build + restart of staged
        assert status == "ready" and idx.n_base == sigs.shape[1]
        rb.join(60)
    status, idx, _ = rb.take()
    assert status == "ready" and idx.n_base == 20   # latest staged won


# ---------------------------------------------------------------- checkpoint

@pytest.mark.filterwarnings(
    "ignore::pytest.PytestUnhandledThreadExceptionWarning")
def test_checkpoint_crash_mid_save_never_corrupts(tmp_path):
    """The injected crash kills the save thread after the shard but before
    the manifest — the dangling thread exception is the simulated crash,
    hence the filterwarnings."""
    d = str(tmp_path)
    tree = {"a": np.arange(6).reshape(2, 3), "b": np.float32(1.5)}
    checkpoint.save(d, tree, step=1, sync=True)
    with faults.injected({"ckpt.save": resil.FaultSpec(at_calls=(0,))}):
        checkpoint.save(d, tree, step=2, sync=True)   # dies before manifest
    assert checkpoint.latest_step(d) == 1
    restored, step = checkpoint.restore(d, tree)
    assert step == 1
    np.testing.assert_array_equal(np.asarray(restored["a"]), tree["a"])
    # the next save cleans the crash remnant and commits normally
    checkpoint.save(d, tree, step=3, sync=True)
    assert checkpoint.latest_step(d) == 3
    assert not [f for f in __import__("os").listdir(d)
                if f.startswith(".tmp-")]


def test_checkpoint_torn_step_is_skipped_not_raised(tmp_path):
    import os
    d = str(tmp_path)
    tree = {"a": np.arange(4), "b": np.ones((2, 2))}
    checkpoint.save(d, tree, step=1, sync=True)
    checkpoint.save(d, tree, step=2, sync=True)
    os.remove(os.path.join(d, "step-00000002", "manifest.json"))   # torn
    assert checkpoint.latest_step(d) == 1
    _, step = checkpoint.restore(d, tree)
    assert step == 1
    with pytest.raises(FileNotFoundError, match="torn"):
        checkpoint.restore(d, tree, step=2)
    # a truncated shard is torn too, even with a manifest present
    shard = os.path.join(d, "step-00000001",
                         f"shard-{jax.process_index()}.npz")
    with open(shard, "wb") as f:
        f.write(b"\x00\x01")
    assert checkpoint.latest_step(d) is None
    assert checkpoint.try_restore(d, tree) is None


# ---------------------------------------------------------------- WAL

@pytest.fixture(scope="module")
def online_state():
    spec = dataclasses.replace(syn.MOVIELENS_LIKE, M=120, N=50, nnz=2000)
    rows, cols, vals, _ = syn.generate(spec, seed=0)
    sp = from_coo(rows, cols, vals, (spec.M, spec.N))
    cfg = simlsh.SimLSHConfig(G=8, p=1, q=6)
    key = jax.random.PRNGKey(0)
    sigs, S = simlsh.encode(sp, cfg, key, return_accumulators=True)
    JK = topk.topk_from_signatures(sigs, jax.random.PRNGKey(1), K=8,
                                   band_cap=cfg.band_cap)
    params = init_from_data(jax.random.PRNGKey(2), sp, 16, 8)
    st = online.OnlineState(params=params, S=S, JK=JK, sp=sp,
                            M=spec.M, N=spec.N, hash_key=key)
    return st, cfg


def _delta(st, M_new, N_new, seed, n=250):
    rng = np.random.default_rng(seed)
    nr = rng.integers(0, M_new, n).astype(np.int32)
    nc = rng.integers(0, N_new, n).astype(np.int32)
    pair = np.unique(nr.astype(np.int64) * N_new + nc)
    old = set((np.asarray(st.sp.rows).astype(np.int64) * N_new
               + np.asarray(st.sp.cols)).tolist())
    pair = np.asarray([p for p in pair.tolist() if p not in old])
    nr = (pair // N_new).astype(np.int32)
    nc = (pair % N_new).astype(np.int32)
    nv = rng.uniform(1, 5, nr.shape[0]).astype(np.float32)
    return nr, nc, nv


def _assert_states_bit_identical(a, b):
    ta, tb = wal.state_tree(a), wal.state_tree(b)
    for k in ta:
        xa, xb = np.asarray(ta[k]), np.asarray(tb[k])
        assert xa.dtype == xb.dtype and np.array_equal(xa, xb), k


def test_wal_crash_mid_ingest_replays_bit_identical(online_state, tmp_path):
    st0, cfg = online_state
    hp = Hyper()
    up = wal.OnlineUpdater(st0, cfg, hp, root=str(tmp_path), K=8,
                           epochs=1, ckpt_every=2)
    M, N = st0.M, st0.N
    for i in range(2):          # one checkpointed, one WAL-only
        M, N = M + 6, N + 3
        nr, nc, nv = _delta(up.state, M, N, seed=100 + i)
        up.update(nr, nc, nv, jax.random.PRNGKey(50 + i), M_new=M, N_new=N)
    # crash between WAL append and the in-memory apply
    M2, N2 = M + 4, N + 2
    nr, nc, nv = _delta(up.state, M2, N2, seed=200)
    pre_crash = up.state
    with faults.injected({"online.update": resil.FaultSpec(at_calls=(0,))}):
        with pytest.raises(resil.InjectedFault):
            up.update(nr, nc, nv, jax.random.PRNGKey(99),
                      M_new=M2, N_new=N2)
    # recovery = newest complete checkpoint + full WAL replay; the logged
    # entry of the crashed update completes it, so the result is
    # bit-identical to the run that never crashed
    rec = wal.OnlineUpdater.recover(str(tmp_path), cfg, hp, K=8, epochs=1,
                                    ckpt_every=2)
    ref = online.online_update(pre_crash, nr, nc, nv, cfg, hp,
                               jax.random.PRNGKey(99), M_new=M2, N_new=N2,
                               K=8, epochs=1)
    assert rec.seq == 3
    _assert_states_bit_identical(rec.state, ref)


def test_wal_refuses_poison_before_logging(online_state, tmp_path):
    st0, cfg = online_state
    up = wal.OnlineUpdater(st0, cfg, Hyper(), root=str(tmp_path), K=8,
                           epochs=1)
    nr = np.array([1, 2], np.int32)
    with pytest.raises(resil.PoisonBatchError):
        up.update(nr, nr, np.array([np.nan, 1.0], np.float32),
                  jax.random.PRNGKey(0), M_new=st0.M, N_new=st0.N)
    assert up.wal.seqs() == []      # the redo log never saw the batch
    assert up.seq == 0 and up.state is st0


def test_wal_divergence_rollback_is_replay_stable(online_state, tmp_path):
    st0, cfg = online_state
    hp = Hyper()
    guard = resil.GuardConfig(max_ratio=1e-9)   # trips on any real update
    up = wal.OnlineUpdater(st0, cfg, hp, root=str(tmp_path), K=8,
                           epochs=1, guard=guard)
    M2, N2 = st0.M + 6, st0.N + 3
    nr, nc, nv = _delta(st0, M2, N2, seed=5)
    with pytest.raises(resil.DivergenceError):
        up.update(nr, nc, nv, jax.random.PRNGKey(0), M_new=M2, N_new=N2)
    assert up.state is st0          # rollback = keep what you had
    assert up.seq == 1              # but the entry is logged
    rec = wal.OnlineUpdater.recover(str(tmp_path), cfg, hp, K=8, epochs=1,
                                    base_state=st0, guard=guard)
    assert rec.seq == 1             # replay re-trips and stays rejected
    _assert_states_bit_identical(rec.state, st0)


def test_wal_recover_refuses_mismatched_static_args(online_state, tmp_path):
    st0, cfg = online_state
    hp = Hyper()
    up = wal.OnlineUpdater(st0, cfg, hp, root=str(tmp_path), K=8, epochs=1,
                           ckpt_every=100)
    M2, N2 = st0.M + 6, st0.N + 3
    nr, nc, nv = _delta(st0, M2, N2, seed=9)
    up.update(nr, nc, nv, jax.random.PRNGKey(0), M_new=M2, N_new=N2)
    with pytest.raises(ValueError, match="static arguments"):
        wal.OnlineUpdater.recover(str(tmp_path), cfg, hp, K=8, epochs=2,
                                  base_state=st0)


# ---------------------------------------------------------------- service

@pytest.fixture(scope="module")
def serving():
    """Small serving stack; the LAST user has no interactions (the
    zero-candidate edge case) and the tail is tiny so ingest overflows."""
    rng = np.random.default_rng(3)
    M, N = 96, 64
    rows = np.repeat(np.arange(M - 1), 4).astype(np.int32)
    cols = rng.integers(0, N, rows.shape[0]).astype(np.int32)
    vals = rng.integers(1, 6, rows.shape[0]).astype(np.float32)
    sp = from_coo(rows, cols, vals, (M, N))
    cfg = simlsh.SimLSHConfig(G=8, p=2, q=8)
    sigs = simlsh.encode(sp, cfg, jax.random.PRNGKey(0))
    index = build_index(sigs, tail_cap=8)
    params = init_from_data(jax.random.PRNGKey(1), sp, 16, 8)
    return sp, sigs, index, params


def _service(serving, **kw):
    sp, sigs, index, params = serving
    defaults = dict(topn=5, micro_batch=8, C=32, n_seeds=4, cap=8,
                    n_popular=16)
    defaults.update(kw)
    return RecsysService(params, index, sp, ServeConfig(**defaults)).warmup()


def test_service_overload_sheds_in_submission_order(serving):
    svc = _service(serving, max_pending=12)
    svc.submit(np.arange(30, dtype=np.int32))    # burst 30 > bound 12
    svc.flush()
    res = svc.take_results()
    st = svc.stats()
    assert st["shed"] == 18 and st["degraded"] == 18
    assert st["users"] == 30, "every user answered — shed ≠ lost"
    all_u = np.concatenate([r[0] for r in res])
    assert all_u.tolist() == list(range(30)), \
        "degraded pseudo-flushes must keep submission order"
    # degraded rows answer with the popularity shortlist, bias-scored
    pop = np.asarray(svc.popular)[:5]
    np.testing.assert_array_equal(res[0][2][0], pop)
    mu = float(svc.params.mu)
    b = np.asarray(svc.params.b)
    bh = np.asarray(svc.params.bh)
    np.testing.assert_allclose(res[0][1][0], mu + b[0] + bh[pop], rtol=1e-6)


def test_service_deadline_shedding_under_stall(serving):
    """An injected stall delays the flush; requests that waited past the
    deadline are shed rather than queued behind the stall."""
    svc = _service(serving, deadline_s=0.02)
    with faults.injected({"serve.flush":
                          resil.FaultSpec(kind="stall", stall_s=0.05,
                                          at_calls=(0,))}):
        # one burst of two micro-batches: flush 0 stalls 50 ms while users
        # 8-15 sit in the queue; by flush 1 they are past the deadline
        svc.submit(np.arange(16, dtype=np.int32))
        svc.flush()
    st = svc.stats()
    res = svc.take_results()
    assert st["shed"] == 8, "stall must shed, not stretch the queue"
    assert np.concatenate([r[0] for r in res]).tolist() == list(range(16))


def test_service_drops_when_no_popular_fallback(serving):
    svc = _service(serving, n_popular=0, max_pending=4)
    svc.submit(np.arange(12, dtype=np.int32))
    svc.flush()
    st = svc.stats()
    served = sum(r[0].shape[0] for r in svc.take_results())
    assert st["dropped"] == 8 and served == 4


def test_service_zero_candidate_user_serves_sentinels(serving):
    sp, _, _, _ = serving
    svc = _service(serving, n_popular=0)
    lonely = sp.M - 1            # no interactions → no seeds → no candidates
    svc.submit(np.full(8, lonely, np.int32))
    svc.flush()
    (users, scores, items), = svc.take_results()
    assert users.shape == (8,) and items.shape == (8, 5)
    assert (items == SENTINEL).all(), \
        "a user with no candidates gets explicit SENTINELs, not garbage"


def test_service_popular_fallback_covers_zero_candidate_user(serving):
    sp, _, _, _ = serving
    svc = _service(serving)      # n_popular=16
    lonely = sp.M - 1
    svc.submit(np.full(8, lonely, np.int32))
    svc.flush()
    (_, _, items), = svc.take_results()
    pop = set(np.asarray(svc.popular).tolist())
    got = set(items[0].tolist()) - {int(SENTINEL)}
    assert got and got <= pop, \
        "with a shortlist, a candidate-less user is served popular items"


def test_service_flush_failure_falls_back_to_exact_full_scoring(serving):
    svc = _service(serving, n_popular=0, topn=3)
    with faults.injected({"serve.flush": resil.FaultSpec(at_calls=(0,))}):
        svc.submit(np.arange(8, dtype=np.int32))
        svc.flush()
    st = svc.stats()
    (users, scores, items), = svc.take_results()
    assert st["fallbacks"] == 1
    p = svc.params
    dense = (float(p.mu) + np.asarray(p.b)[users][:, None]
             + np.asarray(p.bh)[None, :]
             + np.asarray(p.U)[users] @ np.asarray(p.V).T)
    np.testing.assert_array_equal(items[:, 0], np.argmax(dense, axis=1))


def test_service_quarantines_poison_ingest(serving):
    sp, sigs, _, _ = serving
    svc = _service(serving)
    n0 = svc.index.n_items
    with pytest.raises(resil.PoisonBatchError, match="int32"):
        svc.ingest(np.zeros((8, 1), np.float32), np.array([sp.N]))
    with pytest.raises(resil.PoisonBatchError, match="negative"):
        svc.ingest(np.asarray(sigs)[:, :1], np.array([-1]))
    with pytest.raises(resil.PoisonBatchError, match="duplicate"):
        svc.ingest(np.asarray(sigs)[:, :2], np.array([sp.N, sp.N]))
    assert svc.stats()["quarantined"] == 3
    assert svc.index.n_items == n0, "quarantined batches touch no state"


def test_service_background_rebuild_swap_and_rollback(serving):
    sp, sigs, index, params = serving
    full = jnp.concatenate([sigs, sigs[:, :12]], axis=1)
    new_ids = jnp.arange(sp.N, sp.N + 12, dtype=jnp.int32)

    # failure path first: every build dies → bounded retries → rollback
    svc = _service(serving)
    with faults.injected({"serve.rebuild":
                          resil.FaultSpec(at_calls=(0, 1, 2))}):
        svc.ingest(sigs[:, :12], new_ids, full_sigs=full)   # 12 > tail 8
        assert svc.stats()["index_stale"]
        for _ in range(6):
            svc._rebuilder.join(60)
            svc.flush()
    assert svc.index.n_items == sp.N, "failed rebuild must never swap in"
    assert svc.obs.counter("serve.rebuild.gave_up") == 1
    # the service still answers (degraded: index v, stale catalog)
    svc.submit(np.arange(8, dtype=np.int32))
    svc.flush()
    assert len(svc.take_results()) == 1

    # success path: same overflow, no faults → validated v+1 swaps in
    svc2 = _service(serving)
    svc2.ingest(sigs[:, :12], new_ids, full_sigs=full)
    svc2._rebuilder.join(60)
    svc2.submit(np.arange(8, dtype=np.int32))   # poll at the loop edge
    svc2.flush()
    assert svc2.index.n_items == sp.N + 12
    assert not svc2.stats()["index_stale"]
    assert svc2.obs.counter("serve.rebuild.swaps") == 1


def test_service_corrupt_rebuild_is_rejected_by_validation(serving):
    sp, sigs, _, _ = serving
    full = jnp.concatenate([sigs, sigs[:, :12]], axis=1)

    def corrupt(idx):
        bad = dataclasses.replace(
            idx, sorted_ids=idx.sorted_ids.at[0, 0].set(idx.sorted_ids[0, 1]))
        object.__setattr__(bad, "_tail_host", 0)
        return bad

    svc = _service(serving)
    with faults.injected({"serve.rebuild.index":
                          resil.FaultSpec(kind="corrupt", mutate=corrupt,
                                          at_calls=(0, 1, 2))}):
        svc.ingest(sigs[:, :12], jnp.arange(sp.N, sp.N + 12, dtype=jnp.int32),
                   full_sigs=full)
        for _ in range(6):
            svc._rebuilder.join(60)
            svc.flush()
    assert svc.index.n_items == sp.N, \
        "a corrupt build must be caught by the validation gate, never served"
    assert svc._rebuilder.failures == 3
    assert resil.validate_index(svc.index) == []


# ---------------------------------------------------------------- loop (ISSUE 10)

from repro.loop import LoopConfig, OnlineLoop  # noqa: E402

LOOP_SERVE = ServeConfig(topn=5, micro_batch=8, C=32, n_seeds=4, cap=8,
                         n_popular=16)
LOOP_CFG = LoopConfig(serve_flushes=2, micro_epochs=1, micro_batch=512,
                      deltas_per_slice=2, max_lag=2, ckpt_every=2,
                      drift_every=2, drift_window=4, tail_cap=16, seed=0)


def _loop(root, online_state):
    st0, lsh = online_state
    up = wal.OnlineUpdater(st0, lsh, Hyper(), root=str(root), K=8, epochs=1,
                           batch=512)
    svc = OnlineLoop.build_service(st0, LOOP_SERVE,
                                   tail_cap=LOOP_CFG.tail_cap)
    hold = (np.asarray(st0.sp.rows)[:200], np.asarray(st0.sp.cols)[:200],
            np.asarray(st0.sp.vals)[:200])
    return OnlineLoop(up, svc, LOOP_CFG, holdout=hold)


def _drive_loop(loop, n_slices, kill_site=None, kill_call=0):
    """Deterministic slice schedule (fixed seeds for traffic, ΔΩ and
    keys) so the killed arm replays the reference arm's stream exactly.
    Returns (killed, {seq: state_after_slice})."""
    M, N = loop.state.M, loop.state.N
    snaps = {}
    plan = None
    if kill_site:
        plan = faults.install(resil.FaultPlan(
            {kill_site: resil.FaultSpec(at_calls=(kill_call,))}))
    try:
        for s in range(n_slices):
            rng = np.random.default_rng(500 + s)
            loop.svc.submit(rng.integers(0, M, 16).astype(np.int32))
            if s % 2 == 0:
                M, N = M + 4, N + 2
                nr, nc, nv = _delta(loop.state, M, N, seed=1000 + s)
                loop.offer_delta(nr, nc, nv,
                                 np.asarray(jax.random.PRNGKey(70 + s)),
                                 M_new=M, N_new=N)
            try:
                loop.run_slice()
            except resil.InjectedFault:
                return True, snaps
            snaps[loop.updater.seq] = loop.state
        return False, snaps
    finally:
        if plan is not None:
            faults.uninstall()


@pytest.fixture(scope="module")
def loop_reference(online_state, tmp_path_factory):
    """The uninterrupted 6-slice arm every kill scenario is compared
    against: state snapshots keyed by WAL seq."""
    loop = _loop(tmp_path_factory.mktemp("loop-ref"), online_state)
    killed, snaps = _drive_loop(loop, 6)
    assert not killed and loop.updater.seq >= 3
    return snaps


@pytest.mark.parametrize("site,call", [
    ("loop.slice", 3),     # between slices, before anything runs
    ("loop.ckpt", 1),      # before the 2nd durable cut — resume = 1st
                           # checkpoint + unpruned WAL suffix
    ("loop.drift", 1),     # mid-slice, after train, before the probe
])
def test_loop_kill_at_site_recovers_bit_identical(online_state,
                                                  loop_reference, tmp_path,
                                                  site, call):
    st0, lsh = online_state
    loop = _loop(tmp_path, online_state)
    killed, _ = _drive_loop(loop, 6, kill_site=site, kill_call=call)
    assert killed, f"fault at {site} never fired"
    del loop                                # the killed process

    rec = OnlineLoop.recover(str(tmp_path), lsh, Hyper(), LOOP_SERVE, K=8,
                             epochs=1, batch=512, cfg=LOOP_CFG,
                             base_state=st0)
    assert rec.updater.seq in loop_reference, \
        (site, rec.updater.seq, sorted(loop_reference))
    _assert_states_bit_identical(rec.state, loop_reference[rec.updater.seq])
    # the recovered loop keeps going: serve + train a fresh slice
    rec.svc.submit(np.arange(16, dtype=np.int32))
    rec.run_slice()
    st = rec.svc.stats()
    assert st["users"] >= 16 and st["dropped"] == 0


def test_loop_recovered_service_sheds_but_answers_everyone(online_state,
                                                           tmp_path):
    """After a kill + recover, an overload burst degrades (popularity
    answers) — it never drops: shed ≠ lost survives the crash."""
    st0, lsh = online_state
    loop = _loop(tmp_path, online_state)
    killed, _ = _drive_loop(loop, 6, kill_site="loop.ckpt", kill_call=1)
    assert killed
    serve = dataclasses.replace(LOOP_SERVE, max_pending=12)
    rec = OnlineLoop.recover(str(tmp_path), lsh, Hyper(), serve, K=8,
                             epochs=1, batch=512, cfg=LOOP_CFG,
                             base_state=st0)
    rec.svc.submit(np.arange(30, dtype=np.int32))   # burst 30 > bound 12
    rec.run_slice()
    rec.svc.flush()
    st = rec.svc.stats()
    assert st["users"] == 30 and st["degraded"] > 0 and st["dropped"] == 0


def test_loop_slice_guard_rolls_back_whole_slice(online_state, tmp_path):
    """A diverging micro-epoch rejects the *slice's* WAL entry: the state
    is exactly pre-slice, and replay re-trips to the same rejection."""
    st0, lsh = online_state
    up = wal.OnlineUpdater(st0, lsh, Hyper(), root=str(tmp_path), K=8,
                           epochs=1, batch=512,
                           guard=resil.GuardConfig(max_ratio=1e-9))
    svc = OnlineLoop.build_service(st0, LOOP_SERVE,
                                   tail_cap=LOOP_CFG.tail_cap)
    loop = OnlineLoop(up, svc, LOOP_CFG)
    pre = loop.state
    loop.run_slice()                        # micro-epoch trips the guard
    assert int(loop.obs.counter("loop.guard_trips")) == 1
    assert loop.state is pre, "rollback must restore the pre-slice state"
    assert loop.updater.seq == 1            # the entry is logged regardless
    rec = OnlineLoop.recover(str(tmp_path), lsh, Hyper(), LOOP_SERVE, K=8,
                             epochs=1, batch=512, cfg=LOOP_CFG,
                             guard=resil.GuardConfig(max_ratio=1e-9),
                             base_state=st0)
    assert rec.updater.seq == 1             # replay re-trips, stays rejected
    _assert_states_bit_identical(rec.state, pre)
