"""core/online.py — Alg. 4 invariants: old-parameter freezing and
incremental-signature consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import online, simlsh, topk
from repro.core.model import init_from_data
from repro.core.sgd import Hyper
from repro.data.sparse import from_coo


@pytest.fixture(scope="module")
def small_state():
    from repro.data import synthetic as syn
    spec = dataclasses.replace(syn.MOVIELENS_LIKE, M=300, N=80, nnz=6000)
    rows, cols, vals, _ = syn.generate(spec, seed=0)
    sp = from_coo(rows, cols, vals, (spec.M, spec.N))
    cfg = simlsh.SimLSHConfig(G=8, p=1, q=6)
    key = jax.random.PRNGKey(0)
    sigs, S = simlsh.encode(sp, cfg, key, return_accumulators=True)
    JK = topk.topk_from_signatures(sigs, jax.random.PRNGKey(1), K=8,
                                   band_cap=cfg.band_cap)
    params = init_from_data(jax.random.PRNGKey(2), sp, 16, 8)
    st = online.OnlineState(params=params, S=S, JK=JK, sp=sp,
                            M=spec.M, N=spec.N, hash_key=key)
    return st, cfg, key


def _delta(st, M_new, N_new, n=800, seed=3):
    """Fresh ΔΩ triples in the grown id space, disjoint from st.sp."""
    rng = np.random.default_rng(seed)
    nr = rng.integers(0, M_new, n).astype(np.int32)
    nc = rng.integers(0, N_new, n).astype(np.int32)
    pair = np.unique(nr.astype(np.int64) * N_new + nc)
    old = set((np.asarray(st.sp.rows).astype(np.int64) * N_new
               + np.asarray(st.sp.cols)).tolist())
    pair = np.asarray([p for p in pair.tolist() if p not in old])
    nr, nc = (pair // N_new).astype(np.int32), (pair % N_new).astype(np.int32)
    nv = rng.uniform(1, 5, nr.shape[0]).astype(np.float32)
    return jnp.asarray(nr), jnp.asarray(nc), jnp.asarray(nv)


def test_online_update_freezes_old_parameters(small_state):
    st, cfg, key = small_state
    M2, N2 = st.M + 40, st.N + 12
    nr, nc, nv = _delta(st, M2, N2)
    st2 = online.online_update(st, nr, nc, nv, cfg, Hyper(),
                               jax.random.PRNGKey(9), M_new=M2, N_new=N2,
                               K=8, epochs=2)
    p0, p1 = st.params, st2.params
    # the paper's "remains unchanged": ids < old sizes are bit-identical
    np.testing.assert_array_equal(np.asarray(p1.U[:st.M]), np.asarray(p0.U))
    np.testing.assert_array_equal(np.asarray(p1.b[:st.M]), np.asarray(p0.b))
    np.testing.assert_array_equal(np.asarray(p1.V[:st.N]), np.asarray(p0.V))
    np.testing.assert_array_equal(np.asarray(p1.bh[:st.N]), np.asarray(p0.bh))
    np.testing.assert_array_equal(np.asarray(p1.W[:st.N]), np.asarray(p0.W))
    np.testing.assert_array_equal(np.asarray(p1.C[:st.N]), np.asarray(p0.C))
    # old columns keep their Top-K lists; new ones got appended
    np.testing.assert_array_equal(np.asarray(st2.JK[:st.N]),
                                  np.asarray(st.JK))
    assert st2.JK.shape == (N2, 8)
    # and the new parameters actually moved away from their fresh init
    # (same key split as online_update: grow, topk, train)
    k_grow, _, _ = jax.random.split(jax.random.PRNGKey(9), 3)
    p_init = online.grow_params(st.params, M2, N2, k_grow)
    assert not np.array_equal(np.asarray(p1.U[st.M:]),
                              np.asarray(p_init.U[st.M:]))
    assert not np.array_equal(np.asarray(p1.V[st.N:]),
                              np.asarray(p_init.V[st.N:]))


def test_update_accumulators_matches_fresh_encode(small_state):
    """Alg. 4 incremental hashing ≡ from-scratch encode on the merged
    matrix (same key), up to float-summation-order noise near zero."""
    st, cfg, key = small_state
    N2 = st.N + 12
    M2 = st.M + 40
    nr, nc, nv = _delta(st, M2, N2)

    S2, sigs_inc = simlsh.update_accumulators(st.S, nr, nc, nv, cfg, key, N2)

    merged = from_coo(jnp.concatenate([st.sp.rows, nr]),
                      jnp.concatenate([st.sp.cols, nc]),
                      jnp.concatenate([st.sp.vals, nv]), (M2, N2))
    sigs_fresh, S_fresh = simlsh.encode(merged, cfg, key,
                                        return_accumulators=True)

    np.testing.assert_allclose(np.asarray(S2), np.asarray(S_fresh),
                               rtol=1e-4, atol=1e-3)
    # bits may legitimately differ only where the accumulator is ~0
    inc, fresh = np.asarray(sigs_inc), np.asarray(sigs_fresh)
    tiny = np.abs(np.asarray(S_fresh)) < 1e-3
    bit_ok = np.ones_like(inc, bool)
    for b in range(cfg.sig_bits):
        same = ((inc >> b) & 1) == ((fresh >> b) & 1)
        bit_ok &= same | tiny[..., b]
    assert bit_ok.all()


def test_online_update_then_fresh_topk_for_new_columns(small_state):
    st, cfg, key = small_state
    M2, N2 = st.M, st.N + 10          # only new columns this time
    nr, nc, nv = _delta(st, M2, N2, seed=11)
    # make sure the new columns actually receive ratings
    nc = jnp.where(nc < st.N, (nc % 10) + st.N, nc)
    pair = np.unique(np.asarray(nr).astype(np.int64) * N2 + np.asarray(nc))
    nr = jnp.asarray((pair // N2).astype(np.int32))
    nc = jnp.asarray((pair % N2).astype(np.int32))
    nv = nv[:nr.shape[0]]
    st2 = online.online_update(st, nr, nc, nv, cfg, Hyper(),
                               jax.random.PRNGKey(5), M_new=M2, N_new=N2,
                               K=8, epochs=1)
    assert st2.S.shape == (cfg.q, N2, cfg.sig_bits)
    assert st2.sp.nnz == st.sp.nnz + int(nr.shape[0])
    # every new column's Top-K entries point inside the grown id space
    assert int(jnp.max(st2.JK[st.N:])) < N2
