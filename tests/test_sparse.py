"""Sparse substrate: lookup, degrees, baselines, batching, merging."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.data.sparse import (baselines, degrees, epoch_batches, from_coo,
                               lookup, merge_coo)


def _dense_of(sp):
    d = np.zeros(sp.shape, np.float32)
    d[np.asarray(sp.rows), np.asarray(sp.cols)] = np.asarray(sp.vals)
    return d


def test_lookup_matches_dense(tiny_sparse):
    sp = tiny_sparse
    dense = _dense_of(sp)
    rng = np.random.default_rng(0)
    qi = rng.integers(0, sp.M, 500).astype(np.int32)
    qj = rng.integers(0, sp.N, 500).astype(np.int32)
    vals, hit = lookup(sp, jnp.asarray(qi), jnp.asarray(qj))
    np.testing.assert_allclose(np.asarray(vals), dense[qi, qj])
    assert np.all(np.asarray(hit) == (dense[qi, qj] != 0))


def test_lookup_hits_every_nnz(tiny_sparse):
    sp = tiny_sparse
    vals, hit = lookup(sp, sp.rows, sp.cols)
    assert bool(jnp.all(hit))
    np.testing.assert_allclose(np.asarray(vals), np.asarray(sp.vals))


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 40), st.integers(2, 30), st.integers(0, 10**6))
def test_lookup_property(M, N, seed):
    rng = np.random.default_rng(seed)
    nnz = min(M * N, rng.integers(1, 60))
    flat = rng.choice(M * N, size=nnz, replace=False)
    rows, cols = (flat // N).astype(np.int32), (flat % N).astype(np.int32)
    vals = rng.uniform(0.5, 5, nnz).astype(np.float32)
    sp = from_coo(rows, cols, vals, (M, N))
    dense = _dense_of(sp)
    qi = rng.integers(0, M, 32).astype(np.int32)
    qj = rng.integers(0, N, 32).astype(np.int32)
    got, hit = lookup(sp, jnp.asarray(qi), jnp.asarray(qj))
    np.testing.assert_allclose(np.asarray(got), dense[qi, qj])


def test_degrees_and_baselines(tiny_sparse):
    sp = tiny_sparse
    dense = _dense_of(sp)
    dr, dc = degrees(sp)
    np.testing.assert_array_equal(np.asarray(dr), (dense != 0).sum(1))
    np.testing.assert_array_equal(np.asarray(dc), (dense != 0).sum(0))
    mu, b, bh = baselines(sp)
    assert abs(float(mu) - np.asarray(sp.vals).mean()) < 1e-4
    i = int(np.argmax((dense != 0).sum(1)))
    expect = dense[i][dense[i] != 0].mean() - float(mu)
    assert abs(float(b[i]) - expect) < 1e-3


def test_epoch_batches_cover_every_sample():
    idx, valid = epoch_batches(jax.random.PRNGKey(0), 1000, 128)
    flat = np.asarray(idx)[np.asarray(valid)]
    assert sorted(flat.tolist()) == list(range(1000))


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 40), st.integers(2, 30), st.integers(0, 10**6))
def test_merge_coo_matches_from_coo(M, N, seed):
    """Sorted-array union merge ≡ full rebuild, including a grown id space."""
    rng = np.random.default_rng(seed)
    nnz = min(M * N, int(rng.integers(1, 80)))
    flat = rng.choice(M * N, size=nnz, replace=False)
    d = int(rng.integers(1, 40))
    M2, N2 = M + int(rng.integers(0, 8)), N + int(rng.integers(0, 8))
    # delta keys disjoint from the observed set (ΔΩ = new interactions)
    pool = np.setdiff1d(rng.choice(M2 * N2, size=min(4 * d, M2 * N2),
                                   replace=False),
                        (flat // N) * N2 + (flat % N))
    dflat = pool[:min(d, len(pool))]
    rows, cols = (flat // N).astype(np.int32), (flat % N).astype(np.int32)
    vals = rng.uniform(0.5, 5, nnz).astype(np.float32)
    drows = (dflat // N2).astype(np.int32)
    dcols = (dflat % N2).astype(np.int32)
    dvals = rng.uniform(0.5, 5, len(dflat)).astype(np.float32)
    sp = from_coo(rows, cols, vals, (M, N))
    got = merge_coo(sp, drows, dcols, dvals, (M2, N2))
    want = from_coo(np.concatenate([rows, drows]),
                    np.concatenate([cols, dcols]),
                    np.concatenate([vals, dvals]), (M2, N2))
    assert got.shape == want.shape
    for f in ("rows", "cols", "vals"):
        np.testing.assert_array_equal(np.asarray(getattr(got, f)),
                                      np.asarray(getattr(want, f)), err_msg=f)
