"""Online learning (Alg. 4), checkpoint fault-tolerance, NCF baselines."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import model, ncf, online, simlsh, topk
from repro.core.sgd import Hyper
from repro.data.sparse import from_coo
from repro.train import checkpoint as ckpt


def test_online_freezes_old_params(tiny_dataset):
    spec, rows, cols, vals, _ = tiny_dataset
    cut = len(vals) * 3 // 4
    # old ids only in the first part; new rows/cols get fresh id ranges
    M_new, N_new = spec.M + 32, spec.N + 8
    rng = np.random.default_rng(0)
    n_delta = 512
    d_rows = rng.integers(spec.M, M_new, n_delta).astype(np.int32)
    d_cols = rng.integers(0, N_new, n_delta).astype(np.int32)
    d_vals = rng.uniform(1, 5, n_delta).astype(np.float32)

    sp_old = from_coo(rows[:cut], cols[:cut], vals[:cut], (spec.M, spec.N))
    cfg = simlsh.SimLSHConfig(G=8, p=1, q=3)
    key = jax.random.PRNGKey(0)
    sigs, S = simlsh.encode(sp_old, cfg, key, return_accumulators=True)
    K = 4
    JK = topk.topk_from_signatures(sigs, key, K=K, band_cap=cfg.band_cap)
    params = model.init_from_data(key, sp_old, F=8, K=K)
    st = online.OnlineState(params=params, S=S, JK=JK, sp=sp_old,
                            M=spec.M, N=spec.N, hash_key=key)
    st2 = online.online_update(st, d_rows, d_cols, d_vals, cfg, Hyper(), key,
                               M_new=M_new, N_new=N_new, K=K, epochs=2,
                               batch=256)
    # old parameters untouched
    np.testing.assert_array_equal(np.asarray(st2.params.U[:spec.M]),
                                  np.asarray(params.U))
    np.testing.assert_array_equal(np.asarray(st2.params.V[:spec.N]),
                                  np.asarray(params.V))
    np.testing.assert_array_equal(np.asarray(st2.JK[:spec.N]),
                                  np.asarray(JK))
    # new parameters trained (moved from init)
    assert st2.params.U.shape == (M_new, 8)
    assert st2.sp.nnz == sp_old.nnz + n_delta


def test_checkpoint_roundtrip_and_resume(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4), "b": jnp.float32(7.0),
            "nested": [jnp.ones((5,)), jnp.zeros((2, 2), jnp.int32)]}
    d = str(tmp_path / "ck")
    os.makedirs(d)
    ckpt.save(d, tree, step=3, sync=True)
    tree2, step = ckpt.restore(d, tree)
    assert step == 3
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(tree2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_kill_and_restart(tmp_path):
    """Simulated crash: run 4 steps + checkpoint, 'crash', rerun — the
    trainer resumes from the manifest (the fault-tolerance contract)."""
    from repro.configs import base as CB
    from repro.launch.train import train_loop
    from repro.models import lm, steps as S
    cfg = CB.reduced(CB.get("qwen1.5-0.5b"))
    d = str(tmp_path / "ck2")
    os.makedirs(d)
    # run 1: "crashes" after step 4 (checkpoint every 2 → step-4 exists)
    p1, o1, _ = train_loop(cfg, steps_n=4, batch=4, seq=32, ckpt_dir=d,
                           ckpt_every=2, log=lambda *_: None, seed=3)
    assert ckpt.latest_step(d) == 4
    # restored state equals the state at the crash point
    template = (lm.init_params(cfg, jax.random.PRNGKey(3), model_shards=1),)
    template = (template[0], S.init_opt(cfg, template[0]))
    (p_r, o_r), step = ckpt.restore(d, template)
    assert step == 4
    np.testing.assert_allclose(np.asarray(p_r["embed"]),
                               np.asarray(p1["embed"]), rtol=1e-6)
    # run 2: resumes at step 4 and continues to 8 without error
    logs = []
    p2, _, losses = train_loop(cfg, steps_n=8, batch=4, seq=32, ckpt_dir=d,
                               log=logs.append, seed=3)
    assert any("resumed from step 4" in str(x) for x in logs)
    assert len(losses) == 4          # only steps 4..7 executed


def test_checkpoint_prune_keeps_latest(tmp_path):
    d = str(tmp_path / "ck3")
    os.makedirs(d)
    t = {"x": jnp.ones((2,))}
    for s in range(1, 6):
        ckpt.save(d, t, step=s, sync=True)
    steps_present = sorted(x for x in os.listdir(d) if x.startswith("step-"))
    assert len(steps_present) == 3
    assert ckpt.latest_step(d) == 5


def test_ncf_models_learn():
    rng = np.random.default_rng(0)
    M, N = 64, 32
    # planted: user u likes item u % N strongly
    users = np.repeat(np.arange(M), 6).astype(np.int32)
    pos = ((users * 7) % N).astype(np.int32)
    negs = rng.integers(0, N, len(users)).astype(np.int32)
    i = np.concatenate([users, users])
    j = np.concatenate([pos, negs])
    y = np.concatenate([np.ones(len(users)), np.zeros(len(users))])
    y[len(users):][negs == pos] = 1.0

    for kind in ("gmf", "mlp", "neumf"):
        cfg = ncf.NCFConfig(M=M, N=N, F=8, mlp_layers=(16, 8), kind=kind)
        p = ncf.init(cfg, jax.random.PRNGKey(0))
        m = jax.tree.map(jnp.zeros_like, p)
        v = jax.tree.map(jnp.zeros_like, p)
        l0 = float(ncf.bce_loss(p, cfg, i, j, y))
        for t in range(1, 300):
            p, m, v = ncf.adam_step(p, m, v, jnp.float32(t), cfg, i, j, y,
                                    lr=2e-2)
        l1 = float(ncf.bce_loss(p, cfg, i, j, y))
        assert l1 < 0.5 * l0, f"{kind}: {l0} -> {l1}"

    # HR improves over random for the trained model
    cands = rng.integers(0, N, (M, 20)).astype(np.int32)
    hr = float(ncf.hit_ratio(p, cfg, np.arange(M, dtype=np.int32),
                             ((np.arange(M) * 7) % N).astype(np.int32),
                             cands, topk=5))
    assert hr > 5 / 21 * 1.5
