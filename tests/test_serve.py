"""repro.serve: bucketed index, retrieval, candidate-score kernel, service."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import simlsh, topk
from repro.core.model import init_from_data
from repro.core.simlsh import SimLSHConfig
from repro.data.sparse import from_coo
from repro.kernels.candidate_score.kernel import candidate_score_topn
from repro.kernels.candidate_score.ops import score_candidates
from repro.kernels.candidate_score.ref import candidate_score_topn_ref
from repro.serve import (RecsysService, ServeConfig, build_index,
                         dedup_candidates, insert, lookup_items,
                         lookup_signatures, rebuild, retrieve_for_items,
                         retrieve_for_users, seed_items)

SENTINEL = topk.SENTINEL
RNG = np.random.default_rng(0)


def _dup_matrix(M=200, half=30, seed=0):
    """Matrix whose column c+half duplicates column c exactly."""
    rng = np.random.default_rng(seed)
    rows = np.repeat(np.arange(M), 5).astype(np.int32)
    cols = rng.integers(0, half, M * 5).astype(np.int32)
    vals = rng.integers(1, 6, M * 5).astype(np.float32)
    rows2 = np.concatenate([rows, rows])
    cols2 = np.concatenate([cols, cols + half])
    vals2 = np.concatenate([vals, vals])
    keys = rows2.astype(np.int64) * (2 * half) + cols2
    _, uniq = np.unique(keys, return_index=True)
    return from_coo(rows2[uniq], cols2[uniq], vals2[uniq], (M, 2 * half))


@pytest.fixture(scope="module")
def indexed():
    sp = _dup_matrix()
    cfg = SimLSHConfig(G=8, p=2, q=8)
    sigs = simlsh.encode(sp, cfg, jax.random.PRNGKey(0))
    return sp, cfg, sigs, build_index(sigs, tail_cap=32)


# ---------------------------------------------------------------- index

def test_bucket_membership_roundtrip_vs_band_candidates(indexed):
    """Index mates = same-signature items, consistent with band_candidates."""
    sp, cfg, sigs, index = indexed
    N = sp.N
    cap = 8
    ids = jnp.arange(N, dtype=jnp.int32)
    mates = np.asarray(lookup_items(index, ids, cap=cap,
                                    include_tail=False)).reshape(N, cfg.q, cap)
    sigs_np = np.asarray(sigs)
    bc = np.asarray(jax.vmap(
        lambda s: topk.band_candidates(s, band_cap=cap))(sigs))   # [q, N, cap]
    for b in range(cfg.q):
        bucket_size = {s: c for s, c in
                       zip(*np.unique(sigs_np[b], return_counts=True))}
        for j in range(N):
            got = set(mates[j, b][mates[j, b] != SENTINEL])
            # membership: every mate shares the band signature
            assert all(sigs_np[b, m] == sigs_np[b, j] for m in got)
            assert j in got  # the item itself is always a bucket member
            # small buckets: exact agreement with the sort-based path
            if bucket_size[sigs_np[b, j]] <= cap // 2:
                ref = set(bc[b, j][bc[b, j] != SENTINEL]) | {j}
                assert got == ref


def test_lookup_signatures_finds_exact_buckets(indexed):
    sp, cfg, sigs, index = indexed
    qsigs = jnp.asarray(np.asarray(sigs)[:, :16].T)               # [16, q]
    cand = np.asarray(lookup_signatures(index, qsigs, cap=8, n_probe=2))
    sigs_np = np.asarray(sigs)
    for i in range(16):
        got = cand[i][cand[i] != SENTINEL]
        assert i in got  # probing with item i's own signatures finds i


def test_retrieval_recall_vs_bruteforce_cosine():
    """Candidates of an item must cover its brute-force cosine top-K on a
    matrix with planted column clusters (same-group columns share raters)."""
    rng = np.random.default_rng(0)
    n_groups, ipg, upg, deg = 12, 10, 24, 16     # N=120 items, M=288 users
    N, M = n_groups * ipg, n_groups * upg
    cols = np.arange(N, dtype=np.int32).repeat(deg)
    pick = np.argsort(rng.random((N, upg)), axis=1)[:, :deg]
    rows = (pick + (np.arange(N) // ipg)[:, None] * upg).reshape(-1)
    vals = rng.uniform(3, 5, rows.shape[0]).astype(np.float32)
    sp = from_coo(rows.astype(np.int32), cols, vals, (M, N))

    dense = np.zeros(sp.shape, np.float32)
    dense[np.asarray(sp.rows), np.asarray(sp.cols)] = np.asarray(sp.vals)
    norm = dense / np.maximum(np.linalg.norm(dense, axis=0, keepdims=True),
                              1e-9)
    cos = norm.T @ norm
    np.fill_diagonal(cos, -1.0)
    K = 3
    exact = np.argsort(-cos, axis=1)[:, :K]

    cfg = SimLSHConfig(G=8, p=1, q=12)
    sigs = simlsh.encode(sp, cfg, jax.random.PRNGKey(0))
    index = build_index(sigs, tail_cap=32)
    cand = np.asarray(retrieve_for_items(
        index, jnp.arange(N, dtype=jnp.int32), cap=8, C=32))
    hits = sum(len(set(cand[j][cand[j] != SENTINEL]) & set(exact[j]))
               for j in range(N))
    recall = hits / (N * K)
    # C=32 of 120 items → chance recall ≈ 0.27; demand far better
    assert recall >= 0.7, f"recall@{K} vs cosine = {recall:.3f}"


def test_retrieval_always_finds_duplicate_partner(indexed):
    """Exact duplicate columns collide in every band → always retrieved."""
    sp, cfg, sigs, index = indexed
    cand = np.asarray(retrieve_for_items(
        index, jnp.arange(sp.N, dtype=jnp.int32), cap=8, C=64))
    half = sp.N // 2
    partners = (np.arange(sp.N) + half) % sp.N
    dup_hits = np.mean([partners[j] in set(cand[j]) for j in range(sp.N)])
    assert dup_hits == 1.0


def test_insert_then_lookup_and_rebuild(indexed):
    sp, cfg, sigs, index = indexed
    N = sp.N
    # clone three existing items into the tail
    src = jnp.asarray([0, 5, 9], jnp.int32)
    new_ids = jnp.asarray([N, N + 1, N + 2], jnp.int32)
    idx2 = insert(index, sigs[:, np.asarray(src)], new_ids)
    assert idx2.n_items == N + 3

    mates = np.asarray(lookup_items(idx2, src, cap=8))
    for r, nid in enumerate(np.asarray(new_ids)):
        assert nid in mates[r], "tail item not reachable from its bucket"
    # tail item as the query finds its base-bucket mates
    back = np.asarray(lookup_items(idx2, new_ids, cap=8))
    for r, s in enumerate(np.asarray(src)):
        assert s in back[r]

    # rebuild folds the tail into the sorted core; membership is preserved
    full_sigs = jnp.concatenate([sigs, sigs[:, np.asarray(src)]], axis=1)
    idx3 = rebuild(idx2, full_sigs)
    assert int(idx3.tail_len) == 0
    mates3 = np.asarray(lookup_items(idx3, src, cap=8, include_tail=False))
    for r, nid in enumerate(np.asarray(new_ids)):
        assert nid in mates3[r]


def test_insert_overflow_raises(indexed):
    sp, cfg, sigs, index = indexed
    with pytest.raises(ValueError, match="tail overflow"):
        insert(index, jnp.tile(sigs[:, :1], (1, 33)),
               jnp.arange(sp.N, sp.N + 33, dtype=jnp.int32))


# ---------------------------------------------------------------- retrieval

def test_dedup_candidates_unique_and_excludes():
    cands = jnp.asarray([[3, 1, 3, SENTINEL, 1, 7, 2, 2],
                         [5, 5, 5, 5, 5, 5, 5, 5]], jnp.int32)
    out = np.asarray(dedup_candidates(cands, C=6))
    assert sorted(out[0]) == [1, 2, 3, 7, SENTINEL, SENTINEL]
    assert sorted(out[1]) == [5] + [SENTINEL] * 5
    assert np.all(out[0][4:] == SENTINEL), "padding must sort last"
    out = np.asarray(dedup_candidates(
        cands, C=6, exclude_sorted=jnp.asarray([2, 5], jnp.int32)))
    assert sorted(out[0]) == [1, 3, 7, SENTINEL, SENTINEL, SENTINEL]
    assert list(out[1]) == [SENTINEL] * 6


def test_dedup_truncation_not_biased_against_high_ids():
    # overflow truncation must not systematically evict the largest ids
    # (newly ingested items always have the highest ids)
    row = jnp.arange(64, dtype=jnp.int32)[None, :]
    out = np.asarray(dedup_candidates(row, C=16))[0]
    kept = out[out != SENTINEL]
    assert len(kept) == 16
    assert (kept >= 48).any(), "top-quartile ids entirely evicted"


def test_seed_items_are_top_rated(indexed):
    sp, *_ = indexed
    users = jnp.arange(8, dtype=jnp.int32)
    seeds = np.asarray(seed_items(sp, users, n_seeds=4, window=32))
    dense = np.zeros(sp.shape, np.float32)
    dense[np.asarray(sp.rows), np.asarray(sp.cols)] = np.asarray(sp.vals)
    for u in range(8):
        s = seeds[u][seeds[u] != SENTINEL]
        assert len(s) > 0
        rated = dense[u][s]
        assert np.all(rated > 0), "seed item the user never rated"
        assert rated.min() >= np.sort(dense[u][dense[u] > 0])[::-1][
            :len(s)].min() - 1e-6


def test_retrieve_for_users_shapes_and_popular(indexed):
    sp, cfg, sigs, index = indexed
    users = jnp.arange(16, dtype=jnp.int32)
    popular = jnp.asarray([2, 11, 17], jnp.int32)
    cand = np.asarray(retrieve_for_users(
        index, sp, users, n_seeds=4, cap=8, C=32, popular=popular))
    assert cand.shape == (16, 32)
    for u in range(16):
        v = cand[u][cand[u] != SENTINEL]
        assert len(v) == len(set(v)), "duplicate candidates"
        assert {2, 11, 17} <= set(v), "popularity shortlist not reserved"


# ---------------------------------------------------------------- kernel

@pytest.mark.parametrize("B,C,F,topn,tile", [
    (32, 64, 16, 10, 8), (7, 33, 8, 5, 16), (64, 128, 32, 1, 32)])
def test_candidate_score_kernel_matches_ref(B, C, F, topn, tile):
    a = lambda *s: jnp.asarray(RNG.normal(size=s).astype(np.float32))
    u, bu, vc, bc = a(B, F), a(B), a(B, C, F), a(B, C)
    mask = jnp.asarray((RNG.random((B, C)) < 0.7).astype(np.float32))
    s1, i1 = candidate_score_topn(u, bu, vc, bc, mask, topn=topn, tile_b=tile)
    s2, i2 = candidate_score_topn_ref(u, bu, vc, bc, mask, topn=topn)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


def test_candidate_score_kernel_all_masked_rows():
    a = lambda *s: jnp.asarray(RNG.normal(size=s).astype(np.float32))
    B, C, F = 9, 16, 8
    u, bu, vc, bc = a(B, F), a(B), a(B, C, F), a(B, C)
    mask = jnp.zeros((B, C), jnp.float32)
    s1, i1 = candidate_score_topn(u, bu, vc, bc, mask, topn=4, tile_b=4)
    s2, i2 = candidate_score_topn_ref(u, bu, vc, bc, mask, topn=4)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))


def test_score_candidates_pallas_vs_ref_pipeline(indexed):
    sp, cfg, sigs, index = indexed
    params = init_from_data(jax.random.PRNGKey(1), sp, 16, 8)
    users = jnp.arange(24, dtype=jnp.int32)
    cand = retrieve_for_users(index, sp, users, n_seeds=4, cap=8, C=32)
    s1, i1 = score_candidates(params, users, cand, topn=5, impl="pallas")
    s2, i2 = score_candidates(params, users, cand, topn=5, impl="ref")
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    # returned items must come from the candidate set
    c = np.asarray(cand)
    for u in range(24):
        got = np.asarray(i1[u])
        assert set(got[got != SENTINEL]) <= set(c[u])


# ---------------------------------------------------------------- service

def test_service_candidate_matches_full_on_candidates(indexed):
    """Candidate-mode top-1 score equals the full-mode score of that item."""
    sp, cfg, sigs, index = indexed
    params = init_from_data(jax.random.PRNGKey(1), sp, 16, 8)
    scfg = ServeConfig(topn=5, micro_batch=16, C=48, n_seeds=4, cap=8,
                       n_popular=8)
    svc = RecsysService(params, index, sp, scfg).warmup()
    full = RecsysService(params, index, sp,
                         dataclasses.replace(scfg, mode="full")).warmup()
    users = np.arange(16, dtype=np.int32)
    svc.submit(users); svc.flush()
    full.submit(users); full.flush()
    _, s_c, i_c = svc.take_results()[0]
    _, s_f, i_f = full.take_results()[0]
    # every candidate-mode score must equal the exact score of that item
    exact = (np.asarray(params.mu) + np.asarray(params.b)[users][:, None]
             + np.asarray(params.bh)[i_c]
             + np.einsum("bf,bnf->bn", np.asarray(params.U)[users],
                         np.asarray(params.V)[i_c]))
    np.testing.assert_allclose(s_c, exact, rtol=1e-4, atol=1e-4)
    st = svc.stats()
    assert st["users"] == 16 and st["batches"] == 1


def test_service_micro_batching_and_partial_flush(indexed):
    sp, cfg, sigs, index = indexed
    params = init_from_data(jax.random.PRNGKey(1), sp, 16, 8)
    scfg = ServeConfig(topn=3, micro_batch=8, C=32, n_seeds=4, cap=8,
                       n_popular=0)
    svc = RecsysService(params, index, sp, scfg)
    svc.submit(np.arange(5));   assert svc.stats()["batches"] == 0
    svc.submit(np.arange(5));   assert svc.stats()["batches"] == 1
    svc.flush()
    st = svc.stats()
    assert st["users"] == 10 and st["batches"] == 2
    res = svc.take_results()
    assert sum(r[0].shape[0] for r in res) == 10
    assert all(r[2].shape[1] == 3 for r in res)


def test_service_ingest_serves_new_items(indexed):
    sp, cfg, sigs, index = indexed
    params = init_from_data(jax.random.PRNGKey(1), sp, 16, 8)
    scfg = ServeConfig(topn=5, micro_batch=8, C=48, n_seeds=4, cap=8,
                       n_popular=0)
    svc = RecsysService(params, index, sp, scfg)
    # clone item 0's signature as a new item; it joins item 0's buckets
    svc.ingest(sigs[:, :1], jnp.asarray([sp.N], jnp.int32))
    assert svc.index.n_items == sp.N + 1
    cand = np.asarray(retrieve_for_items(
        svc.index, jnp.asarray([0], jnp.int32), cap=8, C=32))
    assert sp.N in cand[0]
