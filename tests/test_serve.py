"""repro.serve: bucketed index, retrieval, candidate-score kernel, service."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import simlsh, topk
from repro.core.model import (Params, init_from_data, pack_serve_planes,
                              unpack_serve_planes)
from repro.core.simlsh import SimLSHConfig
from repro.data.sparse import from_coo
from repro.kernels.candidate_score.kernel import NEG, candidate_score_topn
from repro.kernels.candidate_score.ops import score_candidates
from repro.kernels.candidate_score.ref import candidate_score_topn_ref
from repro.serve import (RecsysService, ServeConfig, build_index,
                         compact_pool, dedup_candidates, insert,
                         lookup_items, lookup_signatures, rebuild,
                         retrieve_for_items, retrieve_for_users, seed_items)

SENTINEL = topk.SENTINEL
RNG = np.random.default_rng(0)


def _dup_matrix(M=200, half=30, seed=0):
    """Matrix whose column c+half duplicates column c exactly."""
    rng = np.random.default_rng(seed)
    rows = np.repeat(np.arange(M), 5).astype(np.int32)
    cols = rng.integers(0, half, M * 5).astype(np.int32)
    vals = rng.integers(1, 6, M * 5).astype(np.float32)
    rows2 = np.concatenate([rows, rows])
    cols2 = np.concatenate([cols, cols + half])
    vals2 = np.concatenate([vals, vals])
    keys = rows2.astype(np.int64) * (2 * half) + cols2
    _, uniq = np.unique(keys, return_index=True)
    return from_coo(rows2[uniq], cols2[uniq], vals2[uniq], (M, 2 * half))


@pytest.fixture(scope="module")
def indexed():
    sp = _dup_matrix()
    cfg = SimLSHConfig(G=8, p=2, q=8)
    sigs = simlsh.encode(sp, cfg, jax.random.PRNGKey(0))
    return sp, cfg, sigs, build_index(sigs, tail_cap=32)


# ---------------------------------------------------------------- index

def test_bucket_membership_roundtrip_vs_band_candidates(indexed):
    """Index mates = same-signature items, consistent with band_candidates."""
    sp, cfg, sigs, index = indexed
    N = sp.N
    cap = 8
    ids = jnp.arange(N, dtype=jnp.int32)
    mates = np.asarray(lookup_items(index, ids, cap=cap,
                                    include_tail=False)).reshape(N, cfg.q, cap)
    sigs_np = np.asarray(sigs)
    bc = np.asarray(jax.vmap(
        lambda s: topk.band_candidates(s, band_cap=cap))(sigs))   # [q, N, cap]
    for b in range(cfg.q):
        bucket_size = {s: c for s, c in
                       zip(*np.unique(sigs_np[b], return_counts=True))}
        for j in range(N):
            got = set(mates[j, b][mates[j, b] != SENTINEL])
            # membership: every mate shares the band signature
            assert all(sigs_np[b, m] == sigs_np[b, j] for m in got)
            assert j in got  # the item itself is always a bucket member
            # small buckets: exact agreement with the sort-based path
            if bucket_size[sigs_np[b, j]] <= cap // 2:
                ref = set(bc[b, j][bc[b, j] != SENTINEL]) | {j}
                assert got == ref


def test_lookup_signatures_finds_exact_buckets(indexed):
    sp, cfg, sigs, index = indexed
    qsigs = jnp.asarray(np.asarray(sigs)[:, :16].T)               # [16, q]
    cand = np.asarray(lookup_signatures(index, qsigs, cap=8, n_probe=2))
    sigs_np = np.asarray(sigs)
    for i in range(16):
        got = cand[i][cand[i] != SENTINEL]
        assert i in got  # probing with item i's own signatures finds i


def test_retrieval_recall_vs_bruteforce_cosine():
    """Candidates of an item must cover its brute-force cosine top-K on a
    matrix with planted column clusters (same-group columns share raters)."""
    rng = np.random.default_rng(0)
    n_groups, ipg, upg, deg = 12, 10, 24, 16     # N=120 items, M=288 users
    N, M = n_groups * ipg, n_groups * upg
    cols = np.arange(N, dtype=np.int32).repeat(deg)
    pick = np.argsort(rng.random((N, upg)), axis=1)[:, :deg]
    rows = (pick + (np.arange(N) // ipg)[:, None] * upg).reshape(-1)
    vals = rng.uniform(3, 5, rows.shape[0]).astype(np.float32)
    sp = from_coo(rows.astype(np.int32), cols, vals, (M, N))

    dense = np.zeros(sp.shape, np.float32)
    dense[np.asarray(sp.rows), np.asarray(sp.cols)] = np.asarray(sp.vals)
    norm = dense / np.maximum(np.linalg.norm(dense, axis=0, keepdims=True),
                              1e-9)
    cos = norm.T @ norm
    np.fill_diagonal(cos, -1.0)
    K = 3
    exact = np.argsort(-cos, axis=1)[:, :K]

    cfg = SimLSHConfig(G=8, p=1, q=12)
    sigs = simlsh.encode(sp, cfg, jax.random.PRNGKey(0))
    index = build_index(sigs, tail_cap=32)
    cand = np.asarray(retrieve_for_items(
        index, jnp.arange(N, dtype=jnp.int32), cap=8, C=32))
    hits = sum(len(set(cand[j][cand[j] != SENTINEL]) & set(exact[j]))
               for j in range(N))
    recall = hits / (N * K)
    # C=32 of 120 items → chance recall ≈ 0.27; demand far better
    assert recall >= 0.7, f"recall@{K} vs cosine = {recall:.3f}"


def test_retrieval_always_finds_duplicate_partner(indexed):
    """Exact duplicate columns collide in every band → always retrieved."""
    sp, cfg, sigs, index = indexed
    cand = np.asarray(retrieve_for_items(
        index, jnp.arange(sp.N, dtype=jnp.int32), cap=8, C=64))
    half = sp.N // 2
    partners = (np.arange(sp.N) + half) % sp.N
    dup_hits = np.mean([partners[j] in set(cand[j]) for j in range(sp.N)])
    assert dup_hits == 1.0


def test_insert_then_lookup_and_rebuild(indexed):
    sp, cfg, sigs, index = indexed
    N = sp.N
    # clone three existing items into the tail
    src = jnp.asarray([0, 5, 9], jnp.int32)
    new_ids = jnp.asarray([N, N + 1, N + 2], jnp.int32)
    idx2 = insert(index, sigs[:, np.asarray(src)], new_ids)
    assert idx2.n_items == N + 3

    mates = np.asarray(lookup_items(idx2, src, cap=8))
    for r, nid in enumerate(np.asarray(new_ids)):
        assert nid in mates[r], "tail item not reachable from its bucket"
    # tail item as the query finds its base-bucket mates
    back = np.asarray(lookup_items(idx2, new_ids, cap=8))
    for r, s in enumerate(np.asarray(src)):
        assert s in back[r]

    # rebuild folds the tail into the sorted core; membership is preserved
    full_sigs = jnp.concatenate([sigs, sigs[:, np.asarray(src)]], axis=1)
    idx3 = rebuild(idx2, full_sigs)
    assert int(idx3.tail_len) == 0
    mates3 = np.asarray(lookup_items(idx3, src, cap=8, include_tail=False))
    for r, nid in enumerate(np.asarray(new_ids)):
        assert nid in mates3[r]


def test_insert_overflow_raises(indexed):
    sp, cfg, sigs, index = indexed
    with pytest.raises(ValueError, match="tail overflow"):
        insert(index, jnp.tile(sigs[:, :1], (1, 33)),
               jnp.arange(sp.N, sp.N + 33, dtype=jnp.int32))


# ---------------------------------------------------------------- retrieval

def test_dedup_candidates_unique_and_excludes():
    cands = jnp.asarray([[3, 1, 3, SENTINEL, 1, 7, 2, 2],
                         [5, 5, 5, 5, 5, 5, 5, 5]], jnp.int32)
    out = np.asarray(dedup_candidates(cands, C=6))
    assert sorted(out[0]) == [1, 2, 3, 7, SENTINEL, SENTINEL]
    assert sorted(out[1]) == [5] + [SENTINEL] * 5
    assert np.all(out[0][4:] == SENTINEL), "padding must sort last"
    out = np.asarray(dedup_candidates(
        cands, C=6, exclude_sorted=jnp.asarray([2, 5], jnp.int32)))
    assert sorted(out[0]) == [1, 3, 7, SENTINEL, SENTINEL, SENTINEL]
    assert list(out[1]) == [SENTINEL] * 6


def test_dedup_truncation_not_biased_against_high_ids():
    # overflow truncation must not systematically evict the largest ids
    # (newly ingested items always have the highest ids)
    row = jnp.arange(64, dtype=jnp.int32)[None, :]
    out = np.asarray(dedup_candidates(row, C=16))[0]
    kept = out[out != SENTINEL]
    assert len(kept) == 16
    assert (kept >= 48).any(), "top-quartile ids entirely evicted"


def test_dedup_property_unique_set_and_hashed_truncation():
    """Property sweep: (a) when a row has ≤ C unique ids the output is
    *exactly* the unique set (minus exclusions); (b) on overflow the kept
    ids are the C smallest under the invertible hash — the unbiased
    truncation order — and are always a duplicate-free subset."""
    _hash = lambda x: (x.astype(np.int64) * np.uint32(2654435761)) % (1 << 30)
    rng = np.random.default_rng(7)
    for trial in range(25):
        B = int(rng.integers(1, 5))
        L = int(rng.integers(1, 48))
        C = int(rng.integers(1, 40))
        ids = rng.integers(0, 60, (B, L)).astype(np.int32)
        ids[rng.random((B, L)) < 0.3] = SENTINEL
        excl = np.unique(rng.integers(0, 60, 4).astype(np.int32)) \
            if trial % 2 else None
        out = np.asarray(dedup_candidates(
            jnp.asarray(ids), C=C,
            exclude_sorted=jnp.asarray(excl) if excl is not None else None))
        assert out.shape == (B, C)
        for b in range(B):
            want = set(ids[b][ids[b] != SENTINEL])
            if excl is not None:
                want -= set(excl)
            got = out[b][out[b] != SENTINEL]
            assert len(got) == len(set(got)), "duplicates in dedup output"
            if len(want) <= C:
                assert set(got) == want, f"unique set not preserved (b={b})"
            else:
                assert len(got) == C
                kept = sorted(want, key=lambda x: _hash(np.int32(x)))[:C]
                assert set(got) == set(kept), "not the hash-order prefix"


def test_compact_pool_preserves_order_and_drops_sentinels():
    pool = jnp.asarray([[SENTINEL, 4, SENTINEL, 9, 2, SENTINEL, 7, 1],
                        [SENTINEL] * 8], jnp.int32)
    out = np.asarray(compact_pool(pool, width=5))
    assert list(out[0]) == [4, 9, 2, 7, 1]
    assert list(out[1]) == [SENTINEL] * 5
    # overflow drops the tail of the row, never reorders the kept prefix
    out = np.asarray(compact_pool(pool, width=3))
    assert list(out[0]) == [4, 9, 2]


def test_fold_prefix_runs_merges_pairs():
    from repro.serve.retrieve import _fold_prefix_runs
    S = SENTINEL
    runs = jnp.asarray([[[1, 2, S, S], [3, S, S, S]],
                        [[S, S, S, S], [4, 5, 6, 7]]], jnp.int32)
    out = np.asarray(_fold_prefix_runs(runs))        # cap=4 → width 6
    assert out.shape == (2, 1, 6)
    assert list(out[0, 0]) == [1, 2, 3, S, S, S]
    assert list(out[1, 0]) == [4, 5, 6, 7, S, S]
    # overflow: 4+4 survivors into 6 slots → right run's tail dropped
    full = jnp.asarray([[[1, 2, 3, 4], [5, 6, 7, 8]]], jnp.int32)
    assert list(np.asarray(_fold_prefix_runs(full))[0, 0]) == [1, 2, 3, 4, 5, 6]
    # odd run counts pass the last run through (padded to the fold width)
    odd = jnp.asarray([[[1, 2, S, S], [3, S, S, S], [9, S, S, S]]], jnp.int32)
    out = np.asarray(_fold_prefix_runs(odd))
    assert out.shape == (1, 2, 6) and list(out[0, 1]) == [9, S, S, S, S, S]


def test_retrieve_pool_width_keeps_popular_and_uniqueness(indexed):
    sp, cfg, sigs, index = indexed
    users = jnp.arange(16, dtype=jnp.int32)
    popular = jnp.asarray([2, 11, 17], jnp.int32)
    cand = np.asarray(retrieve_for_users(
        index, sp, users, n_seeds=4, cap=8, C=32, popular=popular,
        pool_width=64))
    assert cand.shape == (16, 32)
    for u in range(16):
        v = cand[u][cand[u] != SENTINEL]
        assert len(v) == len(set(v)), "duplicate candidates"
        assert {2, 11, 17} <= set(v), "popularity shortlist not reserved"


def test_seed_items_are_top_rated(indexed):
    sp, *_ = indexed
    users = jnp.arange(8, dtype=jnp.int32)
    seeds = np.asarray(seed_items(sp, users, n_seeds=4, window=32))
    dense = np.zeros(sp.shape, np.float32)
    dense[np.asarray(sp.rows), np.asarray(sp.cols)] = np.asarray(sp.vals)
    for u in range(8):
        s = seeds[u][seeds[u] != SENTINEL]
        assert len(s) > 0
        rated = dense[u][s]
        assert np.all(rated > 0), "seed item the user never rated"
        assert rated.min() >= np.sort(dense[u][dense[u] > 0])[::-1][
            :len(s)].min() - 1e-6


def test_retrieve_for_users_shapes_and_popular(indexed):
    sp, cfg, sigs, index = indexed
    users = jnp.arange(16, dtype=jnp.int32)
    popular = jnp.asarray([2, 11, 17], jnp.int32)
    cand = np.asarray(retrieve_for_users(
        index, sp, users, n_seeds=4, cap=8, C=32, popular=popular))
    assert cand.shape == (16, 32)
    for u in range(16):
        v = cand[u][cand[u] != SENTINEL]
        assert len(v) == len(set(v)), "duplicate candidates"
        assert {2, 11, 17} <= set(v), "popularity shortlist not reserved"


# ---------------------------------------------------------------- kernel


def _plane_args(B, C, F, N, rng, mask_p=0.7):
    """Random serve-plane scorer operands: urow [B, F+1] (μ+b folded in),
    plane [N, F+1], cand ids [B, C] (pre-clipped), mask [B, C]."""
    a = lambda *s: jnp.asarray(rng.normal(size=s).astype(np.float32))
    urow, plane = a(B, F + 1), a(N, F + 1)
    cand = jnp.asarray(rng.integers(0, N, (B, C)).astype(np.int32))
    mask = jnp.asarray((rng.random((B, C)) < mask_p).astype(np.float32))
    return urow, plane, cand, mask


@pytest.mark.parametrize("B,C,F,topn,tile", [
    (32, 64, 16, 10, 8), (7, 33, 8, 5, 16), (64, 128, 32, 1, 32)])
def test_candidate_score_kernel_matches_ref(B, C, F, topn, tile):
    """In-kernel gather path (interpret) ≡ tiled-scan jnp ref."""
    urow, plane, cand, mask = _plane_args(B, C, F, 200,
                                          np.random.default_rng(B * 3 + C))
    s1, i1 = candidate_score_topn(urow, plane, cand, mask, topn=topn,
                                  tile_b=tile)
    s2, i2 = candidate_score_topn_ref(urow, plane, cand, mask, topn=topn,
                                      tile_b=tile)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


def test_candidate_score_kernel_all_masked_rows():
    urow, plane, cand, _ = _plane_args(9, 16, 8, 64, np.random.default_rng(5))
    mask = jnp.zeros((9, 16), jnp.float32)
    s1, i1 = candidate_score_topn(urow, plane, cand, mask, topn=4, tile_b=4)
    s2, i2 = candidate_score_topn_ref(urow, plane, cand, mask, topn=4,
                                      tile_b=4)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))


def _pr1_cube_scorer(params, user_ids, cand, *, topn):
    """The PR 1 scorer math — XLA-gathered [B, C, F] cube + `top_k` — as
    the old-vs-new parity oracle (same first-index tie rule)."""
    safe = jnp.clip(cand, 0, params.V.shape[0] - 1)
    mask = cand != SENTINEL
    s = (jnp.einsum("bf,bcf->bc", params.U[user_ids], params.V[safe])
         + params.bh[safe] + (params.mu + params.b[user_ids])[:, None])
    scores, idx = jax.lax.top_k(jnp.where(mask, s, NEG), topn)
    items = jnp.take_along_axis(cand, idx, axis=1)
    return scores, jnp.where(scores > NEG, items, SENTINEL)


@pytest.mark.parametrize("impl", ["ref", "pallas"])
@pytest.mark.parametrize("C,topn,tile", [(32, 5, 8), (48, 10, 16), (24, 3, 4)])
def test_scorer_matches_pr1_cube_scorer(indexed, impl, C, topn, tile):
    """New plane scorer ≡ the old cube scorer on identical candidate sets,
    across tile_b/C/topn sweeps and both impls (ISSUE 5 parity gate)."""
    sp, cfg, sigs, index = indexed
    params = init_from_data(jax.random.PRNGKey(1), sp, 16, 8)
    planes = pack_serve_planes(params)
    users = jnp.arange(24, dtype=jnp.int32)
    cand = retrieve_for_users(index, sp, users, n_seeds=4, cap=8, C=C)
    s_new, i_new = score_candidates(planes, users, cand, topn=topn,
                                    tile_b=tile, impl=impl)
    s_old, i_old = _pr1_cube_scorer(params, users, cand, topn=topn)
    np.testing.assert_allclose(np.asarray(s_new), np.asarray(s_old),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(i_new), np.asarray(i_old))
    # returned items must come from the candidate set
    c = np.asarray(cand)
    for u in range(24):
        got = np.asarray(i_new[u])
        assert set(got[got != SENTINEL]) <= set(c[u])


def test_score_candidates_accepts_params_and_planes(indexed):
    """`Params` is packed on the fly — same result as prebuilt planes."""
    sp, cfg, sigs, index = indexed
    params = init_from_data(jax.random.PRNGKey(1), sp, 16, 8)
    users = jnp.arange(8, dtype=jnp.int32)
    cand = retrieve_for_users(index, sp, users, n_seeds=4, cap=8, C=32)
    s1, i1 = score_candidates(params, users, cand, topn=5, impl="ref")
    s2, i2 = score_candidates(pack_serve_planes(params), users, cand,
                              topn=5, impl="ref")
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


def test_serve_planes_roundtrip(indexed):
    sp, *_ = indexed
    params = init_from_data(jax.random.PRNGKey(2), sp, 16, 8)
    back = unpack_serve_planes(pack_serve_planes(params))
    for f in ("U", "V", "b", "bh", "mu"):
        np.testing.assert_array_equal(np.asarray(getattr(back, f)),
                                      np.asarray(getattr(params, f)))


def test_scorer_hlo_has_no_candidate_cube():
    """ISSUE 5 acceptance: no gather in the scorer's HLO produces a
    B×C×F (or B×C×(F+1)) intermediate — only the tile-sized one."""
    B, C, F, N, tile = 64, 96, 24, 4000, 8
    rng = np.random.default_rng(0)
    planes_args = _plane_args(B, C, F, N, rng)
    users = jnp.arange(B, dtype=jnp.int32)
    params = Params(U=planes_args[1][:, :F], V=planes_args[1][:, :F],
                    b=jnp.zeros((N,)), bh=planes_args[1][:, F],
                    W=jnp.zeros((N, 0)), C=jnp.zeros((N, 0)),
                    mu=jnp.asarray(0.0))
    planes = pack_serve_planes(params)
    cand = planes_args[2]
    for impl in ("ref", "pallas"):
        txt = jax.jit(
            lambda p, u, c, impl=impl: score_candidates(
                p, u, c, topn=10, tile_b=tile, interpret=True, impl=impl)
        ).lower(planes, users[:B], cand).as_text()
        for bad in (f"{B}x{C}x{F}xf32", f"{B}x{C}x{F + 1}xf32"):
            assert bad not in txt, f"candidate cube {bad} in {impl} HLO"
    # the check looks at real lowered text: the ref's *tile* gather is there
    txt = jax.jit(
        lambda p, u, c: score_candidates(p, u, c, topn=10, tile_b=tile,
                                         interpret=True, impl="ref")
    ).lower(planes, users[:B], cand).as_text()
    assert f"{tile}x{C}x{F + 1}xf32" in txt


# ---------------------------------------------------------------- service

def test_service_candidate_matches_full_on_candidates(indexed):
    """Candidate-mode top-1 score equals the full-mode score of that item."""
    sp, cfg, sigs, index = indexed
    params = init_from_data(jax.random.PRNGKey(1), sp, 16, 8)
    scfg = ServeConfig(topn=5, micro_batch=16, C=48, n_seeds=4, cap=8,
                       n_popular=8)
    svc = RecsysService(params, index, sp, scfg).warmup()
    full = RecsysService(params, index, sp,
                         dataclasses.replace(scfg, mode="full")).warmup()
    users = np.arange(16, dtype=np.int32)
    svc.submit(users); svc.flush()
    full.submit(users); full.flush()
    _, s_c, i_c = svc.take_results()[0]
    _, s_f, i_f = full.take_results()[0]
    # every candidate-mode score must equal the exact score of that item
    exact = (np.asarray(params.mu) + np.asarray(params.b)[users][:, None]
             + np.asarray(params.bh)[i_c]
             + np.einsum("bf,bnf->bn", np.asarray(params.U)[users],
                         np.asarray(params.V)[i_c]))
    np.testing.assert_allclose(s_c, exact, rtol=1e-4, atol=1e-4)
    st = svc.stats()
    assert st["users"] == 16 and st["batches"] == 1


def test_service_micro_batching_and_partial_flush(indexed):
    sp, cfg, sigs, index = indexed
    params = init_from_data(jax.random.PRNGKey(1), sp, 16, 8)
    scfg = ServeConfig(topn=3, micro_batch=8, C=32, n_seeds=4, cap=8,
                       n_popular=0)
    svc = RecsysService(params, index, sp, scfg)
    svc.submit(np.arange(5));   assert svc.stats()["batches"] == 0
    svc.submit(np.arange(5));   assert svc.stats()["batches"] == 1
    svc.flush()
    st = svc.stats()
    assert st["users"] == 10 and st["batches"] == 2
    res = svc.take_results()
    assert sum(r[0].shape[0] for r in res) == 10
    assert all(r[2].shape[1] == 3 for r in res)


def test_pipelined_flush_ordering_maps_results_to_users(indexed):
    """Dispatch-ahead flushes must hand each user their own result, in
    flush order, with the padded final batch stripped correctly.  Params
    are planted so user u's exact top-1 item is u itself (U = 5·I,
    V = I): any cross-flush or cross-row mixup is immediately visible."""
    sp, cfg, sigs, index = indexed
    M = N = F = 16
    eye = jnp.eye(M, dtype=jnp.float32)
    params = Params(U=5.0 * eye, V=eye, b=jnp.zeros((M,)),
                    bh=jnp.zeros((N,)), W=jnp.zeros((N, 1)),
                    C=jnp.zeros((N, 1)), mu=jnp.asarray(0.0))
    scfg = ServeConfig(mode="full", topn=3, micro_batch=M, n_popular=0)
    svc = RecsysService(params, index, sp, scfg).warmup()
    rng = np.random.default_rng(11)
    users = rng.integers(0, M, 3 * M + 5).astype(np.int32)
    for chunk in np.split(users, [7, 20, 29, 41]):   # ragged submits
        svc.submit(chunk)
    assert svc.stats()["batches"] == 3               # dispatched, not synced
    svc.flush()
    res = svc.take_results()
    assert len(res) == 4 and res[-1][0].shape[0] == 5   # padded final batch
    got_users = np.concatenate([r[0] for r in res])
    np.testing.assert_array_equal(got_users, users)     # flush order kept
    for r_users, _, r_items in res:
        np.testing.assert_array_equal(r_items[:, 0], r_users)
    st = svc.stats()
    assert st["users"] == users.shape[0] and st["batches"] == 4
    assert st["qps"] > 0 and st["p95_ms"] >= st["p50_ms"]


def test_pipelined_flush_ordering_candidate_mode(indexed):
    """Same per-user identity check through the fused candidate pipeline:
    every top-1 score must equal that item's exact full score for *that*
    user — a result swapped across in-flight flushes would not."""
    sp, cfg, sigs, index = indexed
    params = init_from_data(jax.random.PRNGKey(1), sp, 16, 8)
    scfg = ServeConfig(topn=3, micro_batch=8, C=32, n_seeds=4, cap=8,
                       n_popular=0)
    svc = RecsysService(params, index, sp, scfg).warmup()
    users = np.arange(24, dtype=np.int32)
    for u in users:          # one-at-a-time submits → 3 pipelined flushes
        svc.submit(u)
    svc.flush()
    res = svc.take_results()
    assert [r[0].shape[0] for r in res] == [8, 8, 8]
    for r_users, r_scores, r_items in res:
        safe = np.clip(r_items, 0, sp.N - 1)
        exact = (np.asarray(params.mu) + np.asarray(params.b)[r_users][:, None]
                 + np.asarray(params.bh)[safe]
                 + np.einsum("bf,bnf->bn", np.asarray(params.U)[r_users],
                             np.asarray(params.V)[safe]))
        ok = r_items != SENTINEL
        np.testing.assert_allclose(r_scores[ok], exact[ok],
                                   rtol=1e-4, atol=1e-4)


def test_service_ingest_serves_new_items(indexed):
    sp, cfg, sigs, index = indexed
    params = init_from_data(jax.random.PRNGKey(1), sp, 16, 8)
    scfg = ServeConfig(topn=5, micro_batch=8, C=48, n_seeds=4, cap=8,
                       n_popular=0)
    svc = RecsysService(params, index, sp, scfg)
    # clone item 0's signature as a new item; it joins item 0's buckets
    svc.ingest(sigs[:, :1], jnp.asarray([sp.N], jnp.int32))
    assert svc.index.n_items == sp.N + 1
    cand = np.asarray(retrieve_for_items(
        svc.index, jnp.asarray([0], jnp.int32), cap=8, C=32))
    assert sp.N in cand[0]
