"""Multi-device correctness (subprocess with 4 host devices):
rotation == sequential, MoE a2a == dense ref (+grads), int8 psum with
error feedback, and the dry-run machinery on a 2×2 mesh."""
import os
import subprocess
import sys

import pytest

# chaos / subprocess-heavy: CI splits these into their own step
pytestmark = pytest.mark.slow

HELPER = os.path.join(os.path.dirname(__file__), "helpers",
                      "multidev_checks.py")


def _run(name, timeout=900):
    env = os.environ.copy()
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(__file__)), "src")
    r = subprocess.run([sys.executable, HELPER, name], capture_output=True,
                       text=True, timeout=timeout, env=env)
    assert r.returncode == 0, f"{name} failed:\n{r.stdout}\n{r.stderr}"
    assert f"PASS {name}" in r.stdout


# rotation/compression import `repro.dist.{rotation,compression}`, a module
# the seed commit references but never shipped — xfail until someone either
# recovers/rewrites it or deletes the checks (tracked in ARCHITECTURE.md §10).
_MISSING_DIST = pytest.mark.xfail(
    reason="seed-vestigial: repro.dist module missing from the seed commit",
    strict=True)


@pytest.mark.parametrize("check", [
    pytest.param("rotation", marks=_MISSING_DIST),
    "moe_a2a", "moe_ep2d",
    pytest.param("compression", marks=_MISSING_DIST),
    "elastic", "small_dryrun", "sharded_epoch", "sharded_serve"])
def test_multidevice(check):
    _run(check)
