"""GSM oracle, Eq. (1) prediction, Eq. (5) updates, end-to-end fit."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gsm, model, sgd
from repro.data.sparse import from_coo
from repro.train.trainer import FitConfig, fit
from repro.core.simlsh import SimLSHConfig


def test_gsm_topk_matches_bruteforce():
    rng = np.random.default_rng(0)
    M, N, K = 60, 25, 5
    dense = (rng.uniform(0, 1, (M, N)) < 0.4) * rng.integers(1, 6, (M, N))
    rows, cols = np.nonzero(dense)
    sp = from_coo(rows.astype(np.int32), cols.astype(np.int32),
                  dense[rows, cols].astype(np.float32), (M, N))
    got = np.asarray(gsm.gsm_topk(sp, K=K, lam_rho=100.0, block=8))

    # brute force shrunk Pearson
    X = dense.astype(np.float64)
    B = (dense != 0).astype(np.float64)
    S = np.full((N, N), -np.inf)
    for j1 in range(N):
        for j2 in range(N):
            if j1 == j2:
                continue
            both = (B[:, j1] * B[:, j2]) > 0
            n = both.sum()
            m1 = X[B[:, j1] > 0, j1].mean()
            m2 = X[B[:, j2] > 0, j2].mean()
            d1 = ((X[both, j1] - m1) ** 2).sum()
            d2 = ((X[both, j2] - m2) ** 2).sum()
            num = ((X[both, j1] - m1) * (X[both, j2] - m2)).sum()
            rho = num / np.sqrt(max(d1 * d2, 1e-12))
            S[j1, j2] = n / (n + 100.0) * rho
    # compare top-K *scores* (ties can reorder ids)
    for j in range(N):
        want = np.sort(S[j])[::-1][:K]
        have = np.sort(S[j, got[j]])[::-1]
        np.testing.assert_allclose(have, want, rtol=1e-4, atol=1e-5)


def test_predict_matches_manual():
    rng = np.random.default_rng(0)
    M, N, F, K, B = 10, 8, 4, 3, 6
    p = model.Params(
        U=jnp.asarray(rng.normal(size=(M, F)), jnp.float32),
        V=jnp.asarray(rng.normal(size=(N, F)), jnp.float32),
        b=jnp.asarray(rng.normal(size=(M,)), jnp.float32),
        bh=jnp.asarray(rng.normal(size=(N,)), jnp.float32),
        W=jnp.asarray(rng.normal(size=(N, K)), jnp.float32),
        C=jnp.asarray(rng.normal(size=(N, K)), jnp.float32),
        mu=jnp.float32(3.1))
    i = jnp.asarray(rng.integers(0, M, B), jnp.int32)
    j = jnp.asarray(rng.integers(0, N, B), jnp.int32)
    nb = jnp.asarray(rng.integers(0, N, (B, K)), jnp.int32)
    rnb = jnp.asarray(rng.integers(1, 6, (B, K)), jnp.float32)
    expl = jnp.asarray(rng.integers(0, 2, (B, K)), jnp.float32)
    bt = model.Batch(i, j, jnp.zeros((B,)), nb, rnb * expl, expl, 1 - expl,
                     jnp.ones((B,)))
    pred, _ = model.predict(p, bt)

    for b_ in range(B):
        mu, bi, bj = float(p.mu), float(p.b[i[b_]]), float(p.bh[j[b_]])
        base = mu + bi + bj
        nR = float(expl[b_].sum()); nN = K - nR
        ex = im = 0.0
        for k in range(K):
            bbar_nb = mu + bi + float(p.bh[nb[b_, k]])
            if expl[b_, k]:
                ex += (float(rnb[b_, k]) - bbar_nb) * float(p.W[j[b_], k])
            else:
                im += float(p.C[j[b_], k])
        ex *= nR ** -0.5 if nR else 0.0
        im *= nN ** -0.5 if nN else 0.0
        dot = float(jnp.dot(p.U[i[b_]], p.V[j[b_]]))
        assert abs(float(pred[b_]) - (base + ex + im + dot)) < 1e-4


def test_culsh_step_single_sample_eq5():
    """One sample, hand-computed Eq. (5)."""
    hp = sgd.Hyper()
    p = model.init_params(jax.random.PRNGKey(0), 5, 4, 3, 2, mu=3.0)
    p = dataclasses.replace(p, b=jnp.ones((5,)) * 0.1, bh=jnp.ones((4,)) * 0.2,
                            W=jnp.ones((4, 2)) * 0.3, C=jnp.ones((4, 2)) * 0.4)
    bt = model.Batch(
        i=jnp.asarray([1]), j=jnp.asarray([2]), r=jnp.asarray([4.5]),
        nb=jnp.asarray([[0, 3]]), rnb=jnp.asarray([[5.0, 0.0]]),
        expl=jnp.asarray([[1.0, 0.0]]), impl=jnp.asarray([[0.0, 1.0]]),
        valid=jnp.asarray([1.0]))
    pred, aux = model.predict(p, bt)
    e = 4.5 - float(pred[0])
    p2 = sgd.culsh_step(p, bt, hp, jnp.float32(1.0))
    assert abs(float(p2.b[1]) - (0.1 + hp.a_b * (e - hp.l_b * 0.1))) < 1e-5
    assert abs(float(p2.bh[2]) - (0.2 + hp.a_bh * (e - hp.l_bh * 0.2))) < 1e-5
    resid = 5.0 - (3.0 + 0.1 + 0.2)   # r_nb − b̄_i,nb0 (bh[0]=0.2)
    want_w = 0.3 + hp.a_w * (1.0 * e * resid - hp.l_w * 0.3)
    assert abs(float(p2.W[2, 0]) - want_w) < 1e-5
    want_c = 0.4 + hp.a_c * (1.0 * e - hp.l_c * 0.4)
    assert abs(float(p2.C[2, 1]) - want_c) < 1e-5
    # untouched slots stay put (f32 literal comparison)
    assert float(p2.W[2, 1]) == float(np.float32(0.3))
    assert float(p2.C[2, 0]) == float(np.float32(0.4))
    # U/V rows
    u1, v2_ = np.asarray(p.U[1]), np.asarray(p.V[2])
    np.testing.assert_allclose(np.asarray(p2.U[1]),
                               u1 + hp.a_u * (e * v2_ - hp.l_u * u1), rtol=2e-5)
    np.testing.assert_allclose(np.asarray(p2.V[2]),
                               v2_ + hp.a_v * (e * u1 - hp.l_v * v2_), rtol=2e-5)


def test_lr_decay_eq7():
    hp = sgd.Hyper(beta=0.3)
    t = jnp.asarray(4.0)
    assert abs(float(sgd.lr_decay(hp, t)) - 1 / (1 + 0.3 * 4 ** 1.5)) < 1e-6


def test_fit_improves_rmse(tiny_dataset):
    spec, rows, cols, vals, _ = tiny_dataset
    cut = int(len(vals) * 0.9)
    cfg = FitConfig(F=8, K=4, epochs=3, batch=1024, method="simlsh",
                    lsh=SimLSHConfig(G=8, p=1, q=4, band_cap=8))
    res = fit((rows[:cut], cols[:cut], vals[:cut]),
              (rows[cut:], cols[cut:], vals[cut:]),
              (spec.M, spec.N), cfg)
    rmses = [h[2] for h in res.history]
    assert rmses[-1] < rmses[0]
    assert rmses[-1] < np.std(vals) * 1.2   # beats predicting the mean badly
