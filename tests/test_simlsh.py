"""simLSH encoding + Top-K properties (paper C1)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import baselines as bl
from repro.core import gsm, simlsh, topk
from repro.data.sparse import from_coo


def _dup_matrix(M=200, half=20, seed=0):
    """Matrix whose column c+half duplicates column c exactly."""
    rng = np.random.default_rng(seed)
    rows = np.repeat(np.arange(M), 5).astype(np.int32)
    cols = rng.integers(0, half, M * 5).astype(np.int32)
    vals = rng.integers(1, 6, M * 5).astype(np.float32)
    rows2 = np.concatenate([rows, rows])
    cols2 = np.concatenate([cols, cols + half])
    vals2 = np.concatenate([vals, vals])
    key = rows2.astype(np.int64) * (2 * half) + cols2
    _, uq = np.unique(key, return_index=True)
    return from_coo(rows2[uq], cols2[uq], vals2[uq], (M, 2 * half)), half


def test_duplicate_columns_collide():
    sp, half = _dup_matrix()
    cfg = simlsh.SimLSHConfig(G=8, p=2, q=10, band_cap=8)
    sigs = simlsh.encode(sp, cfg, jax.random.PRNGKey(0))
    assert bool(jnp.all(sigs[:, :half] == sigs[:, half:]))


def test_topk_finds_duplicates():
    sp, half = _dup_matrix()
    cfg = simlsh.SimLSHConfig(G=8, p=2, q=10, band_cap=8)
    key = jax.random.PRNGKey(0)
    sigs = simlsh.encode(sp, cfg, key)
    JK = topk.topk_from_signatures(sigs, key, K=4, band_cap=8)
    dup = jnp.arange(half)[:, None] + half
    assert float(jnp.mean((JK[:half] == dup).any(axis=1))) == 1.0


def test_recall_beats_random(tiny_dataset, tiny_sparse):
    _, _, _, _, group = tiny_dataset
    sp = tiny_sparse
    key = jax.random.PRNGKey(1)
    K = 8
    JK_gsm = gsm.gsm_topk(sp, K=K)
    cfg = simlsh.SimLSHConfig(G=8, p=1, q=20, band_cap=16)
    sigs = simlsh.encode(sp, cfg, key)
    JK = topk.topk_from_signatures(sigs, key, K=K, band_cap=16)
    JK_rand = bl.rand_topk(key, sp.N, K)

    def recall(j):
        return float(jnp.mean(jax.vmap(
            lambda a, b: jnp.mean(jnp.isin(a, b).astype(jnp.float32)))(j, JK_gsm)))

    assert recall(JK) > 1.5 * recall(JK_rand)


def test_online_accumulators_match_recompute(tiny_dataset):
    spec, rows, cols, vals, _ = tiny_dataset
    cut = len(vals) * 3 // 4
    sp_old = from_coo(rows[:cut], cols[:cut], vals[:cut], (spec.M, spec.N))
    sp_all = from_coo(rows, cols, vals, (spec.M, spec.N))
    cfg = simlsh.SimLSHConfig(G=8, p=2, q=4)
    key = jax.random.PRNGKey(0)
    _, S_old = simlsh.encode(sp_old, cfg, key, return_accumulators=True)
    S_inc, sigs_inc = simlsh.update_accumulators(
        S_old, rows[cut:], cols[cut:], vals[cut:], cfg, key, spec.N)
    sigs_full, S_full = simlsh.encode(sp_all, cfg, key,
                                      return_accumulators=True)
    np.testing.assert_allclose(np.asarray(S_inc), np.asarray(S_full),
                               rtol=1e-4, atol=1e-3)
    # signs may differ only where |S| ~ 0
    disagree = np.asarray(sigs_inc != sigs_full)
    assert disagree.mean() < 0.01


def test_empty_delta_is_identity(tiny_sparse):
    sp = tiny_sparse
    cfg = simlsh.SimLSHConfig(G=8, p=2, q=3)
    key = jax.random.PRNGKey(0)
    sigs, S = simlsh.encode(sp, cfg, key, return_accumulators=True)
    S2, sigs2 = simlsh.update_accumulators(
        S, jnp.zeros((0,), jnp.int32), jnp.zeros((0,), jnp.int32),
        jnp.zeros((0,), jnp.float32), cfg, key, sp.N)
    np.testing.assert_array_equal(np.asarray(sigs), np.asarray(sigs2))


@settings(max_examples=10, deadline=None)
@given(st.floats(1.0, 4.0), st.integers(0, 100))
def test_pack_bits_bijective_per_pattern(pow_, seed):
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 2, (16, 24)).astype(bool)
    packed = simlsh.pack_bits(jnp.asarray(bits))
    # distinct bit patterns → distinct signatures
    _, counts = np.unique(np.asarray(packed), return_counts=True)
    uniq_rows = np.unique(bits, axis=0).shape[0]
    assert len(counts) == uniq_rows


def test_topk_excludes_self_and_fills():
    # candidates all SENTINEL → pure random fill, never self
    cands = jnp.full((10, 6), topk.SENTINEL, jnp.int32)
    JK = topk.topk_frequent(cands, jax.random.PRNGKey(0), K=4)
    self_id = jnp.arange(10)[:, None]
    assert not bool(jnp.any(JK == self_id))


def test_topk_frequency_ordering():
    # row 0: candidate 7 appears 3×, candidate 3 appears 2×, 5 once
    row = jnp.asarray([[7, 7, 7, 3, 3, 5]], jnp.int32)
    JK = topk.topk_frequent(row, jax.random.PRNGKey(0), K=2)
    assert JK[0, 0] == 7 and JK[0, 1] == 3
