"""System-level benchmarks: Pallas kernels, rotation scaling, roofline table.

* kernels: interpret-mode µs/call vs the pure-jnp oracle (NOTE: interpret
  mode is a correctness harness — TPU wall-clock is the dry-run's domain);
* rotation: MCUSGD++ epoch on 1 vs 4 host devices (subprocess, own XLA
  device count — the paper's multi-GPU scaling experiment);
* roofline: re-emit the dry-run sweep's per-cell terms as CSV (reads
  reports/dryrun/16x16; run `python -m repro.launch.dryrun --all --roofline`
  first for the full table).
"""
from __future__ import annotations

import glob
import json
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed


def bench_kernels():
    from repro.kernels.mf_sgd.kernel import mf_sgd_step
    from repro.kernels.mf_sgd.ref import mf_sgd_step_ref
    from repro.kernels.neighbor_predict.kernel import neighbor_predict
    from repro.kernels.neighbor_predict.ref import neighbor_predict_ref
    from repro.kernels.simlsh_encode.kernel import simlsh_encode
    from repro.kernels.simlsh_encode.ref import simlsh_encode_ref
    rng = np.random.default_rng(0)

    N, deg, bits = 512, 128, 24
    psi = jnp.asarray(rng.normal(size=(N, deg)).astype(np.float32))
    phi = jnp.asarray(rng.choice([-1., 1.], (N, deg, bits)).astype(np.float32))
    _, t_int = timed(simlsh_encode, psi, phi, repeat=3)
    _, t_ref = timed(simlsh_encode_ref, psi, phi, repeat=3)
    emit("kernel.simlsh_encode.interpret", t_int,
         f"ref_us={t_ref*1e6:.0f};bytes={psi.nbytes + phi.nbytes}")

    B, F, K = 4096, 32, 32
    a = lambda *s: jnp.asarray(rng.normal(size=s).astype(np.float32))
    args = (a(B, F), a(B, F), a(B, K), a(B, K), a(B, K), a(B, K),
            a(B), a(B), a(B))
    _, t_int = timed(neighbor_predict, *args, repeat=3)
    _, t_ref = timed(neighbor_predict_ref, *args, repeat=3)
    emit("kernel.neighbor_predict.interpret", t_int, f"ref_us={t_ref*1e6:.0f}")

    u, v, r = a(B, F), a(B, F), a(B)
    valid = jnp.ones((B,), jnp.float32)
    _, t_int = timed(mf_sgd_step, u, v, r, valid, 0.02, 0.02, 0.01, 0.01,
                     repeat=3)
    _, t_ref = timed(mf_sgd_step_ref, u, v, r, valid, 0.02, 0.02, 0.01, 0.01,
                     repeat=3)
    emit("kernel.mf_sgd.interpret", t_int, f"ref_us={t_ref*1e6:.0f}")


ROTATION_SCRIPT = r"""
import os, sys, time
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d"
import dataclasses, jax, jax.numpy as jnp, numpy as np
from repro.core.sgd import Hyper
from repro.data import synthetic as syn
from repro.dist.rotation import make_rotation_epoch, stage_blocks
D = %d
M, N, F = 1024, 512, 32
spec = dataclasses.replace(syn.MOVIELENS_LIKE, M=M, N=N, nnz=60000)
rows, cols, vals, _ = syn.generate(spec, 0)
staged = stage_blocks(rows, cols, vals, M, N, D)
rng = np.random.default_rng(0)
U = jnp.asarray(rng.normal(size=(M, F)).astype(np.float32) * .1)
V = jnp.asarray(rng.normal(size=(N, F)).astype(np.float32) * .1)
mesh = jax.make_mesh((D,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
fn = jax.jit(make_rotation_epoch(mesh, D, M, N, Hyper(), batch=1024))
args = [jnp.asarray(staged[k]) for k in ("i", "j", "r", "valid")]
with jax.sharding.set_mesh(mesh):
    U1, V1 = fn(U, V, *args, jnp.asarray(0))   # compile
    jax.block_until_ready(U1)
    t0 = time.perf_counter()
    for e in range(3):
        U1, V1 = fn(U1, V1, *args, jnp.asarray(e))
    jax.block_until_ready(U1)
print((time.perf_counter() - t0) / 3)
"""


def bench_rotation():
    env = os.environ.copy()
    env["PYTHONPATH"] = "src"
    base = None
    for D in (1, 2, 4):
        r = subprocess.run([sys.executable, "-c", ROTATION_SCRIPT % (D, D)],
                           capture_output=True, text=True, env=env,
                           timeout=900)
        if r.returncode != 0:
            emit(f"rotation.D{D}", 0.0, "FAILED")
            continue
        secs = float(r.stdout.strip().splitlines()[-1])
        base = base or secs
        emit(f"rotation.D{D}", secs, f"speedup={base/secs:.2f}x")


def bench_roofline():
    files = sorted(glob.glob("reports/dryrun/16x16/*.json"))
    if not files:
        emit("roofline", 0.0, "no dry-run artifacts; run repro.launch.dryrun")
        return
    for f in files:
        rec = json.load(open(f))
        if rec.get("skipped") or "roofline" not in rec:
            continue
        r = rec["roofline"]
        emit(f"roofline.{rec['arch']}.{rec['shape']}", r["t_step"],
             f"bound={r['bound']};t_comp={r['t_compute']:.4g};"
             f"t_mem={r['t_memory']:.4g};t_coll={r['t_collective']:.4g};"
             f"useful={r['useful_ratio']:.3f}")


def run_all():
    bench_kernels()
    bench_rotation()
    bench_roofline()
