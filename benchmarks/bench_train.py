"""Training-throughput benchmark: the scheduled hot path, measured.

Compares, per synthetic Zipf scale, steady-state epoch time (jit compile
excluded via AOT `.lower().compile()`; **min over epochs** — this
container has noisy neighbours that inflate individual epochs 20–100%,
and the min is the standard noise-robust estimator of achievable cost,
applied identically to every path) and updates/sec for:

  * ``base``   — legacy `sgd.train_epoch`: per-batch B×K binary-search
    assembly + per-batch collision rescaling,
  * ``sched``  — `sgd.train_epoch_scheduled`: tiered conflict-free
    schedule scanned over the schedule-ordered `ScheduledData`
    (contiguous-slice assembly; scaled fallback for the zipf-head
    residue), parameters in the packed planes (`model.PackedParams`:
    2 scatters/step vs the legacy path's 6) donated across epochs,
  * ``kernel`` — same, with the fused `kernels/mf_sgd` step on every
    conflict-free tier (``impl="auto"``: pure-jnp ref on CPU, Pallas
    elsewhere).

Also trains both paths for equal epochs from the same init and reports the
held-out RMSE of each (via the per-fit `EvalCache` gather scan), so the
speedup is shown not to cost accuracy.  Results land in
``BENCH_train.json`` at the repo root (see --out).

    PYTHONPATH=src:. python benchmarks/bench_train.py [--scales small,medium,large]
        [--epochs 5] [--smoke] [--check] [--out BENCH_train.json]

``--check`` is the CI regression gate: it asserts the BENCH_train.json
floors (tiered cf_frac ≥ 0.8 everywhere; sched ≥ 2× the legacy path at
the recorded scales, ≥ 1.5× at smoke scale — see CHECK_SPEEDUP_SMOKE)
after the run and exits non-zero on regression.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro import obs
from repro.core import model, sgd, simlsh, topk
from repro.data import synthetic as syn
from repro.data.sparse import conflict_free_schedule, from_coo, train_test_split
from repro.kernels.mf_sgd.ops import resolve_impl

SCALES = {
    # name: (M, N, nnz, cf_batch, tiers, tier_shrink) — zipf-tailed via
    # synthetic.generate.  Schedule knobs are the measured per-scale sweet
    # spots: tier-0 width ≈ min(M, N) (widest steps amortize the fixed
    # per-step scatter cost), a ~quarter-octave shrink (0.71) so emitted
    # rounds are ≥71% full (cf_fill ≈ 0.89 vs 0.77 with plain halving),
    # and enough tiers that the deep zipf tail stays conflict-free
    # (cf_frac ≥ 0.85) instead of spilling to the scaled path.
    "smoke": (400, 100, 6_000, 96, 6, 0.71),
    "small": (1_500, 300, 60_000, 300, 7, 0.71),
    "medium": (3_000, 500, 150_000, 512, 7, 0.71),
    "large": (8_000, 2_000, 600_000, 2_048, 9, 0.71),
}
F, K = 32, 16
BATCH = 4096          # legacy-path batch (the trainer default)
# --check floors (ISSUE 3 / CI gate).  cf_frac is deterministic per seed;
# the wall-clock floor is 2.0 at the recorded bench scales but relaxed at
# smoke scale, where the legacy path is overhead-dominated (2 batches per
# epoch) and its structural speedup sits at ~2x — a 2.0 smoke floor would
# gate CI on noisy-neighbour luck, not on regressions.
CHECK_CF_FRAC = 0.8
CHECK_SPEEDUP = 2.0
CHECK_SPEEDUP_SMOKE = 1.5


def setup(name: str, seed: int = 0):
    M, N, nnz, cf_batch, _tiers, _shrink = SCALES[name]
    spec = dataclasses.replace(syn.MOVIELENS_LIKE, M=M, N=N, nnz=nnz)
    rows, cols, vals, _ = syn.generate(spec, seed=seed)
    rng = np.random.default_rng(seed)
    tr, te = train_test_split(rng, rows, cols, vals, 0.1)
    sp = from_coo(*tr, (M, N))
    key = jax.random.PRNGKey(seed)
    lsh = simlsh.SimLSHConfig(G=8, p=2, q=4, band_cap=16)
    sigs = simlsh.encode(sp, lsh, key)
    JK = topk.topk_from_signatures(sigs, jax.random.fold_in(key, 1), K=K,
                                   band_cap=lsh.band_cap)
    params = model.init_from_data(jax.random.fold_in(key, 2), sp, F, K)
    jax.block_until_ready(JK)
    return sp, JK, params, te, cf_batch, _tiers, _shrink


def run_epochs(compiled, run_args, params, epochs: int,
               reg: obs.Registry | None = None, name: str = "train.epoch"):
    """AOT-compiled epoch fn → (params, [sec/epoch]).

    With a registry, each epoch is an obs span and the reported times are
    the span durations read back from it — the bench shares the trainer's
    timing source (ISSUE 6) instead of a second stopwatch.  Without one
    (the disabled arm of the obs-overhead measurement) a plain stopwatch
    times the identical loop."""
    times = []
    for ep in range(epochs):
        if reg is not None and reg.enabled:
            with reg.span(name):
                params = compiled(params, *run_args(ep))
                jax.block_until_ready(jax.tree.leaves(params)[0])
            times.append(reg.span_durations(name)[-1])
        else:
            t0 = time.perf_counter()
            params = compiled(params, *run_args(ep))
            jax.block_until_ready(jax.tree.leaves(params)[0])
            times.append(time.perf_counter() - t0)
    return params, times


def obs_overhead(compiled, run_args, params0, epochs: int, copy) -> dict:
    """Enabled-vs-disabled obs cost on the steady-state epoch loop: same
    compiled fn, same data, the arms *interleaved* epoch by epoch so both
    sample the same noise window, with the arm order swapped every round
    (a fixed order biases whichever arm runs first into/out of noise
    bursts).  The statistic is the MEDIAN over rounds, not the min the
    rest of this bench uses: under bursty container noise the min
    decorrelates between arms (one lucky quiet window lands in a single
    arm and swings the ratio ±10–20% either way — measured), while the
    median of order-swapped interleaved rounds is a paired statistic that
    cancels the bursts.  The span-per-epoch cost is a few µs against
    ms..s epochs, so overhead_frac should sit well inside the ±2% target
    (noise can make it slightly negative)."""
    reg = obs.Registry(enabled=True)
    p_on, p_off = copy(params0), copy(params0)
    t_on, t_off = [], []

    def run_on(ep):
        nonlocal p_on
        with reg.span("train.epoch"):
            p_on = compiled(p_on, *run_args(ep))
            jax.block_until_ready(jax.tree.leaves(p_on)[0])
        t_on.append(reg.span_durations("train.epoch")[-1])

    def run_off(ep):
        nonlocal p_off
        t0 = time.perf_counter()
        p_off = compiled(p_off, *run_args(ep))
        jax.block_until_ready(jax.tree.leaves(p_off)[0])
        t_off.append(time.perf_counter() - t0)

    rounds = max(epochs, 12)
    for ep in range(rounds):
        first, second = (run_on, run_off) if ep % 2 == 0 else (run_off, run_on)
        first(ep)
        second(ep)
    on = float(np.median(t_on))
    off = float(np.median(t_off))
    return dict(enabled_sec_per_epoch=on, disabled_sec_per_epoch=off,
                overhead_frac=on / off - 1.0, rounds=rounds,
                statistic="median-over-interleaved-order-swapped-rounds")


def bench_scale(name: str, *, epochs: int, seed: int = 0,
                measure_overhead: bool = True) -> dict:
    # every timing below is an obs span read back from this registry —
    # the shared process registry when the caller enabled it (--trace),
    # else a private enabled one (obs.scoped())
    reg = obs.scoped()
    sp, JK, params0, te, cf_batch, tiers, shrink = setup(name, seed)
    te_r, te_c, te_v = (jnp.asarray(a) for a in te)
    hp = sgd.Hyper()
    k_ep = jax.random.PRNGKey(seed + 17)
    keys = lambda ep: jax.random.fold_in(k_ep, ep)
    copy = lambda p: jax.tree.map(jnp.copy, p)
    out = dict(name=name, M=sp.M, N=sp.N, nnz=sp.nnz, F=F, K=K,
               batch=BATCH, cf_batch=cf_batch, tiers=tiers,
               tier_shrink=shrink, epochs=epochs)
    ec = model.build_eval_cache(sp, JK, te_r, te_c)
    ev = lambda p: float(model.rmse_cached(p, ec, te_r, te_c, te_v))

    # --- base: legacy per-batch-search path -------------------------------
    with reg.span("train.compile.base"):
        base_fn = sgd.train_epoch.lower(
            params0, sp, JK, keys(0), jnp.asarray(0), hp,
            batch=BATCH).compile()
    p_base, times = run_epochs(
        base_fn, lambda ep: (sp, JK, keys(ep), jnp.asarray(ep), hp),
        copy(params0), epochs, reg, "train.epoch.base")
    sec = min(times)
    out["base"] = dict(sec_per_epoch=sec, updates_per_sec=sp.nnz / sec,
                       compile_sec=reg.span_durations(
                           "train.compile.base")[-1],
                       rmse=ev(p_base))
    emit(f"train.base.{name}", sec, f"ups={sp.nnz / sec:,.0f}")

    # --- tiered schedule + schedule-ordered data (± fused kernels) --------
    # the scheduled paths train on the packed planes (model.PackedParams:
    # 2 scatters/step vs 6 unpacked) and unpack only for the RMSE eval
    with reg.span("train.prep"):
        sched = conflict_free_schedule(np.asarray(sp.rows),
                                       np.asarray(sp.cols),
                                       batch=cf_batch, tiers=tiers,
                                       tier_shrink=shrink,
                                       M=sp.M, N=sp.N, seed=seed)
        sd = model.build_scheduled_data(sp, JK, sched)
        jax.block_until_ready(sd.r)
    prep = reg.span_durations("train.prep")[-1]
    out["schedule"] = dict(prep_sec=prep, prep_per_epoch=prep / epochs,
                           **sched.stats())
    out["step_layout"] = dict(params="packed-planes",
                              scatters_per_step=2, gathers_per_step=2,
                              unpacked_scatters_per_step=6)

    pp0 = model.pack_params(params0)
    for label, use_kernels in (("sched", False), ("kernel", True)):
        impl = resolve_impl("auto") if use_kernels else "ref"
        with reg.span(f"train.compile.{label}"):
            fn = sgd.train_epoch_scheduled.lower(
                pp0, sd, sched, keys(0), jnp.asarray(0), hp,
                use_kernels=use_kernels, impl=impl,
                interpret=jax.default_backend() == "cpu").compile()
        pp_end, times = run_epochs(
            fn, lambda ep: (sd, sched, keys(ep), jnp.asarray(ep), hp),
            copy(pp0), epochs, reg, f"train.epoch.{label}")
        sec = min(times)
        out[label] = dict(sec_per_epoch=sec, updates_per_sec=sp.nnz / sec,
                          compile_sec=reg.span_durations(
                              f"train.compile.{label}")[-1],
                          rmse=ev(model.unpack_params(pp_end)))
        emit(f"train.{label}.{name}", sec,
             f"ups={sp.nnz / sec:,.0f};speedup={out['base']['sec_per_epoch'] / sec:.2f}x")
        if label == "sched" and measure_overhead:
            # instrumentation-cost gate on the hot path: re-run the same
            # compiled fn with spans on vs off (ISSUE 6 target: ≤ 2%)
            out["obs_overhead"] = obs_overhead(
                fn, lambda ep: (sd, sched, keys(ep), jnp.asarray(ep), hp),
                pp0, epochs, copy)
            emit(f"train.obs_overhead.{name}",
                 out["obs_overhead"]["enabled_sec_per_epoch"],
                 f"frac={out['obs_overhead']['overhead_frac']:+.4f}")

    out["speedup_sched"] = out["base"]["sec_per_epoch"] / out["sched"]["sec_per_epoch"]
    out["speedup_kernel"] = out["base"]["sec_per_epoch"] / out["kernel"]["sec_per_epoch"]
    return out


def check(results) -> list[str]:
    """Regression gate against the BENCH_train.json floors."""
    fails = []
    for r in results:
        cf = r["schedule"]["cf_frac"]
        floor = CHECK_SPEEDUP_SMOKE if r["name"] == "smoke" else CHECK_SPEEDUP
        if cf < CHECK_CF_FRAC:
            fails.append(f"{r['name']}: cf_frac {cf:.3f} < {CHECK_CF_FRAC}")
        if r["speedup_sched"] < floor:
            fails.append(f"{r['name']}: speedup_sched "
                         f"{r['speedup_sched']:.2f} < {floor}")
    return fails


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scales", default="small,medium,large")
    ap.add_argument("--epochs", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_train.json")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config + 2 epochs (CI gate; still writes --out)")
    ap.add_argument("--check", action="store_true",
                    help="assert speedup/cf_frac floors after the run "
                         "(exit 1 on regression)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write the run's obs spans as Chrome trace-event "
                         "JSON (load in Perfetto / chrome://tracing)")
    args = ap.parse_args(argv)
    if args.trace:
        obs.enable()   # scoped() registries below collapse onto the
                       # shared one so the trace covers the whole run

    scales = ["smoke"] if args.smoke else [s for s in args.scales.split(",") if s]
    # --check under --smoke gates CI on a wall-clock floor: min-of-2 epochs
    # has almost no rejection against this box's noisy neighbours, so give
    # the gate 5 epochs (smoke epochs are ~10 ms; compiles dominate anyway)
    epochs = (5 if args.check else 2) if args.smoke else args.epochs
    results = []
    for name in scales:
        results.append(bench_scale(name, epochs=epochs, seed=args.seed))

    doc = dict(
        benchmark="bench_train",
        backend=jax.default_backend(),
        jax_version=jax.__version__,
        protocol=dict(epochs=epochs, timing="min sec/epoch over the run "
                      "(noise-robust on shared boxes), AOT-compiled "
                      "(compile excluded), donated params, tiered "
                      "conflict-free schedule; epochs timed as repro.obs "
                      "spans (single timing source), obs_overhead = "
                      "enabled/disabled median-epoch ratio - 1 over "
                      "interleaved order-swapped rounds (target ≤0.02)",
                      floors=dict(cf_frac=CHECK_CF_FRAC,
                                  speedup=CHECK_SPEEDUP,
                                  speedup_smoke=CHECK_SPEEDUP_SMOKE)),
        scales=results,
    )
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    if args.trace:
        obs.write_trace(args.trace)
        print(f"# trace: {args.trace} "
              f"({len(obs.chrome_trace()['traceEvents'])} events)")

    for r in results:
        st = r["schedule"]
        print(f"# {r['name']}: M={r['M']} N={r['N']} nnz={r['nnz']} | "
              f"base {r['base']['sec_per_epoch']:.3f}s/ep | "
              f"sched {r['sched']['sec_per_epoch']:.3f}s/ep "
              f"({r['speedup_sched']:.2f}x, cf={st['cf_frac']:.2f}) | "
              f"kernel {r['kernel']['sec_per_epoch']:.3f}s/ep "
              f"({r['speedup_kernel']:.2f}x) | rmse "
              f"{r['base']['rmse']:.4f}/{r['sched']['rmse']:.4f}/"
              f"{r['kernel']['rmse']:.4f}")

    if args.check:
        fails = check(results)
        for f_ in fails:
            print(f"CHECK FAIL: {f_}", file=sys.stderr)
        if fails:
            sys.exit(1)
        floors = ",".join(
            str(CHECK_SPEEDUP_SMOKE if n == "smoke" else CHECK_SPEEDUP)
            for n in scales)
        print(f"# check passed: cf_frac ≥ {CHECK_CF_FRAC}, "
              f"speedup_sched ≥ {floors} on {','.join(scales)}")
    return results


if __name__ == "__main__":
    main()
