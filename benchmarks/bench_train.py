"""Training-throughput benchmark: the PR-2 hot-path rebuild, measured.

Compares, per synthetic Zipf scale, steady-state epoch time (jit compile
excluded via AOT `.lower().compile()`) and updates/sec for:

  * ``base``   — legacy `sgd.train_epoch`: per-batch B×K binary-search
    assembly + per-batch collision rescaling,
  * ``sched``  — `sgd.train_epoch_scheduled`: per-fit neighbour-gather
    cache + conflict-free schedule (scaled fallback for zipf-head
    leftovers), params donated across epochs,
  * ``kernel`` — same, with the fused `kernels/mf_sgd` step
    (``impl="auto"``: pure-jnp ref on CPU, Pallas elsewhere).

Also trains both paths for equal epochs from the same init and reports the
held-out RMSE of each, so the speedup is shown not to cost accuracy.
Results land in ``BENCH_train.json`` at the repo root (see --out).

    PYTHONPATH=src:. python benchmarks/bench_train.py [--scales small,medium,large]
        [--epochs 5] [--smoke] [--out BENCH_train.json]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import statistics
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import model, sgd, simlsh, topk
from repro.data import synthetic as syn
from repro.data.sparse import conflict_free_schedule, from_coo, train_test_split
from repro.kernels.mf_sgd.ops import resolve_impl

SCALES = {
    # name: (M, N, nnz, cf_batch)   — zipf-tailed via synthetic.generate
    "smoke": (400, 100, 6_000, 96),
    "small": (1_500, 300, 60_000, 256),
    "medium": (3_000, 500, 150_000, 512),
    "large": (8_000, 2_000, 600_000, 1_024),
}
F, K = 32, 16
BATCH = 4096          # legacy-path batch (the trainer default)


def setup(name: str, seed: int = 0):
    M, N, nnz, cf_batch = SCALES[name]
    spec = dataclasses.replace(syn.MOVIELENS_LIKE, M=M, N=N, nnz=nnz)
    rows, cols, vals, _ = syn.generate(spec, seed=seed)
    rng = np.random.default_rng(seed)
    tr, te = train_test_split(rng, rows, cols, vals, 0.1)
    sp = from_coo(*tr, (M, N))
    key = jax.random.PRNGKey(seed)
    lsh = simlsh.SimLSHConfig(G=8, p=2, q=4, band_cap=16)
    sigs = simlsh.encode(sp, lsh, key)
    JK = topk.topk_from_signatures(sigs, jax.random.fold_in(key, 1), K=K,
                                   band_cap=lsh.band_cap)
    params = model.init_from_data(jax.random.fold_in(key, 2), sp, F, K)
    jax.block_until_ready(JK)
    return sp, JK, params, te, cf_batch


def run_epochs(compiled, run_args, params, epochs: int):
    """AOT-compiled epoch fn → (params, [sec/epoch])."""
    times = []
    for ep in range(epochs):
        t0 = time.perf_counter()
        params = compiled(params, *run_args(ep))
        jax.block_until_ready(params.U)
        times.append(time.perf_counter() - t0)
    return params, times


def bench_scale(name: str, *, epochs: int, seed: int = 0) -> dict:
    sp, JK, params0, te, cf_batch = setup(name, seed)
    te_r, te_c, te_v = (jnp.asarray(a) for a in te)
    hp = sgd.Hyper()
    k_ep = jax.random.PRNGKey(seed + 17)
    keys = lambda ep: jax.random.fold_in(k_ep, ep)
    copy = lambda p: jax.tree.map(jnp.copy, p)
    out = dict(name=name, M=sp.M, N=sp.N, nnz=sp.nnz, F=F, K=K,
               batch=BATCH, cf_batch=cf_batch, epochs=epochs)

    # --- base: legacy per-batch-search path -------------------------------
    t0 = time.perf_counter()
    base_fn = sgd.train_epoch.lower(
        params0, sp, JK, keys(0), jnp.asarray(0), hp, batch=BATCH).compile()
    compile_base = time.perf_counter() - t0
    p_base, times = run_epochs(
        base_fn, lambda ep: (sp, JK, keys(ep), jnp.asarray(ep), hp),
        copy(params0), epochs)
    sec = statistics.median(times)
    out["base"] = dict(sec_per_epoch=sec, updates_per_sec=sp.nnz / sec,
                       compile_sec=compile_base,
                       rmse=float(model.rmse(p_base, sp, JK, te_r, te_c, te_v)))
    emit(f"train.base.{name}", sec, f"ups={sp.nnz / sec:,.0f}")

    # --- scheduled + cached gathers (± fused kernels) ---------------------
    t0 = time.perf_counter()
    cache = model.build_gather_cache(sp, JK)
    sched = conflict_free_schedule(np.asarray(sp.rows), np.asarray(sp.cols),
                                   batch=cf_batch, seed=seed)
    jax.block_until_ready(cache.rnb)
    prep = time.perf_counter() - t0
    out["schedule"] = dict(prep_sec=prep, **sched.stats())

    for label, use_kernels in (("sched", False), ("kernel", True)):
        impl = resolve_impl("auto") if use_kernels else "ref"
        t0 = time.perf_counter()
        fn = sgd.train_epoch_scheduled.lower(
            params0, sp, JK, cache, sched, keys(0), jnp.asarray(0), hp,
            use_kernels=use_kernels, impl=impl,
            interpret=jax.default_backend() == "cpu").compile()
        compile_sec = time.perf_counter() - t0
        p_end, times = run_epochs(
            fn, lambda ep: (sp, JK, cache, sched, keys(ep), jnp.asarray(ep), hp),
            copy(params0), epochs)
        sec = statistics.median(times)
        out[label] = dict(
            sec_per_epoch=sec, updates_per_sec=sp.nnz / sec,
            compile_sec=compile_sec,
            rmse=float(model.rmse(p_end, sp, JK, te_r, te_c, te_v)))
        emit(f"train.{label}.{name}", sec,
             f"ups={sp.nnz / sec:,.0f};speedup={out['base']['sec_per_epoch'] / sec:.2f}x")

    out["speedup_sched"] = out["base"]["sec_per_epoch"] / out["sched"]["sec_per_epoch"]
    out["speedup_kernel"] = out["base"]["sec_per_epoch"] / out["kernel"]["sec_per_epoch"]
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scales", default="small,medium,large")
    ap.add_argument("--epochs", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_train.json")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config + 2 epochs (CI gate; still writes --out)")
    args = ap.parse_args(argv)

    scales = ["smoke"] if args.smoke else [s for s in args.scales.split(",") if s]
    epochs = 2 if args.smoke else args.epochs
    results = []
    for name in scales:
        results.append(bench_scale(name, epochs=epochs, seed=args.seed))

    doc = dict(
        benchmark="bench_train",
        backend=jax.default_backend(),
        jax_version=jax.__version__,
        protocol=dict(epochs=epochs, timing="median sec/epoch, AOT-compiled "
                      "(compile excluded), donated params"),
        scales=results,
    )
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")

    for r in results:
        print(f"# {r['name']}: M={r['M']} N={r['N']} nnz={r['nnz']} | "
              f"base {r['base']['sec_per_epoch']:.3f}s/ep | "
              f"sched {r['sched']['sec_per_epoch']:.3f}s/ep "
              f"({r['speedup_sched']:.2f}x) | "
              f"kernel {r['kernel']['sec_per_epoch']:.3f}s/ep "
              f"({r['speedup_kernel']:.2f}x) | rmse "
              f"{r['base']['rmse']:.4f}/{r['sched']['rmse']:.4f}/"
              f"{r['kernel']['rmse']:.4f}")
    return results


if __name__ == "__main__":
    main()
