"""Table 10: CULSH-MF vs deep models (GMF / MLP / NeuMF), time-to-HR.

Implicit-feedback protocol on synthetic interactions: HR@10 with sampled
negatives; we report wall-clock to reach a shared HR target (the paper's
claim: CULSH-MF needs ~1e-4 of the DL training time at equal HR).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import ncf
from repro.core.simlsh import SimLSHConfig
from repro.train.trainer import FitConfig, fit


def make_implicit(M=400, N=100, per_user=8, seed=0):
    rng = np.random.default_rng(seed)
    # planted preference: user u likes items around (u*7) % N
    users = np.repeat(np.arange(M), per_user).astype(np.int32)
    items = ((users * 7 + rng.integers(0, 6, len(users))) % N).astype(np.int32)
    # binary ratings (implicit)
    vals = np.ones(len(users), np.float32)
    key = users.astype(np.int64) * N + items
    _, uq = np.unique(key, return_index=True)
    return users[uq], items[uq], vals[uq], M, N


def hr_mf(params, JK, users, pos, cands, topk=10):
    from repro.core.model import Params

    def score(u, it):
        return (params.U[u] @ params.V[it] + params.mu + params.b[u]
                + params.bh[it])

    def one(u, p, cs):
        items = jnp.concatenate([p[None], cs])
        z = jax.vmap(lambda it: score(u, it))(items)
        return (jnp.sum(z > z[0]) < topk).astype(jnp.float32)

    return float(jnp.mean(jax.vmap(one)(users, pos, cands)))


def run_all():
    users, items, vals, M, N = make_implicit()
    rng = np.random.default_rng(1)
    # held-out positives: last interaction per user
    te_mask = np.zeros(len(users), bool)
    _, last = np.unique(users[::-1], return_index=True)
    te_mask[len(users) - 1 - last] = True
    tr = (users[~te_mask], items[~te_mask], vals[~te_mask])
    te_u, te_i = users[te_mask], items[te_mask]
    cands = rng.integers(0, N, (len(te_u), 50)).astype(np.int32)

    # CULSH-MF on implicit data: positives=1 + sampled negatives=0 (the
    # paper switches to a discriminative loss for implicit feedback; the
    # MF trainer gets the same pos+neg set the NCF models see)
    t0 = time.perf_counter()
    negs_mf = rng.integers(0, N, 3 * len(tr[0])).astype(np.int32)
    tr_mf = (np.concatenate([tr[0]] * 4),
             np.concatenate([tr[1], negs_mf]),
             np.concatenate([tr[2], np.zeros(3 * len(tr[0]), np.float32)]))
    from repro.core.sgd import Hyper
    hp = Hyper(a_u=0.2, a_v=0.2, a_b=0.1, a_bh=0.1, beta=0.02)
    cfg = FitConfig(F=16, K=8, epochs=40, batch=2048, method="simlsh",
                    lsh=SimLSHConfig(G=8, p=1, q=10, psi_pow=1.0), hp=hp,
                    loss="bce", eval_every=0)
    res = fit(tr_mf, (te_u, te_i, np.ones(len(te_u), np.float32)),
              (M, N), cfg)
    t_culsh = time.perf_counter() - t0
    hr_c = hr_mf(res.params, res.JK, jnp.asarray(te_u), jnp.asarray(te_i),
                 jnp.asarray(cands))
    emit("table10.culshmf", t_culsh, f"hr10={hr_c:.3f}")

    # NCF family
    negs = rng.integers(0, N, len(tr[0])).astype(np.int32)
    i_all = np.concatenate([tr[0], tr[0]])
    j_all = np.concatenate([tr[1], negs])
    y_all = np.concatenate([np.ones(len(tr[0])), np.zeros(len(tr[0]))])
    for kind in ("gmf", "mlp", "neumf"):
        c = ncf.NCFConfig(M=M, N=N, F=16, mlp_layers=(32, 16), kind=kind)
        p = ncf.init(c, jax.random.PRNGKey(0))
        m = jax.tree.map(jnp.zeros_like, p)
        v = jax.tree.map(jnp.zeros_like, p)
        t0 = time.perf_counter()
        for t in range(1, 200):
            p, m, v = ncf.adam_step(p, m, v, jnp.float32(t), c, i_all, j_all,
                                    y_all, lr=2e-2)
        jax.block_until_ready(jax.tree.leaves(p)[0])
        t_dl = time.perf_counter() - t0
        hr = float(ncf.hit_ratio(p, c, te_u, te_i, cands, topk=10))
        emit(f"table10.{kind}", t_dl, f"hr10={hr:.3f};x_culsh={t_dl/t_culsh:.1f}")
