"""Paper-table benchmarks (Tables 4, 6, 7, 8, 9; Figs 6–10).

Each function reproduces one table/figure's protocol at reduced scale and
emits ``name,us_per_call,derived`` CSV rows.  Accuracy claims are validated
as *relative orderings* (DESIGN.md §8.4): simLSH ≈ GSM ≫ no-neighbour,
CULSH-MF descends faster than CUSGD++, online ≈ retrain, etc.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import datasets, emit
from repro.core import gsm
from repro.core.simlsh import SimLSHConfig
from repro.data.sparse import from_coo
from repro.train.trainer import FitConfig, build_neighbours, fit

LSH = SimLSHConfig(G=8, p=1, q=20, band_cap=16)


def _fit(ds, method, F=16, K=8, epochs=6, lsh=LSH, psi_pow=None):
    spec = ds["spec"]
    lshc = lsh if psi_pow is None else dataclasses.replace(lsh, psi_pow=psi_pow)
    cfg = FitConfig(F=F, K=K, epochs=epochs, batch=4096, method=method,
                    lsh=lshc, eval_every=epochs)
    t0 = time.perf_counter()
    res = fit(ds["train"], ds["test"], (spec.M, spec.N), cfg)
    total = time.perf_counter() - t0
    return res, total


def bench_sgd_engines(dss):
    """Table 4 / Fig 6: per-epoch cost of the SGD engines."""
    for name, ds in dss.items():
        res_mf, t_mf = _fit(ds, "none", epochs=6)
        res_full, t_full = _fit(ds, "simlsh", epochs=6)
        emit(f"table4.cusgdpp.{name}", t_mf / 6,
             f"rmse={res_mf.history[-1][2]:.4f}")
        emit(f"table4.culshmf.{name}", t_full / 6,
             f"rmse={res_full.history[-1][2]:.4f};nbr_s={res_full.neighbour_seconds:.2f}")


def bench_serial_vs_lsh(dss):
    """Table 6 / Fig 1: GSM O(N²) vs simLSH O(q·N) — time vs N.

    The paper's complexity claim is the *scaling*: GSM grows ~N², simLSH
    ~N (per-item density held fixed), so the crossover appears as N grows."""
    from repro.data import synthetic as syn
    import dataclasses as dc
    key = jax.random.PRNGKey(0)
    for N in (500, 2000, 6000):
        spec = dc.replace(syn.MOVIELENS_LIKE, M=3000, N=N, nnz=N * 120)
        rows, cols, vals, _ = syn.generate(spec, seed=1)
        sp = from_coo(rows, cols, vals, (spec.M, N))
        row = []
        for method in ("gsm", "simlsh"):
            cfg = FitConfig(K=8, method=method, lsh=LSH)
            _, secs, _, _ = build_neighbours(sp, cfg, key)
            row.append(secs)
            emit(f"table6.neighbour.{method}.N{N}", secs,
                 f"nnz={sp.nnz}")
        emit(f"table6.ratio.N{N}", 0.0,
             f"gsm_over_simlsh={row[0]/max(row[1],1e-9):.2f}x")


def bench_topk_methods(dss):
    """Table 7 / Fig 7: RMSE + time + space for each Top-K method."""
    for name, ds in dss.items():
        spec = ds["spec"]
        psi_pow = 4.0 if name == "yahoo" else 2.0
        for method in ("rand", "gsm", "simlsh", "rp_cos", "minhash"):
            res, total = _fit(ds, method, psi_pow=psi_pow)
            if method == "gsm":
                space = 4.0 * spec.N * spec.N / 2**20        # full GSM, MB
            elif method == "rand":
                space = 0.0
            else:
                space = 4.0 * spec.N * LSH.q / 2**20          # q signatures
            emit(f"table7.{method}.{name}", res.neighbour_seconds,
                 f"rmse={res.history[-1][2]:.4f};space_mb={space:.2f};"
                 f"total_s={total:.1f}")


def bench_pq(dss):
    """Fig 8: RMSE vs (p, q)."""
    ds = dss["movielens"]
    for p in (1, 2, 3):
        for q in (5, 20):
            lsh = SimLSHConfig(G=8, p=p, q=q, band_cap=16)
            res, _ = _fit(ds, "simlsh", lsh=lsh)
            emit(f"fig8.p{p}.q{q}", res.neighbour_seconds,
                 f"rmse={res.history[-1][2]:.4f}")


def bench_fk(dss):
    """Fig 9/10: RMSE and epoch time vs (F, K); CULSH-MF vs CUSGD++."""
    ds = dss["movielens"]
    for F in (16, 32):
        for K in (8, 16):
            res, total = _fit(ds, "simlsh", F=F, K=K)
            emit(f"fig9.F{F}.K{K}", total / 6,
                 f"rmse={res.history[-1][2]:.4f}")
    res_mf, t_mf = _fit(ds, "none", F=32)
    res_nb, t_nb = _fit(ds, "simlsh", F=32, K=8)
    emit("fig10.cusgdpp.F32", t_mf / 6, f"rmse={res_mf.history[-1][2]:.4f}")
    emit("fig10.culshmf.F32K8", t_nb / 6, f"rmse={res_nb.history[-1][2]:.4f}")


def bench_noise(dss):
    """Table 8: RMSE deviation under rating noise."""
    from repro.data.synthetic import add_noise
    ds = dss["movielens"]
    spec = ds["spec"]
    rng = np.random.default_rng(1)
    base_full, _ = _fit(ds, "simlsh")
    base_mf, _ = _fit(ds, "none")
    for rate in (0.01, 0.001):
        tr_r, tr_c, tr_v = ds["train"]
        noisy = dict(ds, train=(tr_r, tr_c,
                                add_noise(rng, tr_v, rate, spec.rmin,
                                          spec.rmax)))
        n_full, _ = _fit(noisy, "simlsh")
        n_mf, _ = _fit(noisy, "none")
        dev_full = abs(n_full.history[-1][2] - base_full.history[-1][2])
        dev_mf = abs(n_mf.history[-1][2] - base_mf.history[-1][2])
        emit(f"table8.noise{rate}", 0.0,
             f"dev_culshmf={dev_full:.5f};dev_cusgdpp={dev_mf:.5f}")


def bench_online(dss):
    """Table 9: online update vs full retrain (time + RMSE delta)."""
    from repro.core import model, online
    from repro.core.sgd import Hyper
    ds = dss["movielens"]
    spec = ds["spec"]
    rows, cols, vals = ds["train"]
    # split: original = rows with id < M−Δ, new = the rest (paper Table 9)
    dM, dN = spec.M // 50, spec.N // 50
    M0, N0 = spec.M - dM, spec.N - dN
    old = (rows < M0) & (cols < N0)
    res, t_full = _fit(ds, "simlsh")

    cfg = FitConfig(F=16, K=8, epochs=6, method="simlsh", lsh=LSH,
                    eval_every=6)
    t0 = time.perf_counter()
    res_old = fit((rows[old], cols[old], vals[old]), ds["test"],
                  (M0, N0), cfg)
    st = online.OnlineState(params=res_old.params, S=res_old.S,
                            JK=res_old.JK,
                            sp=from_coo(rows[old], cols[old], vals[old],
                                        (M0, N0)),
                            M=M0, N=N0, hash_key=res_old.hash_key)
    st2 = online.online_update(st, rows[~old], cols[~old], vals[~old],
                               LSH, Hyper(), jax.random.PRNGKey(0),
                               M_new=spec.M, N_new=spec.N, K=8, epochs=3)
    t_online = time.perf_counter() - t0
    te_r, te_c, te_v = (jnp.asarray(a) for a in ds["test"])
    rmse_online = float(model.rmse(st2.params, st2.sp, st2.JK,
                                   te_r, te_c, te_v))
    emit("table9.online", t_online,
         f"rmse_online={rmse_online:.4f};rmse_retrain={res.history[-1][2]:.4f};"
         f"retrain_s={t_full:.1f}")


def run_all(scale=1.0):
    dss = datasets(scale)
    bench_sgd_engines(dss)
    bench_serial_vs_lsh(dss)
    bench_topk_methods(dss)
    bench_pq(dss)
    bench_fk(dss)
    bench_noise(dss)
    bench_online(dss)
