"""Serving benchmark: candidate-only (repro.serve) vs full U·Vᵀ scoring.

Measures, per synthetic catalog size N:

  * ``serve.full.qps``  — exact dense top-N (the seed `recommend` path),
  * ``serve.cand.qps``  — LSH retrieval + fused candidate-score kernel,
  * ``serve.cand.recall`` — recall@topn of the candidate path against the
    exact top-N, on a held-out probe user set.

The catalog is *planted*: items and users are partitioned into preference
groups, every item is rated by users of its own group, and factors point
along the group direction.  This is the regime the paper's LSH bucketing
targets (co-rated items really are neighbours), so it exercises the whole
retrieval stack — simLSH encode → bucketed index → candidate scoring —
without a multi-hour training run at N = 10⁵..10⁶.

    PYTHONPATH=src:. python benchmarks/bench_serve.py [--sizes 10000,100000]
        [--with-1m] [--batch 256] [--full-batches N] [--cand-batches N]
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import simlsh, topk
from repro.core.model import Params
from repro.data.sparse import from_coo
from repro.serve import RecsysService, ServeConfig, build_index, full_topn


@dataclasses.dataclass(frozen=True)
class CatalogSpec:
    N: int                     # items
    items_per_group: int = 50
    users_per_group: int = 32
    deg: int = 24              # raters per item (out of users_per_group)
    F: int = 48                # factor dim
    group_scale: float = 1.6   # strength of the planted group direction
    noise: float = 0.12        # factor noise around the group direction
    bias_std: float = 0.15


def make_catalog(spec: CatalogSpec, seed: int = 0):
    """Planted-group catalog → (Params, SparseMatrix, group_of_item)."""
    rng = np.random.default_rng(seed)
    N, F = spec.N, spec.F
    G = max(1, N // spec.items_per_group)
    M = G * spec.users_per_group
    g_item = (np.arange(N) // spec.items_per_group) % G
    g_user = np.arange(M) // spec.users_per_group

    gdir = rng.normal(0, 1, (G, F))
    gdir /= np.linalg.norm(gdir, axis=1, keepdims=True)
    gdir *= spec.group_scale
    U = (gdir[g_user] + spec.noise * rng.normal(0, 1, (M, F))).astype(np.float32)
    V = (gdir[g_item] + spec.noise * rng.normal(0, 1, (N, F))).astype(np.float32)
    bh = (spec.bias_std * rng.normal(0, 1, N)).astype(np.float32)

    # each item rated by `deg` distinct users of its group
    pick = np.argsort(rng.random((N, spec.users_per_group)), axis=1)
    raters = (pick[:, :spec.deg] + g_item[:, None] * spec.users_per_group)
    rows = raters.reshape(-1).astype(np.int32)
    cols = np.repeat(np.arange(N, dtype=np.int32), spec.deg)
    dots = np.einsum("ef,ef->e", U[rows], V[cols])
    vals = np.clip(3.0 + 1.5 * dots, 1.0, 5.0).astype(np.float32)

    params = Params(
        U=jnp.asarray(U), V=jnp.asarray(V),
        b=jnp.zeros((M,), jnp.float32), bh=jnp.asarray(bh),
        W=jnp.zeros((N, 1), jnp.float32), C=jnp.zeros((N, 1), jnp.float32),
        mu=jnp.asarray(3.0, jnp.float32))
    sp = from_coo(rows, cols, vals, (M, N))
    return params, sp, g_item


def run_mode(svc: RecsysService, user_stream, batch: int) -> dict:
    svc.warmup()
    for users in user_stream:
        svc.submit(users)
    svc.flush()
    return svc.stats()


def recall_at(svc: RecsysService, params, probe_users, topn: int) -> float:
    exact_s, exact_i = full_topn(params, probe_users, topn=topn)
    svc.take_results()  # drain leftovers from the timing stream
    svc.submit(np.asarray(probe_users))
    svc.flush()
    got = np.concatenate([r[2] for r in svc.take_results()])[:probe_users.shape[0]]
    exact_i = np.asarray(exact_i)
    hits = sum(len(set(got[u]) & set(exact_i[u])) for u in range(got.shape[0]))
    return hits / (got.shape[0] * topn)


def bench_size(N: int, *, batch: int, full_batches: int, cand_batches: int,
               probe: int, topn: int, seed: int = 0, lsh=None, serve=None):
    spec = CatalogSpec(N=N)
    t0 = time.perf_counter()
    params, sp, _ = make_catalog(spec, seed=seed)
    M = params.U.shape[0]

    # 16-bit band signatures: ≈1.5–2.5 random collisions per bucket at
    # N = 10⁴..10⁵, so bucket windows stay dominated by true neighbours
    lsh = lsh or simlsh.SimLSHConfig(G=8, p=2, q=10, band_cap=16)
    key = jax.random.PRNGKey(seed)
    sigs = simlsh.encode(sp, lsh, key)
    JK = topk.topk_from_signatures(sigs, jax.random.fold_in(key, 1), K=16,
                                   band_cap=lsh.band_cap)
    index = build_index(sigs, tail_cap=128)
    jax.block_until_ready(index.sorted_sigs)
    emit(f"serve.setup.N{N}", time.perf_counter() - t0,
         f"M={M};nnz={sp.nnz}")

    cfg = serve or ServeConfig(topn=topn, micro_batch=batch, C=512,
                               n_seeds=16, cap=8, n_popular=64, tile_b=64)
    rng = np.random.default_rng(seed + 1)
    stream = lambda n: [rng.integers(0, M, batch).astype(np.int32)
                        for _ in range(n)]

    full_svc = RecsysService(params, index, sp,
                             dataclasses.replace(cfg, mode="full"), JK=JK)
    st_full = run_mode(full_svc, stream(full_batches), batch)
    emit(f"serve.full.qps.N{N}", 1.0 / max(st_full["qps"], 1e-9),
         f"qps={st_full['qps']:.0f};p50_ms={st_full['p50_ms']:.1f}")

    cand_svc = RecsysService(params, index, sp, cfg, JK=JK)
    st_cand = run_mode(cand_svc, stream(cand_batches), batch)
    emit(f"serve.cand.qps.N{N}", 1.0 / max(st_cand["qps"], 1e-9),
         f"qps={st_cand['qps']:.0f};p50_ms={st_cand['p50_ms']:.1f}")

    probe_users = jnp.asarray(rng.integers(0, M, probe), jnp.int32)
    rec = recall_at(cand_svc, params, probe_users, topn)
    emit(f"serve.cand.recall.N{N}", rec, f"topn={topn};probe={probe}")
    return dict(full_qps=st_full["qps"], cand_qps=st_cand["qps"], recall=rec)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", default="10000,100000",
                    help="comma-separated catalog sizes")
    ap.add_argument("--with-1m", action="store_true",
                    help="append a 1M-item catalog (reduced degree)")
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--full-batches", type=int, default=8)
    ap.add_argument("--cand-batches", type=int, default=16)
    ap.add_argument("--probe", type=int, default=256)
    ap.add_argument("--topn", type=int, default=10)
    args = ap.parse_args(argv)

    sizes = [int(s) for s in args.sizes.split(",") if s]
    if args.with_1m:
        sizes.append(1_000_000)
    out = {}
    for N in sizes:
        kw = {}
        if N >= 1_000_000:
            # 18-bit signatures: ~4 random collisions/bucket at 1M, offset
            # by a wider candidate budget (C=768)
            kw["lsh"] = simlsh.SimLSHConfig(G=9, p=2, q=10, band_cap=16)
            kw["serve"] = ServeConfig(topn=args.topn, micro_batch=args.batch,
                                      C=768, n_seeds=16, cap=8, n_popular=64,
                                      tile_b=64)
        out[N] = bench_size(N, batch=args.batch,
                            full_batches=args.full_batches,
                            cand_batches=args.cand_batches,
                            probe=args.probe, topn=args.topn, **kw)
    for N, r in out.items():
        speed = r["cand_qps"] / max(r["full_qps"], 1e-9)
        print(f"# N={N}: full {r['full_qps']:,.0f} qps | cand "
              f"{r['cand_qps']:,.0f} qps ({speed:.1f}x) | "
              f"recall@{args.topn} {r['recall']:.3f}")
    return out


if __name__ == "__main__":
    main()
